"""Control plane: durable persistence, async admission, adaptive scheduling.

The two ISSUE 3 acceptance invariants live here:

* **kill-and-restore invariance** — a stream snapshotted mid-session and
  resumed in a fresh engine/store produces bit-identical per-chunk outputs
  and uncertainty summaries to the uninterrupted run, on all three
  backends, including across a ``chunk_capacity`` change at resume;
* **admission drains under churn** — 3× store capacity admitted through
  the queue with random mid-stream evictions: every session eventually
  streams to completion, no mask-row is shared by two live sessions, and
  no chunk is dropped.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import checkpoint
from repro.core import classifier as clf, mcd
from repro.serve import (AdmissionQueue, AdaptiveTickScheduler, CapacityError,
                         DrainRejected, JsonlSink, QueueFull, Session,
                         SessionStore, StreamingEngine, TickMetrics,
                         pow2_ladder, restore_store, snapshot_store,
                         summarize)
from repro.serve.scheduler import percentile

BACKENDS = ("reference", "pallas_step", "pallas_seq")


def _cfg_params(s=3, seed=3, hidden=8):
    cfg = clf.ClassifierConfig(
        hidden=hidden, num_layers=2, num_classes=4,
        mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=s, seed=seed))
    return cfg, clf.init(jax.random.key(0), cfg)


class TestAdmissionQueue:
    def test_priority_then_fifo(self):
        q = AdmissionQueue(max_pending=8)
        store = SessionStore(n_samples=1, max_sessions=3)
        q.submit("low", priority=0)
        q.submit("hi", priority=9)
        q.submit("mid-a", priority=5)
        q.submit("mid-b", priority=5)
        assert [t.sid for t in q.waiting()] == ["hi", "mid-a", "mid-b", "low"]
        admitted = q.drain(store)
        # ICU first, FIFO within the class, one left waiting
        assert [s.sid for s in admitted] == ["hi", "mid-a", "mid-b"]
        assert q.depth == 1 and "low" in q

    def test_queue_full_is_typed_backpressure(self):
        q = AdmissionQueue(max_pending=2)
        q.submit("a")
        q.submit("b")
        with pytest.raises(QueueFull, match="shed load"):
            q.submit("c")
        assert isinstance(QueueFull("x"), RuntimeError)  # callers may be old

    def test_duplicate_and_mismatched_submit(self):
        q = AdmissionQueue()
        q.submit("a")
        with pytest.raises(ValueError, match="already queued"):
            q.submit("a")
        sess = SessionStore(n_samples=1).admit("b")
        with pytest.raises(ValueError, match="sid"):
            q.submit("zzz", session=sess)

    def test_cancel_is_lazy_but_effective(self):
        q = AdmissionQueue()
        store = SessionStore(n_samples=1, max_sessions=4)
        q.submit("a", priority=2)
        q.submit("b")
        assert q.cancel("a") and not q.cancel("a")
        assert [s.sid for s in q.drain(store)] == ["b"]
        assert q.depth == 0

    def test_cancel_churn_keeps_heap_bounded(self):
        """A store pinned at capacity never drains; submit/cancel churn
        must not grow the heap (lazy deletion is compacted)."""
        q = AdmissionQueue(max_pending=4)
        for i in range(500):
            q.submit(f"s{i}")
            q.cancel(f"s{i}")
        assert q.depth == 0 and len(q._heap) <= 8

    def test_drain_reattaches_evicted_carry(self):
        store = SessionStore(n_samples=2, seed=0, max_sessions=1)
        evicted = store.admit("old")
        store.evict("old")
        store.admit("hog")
        q = AdmissionQueue()
        q.submit("old", session=evicted)
        assert q.drain(store) == []                 # no room yet
        store.evict("hog")
        (back,) = q.drain(store)
        assert back is evicted                      # same draw, same rows
        np.testing.assert_array_equal(np.asarray(back.rows), [0, 1])

    def test_drain_is_exception_safe(self):
        """Regression (ISSUE 4): a re-attach the store rejects mid-drain
        used to abort the drain — the already-admitted sessions were never
        reported and every ticket behind the bad one was starved for the
        tick.  Now the drain finishes first, then raises the typed
        DrainRejected carrying the partial result."""
        store = SessionStore(n_samples=2, seed=7, max_sessions=4)
        bad = SessionStore(n_samples=2, seed=999).admit("bad")  # wrong seed
        q = AdmissionQueue()
        q.submit("hi", priority=9)
        q.submit("bad", priority=5, session=bad)
        q.submit("low", priority=0)
        with pytest.raises(DrainRejected, match="bad") as exc_info:
            q.drain(store)
        err = exc_info.value
        assert isinstance(err, RuntimeError)        # typed, but compatible
        # both healthy tickets went live — including the one queued BEHIND
        # the bad one — and both are reported in the partial result
        assert [s.sid for s in err.admitted] == ["hi", "low"]
        assert store.active == ["hi", "low"]
        # the poison ticket is gone from the queue (it can never succeed)
        assert [(t.sid, type(e).__name__) for t, e in err.rejected] == \
            [("bad", "ValueError")]
        assert q.depth == 0 and "bad" not in q

    def test_engine_contains_drain_rejection(self):
        """The poison is the ticket owner's problem, not the caller's:
        close_session still returns the evicted carry, the healthy ticket
        behind the poison still goes live, and the reject is recorded in
        engine.dropped_admissions instead of raised at an unrelated call."""
        cfg, params = _cfg_params()
        eng = StreamingEngine(params, cfg, max_sessions=2)
        eng.open_session("live1")                    # rows 0..2, stays live
        eng.open_session("hog")                      # rows 3..5, evicted below
        # passes admit()'s eager seed/chain checks but collides on rows with
        # live1 — only SessionStore.attach can reject it, mid-drain
        clash = SessionStore(n_samples=3, seed=3).admit("clash")
        eng.admit("clash", priority=9, session=clash)
        eng.admit("ok", priority=0)                  # queued behind the poison
        evicted = eng.close_session("hog")           # triggers the drain
        assert evicted.sid == "hog"                  # carry not lost
        assert eng.active_sessions == ["live1", "ok"]
        assert eng.queued_sessions == []
        (ticket, err), = eng.dropped_admissions
        assert ticket.sid == "clash" and "collide" in str(err)

    def test_dropped_threads_through_metrics(self, tmp_path):
        """Regression (ISSUE 8): drops were visible only in the in-memory
        ``dropped_admissions`` deque — invisible to the JSONL trail and
        ``summarize``.  A mid-drain reject must land as ``dropped`` on the
        next tick's TickMetrics, serialize through JsonlSink, sum in
        summarize, and reset (not double-report) on the following tick."""
        cfg, params = _cfg_params()
        path = tmp_path / "ticks.jsonl"
        sink = JsonlSink(str(path))
        eng = StreamingEngine(params, cfg, max_sessions=2,
                              metrics_sink=sink)
        eng.open_session("live1")                    # rows 0..2
        eng.open_session("hog")
        clash = SessionStore(n_samples=3, seed=3).admit("clash")
        eng.admit("clash", priority=9, session=clash)
        eng.close_session("hog")                     # drain drops "clash"
        assert len(eng.dropped_admissions) == 1
        m1 = (eng.step({"live1": jnp.ones((2, 1))}), eng.last_metrics)[1]
        assert m1.dropped == 1
        m2 = (eng.step({"live1": jnp.ones((2, 1))}), eng.last_metrics)[1]
        assert m2.dropped == 0                       # reported once
        assert summarize(list(eng.metrics))["dropped"] == 1
        recs = [__import__("json").loads(line)
                for line in path.read_text().splitlines()]
        assert [r["dropped"] for r in recs] == [1, 0]
        assert all(r["tenant"] is None for r in recs)
        sink.close()

    def test_admit_reraises_own_tickets_rejection(self):
        """When the synchronous drain inside admit() rejects the caller's
        OWN ticket, admit must raise — returning None would read as
        'queued' while the ticket is permanently gone."""
        cfg, params = _cfg_params()
        eng = StreamingEngine(params, cfg, max_sessions=2)
        eng.open_session("live1")                    # rows 0..2
        # passes the eager seed/chain checks; only attach sees the collision
        clash = SessionStore(n_samples=3, seed=3).admit("clash")
        with pytest.raises(ValueError, match="collide"):
            eng.admit("clash", session=clash)
        assert eng.queued_sessions == []             # not silently parked
        assert len(eng.dropped_admissions) == 0      # raised, not swallowed
        assert eng.active_sessions == ["live1"]

    def test_drain_reports_multiple_rejections(self):
        store = SessionStore(n_samples=2, seed=7, max_sessions=4)
        other = SessionStore(n_samples=2, seed=999)
        q = AdmissionQueue()
        q.submit("x", priority=3, session=other.admit("x"))
        q.submit("ok")
        q.submit("y", priority=1, session=other.admit("y"))
        with pytest.raises(DrainRejected) as exc_info:
            q.drain(store)
        err = exc_info.value
        assert [s.sid for s in err.admitted] == ["ok"]
        assert sorted(t.sid for t, _ in err.rejected) == ["x", "y"]
        assert store.active == ["ok"] and q.depth == 0

    def test_store_capacity_error_stays_runtimeerror(self):
        """The typed exception contract: CapacityError subclasses
        RuntimeError so pre-PR 3 callers keep working."""
        store = SessionStore(n_samples=1, max_sessions=1)
        store.admit("a")
        with pytest.raises(RuntimeError):
            store.admit("b")
        with pytest.raises(CapacityError):
            store.attach(SessionStore(n_samples=1).admit("c"))


class TestScheduler:
    def test_pow2_ladder(self):
        assert pow2_ladder(512) == (8, 16, 32, 64, 128, 256, 512)
        assert pow2_ladder(100) == (8, 16, 32, 64, 100)
        assert pow2_ladder(1) == (1,)

    def test_pow2_ladder_honors_max_capacity(self):
        """Regression (ISSUE 4): pow2_ladder(4) returned (8,) — a single
        rung *above* the operator's cap, so the scheduler silently accepted
        chunks longer than the stated maximum.  No rung may exceed the cap,
        and the top rung must equal it (chunks up to the cap still fit)."""
        assert pow2_ladder(4) == (4,)
        for cap in (1, 3, 4, 7, 8, 9, 100, 512):
            ladder = pow2_ladder(cap)
            assert ladder[-1] == cap
            assert all(r <= cap for r in ladder)
            assert list(ladder) == sorted(set(ladder))
        # and the scheduler built on it now rejects what the operator capped
        s = AdaptiveTickScheduler(pow2_ladder(4))
        assert s.max_capacity == 4 and s.plan([4]) == 4
        with pytest.raises(ValueError, match="ladder"):
            s.plan([5])

    def test_rung_tracks_the_window(self):
        s = AdaptiveTickScheduler((4, 16, 64), window=4)
        assert s.plan([3, 2]) == 4
        assert s.plan([10]) == 16
        # windowed max keeps the rung up while the burst is in view
        assert s.plan([2]) == 16
        for _ in range(4):
            s.plan([2])
        assert s.plan([2]) == 4                     # burst aged out

    def test_current_tick_always_covered(self):
        s = AdaptiveTickScheduler((4, 16, 64), percentile=50.0, window=64)
        for _ in range(10):
            s.plan([2])
        assert s.plan([2, 60]) == 64                # outlier climbs anyway

    def test_over_ladder_rejected(self):
        s = AdaptiveTickScheduler((4, 8))
        with pytest.raises(ValueError, match="ladder"):
            s.plan([9])

    def test_state_roundtrip(self):
        s = AdaptiveTickScheduler((4, 16, 64), window=8)
        s.plan([10, 3])
        s2 = AdaptiveTickScheduler((4, 16, 64), window=8)
        s2.load_state(s.state())
        assert s2.plan([2]) == 16                   # remembers the 10

    def test_engine_auto_bounds_shapes_and_matches_dynamic(self):
        """chunk_capacity='auto' serves bit-identically to dynamic mode and
        compiles at most len(ladder) shapes; metrics are emitted per tick."""
        cfg, params = _cfg_params()
        T = 11
        sig = jax.random.normal(jax.random.key(1), (T, 1))
        dyn = StreamingEngine(params, cfg, max_sessions=2)
        aut = StreamingEngine(params, cfg, max_sessions=2,
                              chunk_capacity="auto", ladder=(4, 8))
        for eng in (dyn, aut):
            eng.open_session("a")
        want = got = None
        for a, b in ((0, 4), (4, 5), (5, T)):
            want = dyn.step({"a": sig[a:b]})["a"]
            got = aut.step({"a": sig[a:b]})["a"]
        np.testing.assert_array_equal(np.asarray(got.summary.probs),
                                      np.asarray(want.summary.probs))
        assert aut.tick == 3 and len(aut.metrics) == 3
        caps = {m.capacity for m in aut.metrics}
        assert caps <= {4, 8}
        m = aut.last_metrics
        assert m.queue_depth == 0 and 0.0 <= m.pad_waste < 1.0
        assert m.live_steps == T - 5 and m.tokens_per_sec > 0
        assert m.live_chain_steps == m.live_steps * cfg.mcd.n_samples
        agg = summarize(aut.metrics)
        assert agg["ticks"] == 3 and set(agg["capacities_used"]) == caps
        assert agg["live_chain_steps"] == T * cfg.mcd.n_samples
        assert 0.0 <= agg["pad_waste"] < 1.0
        assert summarize([]) == {"ticks": 0}

    def test_metrics_window_is_bounded(self):
        cfg, params = _cfg_params(s=2)
        eng = StreamingEngine(params, cfg, max_sessions=1, metrics_window=2)
        eng.open_session("a")
        for _ in range(4):
            eng.step({"a": jnp.ones((2, 1))})
        assert len(eng.metrics) == 2 and eng.tick == 4
        assert eng.last_metrics.tick == 3

    def test_percentile_is_nearest_rank(self):
        vals = list(range(1, 21))                   # 1..20
        assert percentile(vals, 50) == 10
        assert percentile(vals, 95) == 19
        assert percentile(vals, 100) == 20
        assert percentile([7.0], 95) == 7.0
        assert percentile([], 95) == 0.0

    def test_summarize_reports_tail_latency(self):
        def m(i, dur):
            return TickMetrics(tick=i, capacity=4, n_chunks=1, live_rows=2,
                               batch_rows=2, queue_depth=0, live_steps=4,
                               live_chain_steps=8, padded_steps=8,
                               pad_waste=0.0, duration_s=dur,
                               tokens_per_sec=8 / dur,
                               queue_wait_s=0.1 * i, compiles=i % 2)
        agg = summarize([m(i, dur) for i, dur in
                         enumerate([1.0] * 19 + [100.0])])
        # the mean would hide the one 100 s tick; the tail must not
        assert agg["duration_s_p50"] == 1.0
        assert agg["duration_s_p95"] == 1.0
        assert agg["duration_s_p95"] < 100.0 <= max(
            [1.0] * 19 + [100.0])
        assert summarize([m(i, 100.0) for i in range(20)])[
            "duration_s_p95"] == 100.0
        assert agg["tokens_per_sec_p50"] == 8.0
        assert agg["queue_wait_s_p95"] == pytest.approx(1.8)
        assert agg["compiles"] == 10

    def test_tick_metrics_thread_queue_wait_and_compiles(self):
        # hidden=6 gives this test its own jit shape family, so the first
        # tick *must* register fresh stack compiles whatever ran before.
        cfg, params = _cfg_params(s=5, hidden=6)
        eng = StreamingEngine(params, cfg, max_sessions=1, chunk_capacity=4)
        eng.open_session("a")
        eng.admit("b")                              # waits: store is full
        m1 = (eng.step({"a": jnp.ones((4, 1))}), eng.last_metrics)[1]
        assert m1.compiles >= 1                     # cold graph, counted
        assert m1.queue_depth == 1
        assert m1.queue_wait_s > 0.0                # b has been waiting
        m2 = (eng.step({"a": jnp.ones((4, 1))}), eng.last_metrics)[1]
        assert m2.compiles == 0                     # warm graph, same shape
        assert m2.queue_wait_s > m1.queue_wait_s    # b is still waiting

    def test_jsonl_sink_flushes_per_record(self, tmp_path):
        # the trail must be readable after a crash — i.e. *before* close()
        path = tmp_path / "ticks.jsonl"
        sink = JsonlSink(str(path))
        sink.emit(TickMetrics(tick=0, capacity=4, n_chunks=1, live_rows=2,
                              batch_rows=2, queue_depth=0, live_steps=4,
                              live_chain_steps=8, padded_steps=8,
                              pad_waste=0.0, duration_s=0.5,
                              tokens_per_sec=16.0))
        lines = path.read_text().splitlines()       # no close(), no flush()
        assert len(lines) == 1
        rec = __import__("json").loads(lines[0])
        assert rec["tick"] == 0 and rec["queue_wait_s"] == 0.0
        assert rec["compiles"] == 0                 # new fields serialize
        sink.close()


class TestPersistence:
    def _store_with_state(self, s=2, hid=4, layers=2):
        store = SessionStore(n_samples=s, seed=5, max_sessions=4)
        a = store.admit("a")                        # fresh, no carry yet
        b = store.admit("b")
        b.state = [(jnp.arange(s * hid, dtype=jnp.bfloat16).reshape(s, hid),
                    jnp.arange(s * hid, dtype=jnp.float32).reshape(s, hid)
                    * 0.5) for _ in range(layers)]
        b.steps, b.chunks = 17, 3
        return store, a, b

    def test_snapshot_restore_bit_exact(self, tmp_path):
        store, _, b = self._store_with_state()
        path = snapshot_store(str(tmp_path), store)
        assert path.endswith("step-0000000000")
        got, meta = restore_store(str(tmp_path))
        assert meta["seed"] == 5 and got.active == ["a", "b"]
        assert got.next_row == store.next_row       # allocator survives
        ga, gb = got.get("a"), got.get("b")
        assert ga.fresh and gb.steps == 17 and gb.chunks == 3
        np.testing.assert_array_equal(np.asarray(gb.rows),
                                      np.asarray(b.rows))
        for (h, c), (h0, c0) in zip(gb.state, b.state):
            assert h.dtype == h0.dtype and c.dtype == jnp.float32
            np.testing.assert_array_equal(np.asarray(h, jnp.float32),
                                          np.asarray(h0, jnp.float32))
            np.testing.assert_array_equal(np.asarray(c), np.asarray(c0))

    def test_restore_subset_burns_unrestored_rows(self, tmp_path):
        store, _, _ = self._store_with_state()
        snapshot_store(str(tmp_path), store)
        got, _ = restore_store(str(tmp_path), sids=["b"])
        assert got.active == ["b"]
        # 'a' was shed, but its rows stay burned: the next admission must
        # not repeat a pre-crash Bayesian draw
        fresh_rows = np.asarray(got.admit("new").rows)
        assert fresh_rows.min() >= store.next_row
        with pytest.raises(KeyError, match="no session"):
            restore_store(str(tmp_path), sids=["ghost"])

    def test_queue_roundtrip_preserves_order_and_carry(self, tmp_path):
        store, _, _ = self._store_with_state()
        q = AdmissionQueue()
        evicted = store.evict("b")                  # carries live state
        q.submit("b", priority=1, session=evicted)
        q.submit("c", priority=7)
        snapshot_store(str(tmp_path), store, queue=q)
        q2 = AdmissionQueue()
        got, _ = restore_store(str(tmp_path), queue=q2)
        assert [t.sid for t in q2.waiting()] == ["c", "b"]
        ticket = {t.sid: t for t in q2.waiting()}["b"]
        assert ticket.session is not None and ticket.session.steps == 17
        for (h, c), (h0, c0) in zip(ticket.session.state, evicted.state):
            np.testing.assert_array_equal(np.asarray(c), np.asarray(c0))
        q2.drain(got)                               # both go live, c first
        assert got.active == ["a", "c", "b"]

    def test_sids_filter_covers_the_wait_list(self, tmp_path):
        """The sids= filter selects fresh wait-list entries too (they carry
        no arrays) and excludes unselected ones of either kind."""
        store, _, _ = self._store_with_state()
        q = AdmissionQueue()
        q.submit("fresh-q", priority=2)
        snapshot_store(str(tmp_path), store, queue=q)
        q2 = AdmissionQueue()
        got, _ = restore_store(str(tmp_path), sids=["a", "fresh-q"],
                               queue=q2)
        assert got.active == ["a"]
        assert [t.sid for t in q2.waiting()] == ["fresh-q"]
        q3 = AdmissionQueue()
        got3, _ = restore_store(str(tmp_path), sids=["b"], queue=q3)
        assert got3.active == ["b"] and q3.depth == 0
        # selecting a wait-list sid without a queue to put it in would
        # silently drop it — refuse instead (sids-filtered or not)
        with pytest.raises(ValueError, match="queue"):
            restore_store(str(tmp_path), sids=["fresh-q"])
        with pytest.raises(ValueError, match="silently drop"):
            restore_store(str(tmp_path))

    def test_aliasing_sids_never_cross_contaminate(self, tmp_path):
        """'ward 3' and 'ward_3' sanitize to the same checkpoint leaf name;
        the recorded per-sid keys keep a partial restore addressing the
        right patient's carry."""
        store = SessionStore(n_samples=1, seed=0, max_sessions=4)
        for sid, fill in (("ward 3", 1.0), ("ward_3", 2.0)):
            sess = store.admit(sid)
            sess.state = [(jnp.full((1, 4), fill),
                           jnp.full((1, 4), fill, jnp.float32))]
            sess.steps = int(fill)
        snapshot_store(str(tmp_path), store)
        for sid, fill in (("ward 3", 1.0), ("ward_3", 2.0)):
            got, _ = restore_store(str(tmp_path), sids=[sid])
            h, c = got.get(sid).state[0]
            np.testing.assert_array_equal(np.asarray(c),
                                          np.full((1, 4), fill, np.float32))
            np.testing.assert_array_equal(np.asarray(got.get(sid).rows),
                                          np.asarray(store.get(sid).rows))

    def test_h_only_carry_roundtrips(self, tmp_path):
        """GRU sessions store (h,) 1-tuples per layer — the snapshot format
        records the carry arity and restores the same pytree shape."""
        store = SessionStore(n_samples=2, seed=5, max_sessions=2)
        g = store.admit("g")
        g.state = [(jnp.arange(8, dtype=jnp.bfloat16).reshape(2, 4),)
                   for _ in range(3)]
        g.steps, g.chunks = 9, 2
        snapshot_store(str(tmp_path), store)
        got, meta = restore_store(str(tmp_path))
        assert meta["sessions"]["g"]["parts"] == 1
        gg = got.get("g")
        assert [len(layer) for layer in gg.state] == [1, 1, 1]
        for (h,), (h0,) in zip(gg.state, g.state):
            assert h.dtype == h0.dtype
            np.testing.assert_array_equal(np.asarray(h, jnp.float32),
                                          np.asarray(h0, jnp.float32))

    def test_snapshot_steps_are_monotone_and_prunable(self, tmp_path):
        store, _, _ = self._store_with_state()
        p0 = snapshot_store(str(tmp_path), store)
        p1 = snapshot_store(str(tmp_path), store)
        assert p0 != p1 and checkpoint.latest_step(str(tmp_path)) == 1
        checkpoint.keep_last(str(tmp_path), 1)
        got, meta = restore_store(str(tmp_path))
        assert meta["step"] == 1 and got.active == ["a", "b"]

    def test_corrupt_snapshot_detected(self, tmp_path):
        import os
        store, _, _ = self._store_with_state()
        path = snapshot_store(str(tmp_path), store)
        victim = [f for f in os.listdir(path) if f.endswith(".npy")][0]
        with open(os.path.join(path, victim), "r+b") as f:
            f.seek(-1, 2)
            f.write(b"\x7f")
        with pytest.raises(IOError, match="checksum"):
            restore_store(str(tmp_path))


class TestKillRestoreInvariance:
    """Acceptance: snapshot mid-session + resume in a fresh engine ==
    the uninterrupted stream, bit-identically."""

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_kill_restore_bit_identical(self, backend, tmp_path):
        cfg, params = _cfg_params()
        T = 10
        sig_a = jax.random.normal(jax.random.key(1), (T, 1))
        sig_b = jax.random.normal(jax.random.key(2), (T, 1))

        gold = StreamingEngine(params, cfg, backend=backend, max_sessions=2)
        gold.open_session("a")
        gold.open_session("b")
        gold.step({"a": sig_a[:4], "b": sig_b[:6]})
        want = gold.step({"a": sig_a[4:], "b": sig_b[6:]})

        victim = StreamingEngine(params, cfg, backend=backend,
                                 max_sessions=2)
        victim.open_session("a")
        victim.open_session("b")
        victim.step({"a": sig_a[:4], "b": sig_b[:6]})
        victim.snapshot(str(tmp_path), extra={"note": "pre-crash"})
        del victim                                   # the crash

        revived = StreamingEngine(params, cfg, backend=backend,
                                  max_sessions=2)
        assert revived.restore(str(tmp_path)) == {"note": "pre-crash"}
        assert sorted(revived.active_sessions) == ["a", "b"]
        got = revived.step({"a": sig_a[4:], "b": sig_b[6:]})
        for sid in ("a", "b"):
            assert got[sid].steps_total == want[sid].steps_total == T
            np.testing.assert_array_equal(
                np.asarray(got[sid].summary.probs),
                np.asarray(want[sid].summary.probs))
            np.testing.assert_array_equal(
                np.asarray(got[sid].summary.mutual_information),
                np.asarray(want[sid].summary.mutual_information))

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_gru_kill_restore_bit_identical(self, backend, tmp_path):
        """GRU parity for the acceptance invariant: h-only carries snapshot
        and restore bit-identically on every backend."""
        cfg = clf.ClassifierConfig(
            hidden=8, num_layers=2, num_classes=4, cell="gru",
            mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=3, seed=3))
        params = clf.init(jax.random.key(0), cfg)
        T = 10
        sig = jax.random.normal(jax.random.key(1), (T, 1))

        gold = StreamingEngine(params, cfg, backend=backend, max_sessions=1)
        gold.open_session("a")
        gold.step({"a": sig[:4]})
        want = gold.step({"a": sig[4:]})["a"]

        victim = StreamingEngine(params, cfg, backend=backend,
                                 max_sessions=1)
        victim.open_session("a")
        victim.step({"a": sig[:4]})
        victim.snapshot(str(tmp_path))
        del victim                                   # the crash

        revived = StreamingEngine(params, cfg, backend=backend,
                                  max_sessions=1)
        revived.restore(str(tmp_path))
        got = revived.step({"a": sig[4:]})["a"]
        assert got.steps_total == want.steps_total == T
        np.testing.assert_array_equal(np.asarray(got.summary.probs),
                                      np.asarray(want.summary.probs))
        np.testing.assert_array_equal(
            np.asarray(got.summary.mutual_information),
            np.asarray(want.summary.mutual_information))

    def test_restore_refuses_cell_mismatch(self, tmp_path):
        """LSTM (h, c) carries must not resume into a GRU engine (or vice
        versa) — the pytrees are not interchangeable."""
        cfg, params = _cfg_params()
        eng = StreamingEngine(params, cfg, max_sessions=1)
        eng.open_session("a")
        eng.snapshot(str(tmp_path))
        g_cfg = clf.ClassifierConfig(
            hidden=8, num_layers=2, num_classes=4, cell="gru",
            mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=3, seed=3))
        with pytest.raises(ValueError, match="cell|gru|lstm"):
            StreamingEngine(clf.init(jax.random.key(0), g_cfg), g_cfg,
                            max_sessions=1).restore(str(tmp_path))

    @pytest.mark.parametrize("capacity", [8, "auto"])
    def test_restore_across_chunk_capacity_change(self, capacity, tmp_path):
        """The snapshotting process ran dynamic shapes; the restoring one
        runs fixed/adaptive — per-chunk outputs stay bit-identical (the
        lengths-pinned graph family is launch-shape independent)."""
        cfg, params = _cfg_params()
        T = 9
        sig = jax.random.normal(jax.random.key(4), (T, 1))
        gold = StreamingEngine(params, cfg, max_sessions=2)
        gold.open_session("x")
        gold.step({"x": sig[:5]})
        want = gold.step({"x": sig[5:]})["x"]

        victim = StreamingEngine(params, cfg, max_sessions=2)
        victim.open_session("x")
        victim.step({"x": sig[:5]})
        victim.snapshot(str(tmp_path))
        revived = StreamingEngine(params, cfg, max_sessions=2,
                                  chunk_capacity=capacity)
        revived.restore(str(tmp_path))
        got = revived.step({"x": sig[5:]})["x"]
        np.testing.assert_array_equal(np.asarray(got.summary.probs),
                                      np.asarray(want.summary.probs))

    def test_admit_of_live_sid_rejected_eagerly(self):
        cfg, params = _cfg_params(s=2)
        eng = StreamingEngine(params, cfg, max_sessions=2)
        eng.admit("a")
        with pytest.raises(ValueError, match="already admitted"):
            eng.admit("a")

    def test_admit_validates_reattach_ticket_eagerly(self):
        """A statically-mismatched re-attach must fail at admit(), not
        blow up whichever later step()/close_session() drains it (and
        cost that caller the evicted carry)."""
        cfg, params = _cfg_params(s=2)
        eng = StreamingEngine(params, cfg, max_sessions=1)
        eng.admit("hog")
        bad_seed = SessionStore(n_samples=2, seed=999).admit("x")
        with pytest.raises(ValueError, match="seed"):
            eng.admit("x", session=bad_seed)
        bad_s = SessionStore(n_samples=5, seed=cfg.mcd.seed).admit("y")
        with pytest.raises(ValueError, match="chains"):
            eng.admit("y", session=bad_s)
        assert eng.queued_sessions == []            # nothing latent queued
        sess = eng.close_session("hog")             # still returns the carry
        assert sess.sid == "hog"

    def test_restore_holds_a_wait_list_larger_than_max_pending(self,
                                                               tmp_path):
        """Crash recovery must not depend on the relaunch flags: a snapshot
        whose wait-list exceeds this process's max_pending still restores
        (the replacement queue is sized from the snapshot)."""
        cfg, params = _cfg_params(s=2)
        big = StreamingEngine(params, cfg, max_sessions=1, max_pending=8)
        big.admit("live")
        for k in range(5):
            big.admit(f"w{k}")
        big.snapshot(str(tmp_path))
        small = StreamingEngine(params, cfg, max_sessions=1, max_pending=2)
        small.restore(str(tmp_path))
        assert small.active_sessions == ["live"]
        assert len(small.queued_sessions) == 5

    def test_restore_refuses_changed_dropout_config(self, tmp_path):
        """p/placement change the mask values under the same (seed, rows);
        resuming across them must be an error, not silent divergence."""
        cfg, params = _cfg_params()
        eng = StreamingEngine(params, cfg, max_sessions=1)
        eng.open_session("a")
        eng.snapshot(str(tmp_path))
        p_cfg = clf.ClassifierConfig(
            hidden=8, num_layers=2, num_classes=4,
            mcd=mcd.MCDConfig(p=0.25, placement="YN", n_samples=3, seed=3))
        with pytest.raises(ValueError, match="masks"):
            StreamingEngine(clf.init(jax.random.key(0), p_cfg), p_cfg,
                            max_sessions=1).restore(str(tmp_path))
        b_cfg = clf.ClassifierConfig(
            hidden=8, num_layers=2, num_classes=4,
            mcd=mcd.MCDConfig(p=0.125, placement="YY", n_samples=3, seed=3))
        with pytest.raises(ValueError, match="masks"):
            StreamingEngine(clf.init(jax.random.key(0), b_cfg), b_cfg,
                            max_sessions=1).restore(str(tmp_path))

    def test_restore_refuses_mismatched_config(self, tmp_path):
        cfg, params = _cfg_params()
        eng = StreamingEngine(params, cfg, max_sessions=1)
        eng.open_session("a")
        eng.snapshot(str(tmp_path))
        other_cfg, other_params = _cfg_params(s=4)
        with pytest.raises(ValueError, match="chains"):
            StreamingEngine(other_params, other_cfg,
                            max_sessions=1).restore(str(tmp_path))
        seed_cfg, seed_params = _cfg_params(seed=99)
        with pytest.raises(ValueError, match="seed"):
            StreamingEngine(seed_params, seed_cfg,
                            max_sessions=1).restore(str(tmp_path))
        with pytest.raises(RuntimeError, match="fresh engine"):
            eng.restore(str(tmp_path))

    def test_attach_roundtrips_through_ckpt(self, tmp_path):
        """Satellite: evict -> repro.ckpt save -> load in a fresh store ->
        attach -> the stream finishes bit-identically, on every backend."""
        cfg, params = _cfg_params(s=2)
        T = 8
        sig = jax.random.normal(jax.random.key(6), (T, 1))
        for backend in BACKENDS:
            solo = StreamingEngine(params, cfg, backend=backend,
                                   max_sessions=1)
            solo.open_session("a")
            want = solo.step({"a": sig})["a"]

            eng = StreamingEngine(params, cfg, backend=backend,
                                  max_sessions=1)
            eng.open_session("a")
            eng.step({"a": sig[:3]})
            evicted = eng.close_session("a")
            d = str(tmp_path / backend)
            checkpoint.save(d, 0, {
                "rows": np.asarray(evicted.rows),
                "state": [[np.asarray(h), np.asarray(c)]
                          for h, c in evicted.state]},
                meta={"steps": evicted.steps, "chunks": evicted.chunks,
                      "seed": cfg.mcd.seed})
            like = {"rows": 0,
                    "state": [[0, 0] for _ in evicted.state]}
            arrays = checkpoint.restore(d, 0, like)
            m = checkpoint.load_meta(d, 0)
            thawed = Session(
                sid="a", rows=jnp.asarray(arrays["rows"]), seed=m["seed"],
                state=[(jnp.asarray(h), jnp.asarray(c))
                       for h, c in arrays["state"]],
                steps=m["steps"], chunks=m["chunks"])
            fresh = StreamingEngine(params, cfg, backend=backend,
                                    max_sessions=1)
            fresh.attach_session(thawed)
            got = fresh.step({"a": sig[3:]})["a"]
            assert got.steps_total == T
            np.testing.assert_array_equal(np.asarray(got.summary.probs),
                                          np.asarray(want.summary.probs))


class TestAdmissionUnderChurn:
    def test_three_x_capacity_all_complete_no_row_reuse(self):
        """Acceptance: 3x store capacity admitted through the queue with
        random mid-stream evictions (each re-queued as a re-attach).  Every
        session streams to completion, live rows never overlap, and every
        submitted chunk produces a result."""
        cfg, params = _cfg_params(s=2)
        capacity, total, T, chunk = 2, 6, 6, 2
        eng = StreamingEngine(params, cfg, max_sessions=capacity,
                              max_pending=2 * total)
        sigs = {f"s{k}": jax.random.normal(jax.random.key(10 + k), (T, 1))
                for k in range(total)}
        for k in range(total):
            eng.admit(f"s{k}", priority=k % 3)
        assert len(eng.active_sessions) == capacity
        assert len(eng.queued_sessions) == total - capacity

        rng = np.random.default_rng(0)
        served: dict[str, int] = {sid: 0 for sid in sigs}
        results_count = 0
        done: set[str] = set()
        guard = 0
        while len(done) < total:
            guard += 1
            assert guard < 200, "churn loop failed to converge"
            live = list(eng.active_sessions)
            # live sessions must never share mask rows
            rows = [tuple(np.asarray(eng.store.get(s).rows)) for s in live]
            flat = [r for rr in rows for r in rr]
            assert len(flat) == len(set(flat)), "row reuse while live"
            chunks = {}
            for sid in live:
                pos = eng.store.get(sid).steps
                if pos < T:
                    chunks[sid] = sigs[sid][pos:pos + chunk]
            results = eng.step(chunks)
            assert sorted(results) == sorted(chunks), "dropped chunks"
            results_count += len(results)
            for sid in chunks:
                served[sid] += int(results[sid].length)
            # random eviction churn: a victim loses its row mid-stream and
            # rejoins the wait-list with its carry (same Bayesian draw)
            live = list(eng.active_sessions)
            if live and rng.random() < 0.5:
                victim = live[int(rng.integers(len(live)))]
                sess = eng.close_session(victim)
                if sess.steps < T:
                    eng.admit(victim, priority=9, session=sess)
                else:
                    done.add(victim)
            for sid in list(eng.active_sessions):
                if eng.store.get(sid).steps >= T:
                    eng.close_session(sid)
                    done.add(sid)

        assert served == {sid: T for sid in sigs}
        assert len(eng.queued_sessions) == 0 and len(eng.active_sessions) == 0
        assert results_count * chunk >= total * T   # every chunk answered

class TestPrewarm:
    """ISSUE 5 satellite: boot-time compilation of the capacity ladder —
    post-warm ticks must trigger **zero** new stack-graph compiles."""

    @staticmethod
    def _stack_cache_sizes():
        from repro.kernels import ops
        return (ops.lstm_stack_layer._cache_size(),
                ops.fused_lstm_seq._cache_size(),
                ops.fused_lstm_layer._cache_size(),
                ops.gru_stack_layer._cache_size(),
                ops.fused_gru_seq._cache_size())

    def test_prewarm_then_zero_new_compiles(self):
        from repro.serve import prewarm
        cfg, params = _cfg_params(s=2)
        eng = StreamingEngine(params, cfg, max_sessions=2,
                              chunk_capacity="auto", ladder=(4, 8))
        assert prewarm(eng) == [4, 8]
        warm = self._stack_cache_sizes()
        eng.open_session("a")
        eng.open_session("b")
        sig = jax.random.normal(jax.random.key(4), (8, 1))
        for a, b in ((3, 2), (8, 4), (1, 1), (5, 8)):   # both rungs, ragged
            eng.step({"a": sig[:a], "b": sig[:b]})
        assert self._stack_cache_sizes() == warm, \
            "a post-warm tick compiled a new stack graph"
        assert {m.capacity for m in eng.metrics} == {4, 8}

    def test_prewarm_fixed_capacity_single_rung(self):
        from repro.serve import prewarm
        cfg, params = _cfg_params(s=2)
        eng = StreamingEngine(params, cfg, max_sessions=2, chunk_capacity=6)
        assert prewarm(eng) == [6]
        warm = self._stack_cache_sizes()
        eng.open_session("a")
        for n in (2, 6, 1):
            eng.step({"a": jnp.ones((n, 1), jnp.float32)})
        assert self._stack_cache_sizes() == warm

    def test_prewarm_rejects_dynamic_shapes(self):
        from repro.serve import prewarm
        cfg, params = _cfg_params(s=2)
        eng = StreamingEngine(params, cfg, max_sessions=2)  # dynamic mode
        with pytest.raises(ValueError, match="bounded"):
            prewarm(eng)


class TestMetricsSinks:
    """ISSUE 5 satellite: the per-tick metrics stream is a pluggable sink
    (bounded ring by default, JSONL file for a durable trail)."""

    def test_default_ring_sink_backs_metrics_property(self):
        from repro.serve import RingBufferSink
        cfg, params = _cfg_params(s=2)
        eng = StreamingEngine(params, cfg, max_sessions=1, metrics_window=2)
        assert isinstance(eng.metrics_sink, RingBufferSink)
        eng.open_session("a")
        for _ in range(4):
            eng.step({"a": jnp.ones((2, 1))})
        assert len(eng.metrics) == 2 and eng.last_metrics.tick == 3

    def test_jsonl_sink_writes_parseable_trail(self, tmp_path):
        import json

        from repro.serve import JsonlSink
        cfg, params = _cfg_params(s=2)
        path = tmp_path / "ticks.jsonl"
        eng = StreamingEngine(params, cfg, max_sessions=1,
                              metrics_sink=JsonlSink(str(path)))
        eng.open_session("a")
        for n in (3, 1, 2):
            eng.step({"a": jnp.ones((n, 1))})
        eng.metrics_sink.close()
        lines = [json.loads(l) for l in path.read_text().splitlines()]
        assert [m["tick"] for m in lines] == [0, 1, 2]
        assert [m["live_steps"] for m in lines] == [3, 1, 2]
        assert all(m["shards"] == 1 for m in lines)
        # the ring window still serves the in-process observables
        assert len(eng.metrics) == 3
        assert summarize(eng.metrics)["ticks"] == 3
        # appending across engine restarts keeps the trail monotone
        eng2 = StreamingEngine(params, cfg, max_sessions=1,
                               metrics_sink=JsonlSink(str(path)))
        eng2.open_session("a")
        eng2.step({"a": jnp.ones((1, 1))})
        eng2.metrics_sink.close()
        assert len(path.read_text().splitlines()) == 4

"""Property tests for the quantization module (ISSUE 6 satellite).

Hypothesis-driven (real hypothesis when installed, the deterministic
conftest stub otherwise): round-trip error bounds per channel, degenerate
zero/constant channels, odd-width int4 packing, and layout invariance —
the algebraic facts the cross-backend bit-identity suite builds on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import quantize


def _weights(key, i, g, h, scale=1.0):
    return jax.random.normal(jax.random.key(key), (i, g, h)) * scale


class TestRoundTrip:
    @settings(max_examples=15)
    @given(bits=st.sampled_from([8, 4]),
           i=st.integers(1, 24), h=st.integers(1, 24),
           key=st.integers(0, 2**16), amp=st.floats(1e-3, 100.0))
    def test_error_bounded_per_channel(self, bits, i, h, key, amp):
        """|w - deq(q)| <= scale/2 per element: round() lands on the nearest
        grid point and |w| <= amax = qmax*scale keeps clip() inactive."""
        w = np.asarray(_weights(key, i, 4, h, amp), np.float64)
        q, s = quantize.quantize(jnp.asarray(w, jnp.float32), bits, axis=0)
        deq = np.asarray(quantize.dequantize(q, s, axis=0), np.float64)
        bound = np.asarray(s, np.float64)[None] / 2 + 1e-6 * amp
        assert (np.abs(w - deq) <= bound).all()

    @settings(max_examples=10)
    @given(bits=st.sampled_from([8, 4]), key=st.integers(0, 2**16))
    def test_codes_within_symmetric_range(self, bits, key):
        q, _ = quantize.quantize(_weights(key, 8, 4, 8), bits, axis=0)
        qmax = quantize.QMAX[bits]
        qn = np.asarray(q)
        assert qn.dtype == np.int8
        assert qn.min() >= -qmax and qn.max() <= qmax

    @settings(max_examples=10)
    @given(bits=st.sampled_from([8, 4]), key=st.integers(0, 2**16),
           h=st.integers(1, 16))
    def test_layout_invariance(self, bits, key, h):
        """Kernel layout [I, G, H] axis=0 and core layout [G, I, H] axis=1
        give bit-identical (q, scale) — run_stack's reference path relies
        on this to fake-quant without re-layouting."""
        w = _weights(key, 12, 4, h)                     # [I, G, H]
        qk, sk = quantize.quantize(w, bits, axis=0)
        qc, sc = quantize.quantize(jnp.moveaxis(w, 0, 1), bits, axis=1)
        np.testing.assert_array_equal(np.asarray(qk),
                                      np.asarray(jnp.moveaxis(qc, 1, 0)))
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(sc))


class TestDegenerateChannels:
    def test_zero_channel_scale_one_codes_zero(self):
        w = jnp.zeros((6, 4, 5))
        q, s = quantize.quantize(w, 8, axis=0)
        np.testing.assert_array_equal(np.asarray(q), 0)
        np.testing.assert_array_equal(np.asarray(s), 1.0)
        np.testing.assert_array_equal(
            np.asarray(quantize.dequantize(q, s, axis=0)), 0.0)

    def test_constant_channel_exact(self):
        """A channel whose elements all equal ±amax round-trips exactly."""
        w = jnp.full((6, 4, 5), 0.375)
        q, s = quantize.quantize(w, 4, axis=0)
        np.testing.assert_array_equal(np.asarray(q), quantize.QMAX[4])
        deq = quantize.dequantize(q, s, axis=0)
        np.testing.assert_allclose(np.asarray(deq), 0.375, rtol=1e-7)

    def test_mixed_zero_and_live_channels(self):
        w = np.zeros((6, 1, 3), np.float32)
        w[:, 0, 1] = np.linspace(-1, 1, 6)
        q, s = quantize.quantize(jnp.asarray(w), 8, axis=0)
        sn = np.asarray(s)
        assert sn[0, 0] == 1.0 and sn[0, 2] == 1.0
        assert sn[0, 1] == pytest.approx(1.0 / 127)


class TestInt4Packing:
    @settings(max_examples=15)
    @given(h=st.integers(1, 33), key=st.integers(0, 2**16))
    def test_pack_unpack_roundtrip_any_width(self, h, key):
        """Exact for every H, odd widths included (pad nibble dropped)."""
        q, _ = quantize.quantize(_weights(key, 5, 4, h), 4, axis=0)
        packed = quantize.pack_int4(q)
        assert packed.shape == (5, 4, (h + 1) // 2)
        assert packed.dtype == jnp.uint8
        np.testing.assert_array_equal(
            np.asarray(quantize.unpack_int4(packed, h)), np.asarray(q))

    def test_every_code_exact(self):
        """All 15 legal int4 codes survive the nibble round-trip."""
        q = jnp.arange(-7, 8, dtype=jnp.int8).reshape(1, -1)
        np.testing.assert_array_equal(
            np.asarray(quantize.unpack_int4(quantize.pack_int4(q), 15)),
            np.asarray(q))

    def test_packed_weight_dispatch(self):
        q = jnp.ones((4, 4, 6), jnp.int8)
        assert quantize.packed_weight(q, 8) is q
        assert quantize.packed_weight(q, 4).shape == (4, 4, 3)


class TestKnobPlumbing:
    def test_check_precision(self):
        for p in quantize.PRECISIONS + (None,):
            quantize.check_precision(p)
        with pytest.raises(ValueError, match="precision"):
            quantize.check_precision("fp16")

    def test_activation_dtype(self):
        assert quantize.activation_dtype(None, jnp.float16) == jnp.float16
        assert quantize.activation_dtype("fp32", jnp.bfloat16) == jnp.float32
        for p in ("bf16", "int8", "int4"):
            assert quantize.activation_dtype(p, jnp.float32) == jnp.bfloat16

    def test_weight_bytes_monotonic(self):
        sizes = [quantize.weight_bytes(16, 32, 4, p)
                 for p in (None, "fp32", "bf16", "int8", "int4")]
        assert sizes[0] == sizes[1]                  # None prices as fp32
        assert sizes[1] > sizes[2] > sizes[3] > sizes[4]
        # bf16 halves fp32 exactly (no scales); int8 adds scale rows
        assert sizes[2] - quantize.weight_bytes(16, 32, 4, "int8") \
            == (16 + 32) * 4 * 32 * 1 - 2 * 4 * 32 * 4

    def test_kernel_weight_matches_fake_quant(self):
        """The in-kernel dequant == the wrapper-level oracle, both widths."""
        w = _weights(3, 10, 4, 7)
        for precision, bits in (("int8", 8), ("int4", 4)):
            q, s = quantize.quantize(w, bits, axis=0)
            got = quantize.kernel_weight(
                quantize.packed_weight(q, bits), s, bits, hidden=7,
                act_dtype=jnp.bfloat16)
            want = quantize.fake_quant(w, precision, axis=0,
                                       act_dtype=jnp.bfloat16)
            np.testing.assert_array_equal(np.asarray(got, np.float32),
                                          np.asarray(want, np.float32))

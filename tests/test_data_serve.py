"""Data pipeline determinism + serving engine behaviour."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import ecg
from repro.models import backbone
from repro.serve.engine import BayesianEngine


class TestEcgData:
    def test_shapes_and_split(self):
        tx, ty, ex, ey = ecg.make_ecg5000(0)
        assert tx.shape == (500, 140, 1) and ex.shape == (4500, 140, 1)
        assert set(np.unique(ty)) <= {0, 1, 2, 3}

    def test_normalization(self):
        tx, *_ = ecg.make_ecg5000(0)
        np.testing.assert_allclose(tx.mean(axis=1), 0.0, atol=1e-5)
        np.testing.assert_allclose(tx.std(axis=1), 1.0, atol=1e-3)

    def test_deterministic(self):
        a = ecg.make_ecg5000(7)[0]
        b = ecg.make_ecg5000(7)[0]
        np.testing.assert_array_equal(a, b)

    def test_pipeline_epoch_deterministic(self):
        tx, ty, *_ = ecg.make_ecg5000(0)
        p = ecg.Pipeline(tx, ty, batch_size=32, seed=1)
        a = next(iter(p.epoch(3)))[0]
        b = next(iter(p.epoch(3)))[0]
        np.testing.assert_array_equal(a, b)
        c = next(iter(p.epoch(4)))[0]
        assert not np.array_equal(a, c)

    def test_class_morphologies_distinct(self):
        tx, ty, *_ = ecg.make_ecg5000(0)
        mean0 = tx[ty == 0].mean(0)[:, 0]
        mean1 = tx[ty == 1].mean(0)[:, 0]
        assert np.abs(mean0 - mean1).max() > 0.5


class TestServingEngine:
    def test_uncertainty_outputs(self):
        cfg = get_config("qwen3-1.7b", reduced=True)
        params = backbone.init_params(jax.random.key(0), cfg, jnp.float32)
        eng = BayesianEngine(params, cfg, max_len=24)
        res = eng.generate(jnp.ones((2, 6), jnp.int32), 4)
        assert res.tokens.shape == (2, 4)
        ent = np.asarray(res.predictive_entropy)
        mi = np.asarray(res.mutual_information)
        assert (ent >= -1e-5).all() and (ent <= np.log(cfg.vocab_size) + 1e-4).all()
        assert (mi >= -1e-4).all()
        assert (mi <= ent + 1e-4).all()      # epistemic ≤ total

    def test_masks_tied_across_decode_steps(self):
        """Same engine+seed → identical generation (stateless mask recompute)."""
        cfg = get_config("qwen3-1.7b", reduced=True)
        params = backbone.init_params(jax.random.key(0), cfg, jnp.float32)
        a = BayesianEngine(params, cfg, max_len=24, seed=5).generate(
            jnp.ones((1, 6), jnp.int32), 4)
        b = BayesianEngine(params, cfg, max_len=24, seed=5).generate(
            jnp.ones((1, 6), jnp.int32), 4)
        np.testing.assert_array_equal(np.asarray(a.tokens),
                                      np.asarray(b.tokens))
        np.testing.assert_allclose(np.asarray(a.predictive_entropy),
                                   np.asarray(b.predictive_entropy),
                                   rtol=1e-6)

    def test_pointwise_engine_zero_mi(self):
        cfg = get_config("qwen3-1.7b", reduced=True)
        cfg = cfg.replace(mcd=cfg.mcd.replace(placement="N"))
        params = backbone.init_params(jax.random.key(0), cfg, jnp.float32)
        res = BayesianEngine(params, cfg, max_len=24).generate(
            jnp.ones((1, 6), jnp.int32), 3)
        np.testing.assert_allclose(np.asarray(res.mutual_information), 0.0,
                                   atol=1e-6)

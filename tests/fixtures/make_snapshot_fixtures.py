"""Regenerate the golden snapshot fixtures (committed, format-compat pins).

Each fixture is a session snapshot written in a *historical* meta layout,
built directly against ``repro.ckpt.checkpoint.save`` — deliberately NOT
through ``persistence.snapshot_store``, which always writes the current
layout.  ``tests/test_snapshot_compat.py`` pins that today's ``restore``
still loads them:

* ``pr3_lstm/`` — the durable-control-plane layout: session metas carry no
  ``parts`` key (every carry was an LSTM ``(h, c)`` 2-tuple) and the engine
  ``extra`` predates ``cell``/``precision``/``data_shards``/``mcd``.
* ``pr4_gru/`` — the variable-arity layout: ``parts`` records the carry
  tuple length (1 for GRU), ``extra`` has ``cell`` but still no
  ``precision``.
* ``fleet_v1/`` — the first multi-tenant fleet layout (``fleet_format: 1``):
  one manifest holding per-group store trees plus the tenant table, the
  shared queue and the fairness ledger.  Written at the layout's birth so
  later fleet-format evolution keeps a restore path for it.
* ``distill_v1/`` — the distilled fast-path layout at its birth: a
  ``mode: "student"`` session meta (written only off the default, so
  pre-distill snapshots stay byte-identical) whose single row carries the
  deterministic high-bit flag, next to a plain MC session and a queued
  fresh student ticket.

Arrays are seeded, so re-running reproduces the same bytes:

    PYTHONPATH=src python tests/fixtures/make_snapshot_fixtures.py
"""

from __future__ import annotations

import os
import shutil

import numpy as np

from repro.ckpt import checkpoint as ckpt

HERE = os.path.dirname(os.path.abspath(__file__))

#: Model geometry the fixtures were streamed under — test engines must match.
HIDDEN, NUM_LAYERS, N_SAMPLES, SEED = 8, 2, 2, 3


def _carry(rng, parts):
    return [[rng.standard_normal((N_SAMPLES, HIDDEN)).astype(np.float32)
             for _ in range(parts)]
            for _ in range(NUM_LAYERS)]


def _write(name, *, parts, extra, include_parts_key):
    rng = np.random.default_rng(1234)
    root = os.path.join(HERE, "snapshots", name)
    if os.path.exists(root):
        shutil.rmtree(root)
    tree, sessions = {}, {}
    for sid in ("ward_1", "ward_2"):
        tree[sid] = {"rows": np.arange(N_SAMPLES, dtype=np.uint32)
                     + (0 if sid == "ward_1" else N_SAMPLES),
                     "state": _carry(rng, parts)}
        smeta = {"steps": 7, "chunks": 2, "layers": NUM_LAYERS, "key": sid}
        if include_parts_key:
            smeta["parts"] = parts
        sessions[sid] = smeta
    meta = {"format": 1, "n_samples": N_SAMPLES, "seed": SEED,
            "max_sessions": 4, "next_row": 2 * N_SAMPLES,
            "sessions": sessions, "queue": [], "extra": extra}
    ckpt.save(root, 0, tree, meta=meta)
    return root


def _write_fleet():
    """The fleet_v1 layout: two tenants sharing launch group ``g0``."""
    rng = np.random.default_rng(5678)
    root = os.path.join(HERE, "snapshots", "fleet_v1")
    if os.path.exists(root):
        shutil.rmtree(root)
    g_tree, sessions = {}, {}
    for i, (gsid, steps, chunks) in enumerate(
            (("ward/p1", 7, 2), ("anom/p1", 4, 1))):
        key = gsid.replace("/", "_")             # the recorded tree key
        g_tree[key] = {"rows": np.arange(N_SAMPLES, dtype=np.uint32)
                       + i * N_SAMPLES,
                       "state": _carry(rng, 2)}
        sessions[gsid] = {"steps": steps, "chunks": chunks,
                          "layers": NUM_LAYERS, "parts": 2, "key": key}
    g_meta = {"format": 1, "n_samples": N_SAMPLES, "seed": SEED,
              "max_sessions": 8, "next_row": 2 * N_SAMPLES,
              "sessions": sessions, "queue": [],
              "extra": {"tick": 3, "kind": "classifier",
                        "backend": "pallas_seq", "cell": "lstm",
                        "precision": None, "data_shards": 1,
                        "mcd": {"p": 0.125, "placement": "YN"}}}
    tenant = {"n_samples": N_SAMPLES, "precision": None,
              "backend": "pallas_seq", "group": "g0"}
    meta = {"fleet_format": 1, "tick": 3,
            "tenants": {"ward": dict(tenant, weight=3.0),
                        "anom": dict(tenant, weight=1.0)},
            "fair": {"admitted": {"ward": 3, "anom": 1},
                     "round": 5, "seq": 4},
            "groups": {"g0": g_meta},
            "queue": [{"tenant": "ward", "sid": "ward/p2",
                       "priority": 1, "attached": False}]}
    ckpt.save(root, 0, {"g0": g_tree}, meta=meta)
    return root


def _write_distill():
    """The distill_v1 layout: student sessions inside a normal snapshot."""
    rng = np.random.default_rng(91011)
    root = os.path.join(HERE, "snapshots", "distill_v1")
    if os.path.exists(root):
        shutil.rmtree(root)
    student_row = np.uint32(0x8000_0000 | N_SAMPLES)   # allocator id 2
    tree = {
        "ward_1": {"rows": np.arange(N_SAMPLES, dtype=np.uint32),
                   "state": _carry(rng, 2)},
        "ward_2": {"rows": np.array([student_row], np.uint32),
                   "state": [[rng.standard_normal((1, HIDDEN))
                              .astype(np.float32) for _ in range(2)]
                             for _ in range(NUM_LAYERS)]},
    }
    sessions = {
        "ward_1": {"steps": 7, "chunks": 2, "layers": NUM_LAYERS,
                   "parts": 2, "key": "ward_1"},
        "ward_2": {"steps": 7, "chunks": 2, "layers": NUM_LAYERS,
                   "parts": 2, "key": "ward_2", "mode": "student"},
    }
    meta = {"format": 1, "n_samples": N_SAMPLES, "seed": SEED,
            "max_sessions": 4, "next_row": N_SAMPLES + 1,
            "sessions": sessions,
            "queue": [{"sid": "ward_3", "priority": 0, "attached": False,
                       "mode": "student"}],
            "extra": {"tick": 2, "kind": "classifier",
                      "backend": "pallas_seq", "cell": "lstm",
                      "precision": None, "data_shards": 1,
                      "mcd": {"p": 0.125, "placement": "YN"}}}
    ckpt.save(root, 0, tree, meta=meta)
    return root


def main():
    _write("pr3_lstm", parts=2, include_parts_key=False,
           extra={"tick": 2, "kind": "classifier", "backend": "pallas_seq"})
    _write("pr4_gru", parts=1, include_parts_key=True,
           extra={"tick": 2, "kind": "classifier", "backend": "pallas_seq",
                  "cell": "gru",
                  "mcd": {"p": 0.125, "placement": "YN"}})
    _write_fleet()
    _write_distill()
    print("fixtures written under", os.path.join(HERE, "snapshots"))


if __name__ == "__main__":
    main()

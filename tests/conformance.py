"""Cross-backend conformance harness (ISSUE 6 satellite).

One place for the repo's strongest invariant: for the same ``(seed, rows)``
mask coordinates the three stack backends — ``reference`` (jnp scan),
``pallas_step`` (per-step kernel scan) and ``pallas_seq`` (sequence-fused
kernel) — produce **bit-identical** outputs and carries, at every serving
precision (``repro.kernels.quantize.PRECISIONS``), for ragged lengths and
across arbitrary chunk boundaries with carried state.

Two ground rules the helpers bake in (violating either breaks bit-identity
for reasons that look like kernel bugs but aren't):

* **Always pass explicit lengths.**  Bit-identity holds within the
  lengths-pinned graph family: the per-row freeze-select pins XLA's fusion
  choices.  Without lengths even the fp32 backends drift ~1e-7 apart.
  ``run_all_backends`` fills in full-T lengths when the caller has none.
* **Reference masks sample in the activation dtype.**  The kernels
  materialize the ``1/(1-p)`` scale in the activation dtype; reference
  masks sampled in fp32 would round differently under bf16 activations.

The helpers are deliberately backend-shaped, not model-shaped: kernel-level
tests (``test_mcd_lstm_seq`` / ``test_mcd_gru_seq``) reuse ``chunked_run``
with their own step closures, stack-level tests use ``run_all_backends``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mcd, rnn
from repro.kernels import quantize

BACKENDS = ("reference", "pallas_step", "pallas_seq")
#: None = native dtypes (the pre-quantization contract) + every knob value.
PRECISIONS = (None,) + quantize.PRECISIONS


def make_stack(cell: str = "lstm", hiddens=(16, 16), in_dim: int = 4,
               placement: str = "YN", p: float = 0.125, seed: int = 5,
               key: int = 0):
    """A small MCD stack: (cfg, params) — the conformance workload."""
    cfg = mcd.MCDConfig(p=p, placement=placement, seed=seed)
    params = rnn.init_stack(jax.random.key(key), in_dim, hiddens, cell=cell)
    return cfg, params


def stack_masks(cfg, rows, in_dim, hiddens, backend, *, cell="lstm",
                precision=None):
    """Backend-appropriate masks, sampled in the activation dtype."""
    if backend != "reference":
        return rnn.stack_mask_plan(cfg, len(hiddens))
    dt = quantize.activation_dtype(precision, jnp.float32)
    return rnn.sample_stack_masks(cfg, rows, in_dim, hiddens, dtype=dt,
                                  cell=cell)


def run_all_backends(params, x, cfg, hiddens, *, cell="lstm", precision=None,
                     lengths=None, initial_state=None):
    """Run the same lengths-pinned pass on all three backends.

    Returns ``{backend: (out, per-layer states)}``.  ``lengths`` defaults
    to full-T — the pin is mandatory, not optional (module docstring).
    """
    B, T, in_dim = x.shape
    rows = jnp.arange(B, dtype=jnp.uint32)
    if lengths is None:
        lengths = jnp.full((B,), T, jnp.int32)
    results = {}
    for backend in BACKENDS:
        masks = stack_masks(cfg, rows, in_dim, hiddens, backend, cell=cell,
                            precision=precision)
        results[backend] = rnn.run_stack(
            params, x, masks, cfg.p, backend=backend, rows=rows,
            seed=cfg.seed, lengths=lengths, initial_state=initial_state,
            return_all_states=True, cell=cell, precision=precision)
    return results


def assert_backends_identical(results, context: str = ""):
    """Every Pallas backend == reference, bit for bit, outputs and carries."""
    ref_out, ref_states = results["reference"]
    for backend in BACKENDS[1:]:
        out, states = results[backend]
        np.testing.assert_array_equal(
            np.asarray(ref_out, np.float32), np.asarray(out, np.float32),
            err_msg=f"{context} outputs: reference vs {backend}")
        assert len(ref_states) == len(states)
        for li, (ref_layer, layer) in enumerate(zip(ref_states, states)):
            assert len(ref_layer) == len(layer)
            for pi, (a, b) in enumerate(zip(ref_layer, layer)):
                assert a.dtype == b.dtype, (
                    f"{context} layer {li} part {pi}: carry dtype "
                    f"{a.dtype} (reference) vs {b.dtype} ({backend})")
                np.testing.assert_array_equal(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    err_msg=f"{context} layer {li} part {pi}: "
                            f"reference vs {backend}")


def chunked_run(step_fn, x, splits, *, state=None):
    """Feed ``x`` through ``step_fn`` chunk by chunk along time.

    ``step_fn(x_chunk, carried_state) -> (out_chunk, new_state)`` — the
    caller closes over whatever backend/kernel/engine it is testing and
    supplies per-chunk lengths inside the closure.  Returns the
    concatenated outputs and the final carried state; asserting those
    against one full-length pass is the chunk-invariance check every
    streaming test in the repo shares.
    """
    assert sum(splits) == x.shape[1], "splits must tile the sequence"
    outs, pos = [], 0
    for n in splits:
        out, state = step_fn(x[:, pos:pos + n], state)
        outs.append(out)
        pos += n
    return jnp.concatenate(outs, axis=1), state


def assert_states_equal(a, b, context: str = ""):
    """Per-layer carried states match bit for bit (any pytree arity)."""
    assert len(a) == len(b)
    for li, (la, lb) in enumerate(zip(a, b)):
        for pi, (pa, pb) in enumerate(zip(la, lb)):
            np.testing.assert_array_equal(
                np.asarray(pa, np.float32), np.asarray(pb, np.float32),
                err_msg=f"{context} layer {li} part {pi}")

"""GRU cell with per-gate MCD masks (paper §III-A: 'a similar design logic
can be used for other recurrent units such as the gated recurrent unit')."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cells, mcd


def test_gru_step_shapes_and_finite():
    B, I, H = 4, 12, 8
    p = cells.init_gru(jax.random.key(0), I, H)
    x = jax.random.normal(jax.random.key(1), (B, I))
    h = jnp.zeros((B, H))
    rows = jnp.arange(B, dtype=jnp.uint32)
    zx = jnp.stack([mcd.feature_mask(0, 0, rows, I, 0.125, gate=g)
                    for g in range(3)], axis=-2)
    zh = jnp.stack([mcd.feature_mask(0, 0, rows, H, 0.125, kind=mcd.KIND_H,
                                     gate=g) for g in range(3)], axis=-2)
    h1 = cells.gru_step(p, h, x, zx, zh, 0.125)
    assert h1.shape == (B, H)
    assert np.isfinite(np.asarray(h1)).all()


def test_gru_mask_tying_determinism():
    """Same masks (tied across steps) → same trajectory on repeat."""
    B, I, H = 2, 6, 4
    p = cells.init_gru(jax.random.key(0), I, H)
    xs = jax.random.normal(jax.random.key(1), (5, B, I))
    rows = jnp.arange(B, dtype=jnp.uint32)
    zx = jnp.stack([mcd.feature_mask(7, 0, rows, I, 0.25, gate=g)
                    for g in range(3)], axis=-2)
    zh = jnp.stack([mcd.feature_mask(7, 0, rows, H, 0.25, kind=mcd.KIND_H,
                                     gate=g) for g in range(3)], axis=-2)

    def run():
        h = jnp.zeros((B, H))
        for t in range(5):
            h = cells.gru_step(p, h, xs[t], zx, zh, 0.25)
        return h

    np.testing.assert_array_equal(np.asarray(run()), np.asarray(run()))


def test_gru_pointwise_no_mask():
    B, I, H = 2, 6, 4
    p = cells.init_gru(jax.random.key(0), I, H)
    x = jax.random.normal(jax.random.key(1), (B, I))
    h = cells.gru_step(p, jnp.zeros((B, H)), x, None, None, 0.0)
    assert np.isfinite(np.asarray(h)).all()

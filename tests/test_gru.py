"""GRU cell with per-gate MCD masks (paper §III-A: 'a similar design logic
can be used for other recurrent units such as the gated recurrent unit')."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cells, mcd


def test_gru_step_shapes_and_finite():
    B, I, H = 4, 12, 8
    p = cells.init_gru(jax.random.key(0), I, H)
    x = jax.random.normal(jax.random.key(1), (B, I))
    h = jnp.zeros((B, H))
    rows = jnp.arange(B, dtype=jnp.uint32)
    zx = jnp.stack([mcd.feature_mask(0, 0, rows, I, 0.125, gate=g)
                    for g in range(3)], axis=-2)
    zh = jnp.stack([mcd.feature_mask(0, 0, rows, H, 0.125, kind=mcd.KIND_H,
                                     gate=g) for g in range(3)], axis=-2)
    h1 = cells.gru_step(p, h, x, zx, zh, 0.125)
    assert h1.shape == (B, H)
    assert np.isfinite(np.asarray(h1)).all()


def test_gru_mask_tying_determinism():
    """Same masks (tied across steps) → same trajectory on repeat."""
    B, I, H = 2, 6, 4
    p = cells.init_gru(jax.random.key(0), I, H)
    xs = jax.random.normal(jax.random.key(1), (5, B, I))
    rows = jnp.arange(B, dtype=jnp.uint32)
    zx = jnp.stack([mcd.feature_mask(7, 0, rows, I, 0.25, gate=g)
                    for g in range(3)], axis=-2)
    zh = jnp.stack([mcd.feature_mask(7, 0, rows, H, 0.25, kind=mcd.KIND_H,
                                     gate=g) for g in range(3)], axis=-2)

    def run():
        h = jnp.zeros((B, H))
        for t in range(5):
            h = cells.gru_step(p, h, xs[t], zx, zh, 0.25)
        return h

    np.testing.assert_array_equal(np.asarray(run()), np.asarray(run()))


def test_gru_pointwise_no_mask():
    B, I, H = 2, 6, 4
    p = cells.init_gru(jax.random.key(0), I, H)
    x = jax.random.normal(jax.random.key(1), (B, I))
    h = cells.gru_step(p, jnp.zeros((B, H)), x, None, None, 0.0)
    assert np.isfinite(np.asarray(h)).all()


class TestGruComputeDtype:
    """The lstm_step dtype-policy alignment (ISSUE 4 satellite): bf16
    inputs/weights, fp32 gate accumulation — gru_step previously had no
    ``compute_dtype`` and never cast its weights."""

    def _setup(self, B=4, I=12, H=8):
        p = cells.init_gru(jax.random.key(0), I, H)
        x = jax.random.normal(jax.random.key(1), (B, I))
        h = jax.random.normal(jax.random.key(2), (B, H)) * 0.3
        rows = jnp.arange(B, dtype=jnp.uint32)
        zx = jnp.stack([mcd.feature_mask(0, 0, rows, I, 0.125, gate=g)
                        for g in range(3)], axis=-2)
        zh = jnp.stack([mcd.feature_mask(0, 0, rows, H, 0.125,
                                         kind=mcd.KIND_H, gate=g)
                        for g in range(3)], axis=-2)
        return p, x, h, zx, zh

    def test_bf16_inputs_cast_weights(self):
        """compute_dtype defaults to x's dtype: bf16 activations against
        fp32 params must compute in bf16 — same as casting params up front
        — not silently promote the matmuls to fp32."""
        p, x, h, zx, zh = self._setup()
        to = lambda a: a.astype(jnp.bfloat16)
        got = cells.gru_step(p, to(h), to(x), to(zx), to(zh), 0.125)
        pre = cells.GRUParams(*(to(w) for w in p))
        want = cells.gru_step(pre, to(h), to(x), to(zx), to(zh), 0.125)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(want, np.float32))

    def test_explicit_compute_dtype_casts_weights(self):
        """fp32 params + compute_dtype=bf16 must equal pre-cast bf16 params
        under the same knob — i.e. the weights really are cast (the old
        gru_step never touched them), while the output follows h's dtype."""
        p, x, h, zx, zh = self._setup()
        got = cells.gru_step(p, h, x, zx, zh, 0.125,
                             compute_dtype=jnp.bfloat16)
        assert got.dtype == h.dtype == jnp.float32
        pre = cells.GRUParams(*(w.astype(jnp.bfloat16) for w in p))
        want = cells.gru_step(pre, h, x, zx, zh, 0.125,
                              compute_dtype=jnp.bfloat16)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_fp32_default_unchanged(self):
        """The default path (fp32 in, no compute_dtype) is numerically the
        pre-fix graph: casts are no-ops."""
        p, x, h, zx, zh = self._setup()
        a = cells.gru_step(p, h, x, zx, zh, 0.125)
        b = cells.gru_step(p, h, x, zx, zh, 0.125,
                           compute_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_bf16_accumulation_stays_fp32(self):
        """bf16 end to end stays finite and close to the fp32 trajectory —
        the convex update and gate sums run in fp32 regardless of
        compute_dtype."""
        p, x, h, zx, zh = self._setup()
        to = lambda a: a.astype(jnp.bfloat16)
        pre = cells.GRUParams(*(to(w) for w in p))
        hb = to(h)
        for _ in range(5):
            hb = cells.gru_step(pre, hb, to(x), to(zx), to(zh), 0.125)
        assert hb.dtype == jnp.bfloat16
        hf = h
        for _ in range(5):
            hf = cells.gru_step(p, hf, x, zx, zh, 0.125)
        np.testing.assert_allclose(np.asarray(hb, np.float32),
                                   np.asarray(hf), rtol=0.1, atol=0.1)

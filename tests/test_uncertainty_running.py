"""Property tests for the incremental chain-axis uncertainty summaries.

The early-exit sampler (ISSUE 9) decides whether to retire a session's
surplus MC chains by comparing the uncertainty summary over a chain
*prefix* against the full set, both computed by the ``Running*Summary``
accumulators in ``repro.core.uncertainty``.  Two properties make that
decision trustworthy, and both are pinned here over randomized inputs:

1. **Batch agreement** — an accumulator fed all S chains finalizes to the
   same values (at fp32) as the batch formulas ``classification_summary``
   / ``regression_summary`` over the stacked ``[S, ...]`` array.
2. **Partition invariance** — any split of the chain axis into blocks,
   accumulated via ``update``/``merge`` in any grouping, agrees with the
   one-shot result (Chan's parallel rule; plain sums for classification).

Property-based via ``hypothesis`` when the environment has it; on minimal
installs ``tests/conftest.py`` provides a deterministic stand-in that
sweeps seeded examples through the same properties, so the coverage does
not silently vanish.  The strategies draw only a case *seed* — the case
shapes/values come from ``numpy.random.default_rng(seed)``, which both
the real and stand-in runners reproduce exactly.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.uncertainty import (ClassificationSummary,
                                    RegressionSummary,
                                    RunningClassificationSummary,
                                    RunningRegressionSummary,
                                    classification_summary,
                                    regression_summary)

# fp32 has ~7 decimal digits; the accumulators work in float64 and only
# round once at finalize, so agreement holds to a few ulps of the batch
# (fp32-accumulated) result's own error.
ATOL, RTOL = 1e-5, 1e-5


def _random_case(rng, *, regression: bool):
    s = int(rng.integers(2, 17))
    b = int(rng.integers(1, 4))
    scale = float(rng.uniform(0.1, 8.0))
    if regression:
        t, i = int(rng.integers(1, 6)), int(rng.integers(1, 3))
        means = rng.normal(0, scale, (s, b, t, i))
        log_vars = rng.normal(-1, 1, (s, b, t, i))
        return means, log_vars
    c = int(rng.integers(2, 7))
    return rng.normal(0, scale, (s, b, c))


def _partitions(rng, s):
    """A random composition of s into >=1 block sizes."""
    sizes, left = [], s
    while left > 0:
        k = int(rng.integers(1, left + 1))
        sizes.append(k)
        left -= k
    return sizes


def _assert_cls_close(got: ClassificationSummary,
                      want: ClassificationSummary):
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=ATOL, rtol=RTOL)


def _assert_reg_close(got: RegressionSummary, want: RegressionSummary):
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=ATOL, rtol=RTOL)


def _check_classification(logits, sizes):
    want = classification_summary(np.asarray(logits, np.float32))
    acc = RunningClassificationSummary()
    off = 0
    for k in sizes:
        acc.update(logits[off:off + k])
        off += k
    _assert_cls_close(acc.finalize(), want)
    # merge of independently-built accumulators agrees too
    merged = RunningClassificationSummary()
    off = 0
    for k in sizes:
        merged.merge(RunningClassificationSummary().update(
            logits[off:off + k]))
        off += k
    _assert_cls_close(merged.finalize(), want)


def _check_regression(means, log_vars, sizes):
    want = regression_summary(np.asarray(means, np.float32),
                              np.asarray(log_vars, np.float32))
    acc = RunningRegressionSummary()
    off = 0
    for k in sizes:
        acc.update(means[off:off + k], log_vars[off:off + k])
        off += k
    _assert_reg_close(acc.finalize(), want)
    merged = RunningRegressionSummary()
    off = 0
    for k in sizes:
        merged.merge(RunningRegressionSummary().update(
            means[off:off + k], log_vars[off:off + k]))
        off += k
    _assert_reg_close(merged.finalize(), want)


@settings(max_examples=60)
@given(seed=st.integers(0, 2**32 - 1))
def test_classification_matches_batch_any_partition(seed):
    rng = np.random.default_rng(seed)
    logits = _random_case(rng, regression=False)
    _check_classification(logits, _partitions(rng, logits.shape[0]))


@settings(max_examples=60)
@given(seed=st.integers(0, 2**32 - 1))
def test_regression_matches_batch_any_partition(seed):
    rng = np.random.default_rng(seed)
    means, log_vars = _random_case(rng, regression=True)
    _check_regression(means, log_vars, _partitions(rng, means.shape[0]))


class TestEdgeCases:
    def test_single_chain_prefix_then_rest(self):
        """The early-exit access pattern: prefix block, copy, fold rest."""
        rng = np.random.default_rng(7)
        logits = rng.normal(0, 3, (8, 2, 5))
        prefix = RunningClassificationSummary().update(logits[:4])
        full = prefix.copy().update(logits[4:])
        # the copy kept the prefix accumulator intact
        assert prefix.count == 4 and full.count == 8
        _assert_cls_close(prefix.finalize(),
                          classification_summary(
                              np.asarray(logits[:4], np.float32)))
        _assert_cls_close(full.finalize(),
                          classification_summary(
                              np.asarray(logits, np.float32)))

    def test_regression_without_log_vars(self):
        rng = np.random.default_rng(8)
        means = rng.normal(0, 2, (6, 1, 3, 1))
        want = regression_summary(np.asarray(means, np.float32), None)
        got = RunningRegressionSummary().update(means).finalize()
        _assert_reg_close(got, want)
        assert float(np.max(np.abs(np.asarray(got.aleatoric)))) == 0.0

    def test_identical_chains_give_exactly_zero_epistemic(self):
        """The zeros-traffic early-exit argument: identical chains mean
        exactly zero MI / epistemic variance — not merely tiny — so a 0.0
        threshold retires them and nothing else."""
        block = np.tile(np.arange(6.0)[None, None, :], (5, 1, 1))  # [5,1,6]
        cls = RunningClassificationSummary().update(block).finalize()
        assert float(np.asarray(cls.mutual_information)[0]) == 0.0
        means = np.tile(np.ones((1, 2, 3, 1)), (4, 1, 1, 1))
        reg = RunningRegressionSummary().update(means).finalize()
        assert float(np.max(np.asarray(reg.epistemic))) == 0.0

    def test_empty_finalize_raises(self):
        with pytest.raises(ValueError, match="no chains"):
            RunningClassificationSummary().finalize()
        with pytest.raises(ValueError, match="no chains"):
            RunningRegressionSummary().finalize()

    def test_bad_block_shapes_raise(self):
        with pytest.raises(ValueError, match=r"\[s, B, C\]"):
            RunningClassificationSummary().update(np.zeros((3, 4)))
        with pytest.raises(ValueError, match=r"\[s, "):
            RunningRegressionSummary().update(np.zeros(3))

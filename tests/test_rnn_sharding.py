"""Multi-device data plane: sharded == unsharded, bit for bit.

The ISSUE 5 acceptance invariants live here:

* ``run_stack(mesh=...)`` output is **bit-identical** to the unsharded
  lengths-enabled reference at device counts 1, 2 and 8, for both cells,
  under both strategies (shard_map data partition and the GSPMD wide-H
  fallback) — masks key off global ``(seed, rows)`` coordinates, so no
  device ever draws different bits;
* chunked == unchunked stays bit-identical *through* the mesh (carried
  state crosses shard boundaries losslessly);
* a mesh-placed ``StreamingEngine`` serves bit-identically to an
  unsharded one, and a snapshot taken on an N-device engine restores onto
  a 1-device engine (and vice versa) — host-portability of the durable
  state.

Device counts above the host's are skipped; CI runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the 2- and
8-way cases are exercised (single-device runs still pin the mesh=1 path).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import classifier as clf, mcd, rnn
from repro.launch import rnn_shardings as rs
from repro.launch.mesh import make_data_mesh
from repro.serve import StreamingEngine

DEVICE_COUNTS = (1, 2, 8)
CELLS = ("lstm", "gru")


def _mesh_or_skip(n_data: int, model: int = 1):
    if n_data * model > len(jax.devices()):
        pytest.skip(f"needs {n_data * model} devices, host has "
                    f"{len(jax.devices())} (set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    return make_data_mesh(n_data, model=model)


def _stack(cell, B=7, T=5, H=8, NL=3, seed=0, dtype=jnp.float32):
    cfg = mcd.MCDConfig(p=0.125, placement="YNY", n_samples=2, seed=seed)
    params = rnn.init_stack(jax.random.key(0), 1, (H,) * NL, dtype, cell=cell)
    rows = jnp.arange(B, dtype=jnp.uint32)
    x = jax.random.normal(jax.random.key(1), (B, T, 1), dtype)
    lengths = jnp.asarray([(i % T) + 1 for i in range(B)], jnp.int32)
    return cfg, params, rows, x, lengths


def _assert_tree_equal(got, want):
    for la, lb in zip(got, want):
        for a, b in zip(la, lb):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestShardedStack:
    @pytest.mark.parametrize("cell", CELLS)
    @pytest.mark.parametrize("n_dev", DEVICE_COUNTS)
    def test_data_strategy_bit_identical(self, cell, n_dev):
        mesh = _mesh_or_skip(n_dev)
        cfg, params, rows, x, lengths = _stack(cell)
        masks = rnn.stack_mask_plan(cfg, 3)
        ref_o, ref_s = rnn.run_stack(params, x, masks, cfg.p,
                                     backend="pallas_seq", rows=rows,
                                     seed=cfg.seed, lengths=lengths,
                                     return_all_states=True, cell=cell)
        out, states = rnn.run_stack(params, x, masks, cfg.p,
                                    backend="pallas_seq", rows=rows,
                                    seed=cfg.seed, lengths=lengths,
                                    return_all_states=True, cell=cell,
                                    mesh=mesh)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_o))
        _assert_tree_equal(states, ref_s)

    @pytest.mark.parametrize("cell", CELLS)
    @pytest.mark.parametrize("n_dev", DEVICE_COUNTS)
    def test_gspmd_strategy_bit_identical(self, cell, n_dev):
        """The wide-H fallback (reference scan, H over `model`) draws the
        same bits and computes the same numbers as the Pallas launch —
        the lengths-pinned graph family is backend- and shard-invariant."""
        model = 2 if n_dev * 2 <= len(jax.devices()) else 1
        mesh = _mesh_or_skip(n_dev, model)
        cfg, params, rows, x, lengths = _stack(cell)
        masks = rnn.stack_mask_plan(cfg, 3)
        ref_o, _ = rnn.run_stack(params, x, masks, cfg.p,
                                 backend="pallas_seq", rows=rows,
                                 seed=cfg.seed, lengths=lengths,
                                 return_all_states=True, cell=cell)
        out, _ = rnn.run_stack(params, x, masks, cfg.p, backend="pallas_seq",
                               rows=rows, seed=cfg.seed, lengths=lengths,
                               return_all_states=True, cell=cell, mesh=mesh,
                               policy=rs.StackShardingPolicy(strategy="gspmd"))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_o))

    @pytest.mark.parametrize("cell", CELLS)
    def test_chunked_equals_unchunked_through_mesh(self, cell):
        """Carried state crosses chunk boundaries losslessly on a mesh:
        chunk 1 sharded → carry → chunk 2 sharded == one unsharded pass."""
        n_dev = max(c for c in DEVICE_COUNTS if c <= len(jax.devices()))
        mesh = make_data_mesh(n_dev)
        cfg, params, rows, x, _ = _stack(cell, T=6)
        T = x.shape[1]
        full = jnp.full((x.shape[0],), T, jnp.int32)
        masks = rnn.stack_mask_plan(cfg, 3)
        kw = dict(p=cfg.p, backend="pallas_seq", rows=rows, seed=cfg.seed,
                  return_all_states=True, cell=cell)
        _, want = rnn.run_stack(params, x, masks, lengths=full, **kw)
        cut = 3
        part = jnp.full((x.shape[0],), cut, jnp.int32)
        _, s1 = rnn.run_stack(params, x[:, :cut], masks, lengths=part,
                              mesh=mesh, **kw)
        _, got = rnn.run_stack(params, x[:, cut:], masks,
                               lengths=full - cut, initial_state=s1,
                               mesh=mesh, **kw)
        _assert_tree_equal(got, want)

    def test_reference_backend_routes_to_gspmd(self):
        mesh = _mesh_or_skip(1)
        cfg, params, rows, x, lengths = _stack("lstm")
        masks = rnn.sample_stack_masks(cfg, rows, 1, (8,) * 3)
        ref_o, _ = rnn.run_stack(params, x, masks, cfg.p,
                                 backend="reference", rows=rows,
                                 lengths=lengths, return_all_states=True)
        out, _ = rnn.run_stack(params, x, masks, cfg.p, backend="reference",
                               rows=rows, lengths=lengths,
                               return_all_states=True, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_o))

    def test_host_numpy_masks_accepted(self):
        """Regression: numpy mask values (not jax.Arrays) used to land in
        the static plan that keys the compiled-callable cache →
        'unhashable type: numpy.ndarray'.  They must behave like the
        unsharded path: arrays are arrays, wherever they were made."""
        mesh = _mesh_or_skip(1)
        cfg, params, rows, x, lengths = _stack("lstm")
        masks = [tuple(None if m is None else np.asarray(m) for m in pair)
                 for pair in rnn.sample_stack_masks(cfg, rows, 1, (8,) * 3)]
        ref_o, _ = rnn.run_stack(params, x, masks, cfg.p,
                                 backend="reference", rows=rows,
                                 lengths=lengths, return_all_states=True)
        out, _ = rnn.run_stack(params, x, masks, cfg.p, backend="reference",
                               rows=rows, lengths=lengths,
                               return_all_states=True, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_o))

    def test_mesh_requires_rows(self):
        mesh = _mesh_or_skip(1)
        cfg, params, _, x, lengths = _stack("lstm")
        with pytest.raises(ValueError, match="rows"):
            rnn.run_stack(params, x, rnn.stack_mask_plan(cfg, 3), cfg.p,
                          backend="pallas_seq", lengths=lengths, mesh=mesh)


class TestPolicy:
    def test_resolve_strategy(self):
        mesh = _mesh_or_skip(1, 1)
        po = rs.DEFAULT_POLICY
        assert rs.resolve_strategy(mesh, po, "reference", [8]) == "gspmd"
        assert rs.resolve_strategy(mesh, po, "pallas_seq", [8]) == "data"
        # wide H falls back to gspmd only when a model axis exists to use
        assert rs.resolve_strategy(mesh, po, "pallas_seq", [4096]) == "data"
        if len(jax.devices()) >= 2:
            mesh2 = make_data_mesh(1, model=2)
            assert rs.resolve_strategy(mesh2, po, "pallas_seq",
                                       [4096]) == "gspmd"
            assert rs.resolve_strategy(mesh2, po, "pallas_seq",
                                       [8]) == "data"
        forced = rs.StackShardingPolicy(strategy="gspmd")
        assert rs.resolve_strategy(mesh, forced, "pallas_seq", [8]) == "gspmd"
        with pytest.raises(ValueError, match="strategy"):
            rs.StackShardingPolicy(strategy="banana")

    def test_param_specs_shard_h_out_dim_only(self):
        if len(jax.devices()) < 2:
            pytest.skip("needs 2 devices for a model axis")
        mesh = make_data_mesh(1, model=2)
        params = rnn.init_stack(jax.random.key(0), 1, (8, 8))
        specs = rs.stack_param_specs(params, mesh, strategy="gspmd")
        for sp in specs:
            assert sp.wx[-1] == "model" and sp.wh[-1] == "model"
            assert sp.wh[1] is None          # contraction dim never sharded
        # indivisible H replicates instead of erroring
        odd = rnn.init_stack(jax.random.key(0), 1, (7,))
        (sp,) = rs.stack_param_specs(odd, mesh, strategy="gspmd")
        assert sp.wh[-1] is None
        # the data strategy replicates weights entirely
        for sp in rs.stack_param_specs(params, mesh, strategy="data"):
            assert all(ax is None for ax in sp.wh)

    def test_shard_pad_floor(self):
        assert rs._shard_pad(7, 1) == 0       # 1 device = exact unsharded run
        assert rs._shard_pad(7, 2) == 1       # even split
        assert rs._shard_pad(8, 8) == 8       # 2-row floor per shard
        assert rs._shard_pad(16, 8) == 0


class TestShardedEngine:
    def _engine(self, cell, mesh, s=2, max_sessions=3):
        cfg = clf.ClassifierConfig(
            hidden=8, num_layers=2, cell=cell,
            mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=s, seed=3))
        params = clf.init(jax.random.key(0), cfg)
        return StreamingEngine(params, cfg, backend="pallas_seq",
                               max_sessions=max_sessions, mesh=mesh)

    @pytest.mark.parametrize("cell", CELLS)
    def test_mesh_engine_serves_bit_identically(self, cell):
        n_dev = max(c for c in DEVICE_COUNTS if c <= len(jax.devices()))
        plain = self._engine(cell, None)
        meshy = self._engine(cell, make_data_mesh(n_dev))
        sigs = {f"s{k}": jax.random.normal(jax.random.key(k), (9, 1))
                for k in range(3)}
        for eng in (plain, meshy):
            for sid in sigs:
                eng.open_session(sid)
        # ragged ticks: different chunk lengths per session per tick
        for lens in ((9, 4, 7), (3, 9, 1)):
            want = plain.step({sid: sig[:n] for (sid, sig), n
                               in zip(sigs.items(), lens)})
            got = meshy.step({sid: sig[:n] for (sid, sig), n
                              in zip(sigs.items(), lens)})
            for sid in want:
                np.testing.assert_array_equal(
                    np.asarray(want[sid].summary.probs),
                    np.asarray(got[sid].summary.probs))
        assert meshy.last_metrics.shards == n_dev
        assert plain.last_metrics.shards == 1

    def test_snapshot_is_mesh_portable(self, tmp_path):
        """Snapshot on an N-device engine, restore on a 1-device engine:
        the continuation is bit-identical to the uninterrupted unsharded
        run (and the N-dev continuation matches too) — durable state
        carries nothing device-shaped."""
        n_dev = max(c for c in DEVICE_COUNTS if c <= len(jax.devices()))
        sig = jax.random.normal(jax.random.key(9), (12, 1))
        # uninterrupted, unsharded ground truth
        base = self._engine("lstm", None)
        base.open_session("p")
        base.step({"p": sig[:5]})
        want = base.step({"p": sig[5:]})["p"]
        # sharded engine, killed mid-stream
        meshy = self._engine("lstm", make_data_mesh(n_dev))
        meshy.open_session("p")
        meshy.step({"p": sig[:5]})
        meshy.snapshot(str(tmp_path))
        # restored onto a single device (mesh=None)
        fresh = self._engine("lstm", None)
        fresh.restore(str(tmp_path))
        got = fresh.step({"p": sig[5:]})["p"]
        np.testing.assert_array_equal(np.asarray(want.summary.probs),
                                      np.asarray(got.summary.probs))
        assert got.steps_total == want.steps_total
        # and back onto a mesh: 1-dev snapshot → N-dev engine
        base2 = self._engine("lstm", None)
        base2.open_session("p")
        base2.step({"p": sig[:5]})
        snap2 = tmp_path / "snap2"
        base2.snapshot(str(snap2))
        meshy2 = self._engine("lstm", make_data_mesh(n_dev))
        meshy2.restore(str(snap2))
        got2 = meshy2.step({"p": sig[5:]})["p"]
        np.testing.assert_array_equal(np.asarray(want.summary.probs),
                                      np.asarray(got2.summary.probs))

    def test_slot_padding_keeps_whole_sessions_per_shard(self):
        """max_sessions that doesn't divide the shard count pads slots up:
        batch_rows is a multiple of shards × S and results are unchanged."""
        if len(jax.devices()) < 2:
            pytest.skip("needs 2 devices")
        meshy = self._engine("lstm", make_data_mesh(2), s=3, max_sessions=3)
        plain = self._engine("lstm", None, s=3, max_sessions=3)
        sig = jax.random.normal(jax.random.key(2), (6, 1))
        for eng in (meshy, plain):
            eng.open_session("a")
            eng.step({"a": sig})
        m = meshy.last_metrics
        assert m.batch_rows % (2 * 3) == 0
        np.testing.assert_array_equal(
            np.asarray(meshy.store.get("a").state[0][0]),
            np.asarray(plain.store.get("a").state[0][0]))

"""End-to-end behaviour of the paper's system on the ECG task (integration).

Reproduces the paper's qualitative claims on the synthetic ECG5000:
  * the classifier trains to usable accuracy with MCD on (§V-A2),
  * the Bayesian autoencoder separates anomalies by reconstruction error
    (§V-A1) and is *more uncertain* on anomalies than on normals (Fig. 1),
  * Gaussian-noise inputs get higher predictive entropy than real beats.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autoencoder as ae
from repro.core import bayesian, classifier as clf, mcd, uncertainty as unc
from repro.data import ecg
from repro.train import optimizer, trainer


@pytest.fixture(scope="module")
def data():
    return ecg.make_ecg5000(0)


@pytest.fixture(scope="module")
def trained_classifier(data):
    tx, ty, ex, ey = data
    cfg = clf.ClassifierConfig(
        hidden=8, num_layers=2,
        mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=10, seed=0))
    params = clf.init(jax.random.key(0), cfg)

    def loss(p, batch, step):
        x, y = batch
        rows = jnp.arange(x.shape[0], dtype=jnp.uint32)
        logits = clf.apply(p, x, rows, cfg)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], -1)), {}

    tcfg = trainer.TrainConfig(adamw=optimizer.AdamWConfig(lr=3e-3),
                               log_every=0)
    tr = trainer.Trainer(loss, params, tcfg)
    pipe = ecg.Pipeline(tx, ty, batch_size=64, seed=0)
    batches = (tuple(map(jnp.asarray, b))
               for e in range(40) for b in pipe.epoch(e))
    hist = tr.run(batches, 120)
    return cfg, tr.params, hist


class TestClassifierEndToEnd:
    def test_loss_decreases(self, trained_classifier):
        _, _, hist = trained_classifier
        assert hist[-1]["loss"] < 0.7 * hist[0]["loss"]

    def test_bayesian_test_accuracy(self, trained_classifier, data):
        cfg, params, _ = trained_classifier
        _, _, ex, ey = data
        x = jnp.asarray(ex[:512])
        logits = bayesian.predict(
            lambda p, x_, r: clf.apply(p, x_, r, cfg), params, x, cfg.mcd)
        s = unc.classification_summary(logits)
        acc = float(unc.accuracy(s.probs, jnp.asarray(ey[:512])))
        assert acc > 0.6, acc      # majority class is 58%

    def test_noise_entropy_higher_than_data(self, trained_classifier, data):
        """Paper §V-A2: predictive entropy on random Gaussian noise."""
        cfg, params, _ = trained_classifier
        _, _, ex, _ = data
        x = jnp.asarray(ex[:256])
        noise = jax.random.normal(jax.random.key(9), x.shape)
        ent = lambda inp: float(unc.classification_summary(
            bayesian.predict(lambda p, x_, r: clf.apply(p, x_, r, cfg),
                             params, inp, cfg.mcd)).predictive_entropy.mean())
        assert ent(noise) > ent(x)


class TestAutoencoderEndToEnd:
    @pytest.fixture(scope="class")
    def trained_ae(self, data):
        tx, ty, _, _ = data
        normal = jnp.asarray(tx[ty == 0])          # train on normals only
        cfg = ae.AutoencoderConfig(
            hidden=16, num_layers=1,
            mcd=mcd.MCDConfig(p=0.125, placement="YY", n_samples=10, seed=0))
        params = ae.init(jax.random.key(0), cfg)

        def loss(p, batch, step):
            x = batch
            rows = jnp.arange(x.shape[0], dtype=jnp.uint32)
            mean, log_var = ae.apply(p, x, rows, cfg)
            return jnp.mean(ae.gaussian_nll(mean, log_var, x)), {}

        tcfg = trainer.TrainConfig(adamw=optimizer.AdamWConfig(lr=3e-3),
                                   log_every=0)
        tr = trainer.Trainer(loss, params, tcfg)
        batches = (normal[(i * 64) % 256:(i * 64) % 256 + 64]
                   for i in range(120))
        tr.run(batches, 120)
        return cfg, tr.params

    def test_anomaly_separation(self, trained_ae, data):
        cfg, params = trained_ae
        _, _, ex, ey = data
        x = jnp.asarray(ex[:768])
        is_anom = np.asarray(ey[:768]) != 0

        means, log_vars = bayesian.predict(
            lambda p, x_, r: ae.apply(p, x_, r, cfg), params, x, cfg.mcd)
        s = unc.regression_summary(means, log_vars)
        score = np.asarray(unc.rmse(s, x))
        # rank-based ROC-AUC: anomalies reconstruct worse (paper §V-A1)
        order = np.argsort(score)
        ranks = np.empty(len(score))
        ranks[order] = np.arange(1, len(score) + 1)
        pos, neg = is_anom.sum(), (~is_anom).sum()
        auc = (ranks[is_anom].sum() - pos * (pos + 1) / 2) / (pos * neg)
        assert auc > 0.55, auc

    def test_fig1_uncertainty_on_morphology_anomaly(self, trained_ae, data):
        """Fig. 1: the model is *more uncertain* on the anomalous beat.  The
        paper's figure shows a morphology anomaly (inverted/shifted waves) —
        class 1 here; at CI training budgets the heteroscedastic head is not
        yet discriminative on the fibrillation class."""
        cfg, params = trained_ae
        _, _, ex, ey = data
        xn = jnp.asarray(ex[ey == 0][:128])
        xa = jnp.asarray(ex[ey == 1][:64])

        def total_unc(x):
            means, log_vars = bayesian.predict(
                lambda p, x_, r: ae.apply(p, x_, r, cfg), params, x, cfg.mcd)
            return float(unc.regression_summary(means, log_vars).total.mean())

        assert total_unc(xa) > total_unc(xn)

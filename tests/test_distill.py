"""Distilled fast path (ISSUE 10): single-chain student + uncertainty head.

Covers the distillation stack end to end:

* ``repro.core.distill`` — deterministic (flagged) rows are the identity
  draw in every backend, student heads adopt the teacher's prediction head,
  the one-pass summaries obey the same decomposition identities as the
  S-chain estimator, and the teacher targets are exactly the ``Running*``
  accumulators' output.
* ``repro.train.distill`` — the heads-only trainer actually fits, and
  ``cache_targets`` (one teacher sweep, cycled head steps) is equivalent to
  re-feeding the same batches.
* serving integration — a ``mode="student"`` session's summary equals the
  student heads on a solo deterministic pass, co-batching with MC sessions
  changes nothing, and ``student_rows``/``escalations`` thread through
  ``JsonlSink``/``summarize``/fleet attribution.

The escalation/regrowth bit-identity pin (``SessionStore.grow``) lives in
``tests/test_streaming.py``; snapshot durability of session modes in
``tests/test_snapshot_compat.py``.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autoencoder as ae, classifier as clf, distill, mcd
from repro.core.uncertainty import RunningClassificationSummary
from repro.serve import (FleetEngine, JsonlSink, StreamingEngine, TenantSpec,
                         summarize)
from repro.train import distill as distill_train

BACKENDS = ("reference", "pallas_step", "pallas_seq")


def _clf_cfg(s=4, seed=3, placement="YN"):
    return clf.ClassifierConfig(
        hidden=8, num_layers=2, num_classes=4,
        mcd=mcd.MCDConfig(p=0.25, placement=placement, n_samples=s,
                          seed=seed))


def _ae_cfg(s=4, heteroscedastic=True):
    return ae.AutoencoderConfig(
        hidden=8, num_layers=1, heteroscedastic=heteroscedastic,
        mcd=mcd.MCDConfig(p=0.25, placement="Y", n_samples=s, seed=1))


def _x(b=3, t=6, key=0):
    return jax.random.normal(jax.random.key(key), (b, t, 1))


class TestDetRows:
    def test_flag_roundtrip(self):
        rows = np.asarray(distill.det_rows(3, base=5))
        assert [mcd.base_row(r) for r in rows] == [5, 6, 7]
        assert all(mcd.is_student_row(r) for r in rows)
        assert not mcd.is_student_row(mcd.base_row(rows[0]))

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_det_row_is_the_identity_draw(self, backend):
        """A flagged row's masks are the identity: its output equals the
        same trunk with MC dropout placed nowhere — for any base id.
        (Allclose against the no-placement graph: it skips the mask
        multiply entirely, a different op order at float epsilon.)"""
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        x = _x()
        out = clf.apply(params, x, distill.det_rows(3), cfg, backend=backend)
        cfg_off = dataclasses.replace(
            cfg, mcd=cfg.mcd.replace(placement="NN"))
        want = clf.apply(params, x, jnp.arange(3, dtype=jnp.uint32), cfg_off,
                         backend=backend)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=1e-6)
        shifted = clf.apply(params, x, distill.det_rows(3, base=17), cfg,
                            backend=backend)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(shifted))

    def test_det_rows_agree_across_backends(self):
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        x = _x()
        outs = [np.asarray(clf.apply(params, x, distill.det_rows(3), cfg,
                                     backend=b)) for b in BACKENDS]
        for got in outs[1:]:
            np.testing.assert_allclose(got, outs[0], atol=1e-5)


class TestStudentHeads:
    def test_init_adopts_teacher_head(self):
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        student = distill.init_student(jax.random.key(1), cfg, params)
        assert set(student) == {"head", "unc"}
        for a, b in zip(jax.tree_util.tree_leaves(student["head"]),
                        jax.tree_util.tree_leaves(params["head"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # the uncertainty head is H -> 1 and always fresh
        w = jax.tree_util.tree_leaves(student["unc"])
        assert any(lf.shape == (cfg.hidden, 1) for lf in w)

    def test_classifier_summary_decomposition(self):
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        student = distill.init_student(jax.random.key(1), cfg, params)
        h = jax.random.normal(jax.random.key(2), (5, cfg.hidden))
        summ = distill.classifier_student_summary(student, h)
        probs = np.asarray(summ.probs)
        np.testing.assert_allclose(probs.sum(-1), 1.0, atol=1e-6)
        assert (np.asarray(summ.mutual_information) >= 0).all()
        np.testing.assert_allclose(
            np.asarray(summ.expected_entropy),
            np.asarray(summ.predictive_entropy)
            - np.asarray(summ.mutual_information), atol=1e-6)

    @pytest.mark.parametrize("het", (True, False))
    def test_autoencoder_summary_decomposition(self, het):
        cfg = _ae_cfg(heteroscedastic=het)
        params = ae.init(jax.random.key(0), cfg)
        student = distill.init_student(jax.random.key(1), cfg, params)
        dec = jax.random.normal(jax.random.key(2), (2, 6, cfg.hidden))
        summ = distill.autoencoder_student_summary(student, dec, het)
        np.testing.assert_allclose(
            np.asarray(summ.total),
            np.asarray(summ.aleatoric) + np.asarray(summ.epistemic),
            atol=1e-6)
        assert (np.asarray(summ.epistemic) >= 0).all()
        if not het:
            assert (np.asarray(summ.aleatoric) == 0).all()


class TestTeacherTargets:
    def test_classifier_targets_are_the_running_estimator(self):
        """The distill target is exactly what serving reports: S chains
        folded through RunningClassificationSummary, chain-major rows."""
        cfg = _clf_cfg(s=3)
        params = clf.init(jax.random.key(0), cfg)
        x = _x(b=2)
        got = distill.classifier_teacher_targets(params, x, cfg)
        S, B = 3, 2
        logits = clf.apply(params, jnp.tile(x, (S, 1, 1)),
                           jnp.arange(S * B, dtype=jnp.uint32), cfg)
        acc = RunningClassificationSummary()
        acc.update(jnp.reshape(logits, (S, B, -1)))
        want = acc.finalize()
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    def test_n_samples_and_base_row_override(self):
        cfg = _clf_cfg(s=4)
        params = clf.init(jax.random.key(0), cfg)
        x = _x(b=1)
        a = distill.classifier_teacher_targets(params, x, cfg, n_samples=2)
        b = distill.classifier_teacher_targets(params, x, cfg, n_samples=2,
                                               base_row=64)
        # different rows, different draws — same estimator, different value
        assert not np.array_equal(np.asarray(a.probs), np.asarray(b.probs))

    def test_autoencoder_targets_shapes(self):
        cfg = _ae_cfg(s=3)
        params = ae.init(jax.random.key(0), cfg)
        x = _x(b=2, t=5)
        t = distill.autoencoder_teacher_targets(params, x, cfg)
        assert np.asarray(t.mean).shape[0] == 2
        assert (np.asarray(t.epistemic) >= 0).all()


class TestDistillTrainer:
    def test_classifier_heads_fit(self):
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        xs = [_x(b=4, key=k) for k in range(2)]
        dcfg = distill_train.DistillConfig(lr=3e-2, cache_targets=True)
        student, hist = distill_train.distill_classifier(
            params, cfg, xs, 60, key=jax.random.key(1), dcfg=dcfg)
        assert len(hist) == 60
        assert hist[-1]["loss"] < hist[0]["loss"]

    def test_autoencoder_heads_fit(self):
        cfg = _ae_cfg()
        params = ae.init(jax.random.key(0), cfg)
        xs = [_x(b=4, t=5)]
        dcfg = distill_train.DistillConfig(lr=3e-2, cache_targets=True)
        student, hist = distill_train.distill_autoencoder(
            params, cfg, xs, 40, key=jax.random.key(1), dcfg=dcfg)
        assert hist[-1]["loss"] < hist[0]["loss"]

    def test_cache_targets_equals_refeeding(self):
        """Cycling one cached teacher batch must produce the same student
        as feeding the identical batch again (targets are deterministic in
        (params, x) — re-sweeping buys nothing)."""
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        x = _x(b=4)
        cached, _ = distill_train.distill_classifier(
            params, cfg, [x], 4, key=jax.random.key(1),
            dcfg=distill_train.DistillConfig(cache_targets=True))
        refed, _ = distill_train.distill_classifier(
            params, cfg, [x, x, x, x], 4, key=jax.random.key(1),
            dcfg=distill_train.DistillConfig())
        for a, b in zip(jax.tree_util.tree_leaves(cached),
                        jax.tree_util.tree_leaves(refed)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _student_engine(cfg_fn=_clf_cfg, init_fn=clf.init, **kw):
    cfg = cfg_fn()
    params = init_fn(jax.random.key(0), cfg)
    student = distill.init_student(jax.random.key(1), cfg, params)
    eng = StreamingEngine(params, cfg, backend="pallas_seq", student=student,
                          **kw)
    return eng, params, cfg, student


class TestStudentServing:
    def test_admission_requires_heads(self):
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        eng = StreamingEngine(params, cfg, backend="pallas_seq")
        with pytest.raises(ValueError, match="student"):
            eng.open_session("s", mode="student")
        with pytest.raises(ValueError, match="student"):
            StreamingEngine(params, cfg, backend="pallas_seq",
                            student_escalate_threshold=0.1)
        student = distill.init_student(jax.random.key(1), cfg, params)
        with pytest.raises(ValueError, match=">= 0"):
            StreamingEngine(params, cfg, backend="pallas_seq",
                            student=student,
                            student_escalate_threshold=-1.0)

    def test_classifier_summary_matches_direct_student_pass(self):
        """A served student chunk == the student heads on a solo
        deterministic trunk pass over the same signal."""
        eng, params, cfg, student = _student_engine(max_sessions=1)
        eng.open_session("s", mode="student")
        x = np.asarray(_x(b=1, t=6, key=5)[0], np.float32)
        got = eng.step({"s": jnp.asarray(x)})["s"].summary
        _, states = clf.apply(params, jnp.asarray(x)[None],
                              distill.det_rows(1), cfg,
                              backend="pallas_seq", return_state=True)
        want = distill.classifier_student_summary(student, states[-1][0])
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w[0]))

    def test_autoencoder_student_session_serves(self):
        eng, params, cfg, student = _student_engine(
            cfg_fn=_ae_cfg, init_fn=ae.init, max_sessions=1)
        eng.open_session("s", mode="student")
        x = np.asarray(_x(b=1, t=5, key=6)[0], np.float32)
        got = eng.step({"s": jnp.asarray(x)})["s"].summary
        assert np.asarray(got.mean).shape[0] == 5
        np.testing.assert_allclose(
            np.asarray(got.total),
            np.asarray(got.aleatoric) + np.asarray(got.epistemic), atol=1e-6)
        assert eng.last_metrics.student_rows == 1

    def test_cobatching_with_mc_sessions_changes_nothing(self):
        """Student rows fold into the same per-layer launches as the MC
        sessions; neither side's outputs move.  The student side is
        allclose-pinned: its h_T rides a different batch geometry solo vs
        co-batched (XLA batches the matmul differently at float epsilon),
        unlike MC rows whose summary reductions are layout-invariant."""
        solo, params, cfg, student = _student_engine(max_sessions=1)
        solo.open_session("s", mode="student")
        mixed = StreamingEngine(params, cfg, backend="pallas_seq",
                                student=student, max_sessions=3)
        mc_solo = StreamingEngine(params, cfg, backend="pallas_seq",
                                  max_sessions=2)
        # admission order: MC first so the MC engines hand out identical
        # row ids; the student row's id is compute-irrelevant either way
        mixed.open_session("mc0")
        mixed.open_session("mc1")
        mixed.open_session("s", mode="student")
        mc_solo.open_session("mc0")
        mc_solo.open_session("mc1")
        for t in range(3):
            x = {sid: _sig(10 + 3 * t + i, 4)
                 for i, sid in enumerate(("mc0", "mc1", "s"))}
            got = mixed.step(x)
            want_s = solo.step({"s": x["s"]})["s"]
            want_mc = mc_solo.step({k: x[k] for k in ("mc0", "mc1")})
            assert mixed.last_metrics.student_rows == 1
            np.testing.assert_allclose(
                np.asarray(got["s"].summary.probs),
                np.asarray(want_s.summary.probs), atol=1e-6)
            for sid in ("mc0", "mc1"):
                np.testing.assert_array_equal(
                    np.asarray(got[sid].summary.probs),
                    np.asarray(want_mc[sid].summary.probs))


def _sig(key, t):
    return jax.random.normal(jax.random.key(key), (t, 1))


class TestMetricsThreading:
    def test_jsonl_sink_carries_student_fields(self, tmp_path):
        """Tick 0: both rows on the student, the noisy one escalates.
        Tick 1 onward: the quiet stream stays a student row, no further
        escalations.  The unc head is crafted, not trained: its weight
        vector points along the noisy chunk's h_T, so the noisy stream
        predicts softplus(|h|) while the quiet (flatline through a
        zero-bias fresh init) predicts exactly softplus(0) — a threshold
        of softplus(0) separates them by construction under strict >."""
        from repro.core import linear

        path = str(tmp_path / "ticks.jsonl")
        sink = JsonlSink(path)
        eng, params, cfg, student = _student_engine(
            max_sessions=2, metrics_sink=sink,
            student_escalate_threshold=float(jax.nn.softplus(0.0)))
        _, states = clf.apply(params, jnp.asarray(_sig(20, 4))[None],
                              distill.det_rows(1), cfg,
                              backend="pallas_seq", return_state=True)
        h = np.asarray(states[-1][0][0])
        student["unc"] = linear.DenseParams(
            jnp.asarray(h[:, None] / np.linalg.norm(h)),
            jnp.zeros((1,), jnp.float32))
        eng.open_session("quiet", mode="student")
        eng.open_session("noisy", mode="student")
        for t in range(2):
            eng.step({"quiet": jnp.zeros((4, 1)),
                      "noisy": _sig(20 + t, 4)})
        sink.close()
        recs = [json.loads(ln) for ln in open(path)]
        assert [r["student_rows"] for r in recs] == [2, 1]
        assert [r["escalations"] for r in recs] == [1, 0]
        assert eng.store.get("noisy").mode == "mc"
        assert eng.store.get("quiet").mode == "student"
        agg = summarize(eng.metrics)
        assert agg["escalations"] == 1
        assert agg["student_rows_mean"] == pytest.approx(1.5)

    def test_fleet_metrics_attribute_per_tenant(self, tmp_path):
        """A student tenant next to a plain MC tenant: the student rows
        and the escalation land on the right tenant's records."""
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        student = distill.init_student(jax.random.key(1), cfg, params)
        path = str(tmp_path / "fleet.jsonl")
        sink = JsonlSink(path)
        fleet = FleetEngine([
            TenantSpec(name="fast", cfg=cfg, params=params, student=student,
                       student_escalate_threshold=0.0),
            TenantSpec(name="plain", cfg=cfg, params=params),
        ], metrics_sink=sink)
        assert len(fleet.groups) == 2            # student policy splits
        fleet.admit("fast", "p", mode="student")
        fleet.admit("plain", "p")
        for t in range(2):
            fleet.step({"fast": {"p": _sig(30 + t, 4)},
                        "plain": {"p": _sig(40 + t, 4)}})
        sink.close()
        store = fleet.group_of("fast").engine.store
        assert store.get("fast/p").mode == "mc"  # threshold 0.0 escalated
        per_tenant = {}
        for ln in open(path):
            r = json.loads(ln)
            if r.get("tenant"):
                per_tenant.setdefault(r["tenant"], []).append(r)
        assert [r["student_rows"] for r in per_tenant["fast"]] == [1, 0]
        assert sum(r["escalations"] for r in per_tenant["fast"]) == 1
        assert all(r["student_rows"] == 0 and r["escalations"] == 0
                   for r in per_tenant["plain"])
        agg = summarize(fleet.metrics)
        assert agg["tenants"]["fast"]["escalations"] == 1
        assert agg["tenants"]["plain"]["escalations"] == 0

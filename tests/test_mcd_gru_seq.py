"""Sequence-fused MCD-GRU kernel vs per-step kernel scan vs jnp oracle.

GRU parity with the LSTM stack (ISSUE 4 tentpole): for the same
``mcd_gru.gate_keys`` streams the sequence kernel draws bit-identical 3-gate
masks to the per-step kernel and the reference, its h trajectory matches for
any T, and the ``cell="gru"`` dispatch keeps all three ``run_stack``
backends bit-identical — including carried state and ragged ``lengths``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autoencoder as ae, cells, classifier as clf, mcd, rnn
from repro.kernels import mcd_gru, mcd_gru_seq, ops, ref

import conformance

SEED, LAYER = 11, 2
BACKENDS = ("reference", "pallas_step", "pallas_seq")


def _layer(b, t, i, h, key=0):
    ks = jax.random.split(jax.random.key(key), 3)
    wx = jax.random.normal(ks[0], (i, 3, h)) * 0.1
    wh = jax.random.normal(ks[1], (h, 3, h)) * 0.1
    bias = jax.random.normal(ks[2], (3, h)) * 0.1
    x_seq = jax.random.normal(jax.random.key(key + 1), (b, t, i))
    rows = jnp.arange(b, dtype=jnp.uint32) + 17
    return x_seq, wx, wh, bias, rows


class TestGruSeqKernel:
    @pytest.mark.parametrize("t", [1, 8, 33])
    @pytest.mark.parametrize("p", [0.0, 0.125, 0.5])
    def test_matches_ref_and_step_kernel(self, t, p):
        b, i, h = 8, 48, 32
        x_seq, wx, wh, bias, rows = _layer(b, t, i, h)
        keys = mcd_gru.gate_keys(SEED, LAYER)
        ys, hT = mcd_gru_seq.mcd_gru_seq(x_seq, wx, wh, bias, rows, keys, p)
        yr, hr = ref.mcd_gru_seq(x_seq, wx, wh, bias, rows, keys, p)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(yr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(hT), np.asarray(hr),
                                   rtol=1e-5, atol=1e-5)
        ys2, (h2,) = ops.fused_gru_layer(wx, wh, bias, x_seq, rows,
                                         SEED, LAYER, p)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(ys2),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(hT), np.asarray(h2),
                                   rtol=1e-5, atol=1e-5)

    def test_mask_streams_bit_identical(self):
        """x ≡ 1 + heavy dropout separates mask patterns: any bit flip vs
        the reference 3-gate streams would swing a gate matmul input by
        ±scale, far above fp tolerance."""
        b, t, i, h = 8, 5, 64, 32
        _, wx, wh, bias, rows = _layer(b, t, i, h)
        x_seq = jnp.ones((b, t, i))
        keys = mcd_gru.gate_keys(SEED, LAYER)
        ys, _ = mcd_gru_seq.mcd_gru_seq(x_seq, wx, wh, bias, rows, keys, 0.5)
        yr, _ = ref.mcd_gru_seq(x_seq, wx, wh, bias, rows, keys, 0.5)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(yr),
                                   rtol=1e-5, atol=1e-5)

    def test_masks_tied_across_time(self):
        """Constant input: step 2 = the step kernel applied to h1 — only
        true when both steps drew the same (tied) masks."""
        b, i, h = 4, 32, 32
        _, wx, wh, bias, rows = _layer(b, 2, i, h)
        x1 = jnp.ones((b, 1, i))
        x2 = jnp.ones((b, 2, i))
        keys = mcd_gru.gate_keys(SEED, LAYER)
        ys1, h1 = mcd_gru_seq.mcd_gru_seq(x1, wx, wh, bias, rows, keys, 0.25)
        ys2, _ = mcd_gru_seq.mcd_gru_seq(x2, wx, wh, bias, rows, keys, 0.25)
        np.testing.assert_allclose(np.asarray(ys1[:, 0]),
                                   np.asarray(ys2[:, 0]),
                                   rtol=1e-6, atol=1e-6)
        h2 = mcd_gru.mcd_gru_step(x2[:, 1], h1, wx, wh, bias, rows, keys,
                                  0.25)
        np.testing.assert_allclose(np.asarray(ys2[:, 1]), np.asarray(h2),
                                   rtol=1e-5, atol=1e-5)

    def test_prime_batch_pads_instead_of_serializing(self):
        """B prime must not degrade to bb=1: the batch pads up to the block
        multiple and outputs slice back — same fallback as the LSTM kernels."""
        b, t, i, h = 13, 3, 8, 8
        x_seq, wx, wh, bias, rows = _layer(b, t, i, h)
        keys = mcd_gru.gate_keys(SEED, LAYER)
        ys, hT = mcd_gru_seq.mcd_gru_seq(x_seq, wx, wh, bias, rows, keys,
                                         0.125, block_b=4)
        yr, hr = ref.mcd_gru_seq(x_seq, wx, wh, bias, rows, keys, 0.125)
        assert ys.shape == (b, t, h) and hT.shape == (b, h)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(yr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(hT), np.asarray(hr),
                                   rtol=1e-5, atol=1e-5)


class TestGruCarriedState:
    """The h0 streaming operand — the GRU's whole carry is h."""

    @pytest.mark.parametrize("p", [0.0, 0.25])
    def test_resume_matches_oracle(self, p):
        b, t, i, h = 6, 7, 16, 16
        x_seq, wx, wh, bias, rows = _layer(b, t, i, h)
        keys = mcd_gru.gate_keys(SEED, LAYER)
        h0 = jax.random.normal(jax.random.key(5), (b, h)) * 0.5
        ys, hT = mcd_gru_seq.mcd_gru_seq(x_seq, wx, wh, bias, rows, keys, p,
                                         h0=h0)
        yr, hr = ref.mcd_gru_seq(x_seq, wx, wh, bias, rows, keys, p, h0=h0)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(yr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(hT), np.asarray(hr),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("splits", [[4, 5], [1] * 9, [2, 1, 6]])
    def test_chunked_equals_unchunked_bit_identical(self, splits):
        """Arbitrary chunk boundaries (incl. length 1) are invisible — the
        lengths-pinned graph family makes the comparison bit-exact, and the
        h carry round-trips losslessly in the activation dtype."""
        b, t, i, h = 6, 9, 16, 16
        x_seq, wx, wh, bias, rows = _layer(b, t, i, h)
        keys = mcd_gru.gate_keys(SEED, LAYER)
        lens = lambda n: jnp.full((b,), n, jnp.int32)

        def step(xc, h0):
            return mcd_gru_seq.mcd_gru_seq(
                xc, wx, wh, bias, rows, keys, 0.125, h0=h0,
                lengths=lens(xc.shape[1]))

        full, hF = step(x_seq, None)
        outs, hT = conformance.chunked_run(step, x_seq, splits)
        np.testing.assert_array_equal(np.asarray(outs), np.asarray(full))
        np.testing.assert_array_equal(np.asarray(hT), np.asarray(hF))

    def test_lengths_freeze_state_per_row(self):
        """Ragged rows keep h at their own length; live prefixes are
        bit-identical to the full-length varlen pass."""
        b, t, i, h = 6, 8, 16, 16
        x_seq, wx, wh, bias, rows = _layer(b, t, i, h)
        keys = mcd_gru.gate_keys(SEED, LAYER)
        lens = jnp.array([8, 1, 3, 5, 2, 7], jnp.int32)
        ys, hT = mcd_gru_seq.mcd_gru_seq(x_seq, wx, wh, bias, rows, keys,
                                         0.125, lengths=lens)
        full, _ = mcd_gru_seq.mcd_gru_seq(
            x_seq, wx, wh, bias, rows, keys, 0.125,
            lengths=jnp.full((b,), t, jnp.int32))
        for r in range(b):
            L = int(lens[r])
            np.testing.assert_array_equal(np.asarray(ys[r, :L]),
                                          np.asarray(full[r, :L]))
            np.testing.assert_array_equal(np.asarray(hT[r]),
                                          np.asarray(ys[r, L - 1]))
        yr, hr = ref.mcd_gru_seq(x_seq, wx, wh, bias, rows, keys, 0.125,
                                 lengths=lens)
        np.testing.assert_array_equal(np.asarray(hT), np.asarray(hr))


class TestGruBf16:
    """bf16 weights/activations; gate math accumulates in fp32."""

    @pytest.mark.parametrize("p", [0.0, 0.125])
    def test_bf16_matches_bf16_oracle(self, p):
        b, t, i, h = 6, 6, 16, 16
        x_seq, wx, wh, bias, rows = _layer(b, t, i, h)
        to = lambda a: a.astype(jnp.bfloat16)
        keys = mcd_gru.gate_keys(SEED, LAYER)
        ys, hT = mcd_gru_seq.mcd_gru_seq(to(x_seq), to(wx), to(wh), to(bias),
                                         rows, keys, p)
        assert ys.dtype == jnp.bfloat16 and hT.dtype == jnp.bfloat16
        yr, hr = ref.mcd_gru_seq(to(x_seq), to(wx), to(wh), to(bias),
                                 rows, keys, p)
        np.testing.assert_allclose(np.asarray(ys, jnp.float32),
                                   np.asarray(yr, jnp.float32),
                                   rtol=0.05, atol=0.05)
        np.testing.assert_allclose(np.asarray(hT, jnp.float32),
                                   np.asarray(hr, jnp.float32),
                                   rtol=0.05, atol=0.05)

    def test_bf16_carried_state_resume_bit_identical(self):
        """Chunk boundaries stay invisible in bf16: h both carries in VMEM
        scratch and round-trips across chunks in bf16, so the per-step
        rounding is identical either way."""
        b, t, i, h = 6, 8, 16, 16
        x_seq, wx, wh, bias, rows = _layer(b, t, i, h)
        to = lambda a: a.astype(jnp.bfloat16)
        xb, wxb, whb, bb_ = to(x_seq), to(wx), to(wh), to(bias)
        keys = mcd_gru.gate_keys(SEED, LAYER)
        lens = lambda n: jnp.full((b,), n, jnp.int32)
        full, hF = mcd_gru_seq.mcd_gru_seq(xb, wxb, whb, bb_, rows, keys,
                                           0.125, lengths=lens(t))

        def step(xc, h0):
            ys, hT = mcd_gru_seq.mcd_gru_seq(
                xc, wxb, whb, bb_, rows, keys, 0.125, h0=h0,
                lengths=lens(xc.shape[1]))
            assert hT.dtype == jnp.bfloat16
            return ys, hT

        outs, hT = conformance.chunked_run(step, xb, [3, 1, 4])
        np.testing.assert_array_equal(np.asarray(outs, jnp.float32),
                                      np.asarray(full, jnp.float32))
        np.testing.assert_array_equal(np.asarray(hT, jnp.float32),
                                      np.asarray(hF, jnp.float32))


class TestGruRunStackBackends:
    """The cell="gru" dispatch — ISSUE 4 acceptance: reference vs
    pallas_step vs pallas_seq, bit-identical."""

    def _stack(self, hiddens=(16, 16, 16), placement="YNY"):
        cfg = mcd.MCDConfig(p=0.125, placement=placement, seed=5)
        params = rnn.init_stack(jax.random.key(0), 4, hiddens, cell="gru")
        return cfg, params

    @pytest.mark.parametrize("placement", ["YN", "NNN", "YYY"])
    @pytest.mark.parametrize("backend", ["pallas_step", "pallas_seq"])
    def test_stack_matches_reference(self, placement, backend):
        cfg, params = self._stack(placement=placement)
        hiddens = (16, 16, 16)
        x = jax.random.normal(jax.random.key(1), (6, 9, 4))
        rows = jnp.arange(6, dtype=jnp.uint32)
        masks = rnn.sample_stack_masks(cfg, rows, 4, hiddens, cell="gru")
        out0, (h0,) = rnn.run_stack(params, x, masks, cfg.p, cell="gru")
        out1, (h1,) = rnn.run_stack(params, x, masks, cfg.p,
                                    backend=backend, rows=rows,
                                    seed=cfg.seed, cell="gru")
        np.testing.assert_allclose(np.asarray(out0), np.asarray(out1),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h0), np.asarray(h1),
                                   rtol=1e-4, atol=1e-4)

    def test_ragged_states_bit_identical_across_backends(self):
        """Acceptance bullet: same ragged batch (carried state + lengths)
        through all three backends — per-row h carries bit-identical."""
        cfg, params = self._stack(hiddens=(8, 8), placement="YN")
        hiddens = (8, 8)
        B, T = 4, 9
        x = jax.random.normal(jax.random.key(2), (B, T, 4))
        rows = jnp.arange(B, dtype=jnp.uint32)
        lens = jnp.array([9, 1, 4, 6], jnp.int32)
        h0 = [(jax.random.normal(jax.random.key(7 + i), (B, hid)) * 0.3,)
              for i, hid in enumerate(hiddens)]
        got = {}
        for backend in BACKENDS:
            masks = (rnn.sample_stack_masks(cfg, rows, 4, hiddens, cell="gru")
                     if backend == "reference"
                     else rnn.stack_mask_plan(cfg, len(hiddens)))
            out, states = rnn.run_stack(params, x, masks, cfg.p,
                                        backend=backend, rows=rows,
                                        seed=cfg.seed, lengths=lens,
                                        initial_state=h0,
                                        return_all_states=True, cell="gru")
            got[backend] = (out, states)
        for backend in ("pallas_step", "pallas_seq"):
            np.testing.assert_array_equal(np.asarray(got["reference"][0]),
                                          np.asarray(got[backend][0]))
            for (h1,), (h2,) in zip(got["reference"][1], got[backend][1]):
                np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))

    def test_return_all_states_is_h_only(self):
        cfg, params = self._stack(hiddens=(16, 8), placement="YY")
        x = jax.random.normal(jax.random.key(3), (3, 5, 4))
        rows = jnp.arange(3, dtype=jnp.uint32)
        _, st = rnn.run_stack(params, x, rnn.stack_mask_plan(cfg, 2), cfg.p,
                              backend="pallas_seq", rows=rows, seed=cfg.seed,
                              return_all_states=True, cell="gru")
        assert [len(layer) for layer in st] == [1, 1]
        for (h,), hid in zip(st, (16, 8)):
            assert h.shape == (3, hid) and h.dtype == x.dtype

    def test_bad_cell_rejected(self):
        params = rnn.init_stack(jax.random.key(0), 4, (8,))
        x = jnp.zeros((2, 3, 4))
        with pytest.raises(ValueError, match="cell"):
            rnn.run_stack(params, x, [(None, None)], 0.0, cell="elman")
        with pytest.raises(ValueError, match="cell"):
            rnn.init_stack(jax.random.key(0), 4, (8,), cell="elman")

    def test_classifier_gru_end_to_end(self):
        cfg = clf.ClassifierConfig(
            hidden=16, num_layers=3, cell="gru",
            mcd=mcd.MCDConfig(p=0.125, placement="YN", seed=5))
        params = clf.init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (6, 12, 1))
        rows = jnp.arange(6, dtype=jnp.uint32)
        want = clf.apply(params, x, rows, cfg)
        for be in ("pallas_step", "pallas_seq"):
            got = clf.apply(params, x, rows, cfg, backend=be)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4, atol=1e-4)

    def test_autoencoder_gru_end_to_end(self):
        cfg = ae.AutoencoderConfig(
            hidden=16, num_layers=2, cell="gru",
            mcd=mcd.MCDConfig(p=0.125, placement="YNYN", seed=7))
        params = ae.init(jax.random.key(2), cfg)
        x = jax.random.normal(jax.random.key(3), (5, 10, 1))
        rows = jnp.arange(5, dtype=jnp.uint32)
        m0, lv0 = ae.apply(params, x, rows, cfg)
        for be in ("pallas_step", "pallas_seq"):
            m, lv = ae.apply(params, x, rows, cfg, backend=be)
            np.testing.assert_allclose(np.asarray(m), np.asarray(m0),
                                       rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(np.asarray(lv), np.asarray(lv0),
                                       rtol=1e-4, atol=1e-4)


def test_gru_gate_stacked_roundtrip():
    params = cells.init_gru(jax.random.key(0), 5, 8)
    wx3, wh3, b = cells.gate_stacked(params)
    assert wx3.shape == (5, 3, 8) and wh3.shape == (8, 3, 8)
    np.testing.assert_array_equal(np.asarray(jnp.moveaxis(wx3, 1, 0)),
                                  np.asarray(params.wx))
    np.testing.assert_array_equal(np.asarray(b), np.asarray(params.b))

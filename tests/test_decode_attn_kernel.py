"""Flash-decode Pallas kernel vs oracle: shape/dtype/position sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import decode_attn, ref


@pytest.mark.parametrize("B,S,KV,rep,hd,bs", [
    (2, 64, 2, 2, 16, 16),
    (1, 128, 4, 1, 32, 64),
    (3, 96, 2, 4, 16, 32),
    (2, 64, 1, 8, 16, 64),        # MQA
])
@pytest.mark.parametrize("pos_frac", [0.0, 0.5, 1.0])
def test_matches_ref(B, S, KV, rep, hd, bs, pos_frac):
    H = KV * rep
    pos = int(pos_frac * (S - 1))
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    out = decode_attn.decode_attention(q, k, v, pos, block_s=bs)
    exp = ref.decode_attention(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_block_size_invariance():
    """Online-softmax law: result independent of seq tiling."""
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (2, 4, 16))
    k = jax.random.normal(ks[1], (2, 96, 2, 16))
    v = jax.random.normal(ks[2], (2, 96, 2, 16))
    a = decode_attn.decode_attention(q, k, v, 77, block_s=96)
    b = decode_attn.decode_attention(q, k, v, 77, block_s=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


def test_bf16():
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (2, 8, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (2, 64, 4, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 64, 4, 32), jnp.bfloat16)
    out = decode_attn.decode_attention(q, k, v, 40, block_s=16)
    exp = ref.decode_attention(q, k, v, 40)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=3e-2, atol=3e-2)

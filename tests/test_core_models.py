"""Paper's AE/classifier + uncertainty decomposition behaviour tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (autoencoder as ae, bayesian, classifier as clf, mcd,
                        uncertainty as unc)


def _ae_cfg(**kw):
    return ae.AutoencoderConfig(
        input_dim=1, hidden=16, num_layers=2,
        mcd=mcd.MCDConfig(p=0.125, placement="YNYN", n_samples=5, seed=1), **kw)


class TestAutoencoder:
    def test_shapes_and_finite(self):
        cfg = _ae_cfg()
        params = ae.init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (3, 20, 1))
        rows = jnp.arange(3, dtype=jnp.uint32)
        mean, log_var = ae.apply(params, x, rows, cfg)
        assert mean.shape == x.shape and log_var.shape == x.shape
        assert np.isfinite(np.asarray(mean)).all()
        nll = ae.gaussian_nll(mean, log_var, x)
        assert nll.shape == (3,) and np.isfinite(np.asarray(nll)).all()

    def test_bottleneck_dim(self):
        cfg = _ae_cfg()
        assert cfg.encoder_hiddens == (16, 8)      # H/2 bottleneck (paper)
        assert cfg.decoder_hiddens == (16, 16)

    def test_mc_samples_vary(self):
        """Different MC samples → different reconstructions (epistemic > 0)."""
        cfg = _ae_cfg()
        params = ae.init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (2, 16, 1))
        means, log_vars = bayesian.predict(
            lambda p, x_, r: ae.apply(p, x_, r, cfg), params, x, cfg.mcd)
        s = unc.regression_summary(means, log_vars)
        assert float(s.epistemic.mean()) > 0.0
        np.testing.assert_allclose(np.asarray(s.total),
                                   np.asarray(s.aleatoric + s.epistemic))

    def test_pointwise_zero_epistemic(self):
        cfg = _ae_cfg()
        cfg = ae.AutoencoderConfig(
            input_dim=1, hidden=16, num_layers=2,
            mcd=mcd.MCDConfig(p=0.125, placement="NNNN", n_samples=5))
        params = ae.init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (2, 16, 1))
        means, log_vars = bayesian.predict(
            lambda p, x_, r: ae.apply(p, x_, r, cfg), params, x, cfg.mcd)
        s = unc.regression_summary(means, log_vars)
        assert float(s.epistemic.max()) == 0.0     # S collapses to 1


class TestClassifier:
    def test_logits_and_uncertainty(self):
        cfg = clf.ClassifierConfig(
            mcd=mcd.MCDConfig(p=0.125, placement="YNY", n_samples=6, seed=2))
        params = clf.init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (4, 20, 1))
        logits = bayesian.predict(
            lambda p, x_, r: clf.apply(p, x_, r, cfg), params, x, cfg.mcd)
        assert logits.shape == (6, 4, cfg.num_classes)
        s = unc.classification_summary(logits)
        c = cfg.num_classes
        ent = np.asarray(s.predictive_entropy)
        assert (ent >= -1e-6).all() and (ent <= np.log(c) + 1e-6).all()
        assert (np.asarray(s.mutual_information) >= -1e-5).all()
        np.testing.assert_allclose(np.asarray(s.probs.sum(-1)), 1.0,
                                   rtol=1e-5)


class TestUncertaintyMetrics:
    def test_ece_bounds(self):
        probs = jax.nn.softmax(jax.random.normal(jax.random.key(0), (100, 4)))
        labels = jnp.zeros((100,), jnp.int32)
        e = float(unc.expected_calibration_error(probs, labels))
        assert 0.0 <= e <= 1.0

    def test_accuracy(self):
        probs = jnp.eye(4)
        labels = jnp.arange(4)
        assert float(unc.accuracy(probs, labels)) == 1.0

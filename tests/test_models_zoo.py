"""Per-arch smoke tests (reduced configs): forward/train-step shapes, no
NaNs, and prefill→decode consistency — one test class per assigned arch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core import prng
from repro.models import backbone
from repro.models.layers import Ctx


def _inputs(cfg, B, S, key=2):
    kw = {}
    if cfg.family == "audio":
        kw["frames"] = jax.random.normal(
            jax.random.key(key), (B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        kw["patches"] = jax.random.normal(
            jax.random.key(key), (B, cfg.num_patches, cfg.d_model))
    return kw


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_no_nan(self, arch):
        cfg = get_config(arch, reduced=True)
        params = backbone.init_params(jax.random.key(0), cfg, jnp.float32)
        B, S = 2, 16
        toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
        ctx = Ctx(rows=jnp.arange(B, dtype=jnp.uint32), seed=3, cfg=cfg.mcd)
        logits, aux, _ = backbone.forward(params, cfg, toks, ctx,
                                          **_inputs(cfg, B, S))
        off = cfg.num_patches if cfg.family == "vlm" else 0
        assert logits.shape == (B, S + off, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()
        assert np.isfinite(float(aux))

    def test_train_step_no_nan(self, arch):
        cfg = get_config(arch, reduced=True)
        params = backbone.init_params(jax.random.key(0), cfg, jnp.float32)
        B, S = 2, 16
        toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
        targets = jnp.roll(toks, -1, axis=1)

        def loss(p):
            ctx = Ctx(rows=jnp.arange(B, dtype=jnp.uint32),
                      seed=prng.fold_ids(cfg.mcd.seed, 0), cfg=cfg.mcd)
            l, _ = backbone.loss_fn(p, cfg, toks, targets, ctx,
                                    **_inputs(cfg, B, S))
            return l

        val, grads = jax.value_and_grad(loss)(params)
        assert np.isfinite(float(val))
        gn = sum(float(jnp.sum(jnp.square(g)))
                 for g in jax.tree_util.tree_leaves(grads))
        assert np.isfinite(gn) and gn > 0.0

    def test_prefill_decode_consistency(self, arch):
        cfg = get_config(arch, reduced=True)
        params = backbone.init_params(jax.random.key(0), cfg, jnp.float32)
        B, S = 2, 10
        toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                                  cfg.vocab_size)
        ctx = Ctx(rows=jnp.arange(B, dtype=jnp.uint32), seed=3, cfg=cfg.mcd)
        kw = _inputs(cfg, B, S)
        off = cfg.num_patches if cfg.family == "vlm" else 0
        ref, _, _ = backbone.forward(params, cfg, toks, ctx, **kw)
        lg, state = backbone.prefill(params, cfg, toks[:, :S], ctx,
                                     off + S + 4, **kw)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(ref[:, off + S - 1]),
                                   rtol=3e-4, atol=3e-4)
        lg1, _ = backbone.decode_step(params, cfg, toks[:, S:S + 1], state, ctx)
        np.testing.assert_allclose(np.asarray(lg1[:, 0]),
                                   np.asarray(ref[:, off + S]),
                                   rtol=3e-4, atol=3e-4)


def test_registry_covers_assignment():
    assert len(ARCH_IDS) == 6
    families = {get_config(a).family for a in ARCH_IDS}
    assert families == {"dense", "moe", "hybrid", "ssm"}


def test_full_configs_match_assignment():
    cfg = get_config("llama3-8b")
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == (32, 4096, 32, 8, 14336, 128256)
    j = get_config("jamba-1.5-large-398b")
    assert j.num_layers == 72 and j.moe.num_experts == 16 and j.moe.top_k == 2
    m = get_config("mamba2-370m")
    assert m.num_layers == 48 and m.ssm.d_state == 128 and m.sub_quadratic
    d = get_config("deepseek-v2-lite-16b")
    assert d.mla.kv_lora_rank == 512 and d.moe.top_k == 6

"""Correctness of the §Perf optimization variants: every speed knob must be
semantics-preserving (grouped MoE dispatch, int8 KV cache, attention tiling).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import backbone, layers, moe
from repro.models.config import MoEConfig
from repro.models.layers import Ctx


class TestGroupedMoE:
    def test_grouped_equals_ungrouped_when_no_drops(self):
        """Group-local dispatch == global dispatch when capacity is ample."""
        cfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                        capacity_factor=16.0)
        p = moe.init_moe(jax.random.key(0), 64, cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (4, 16, 64))
        y0, aux0 = moe.moe_forward(p, x, cfg, None, 0.0)
        with moe.moe_sharding(groups=4):
            y4, aux4 = moe.moe_forward(p, x, cfg, None, 0.0)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y4),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(float(aux0), float(aux4), rtol=1e-5)

    def test_groups_fall_back_when_not_divisible(self):
        cfg = MoEConfig(num_experts=4, top_k=1, d_ff_expert=16,
                        capacity_factor=16.0)
        p = moe.init_moe(jax.random.key(0), 32, cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (3, 5, 32))  # T=15, G=4 ∤
        with moe.moe_sharding(groups=4):
            y, _ = moe.moe_forward(p, x, cfg, None, 0.0)
        assert y.shape == x.shape


class TestInt8KVCache:
    def test_quantized_decode_close_to_bf16(self):
        cfg = get_config("llama3-8b", reduced=True)
        params = backbone.init_params(jax.random.key(0), cfg, jnp.float32)
        B, S = 2, 10
        toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                                  cfg.vocab_size)
        ctx = Ctx(rows=jnp.arange(B, dtype=jnp.uint32), seed=3, cfg=cfg.mcd)
        # reference: exact decode after prefill
        _, state = backbone.prefill(params, cfg, toks[:, :S], ctx, S + 4)
        lg_ref, _ = backbone.decode_step(params, cfg, toks[:, S:S + 1],
                                         state, ctx)
        # quantized cache: re-run the decode steps from scratch (prefill not
        # quantized; feed the same tokens step by step)
        qstate = backbone.init_decode_state(cfg, B, S + 4, jnp.float32,
                                            kv_quant=True)
        lg_q = None
        for t in range(S + 1):
            lg_q, qstate = backbone.decode_step(params, cfg, toks[:, t:t + 1],
                                                qstate, ctx)
        # int8 quantization noise is bounded; argmax token agreement is the
        # serving-level contract
        probs_ref = jax.nn.softmax(lg_ref[:, 0].astype(jnp.float32))
        probs_q = jax.nn.softmax(lg_q[:, 0].astype(jnp.float32))
        tv = 0.5 * float(jnp.abs(probs_ref - probs_q).sum(-1).max())
        assert tv < 0.15, f"total variation {tv}"

    def test_step_by_step_equals_prefill_bf16(self):
        """Sanity: bf16 step-by-step decode == prefill+decode (exact path)."""
        cfg = get_config("qwen3-1.7b", reduced=True)
        params = backbone.init_params(jax.random.key(0), cfg, jnp.float32)
        B, S = 2, 8
        toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                                  cfg.vocab_size)
        ctx = Ctx(rows=jnp.arange(B, dtype=jnp.uint32), seed=3, cfg=cfg.mcd)
        _, state = backbone.prefill(params, cfg, toks[:, :S], ctx, S + 4)
        lg_ref, _ = backbone.decode_step(params, cfg, toks[:, S:S + 1],
                                         state, ctx)
        st = backbone.init_decode_state(cfg, B, S + 4, jnp.float32)
        lg = None
        for t in range(S + 1):
            lg, st = backbone.decode_step(params, cfg, toks[:, t:t + 1], st,
                                          ctx)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref),
                                   rtol=2e-4, atol=2e-4)


class TestAttentionTiling:
    def test_block_size_invariance(self):
        """Attention result independent of tile decomposition (flash law)."""
        q = jax.random.normal(jax.random.key(0), (2, 64, 4, 16))
        k = jax.random.normal(jax.random.key(1), (2, 64, 2, 16))
        v = jax.random.normal(jax.random.key(2), (2, 64, 2, 16))
        a = layers.blockwise_attention(q, k, v, causal=True, q_block=64,
                                       kv_block=64)
        with layers.attention_override(q_block=16, kv_block=8):
            b = layers.blockwise_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)

"""Counter-PRNG: statistical quality + the invariants the framework relies on."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import prng


class TestDeterminism:
    def test_same_key_same_bits(self):
        k = prng.fold_ids(1, 2, 3)
        a = prng.random_bits(k, (64, 64))
        b = prng.random_bits(k, (64, 64))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_different_streams_differ(self):
        a = prng.random_bits(prng.fold_ids(0, 1), (128,))
        b = prng.random_bits(prng.fold_ids(0, 2), (128,))
        assert np.mean(np.asarray(a) == np.asarray(b)) < 0.05

    def test_tile_consistency(self):
        """Block-tiled generation equals the global stream (sharding-safety)."""
        k = prng.fold_ids(7)
        full = prng.random_bits(k, (64, 96))
        tile = prng.random_bits_at(k, 16, 32, (8, 8), row_stride=96)
        np.testing.assert_array_equal(np.asarray(full[16:24, 32:40]),
                                      np.asarray(tile))


class TestStatistics:
    def test_uniform_moments(self):
        u = np.asarray(prng.uniform(prng.fold_ids(3), (100_000,)))
        assert abs(u.mean() - 0.5) < 0.01
        assert abs(u.var() - 1.0 / 12) < 0.005
        assert u.min() >= 0.0 and u.max() < 1.0

    @pytest.mark.parametrize("p", [0.0, 0.125, 0.3, 0.5, 0.9])
    def test_bernoulli_rate(self, p):
        z = np.asarray(prng.bernoulli(prng.fold_ids(11), p, (200_000,)))
        assert abs(z.mean() - (1.0 - p)) < 0.01

    def test_bit_balance(self):
        bits = np.asarray(prng.random_bits(prng.fold_ids(5), (4096,)))
        ones = sum(int(b) for x in bits for b in np.binary_repr(x, 32)) \
            / (4096 * 32)
        assert abs(ones - 0.5) < 0.01

    def test_row_decorrelation(self):
        u = np.asarray(prng.uniform(prng.fold_ids(9), (512, 512)))
        c = np.corrcoef(u[:-1].ravel(), u[1:].ravel())[0, 1]
        assert abs(c) < 0.02


@given(seed=st.integers(0, 2**31 - 1), ids=st.lists(
    st.integers(0, 2**31 - 1), min_size=0, max_size=4))
@settings(max_examples=25, deadline=None)
def test_fold_ids_deterministic(seed, ids):
    a = prng.fold_ids(seed, *ids)
    b = prng.fold_ids(seed, *ids)
    assert int(a) == int(b)


@given(p=st.floats(0.0, 0.99))
@settings(max_examples=20, deadline=None)
def test_threshold_monotone(p):
    """Keep-threshold grows with p; boundary values exact."""
    t = int(prng.bernoulli_keep_threshold(p))
    assert 0 <= t <= 0xFFFFFFFF
    assert int(prng.bernoulli_keep_threshold(0.0)) == 0

"""Co-design framework: resource/latency models + optimization modes."""

import dataclasses

import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.dse import fpga_model as fm
from repro.dse import search, tpu_model
from repro.models.config import SHAPES


AE = fm.RNNArch(hidden=16, num_layers=2, placement="YNYN",
                kind="autoencoder", output_dim=1)
CLF = fm.RNNArch(hidden=8, num_layers=3, placement="YNY", kind="classifier")


class TestFpgaModels:
    def test_latency_matches_paper_estimates(self):
        """§V-C: 42.25 ms (AE) and 25.77 ms (classifier) at batch 50, S=30."""
        lat_ae = fm.latency_s(AE, fm.HwConfig(16, 5, 16), batch=50,
                              n_samples=30) * 1e3
        lat_clf = fm.latency_s(CLF, fm.HwConfig(12, 1, 1), batch=50,
                               n_samples=30) * 1e3
        assert abs(lat_ae - 42.25) / 42.25 < 0.03
        assert abs(lat_clf - 25.77) / 25.77 < 0.03

    def test_dsp_formula_structure(self):
        """Higher reuse → fewer DSPs (the paper's parallelism trade-off)."""
        lo = fm.dsp_usage(CLF, fm.HwConfig(1, 1, 1))
        hi = fm.dsp_usage(CLF, fm.HwConfig(16, 16, 16))
        assert hi < lo
        assert fm.dsp_usage(CLF, fm.HwConfig(12, 1, 1)) == pytest.approx(
            941.3, abs=0.5)   # paper's estimate 915; see bench notes

    @given(rx=st.integers(1, 32), rh=st.integers(1, 32))
    @settings(max_examples=20, deadline=None)
    def test_latency_monotone_in_reuse(self, rx, rh):
        base = fm.latency_s(CLF, fm.HwConfig(rx, rh, 1))
        worse = fm.latency_s(CLF, fm.HwConfig(rx + 1, rh, 1))
        assert worse >= base

    def test_best_reuse_fits(self):
        hw = fm.best_reuse_factors(CLF)
        assert hw is not None and fm.fits(CLF, hw)


class TestSearch:
    def _table(self):
        return [
            search.Candidate(
                arch=fm.RNNArch(8, 1, "N"), n_samples=1,
                metrics={"accuracy": 0.90, "ap": 0.62, "ar": 0.66,
                         "entropy": 0.15}),
            search.Candidate(
                arch=fm.RNNArch(8, 3, "YNY"),
                metrics={"accuracy": 0.92, "ap": 0.69, "ar": 0.64,
                         "entropy": 0.30}),
            search.Candidate(
                arch=fm.RNNArch(8, 3, "YNN"),
                metrics={"accuracy": 0.89, "ap": 0.59, "ar": 0.64,
                         "entropy": 0.60}),
        ]

    def test_modes_pick_per_priority(self):
        table = self._table()
        assert search.optimize(table, "Opt-Accuracy").arch.placement == "YNY"
        assert search.optimize(table, "Opt-Entropy").arch.placement == "YNN"
        lat = search.optimize(table, "Opt-Latency")
        assert lat.arch.num_layers == 1     # paper: latency trades depth away

    def test_requirements_filter(self):
        got = search.optimize(self._table(), "Opt-Latency",
                              requirements={"accuracy": 0.91})
        assert got.arch.placement == "YNY"

    def test_infeasible_returns_none(self):
        huge = [search.Candidate(arch=fm.RNNArch(2048, 3, "Y"), metrics={})]
        assert search.optimize(huge, "Opt-Latency") is None

    def test_pareto_front_nonempty(self):
        front = search.pareto_front(self._table(), "entropy", "accuracy")
        assert front


class TestGruRow:
    """§III-A in the DSE: the 3-gate cell as a co-design knob (PR 4's
    open item — the models price GRU at 3/4 of the LSTM datapath)."""

    def test_dsp_recurrent_terms_scale_three_quarters(self):
        gru = dataclasses.replace(CLF, cell="gru")
        hw = fm.HwConfig(1, 1, 1)
        head = CLF.layer_dims()[-1][1] * CLF.output_dim / hw.r_d
        lstm_rec = fm.dsp_usage(CLF, hw) - head
        gru_rec = fm.dsp_usage(gru, hw) - head
        assert gru_rec == pytest.approx(lstm_rec * 3.0 / 4.0)

    def test_lstm_formula_unchanged(self):
        """The published instance (G=4) must still match the paper pin."""
        assert CLF.cell == "lstm" and CLF.gates == 4
        assert fm.dsp_usage(CLF, fm.HwConfig(12, 1, 1)) == pytest.approx(
            941.3, abs=0.5)

    def test_gru_fits_lower_reuse_hence_latency_no_worse(self):
        """Fewer DSPs → smaller feasible reuse factors → lower (or equal)
        II — exactly the trade the cheaper cell buys."""
        gru = dataclasses.replace(CLF, cell="gru")
        hw_l = fm.best_reuse_factors(CLF)
        hw_g = fm.best_reuse_factors(gru)
        assert fm.latency_s(gru, hw_g, batch=50, n_samples=30) <= \
            fm.latency_s(CLF, hw_l, batch=50, n_samples=30)

    def test_bad_cell_rejected(self):
        with pytest.raises(ValueError, match="cell"):
            _ = dataclasses.replace(CLF, cell="rnn").gates

    def test_candidate_cell_field_rewrites_arch(self):
        cand = search.Candidate(arch=CLF, metrics={}, cell="gru")
        assert cand.arch.cell == "gru" and cand.cell == "gru"
        # default: inherit the arch's cell
        assert search.Candidate(arch=CLF, metrics={}).cell == "lstm"

    def test_optimize_trades_cell_against_accuracy(self):
        # H=16: big enough that the DSP budget binds, so the 3-gate cell
        # buys strictly smaller reuse factors (at H=8 both cells already
        # reach II=2 and the trade is moot).
        table = [
            search.Candidate(arch=fm.RNNArch(16, 3, "YNY"),
                             metrics={"accuracy": 0.92}),
            search.Candidate(arch=fm.RNNArch(16, 3, "YNY"), cell="gru",
                             metrics={"accuracy": 0.90}),
        ]
        fast = search.optimize(table, "Opt-Latency")
        assert fast.cell == "gru"            # cheaper datapath wins latency
        acc = search.optimize(table, "Opt-Latency",
                              requirements={"accuracy": 0.91})
        assert acc.cell == "lstm"            # until accuracy floors bind

    def test_tpu_rnn_roofline_counts_gates(self):
        gru = dataclasses.replace(CLF, cell="gru")
        r_l = tpu_model.rnn_step_model(CLF, batch=50, n_samples=30)
        r_g = tpu_model.rnn_step_model(gru, batch=50, n_samples=30)
        assert 0 < r_g["flops"] < r_l["flops"]
        assert 0 < r_g["bytes"] < r_l["bytes"]
        assert r_g["t_step"] <= r_l["t_step"]

    def test_tpu_rnn_model_ae_flops_not_double_counted(self):
        """Regression: AE layer_dims() already spans encoder + decoder;
        multiplying T by 2 on top priced AE work ~2× (the paper's ×2 is
        latency serialization, not extra flops)."""
        r = tpu_model.rnn_step_model(AE)
        g = AE.gates
        per_step = sum(2.0 * g * (i * h + h * h) + 12.0 * h
                       for i, h in AE.layer_dims())
        head = 2.0 * AE.layer_dims()[-1][1] * AE.output_dim * AE.timesteps
        assert r["flops"] == pytest.approx(AE.timesteps * per_step + head)

    def test_tpu_rnn_model_data_sharding_scales_rows(self):
        r1 = tpu_model.rnn_step_model(CLF, batch=64, n_samples=8, data=1)
        r8 = tpu_model.rnn_step_model(CLF, batch=64, n_samples=8, data=8)
        assert r8["flops"] == pytest.approx(r1["flops"] / 8, rel=0.05)

    def test_tpu_latency_model_pluggable_into_optimize(self):
        table = [
            search.Candidate(arch=fm.RNNArch(8, 3, "YNY"),
                             metrics={"accuracy": 0.92}),
            search.Candidate(arch=fm.RNNArch(8, 3, "YNY"), cell="gru",
                             metrics={"accuracy": 0.90}),
        ]
        got = search.optimize(table, "Opt-Latency",
                              latency_model=tpu_model.rnn_latency_s,
                              hw_model=None)
        assert got is not None and got.latency_s > 0
        assert got.cell == "gru" and got.hw is None

    def test_tpu_flow_prices_archs_the_fpga_gate_rejects(self):
        """An H=256 stack fits no ZC706 reuse config (the default gate
        returns None and optimize drops it) but is a perfectly good TPU
        candidate — hw_model=None is the documented TPU flow."""
        big = [search.Candidate(arch=fm.RNNArch(256, 3, "YNY"),
                                metrics={"accuracy": 0.95})]
        assert search.optimize(big, "Opt-Latency") is None   # FPGA gate
        got = search.optimize(big, "Opt-Latency",
                              latency_model=tpu_model.rnn_latency_s,
                              hw_model=None)
        assert got is not None and 0 < got.latency_s < 1.0
        # no-gate without a latency model is a config error, not a deep
        # AttributeError inside the FPGA formula
        with pytest.raises(ValueError, match="latency_model"):
            search.optimize(big, "Opt-Latency", hw_model=None)


class TestWeightBits:
    """The quantized serving path's bit-width in the resource models."""

    def test_default_16_bit_keeps_calibration(self):
        """weight_bits=16 is the paper's fixed-point width: DSP_PER_MAC is
        1.0 there, so the §V-C-calibrated numbers are unchanged."""
        assert CLF.weight_bits == 16
        assert dataclasses.replace(CLF, weight_bits=16).dsp_per_mac == 1.0
        assert fm.dsp_usage(CLF, fm.HwConfig(12, 1, 1)) == pytest.approx(
            fm.dsp_usage(dataclasses.replace(CLF, weight_bits=16),
                         fm.HwConfig(12, 1, 1)))

    def test_dsp_monotone_in_bits(self):
        hw = fm.HwConfig(4, 4, 4)
        costs = [fm.dsp_usage(dataclasses.replace(CLF, weight_bits=b), hw)
                 for b in (32, 16, 8, 4)]
        assert costs == sorted(costs, reverse=True)

    def test_unknown_width_rejected(self):
        with pytest.raises(ValueError, match="weight_bits"):
            _ = dataclasses.replace(CLF, weight_bits=12).dsp_per_mac

    def test_narrow_macs_scale_the_feasible_hidden_width(self):
        """The co-design payoff: H=48 at 16-bit overflows the ZC706 DSP
        budget at every reuse factor; int8/int4 MACs fit it — narrower
        MACs buy resident width, the same lever quantize.py pulls in
        VMEM.  (The head term never scales — serving keeps the fp32 head
        — so width eventually saturates regardless of bits: H=64 is out
        at every precision.)"""
        wide = fm.RNNArch(hidden=48, num_layers=3, placement="YNY",
                          kind="classifier")
        assert fm.best_reuse_factors(wide) is None
        for bits in (8, 4):
            hw = fm.best_reuse_factors(
                dataclasses.replace(wide, weight_bits=bits))
            assert hw is not None and fm.fits(
                dataclasses.replace(wide, weight_bits=bits), hw)
        assert fm.best_reuse_factors(fm.RNNArch(
            hidden=64, num_layers=3, placement="YNY", kind="classifier",
            weight_bits=4)) is None

    def test_roofline_bytes_shrink_with_bits(self):
        full = tpu_model.rnn_step_model(CLF)["bytes"]
        w8 = tpu_model.rnn_step_model(
            dataclasses.replace(CLF, weight_bits=8))["bytes"]
        w4 = tpu_model.rnn_step_model(
            dataclasses.replace(CLF, weight_bits=4))["bytes"]
        assert full > w8 > w4


class TestTpuModel:
    def test_memory_decreases_with_chips(self):
        cfg = get_config("llama3-8b")
        cell = SHAPES["train_4k"]
        m256 = tpu_model.memory_model(cfg, cell,
                                      tpu_model.TpuHwConfig(data=16, model=16))
        m512 = tpu_model.memory_model(
            cfg, cell, tpu_model.TpuHwConfig(data=16, model=16, pod=2))
        assert m512 < m256

    def test_search_feasible_configs_exist(self):
        cfg = get_config("qwen3-1.7b")
        out = tpu_model.search_hw(cfg, SHAPES["train_4k"])
        assert out and out[0]["feasible"]
        assert out[0]["t_step"] <= out[-1]["t_step"] or not out[-1]["feasible"]

    def test_jamba_train_needs_more_than_one_pod(self):
        """398B AdamW does not fit 256 × 16 GB — the multi-pod motivation."""
        cfg = get_config("jamba-1.5-large-398b")
        out = tpu_model.search_hw(cfg, SHAPES["train_4k"], chips=256)
        assert not any(r["feasible"] for r in out)
        out2 = tpu_model.search_hw(cfg, SHAPES["train_4k"], chips=256, pod=2)
        assert any(r["feasible"] for r in out2)

    def test_decode_is_memory_or_collective_bound(self):
        cfg = get_config("llama3-8b")
        r = tpu_model.step_model(cfg, SHAPES["decode_32k"],
                                 tpu_model.TpuHwConfig())
        assert max(r["t_memory"], r["t_collective"]) > r["t_compute"]

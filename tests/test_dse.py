"""Co-design framework: resource/latency models + optimization modes."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.dse import fpga_model as fm
from repro.dse import search, tpu_model
from repro.models.config import SHAPES


AE = fm.RNNArch(hidden=16, num_layers=2, placement="YNYN",
                kind="autoencoder", output_dim=1)
CLF = fm.RNNArch(hidden=8, num_layers=3, placement="YNY", kind="classifier")


class TestFpgaModels:
    def test_latency_matches_paper_estimates(self):
        """§V-C: 42.25 ms (AE) and 25.77 ms (classifier) at batch 50, S=30."""
        lat_ae = fm.latency_s(AE, fm.HwConfig(16, 5, 16), batch=50,
                              n_samples=30) * 1e3
        lat_clf = fm.latency_s(CLF, fm.HwConfig(12, 1, 1), batch=50,
                               n_samples=30) * 1e3
        assert abs(lat_ae - 42.25) / 42.25 < 0.03
        assert abs(lat_clf - 25.77) / 25.77 < 0.03

    def test_dsp_formula_structure(self):
        """Higher reuse → fewer DSPs (the paper's parallelism trade-off)."""
        lo = fm.dsp_usage(CLF, fm.HwConfig(1, 1, 1))
        hi = fm.dsp_usage(CLF, fm.HwConfig(16, 16, 16))
        assert hi < lo
        assert fm.dsp_usage(CLF, fm.HwConfig(12, 1, 1)) == pytest.approx(
            941.3, abs=0.5)   # paper's estimate 915; see bench notes

    @given(rx=st.integers(1, 32), rh=st.integers(1, 32))
    @settings(max_examples=20, deadline=None)
    def test_latency_monotone_in_reuse(self, rx, rh):
        base = fm.latency_s(CLF, fm.HwConfig(rx, rh, 1))
        worse = fm.latency_s(CLF, fm.HwConfig(rx + 1, rh, 1))
        assert worse >= base

    def test_best_reuse_fits(self):
        hw = fm.best_reuse_factors(CLF)
        assert hw is not None and fm.fits(CLF, hw)


class TestSearch:
    def _table(self):
        return [
            search.Candidate(
                arch=fm.RNNArch(8, 1, "N"), n_samples=1,
                metrics={"accuracy": 0.90, "ap": 0.62, "ar": 0.66,
                         "entropy": 0.15}),
            search.Candidate(
                arch=fm.RNNArch(8, 3, "YNY"),
                metrics={"accuracy": 0.92, "ap": 0.69, "ar": 0.64,
                         "entropy": 0.30}),
            search.Candidate(
                arch=fm.RNNArch(8, 3, "YNN"),
                metrics={"accuracy": 0.89, "ap": 0.59, "ar": 0.64,
                         "entropy": 0.60}),
        ]

    def test_modes_pick_per_priority(self):
        table = self._table()
        assert search.optimize(table, "Opt-Accuracy").arch.placement == "YNY"
        assert search.optimize(table, "Opt-Entropy").arch.placement == "YNN"
        lat = search.optimize(table, "Opt-Latency")
        assert lat.arch.num_layers == 1     # paper: latency trades depth away

    def test_requirements_filter(self):
        got = search.optimize(self._table(), "Opt-Latency",
                              requirements={"accuracy": 0.91})
        assert got.arch.placement == "YNY"

    def test_infeasible_returns_none(self):
        huge = [search.Candidate(arch=fm.RNNArch(2048, 3, "Y"), metrics={})]
        assert search.optimize(huge, "Opt-Latency") is None

    def test_pareto_front_nonempty(self):
        front = search.pareto_front(self._table(), "entropy", "accuracy")
        assert front


class TestTpuModel:
    def test_memory_decreases_with_chips(self):
        cfg = get_config("llama3-8b")
        cell = SHAPES["train_4k"]
        m256 = tpu_model.memory_model(cfg, cell,
                                      tpu_model.TpuHwConfig(data=16, model=16))
        m512 = tpu_model.memory_model(
            cfg, cell, tpu_model.TpuHwConfig(data=16, model=16, pod=2))
        assert m512 < m256

    def test_search_feasible_configs_exist(self):
        cfg = get_config("qwen3-1.7b")
        out = tpu_model.search_hw(cfg, SHAPES["train_4k"])
        assert out and out[0]["feasible"]
        assert out[0]["t_step"] <= out[-1]["t_step"] or not out[-1]["feasible"]

    def test_jamba_train_needs_more_than_one_pod(self):
        """398B AdamW does not fit 256 × 16 GB — the multi-pod motivation."""
        cfg = get_config("jamba-1.5-large-398b")
        out = tpu_model.search_hw(cfg, SHAPES["train_4k"], chips=256)
        assert not any(r["feasible"] for r in out)
        out2 = tpu_model.search_hw(cfg, SHAPES["train_4k"], chips=256, pod=2)
        assert any(r["feasible"] for r in out2)

    def test_decode_is_memory_or_collective_bound(self):
        cfg = get_config("llama3-8b")
        r = tpu_model.step_model(cfg, SHAPES["decode_32k"],
                                 tpu_model.TpuHwConfig())
        assert max(r["t_memory"], r["t_collective"]) > r["t_compute"]

"""Sharding rules: spec pytrees must mirror param/state pytrees exactly,
and every sharded dim must divide its mesh axes (the invariant that makes
the 512-device dry-run compile)."""

import functools

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch import shardings
from repro.models import backbone
from repro.train import optimizer

PO = shardings.Policy(axes={"data": 16, "model": 16}, dp=("data",))
PO_FSDP = shardings.Policy(axes={"data": 16, "model": 16}, dp=("data",),
                           fsdp=True)


def _spec_matches(shapes, specs):
    """Every leaf has a spec of rank ≤ ndim whose axes divide the dims."""
    flat_sh = jax.tree_util.tree_leaves(shapes)
    flat_sp = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_sh) == len(flat_sp), (len(flat_sh), len(flat_sp))
    for sh, sp in zip(flat_sh, flat_sp):
        assert isinstance(sp, P)
        assert len(sp) <= len(sh.shape), (sp, sh.shape)
        for dim, axes in zip(sh.shape, tuple(sp)):
            if axes is None:
                continue
            axes = axes if isinstance(axes, tuple) else (axes,)
            total = 1
            for a in axes:
                total *= PO.axes[a]
            assert dim % total == 0, (sh.shape, sp)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("po", [PO, PO_FSDP], ids=["tp", "tp+fsdp"])
def test_param_specs_mirror_params(arch, po):
    cfg = get_config(arch)          # FULL config — eval_shape only
    shapes = jax.eval_shape(
        functools.partial(backbone.init_params, cfg=cfg, dtype=jnp.bfloat16),
        jax.random.key(0))
    specs = shardings.param_specs(cfg, po)
    _spec_matches(shapes, specs)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_specs_mirror_state(arch):
    cfg = get_config(arch)
    batch = 128
    shapes = jax.eval_shape(
        functools.partial(backbone.init_decode_state, cfg, batch, 1024,
                          jnp.bfloat16))
    specs = shardings.cache_specs(cfg, PO, batch)
    _spec_matches(shapes.caches, specs.caches)


def test_optstate_specs_fold_data_axis():
    cfg = get_config("llama3-8b")
    shapes = jax.eval_shape(
        functools.partial(backbone.init_params, cfg=cfg, dtype=jnp.bfloat16),
        jax.random.key(0))
    pspecs = shardings.param_specs(cfg, PO)
    ospecs = shardings.optstate_specs(pspecs, PO, shapes)
    opt_shapes = jax.eval_shape(optimizer.init, shapes)
    _spec_matches(opt_shapes.m, ospecs.m)
    # ZeRO: at least one big moment leaf gained a data axis
    flat = jax.tree_util.tree_leaves(ospecs.m,
                                     is_leaf=lambda x: isinstance(x, P))
    assert any(any(ax == ("data",) or ax == "data"
                   for ax in tuple(sp) if ax is not None) for sp in flat)


def test_batch_spec_unshardable_batch_replicates():
    assert shardings.batch_spec(1, PO) is None       # long_500k
    assert shardings.batch_spec(128, PO) == ("data",)

"""Snapshot format compatibility: committed golden fixtures must restore.

The fixtures under ``tests/fixtures/snapshots/`` were written in historical
meta layouts (see ``tests/fixtures/make_snapshot_fixtures.py``): the
durable-control-plane layout (2-part LSTM carries, no ``parts`` key, no
``cell``/``precision`` in the engine extra) and the variable-arity layout
(``parts`` present, ``cell`` present, still no ``precision``).  These tests
pin that today's ``restore`` path keeps loading both — i.e. that format
evolution stays additive — and that the ``precision`` meta added by the
quantized serving path refuses mismatched restores with a typed error.

``fleet_v1/`` is the multi-tenant fleet layout golden (committed at the
layout's birth): it must restore into a matching ``FleetEngine``, the old
single-engine snapshots must adopt into a *one-tenant* fleet, and every
cross-layout or mismatched-spec load must fail with a typed error.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import classifier as clf, distill, mcd
from repro.serve import (FleetEngine, StreamingEngine, TenantSpec,
                         load_fleet_meta, load_snapshot_meta)

FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures", "snapshots")
# Geometry the fixtures were streamed under (make_snapshot_fixtures.py).
HIDDEN, NUM_LAYERS, N_SAMPLES, SEED = 8, 2, 2, 3


def _engine(cell="lstm", precision=None):
    cfg = clf.ClassifierConfig(
        hidden=HIDDEN, num_layers=NUM_LAYERS, cell=cell,
        mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=N_SAMPLES,
                          seed=SEED))
    params = clf.init(jax.random.key(0), cfg)
    return StreamingEngine(params, cfg, backend="pallas_seq")


class TestGoldenFixtures:
    def test_pr3_two_part_layout_restores(self):
        """Session metas without a ``parts`` key default to 2-part (h, c)
        LSTM carries; an extra without ``cell``/``precision`` restores into
        an LSTM native-precision engine."""
        eng = _engine("lstm")
        eng.restore(os.path.join(FIXTURES, "pr3_lstm"))
        assert sorted(eng.active_sessions) == ["ward_1", "ward_2"]
        assert eng.tick == 2
        sess = eng.store.get("ward_1")
        assert sess.steps == 7 and sess.chunks == 2
        assert [len(layer) for layer in sess.state] == [2, 2]
        for h, c in sess.state:
            assert h.shape == c.shape == (N_SAMPLES, HIDDEN)
        # rows are the Bayesian coordinates — they must round-trip exactly
        np.testing.assert_array_equal(np.asarray(sess.rows), [0, 1])
        np.testing.assert_array_equal(
            np.asarray(eng.store.get("ward_2").rows), [2, 3])
        # and the restored store must actually serve
        out = eng.step({"ward_1": jnp.ones((3, 1))})
        assert out["ward_1"].steps_total == 10

    def test_pr4_variable_arity_layout_restores(self):
        """``parts: 1`` GRU carries restore into a GRU engine and serve."""
        eng = _engine("gru")
        eng.restore(os.path.join(FIXTURES, "pr4_gru"))
        sess = eng.store.get("ward_2")
        assert [len(layer) for layer in sess.state] == [1, 1]
        out = eng.step({"ward_2": jnp.ones((2, 1))})
        assert out["ward_2"].steps_total == 9

    def test_pr3_fixture_refused_by_wrong_cell(self):
        with pytest.raises(ValueError, match="lstm"):
            _engine("gru").restore(os.path.join(FIXTURES, "pr3_lstm"))

    def test_old_snapshot_refused_by_quantized_engine(self):
        """Pre-quantization snapshots carry no ``precision`` key: they were
        written by native-dtype engines, so only a ``precision=None`` engine
        may resume them — a quantized engine would change the carry dtypes
        mid-stream."""
        cfg = clf.ClassifierConfig(
            hidden=HIDDEN, num_layers=NUM_LAYERS,
            mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=N_SAMPLES,
                              seed=SEED))
        params = clf.init(jax.random.key(0), cfg)
        eng = StreamingEngine(params, cfg, backend="pallas_seq",
                              precision="int8")
        with pytest.raises(ValueError, match="precision"):
            eng.restore(os.path.join(FIXTURES, "pr3_lstm"))


def _fleet_cfg():
    return clf.ClassifierConfig(
        hidden=HIDDEN, num_layers=NUM_LAYERS,
        mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=N_SAMPLES,
                          seed=SEED))


class TestFleetFixtures:
    """The fleet_v1 golden: today's fleet layout, committed at its birth."""

    def _fleet(self, tenants=("ward", "anom")):
        cfg = _fleet_cfg()
        params = clf.init(jax.random.key(0), cfg)
        return FleetEngine([TenantSpec(name=n, cfg=cfg, params=params,
                                       max_sessions=4)
                            for n in tenants])

    def test_fleet_v1_restores_and_serves(self):
        fleet = self._fleet()
        meta = fleet.restore(os.path.join(FIXTURES, "fleet_v1"))
        assert fleet.tick == 3
        assert fleet.active_sessions == {"ward": ["p1"], "anom": ["p1"]}
        sess = fleet.group_of("ward").engine.store.get("ward/p1")
        assert sess.steps == 7 and sess.chunks == 2
        np.testing.assert_array_equal(np.asarray(sess.rows), [0, 1])
        # the fairness ledger survived — long-run shares don't reset
        assert fleet.queue.state()["admitted"] == {"ward": 3, "anom": 1}
        # the fresh wait-list entry is back in the shared queue
        assert [(t.tenant, t.sid) for t in fleet.queue.waiting()] == \
            [("ward", "ward/p2")]
        assert meta["fleet_format"] == 1
        # and the restored group actually serves
        out = fleet.step({"ward": {"p1": jnp.ones((3, 1))}})
        assert out["ward"]["p1"].steps_total == 10

    def test_single_engine_snapshot_adopts_into_one_tenant_fleet(self):
        """A pre-fleet StreamingEngine snapshot loads into a one-tenant
        fleet: sessions are re-namespaced under the tenant and serve on."""
        cfg = _fleet_cfg()
        params = clf.init(jax.random.key(0), cfg)
        fleet = FleetEngine([TenantSpec(name="icu", cfg=cfg, params=params,
                                        backend="pallas_seq")])
        fleet.restore(os.path.join(FIXTURES, "pr3_lstm"))
        assert sorted(fleet.active_sessions["icu"]) == ["ward_1", "ward_2"]
        assert fleet.tick == 2
        out = fleet.step({"icu": {"ward_1": jnp.ones((3, 1))}})
        assert out["icu"]["ward_1"].steps_total == 10

    def test_multi_tenant_fleet_refuses_single_engine_snapshot(self):
        with pytest.raises(ValueError, match="one-tenant"):
            self._fleet().restore(os.path.join(FIXTURES, "pr3_lstm"))

    def test_fleet_snapshot_refused_by_streaming_engine(self):
        """The layouts never cross: a StreamingEngine cannot silently load
        one group of a fleet manifest."""
        eng = _engine("lstm")
        with pytest.raises(IOError, match="not a session"):
            eng.restore(os.path.join(FIXTURES, "fleet_v1"))
        with pytest.raises(IOError, match="not a session"):
            load_snapshot_meta(os.path.join(FIXTURES, "fleet_v1"), 0)
        with pytest.raises(IOError, match="fleet"):
            load_fleet_meta(os.path.join(FIXTURES, "pr3_lstm"), 0)

    def test_fleet_fixture_refused_by_wrong_tenant_set(self):
        with pytest.raises(ValueError, match="tenants"):
            self._fleet(("ward", "other")).restore(
                os.path.join(FIXTURES, "fleet_v1"))

    def test_fleet_fixture_refused_by_mismatched_grouping(self):
        """Same tenant names, but this fleet's specs split them into two
        launch groups while the snapshot co-batched them — typed refusal
        (the specs diverged; carries cannot be adopted safely)."""
        cfg = _fleet_cfg()
        params = clf.init(jax.random.key(0), cfg)
        split = FleetEngine([
            TenantSpec(name="ward", cfg=cfg, params=params),
            TenantSpec(name="anom", cfg=cfg,
                       params=clf.init(jax.random.key(1), cfg)),
        ])
        assert len(split.groups) == 2
        with pytest.raises(ValueError, match="diverge"):
            split.restore(os.path.join(FIXTURES, "fleet_v1"))


class TestPrecisionMismatch:
    def test_restore_refuses_precision_change(self, tmp_path):
        cfg = clf.ClassifierConfig(
            hidden=HIDDEN, num_layers=NUM_LAYERS,
            mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=N_SAMPLES,
                              seed=SEED))
        params = clf.init(jax.random.key(0), cfg)
        writer = StreamingEngine(params, cfg, backend="pallas_seq",
                                 precision="int8")
        writer.open_session("a")
        writer.step({"a": jnp.ones((4, 1))})
        writer.snapshot(str(tmp_path))
        for wrong in (None, "bf16", "int4"):
            reader = StreamingEngine(params, cfg, backend="pallas_seq",
                                     precision=wrong)
            with pytest.raises(ValueError, match="precision"):
                reader.restore(str(tmp_path))
        # the matching precision resumes fine
        ok = StreamingEngine(params, cfg, backend="pallas_seq",
                             precision="int8")
        ok.restore(str(tmp_path))
        assert ok.active_sessions == ["a"]


class TestDistillCompat:
    """ISSUE 10: session ``mode`` became durable state.  The ``mode`` key is
    written only off the default, so pre-distill snapshots stay
    byte-identical to the current format and restore as all-MC; the
    ``distill_v1`` golden pins that student sessions (flagged single row,
    student-heads decode) and queued student tickets keep restoring; and a
    student snapshot must be refused by an engine built without heads."""

    def _cfg(self):
        return clf.ClassifierConfig(
            hidden=HIDDEN, num_layers=NUM_LAYERS,
            mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=N_SAMPLES,
                              seed=SEED))

    def _student_engine(self, **kw):
        cfg = self._cfg()
        params = clf.init(jax.random.key(0), cfg)
        student = distill.init_student(jax.random.key(1), cfg, params)
        return StreamingEngine(params, cfg, backend="pallas_seq",
                               student=student, **kw), params, student

    def test_pre_distill_fixtures_restore_all_mc(self):
        eng = _engine("lstm")
        eng.restore(os.path.join(FIXTURES, "pr3_lstm"))
        assert all(eng.store.get(sid).mode == "mc"
                   for sid in eng.store.active)

    def test_all_mc_snapshot_writes_no_mode_key(self, tmp_path):
        """The byte-compat claim itself: an all-MC store's session metas
        must not grow a ``mode`` key (old readers never look for one)."""
        eng = _engine("lstm")
        eng.open_session("a")
        eng.step({"a": jnp.ones((3, 1))})
        eng.snapshot(str(tmp_path))
        meta = load_snapshot_meta(str(tmp_path))
        assert all("mode" not in m for m in meta["sessions"].values())

    def test_distill_v1_restores_and_serves(self):
        eng, _, _ = self._student_engine()
        eng.restore(os.path.join(FIXTURES, "distill_v1"))
        sess = eng.store.get("ward_2")
        assert sess.mode == "student"
        rows = np.asarray(sess.rows)
        assert rows.shape == (1,) and int(rows[0]) == 0x8000_0000 | N_SAMPLES
        assert eng.store.get("ward_1").mode == "mc"
        # the queued fresh student ticket survived with its mode
        assert [t.mode for t in eng.queue.waiting()] == ["student"]
        # and the student session actually serves on the fast path
        out = eng.step({"ward_2": jnp.ones((3, 1)), "ward_1": jnp.ones((3, 1))})
        assert out["ward_2"].steps_total == 10
        assert eng.last_metrics.student_rows == 1

    def test_student_snapshot_refused_without_student_heads(self):
        with pytest.raises(ValueError, match="student= heads"):
            _engine("lstm").restore(os.path.join(FIXTURES, "distill_v1"))

    def test_engine_student_round_trip_bit_identical(self, tmp_path):
        """Kill→restore around live student + MC sessions: modes survive
        and the resumed streams continue bit-identically."""
        gold, params, student = self._student_engine(max_sessions=4)
        sig = np.asarray(jax.random.normal(jax.random.key(3), (12, 1)),
                         np.float32)

        def serve(eng, lo, hi, out=None):
            for t in range(lo, hi):
                out = eng.step({
                    "stu": jnp.asarray(sig[3 * t:3 * (t + 1)]),
                    "mc": jnp.asarray(sig[3 * t:3 * (t + 1)])})
            return out

        gold.open_session("stu", mode="student")
        gold.open_session("mc")
        final_gold = serve(gold, 0, 4)

        victim, *_ = self._student_engine(max_sessions=4)
        victim.student = student          # same heads as gold
        victim.open_session("stu", mode="student")
        victim.open_session("mc")
        serve(victim, 0, 2)
        victim.snapshot(str(tmp_path))
        del victim

        revived, *_ = self._student_engine(max_sessions=4)
        revived.student = student
        revived.restore(str(tmp_path))
        assert revived.store.get("stu").mode == "student"
        assert revived.store.get("mc").mode == "mc"
        final_res = serve(revived, 2, 4)
        for sid in ("stu", "mc"):
            np.testing.assert_array_equal(
                np.asarray(final_res[sid].summary.probs),
                np.asarray(final_gold[sid].summary.probs))

    def test_fleet_student_round_trip_bit_identical(self, tmp_path):
        """Same contract through a fleet manifest: a tenant's student
        session survives the fleet kill→restore, mode intact."""
        cfg = self._cfg()
        params = clf.init(jax.random.key(0), cfg)
        student = distill.init_student(jax.random.key(1), cfg, params)

        def make_fleet():
            return FleetEngine([TenantSpec(name="t", cfg=cfg, params=params,
                                           max_sessions=4, student=student)])

        sig = np.asarray(jax.random.normal(jax.random.key(4), (12, 1)),
                         np.float32)

        def serve(fleet, lo, hi, out=None):
            for t in range(lo, hi):
                out = fleet.step({"t": {
                    "stu": jnp.asarray(sig[3 * t:3 * (t + 1)]),
                    "mc": jnp.asarray(sig[3 * t:3 * (t + 1)])}})
            return out

        gold = make_fleet()
        gold.admit("t", "stu", mode="student")
        gold.admit("t", "mc")
        final_gold = serve(gold, 0, 4)

        victim = make_fleet()
        victim.admit("t", "stu", mode="student")
        victim.admit("t", "mc")
        serve(victim, 0, 2)
        victim.snapshot(str(tmp_path))
        del victim

        revived = make_fleet()
        revived.restore(str(tmp_path))
        store = revived.group_of("t").engine.store
        assert store.get("t/stu").mode == "student"
        final_res = serve(revived, 2, 4)
        for sid in ("stu", "mc"):
            np.testing.assert_array_equal(
                np.asarray(final_res["t"][sid].summary.probs),
                np.asarray(final_gold["t"][sid].summary.probs))


class TestDynamicSCompat:
    """ISSUE 9: per-session S became durable state.  Pre-dynamic snapshots
    are the uniform-S special case (every session at the writing engine's
    ceiling), and new snapshots written after early exit must round-trip
    the *reduced* per-session chain counts — including through a fleet
    kill→restore."""

    def test_pre_dynamic_fixtures_restore_at_uniform_s(self):
        """The committed goldens predate per-session S: every restored
        session must hold exactly the old engine-wide S chains."""
        eng = _engine("lstm")
        eng.restore(os.path.join(FIXTURES, "pr3_lstm"))
        for sid in eng.store.active:
            assert int(eng.store.get(sid).rows.shape[0]) == N_SAMPLES
        assert eng.store.active_chains == N_SAMPLES * len(eng.store.active)

    def test_fleet_kill_restore_preserves_per_session_s(self, tmp_path):
        """A fleet tenant early-exits a stream, the fleet is killed and
        restored: the reduced S survives and the resumed streams continue
        bit-identically to a never-killed fleet."""
        cfg = clf.ClassifierConfig(
            hidden=HIDDEN, num_layers=NUM_LAYERS,
            mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=4,
                              seed=SEED))
        params = clf.init(jax.random.key(0), cfg)

        def make_fleet():
            return FleetEngine([TenantSpec(
                name="t", cfg=cfg, params=params, max_sessions=2,
                early_exit_threshold=0.0, min_samples=1)])

        hard = np.asarray(jax.random.normal(jax.random.key(2), (16, 1)))

        def serve(fleet, lo, hi, out=None):
            for t in range(lo, hi):
                out = fleet.step({"t": {
                    "easy": jnp.zeros((4, 1)),
                    "hard": jnp.asarray(hard[4 * t:4 * (t + 1)],
                                        jnp.float32)}})
            return out

        gold = make_fleet()
        gold.admit("t", "easy")
        gold.admit("t", "hard")
        final_gold = serve(gold, 0, 4)

        victim = make_fleet()
        victim.admit("t", "easy")
        victim.admit("t", "hard")
        serve(victim, 0, 2)
        store = victim.group_of("t").engine.store
        assert int(store.get("t/easy").rows.shape[0]) == 1   # retired
        victim.snapshot(str(tmp_path))
        del victim

        revived = make_fleet()
        revived.restore(str(tmp_path))
        store = revived.group_of("t").engine.store
        assert int(store.get("t/easy").rows.shape[0]) == 1
        assert int(store.get("t/hard").rows.shape[0]) == 4
        final_res = serve(revived, 2, 4)
        for sid in ("easy", "hard"):
            np.testing.assert_array_equal(
                np.asarray(final_res["t"][sid].summary.probs),
                np.asarray(final_gold["t"][sid].summary.probs))

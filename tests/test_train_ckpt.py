"""Trainer + checkpoint fault-tolerance behaviour."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint
from repro.kernels import compat
from repro.train import optimizer, trainer


def _toy_problem(n=640):
    rng = np.random.default_rng(0)
    X = rng.normal(size=(n, 3)).astype(np.float32)
    Y = (X @ np.array([[1.0], [2.0], [-0.5]]) + 0.3).astype(np.float32)
    return jnp.asarray(X), jnp.asarray(Y)


def _loss(params, batch, step):
    x, y = batch
    return jnp.mean((x @ params["w"] + params["b"] - y) ** 2), {}


def _batches(X, Y, bs=64):
    for i in range(0, len(X), bs):
        yield X[i:i + bs], Y[i:i + bs]


def _params():
    return {"w": jnp.zeros((3, 1)), "b": jnp.zeros((1,))}


class TestTrainer:
    def test_loss_decreases(self):
        X, Y = _toy_problem()
        cfg = trainer.TrainConfig(adamw=optimizer.AdamWConfig(lr=0.05),
                                  log_every=0)
        tr = trainer.Trainer(_loss, _params(), cfg)
        hist = tr.run(_batches(X, Y), 10)
        assert hist[-1]["loss"] < hist[0]["loss"]

    def test_microbatch_equals_full(self):
        """Grad accumulation over microbatches == one big batch (same update)."""
        X, Y = _toy_problem(128)
        batch = (X, Y)
        p0 = _params()
        s0 = optimizer.init(p0)
        err = jax.tree.map(lambda p: jnp.zeros((), jnp.float32), p0)
        f1 = trainer.make_train_step(_loss, trainer.TrainConfig(microbatches=1))
        f4 = trainer.make_train_step(_loss, trainer.TrainConfig(microbatches=4))
        p1, *_ = f1(p0, s0, err, batch, jnp.int32(0))
        p4, *_ = f4(p0, s0, err, batch, jnp.int32(0))
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("mode", ["bf16", "int8"])
    def test_compressed_grads_converge(self, mode):
        """Error-feedback compression still reaches a good solution."""
        X, Y = _toy_problem()
        cfg = trainer.TrainConfig(adamw=optimizer.AdamWConfig(lr=0.05),
                                  grad_compression=mode, log_every=0)
        tr = trainer.Trainer(_loss, _params(), cfg)
        hist = tr.run((b for _ in range(6) for b in _batches(X, Y)), 50)
        assert hist[-1]["loss"] < 0.1 * hist[0]["loss"]

    def test_clip_norm(self):
        g = {"a": jnp.full((10,), 100.0)}
        clipped, norm = optimizer.clip_by_global_norm(g, 3.0)
        assert float(norm) > 3.0
        assert abs(float(optimizer.global_norm(clipped)) - 3.0) < 1e-4

    def test_straggler_watchdog(self):
        cfg = trainer.TrainConfig(straggler_factor=2.0, log_every=0)
        tr = trainer.Trainer(_loss, _params(), cfg)
        tr.step_times = [0.1] * 20
        tr._watchdog(0.5)
        assert tr.straggler_events


class TestCheckpoint:
    def test_resume_continues(self, tmp_path):
        X, Y = _toy_problem()
        d = str(tmp_path / "ck")
        cfg = trainer.TrainConfig(adamw=optimizer.AdamWConfig(lr=0.05),
                                  ckpt_dir=d, ckpt_every=5, log_every=0)
        tr1 = trainer.Trainer(_loss, _params(), cfg)
        tr1.run(_batches(X, Y), 10)
        assert checkpoint.latest_step(d) == 10
        # simulate crash + restart: a fresh Trainer resumes at step 10
        tr2 = trainer.Trainer(_loss, _params(), cfg)
        assert tr2.step == 10
        for a, b in zip(jax.tree_util.tree_leaves(tr1.params),
                        jax.tree_util.tree_leaves(tr2.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_corruption_detected_and_skipped(self, tmp_path):
        d = str(tmp_path / "ck")
        tree = {"w": jnp.arange(8.0)}
        checkpoint.save(d, 1, tree)
        checkpoint.save(d, 2, jax.tree.map(lambda x: x * 2, tree))
        # corrupt the newest checkpoint
        victim = os.path.join(d, "step-0000000002", "w.npy")
        with open(victim, "r+b") as f:
            f.seek(-1, 2)
            f.write(b"\x00")
        with pytest.raises(IOError):
            checkpoint.restore(d, 2, tree)
        step, restored = checkpoint.resume_or_none(d, tree)
        assert step == 1                       # fell back to the older one
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(8.0))

    def test_atomicity_no_partial_dir(self, tmp_path):
        d = str(tmp_path / "ck")
        checkpoint.save(d, 3, {"x": jnp.ones(4)})
        assert not [f for f in os.listdir(d) if f.startswith(".tmp")]

    def test_elastic_reshard_restore(self, tmp_path):
        """Restore onto explicit shardings (the elastic-rescale path)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        d = str(tmp_path / "ck")
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        checkpoint.save(d, 1, tree)
        mesh = compat.make_mesh((1,), ("data",))
        shardings = {"w": NamedSharding(mesh, P("data", None))}
        restored = checkpoint.restore(d, 1, tree, shardings=shardings)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        assert restored["w"].sharding == shardings["w"]

    def test_keep_last(self, tmp_path):
        d = str(tmp_path / "ck")
        for s in (1, 2, 3, 4):
            checkpoint.save(d, s, {"x": jnp.ones(2) * s})
        checkpoint.keep_last(d, 2)
        steps = sorted(int(f.split("-")[1]) for f in os.listdir(d))
        assert steps == [3, 4]

    def test_meta_rides_the_manifest(self, tmp_path):
        """save(meta=) commits JSON alongside the arrays atomically; it comes
        back via load_meta and never perturbs the array restore."""
        d = str(tmp_path / "ck")
        tree = {"x": jnp.ones(3)}
        checkpoint.save(d, 1, tree, meta={"cursor": 7, "sids": ["a", "b"]})
        assert checkpoint.load_meta(d, 1) == {"cursor": 7,
                                              "sids": ["a", "b"]}
        np.testing.assert_array_equal(
            np.asarray(checkpoint.restore(d, 1, tree)["x"]), np.ones(3))
        checkpoint.save(d, 2, tree)                  # meta stays optional
        assert checkpoint.load_meta(d, 2) is None

    def test_meta_must_be_json(self, tmp_path):
        with pytest.raises(TypeError):
            checkpoint.save(str(tmp_path / "ck"), 1, {"x": jnp.ones(2)},
                            meta={"bad": jnp.ones(2)})

    def test_partial_restore_subset(self, tmp_path):
        """A like-tree naming a subset of the saved leaves restores just
        that subset; a leaf the manifest doesn't know stays an error."""
        d = str(tmp_path / "ck")
        tree = {"a": {"w": jnp.arange(4.0)}, "b": {"w": jnp.arange(2.0)}}
        checkpoint.save(d, 1, tree)
        sub = checkpoint.restore(d, 1, {"a": {"w": 0}}, partial=True)
        np.testing.assert_array_equal(np.asarray(sub["a"]["w"]),
                                      np.arange(4.0))
        with pytest.raises(KeyError, match="not in checkpoint"):
            checkpoint.restore(d, 1, {"zz": {"w": 0}}, partial=True)
        # without partial=True a truncated like-tree is a caller bug
        with pytest.raises(ValueError, match="partial=True"):
            checkpoint.restore(d, 1, {"a": {"w": 0}})

    def test_partial_restore_refuses_deduped_names(self, tmp_path):
        """'a b' and 'a_b' sanitize to the same leaf name; the positional
        __k disambiguation is full-tree-order dependent, so a partial
        restore must refuse rather than silently return a sibling's
        array."""
        d = str(tmp_path / "ck")
        checkpoint.save(d, 1, {"a b": jnp.zeros(2), "a_b": jnp.ones(2)})
        with pytest.raises(ValueError, match="disambiguated"):
            checkpoint.restore(d, 1, {"a_b": 0}, partial=True)
        full = checkpoint.restore(d, 1, {"a b": 0, "a_b": 0})
        np.testing.assert_array_equal(np.asarray(full["a_b"]), np.ones(2))

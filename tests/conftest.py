"""Test-suite bootstrap: provide a `hypothesis` fallback when absent.

The suite's property tests use a small, fixed subset of the hypothesis API
(`given`, `settings`, `strategies.{integers,floats,booleans,sampled_from,
lists}`).  Real hypothesis is declared in pyproject.toml and used when
installed; in hermetic containers without it we register a deterministic
stand-in that draws `max_examples` pseudo-random examples per test, so the
property tests still execute instead of failing at collection.

It also bounds in-process XLA compile state (see `_release_jax_executables`):
without the per-module cache clear, the CPU backend segfaults inside
`backend_compile` once a single pytest process has accumulated a few hundred
compiled executables.
"""

from __future__ import annotations

import random
import sys
import types

import pytest


@pytest.fixture(autouse=True, scope="module")
def _release_jax_executables():
    """Drop jit/pjit executable caches after each test module.

    A full-suite run compiles >400 distinct programs in one process; on the
    CPU backend this reliably segfaults deep in XLA's `backend_compile` once
    enough LLVM-JIT'd executables are live (deterministic at the same test
    across runs, while the same test passes in isolation).  Releasing the
    cached executables at module boundaries keeps the live-executable count
    bounded.  Within a module caches are untouched, so the bit-identity
    tests that rely on hitting the same compiled graph are unaffected.
    """
    yield
    import jax

    jax.clear_caches()


def _install_hypothesis_stub() -> None:
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value: float = 0.0, max_value: float = 1.0, **_) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def lists(elements: _Strategy, *, min_size: int = 0,
              max_size: int = 10, **_) -> _Strategy:
        def draw(rng: random.Random):
            n = rng.randint(min_size, max_size)
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def settings(max_examples: int = 20, **_):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    def given(**strategies_kw):
        def deco(fn):
            def wrapper(*args, **kwargs):
                # Read at call time: @settings works above or below @given
                # (above decorates `wrapper`, below decorates `fn`).
                n = getattr(wrapper, "_stub_max_examples",
                            getattr(fn, "_stub_max_examples", 20))
                # Deterministic per-test stream: same examples every run.
                rng = random.Random(fn.__qualname__)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies_kw.items()}
                    fn(*args, **kwargs, **drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = types.SimpleNamespace(too_slow=None)
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.booleans = booleans
    st_mod.sampled_from = sampled_from
    st_mod.lists = lists
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _install_hypothesis_stub()

"""Launch-layer machinery: job construction, analysis parsing, param
accounting — everything that the 512-device dry-run relies on, exercised on
the 1-device host mesh with reduced configs so it runs in CI."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.kernels import compat
from repro.launch import analysis, mesh as mesh_lib, specs
from repro.models import backbone
from repro.models.config import SHAPES


class TestCollectiveParser:
    HLO = """
  %ag = bf16[2048,512]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[16,128]{1,0} all-reduce-start(%y), to_apply=%add
  %rs = bf16[64,64]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = f32[8,8]{1,0} all-to-all(%w), dimensions={1}
  %cp = bf16[4,4]{1,0} collective-permute(%v), source_target_pairs={{0,1}}
"""

    def test_kinds_and_bytes(self):
        got = analysis.collective_bytes(self.HLO)
        assert got["all-gather"] == 2048 * 512 * 2
        assert got["all-reduce"] == 16 * 128 * 4
        assert got["reduce-scatter"] == 64 * 64 * 2
        assert got["all-to-all"] == 8 * 8 * 4
        assert got["collective-permute"] == 4 * 4 * 2

    def test_allreduce_counts_double(self):
        r = analysis.Roofline(flops=0, bytes_hbm=0, bytes_collective=0,
                              coll_by_kind={}, t_compute=0, t_memory=0,
                              t_collective=0, bottleneck="memory",
                              memory_per_device={})
        # factor table: all-reduce weighted 2×
        assert analysis._FACTORS["all-reduce"] == 2.0


class TestActiveParams:
    @pytest.mark.parametrize("arch", ["llama3-8b", "qwen3-1.7b",
                                      "mamba2-370m"])
    def test_analytic_matches_actual_dense(self, arch):
        """For non-MoE archs, analytic active_params == real leaf count."""
        cfg = get_config(arch, reduced=True)
        shapes = jax.eval_shape(
            functools.partial(backbone.init_params, cfg=cfg,
                              dtype=jnp.float32), jax.random.key(0))
        actual = sum(np.prod(s.shape) for s in
                     jax.tree_util.tree_leaves(shapes))
        analytic = analysis.active_params(cfg)
        # norms/scales are not counted analytically (≤1 % of params)
        assert abs(actual - analytic) / actual < 0.05, (actual, analytic)

    def test_moe_active_below_total(self):
        cfg = get_config("olmoe-1b-7b")
        shapes = jax.eval_shape(
            functools.partial(backbone.init_params, cfg=cfg,
                              dtype=jnp.bfloat16), jax.random.key(0))
        total = sum(np.prod(s.shape) for s in
                    jax.tree_util.tree_leaves(shapes))
        active = analysis.active_params(cfg)
        assert active < 0.4 * total      # 8 of 64 experts active

    def test_llama3_param_count_published(self):
        """Full llama3-8b config must land at ~8.0B parameters."""
        n = analysis.active_params(get_config("llama3-8b"))
        assert 7.5e9 < n < 8.5e9, n


class TestJobsOnHostMesh:
    def _mesh(self):
        return mesh_lib.make_host_mesh()

    @pytest.mark.parametrize("kind", ["train_4k", "prefill_32k", "decode_32k"])
    def test_job_specs_build_for_all_archs(self, kind):
        """Job construction (eval_shape + shardings) for every full config —
        no allocation, catches spec/pytree mismatches early."""
        mesh = self._mesh()
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            job = specs.make_job(cfg, kind, mesh)
            assert job is not None
            flat_args = jax.tree_util.tree_leaves(job.args)
            assert all(hasattr(a, "shape") for a in flat_args)

    def test_reduced_train_step_compiles_and_runs(self):
        """A reduced-config train job actually executes on the host mesh."""
        mesh = self._mesh()
        cfg = get_config("qwen3-1.7b", reduced=True)
        # shrink the cell for CPU: patch a tiny shape through train_job path
        from repro.launch.specs import train_job
        import repro.models.config as mc
        tiny = mc.ShapeCell("tiny", 16, 4, "train")
        old = dict(mc.SHAPES)
        mc.SHAPES["tiny"] = tiny
        try:
            job = train_job(cfg, "tiny", mesh)
            with compat.set_mesh(mesh):
                compiled = jax.jit(job.fn, in_shardings=job.in_shardings,
                                   out_shardings=job.out_shardings
                                   ).lower(*job.args).compile()
            # run it with real (tiny) inputs
            params = backbone.init_params(jax.random.key(0), cfg,
                                          jnp.bfloat16)
            from repro.train import optimizer
            opt = optimizer.init(params)
            batch = {"tokens": jnp.zeros((4, 16), jnp.int32),
                     "targets": jnp.zeros((4, 16), jnp.int32)}
            with compat.set_mesh(mesh):
                p2, o2, metrics = compiled(params, opt, batch,
                                           jnp.zeros((), jnp.int32))
            assert np.isfinite(float(metrics["loss"]))
        finally:
            mc.SHAPES.clear()
            mc.SHAPES.update(old)

    def test_probe_jobs_cover_every_stage_position(self):
        mesh = self._mesh()
        cfg = get_config("jamba-1.5-large-398b")
        probes = specs.probe_jobs(cfg, "train_4k", mesh)
        block_probes = [p for p in probes if p.name.startswith("blk")]
        assert len(block_probes) == len(cfg.stages[0].pattern)
        assert {p.multiplier for p in block_probes} == {9}
        assert any(p.name.startswith("opt:") for p in probes)

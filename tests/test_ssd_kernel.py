"""Fused SSD chunk-scan Pallas kernel vs the validated jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ssd_chunk
from repro.models import mamba2


def _case(B, L, H, P, N, seed=0):
    ks = jax.random.split(jax.random.key(seed), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    bm = jax.random.normal(ks[3], (B, L, N)) * 0.3
    cm = jax.random.normal(ks[4], (B, L, N)) * 0.3
    d = jnp.linspace(0.5, 1.5, H)
    return x, dt, a, bm, cm, d


@pytest.mark.parametrize("B,L,H,P,N,q,bh", [
    (2, 32, 4, 8, 16, 8, 2),
    (1, 64, 8, 16, 32, 16, 8),
    (3, 24, 2, 8, 8, 8, 1),
    (2, 40, 4, 8, 16, 16, 4),     # q doesn't divide → falls back to divisor
])
def test_matches_jnp_oracle(B, L, H, P, N, q, bh):
    x, dt, a, bm, cm, d = _case(B, L, H, P, N)
    y_k, h_k = ssd_chunk.ssd_chunk_scan(x, dt, a, bm, cm, d, q_chunk=q,
                                        block_h=bh)
    # oracle: the jnp chunked path (validated against the naive recurrence
    # in tests/test_mamba_ssd.py) with a matching chunk size
    qq = min(q, L)
    while L % qq:
        qq -= 1
    y_r, h_r = mamba2._ssd_chunked(x, dt, a, bm[:, :, None, :],
                                   cm[:, :, None, :], d, qq)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=3e-4, atol=3e-4)


def test_tiling_invariance():
    x, dt, a, bm, cm, d = _case(2, 32, 4, 8, 16)
    y1, h1 = ssd_chunk.ssd_chunk_scan(x, dt, a, bm, cm, d, q_chunk=8,
                                      block_h=2)
    y2, h2 = ssd_chunk.ssd_chunk_scan(x, dt, a, bm, cm, d, q_chunk=16,
                                      block_h=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=3e-4, atol=3e-4)


def test_bf16_inputs():
    x, dt, a, bm, cm, d = _case(1, 16, 2, 8, 8)
    y_k, _ = ssd_chunk.ssd_chunk_scan(x.astype(jnp.bfloat16), dt, a,
                                      bm.astype(jnp.bfloat16),
                                      cm.astype(jnp.bfloat16), d,
                                      q_chunk=8, block_h=2)
    y_r, _ = mamba2._ssd_chunked(x, dt, a, bm[:, :, None, :],
                                 cm[:, :, None, :], d, 8)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=5e-2, atol=5e-2)

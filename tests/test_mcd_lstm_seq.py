"""Sequence-fused MCD-LSTM kernel vs per-step kernel scan vs jnp oracle.

The contract under test (docs/kernels.md): for the same ``gate_keys`` streams
the sequence kernel draws bit-identical masks to the per-step kernel and the
reference, and its (h, c) trajectory matches within fp tolerance for any T.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autoencoder as ae, cells, classifier as clf, mcd, rnn
from repro.kernels import mcd_lstm, mcd_lstm_seq, ops, ref

import conformance

SEED, LAYER = 11, 2


def _layer(b, t, i, h, key=0):
    ks = jax.random.split(jax.random.key(key), 3)
    wx = jax.random.normal(ks[0], (i, 4, h)) * 0.1
    wh = jax.random.normal(ks[1], (h, 4, h)) * 0.1
    bias = jax.random.normal(ks[2], (4, h)) * 0.1
    x_seq = jax.random.normal(jax.random.key(key + 1), (b, t, i))
    rows = jnp.arange(b, dtype=jnp.uint32) + 17
    return x_seq, wx, wh, bias, rows


class TestSeqKernel:
    @pytest.mark.parametrize("t", [1, 8, 33])
    @pytest.mark.parametrize("p", [0.0, 0.125, 0.5])
    def test_matches_ref_and_step_kernel(self, t, p):
        b, i, h = 8, 48, 32
        x_seq, wx, wh, bias, rows = _layer(b, t, i, h)
        keys = mcd_lstm.gate_keys(SEED, LAYER)
        ys, hT, cT = mcd_lstm_seq.mcd_lstm_seq(x_seq, wx, wh, bias, rows,
                                               keys, p)
        yr, hr, cr = ref.mcd_lstm_seq(x_seq, wx, wh, bias, rows, keys, p)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(yr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(hT), np.asarray(hr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(cT), np.asarray(cr),
                                   rtol=1e-5, atol=1e-5)
        ys2, (h2, c2) = ops.fused_lstm_layer(wx, wh, bias, x_seq, rows,
                                             SEED, LAYER, p)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(ys2),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(cT), np.asarray(c2),
                                   rtol=1e-5, atol=1e-5)

    def test_mask_streams_bit_identical(self):
        """With x ≡ 1 and heavy dropout the output separates mask patterns:
        any bit flip vs the reference stream would change a gate matmul
        input by ±scale and show up far above fp tolerance."""
        b, t, i, h = 8, 5, 64, 32
        _, wx, wh, bias, rows = _layer(b, t, i, h)
        x_seq = jnp.ones((b, t, i))
        keys = mcd_lstm.gate_keys(SEED, LAYER)
        ys, _, _ = mcd_lstm_seq.mcd_lstm_seq(x_seq, wx, wh, bias, rows,
                                             keys, 0.5)
        yr, _, _ = ref.mcd_lstm_seq(x_seq, wx, wh, bias, rows, keys, 0.5)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(yr),
                                   rtol=1e-5, atol=1e-5)

    def test_masks_tied_across_time(self):
        """Constant input ⇒ step 2 equals step 1 only if both steps drew the
        same masks (h changes between steps, so compare two constant runs)."""
        b, i, h = 4, 32, 32
        _, wx, wh, bias, rows = _layer(b, 2, i, h)
        x1 = jnp.ones((b, 1, i))
        x2 = jnp.ones((b, 2, i))
        keys = mcd_lstm.gate_keys(SEED, LAYER)
        ys1, h1, c1 = mcd_lstm_seq.mcd_lstm_seq(x1, wx, wh, bias, rows,
                                                keys, 0.25)
        ys2, _, _ = mcd_lstm_seq.mcd_lstm_seq(x2, wx, wh, bias, rows,
                                              keys, 0.25)
        # first step identical; second step = step-kernel applied to (h1, c1)
        np.testing.assert_allclose(np.asarray(ys1[:, 0]), np.asarray(ys2[:, 0]),
                                   rtol=1e-6, atol=1e-6)
        h2, _ = mcd_lstm.mcd_lstm_step(x2[:, 1], h1, c1, wx, wh, bias, rows,
                                       keys, 0.25)
        np.testing.assert_allclose(np.asarray(ys2[:, 1]), np.asarray(h2),
                                   rtol=1e-5, atol=1e-5)

    def test_odd_batch_blocks(self):
        """block_b that does not divide B pads to the next block multiple."""
        b, t, i, h = 6, 4, 16, 16
        x_seq, wx, wh, bias, rows = _layer(b, t, i, h)
        keys = mcd_lstm.gate_keys(SEED, LAYER)
        ys, _, _ = mcd_lstm_seq.mcd_lstm_seq(x_seq, wx, wh, bias, rows, keys,
                                             0.125, block_b=4)
        yr, _, _ = ref.mcd_lstm_seq(x_seq, wx, wh, bias, rows, keys, 0.125)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(yr),
                                   rtol=1e-5, atol=1e-5)

    def test_prime_batch_pads_instead_of_serializing(self):
        """B prime (no divisor ≤ block_b except 1) must not degrade to bb=1:
        the batch pads up to the block multiple and outputs slice back."""
        b, t, i, h = 13, 3, 8, 8
        x_seq, wx, wh, bias, rows = _layer(b, t, i, h)
        keys = mcd_lstm.gate_keys(SEED, LAYER)
        ys, hT, cT = mcd_lstm_seq.mcd_lstm_seq(x_seq, wx, wh, bias, rows,
                                               keys, 0.125, block_b=4)
        yr, hr, cr = ref.mcd_lstm_seq(x_seq, wx, wh, bias, rows, keys, 0.125)
        assert ys.shape == (b, t, h) and hT.shape == (b, h)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(yr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(cT), np.asarray(cr),
                                   rtol=1e-5, atol=1e-5)


class TestCarriedState:
    """The (h0, c0) streaming operands (ISSUE 2 tentpole, layer 1)."""

    @pytest.mark.parametrize("p", [0.0, 0.25])
    def test_resume_matches_oracle(self, p):
        b, t, i, h = 6, 7, 16, 16
        x_seq, wx, wh, bias, rows = _layer(b, t, i, h)
        keys = mcd_lstm.gate_keys(SEED, LAYER)
        h0 = jax.random.normal(jax.random.key(5), (b, h)) * 0.5
        c0 = jax.random.normal(jax.random.key(6), (b, h)).astype(jnp.float32)
        ys, hT, cT = mcd_lstm_seq.mcd_lstm_seq(x_seq, wx, wh, bias, rows,
                                               keys, p, h0=h0, c0=c0)
        yr, hr, cr = ref.mcd_lstm_seq(x_seq, wx, wh, bias, rows, keys, p,
                                      h0=h0, c0=c0)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(yr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(cT), np.asarray(cr),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("splits", [[4, 5], [1] * 9, [2, 1, 6]])
    def test_chunked_equals_unchunked_bit_identical(self, splits):
        """Arbitrary chunk boundaries (incl. length 1) are invisible: the
        lengths-pinned graph family makes the comparison bit-exact."""
        b, t, i, h = 6, 9, 16, 16
        x_seq, wx, wh, bias, rows = _layer(b, t, i, h)
        keys = mcd_lstm.gate_keys(SEED, LAYER)
        lens = lambda n: jnp.full((b,), n, jnp.int32)

        def step(xc, st):
            h0, c0 = st if st is not None else (None, None)
            ys, hT, cT = mcd_lstm_seq.mcd_lstm_seq(
                xc, wx, wh, bias, rows, keys, 0.125, h0=h0, c0=c0,
                lengths=lens(xc.shape[1]))
            return ys, (hT, cT)

        full, (hF, cF) = step(x_seq, None)
        outs, (hT, cT) = conformance.chunked_run(step, x_seq, splits)
        np.testing.assert_array_equal(np.asarray(outs), np.asarray(full))
        np.testing.assert_array_equal(np.asarray(hT), np.asarray(hF))
        np.testing.assert_array_equal(np.asarray(cT), np.asarray(cF))

    def test_lengths_freeze_state_per_row(self):
        """Ragged rows keep the state at their own length; live prefixes are
        bit-identical to the full-length varlen pass."""
        b, t, i, h = 6, 8, 16, 16
        x_seq, wx, wh, bias, rows = _layer(b, t, i, h)
        keys = mcd_lstm.gate_keys(SEED, LAYER)
        lens = jnp.array([8, 1, 3, 5, 2, 7], jnp.int32)
        ys, hT, cT = mcd_lstm_seq.mcd_lstm_seq(x_seq, wx, wh, bias, rows,
                                               keys, 0.125, lengths=lens)
        full, _, _ = mcd_lstm_seq.mcd_lstm_seq(
            x_seq, wx, wh, bias, rows, keys, 0.125,
            lengths=jnp.full((b,), t, jnp.int32))
        for r in range(b):
            L = int(lens[r])
            np.testing.assert_array_equal(np.asarray(ys[r, :L]),
                                          np.asarray(full[r, :L]))
            np.testing.assert_array_equal(np.asarray(hT[r]),
                                          np.asarray(ys[r, L - 1]))
        yr, hr, cr = ref.mcd_lstm_seq(x_seq, wx, wh, bias, rows, keys, 0.125,
                                      lengths=lens)
        np.testing.assert_array_equal(np.asarray(cT), np.asarray(cr))


class TestBf16:
    """bf16 weights/activations; c stays fp32 (ROADMAP 32-bit cell policy)."""

    @pytest.mark.parametrize("p", [0.0, 0.125])
    def test_bf16_matches_bf16_oracle(self, p):
        b, t, i, h = 6, 6, 16, 16
        x_seq, wx, wh, bias, rows = _layer(b, t, i, h)
        to = lambda a: a.astype(jnp.bfloat16)
        keys = mcd_lstm.gate_keys(SEED, LAYER)
        ys, hT, cT = mcd_lstm_seq.mcd_lstm_seq(to(x_seq), to(wx), to(wh),
                                               to(bias), rows, keys, p)
        assert ys.dtype == jnp.bfloat16 and hT.dtype == jnp.bfloat16
        assert cT.dtype == jnp.float32          # cell state stays 32-bit
        yr, hr, cr = ref.mcd_lstm_seq(to(x_seq), to(wx), to(wh), to(bias),
                                      rows, keys, p)
        np.testing.assert_allclose(np.asarray(ys, jnp.float32),
                                   np.asarray(yr, jnp.float32),
                                   rtol=0.05, atol=0.05)
        np.testing.assert_allclose(np.asarray(cT), np.asarray(cr),
                                   rtol=0.05, atol=0.05)

    def test_bf16_carried_state_resume_bit_identical(self):
        """Chunk boundaries stay invisible in bf16: h round-trips in bf16
        (its carry dtype) and c in fp32, so resume is lossless."""
        b, t, i, h = 6, 8, 16, 16
        x_seq, wx, wh, bias, rows = _layer(b, t, i, h)
        to = lambda a: a.astype(jnp.bfloat16)
        xb, wxb, whb, bb_ = to(x_seq), to(wx), to(wh), to(bias)
        keys = mcd_lstm.gate_keys(SEED, LAYER)
        lens = lambda n: jnp.full((b,), n, jnp.int32)
        full, hF, cF = mcd_lstm_seq.mcd_lstm_seq(xb, wxb, whb, bb_, rows,
                                                 keys, 0.125, lengths=lens(t))

        def step(xc, st):
            h0, c0 = st if st is not None else (None, None)
            ys, hT, cT = mcd_lstm_seq.mcd_lstm_seq(
                xc, wxb, whb, bb_, rows, keys, 0.125, h0=h0, c0=c0,
                lengths=lens(xc.shape[1]))
            assert cT.dtype == jnp.float32
            return ys, (hT, cT)

        outs, (hT, cT) = conformance.chunked_run(step, xb, [3, 1, 4])
        np.testing.assert_array_equal(np.asarray(outs, jnp.float32),
                                      np.asarray(full, jnp.float32))
        np.testing.assert_array_equal(np.asarray(cT), np.asarray(cF))


class TestRunStackBackends:
    @pytest.mark.parametrize("placement", ["YN", "NNN", "YYY"])
    @pytest.mark.parametrize("backend", ["pallas_step", "pallas_seq"])
    def test_stack_matches_reference(self, placement, backend):
        cfg = mcd.MCDConfig(p=0.125, placement=placement, seed=5)
        hiddens = (16, 16, 16)
        params = rnn.init_stack(jax.random.key(0), 4, hiddens)
        x = jax.random.normal(jax.random.key(1), (6, 9, 4))
        rows = jnp.arange(6, dtype=jnp.uint32)
        masks = rnn.sample_stack_masks(cfg, rows, 4, hiddens)
        out0, (h0, c0) = rnn.run_stack(params, x, masks, cfg.p)
        out1, (h1, c1) = rnn.run_stack(params, x, masks, cfg.p,
                                       backend=backend, rows=rows,
                                       seed=cfg.seed)
        np.testing.assert_allclose(np.asarray(out0), np.asarray(out1),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h0), np.asarray(h1),
                                   rtol=1e-4, atol=1e-4)

    def test_p_zero_ignores_masks(self):
        cfg = mcd.MCDConfig(p=0.0, placement="YY", seed=5)
        params = rnn.init_stack(jax.random.key(0), 4, (16,))
        x = jax.random.normal(jax.random.key(1), (4, 7, 4))
        rows = jnp.arange(4, dtype=jnp.uint32)
        masks = rnn.sample_stack_masks(cfg, rows, 4, (16,))
        out0, _ = rnn.run_stack(params, x, masks, cfg.p)
        out1, _ = rnn.run_stack(params, x, masks, cfg.p, backend="pallas_seq",
                                rows=rows, seed=cfg.seed)
        np.testing.assert_allclose(np.asarray(out0), np.asarray(out1),
                                   rtol=1e-5, atol=1e-5)

    def test_mask_plan_matches_sampled_masks(self):
        """stack_mask_plan (no tensors) == sample_stack_masks on pallas path."""
        cfg = mcd.MCDConfig(p=0.125, placement="YN", seed=5)
        hiddens = (16, 16, 16)
        params = rnn.init_stack(jax.random.key(0), 4, hiddens)
        x = jax.random.normal(jax.random.key(1), (6, 9, 4))
        rows = jnp.arange(6, dtype=jnp.uint32)
        sampled = rnn.sample_stack_masks(cfg, rows, 4, hiddens)
        plan = rnn.stack_mask_plan(cfg, len(hiddens))
        assert [zx is None for zx, _ in plan] == \
            [zx is None for zx, _ in sampled]
        out0, _ = rnn.run_stack(params, x, sampled, cfg.p,
                                backend="pallas_seq", rows=rows, seed=cfg.seed)
        out1, _ = rnn.run_stack(params, x, plan, cfg.p, backend="pallas_seq",
                                rows=rows, seed=cfg.seed)
        np.testing.assert_array_equal(np.asarray(out0), np.asarray(out1))

    def test_mask_plan_rejected_by_reference_backend(self):
        params = rnn.init_stack(jax.random.key(0), 4, (8,))
        x = jnp.zeros((2, 3, 4))
        plan = rnn.stack_mask_plan(mcd.MCDConfig(p=0.125, placement="Y"), 1)
        with pytest.raises(ValueError, match="sample_stack_masks"):
            rnn.run_stack(params, x, plan, 0.125)

    def test_backend_validation(self):
        params = rnn.init_stack(jax.random.key(0), 4, (8,))
        x = jnp.zeros((2, 3, 4))
        with pytest.raises(ValueError, match="backend"):
            rnn.run_stack(params, x, [(None, None)], 0.0, backend="bogus",
                          rows=jnp.arange(2, dtype=jnp.uint32))
        with pytest.raises(ValueError, match="rows"):
            rnn.run_stack(params, x, [(None, None)], 0.0,
                          backend="pallas_seq")

    def test_classifier_partial_bayesian_end_to_end(self):
        cfg = clf.ClassifierConfig(
            hidden=16, num_layers=3,
            mcd=mcd.MCDConfig(p=0.125, placement="YN", seed=5))
        params = clf.init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (6, 12, 1))
        rows = jnp.arange(6, dtype=jnp.uint32)
        want = clf.apply(params, x, rows, cfg)
        for be in ("pallas_step", "pallas_seq"):
            got = clf.apply(params, x, rows, cfg, backend=be)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4, atol=1e-4)


    @pytest.mark.parametrize("placement", ["YNYN", "YNNY"])
    def test_autoencoder_decoder_offset_end_to_end(self, placement):
        """Guards the decoder's layer_offset: a pallas decoder drawing the
        encoder's mask streams would diverge from the reference here."""
        cfg = ae.AutoencoderConfig(
            hidden=16, num_layers=2,
            mcd=mcd.MCDConfig(p=0.125, placement=placement, seed=7))
        params = ae.init(jax.random.key(2), cfg)
        x = jax.random.normal(jax.random.key(3), (5, 10, 1))
        rows = jnp.arange(5, dtype=jnp.uint32)
        m0, lv0 = ae.apply(params, x, rows, cfg)
        for be in ("pallas_step", "pallas_seq"):
            m, lv = ae.apply(params, x, rows, cfg, backend=be)
            np.testing.assert_allclose(np.asarray(m), np.asarray(m0),
                                       rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(np.asarray(lv), np.asarray(lv0),
                                       rtol=1e-4, atol=1e-4)


def test_gate_stacked_roundtrip():
    params = cells.init_lstm(jax.random.key(0), 5, 8)
    wx4, wh4, b = cells.gate_stacked(params)
    assert wx4.shape == (5, 4, 8) and wh4.shape == (8, 4, 8)
    np.testing.assert_array_equal(np.asarray(jnp.moveaxis(wx4, 1, 0)),
                                  np.asarray(params.wx))
    np.testing.assert_array_equal(np.asarray(b), np.asarray(params.b))

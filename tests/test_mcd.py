"""MCD semantics: the paper's §II-B invariants as property tests."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import bayesian, mcd


class TestPlacement:
    def test_parse_roundtrip(self):
        assert mcd.parse_placement("YNYN") == (True, False, True, False)
        assert mcd.placement_str((True, False)) == "YN"

    def test_cycling(self):
        cfg = mcd.MCDConfig(placement="YN")
        assert [cfg.bayesian(i) for i in range(4)] == [True, False, True, False]

    def test_empty_placement_pointwise(self):
        assert not mcd.MCDConfig(placement="").any_bayesian


class TestMasks:
    def test_tied_across_time(self):
        """One mask per sample, reused at every time step (paper §II-B)."""
        rows = jnp.arange(4, dtype=jnp.uint32)
        m1 = mcd.feature_mask(0, 1, rows, 32, 0.125)
        m2 = mcd.feature_mask(0, 1, rows, 32, 0.125)
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))

    def test_per_gate_masks_differ(self):
        rows = jnp.arange(8, dtype=jnp.uint32)
        zx, zh = mcd.lstm_gate_masks(0, 0, rows, 64, 64, 0.5)
        assert zx.shape == (8, 4, 64) and zh.shape == (8, 4, 64)
        gates = np.asarray(zx)
        for g in range(1, 4):
            assert not np.array_equal(gates[:, 0], gates[:, g])

    def test_per_sample_masks_differ(self):
        rows = jnp.arange(2, dtype=jnp.uint32)
        m = np.asarray(mcd.feature_mask(0, 0, rows, 256, 0.5))
        assert not np.array_equal(m[0], m[1])

    def test_layer_streams_differ(self):
        rows = jnp.arange(4, dtype=jnp.uint32)
        a = np.asarray(mcd.feature_mask(0, 1, rows, 256, 0.5))
        b = np.asarray(mcd.feature_mask(0, 2, rows, 256, 0.5))
        assert not np.array_equal(a, b)

    @given(p=st.sampled_from([0.1, 0.125, 0.25, 0.5]),
           seed=st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_inverted_dropout_unbiased(self, p, seed):
        """E[x ⊙ z / (1-p)] = x — the scaling contract."""
        rows = jnp.arange(4096, dtype=jnp.uint32)
        x = jnp.ones((4096, 16))
        m = mcd.feature_mask(seed, 0, rows, 16, p)
        y = mcd.apply_mask(x, m, p)
        assert abs(float(y.mean()) - 1.0) < 0.02

    def test_apply_mask_none_passthrough(self):
        x = jnp.ones((3, 5))
        np.testing.assert_array_equal(np.asarray(mcd.apply_mask(x, None, 0.5)),
                                      np.asarray(x))


class TestPredictiveEngine:
    def test_fold_equals_scan(self):
        """Folding S into batch and scanning over S draw identical masks."""
        cfg = mcd.MCDConfig(p=0.25, placement="Y", n_samples=5, seed=3)

        def apply_fn(params, x, rows):
            m = mcd.feature_mask(cfg.seed, 0, rows, x.shape[-1], cfg.p)
            return mcd.apply_mask(x, m, cfg.p) @ params

        params = jax.random.normal(jax.random.key(0), (16, 8))
        x = jax.random.normal(jax.random.key(1), (6, 16))
        a = bayesian.predict(apply_fn, params, x, cfg, strategy="fold")
        b = bayesian.predict(apply_fn, params, x, cfg, strategy="scan")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)

    def test_pointwise_single_pass(self):
        cfg = mcd.MCDConfig(p=0.25, placement="N", n_samples=7)
        out = bayesian.predict(lambda p, x, r: x, None,
                               jnp.ones((3, 2)), cfg)
        assert out.shape == (1, 3, 2)     # S collapses to 1 when pointwise

"""SSD chunked scan vs the naive per-step recurrence oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mamba2
from repro.models.config import SSMConfig


def naive_ssd(x, dt, a, bm, cm, d_skip):
    """Step-by-step h_t = exp(dt·A)·h + dt·B x ; y = C·h + D·x (oracle)."""
    B, L, H, P = x.shape
    G, N = bm.shape[2], bm.shape[3]
    rep = H // G
    h = np.zeros((B, H, P, N))
    ys = np.zeros((B, L, H, P))
    x, dt, bm, cm = map(np.asarray, (x, dt, bm, cm))
    a = np.asarray(a)
    for t in range(L):
        decay = np.exp(dt[:, t] * a)                  # [B, H]
        bh = np.repeat(bm[:, t], rep, axis=1)         # [B, H, N]
        ch = np.repeat(cm[:, t], rep, axis=1)
        upd = (dt[:, t][..., None] * x[:, t])[..., None] * bh[:, :, None, :]
        h = h * decay[..., None, None] + upd
        ys[:, t] = np.einsum("bhpn,bhn->bhp", h, ch) \
            + np.asarray(d_skip)[None, :, None] * x[:, t]
    return ys, h


@pytest.mark.parametrize("L,chunk", [(16, 4), (32, 8), (24, 16), (7, 4)])
def test_chunked_equals_naive(L, chunk):
    B, H, P, G, N = 2, 4, 8, 2, 16
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    bm = jax.random.normal(ks[3], (B, L, G, N)) * 0.3
    cm = jax.random.normal(ks[4], (B, L, G, N)) * 0.3
    d_skip = jnp.ones((H,))
    y, h_final = mamba2._ssd_chunked(x, dt, a, bm, cm, d_skip, chunk)
    y_ref, h_ref = naive_ssd(x, dt, a, bm, cm, d_skip)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_final), h_ref, rtol=2e-4,
                               atol=2e-4)


def test_decode_continues_prefill_state():
    """mamba_forward(return_state) + mamba_decode == longer mamba_forward."""
    cfg = SSMConfig(d_state=16, head_dim=8, expand=2, chunk=8)
    d_model = 32
    p = mamba2.init_mamba(jax.random.key(0), d_model, cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 13, d_model))
    full = mamba2.mamba_forward(p, x, cfg, None, 0.0, d_model)
    out12, state = mamba2.mamba_forward(p, x[:, :12], cfg, None, 0.0, d_model,
                                        return_state=True)
    np.testing.assert_allclose(np.asarray(out12), np.asarray(full[:, :12]),
                               rtol=2e-4, atol=2e-4)
    out_t, _ = mamba2.mamba_decode(p, x[:, 12:13], state, cfg, None, 0.0,
                                   d_model)
    np.testing.assert_allclose(np.asarray(out_t), np.asarray(full[:, 12:13]),
                               rtol=3e-4, atol=3e-4)

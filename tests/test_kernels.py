"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cells, mcd
from repro.kernels import bernoulli_mask, mcd_lstm, mcd_matmul, ops, ref

KEY = mcd.mask_key(7, 3, mcd.KIND_FEAT, 1)


class TestBernoulliMaskKernel:
    @pytest.mark.parametrize("shape,blocks", [
        ((32, 128), (32, 128)),
        ((64, 256), (16, 64)),
        ((128, 512), (32, 128)),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("p", [0.125, 0.5])
    def test_matches_ref_exactly(self, shape, blocks, dtype, p):
        rows = jnp.arange(shape[0], dtype=jnp.uint32) + 17
        x = jax.random.normal(jax.random.key(0), shape, dtype)
        out = bernoulli_mask.masked_activation(
            x, rows, KEY, p, block_b=blocks[0], block_f=blocks[1])
        expect = ref.masked_activation(x, rows, KEY, p)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))

    def test_tiling_invariance(self):
        """Same bits regardless of block decomposition (counter-PRNG law)."""
        rows = jnp.arange(64, dtype=jnp.uint32)
        x = jnp.ones((64, 256), jnp.float32)
        a = bernoulli_mask.masked_activation(x, rows, KEY, 0.25,
                                             block_b=64, block_f=256)
        b = bernoulli_mask.masked_activation(x, rows, KEY, 0.25,
                                             block_b=16, block_f=64)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestMcdMatmulKernel:
    @pytest.mark.parametrize("m,k,n,bm,bk,bn", [
        (32, 64, 32, 32, 64, 32),
        (64, 256, 128, 32, 64, 64),
        (128, 128, 256, 64, 128, 128),
    ])
    @pytest.mark.parametrize("p", [0.0, 0.125])
    def test_matches_ref(self, m, k, n, bm, bk, bn, p):
        rows = jnp.arange(m, dtype=jnp.uint32)
        x = jax.random.normal(jax.random.key(1), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.key(2), (k, n), jnp.float32)
        out = mcd_matmul.mcd_matmul(x, w, rows, KEY, p, block_m=bm,
                                    block_n=bn, block_k=bk)
        expect = ref.mcd_matmul(x, w, rows, KEY, p)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        rows = jnp.arange(32, dtype=jnp.uint32)
        x = jax.random.normal(jax.random.key(1), (32, 64), jnp.bfloat16)
        w = jax.random.normal(jax.random.key(2), (64, 32), jnp.bfloat16)
        out = mcd_matmul.mcd_matmul(x, w, rows, KEY, 0.125,
                                    block_m=32, block_n=32, block_k=64)
        expect = ref.mcd_matmul(x, w, rows, KEY, 0.125)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect, np.float32),
                                   rtol=3e-2, atol=3e-2)


class TestMcdLstmKernel:
    @pytest.mark.parametrize("b,i,h,bb,bh", [
        (8, 32, 32, 8, 32),
        (16, 96, 64, 8, 32),
        (32, 64, 128, 16, 64),
    ])
    @pytest.mark.parametrize("p", [0.0, 0.125, 0.5])
    def test_matches_ref(self, b, i, h, bb, bh, p):
        ks = jax.random.split(jax.random.key(0), 6)
        x = jax.random.normal(ks[0], (b, i))
        hh = jax.random.normal(ks[1], (b, h))
        c = jax.random.normal(ks[2], (b, h))
        wx = jax.random.normal(ks[3], (i, 4, h)) * 0.1
        wh = jax.random.normal(ks[4], (h, 4, h)) * 0.1
        bias = jax.random.normal(ks[5], (4, h)) * 0.1
        rows = jnp.arange(b, dtype=jnp.uint32)
        keys = mcd_lstm.gate_keys(11, 2)
        hk, ck = mcd_lstm.mcd_lstm_step(x, hh, c, wx, wh, bias, rows, keys, p,
                                        block_b=bb, block_h=bh)
        hr, cr = ref.mcd_lstm_step(x, hh, c, wx, wh, bias, rows, keys, p)
        np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(ck), np.asarray(cr),
                                   rtol=1e-5, atol=1e-5)

    def test_odd_batch_pads_to_block(self):
        """B that block_b doesn't divide (e.g. a ragged session batch) pads
        to the block multiple instead of failing the old divisibility
        assert — same fallback as the sequence kernel."""
        b, i, h = 13, 16, 16
        ks = jax.random.split(jax.random.key(2), 6)
        x = jax.random.normal(ks[0], (b, i))
        hh = jax.random.normal(ks[1], (b, h))
        c = jax.random.normal(ks[2], (b, h))
        wx = jax.random.normal(ks[3], (i, 4, h)) * 0.1
        wh = jax.random.normal(ks[4], (h, 4, h)) * 0.1
        bias = jax.random.normal(ks[5], (4, h)) * 0.1
        rows = jnp.arange(b, dtype=jnp.uint32)
        keys = mcd_lstm.gate_keys(11, 2)
        hk, ck = mcd_lstm.mcd_lstm_step(x, hh, c, wx, wh, bias, rows, keys,
                                        0.125, block_b=4, block_h=16)
        assert hk.shape == (b, h) and ck.shape == (b, h)
        hr, cr = ref.mcd_lstm_step(x, hh, c, wx, wh, bias, rows, keys, 0.125)
        np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(ck), np.asarray(cr),
                                   rtol=1e-5, atol=1e-5)

    def test_fused_layer_equals_core_path(self):
        """Kernel scan over T == repro.core cells path, mask streams and all."""
        B, T, I, H = 8, 6, 48, 32
        ks = jax.random.split(jax.random.key(1), 4)
        wx = jax.random.normal(ks[0], (I, 4, H)) * 0.1
        wh = jax.random.normal(ks[1], (H, 4, H)) * 0.1
        bias = jnp.zeros((4, H))
        x_seq = jax.random.normal(ks[2], (B, T, I))
        rows = jnp.arange(B, dtype=jnp.uint32)
        _, (hT, _) = ops.fused_lstm_layer(wx, wh, bias, x_seq, rows, 11, 2,
                                          0.125)
        zx, zh = mcd.lstm_gate_masks(11, 2, rows, I, H, 0.125)
        params = cells.LSTMParams(wx=jnp.moveaxis(wx, 1, 0),
                                  wh=jnp.moveaxis(wh, 1, 0), b=bias)
        h = jnp.zeros((B, H))
        c = jnp.zeros((B, H))
        for t in range(T):
            h, c = cells.lstm_step(params, h, c, x_seq[:, t], zx, zh, 0.125)
        np.testing.assert_allclose(np.asarray(hT), np.asarray(h),
                                   rtol=1e-4, atol=1e-4)


@given(p=st.sampled_from([0.1, 0.25, 0.5]), seed=st.integers(0, 100))
@settings(max_examples=8, deadline=None)
def test_kernel_mask_rate_property(p, seed):
    """Kernel-generated masks hit the Bernoulli keep rate."""
    rows = jnp.arange(128, dtype=jnp.uint32)
    key = mcd.mask_key(seed, 0, mcd.KIND_FEAT, 0)
    x = jnp.ones((128, 512), jnp.float32)
    out = bernoulli_mask.masked_activation(x, rows, key, p)
    keep = float((np.asarray(out) != 0).mean())
    assert abs(keep - (1.0 - p)) < 0.03

"""Cross-backend × precision conformance suite (ISSUE 6 acceptance).

The parametrized fixture the tentpole is pinned by: reference == pallas_step
== pallas_seq **bit-identically** over (cell × precision × lengths ×
carried-state).  Quantized serving is only trustworthy because the jnp
fake-quant oracle and the in-kernel dequant provably agree — these tests are
that proof, re-run on every change.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import conformance
from repro.core import rnn
from repro.kernels import quantize

B, T, IN_DIM = 6, 11, 5
HIDDENS = (16, 16)


def _x(key=1):
    return jax.random.normal(jax.random.key(key), (B, T, IN_DIM),
                             jnp.float32)


@pytest.mark.parametrize("cell", ("lstm", "gru"))
@pytest.mark.parametrize("precision", conformance.PRECISIONS)
class TestCrossBackend:
    """One class = one (cell, precision) cell of the conformance matrix."""

    def test_full_length(self, cell, precision):
        cfg, params = conformance.make_stack(cell, HIDDENS, IN_DIM,
                                             placement="YY")
        results = conformance.run_all_backends(params, _x(), cfg, HIDDENS,
                                               cell=cell, precision=precision)
        conformance.assert_backends_identical(
            results, f"{cell}/{precision}/full")

    def test_ragged_lengths(self, cell, precision):
        cfg, params = conformance.make_stack(cell, HIDDENS, IN_DIM,
                                             placement="YY")
        lens = jnp.array([11, 3, 7, 11, 1, 5], jnp.int32)
        results = conformance.run_all_backends(params, _x(), cfg, HIDDENS,
                                               cell=cell, precision=precision,
                                               lengths=lens)
        conformance.assert_backends_identical(
            results, f"{cell}/{precision}/ragged")

    def test_carried_state(self, cell, precision):
        """A reference warmup chunk's carry resumes identically everywhere —
        the snapshot/restore shape of the invariant (a carry produced by one
        backend must be consumable by any other)."""
        cfg, params = conformance.make_stack(cell, HIDDENS, IN_DIM,
                                             placement="YY")
        x = _x()
        rows = jnp.arange(B, dtype=jnp.uint32)
        warm_masks = conformance.stack_masks(cfg, rows, IN_DIM, HIDDENS,
                                             "reference", cell=cell,
                                             precision=precision)
        _, carry = rnn.run_stack(params, x[:, :4], warm_masks, cfg.p,
                                 rows=rows, seed=cfg.seed,
                                 lengths=jnp.full((B,), 4, jnp.int32),
                                 return_all_states=True, cell=cell,
                                 precision=precision)
        results = conformance.run_all_backends(params, x[:, 4:], cfg,
                                               HIDDENS, cell=cell,
                                               precision=precision,
                                               initial_state=carry)
        conformance.assert_backends_identical(
            results, f"{cell}/{precision}/carried")

    def test_chunked_equals_unchunked(self, cell, precision):
        """pallas_seq chunk boundaries are invisible at every precision."""
        cfg, params = conformance.make_stack(cell, HIDDENS, IN_DIM,
                                             placement="YY")
        x = _x()
        rows = jnp.arange(B, dtype=jnp.uint32)
        plan = rnn.stack_mask_plan(cfg, len(HIDDENS))

        def step(x_chunk, state):
            return rnn.run_stack(
                params, x_chunk, plan, cfg.p, backend="pallas_seq",
                rows=rows, seed=cfg.seed, initial_state=state,
                lengths=jnp.full((B,), x_chunk.shape[1], jnp.int32),
                return_all_states=True, cell=cell, precision=precision)

        full, st_full = step(x, None)
        outs, st = conformance.chunked_run(step, x, [4, 1, 6])
        np.testing.assert_array_equal(np.asarray(outs, np.float32),
                                      np.asarray(full, np.float32))
        conformance.assert_states_equal(st, st_full,
                                        f"{cell}/{precision}/chunked")


class TestPrecisionContracts:
    """Dtype / validation behavior of the precision knob itself."""

    def test_carry_dtypes(self):
        cfg, params = conformance.make_stack("lstm", HIDDENS, IN_DIM)
        for precision, h_dtype in (("bf16", jnp.bfloat16),
                                   ("int8", jnp.bfloat16),
                                   ("fp32", jnp.float32)):
            results = conformance.run_all_backends(
                params, _x(), cfg, HIDDENS, precision=precision)
            for backend, (out, states) in results.items():
                assert out.dtype == h_dtype, (backend, precision)
                for h, c in states:
                    assert h.dtype == h_dtype, (backend, precision)
                    # 32-bit cell-state policy holds on *every* backend
                    assert c.dtype == jnp.float32, (backend, precision)

    def test_unknown_precision_rejected(self):
        cfg, params = conformance.make_stack("lstm", HIDDENS, IN_DIM)
        x = _x()
        rows = jnp.arange(B, dtype=jnp.uint32)
        masks = rnn.sample_stack_masks(cfg, rows, IN_DIM, HIDDENS)
        with pytest.raises(ValueError, match="precision"):
            rnn.run_stack(params, x, masks, cfg.p, precision="int2")

    def test_quantized_weights_actually_quantize(self):
        """int4 must change the numbers (a no-op fake-quant would pass every
        equality test above) while staying within the per-channel bound."""
        cfg, params = conformance.make_stack("lstm", HIDDENS, IN_DIM)
        x = _x()
        r_fp, _ = conformance.run_all_backends(
            params, x, cfg, HIDDENS, precision="fp32")["reference"]
        r_i4, _ = conformance.run_all_backends(
            params, x, cfg, HIDDENS, precision="int4")["reference"]
        assert not np.array_equal(np.asarray(r_fp, np.float32),
                                  np.asarray(r_i4, np.float32))
        # and the weights the oracle would serve match quantize.fake_quant
        lp = params[0]
        fq = quantize.fake_quant(lp.wx, "int4", axis=1,
                                 act_dtype=jnp.float32)
        q, s = quantize.quantize(lp.wx, 4, axis=1)
        np.testing.assert_array_equal(
            np.asarray(fq), np.asarray(quantize.dequantize(q, s, axis=1)))

"""Per-session dynamic S + early-exit adaptive sampling (ISSUE 9).

The MC-chain count S is session state, not an engine constant: sessions
can open below the engine ceiling, and with ``early_exit_threshold`` set
the engine retires a converged session's surplus chains mid-stream
(prefix-trim only — surviving chains keep their mask rows and carries).

The invariants pinned here:

* **Ragged-layout identity** — a tick mixing per-session chain counts
  produces, for every session, exactly the outputs that session gets
  served alone (batch composition stays invisible, now including the
  chain dimension), on all three backends and both cells, chunked and
  unchunked.
* **Retirement behaviour** — with ``threshold=0.0`` a provably-converged
  (flatline) stream steps down to the ``min_samples`` floor one halving
  per tick, a random stream keeps every chain, and retained sessions'
  outputs never move.
* **Durability** — per-session S survives kill→snapshot→restore (live
  sessions and queued tickets alike) and the resumed engine continues
  bit-identically.
* **Observability** — ``active_chains``/``reclaimed_rows`` ride
  ``TickMetrics`` through the JSONL sink and ``summarize()``, per-tenant
  in fleet mode.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autoencoder as ae, classifier as clf, mcd
from repro.serve import (FleetEngine, JsonlSink, SessionStore,
                         StreamingEngine, TenantSpec, summarize)

BACKENDS = ("reference", "pallas_step", "pallas_seq")


def _clf_cfg(s=8, seed=3, cell="lstm"):
    return clf.ClassifierConfig(
        hidden=8, num_layers=2, num_classes=4, cell=cell,
        mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=s, seed=seed))


def _clf_engine(s=8, cell="lstm", **kw):
    cfg = _clf_cfg(s=s, cell=cell)
    params = clf.init(jax.random.key(0), cfg)
    return StreamingEngine(params, cfg, **kw), params, cfg


def _ae_engine(s=8, **kw):
    cfg = ae.AutoencoderConfig(
        hidden=8, num_layers=1,
        mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=s, seed=1))
    params = ae.init(jax.random.key(0), cfg)
    return StreamingEngine(params, cfg, **kw), params, cfg


def _sig(key, t):
    return jax.random.normal(jax.random.key(key), (t, 1))


class TestStoreRetire:
    def test_retire_prefix_trims_rows_and_state(self):
        store = SessionStore(n_samples=6, seed=0)
        sess = store.admit("a")
        rows_before = np.asarray(sess.rows).copy()
        sess.state = [(np.arange(12.0).reshape(6, 2),
                       np.arange(12.0).reshape(6, 2) + 100)]
        assert store.retire("a", 4) == 2
        np.testing.assert_array_equal(np.asarray(sess.rows),
                                      rows_before[:4])
        assert sess.state[0][0].shape == (4, 2)
        np.testing.assert_array_equal(sess.state[0][1],
                                      np.arange(8.0).reshape(4, 2) + 100)
        assert store.retire("a", 4) == 0          # no-op at current size
        with pytest.raises(ValueError, match="keep"):
            store.retire("a", 5)                  # chains never come back
        with pytest.raises(ValueError, match="keep"):
            store.retire("a", 0)

    def test_admit_below_ceiling_and_bounds(self):
        store = SessionStore(n_samples=8, seed=0)
        assert store.admit("lo", n_samples=3).rows.shape[0] == 3
        assert store.active_chains == 3
        with pytest.raises(ValueError, match="ceiling"):
            store.admit("hi", n_samples=9)
        with pytest.raises(ValueError, match="floor"):
            store.admit("zero", n_samples=0)

    def test_engine_validation(self):
        with pytest.raises(ValueError, match="threshold"):
            _clf_engine(early_exit_threshold=-0.5)
        with pytest.raises(ValueError, match="min_samples"):
            _clf_engine(s=4, min_samples=5)
        with pytest.raises(ValueError, match="min_samples"):
            _clf_engine(min_samples=0)


class TestRaggedLayoutIdentity:
    """Mixed chain counts in one tick change nothing for any session."""

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("cell", ("lstm", "gru"))
    def test_cobatched_mixed_s_equals_sequential(self, backend, cell):
        """Same engine geometry, same admission order (so identical mask
        rows): serving a full-S and a reduced-S session in one ragged
        tick == serving each in its own tick, bit-identically."""
        T = 9
        sig_a, sig_b = _sig(1, T), _sig(2, T)
        eng, params, cfg = _clf_engine(s=6, cell=cell, backend=backend,
                                       max_sessions=2)
        eng.open_session("a")                     # rows [0..5]
        eng.open_session("b", n_samples=2)        # rows [6, 7]
        both = eng.step({"a": sig_a, "b": sig_b})

        solo = StreamingEngine(params, cfg, backend=backend, max_sessions=2)
        solo.open_session("a")
        solo.open_session("b", n_samples=2)
        ra = solo.step({"a": sig_a})["a"]
        rb = solo.step({"b": sig_b})["b"]
        for got, want in ((both["a"], ra), (both["b"], rb)):
            np.testing.assert_array_equal(np.asarray(got.summary.probs),
                                          np.asarray(want.summary.probs))
            np.testing.assert_array_equal(
                np.asarray(got.summary.mutual_information),
                np.asarray(want.summary.mutual_information))

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_chunked_mixed_s_equals_unchunked(self, backend):
        """Chunk boundaries stay invisible when the co-batch is ragged in
        the chain dimension too."""
        T = 11
        sig_a, sig_b = _sig(3, T), _sig(4, T)

        def serve(splits):
            eng, _, _ = _clf_engine(s=5, backend=backend, max_sessions=2)
            eng.open_session("a")
            eng.open_session("b", n_samples=2)
            out = {}
            lo = 0
            for n in splits:
                out = eng.step({"a": sig_a[lo:lo + n],
                                "b": sig_b[lo:lo + n]})
                lo += n
            return out

        whole = serve([T])
        for splits in ([4, 7], [1] * T, [2, 1, 8]):
            split = serve(splits)
            for sid in ("a", "b"):
                np.testing.assert_array_equal(
                    np.asarray(split[sid].summary.probs),
                    np.asarray(whole[sid].summary.probs))

    def test_uniform_below_ceiling_equals_lower_ceiling_engine(self):
        """Every session at S' < ceiling is byte-identical to an engine
        whose ceiling *is* S' — the rows allocator hands out the same ids
        in admission order, so the Bayesian draw matches exactly."""
        T = 7
        sig_a, sig_b = _sig(5, T), _sig(6, T)
        hi, _, _ = _clf_engine(s=8, max_sessions=2)
        hi.open_session("a", n_samples=3)
        hi.open_session("b", n_samples=3)
        out_hi = hi.step({"a": sig_a, "b": sig_b})

        lo, _, _ = _clf_engine(s=3, max_sessions=2)
        lo.open_session("a")
        lo.open_session("b")
        out_lo = lo.step({"a": sig_a, "b": sig_b})
        for sid in ("a", "b"):
            np.testing.assert_array_equal(
                np.asarray(out_hi[sid].summary.probs),
                np.asarray(out_lo[sid].summary.probs))


class TestRetirementBehaviour:
    def test_flatline_halves_to_floor_random_keeps_all(self):
        """threshold=0.0: a flatline stream (identical chains — zero
        input × zero-init biases keeps every activation 0) halves once
        per tick down to the floor; a random stream keeps every chain."""
        eng, _, _ = _clf_engine(s=8, max_sessions=2,
                                early_exit_threshold=0.0, min_samples=2)
        eng.open_session("hard")
        eng.open_session("easy")
        hard = _sig(7, 24) * 3
        expect_easy = [4, 2, 2]                   # 8 -> 4 -> 2, then floor
        for t in range(3):
            eng.step({"easy": jnp.zeros((8, 1)),
                      "hard": hard[8 * t:8 * (t + 1)]})
            assert int(eng.store.get("easy").rows.shape[0]) == \
                expect_easy[t]
            assert int(eng.store.get("hard").rows.shape[0]) == 8
        assert sum(m.reclaimed_rows for m in eng.metrics) == 6
        assert eng.store.active_chains == 10

    def test_retained_stream_outputs_never_move(self):
        """A neighbour's retirement must not perturb a retained stream:
        per-chunk summaries match a no-early-exit engine bit-exactly."""
        T, chunk = 16, 4
        hard = _sig(8, T)
        plain, params, cfg = _clf_engine(s=8, max_sessions=2)
        plain.open_session("hard")
        plain.open_session("easy")
        eng = StreamingEngine(params, cfg, max_sessions=2,
                              early_exit_threshold=0.0, min_samples=1)
        eng.open_session("hard")
        eng.open_session("easy")
        for lo in range(0, T, chunk):
            zeros = jnp.zeros((chunk, 1))
            want = plain.step({"hard": hard[lo:lo + chunk],
                               "easy": zeros})["hard"]
            got = eng.step({"hard": hard[lo:lo + chunk],
                            "easy": zeros})["hard"]
            np.testing.assert_array_equal(np.asarray(got.summary.probs),
                                          np.asarray(want.summary.probs))
        assert int(eng.store.get("easy").rows.shape[0]) == 1
        assert int(plain.store.get("easy").rows.shape[0]) == 8

    def test_autoencoder_flatline_retires(self):
        eng, _, _ = _ae_engine(s=8, max_sessions=1,
                               early_exit_threshold=0.0, min_samples=2)
        eng.open_session("z")
        for _ in range(3):
            eng.step({"z": jnp.zeros((5, 1))})
        assert int(eng.store.get("z").rows.shape[0]) == 2

    def test_min_samples_floor_binds_mid_halving(self):
        """floor=3: 8 -> 4 -> 3 (the second stage clamps to the floor,
        not to ceil(4/2)=2)."""
        eng, _, _ = _clf_engine(s=8, max_sessions=1,
                                early_exit_threshold=0.0, min_samples=3)
        eng.open_session("z")
        sizes = []
        for _ in range(3):
            eng.step({"z": jnp.zeros((4, 1))})
            sizes.append(int(eng.store.get("z").rows.shape[0]))
        assert sizes == [4, 3, 3]

    def test_threshold_disabled_never_retires(self):
        eng, _, _ = _clf_engine(s=4, max_sessions=1)
        eng.open_session("z")
        for _ in range(3):
            eng.step({"z": jnp.zeros((4, 1))})
        assert int(eng.store.get("z").rows.shape[0]) == 4
        assert all(m.reclaimed_rows == 0 for m in eng.metrics)
        assert all(m.active_chains == 4 for m in eng.metrics)


class TestShardingGuards:
    def test_mesh_refuses_early_exit(self):
        from repro.launch.mesh import make_data_mesh
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        with pytest.raises(ValueError, match="shard"):
            StreamingEngine(params, cfg, mesh=make_data_mesh(1),
                            early_exit_threshold=0.0)


class TestDurability:
    def test_per_session_s_roundtrips_through_snapshot(self, tmp_path):
        """Kill→snapshot→restore with a retired session: the reduced S
        survives, and the resumed engine continues bit-identically to an
        uninterrupted one."""
        T, chunk = 16, 4
        hard = _sig(9, T)

        def open_serve(eng, lo, hi, out=None):
            for a in range(lo, hi, chunk):
                out = eng.step({"hard": hard[a:a + chunk],
                                "easy": jnp.zeros((chunk, 1))})
            return out

        kw = dict(max_sessions=2, early_exit_threshold=0.0, min_samples=2)
        gold, params, cfg = _clf_engine(s=8, **kw)
        gold.open_session("hard")
        gold.open_session("easy")
        final_gold = open_serve(gold, 0, T)

        victim = StreamingEngine(params, cfg, **kw)
        victim.open_session("hard")
        victim.open_session("easy")
        open_serve(victim, 0, T // 2)
        assert int(victim.store.get("easy").rows.shape[0]) == 2
        victim.snapshot(str(tmp_path))
        del victim

        revived = StreamingEngine(params, cfg, **kw)
        revived.restore(str(tmp_path))
        sess = revived.store.get("easy")
        assert int(sess.rows.shape[0]) == 2       # reduced S survived
        np.testing.assert_array_equal(np.asarray(sess.rows), [8, 9])
        final_res = open_serve(revived, T // 2, T)
        for sid in ("hard", "easy"):
            np.testing.assert_array_equal(
                np.asarray(final_res[sid].summary.probs),
                np.asarray(final_gold[sid].summary.probs))

    def test_queued_ticket_n_samples_survives_snapshot(self, tmp_path):
        eng, params, cfg = _clf_engine(s=8, max_sessions=1)
        eng.open_session("live")
        assert eng.admit("waiting", n_samples=3) is None   # queued
        eng.step({"live": jnp.ones((2, 1))})
        eng.snapshot(str(tmp_path))
        revived = StreamingEngine(params, cfg, max_sessions=1)
        revived.restore(str(tmp_path))
        revived.close_session("live")              # frees the row quota
        revived.step({})                           # drain tick
        assert int(revived.store.get("waiting").rows.shape[0]) == 3


class TestMetricsThreading:
    def test_jsonl_sink_carries_chain_fields(self, tmp_path):
        path = str(tmp_path / "ticks.jsonl")
        sink = JsonlSink(path)
        eng, _, _ = _clf_engine(s=8, max_sessions=1, metrics_sink=sink,
                                early_exit_threshold=0.0, min_samples=2)
        eng.open_session("z")
        for _ in range(2):
            eng.step({"z": jnp.zeros((4, 1))})
        sink.close()
        recs = [json.loads(ln) for ln in open(path)]
        assert [r["active_chains"] for r in recs] == [4, 2]
        assert [r["reclaimed_rows"] for r in recs] == [4, 2]
        agg = summarize(eng.metrics)
        assert agg["reclaimed_rows"] == 6
        assert agg["active_chains_mean"] == pytest.approx(3.0)

    def test_fleet_metrics_attribute_per_tenant(self, tmp_path):
        """Two tenants in one launch group, only one with early exit off
        the floor: the reclaimed rows land on the right tenant's records
        and in its summarize() sub-block."""
        cfg = _clf_cfg(s=4)
        params = clf.init(jax.random.key(0), cfg)
        path = str(tmp_path / "fleet.jsonl")
        sink = JsonlSink(path)
        fleet = FleetEngine([
            TenantSpec(name="adaptive", cfg=cfg, params=params,
                       early_exit_threshold=0.0, min_samples=1),
            TenantSpec(name="fixed", cfg=cfg, params=params),
        ], metrics_sink=sink)
        assert len(fleet.groups) == 2              # thresholds split groups
        fleet.admit("adaptive", "p")
        fleet.admit("fixed", "p")
        for _ in range(2):
            fleet.step({"adaptive": {"p": jnp.zeros((3, 1))},
                        "fixed": {"p": jnp.zeros((3, 1))}})
        sink.close()
        eng = fleet.group_of("adaptive").engine
        assert int(eng.store.get("adaptive/p").rows.shape[0]) == 1
        fixed_eng = fleet.group_of("fixed").engine
        assert int(fixed_eng.store.get("fixed/p").rows.shape[0]) == 4
        per_tenant = {}
        for ln in open(path):
            r = json.loads(ln)
            if r.get("tenant"):
                per_tenant.setdefault(r["tenant"], []).append(r)
        assert sum(r["reclaimed_rows"]
                   for r in per_tenant["adaptive"]) == 3
        assert all(r["reclaimed_rows"] == 0 for r in per_tenant["fixed"])
        agg = summarize(fleet.metrics)
        assert agg["tenants"]["adaptive"]["reclaimed_rows"] == 3
        assert agg["tenants"]["fixed"]["reclaimed_rows"] == 0


class TestFleetDynamicS:
    def test_shared_group_tenants_open_at_their_own_s(self):
        """Tenants differing only in S fold into one group (signature
        drops S when unsharded); each opens sessions at its own S and the
        outputs match a dedicated engine bit-exactly."""
        cfg = _clf_cfg(s=6)
        params = clf.init(jax.random.key(0), cfg)
        fleet = FleetEngine([
            TenantSpec(name="big", cfg=cfg, params=params),
            TenantSpec(name="small", cfg=cfg, params=params, n_samples=2),
        ])
        assert len(fleet.groups) == 1
        eng = fleet.group_of("big").engine
        assert eng.n_samples == 6
        fleet.admit("big", "p")
        fleet.admit("small", "p")
        sig = _sig(11, 6)
        out = fleet.step({"big": {"p": sig}, "small": {"p": sig}})
        solo = StreamingEngine(params, cfg, max_sessions=2)
        solo.open_session("big/p")
        solo.open_session("small/p", n_samples=2)
        want = solo.step({"big/p": sig, "small/p": sig})
        for tenant in ("big", "small"):
            np.testing.assert_array_equal(
                np.asarray(out[tenant]["p"].summary.probs),
                np.asarray(want[f"{tenant}/p"].summary.probs))

    def test_reconfigure_never_resurrects_retired_chains(self):
        """Downshift + upshift round-trip: a session that early-exited
        below the old ceiling keeps its reduced S; sessions at the old
        ceiling track the new one."""
        from repro.serve import ServingConfig
        cfg = _clf_cfg(s=8)
        params = clf.init(jax.random.key(0), cfg)
        fleet = FleetEngine([
            TenantSpec(name="t", cfg=cfg, params=params,
                       early_exit_threshold=0.0, min_samples=2),
        ])
        fleet.admit("t", "easy")
        fleet.admit("t", "hard")
        hard = _sig(12, 8) * 3
        fleet.step({"t": {"easy": jnp.zeros((8, 1)), "hard": hard}})
        store = fleet.group_of("t").engine.store
        assert int(store.get("t/easy").rows.shape[0]) == 4
        assert int(store.get("t/hard").rows.shape[0]) == 8
        fleet.reconfigure_tenant("t", ServingConfig(n_samples=6))
        store = fleet.group_of("t").engine.store
        assert int(store.get("t/hard").rows.shape[0]) == 6   # at ceiling
        assert int(store.get("t/easy").rows.shape[0]) == 4   # untouched
        fleet.reconfigure_tenant("t", ServingConfig(n_samples=8))
        store = fleet.group_of("t").engine.store
        assert int(store.get("t/hard").rows.shape[0]) == 8   # tracks up
        assert int(store.get("t/easy").rows.shape[0]) == 4   # never back

"""Multi-tenant fleet serving: heterogeneity, weighted fairness, durability.

The ISSUE 8 acceptance pins live here:

* **Heterogeneity** (``TestHeterogeneityPin``): a fleet tick serving >= 2
  tenants with different (cell, H, S, precision) is bit-identical, per
  session, to each tenant served alone in a single-tenant
  ``StreamingEngine`` from the same carried state — across backends, chunk
  splits, and a fleet kill -> snapshot -> restore in the middle of a
  stream.  This is PR 2/6's batch-composition + chunk-split invariance
  promoted to the tenant level: a shared launch group is *the same* batched
  launch a solo engine would run, just with more rows.
* **Fairness** (``TestWeightedFairness``): under sustained overload the
  admitted-capacity shares converge to the tenant weights, order within a
  tenant stays FIFO, and the aging guard un-starves a low-weight tenant
  that the stride pick alone would leave queued (skewed ledger +
  replenishing backlog — the scenario where pure stride scheduling fails).
* **Observability** (``TestPerTenantObservability``): every fleet tick
  lands one tenant-tagged ``TickMetrics`` per involved tenant; per-tenant
  ``queue_wait_s``/``dropped`` read off ``summarize()["tenants"]`` and the
  JSONL trail.
"""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autoencoder as ae, classifier as clf, mcd
from repro.serve import (CapacityError, FleetController, FleetEngine,
                         JsonlSink, QueueFull, SLOPolicy, SessionStore,
                         StreamingEngine, TenantSpec, TickMetrics,
                         WeightedFairQueue, load_fleet_meta, summarize)

BACKENDS = ("reference", "pallas_step", "pallas_seq")


def _clf_cfg(s=3, seed=3, hidden=8, cell="lstm"):
    return clf.ClassifierConfig(
        hidden=hidden, num_layers=2, num_classes=4, cell=cell,
        mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=s, seed=seed))


def _ae_cfg(s=2, seed=1, hidden=8, cell="gru"):
    return ae.AutoencoderConfig(
        hidden=hidden, num_layers=1, cell=cell,
        mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=s, seed=seed))


def _two_tenant_fleet(backend, **kw):
    """An LSTM classifier + a GRU autoencoder: different cell, task, S."""
    cfg_w = _clf_cfg()
    cfg_a = _ae_cfg()
    p_w = clf.init(jax.random.key(0), cfg_w)
    p_a = ae.init(jax.random.key(1), cfg_a)
    fleet = FleetEngine([
        TenantSpec(name="ward", cfg=cfg_w, params=p_w, weight=3.0,
                   max_sessions=4, backend=backend),
        TenantSpec(name="anom", cfg=cfg_a, params=p_a, weight=1.0,
                   max_sessions=4, backend=backend),
    ], **kw)
    return fleet, (cfg_w, p_w), (cfg_a, p_a)


class TestTenantSpecAndGrouping:
    def test_spec_validation(self):
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        with pytest.raises(ValueError, match="/"):
            TenantSpec(name="a/b", cfg=cfg, params=params)
        with pytest.raises(ValueError, match="weight"):
            TenantSpec(name="a", cfg=cfg, params=params, weight=0.0)
        with pytest.raises(TypeError, match="config"):
            TenantSpec(name="a", cfg=object(), params=params)
        with pytest.raises(ValueError, match="duplicate"):
            FleetEngine([TenantSpec(name="a", cfg=cfg, params=params),
                         TenantSpec(name="a", cfg=cfg, params=params)])
        with pytest.raises(ValueError, match="at least one"):
            FleetEngine([])

    def test_same_signature_tenants_fold_into_one_group(self):
        """Same params object + same resolved config -> one shared engine
        whose capacity is the sum of the member caps; sessions of both
        tenants co-batch without colliding (namespaced sids)."""
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        fleet = FleetEngine([
            TenantSpec(name="icu", cfg=cfg, params=params, max_sessions=2,
                       backend="reference"),
            TenantSpec(name="er", cfg=cfg, params=params, max_sessions=3,
                       backend="reference"),
        ])
        assert len(fleet.groups) == 1
        eng = fleet.group_of("icu").engine
        assert eng is fleet.group_of("er").engine
        assert eng.max_sessions == 5
        fleet.admit("icu", "p1")
        fleet.admit("er", "p1")               # same bare sid, no collision
        assert fleet.active_sessions == {"icu": ["p1"], "er": ["p1"]}
        assert sorted(eng.active_sessions) == ["er/p1", "icu/p1"]

    def test_different_signatures_get_own_groups(self):
        """Precision splits the launch group; an S override does *not* —
        S is per-session state now, so tenants differing only in S share
        one group whose engine ceiling covers the larger tenant."""
        cfg = _clf_cfg()                                  # S=3
        params = clf.init(jax.random.key(0), cfg)
        fleet = FleetEngine([
            TenantSpec(name="a", cfg=cfg, params=params, backend="reference"),
            TenantSpec(name="b", cfg=cfg, params=params, n_samples=2,
                       backend="reference"),
            TenantSpec(name="c", cfg=cfg, params=params, precision="int8",
                       backend="pallas_seq"),
        ])
        assert len(fleet.groups) == 2
        eng = fleet.group_of("b").engine
        assert eng is fleet.group_of("a").engine
        assert eng.n_samples == 3                         # group ceiling
        assert fleet.group_of("c").engine.precision == "int8"
        # Each tenant's sessions still open at the *tenant's* S.
        fleet.admit("a", "p")
        fleet.admit("b", "p")
        assert int(eng.store.get("a/p").rows.shape[0]) == 3
        assert int(eng.store.get("b/p").rows.shape[0]) == 2

    def test_per_tenant_capacity_enforced_inside_shared_group(self):
        """A tenant's own max_sessions binds even when the shared group
        store still has room for its peers."""
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        fleet = FleetEngine([
            TenantSpec(name="icu", cfg=cfg, params=params, max_sessions=1,
                       backend="reference"),
            TenantSpec(name="er", cfg=cfg, params=params, max_sessions=2,
                       backend="reference"),
        ])
        assert fleet.admit("icu", "p1") is not None
        assert fleet.admit("icu", "p2") is None          # queued, not live
        assert fleet.queue.depth_of("icu") == 1
        assert fleet.admit("er", "p1") is not None       # peer unaffected
        fleet.close("icu", "p1")                         # frees icu's slot
        assert fleet.active_sessions["icu"] == ["p2"]


class TestHeterogeneityPin:
    """The acceptance invariant: co-tenancy is invisible in the outputs."""

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_fleet_tick_bit_identical_to_solo(self, backend):
        fleet, (cfg_w, p_w), (cfg_a, p_a) = _two_tenant_fleet(backend)
        T = 8
        sig_w = jax.random.normal(jax.random.key(2), (T, 1))
        sig_a = jax.random.normal(jax.random.key(3), (T, 1))
        fleet.admit("ward", "p1")
        fleet.admit("anom", "p1")
        # Ragged fleet ticks: different split per tenant, incl. a length-1
        # chunk and a tick one tenant sits out.
        fleet.step({"ward": {"p1": sig_w[:3]}, "anom": {"p1": sig_a[:5]}})
        fleet.step({"ward": {"p1": sig_w[3:4]}})
        got = fleet.step({"ward": {"p1": sig_w[4:]},
                          "anom": {"p1": sig_a[5:]}})

        solo_w = StreamingEngine(p_w, cfg_w, backend=backend, max_sessions=1)
        solo_w.open_session("p1")
        want_w = solo_w.step({"p1": sig_w})["p1"]     # different split too
        np.testing.assert_array_equal(
            np.asarray(got["ward"]["p1"].summary.probs),
            np.asarray(want_w.summary.probs))
        np.testing.assert_array_equal(
            np.asarray(got["ward"]["p1"].summary.mutual_information),
            np.asarray(want_w.summary.mutual_information))
        assert got["ward"]["p1"].steps_total == want_w.steps_total == T

        # The AE summary is per-chunk reconstruction, so the solo run uses
        # the same final chunk boundary; the carried bottleneck it decodes
        # from integrated the stream under a *different* earlier split.
        solo_a = StreamingEngine(p_a, cfg_a, backend=backend, max_sessions=1)
        solo_a.open_session("p1")
        solo_a.step({"p1": sig_a[:5]})
        want_a = solo_a.step({"p1": sig_a[5:]})["p1"]
        np.testing.assert_array_equal(
            np.asarray(got["anom"]["p1"].summary.mean),
            np.asarray(want_a.summary.mean))
        np.testing.assert_array_equal(
            np.asarray(got["anom"]["p1"].summary.total),
            np.asarray(want_a.summary.total))

    def test_quantized_tenant_bit_identical_to_solo(self):
        """An int8 low-priority tenant next to a native one: the quantized
        group serves exactly what a solo quantized engine serves."""
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        fleet = FleetEngine([
            TenantSpec(name="hi", cfg=cfg, params=params, weight=4.0),
            TenantSpec(name="lo", cfg=cfg, params=params, weight=1.0,
                       precision="int8"),
        ])
        assert len(fleet.groups) == 2
        T = 6
        sig = jax.random.normal(jax.random.key(4), (T, 1))
        fleet.admit("hi", "s")
        fleet.admit("lo", "s")
        got = None
        for a, b in ((0, 4), (4, T)):
            got = fleet.step({"hi": {"s": sig[a:b]}, "lo": {"s": sig[a:b]}})
        for tenant, precision in (("hi", None), ("lo", "int8")):
            solo = StreamingEngine(params, cfg, max_sessions=1,
                                   precision=precision)
            solo.open_session("s")
            want = solo.step({"s": sig})["s"]
            np.testing.assert_array_equal(
                np.asarray(got[tenant]["s"].summary.probs),
                np.asarray(want.summary.probs))

    @pytest.mark.parametrize("backend", ("reference", "pallas_seq"))
    def test_kill_restore_mid_stream_bit_identical(self, backend, tmp_path):
        """snapshot -> kill -> restore into a fresh fleet between two
        chunks: the continuation is bit-identical to solo uninterrupted
        engines — for *both* heterogeneous tenants at once."""
        fleet, (cfg_w, p_w), (cfg_a, p_a) = _two_tenant_fleet(backend)
        T = 8
        sig_w = jax.random.normal(jax.random.key(5), (T, 1))
        sig_a = jax.random.normal(jax.random.key(6), (T, 1))
        fleet.admit("ward", "p1")
        fleet.admit("anom", "p1")
        fleet.step({"ward": {"p1": sig_w[:3]}, "anom": {"p1": sig_a[:3]}})
        fleet.snapshot(str(tmp_path))

        fleet2 = FleetEngine([
            TenantSpec(name="ward", cfg=cfg_w, params=p_w, weight=3.0,
                       max_sessions=4, backend=backend),
            TenantSpec(name="anom", cfg=cfg_a, params=p_a, weight=1.0,
                       max_sessions=4, backend=backend),
        ])
        fleet2.restore(str(tmp_path))
        assert fleet2.tick == fleet.tick
        got = fleet2.step({"ward": {"p1": sig_w[3:]},
                           "anom": {"p1": sig_a[3:]}})

        solo_w = StreamingEngine(p_w, cfg_w, backend=backend, max_sessions=1)
        solo_w.open_session("p1")
        solo_w.step({"p1": sig_w[:3]})
        want_w = solo_w.step({"p1": sig_w[3:]})["p1"]
        np.testing.assert_array_equal(
            np.asarray(got["ward"]["p1"].summary.probs),
            np.asarray(want_w.summary.probs))
        solo_a = StreamingEngine(p_a, cfg_a, backend=backend, max_sessions=1)
        solo_a.open_session("p1")
        solo_a.step({"p1": sig_a[:3]})
        want_a = solo_a.step({"p1": sig_a[3:]})["p1"]
        np.testing.assert_array_equal(
            np.asarray(got["anom"]["p1"].summary.mean),
            np.asarray(want_a.summary.mean))
        assert got["anom"]["p1"].steps_total == T


class TestFleetSnapshot:
    def _fleet(self, **kw):
        fleet, *_ = _two_tenant_fleet("reference", **kw)
        return fleet

    def test_one_atomic_manifest(self, tmp_path):
        fleet = self._fleet()
        fleet.admit("ward", "p1")
        fleet.step({"ward": {"p1": jnp.ones((3, 1))}})
        fleet.snapshot(str(tmp_path))
        # one committed step directory, one meta covering every group
        steps = [d for d in os.listdir(tmp_path) if d.startswith("step-")]
        assert len(steps) == 1
        meta = load_fleet_meta(str(tmp_path))
        assert meta["fleet_format"] == 1
        assert set(meta["tenants"]) == {"ward", "anom"}
        assert set(meta["groups"]) == {g.name for g in fleet.groups.values()}

    def test_queue_and_fairness_ledger_roundtrip(self, tmp_path):
        fleet = self._fleet(admit_per_tick=1)
        for i in range(3):
            fleet.admit("ward", f"w{i}")
        fleet.admit("anom", "a0", priority=2)
        fleet.step({})                          # budget 1: one admission
        ledger = fleet.queue.state()
        pending = [(t.tenant, t.sid) for t in fleet.queue.waiting()]
        assert pending                           # something is still queued
        fleet.snapshot(str(tmp_path))

        fleet2 = self._fleet(admit_per_tick=1)
        fleet2.restore(str(tmp_path))
        assert fleet2.queue.state()["admitted"] == ledger["admitted"]
        assert [(t.tenant, t.sid) for t in fleet2.queue.waiting()] == pending
        assert fleet2.active_sessions == fleet.active_sessions

    def test_restore_refuses_wrong_tenant_set(self, tmp_path):
        fleet = self._fleet()
        fleet.admit("ward", "p1")
        fleet.snapshot(str(tmp_path))
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        other = FleetEngine([TenantSpec(name="ward", cfg=cfg, params=params,
                                        backend="reference")])
        with pytest.raises(ValueError, match="tenants"):
            other.restore(str(tmp_path))

    def test_restore_refuses_mismatched_tenant_config(self, tmp_path):
        """Same tenant names but a changed S: the group's typed restore
        validation (the standalone engine's own checks) must refuse."""
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        fleet = FleetEngine([TenantSpec(name="a", cfg=cfg, params=params,
                                        backend="reference")])
        fleet.admit("a", "s")
        fleet.step({"a": {"s": jnp.ones((2, 1))}})
        fleet.snapshot(str(tmp_path))
        wrong = FleetEngine([TenantSpec(name="a", cfg=cfg, params=params,
                                        n_samples=2, backend="reference")])
        with pytest.raises(ValueError, match="chains|n_samples"):
            wrong.restore(str(tmp_path))

    def test_restore_needs_fresh_fleet(self, tmp_path):
        fleet = self._fleet()
        fleet.admit("ward", "p1")
        fleet.snapshot(str(tmp_path))
        with pytest.raises(RuntimeError, match="fresh"):
            fleet.restore(str(tmp_path))


class TestWeightedFairness:
    """WeightedFairQueue semantics + the fleet-level fairness pin."""

    def test_queue_validation(self):
        with pytest.raises(ValueError, match="/"):
            WeightedFairQueue({"a/b": 1.0})
        with pytest.raises(ValueError, match="weight"):
            WeightedFairQueue({"a": 0.0})
        with pytest.raises(ValueError, match="at least one"):
            WeightedFairQueue({})
        q = WeightedFairQueue({"a": 1.0}, max_pending=1)
        q.submit("a", "s1")
        with pytest.raises(QueueFull):
            q.submit("a", "s2")
        with pytest.raises(KeyError, match="unknown tenant"):
            q.submit("zzz", "s3")
        with pytest.raises(ValueError, match="already queued"):
            q.submit("a", "s1")

    def test_fifo_within_tenant(self):
        q = WeightedFairQueue({"a": 1.0, "b": 1.0})
        for sid in ("s1", "s2", "s3"):
            q.submit("a", sid)
        order = []
        q.drain(lambda t: order.append(t.sid), lambda n: True)
        assert order == ["s1", "s2", "s3"]
        assert q.depth == 0

    def test_rejects_do_not_consume_budget(self):
        store = SessionStore(n_samples=2, seed=7, max_sessions=4)
        poison = SessionStore(n_samples=2, seed=999).admit("a/bad")
        q = WeightedFairQueue({"a": 1.0})
        q.submit("a", "a/bad", session=poison)
        q.submit("a", "a/ok")
        from repro.serve import DrainRejected
        with pytest.raises(DrainRejected) as exc_info:
            q.drain(lambda t: (store.attach(t.session) if t.session
                               is not None else store.admit(t.sid)),
                    lambda n: True, 1)          # budget 1
        err = exc_info.value
        # the poison ticket burned no budget: the healthy one still went in
        assert [t.sid for t in err.admitted] == ["a/ok"]
        assert [t.sid for t, _ in err.rejected] == ["a/bad"]

    def test_shares_converge_to_weights_under_overload(self):
        """The fairness pin: sustained overload, weights 3:1, rate-limited
        admission -> cumulative admitted shares converge to 0.75/0.25."""
        fleet, *_ = _two_tenant_fleet(
            "reference", admit_per_tick=2, max_pending=512,
            aging_rounds=10**6)
        for i in range(100):
            fleet.admit("ward", f"w{i}")
            fleet.admit("anom", f"a{i}")
        admitted = {"ward": 0, "anom": 0}
        for _ in range(60):
            fleet.step({})
            for t in ("ward", "anom"):
                for sid in fleet.active_sessions[t]:
                    fleet.close(t, sid)
                    admitted[t] += 1
        total = sum(admitted.values())
        assert total >= 100
        assert admitted["ward"] / total == pytest.approx(0.75, abs=0.05)
        assert admitted["anom"] / total == pytest.approx(0.25, abs=0.05)
        shares = fleet.queue.shares()
        assert shares["ward"] == pytest.approx(0.75, abs=0.05)

    def test_aging_guard_prevents_starvation(self):
        """A skewed fairness ledger makes the stride pick starve the
        low-weight tenant indefinitely (its historic admitted/weight ratio
        is huge); the aging guard admits its head ticket within
        ``aging_rounds`` anyway.  With the guard effectively disabled the
        same scenario starves — proving the guard is what un-starves it."""
        def run(aging_rounds, rounds=30):
            fleet, *_ = _two_tenant_fleet(
                "reference", admit_per_tick=1, max_pending=512,
                aging_rounds=aging_rounds)
            st = fleet.queue.state()
            st["admitted"] = {"ward": 0, "anom": 1000}
            fleet.queue.load_state(st)
            fleet.admit("anom", "t0")
            k = 0
            for r in range(rounds):
                for _ in range(2):          # ward backlog replenishes
                    fleet.admit("ward", f"w{k}")
                    k += 1
                fleet.step({})
                if "t0" in fleet.active_sessions["anom"]:
                    return r
                for sid in fleet.active_sessions["ward"]:
                    fleet.close("ward", sid)
            return None

        guarded = run(aging_rounds=4)
        assert guarded is not None and guarded <= 4 + 1
        assert run(aging_rounds=10**6) is None

    def test_rate_limited_admit_only_queues(self):
        fleet, *_ = _two_tenant_fleet("reference", admit_per_tick=2)
        assert fleet.admit("ward", "p1") is None
        assert fleet.queue.depth_of("ward") == 1
        assert fleet.active_sessions["ward"] == []
        fleet.step({})
        assert fleet.active_sessions["ward"] == ["p1"]

    def test_eager_mode_admits_on_submit(self):
        fleet, *_ = _two_tenant_fleet("reference")
        sess = fleet.admit("ward", "p1")
        assert sess is not None and sess.sid == "ward/p1"
        assert fleet.close("ward", "p1").sid == "p1"   # bare sid restored


class TestPerTenantObservability:
    def test_tick_metrics_tagged_per_tenant(self):
        fleet, *_ = _two_tenant_fleet("reference")
        fleet.admit("ward", "p1")
        fleet.admit("anom", "p1")
        fleet.step({"ward": {"p1": jnp.ones((4, 1))},
                    "anom": {"p1": jnp.ones((2, 1))}})
        recs = {m.tenant: m for m in fleet.metrics}
        assert set(recs) == {"ward", "anom"}
        # per-tenant load fields are the tenant's own slice
        assert recs["ward"].n_chunks == 1 and recs["ward"].live_steps == 4
        assert recs["anom"].live_steps == 2
        s_w = fleet.group_of("ward").engine.n_samples
        assert recs["ward"].live_chain_steps == 4 * s_w
        assert recs["ward"].tick == recs["anom"].tick == 0

    def test_starving_tenant_emits_quiet_record(self):
        """A tenant with queued-but-unserved work must be visible in the
        trail of the tick it did NOT serve in."""
        fleet, *_ = _two_tenant_fleet("reference", admit_per_tick=1)
        fleet.admit("ward", "p1")
        fleet.admit("anom", "p1")
        fleet.step({})                # budget 1: one tenant stays queued
        (starved,) = [t for t in ("ward", "anom")
                      if fleet.queue.depth_of(t) == 1]
        quiet = [m for m in fleet.metrics if m.tenant == starved]
        assert len(quiet) == 1
        assert quiet[0].n_chunks == 0 and quiet[0].queue_depth == 1

    def test_dropped_lands_in_tenant_slice_and_jsonl(self, tmp_path):
        """A poison re-attach (row collision only the store can catch) is
        dropped mid-drain; the drop must surface as ``dropped`` on the
        owning tenant's next record — in memory and in the JSONL trail."""
        path = tmp_path / "fleet.jsonl"
        sink = JsonlSink(str(path))
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        fleet = FleetEngine(
            [TenantSpec(name="icu", cfg=cfg, params=params, max_sessions=2,
                        backend="reference"),
             TenantSpec(name="er", cfg=cfg, params=params, max_sessions=2,
                        backend="reference")],
            admit_per_tick=4, metrics_sink=sink)
        fleet.admit("icu", "live")
        fleet.step({})                                   # live goes in
        # collides on rows with "live" — passes the eager checks, only
        # SessionStore.attach can reject it, mid-drain
        s = cfg.mcd.n_samples
        clash = SessionStore(n_samples=s, seed=cfg.mcd.seed).admit("icu/bad")
        fleet.admit("icu", "bad", session=clash)
        fleet.step({})
        icu = [m for m in fleet.metrics if m.tenant == "icu"]
        assert icu[-1].dropped == 1
        (ticket, err), = fleet.dropped_admissions
        assert ticket.tenant == "icu" and "collide" in str(err)
        recs = [json.loads(line) for line in
                path.read_text().splitlines()]
        assert any(r["tenant"] == "icu" and r["dropped"] == 1 for r in recs)
        sink.close()

    def test_summarize_groups_by_tenant(self):
        fleet, *_ = _two_tenant_fleet("reference")
        fleet.admit("ward", "p1")
        fleet.admit("anom", "p1")
        for _ in range(3):
            fleet.step({"ward": {"p1": jnp.ones((2, 1))},
                        "anom": {"p1": jnp.ones((2, 1))}})
        agg = fleet.summarize()
        assert set(agg["tenants"]) == {"ward", "anom"}
        sub = agg["tenants"]["ward"]
        assert sub["ticks"] == 3
        assert "queue_wait_s_p95" in sub and "dropped" in sub
        assert "tenants" not in sub          # no recursive nesting
        # the roll-up across tenants still aggregates everything
        assert agg["ticks"] == 6

    def test_summarize_handles_untagged_trail(self):
        """A single-engine trail (no tenant tags) keeps the old shape."""
        m = TickMetrics(tick=0, capacity=4, n_chunks=1, live_rows=2,
                        batch_rows=2, queue_depth=0, live_steps=4,
                        live_chain_steps=8, padded_steps=8, pad_waste=0.0,
                        duration_s=0.5, tokens_per_sec=16.0)
        assert "tenants" not in summarize([m])


class TestReconfigureAndController:
    def test_reconfigure_tenant_moves_to_dedicated_group(self):
        """Downshifting one tenant of a shared group: its sessions move,
        the peer's stay; both keep serving; the row allocators of both
        stores advance past every transferred row."""
        cfg = _clf_cfg()
        params = clf.init(jax.random.key(0), cfg)
        fleet = FleetEngine([
            TenantSpec(name="icu", cfg=cfg, params=params, max_sessions=2,
                       backend="reference"),
            TenantSpec(name="er", cfg=cfg, params=params, max_sessions=2,
                       backend="reference"),
        ])
        assert len(fleet.groups) == 1
        fleet.admit("icu", "s")
        fleet.admit("er", "s")
        sig = jax.random.normal(jax.random.key(7), (6, 1))
        fleet.step({"icu": {"s": sig[:3]}, "er": {"s": sig[:3]}})

        from repro.serve import ServingConfig
        fleet.reconfigure_tenant("icu", ServingConfig(
            n_samples=2, precision=None, chunk_capacity=0))
        assert len(fleet.groups) == 2
        new_eng = fleet.group_of("icu").engine
        old_eng = fleet.group_of("er").engine
        assert new_eng is not old_eng and new_eng.n_samples == 2
        assert fleet.active_sessions == {"icu": ["s"], "er": ["s"]}
        # downshift keeps the surviving chains' carried draw: serving
        # continues from the same state in the new group
        got = fleet.step({"icu": {"s": sig[3:]}, "er": {"s": sig[3:]}})
        assert got["icu"]["s"].steps_total == 6
        assert got["er"]["s"].steps_total == 6
        # no later admission in either group can repeat a transferred row
        assert new_eng.store.next_row >= old_eng.store.next_row

    def test_fleet_controller_downshifts_breaching_tenant_only(self):
        """Synthetic sustained breach on one tenant's slice: its controller
        downshifts S via reconfigure_tenant; the unmanaged peer keeps its
        group untouched."""
        cfg_hot = _clf_cfg(s=8)
        cfg_cold = _clf_cfg(s=3, seed=11)
        p_hot = clf.init(jax.random.key(0), cfg_hot)
        p_cold = clf.init(jax.random.key(1), cfg_cold)
        fleet = FleetEngine([
            TenantSpec(name="hot", cfg=cfg_hot, params=p_hot,
                       max_sessions=4, chunk_capacity=64,
                       backend="reference",
                       slo=SLOPolicy(p95_tick_s=4e-3)),
            TenantSpec(name="cold", cfg=cfg_cold, params=p_cold,
                       max_sessions=4, backend="reference"),
        ])
        ctrl = FleetController(fleet, window=8, min_ticks=4)
        assert set(ctrl.controllers) == {"hot"}     # cold has no SLO
        cold_eng = fleet.group_of("cold").engine
        # a constant 10 ms trail on the hot tenant, well over the 4 ms SLO
        s, cap, slots = 8, 64, 4
        for i in range(8):
            live = 4 * cap * s
            fleet.metrics_sink.emit(TickMetrics(
                tick=i, capacity=cap, n_chunks=4, live_rows=4 * s,
                batch_rows=slots * s, queue_depth=0, live_steps=4 * cap,
                live_chain_steps=live, padded_steps=slots * s * cap,
                pad_waste=1.0 - live / (slots * s * cap),
                duration_s=10e-3, tokens_per_sec=live / 10e-3,
                tenant="hot"))
        recs = ctrl.maybe_reconfigure()
        assert len(recs) == 1
        rec = recs[0]
        assert rec.applied and rec.tenant == "hot"
        assert rec.winner["n_samples"] < 8
        assert fleet.group_of("hot").engine.n_samples == \
            rec.winner["n_samples"]
        assert fleet.group_of("cold").engine is cold_eng
        assert ctrl.decisions[-1].tenant == "hot"

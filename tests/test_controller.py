"""Online co-design controller: decisions, calibration, config swaps.

The ISSUE 7 acceptance invariant lives in ``TestEndToEnd``: under an
injected overload burst the controller reconfigures (>=1 DecisionRecord
with a changed config), p95 tick latency returns under the SLO within the
cooldown budget, and every session's streamed outputs across the
reconfiguration boundary are bit-identical to an uninterrupted run at the
new config from the same carried state.

The decision-logic tests run the controller *detached* (no engine) over
hand-built synthetic metrics windows — the controller cannot tell (it
reads a sink window either way), and the tests pin the policy itself:
breach → highest-quality feasible downshift, compile stall → hold,
uncertainty floor → never traded away, recovery → hysteresis-gated
upshift.
"""

import copy
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import classifier as clf, mcd
from repro.dse import calibrate
from repro.dse.fpga_model import RNNArch
from repro.serve import (CoDesignController, DecisionRecord, JsonlSink,
                         KnobSpace, ServingConfig, SimulatedLoadSink,
                         SLOPolicy, StreamingEngine, TickMetrics)
from repro.serve.controller import carry_dtypes, convert_session

ARCH = RNNArch(hidden=8, num_layers=2, placement="YN", kind="classifier",
               cell="lstm", weight_bits=32, input_dim=1, output_dim=4,
               timesteps=64)
SLOTS = 4
SLO = SLOPolicy(p95_tick_s=4e-3)


def _cfg_params(s=3, seed=3, hidden=8):
    cfg = clf.ClassifierConfig(
        hidden=hidden, num_layers=2, num_classes=4,
        mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=s, seed=seed))
    return cfg, clf.init(jax.random.key(0), cfg)


def _tick(i, dur, *, s=8, cap=64, compiles=0, n_chunks=4, queue_depth=0,
          queue_wait=0.0, slots=SLOTS):
    rows = slots * s
    live = n_chunks * cap * s
    return TickMetrics(tick=i, capacity=cap, n_chunks=n_chunks,
                       live_rows=n_chunks * s, batch_rows=rows,
                       queue_depth=queue_depth, live_steps=n_chunks * cap,
                       live_chain_steps=live, padded_steps=rows * cap,
                       pad_waste=1.0 - live / (rows * cap), duration_s=dur,
                       tokens_per_sec=live / dur, queue_wait_s=queue_wait,
                       compiles=compiles)


def _controller(slo=SLO, *, s=8, knobs=None, **kw):
    cfg = ServingConfig(n_samples=s, precision=None, chunk_capacity=64)
    kw.setdefault("window", 8)
    kw.setdefault("min_ticks", 4)
    return CoDesignController(None, slo, config=cfg, arch=ARCH, slots=SLOTS,
                              knobs=knobs, **kw)


class TestDecisionLogic:
    def test_slo_met_at_top_quality_is_noop(self):
        ctrl = _controller()
        win = [_tick(i, 1e-3) for i in range(8)]
        assert ctrl.plan(win) is None
        assert ctrl.decisions == []

    def test_too_little_history_is_noop(self):
        ctrl = _controller()
        assert ctrl.plan([_tick(i, 99.0) for i in range(3)]) is None

    def test_breach_downshifts_to_highest_feasible_quality(self):
        # Constant 10 ms ticks at S=8 against a 4 ms SLO.  The degenerate
        # (single-shape) window collapses calibration to the ratio fit, so
        # candidate S' is predicted at ~10 ms x raw(S')/raw(8): S=4 lands
        # over the 3.6 ms headroom target, S=2 under it -> the winner must
        # be S=2 (highest quality among feasible), not S=1 (fastest).
        ctrl = _controller()
        rec = ctrl.plan([_tick(i, 10e-3) for i in range(8)])
        assert rec is not None and rec.applied
        assert rec.reason == "slo-breach"
        assert rec.winner["n_samples"] == 2
        assert rec.predicted_s <= ctrl.headroom * SLO.p95_tick_s
        assert rec.fit is not None and rec.fit["n_ticks"] == 8
        # the full candidate table is in the trail, with feasibility flags
        by_s = {c["n_samples"]: c for c in rec.candidates}
        assert set(by_s) == {1, 2, 4, 8}
        assert by_s[2]["feasible"] and not by_s[4]["feasible"]
        assert not by_s[8]["feasible"]

    def test_uncertainty_floor_is_never_traded(self):
        # With min_samples=4 no candidate meets the latency target; the
        # fallback picks the fastest config that still honors the floor.
        ctrl = _controller(SLOPolicy(p95_tick_s=4e-3, min_samples=4))
        rec = ctrl.plan([_tick(i, 10e-3) for i in range(8)])
        assert rec is not None and rec.applied
        assert rec.reason == "no-feasible-fallback"
        assert rec.winner["n_samples"] == 4

    def test_compile_stall_is_not_overload(self):
        # p95 over the window breaches, but every slow tick carries fresh
        # jit entries and the compile-free ticks are comfortably under the
        # SLO: reconfiguring would only compile more.  The controller must
        # record the distinction and hold.
        ctrl = _controller(min_ticks=3)
        win = ([_tick(i, 10e-3, compiles=2) for i in range(3)]
               + [_tick(3 + i, 1e-3) for i in range(3)])
        rec = ctrl.plan(win)
        assert rec is not None and not rec.applied
        assert rec.reason == "compile-stall"
        assert rec.winner is None
        assert rec.observed["compiles"] == 6

    def test_contaminated_window_holds_too(self):
        # Compiles present and too few clean ticks to judge: the breach
        # evidence is contaminated — hold rather than downshift on it
        # (this is the boot window of every cold engine).
        ctrl = _controller()
        win = ([_tick(i, 10e-3, compiles=1) for i in range(5)]
               + [_tick(5 + i, 1e-3) for i in range(3)])
        rec = ctrl.plan(win)
        assert rec is not None and not rec.applied
        assert rec.reason == "compile-stall"

    def test_cooldown_blocks_reevaluation(self):
        ctrl = _controller(cooldown_ticks=8)
        win = [_tick(i, 10e-3) for i in range(8)]
        rec = ctrl.plan(win)
        assert rec is not None and rec.applied
        ctrl.mark_applied(rec)
        assert ctrl.config.n_samples == 2
        # still breaching, but inside the cooldown -> silence
        more = win + [_tick(8 + i, 10e-3, s=2) for i in range(5)]
        assert ctrl.plan(more) is None

    def test_window_resets_at_the_swap(self):
        # Post-apply decisions must not see pre-swap ticks: the old config
        # produced them, and a fit straddling the swap is meaningless.
        ctrl = _controller(cooldown_ticks=2)
        rec = ctrl.plan([_tick(i, 10e-3) for i in range(8)])
        ctrl.mark_applied(rec)
        assert ctrl.window_metrics(
            [_tick(i, 10e-3) for i in range(8)]
            + [_tick(8 + i, 1e-3, s=2) for i in range(4)]) \
            == [_tick(8 + i, 1e-3, s=2) for i in range(4)]

    def test_recovery_upshift_is_hysteresis_gated(self):
        knobs = KnobSpace(samples=(8, 4, 2, 1), capacities=(64,))
        ctrl = _controller(s=2, knobs=knobs)
        # under the SLO but above the upshift margin (0.5 x 4ms): hold
        warm = [_tick(i, 2.5e-3, s=2) for i in range(8)]
        assert ctrl.plan(warm) is None
        # comfortably under, but only a partial window: still hold
        cool = [_tick(i, 0.3e-3, s=2) for i in range(8)]
        assert ctrl.plan(cool[:6]) is None
        # a full comfortable window with a safe prediction: upshift to max
        rec = ctrl.plan(cool)
        assert rec is not None and rec.applied
        assert rec.reason == "headroom-upshift"
        assert rec.winner["n_samples"] == 8
        assert rec.predicted_s <= ctrl.upshift_margin * SLO.p95_tick_s

    def test_knob_grid_orders_quality_first(self):
        ks = KnobSpace.around(ServingConfig(n_samples=8, chunk_capacity=64))
        assert [c.n_samples for c in ks.configs()] == [8, 4, 2, 1]
        qualities = [c.quality for c in ks.configs()]
        assert qualities == sorted(qualities, reverse=True)
        # precision ranks below one extra chain, above nothing
        assert ServingConfig(2, "int4").quality < ServingConfig(2).quality \
            < ServingConfig(3, "int4").quality

    def test_slo_validation(self):
        with pytest.raises(ValueError, match="p95_tick_s"):
            SLOPolicy(p95_tick_s=0.0)
        with pytest.raises(ValueError, match="min_samples"):
            SLOPolicy(p95_tick_s=1.0, min_samples=0)
        with pytest.raises(ValueError, match="config= and arch="):
            CoDesignController(None, SLO)


class TestCalibration:
    def test_fit_recovers_known_roofline(self):
        # Synthesize ticks from a known affine world (scale 2000x, 1 ms
        # dispatch overhead) across *varying* launch shapes -> the affine
        # fit is identifiable and must recover both constants.
        scale, overhead = 2000.0, 1e-3
        win = []
        for i, rows in enumerate((8, 16, 24, 32, 48, 64)):
            raw = calibrate.tick_raw_seconds(ARCH, rows=rows, capacity=64)
            win.append(dataclasses.replace(
                _tick(i, scale * raw + overhead), batch_rows=rows))
        fit = calibrate.fit_roofline(win, ARCH)
        assert fit is not None and fit.n_ticks == 6
        assert fit.scale == pytest.approx(scale, rel=1e-6)
        assert fit.overhead_s == pytest.approx(overhead, rel=1e-6)
        assert fit.resid_s < 1e-9
        raw = calibrate.tick_raw_seconds(ARCH, rows=40, capacity=64)
        assert fit.predict(raw) == pytest.approx(scale * raw + overhead)

    def test_degenerate_window_falls_back_to_ratio(self):
        # Every tick the same shape: slope unidentifiable, fit collapses to
        # the ratio through the origin — and reproduces the observed mean.
        win = [_tick(i, 5e-3) for i in range(6)]
        fit = calibrate.fit_roofline(win, ARCH)
        assert fit.overhead_s == 0.0
        raw = calibrate.tick_raw_seconds(ARCH, rows=win[0].batch_rows,
                                         capacity=win[0].capacity)
        assert fit.predict(raw) == pytest.approx(5e-3)

    def test_fit_needs_min_ticks(self):
        assert calibrate.fit_roofline([_tick(i, 1e-3) for i in range(3)],
                                      ARCH) is None

    def test_latency_model_pads_to_slots(self):
        fit = calibrate.RooflineFit(scale=1000.0, overhead_s=1e-4,
                                    n_ticks=8, resid_s=0.0)
        model = calibrate.latency_model(fit, slots=4)
        arch = dataclasses.replace(ARCH, timesteps=32)
        # below the slot count the launch shape is the padded one
        assert model(arch, None, batch=1, n_samples=2) \
            == model(arch, None, batch=4, n_samples=2)
        assert model(arch, None, batch=8, n_samples=2) \
            > model(arch, None, batch=4, n_samples=2)


class TestConvertSession:
    def _sess(self, s=4, hid=8, layers=2):
        from repro.serve import SessionStore
        store = SessionStore(n_samples=s, seed=7, max_sessions=2)
        sess = store.admit("a")
        sess.state = [(jnp.arange(s * hid, dtype=jnp.float32)
                       .reshape(s, hid),
                       jnp.ones((s, hid), jnp.float32) * (i + 1))
                      for i in range(layers)]
        sess.steps, sess.chunks = 12, 3
        return sess

    def test_downshift_keeps_prefix_chains(self):
        sess = self._sess(s=4)
        got = convert_session(sess, n_samples=2,
                              part_dtypes=(jnp.float32, jnp.float32))
        np.testing.assert_array_equal(np.asarray(got.rows),
                                      np.asarray(sess.rows)[:2])
        for (h, c), (h0, c0) in zip(got.state, sess.state):
            np.testing.assert_array_equal(np.asarray(h), np.asarray(h0)[:2])
            np.testing.assert_array_equal(np.asarray(c), np.asarray(c0)[:2])
        assert got.steps == 12 and got.chunks == 3 and got.sid == "a"

    def test_upshift_pads_fresh_chains(self):
        sess = self._sess(s=2)
        extra = np.array([40, 41], np.uint32)
        got = convert_session(sess, n_samples=4,
                              part_dtypes=(jnp.bfloat16, jnp.float32),
                              extra_rows=extra)
        np.testing.assert_array_equal(
            np.asarray(got.rows), np.concatenate([np.asarray(sess.rows),
                                                  extra]))
        h, c = got.state[0]
        assert h.dtype == jnp.bfloat16 and c.dtype == jnp.float32
        assert np.all(np.asarray(h, np.float32)[2:] == 0.0)
        np.testing.assert_array_equal(
            np.asarray(h, np.float32)[:2],
            np.asarray(sess.state[0][0].astype(jnp.bfloat16), np.float32))

    def test_upshift_requires_fresh_rows(self):
        with pytest.raises(ValueError, match="extra_rows"):
            convert_session(self._sess(s=2), n_samples=4,
                            part_dtypes=(jnp.float32, jnp.float32))

    def test_carry_dtypes_follow_precision(self):
        assert carry_dtypes("lstm", None, "pallas_seq") \
            == (jnp.float32, jnp.float32)
        assert carry_dtypes("lstm", "bf16", "pallas_seq") \
            == (jnp.bfloat16, jnp.float32)
        assert carry_dtypes("lstm", "fp32", "reference") \
            == (jnp.float32, jnp.float32)
        assert carry_dtypes("gru", "int8", "pallas_seq") == (jnp.bfloat16,)


class TestConfigSwap:
    """The snapshot contract, extended across config changes."""

    def test_s_downshift_is_bitwise_a_smaller_engine(self):
        # Chains are independent: after a 4->2 downshift the survivors'
        # stream must continue bit-identically to an engine that had served
        # S=2 with those same (seed, rows) coordinates from the start.
        # That reference is *independent* of the swap machinery — the
        # strongest equivalence the mask-stream contract offers.
        cfg, params = _cfg_params(s=4)
        sig = jax.random.normal(jax.random.key(2), (12, 1))
        eng = StreamingEngine(params, cfg, max_sessions=2,
                              chunk_capacity="auto", ladder=(4, 8))
        eng.open_session("a")
        eng.step({"a": sig[0:4]})
        eng.step({"a": sig[4:8]})
        ctrl = CoDesignController(eng, SLO)
        ctrl.apply_config(ServingConfig(n_samples=2, chunk_capacity=8))
        assert ctrl.engine is not eng and ctrl.engine.n_samples == 2
        assert ctrl.engine.tick == eng.tick     # one continuous tick line
        got = ctrl.engine.step({"a": sig[8:12]})["a"]
        assert got.steps_total == 12            # cursors survived the swap

        cfg2 = dataclasses.replace(cfg, mcd=cfg.mcd.replace(n_samples=2))
        ref = StreamingEngine(params, cfg2, max_sessions=2,
                              chunk_capacity="auto", ladder=(4, 8))
        ref.open_session("a")                   # rows [0, 1] == rows[:2]
        for a, b in ((0, 4), (4, 8), (8, 12)):
            want = ref.step({"a": sig[a:b]})["a"]
        np.testing.assert_array_equal(np.asarray(got.summary.probs),
                                      np.asarray(want.summary.probs))

    def test_precision_swap_is_bitwise_a_converted_restore(self):
        # fp32 -> bf16 mid-stream: the post-swap stream must equal a fresh
        # bf16 engine resuming from the *converted* carry — the one-time
        # rounding at the boundary is the documented semantic, everything
        # after it is bit-identical.
        cfg, params = _cfg_params(s=2)
        sig = jax.random.normal(jax.random.key(3), (8, 1))
        eng = StreamingEngine(params, cfg, max_sessions=1, chunk_capacity=4)
        eng.open_session("a")
        eng.step({"a": sig[0:4]})
        ctrl = CoDesignController(eng, SLO)
        ctrl.apply_config(ServingConfig(n_samples=2, precision="bf16",
                                        chunk_capacity=4))
        got = ctrl.engine.step({"a": sig[4:8]})["a"]
        # the stashed pre-swap state is the verification anchor
        (pre,) = ctrl.last_swap["old_sessions"]
        ref = StreamingEngine(params, cfg, max_sessions=1, chunk_capacity=4,
                              precision="bf16")
        ref.attach_session(convert_session(
            pre, n_samples=2,
            part_dtypes=carry_dtypes("lstm", "bf16", ref.backend)))
        want = ref.step({"a": sig[4:8]})["a"]
        np.testing.assert_array_equal(np.asarray(got.summary.probs),
                                      np.asarray(want.summary.probs))

    def test_upshift_swap_adds_fresh_chains(self):
        cfg, params = _cfg_params(s=2)
        sig = jax.random.normal(jax.random.key(4), (8, 1))
        eng = StreamingEngine(params, cfg, max_sessions=2, chunk_capacity=4)
        eng.open_session("a")
        eng.step({"a": sig[0:4]})
        old_rows = np.asarray(eng.store.get("a").rows)
        ctrl = CoDesignController(
            eng, SLO, knobs=KnobSpace(samples=(4, 2, 1), capacities=(4,)))
        ctrl.apply_config(ServingConfig(n_samples=4, chunk_capacity=4))
        sess = ctrl.engine.store.get("a")
        rows = np.asarray(sess.rows)
        np.testing.assert_array_equal(rows[:2], old_rows)
        assert len(set(rows.tolist())) == 4     # fresh chains, fresh rows
        res = ctrl.engine.step({"a": sig[4:8]})["a"]
        assert res.steps_total == 8             # joined chains serve fine

    def test_swap_preserves_queue_and_row_disjointness(self):
        cfg, params = _cfg_params(s=2)
        eng = StreamingEngine(params, cfg, max_sessions=1, chunk_capacity=4)
        eng.open_session("a")
        eng.admit("b", priority=3)              # waits: store is full
        used = set(np.asarray(eng.store.get("a").rows).tolist())
        ctrl = CoDesignController(eng, SLO)
        ctrl.apply_config(ServingConfig(n_samples=1, chunk_capacity=4))
        assert "b" in ctrl.engine.queue         # ticket crossed the swap
        ctrl.engine.close_session("a")          # frees the row; b drains
        sess_b = ctrl.engine.store.get("b")
        assert not used & set(np.asarray(sess_b.rows).tolist())

    def test_swap_rejects_unknown_precision(self):
        cfg, params = _cfg_params(s=2)
        eng = StreamingEngine(params, cfg, max_sessions=1, chunk_capacity=4)
        ctrl = CoDesignController(eng, SLO)
        with pytest.raises(ValueError, match="precision"):
            ctrl.apply_config(ServingConfig(n_samples=2, precision="fp64"))


class TestEndToEnd:
    """The acceptance invariant: burst -> downshift -> recovery, bit-safe."""

    def test_overload_burst_downshift_recovery_bit_identity(self, tmp_path):
        slo = SLOPolicy(p95_tick_s=3e-3)
        burst = lambda tick: 4.0 if tick >= 8 else 1.0
        sink = SimulatedLoadSink(per_chain_step_s=1e-5, overhead_s=2e-4,
                                 load=burst)
        cfg, params = _cfg_params(s=4)
        sig = jax.random.normal(jax.random.key(5), (2, 240, 1))
        eng = StreamingEngine(params, cfg, max_sessions=2,
                              chunk_capacity="auto", ladder=(8,),
                              metrics_sink=sink)
        eng.open_session("a")
        eng.open_session("b")
        trail = JsonlSink(str(tmp_path / "decisions.jsonl"))
        ctrl = CoDesignController(eng, slo, decision_sink=trail,
                                  window=8, min_ticks=4, cooldown_ticks=8)
        post_swap: list[dict] = []
        swap_tick = None
        for t in range(28):
            chunks = {"a": sig[0, 8 * t:8 * (t + 1)],
                      "b": sig[1, 8 * t:8 * (t + 1)]}
            res = ctrl.engine.step(chunks)
            if swap_tick is not None:
                post_swap.append({sid: np.asarray(r.summary.probs)
                                  for sid, r in res.items()})
            rec = ctrl.maybe_reconfigure()
            if rec is not None and rec.applied and swap_tick is None:
                swap_tick = rec.tick

        # 1. the controller reconfigured, and recorded why
        applied = [r for r in ctrl.decisions if r.applied]
        assert applied and applied[0].reason == "slo-breach"
        assert applied[0].winner != applied[0].current
        new_cfg = ServingConfig(**applied[0].winner)
        assert new_cfg.n_samples < 4            # a genuine downshift
        assert ctrl.config == new_cfg

        # 2. p95 back under the SLO within the cooldown budget
        recov = [m.duration_s for m in sink.window()
                 if swap_tick < m.tick <= swap_tick + ctrl.cooldown_ticks]
        assert len(recov) >= 4
        from repro.serve.scheduler import percentile
        assert percentile(recov, 95) <= slo.p95_tick_s

        # 3. the decision trail is durable JSONL, readable pre-close
        lines = [json.loads(l) for l in
                 (tmp_path / "decisions.jsonl").read_text().splitlines()]
        assert len(lines) == len(ctrl.decisions)
        assert any(l["applied"] for l in lines)
        assert all("candidates" in l and "slo" in l for l in lines)

        # 4. bit-identity across the boundary: an uninterrupted engine at
        # the new config, resuming from the same carried state, streams
        # the same chunks to the same outputs.
        part_dtypes = carry_dtypes("lstm", new_cfg.precision,
                                   ctrl.engine.backend)
        cfg2 = dataclasses.replace(
            cfg, mcd=cfg.mcd.replace(n_samples=new_cfg.n_samples))
        ref = StreamingEngine(params, cfg2, max_sessions=2,
                              chunk_capacity="auto", ladder=(8,),
                              precision=new_cfg.precision)
        for sess in ctrl.last_swap["old_sessions"]:
            ref.attach_session(convert_session(
                sess, n_samples=new_cfg.n_samples, part_dtypes=part_dtypes))
        for t, probs in zip(range(swap_tick + 1, 28), post_swap):
            chunks = {"a": sig[0, 8 * t:8 * (t + 1)],
                      "b": sig[1, 8 * t:8 * (t + 1)]}
            want = ref.step(chunks)
            for sid in ("a", "b"):
                np.testing.assert_array_equal(
                    probs[sid], np.asarray(want[sid].summary.probs))

"""Streaming session serving: carried state, chunk invariance, the engine.

The load-bearing invariant (ISSUE 2 acceptance): decoding an unbounded
signal chunk-by-chunk with carried ``(h, c)`` — through any backend — is
bit-identical to one full-sequence pass, for arbitrary chunk boundaries
including length-1 chunks, with the MC masks tied across the *whole*
session.  Streaming passes always supply ``lengths``; that graph family is
bit-stable across launch sizes, splits, batch composition and backends
(see docs/kernels.md), which is what makes exact assertions possible here.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autoencoder as ae, classifier as clf, distill, mcd, rnn
from repro.core.uncertainty import classification_summary
from repro.serve import (CapacityError, SessionStore, StreamingEngine)

import conformance

BACKENDS = ("reference", "pallas_step", "pallas_seq")


def _stack(hiddens=(16, 16, 16), in_dim=4, placement="YNY", seed=5, key=0):
    cfg = mcd.MCDConfig(p=0.125, placement=placement, seed=seed)
    params = rnn.init_stack(jax.random.key(key), in_dim, hiddens)
    return cfg, params


def _masks(cfg, rows, in_dim, hiddens, backend):
    if backend == "reference":
        return rnn.sample_stack_masks(cfg, rows, in_dim, hiddens)
    return rnn.stack_mask_plan(cfg, len(hiddens))


def _full(n, b=6):
    return jnp.full((b,), n, jnp.int32)


class TestRunStackStreaming:
    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("splits", [[5, 12], [1] * 17, [3, 1, 6, 7]])
    def test_chunked_equals_unchunked_bit_identical(self, backend, splits):
        """Any split of T=17 (incl. all-ones) == one pass, exactly."""
        hiddens = (16, 16, 16)
        cfg, params = _stack(hiddens)
        B, T = 6, 17
        x = jax.random.normal(jax.random.key(1), (B, T, 4))
        rows = jnp.arange(B, dtype=jnp.uint32)
        masks = _masks(cfg, rows, 4, hiddens, backend)
        full, st_full = rnn.run_stack(params, x, masks, cfg.p,
                                      backend=backend, rows=rows,
                                      seed=cfg.seed, lengths=_full(T),
                                      return_all_states=True)

        def step(xc, state):
            return rnn.run_stack(params, xc, masks, cfg.p, backend=backend,
                                 rows=rows, seed=cfg.seed,
                                 initial_state=state,
                                 lengths=_full(xc.shape[1]),
                                 return_all_states=True)

        outs, state = conformance.chunked_run(step, x, splits)
        np.testing.assert_array_equal(np.asarray(outs), np.asarray(full))
        conformance.assert_states_equal(state, st_full, f"{backend} {splits}")

    def test_pallas_seq_chunked_equals_reference_full(self):
        """The acceptance bullet: chunked pallas_seq streaming == a single
        full-sequence *reference* pass, bit-identical."""
        hiddens = (16, 16, 16)
        cfg, params = _stack(hiddens)
        B, T = 6, 17
        x = jax.random.normal(jax.random.key(1), (B, T, 4))
        rows = jnp.arange(B, dtype=jnp.uint32)
        full_ref, _ = rnn.run_stack(
            params, x, rnn.sample_stack_masks(cfg, rows, 4, hiddens), cfg.p,
            lengths=_full(T))
        plan = rnn.stack_mask_plan(cfg, 3)

        def step(xc, state):
            return rnn.run_stack(params, xc, plan, cfg.p,
                                 backend="pallas_seq", rows=rows,
                                 seed=cfg.seed, initial_state=state,
                                 lengths=_full(xc.shape[1]),
                                 return_all_states=True)

        for splits in ([5, 12], [1] * 17, [3, 1, 6, 7]):
            outs, _ = conformance.chunked_run(step, x, splits)
            np.testing.assert_array_equal(np.asarray(outs),
                                          np.asarray(full_ref))

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_ragged_lengths_freeze_per_row(self, backend):
        """A ragged batch (per-row lengths, padded to max T) returns each
        row's state at its own length: live prefixes are bit-identical to
        the full-length pass of the same batch (lengths is a *data* input —
        same program, so frozen rows cannot perturb live ones), and serving
        a row alone agrees to fp tolerance (a different batch shape compiles
        a different program, so solo extraction is ulp- not bit-exact)."""
        hiddens = (8, 8)
        cfg, params = _stack(hiddens, placement="YN")
        B, T = 4, 9
        x = jax.random.normal(jax.random.key(2), (B, T, 4))
        rows = jnp.arange(B, dtype=jnp.uint32)
        lens = jnp.array([9, 1, 4, 6], jnp.int32)
        masks = _masks(cfg, rows, 4, hiddens, backend)
        out, states = rnn.run_stack(params, x, masks, cfg.p, backend=backend,
                                    rows=rows, seed=cfg.seed, lengths=lens,
                                    return_all_states=True)
        full, full_states = rnn.run_stack(params, x, masks, cfg.p,
                                          backend=backend, rows=rows,
                                          seed=cfg.seed, lengths=_full(T, B),
                                          return_all_states=True)
        for r in range(B):
            L = int(lens[r])
            np.testing.assert_array_equal(np.asarray(out[r, :L]),
                                          np.asarray(full[r, :L]))
            # frozen at own length: last layer's h equals its last live step
            np.testing.assert_array_equal(np.asarray(states[-1][0][r]),
                                          np.asarray(out[r, L - 1]))
            solo_masks = _masks(cfg, rows[r:r + 1], 4, hiddens, backend)
            solo, solo_states = rnn.run_stack(
                params, x[r:r + 1, :L], solo_masks, cfg.p, backend=backend,
                rows=rows[r:r + 1], seed=cfg.seed,
                lengths=jnp.full((1,), L, jnp.int32), return_all_states=True)
            np.testing.assert_allclose(np.asarray(out[r, :L]),
                                       np.asarray(solo[0]),
                                       rtol=1e-5, atol=1e-6)
            for (h1, c1), (h2, c2) in zip(states, solo_states):
                np.testing.assert_allclose(np.asarray(h1[r]),
                                           np.asarray(h2[0]),
                                           rtol=1e-5, atol=1e-6)
                np.testing.assert_allclose(np.asarray(c1[r]),
                                           np.asarray(c2[0]),
                                           rtol=1e-5, atol=1e-6)

    def test_ragged_states_agree_across_backends(self):
        """Same ragged batch through all three backends: the lengths-pinned
        graph family keeps the per-row carries bit-identical across them."""
        hiddens = (8, 8)
        cfg, params = _stack(hiddens, placement="YN")
        B, T = 4, 9
        x = jax.random.normal(jax.random.key(2), (B, T, 4))
        rows = jnp.arange(B, dtype=jnp.uint32)
        lens = jnp.array([9, 1, 4, 6], jnp.int32)
        got = {}
        for backend in BACKENDS:
            masks = _masks(cfg, rows, 4, hiddens, backend)
            _, states = rnn.run_stack(params, x, masks, cfg.p,
                                      backend=backend, rows=rows,
                                      seed=cfg.seed, lengths=lens,
                                      return_all_states=True)
            got[backend] = states
        for backend in ("pallas_step", "pallas_seq"):
            for (h1, c1), (h2, c2) in zip(got["reference"], got[backend]):
                np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
                np.testing.assert_array_equal(
                    np.asarray(c1, np.float32), np.asarray(c2, np.float32))

    def test_return_all_states_shapes_and_dtypes(self):
        hiddens = (16, 8)
        cfg, params = _stack(hiddens, placement="YY")
        B, T = 3, 5
        x = jax.random.normal(jax.random.key(3), (B, T, 4))
        rows = jnp.arange(B, dtype=jnp.uint32)
        _, st_ref = rnn.run_stack(params, x,
                                  rnn.sample_stack_masks(cfg, rows, 4, hiddens),
                                  cfg.p, return_all_states=True)
        _, st_seq = rnn.run_stack(params, x, rnn.stack_mask_plan(cfg, 2),
                                  cfg.p, backend="pallas_seq", rows=rows,
                                  seed=cfg.seed, return_all_states=True)
        assert len(st_ref) == len(st_seq) == 2
        for (h, c), hid in zip(st_seq, hiddens):
            assert h.shape == (B, hid) and c.shape == (B, hid)
            assert c.dtype == jnp.float32       # Pallas carries c in fp32
        for (h, c), hid in zip(st_ref, hiddens):
            assert h.shape == (B, hid) and c.dtype == x.dtype

    def test_default_return_contract_unchanged(self):
        """Without the new kwargs run_stack returns (out, (h_T, c_T)) of the
        last layer with c in the input dtype — the pre-streaming contract."""
        hiddens = (8, 8)
        cfg, params = _stack(hiddens, placement="YN")
        x = jax.random.normal(jax.random.key(4), (3, 5, 4))
        rows = jnp.arange(3, dtype=jnp.uint32)
        out, (hT, cT) = rnn.run_stack(params, x, rnn.stack_mask_plan(cfg, 2),
                                      cfg.p, backend="pallas_seq", rows=rows,
                                      seed=cfg.seed)
        assert hT.shape == (3, 8) and cT.dtype == x.dtype


class TestRunStackStreamingGru:
    """GRU parity (ISSUE 4 acceptance): chunked == unchunked bit-identical
    on all three backends, incl. carried h state and ragged lengths."""

    def _stack(self, hiddens=(16, 16, 16), placement="YNY", seed=5):
        cfg = mcd.MCDConfig(p=0.125, placement=placement, seed=seed)
        params = rnn.init_stack(jax.random.key(0), 4, hiddens, cell="gru")
        return cfg, params

    def _masks(self, cfg, rows, hiddens, backend):
        if backend == "reference":
            return rnn.sample_stack_masks(cfg, rows, 4, hiddens, cell="gru")
        return rnn.stack_mask_plan(cfg, len(hiddens))

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("splits", [[5, 12], [1] * 17, [3, 1, 6, 7]])
    def test_chunked_equals_unchunked_bit_identical(self, backend, splits):
        hiddens = (16, 16, 16)
        cfg, params = self._stack(hiddens)
        B, T = 6, 17
        x = jax.random.normal(jax.random.key(1), (B, T, 4))
        rows = jnp.arange(B, dtype=jnp.uint32)
        masks = self._masks(cfg, rows, hiddens, backend)
        full, st_full = rnn.run_stack(params, x, masks, cfg.p,
                                      backend=backend, rows=rows,
                                      seed=cfg.seed, lengths=_full(T),
                                      return_all_states=True, cell="gru")

        def step(xc, state):
            return rnn.run_stack(params, xc, masks, cfg.p, backend=backend,
                                 rows=rows, seed=cfg.seed,
                                 initial_state=state,
                                 lengths=_full(xc.shape[1]),
                                 return_all_states=True, cell="gru")

        outs, state = conformance.chunked_run(step, x, splits)
        np.testing.assert_array_equal(np.asarray(outs), np.asarray(full))
        conformance.assert_states_equal(state, st_full, f"gru {backend}")

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_ragged_lengths_freeze_per_row(self, backend):
        """Ragged GRU batch: each row's h comes back frozen at its own
        length, bit-identical to the full-length pass's live prefix."""
        hiddens = (8, 8)
        cfg, params = self._stack(hiddens, placement="YN")
        B, T = 4, 9
        x = jax.random.normal(jax.random.key(2), (B, T, 4))
        rows = jnp.arange(B, dtype=jnp.uint32)
        lens = jnp.array([9, 1, 4, 6], jnp.int32)
        masks = self._masks(cfg, rows, hiddens, backend)
        out, states = rnn.run_stack(params, x, masks, cfg.p, backend=backend,
                                    rows=rows, seed=cfg.seed, lengths=lens,
                                    return_all_states=True, cell="gru")
        full, _ = rnn.run_stack(params, x, masks, cfg.p, backend=backend,
                                rows=rows, seed=cfg.seed,
                                lengths=_full(T, B),
                                return_all_states=True, cell="gru")
        for r in range(B):
            L = int(lens[r])
            np.testing.assert_array_equal(np.asarray(out[r, :L]),
                                          np.asarray(full[r, :L]))
            np.testing.assert_array_equal(np.asarray(states[-1][0][r]),
                                          np.asarray(out[r, L - 1]))

    def test_ragged_states_agree_across_backends(self):
        hiddens = (8, 8)
        cfg, params = self._stack(hiddens, placement="YN")
        B, T = 4, 9
        x = jax.random.normal(jax.random.key(2), (B, T, 4))
        rows = jnp.arange(B, dtype=jnp.uint32)
        lens = jnp.array([9, 1, 4, 6], jnp.int32)
        got = {}
        for backend in BACKENDS:
            masks = self._masks(cfg, rows, hiddens, backend)
            _, states = rnn.run_stack(params, x, masks, cfg.p,
                                      backend=backend, rows=rows,
                                      seed=cfg.seed, lengths=lens,
                                      return_all_states=True, cell="gru")
            got[backend] = states
        for backend in ("pallas_step", "pallas_seq"):
            for (h1,), (h2,) in zip(got["reference"], got[backend]):
                np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))


class TestSessionStore:
    def test_admission_rows_unique_and_stable(self):
        store = SessionStore(n_samples=4, seed=7, max_sessions=3)
        a = store.admit("a")
        b = store.admit("b")
        np.testing.assert_array_equal(np.asarray(a.rows), [0, 1, 2, 3])
        np.testing.assert_array_equal(np.asarray(b.rows), [4, 5, 6, 7])
        assert a.seed == 7 and store.get("a") is a
        assert len(store) == 2 and "a" in store

    def test_duplicate_admission_rejected(self):
        store = SessionStore(n_samples=2)
        store.admit("a")
        with pytest.raises(ValueError, match="already admitted"):
            store.admit("a")

    def test_capacity_and_eviction(self):
        store = SessionStore(n_samples=2, max_sessions=2)
        store.admit("a")
        store.admit("b")
        with pytest.raises(CapacityError):
            store.admit("c")
        evicted = store.evict("a")
        assert evicted.sid == "a" and "a" not in store
        c = store.admit("c")                       # slot freed
        # rows never reused: a new session is a new Bayesian draw
        np.testing.assert_array_equal(np.asarray(c.rows), [4, 5])

    def test_unknown_session(self):
        store = SessionStore(n_samples=2)
        with pytest.raises(KeyError, match="unknown session"):
            store.get("nope")
        with pytest.raises(KeyError, match="unknown session"):
            store.evict("nope")

    def test_attach_validates_coordinates(self):
        store = SessionStore(n_samples=2, seed=7, max_sessions=2)
        sess = store.admit("a")
        evicted = store.evict("a")
        store.attach(evicted)                       # round-trips
        assert store.get("a") is sess
        store.evict("a")
        other = SessionStore(n_samples=2, seed=8).admit("b")
        with pytest.raises(ValueError, match="seed"):
            store.attach(other)
        wrong_s = SessionStore(n_samples=3, seed=7).admit("c")
        with pytest.raises(ValueError, match="chains"):
            store.attach(wrong_s)

    def test_attach_protects_row_allocator(self):
        """Re-attaching into a fresh store (restart) must not let later
        admissions re-allocate the attached rows, nor collide with live
        sessions — shared (seed, rows) would correlate Bayesian draws."""
        old = SessionStore(n_samples=2, seed=7)
        old.admit("s0")
        saved = old.admit("s1")                      # rows [2, 3]
        fresh = SessionStore(n_samples=2, seed=7, max_sessions=4)
        fresh.attach(saved)
        nxt = fresh.admit("s2")                      # allocator bumped past 3
        np.testing.assert_array_equal(np.asarray(nxt.rows), [4, 5])
        colliding = SessionStore(n_samples=2, seed=7).admit("ghost")  # [0, 1]
        fresh.admit("s3")                            # rows [6, 7] — fine
        with pytest.raises(ValueError, match="collide"):
            # a live session in `fresh` could then share rows — refuse
            fresh2 = SessionStore(n_samples=2, seed=7, max_sessions=4)
            fresh2.admit("live")                     # rows [0, 1]
            fresh2.attach(colliding)


class TestStudentFallback:
    """``SessionStore.grow`` and the distill fallback pin (the grow
    docstring's contract): an escalated student session must stream on
    bit-identically to an always-MC session attached with the regrown
    rows and the tiled carry."""

    def test_grow_mc_appends_fresh_zero_carry_chains(self):
        store = SessionStore(n_samples=6, seed=0)
        sess = store.admit("a", n_samples=2)            # rows [0, 1]
        sess.state = [(np.full((2, 3), 5.0, np.float32),
                       np.full((2, 3), 9.0, np.float32))]
        assert store.grow("a", 5) == 3
        np.testing.assert_array_equal(np.asarray(sess.rows),
                                      [0, 1, 2, 3, 4])  # fresh, never reused
        h, c = sess.state[0]
        np.testing.assert_array_equal(np.asarray(h[:2]), 5.0 * np.ones((2, 3)))
        np.testing.assert_array_equal(np.asarray(h[2:]),
                                      np.zeros((3, 3)))  # newcomers fresh
        np.testing.assert_array_equal(np.asarray(c[2:]), np.zeros((3, 3)))
        assert store.grow("a", 5) == 0                   # no-op at target
        with pytest.raises(ValueError, match="grow target"):
            store.grow("a", 7)                           # above the ceiling
        with pytest.raises(ValueError, match="grow target"):
            store.grow("a", 4)                           # chains never shrink

    def test_grow_student_replaces_row_tiles_carry_flips_mode(self):
        store = SessionStore(n_samples=4, seed=0)
        sess = store.admit("s", mode="student")          # one flagged row
        assert sess.mode == "student"
        assert mcd.is_student_row(int(np.asarray(sess.rows)[0]))
        carry = np.arange(3.0, dtype=np.float32)[None]   # (1, H)
        sess.state = [(carry, carry + 10.0)]
        assert store.grow("s", 4) == 4
        rows = np.asarray(sess.rows)
        assert rows.shape == (4,)
        assert not any(mcd.is_student_row(int(r)) for r in rows)
        assert sess.mode == "mc"
        for part, base in zip(sess.state[0], (carry, carry + 10.0)):
            np.testing.assert_array_equal(np.asarray(part),
                                          np.tile(base, (4, 1)))
        # the det row's base id stays burned; fresh rows follow it
        later = store.admit("next")
        assert int(np.asarray(later.rows)[0]) == int(rows[-1]) + 1

    @pytest.mark.parametrize("backend", ("reference", "pallas_seq"))
    def test_escalated_session_bit_identical_to_attached_mc_twin(self,
                                                                 backend):
        """The distill fallback pin: fresh rows ⇒ fresh masks, so from the
        first post-escalation chunk the regrown session is byte-for-byte
        an always-MC session attached at the student's carry."""
        cfg = clf.ClassifierConfig(
            hidden=8, num_layers=2, num_classes=4,
            mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=4, seed=3))
        params = clf.init(jax.random.key(0), cfg)
        student = distill.init_student(jax.random.key(1), cfg, params)
        sig = np.asarray(jax.random.normal(jax.random.key(2), (16, 1)),
                         np.float32)

        def chunk(t):
            return {"p": jnp.asarray(sig[4 * t:4 * (t + 1)])}

        # threshold 0.0: a fresh unc head predicts softplus-positive MI on
        # any input, so the first served chunk escalates
        esc = StreamingEngine(params, cfg, backend=backend, max_sessions=1,
                              student=student,
                              student_escalate_threshold=0.0)
        esc.open_session("p", mode="student")
        esc.step(chunk(0))
        assert esc.last_metrics.escalations == 1
        sess = esc.store.get("p")
        assert sess.mode == "mc" and int(sess.rows.shape[0]) == 4

        plain = StreamingEngine(params, cfg, backend=backend, max_sessions=1)
        plain.attach_session(dataclasses.replace(
            sess, state=[tuple(layer) for layer in sess.state]))
        for t in range(1, 4):
            got = esc.step(chunk(t))["p"].summary
            want = plain.step(chunk(t))["p"].summary
            for g, w in zip(got, want):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


class TestStreamingEngine:
    def _cfg_params(self, s=3, seed=3):
        cfg = clf.ClassifierConfig(
            hidden=8, num_layers=2, num_classes=4,
            mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=s,
                              seed=seed))
        return cfg, clf.init(jax.random.key(0), cfg)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_ragged_cobatched_equals_solo_full(self, backend):
        """Ragged co-batched chunked serving == solo single-chunk serving,
        bit-identical per session (batch composition is invisible)."""
        cfg, params = self._cfg_params()
        T = 11
        sig_a = jax.random.normal(jax.random.key(1), (T, 1))
        sig_b = jax.random.normal(jax.random.key(2), (T, 1))
        eng = StreamingEngine(params, cfg, backend=backend, max_sessions=2)
        eng.open_session("a")
        eng.open_session("b")
        eng.step({"a": sig_a[:4], "b": sig_b[:7]})     # ragged tick
        eng.step({"a": sig_a[4:5], "b": sig_b[7:]})    # length-1 chunk for a
        ra = eng.step({"a": sig_a[5:]})["a"]           # b sits this tick out
        solo = StreamingEngine(params, cfg, backend=backend, max_sessions=1)
        solo.open_session("a")
        qa = solo.step({"a": sig_a})["a"]
        np.testing.assert_array_equal(np.asarray(ra.summary.probs),
                                      np.asarray(qa.summary.probs))
        np.testing.assert_array_equal(
            np.asarray(ra.summary.mutual_information),
            np.asarray(qa.summary.mutual_information))
        assert ra.steps_total == qa.steps_total == T

    def test_matches_direct_classifier_pass(self):
        """Engine output == a single full-sequence classifier pass on the
        reference backend (masks tied across every chunk boundary)."""
        cfg, params = self._cfg_params()
        s = cfg.mcd.n_samples
        T = 9
        sig = jax.random.normal(jax.random.key(4), (T, 1))
        eng = StreamingEngine(params, cfg, backend="pallas_seq",
                              max_sessions=1)
        eng.open_session("x")
        res = None
        for a in range(0, T, 2):                      # chunks of 2 then 1
            res = eng.step({"x": sig[a:a + 2]})["x"]
        rows = jnp.arange(s, dtype=jnp.uint32)
        logits = clf.apply(params, jnp.broadcast_to(sig[None], (s, T, 1)),
                           rows, cfg, backend="reference",
                           lengths=jnp.full((s,), T, jnp.int32))
        want = classification_summary(logits[:, None].astype(jnp.float32))
        np.testing.assert_array_equal(np.asarray(res.summary.probs),
                                      np.asarray(want.probs[0]))

    def test_autoencoder_streaming(self):
        cfg = ae.AutoencoderConfig(
            hidden=8, num_layers=1,
            mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=2, seed=1))
        params = ae.init(jax.random.key(0), cfg)
        eng = StreamingEngine(params, cfg, backend="pallas_seq",
                              max_sessions=2)
        eng.open_session("a")
        eng.open_session("b")
        res = eng.step({"a": jnp.ones((5, 1)), "b": jnp.zeros((3, 1))})
        assert res["a"].summary.mean.shape == (5, 1)
        assert res["b"].summary.total.shape == (3, 1)
        assert (np.asarray(res["a"].summary.total) >= 0).all()
        res2 = eng.step({"a": jnp.ones((2, 1))})
        assert res2["a"].steps_total == 7

    def test_bookkeeping_and_eviction(self):
        cfg, params = self._cfg_params(s=2)
        eng = StreamingEngine(params, cfg, max_sessions=1)
        eng.open_session("a")
        with pytest.raises(CapacityError):
            eng.open_session("b")
        eng.step({"a": jnp.ones((3, 1))})
        sess = eng.close_session("a")
        assert sess.steps == 3 and sess.chunks == 1
        assert sess.state is not None and eng.active_sessions == []
        eng.open_session("b")                          # capacity freed

    def test_evict_attach_resumes_same_draw(self):
        """close → attach continues the stream bit-identically (same state,
        same (seed, rows) coordinates — the checkpoint/restore path)."""
        cfg, params = self._cfg_params()
        T = 8
        sig = jax.random.normal(jax.random.key(6), (T, 1))
        eng = StreamingEngine(params, cfg, max_sessions=1)
        eng.open_session("a")
        eng.step({"a": sig[:3]})
        frozen = eng.close_session("a")
        eng.attach_session(frozen)
        res = eng.step({"a": sig[3:]})["a"]
        solo = StreamingEngine(params, cfg, max_sessions=1)
        solo.open_session("a")
        want = solo.step({"a": sig})["a"]
        np.testing.assert_array_equal(np.asarray(res.summary.probs),
                                      np.asarray(want.summary.probs))
        assert res.steps_total == T and frozen.chunks == 2

    def test_chunk_capacity_fixed_shapes(self):
        """Fixed-shape mode (pad to capacity + idle slots) serves the same
        results while reusing one compiled graph across ragged ticks."""
        cfg, params = self._cfg_params()
        T = 9
        sig_a = jax.random.normal(jax.random.key(1), (T, 1))
        sig_b = jax.random.normal(jax.random.key(2), (T, 1))
        fixed = StreamingEngine(params, cfg, max_sessions=3, chunk_capacity=5)
        fixed.open_session("a")
        fixed.open_session("b")
        fixed.step({"a": sig_a[:4], "b": sig_b[:5]})
        fixed.step({"a": sig_a[4:6]})               # idle slots padded
        ra = fixed.step({"a": sig_a[6:], "b": sig_b[5:]})
        solo = StreamingEngine(params, cfg, max_sessions=1)
        solo.open_session("a")
        qa = solo.step({"a": sig_a})["a"]
        np.testing.assert_allclose(np.asarray(ra["a"].summary.probs),
                                   np.asarray(qa.summary.probs),
                                   rtol=1e-5, atol=1e-6)
        assert ra["a"].steps_total == ra["b"].steps_total == T
        with pytest.raises(ValueError, match="chunk_capacity"):
            fixed.step({"a": jnp.ones((6, 1))})
        # one-graph guarantee: an all-fresh tick must present the same jit
        # pytree as later ticks (states materialized, never None)
        probe = StreamingEngine(params, cfg, max_sessions=2, chunk_capacity=5)
        sess = probe.open_session("f")
        assert probe._gather_states([sess], jnp.float32, 2) is not None

    def test_autoencoder_cobatched_equals_solo(self):
        """AE streaming: ragged co-batched == solo, bit-identical (decoder
        inherits the lengths pin, so the whole pass stays on the pinned
        graph family)."""
        cfg = ae.AutoencoderConfig(
            hidden=8, num_layers=1,
            mcd=mcd.MCDConfig(p=0.125, placement="YNYN", n_samples=2,
                              seed=1))
        params = ae.init(jax.random.key(0), cfg)
        T = 7
        sig_a = jax.random.normal(jax.random.key(8), (T, 1))
        sig_b = jax.random.normal(jax.random.key(9), (T, 1))
        eng = StreamingEngine(params, cfg, backend="pallas_seq",
                              max_sessions=2)
        eng.open_session("a")
        eng.open_session("b")
        eng.step({"a": sig_a[:3], "b": sig_b[:5]})
        ra = eng.step({"a": sig_a[3:], "b": sig_b[5:]})["a"]
        solo = StreamingEngine(params, cfg, backend="pallas_seq",
                               max_sessions=1)
        solo.open_session("a")
        solo.step({"a": sig_a[:3]})
        qa = solo.step({"a": sig_a[3:]})["a"]
        np.testing.assert_array_equal(np.asarray(ra.summary.mean),
                                      np.asarray(qa.summary.mean))
        np.testing.assert_array_equal(np.asarray(ra.summary.total),
                                      np.asarray(qa.summary.total))

    def test_bad_chunks_rejected(self):
        cfg, params = self._cfg_params(s=2)
        eng = StreamingEngine(params, cfg, max_sessions=2)
        eng.open_session("a")
        with pytest.raises(KeyError, match="unknown session"):
            eng.step({"zzz": jnp.ones((3, 1))})
        with pytest.raises(ValueError, match="t>=1"):
            eng.step({"a": jnp.ones((0, 1))})
        assert eng.step({}) == {}


class TestWindowedDecoder:
    """ISSUE 8 satellite: the windowed-decoder AE (``decode_window``).

    The encoder — and therefore the rolling bottleneck a streaming session
    carries — is untouched by the window, and the decoder replay at
    position t depends only on the bottleneck and the time-invariant
    per-row masks.  So (a) a windowed decode is bit-identical to the first
    min(T, W) positions of the full replay, and (b) chunked streaming with
    a windowed decoder stays bit-identical to unchunked, on every backend.
    """

    def _cfg_params(self, window, cell="lstm", s=2):
        cfg = ae.AutoencoderConfig(
            hidden=8, num_layers=1, cell=cell, decode_window=window,
            mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=s, seed=1))
        return cfg, ae.init(jax.random.key(0), cfg)

    def test_window_validation(self):
        with pytest.raises(ValueError, match="decode_window"):
            ae.AutoencoderConfig(decode_window=0)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_windowed_equals_full_prefix_bit_identical(self, backend):
        """apply() with decode_window=W == the first W positions of the
        full repeat-T replay, bit-exact (same bottleneck, same masks)."""
        W, T, B = 3, 7, 2
        cfg_w, params = self._cfg_params(W)
        cfg_full = ae.AutoencoderConfig(
            **{**dataclasses.asdict(cfg_w), "mcd": cfg_w.mcd,
               "decode_window": None})
        x = jax.random.normal(jax.random.key(2), (B, T, 1))
        rows = jnp.arange(B, dtype=jnp.uint32)
        lens = jnp.full((B,), T, jnp.int32)
        mean_w, lv_w = ae.apply(params, x, rows, cfg_w, backend=backend,
                                lengths=lens)
        mean_f, lv_f = ae.apply(params, x, rows, cfg_full, backend=backend,
                                lengths=lens)
        assert mean_w.shape == (B, W, 1)
        np.testing.assert_array_equal(np.asarray(mean_w),
                                      np.asarray(mean_f[:, :W]))
        np.testing.assert_array_equal(np.asarray(lv_w),
                                      np.asarray(lv_f[:, :W]))
        # a window past T is a no-op: full replay, full shape
        cfg_big = dataclasses.replace(cfg_w, decode_window=99)
        mean_b, _ = ae.apply(params, x, rows, cfg_big, backend=backend,
                             lengths=lens)
        np.testing.assert_array_equal(np.asarray(mean_b),
                                      np.asarray(mean_f))

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("cell", ("lstm", "gru"))
    def test_chunked_equals_unchunked_bit_identical(self, backend, cell):
        """The satellite's acceptance pin: engine streaming with a windowed
        decoder — chunked == unchunked, bit-identical, all backends.  The
        carried bottleneck is window-independent, so the final chunk's
        reconstruction matches a run that saw the prefix as one chunk."""
        W, T = 4, 9
        cfg, params = self._cfg_params(W, cell=cell)
        sig = jax.random.normal(jax.random.key(3), (T, 1))
        eng = StreamingEngine(params, cfg, backend=backend, max_sessions=1)
        eng.open_session("a")
        eng.step({"a": sig[:3]})
        eng.step({"a": sig[3:4]})                  # length-1 chunk
        got = eng.step({"a": sig[4:]})["a"]
        solo = StreamingEngine(params, cfg, backend=backend, max_sessions=1)
        solo.open_session("a")
        solo.step({"a": sig[:4]})                  # different split
        want = solo.step({"a": sig[4:]})["a"]
        # the last chunk is 5 steps but the decode window caps the
        # reconstruction at W=4 positions
        assert got.summary.mean.shape == (W, 1)
        np.testing.assert_array_equal(np.asarray(got.summary.mean),
                                      np.asarray(want.summary.mean))
        np.testing.assert_array_equal(np.asarray(got.summary.total),
                                      np.asarray(want.summary.total))
        assert got.steps_total == T

    def test_short_chunk_keeps_own_length(self):
        """Chunks shorter than the window reconstruct their full length."""
        cfg, params = self._cfg_params(window=4)
        eng = StreamingEngine(params, cfg, backend="pallas_seq",
                              max_sessions=1)
        eng.open_session("a")
        res = eng.step({"a": jnp.ones((2, 1))})["a"]
        assert res.summary.mean.shape == (2, 1)


class TestStreamingEngineGru:
    """GRU sessions through the engine: h-only carry pytrees end to end."""

    def _cfg_params(self, s=3, seed=3):
        cfg = clf.ClassifierConfig(
            hidden=8, num_layers=2, num_classes=4, cell="gru",
            mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=s,
                              seed=seed))
        return cfg, clf.init(jax.random.key(0), cfg)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_ragged_cobatched_equals_solo_full(self, backend):
        """Ragged co-batched chunked GRU serving == solo single-chunk
        serving, bit-identical per session."""
        cfg, params = self._cfg_params()
        T = 11
        sig_a = jax.random.normal(jax.random.key(1), (T, 1))
        sig_b = jax.random.normal(jax.random.key(2), (T, 1))
        eng = StreamingEngine(params, cfg, backend=backend, max_sessions=2)
        eng.open_session("a")
        eng.open_session("b")
        eng.step({"a": sig_a[:4], "b": sig_b[:7]})     # ragged tick
        eng.step({"a": sig_a[4:5], "b": sig_b[7:]})    # length-1 chunk for a
        ra = eng.step({"a": sig_a[5:]})["a"]           # b sits this tick out
        solo = StreamingEngine(params, cfg, backend=backend, max_sessions=1)
        solo.open_session("a")
        qa = solo.step({"a": sig_a})["a"]
        np.testing.assert_array_equal(np.asarray(ra.summary.probs),
                                      np.asarray(qa.summary.probs))
        assert ra.steps_total == qa.steps_total == T

    def test_session_state_is_h_only(self):
        cfg, params = self._cfg_params(s=2)
        eng = StreamingEngine(params, cfg, max_sessions=1)
        eng.open_session("a")
        eng.step({"a": jnp.ones((3, 1))})
        sess = eng.store.get("a")
        assert [len(layer) for layer in sess.state] == [1, 1]
        for (h,) in sess.state:
            assert h.shape == (2, cfg.hidden)

    def test_fixed_capacity_matches_dynamic(self):
        """Fixed-shape GRU ticks (idle slots padded, h-only zero states)
        serve the same results as dynamic shapes."""
        cfg, params = self._cfg_params()
        T = 9
        sig = jax.random.normal(jax.random.key(4), (T, 1))
        fixed = StreamingEngine(params, cfg, max_sessions=3, chunk_capacity=5)
        dyn = StreamingEngine(params, cfg, max_sessions=1)
        for eng in (fixed, dyn):
            eng.open_session("a")
        want = got = None
        for a, b in ((0, 4), (4, 6), (6, T)):
            got = fixed.step({"a": sig[a:b]})["a"]
            want = dyn.step({"a": sig[a:b]})["a"]
        np.testing.assert_allclose(np.asarray(got.summary.probs),
                                   np.asarray(want.summary.probs),
                                   rtol=1e-5, atol=1e-6)

    def test_autoencoder_gru_cobatched_equals_solo(self):
        cfg = ae.AutoencoderConfig(
            hidden=8, num_layers=1, cell="gru",
            mcd=mcd.MCDConfig(p=0.125, placement="YNYN", n_samples=2,
                              seed=1))
        params = ae.init(jax.random.key(0), cfg)
        T = 7
        sig_a = jax.random.normal(jax.random.key(8), (T, 1))
        sig_b = jax.random.normal(jax.random.key(9), (T, 1))
        eng = StreamingEngine(params, cfg, backend="pallas_seq",
                              max_sessions=2)
        eng.open_session("a")
        eng.open_session("b")
        eng.step({"a": sig_a[:3], "b": sig_b[:5]})
        ra = eng.step({"a": sig_a[3:], "b": sig_b[5:]})["a"]
        solo = StreamingEngine(params, cfg, backend="pallas_seq",
                               max_sessions=1)
        solo.open_session("a")
        solo.step({"a": sig_a[:3]})
        qa = solo.step({"a": sig_a[3:]})["a"]
        np.testing.assert_array_equal(np.asarray(ra.summary.mean),
                                      np.asarray(qa.summary.mean))
        np.testing.assert_array_equal(np.asarray(ra.summary.total),
                                      np.asarray(qa.summary.total))

"""Version compatibility for the Pallas TPU API surface.

The kernels target the current Pallas API names; older jax releases spell
some of them differently (``pltpu.CompilerParams`` was ``TPUCompilerParams``
before the rename, ``jax.sharding.AxisType`` arrived after 0.4.x).  All
version probing lives here so the kernel files stay on one spelling.
"""

from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def compiler_params(*dimension_semantics: str):
    """``pltpu.CompilerParams(dimension_semantics=...)`` under either name."""
    return _COMPILER_PARAMS_CLS(dimension_semantics=tuple(dimension_semantics))


def set_mesh(mesh):
    """``jax.set_mesh(mesh)`` context; older releases enter the Mesh itself."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def shard_map(*args, **kw):
    """``jax.shard_map`` where it exists; the pre-graduation experimental
    location otherwise (removed in newer releases — probe, don't pin)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(*args, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(*args, **kw)


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the release supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))

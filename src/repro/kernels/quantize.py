"""Per-channel symmetric weight quantization for the serving kernels.

The paper's co-design treats bit-width as a first-class axis: the FPGA
design runs 16-bit fixed point and the DSE trades precision against DSPs
and accuracy (§IV, Tables I/II).  The TPU serving analogue is *weight*
quantization in the sequence-fused kernels: the VMEM-resident byte budget
(docs/kernels.md — weights ≈ 2·G·H·(I+H) bytes in bf16) is what bounds
the on-chip hidden width, so int8 halves and packed int4 quarters the
residency footprint while activations stay bf16 and accumulation fp32.

Scheme (one definition, shared by every backend — bit-identity depends on
it):

* **Symmetric, per-output-channel scales.**  For a gate-stacked weight
  ``w[..., G, H]`` each output channel ``(g, h)`` gets
  ``scale[g, h] = max_i |w[i, g, h]| / qmax`` with ``qmax = 2^(bits-1)-1``
  (127 for int8, 7 for int4); ``q = clip(round(w / scale), ±qmax)``.
  ``round`` is round-half-to-even and the reduction axis is always the
  *contraction* dim, so quantizing in kernel layout ``[I, G, H]`` (axis 0)
  or core layout ``[G, I, H]`` (axis 1) yields bit-identical ``(q, scale)``
  — max/divide/round are elementwise or exact reductions over the same
  element sets.
* **Canonical dequant** ``w_deq = (q.astype(f32) * scale).astype(act)``.
  The sequence kernels apply it in-register to their VMEM-resident int
  operands; the step-kernel wrapper and the jnp reference apply the same
  jnp expression outside — identical values, so the three backends stay
  bit-identical per precision.
* **int4 packs two's-complement nibbles** two-per-byte along the last
  (output/H) axis, padding odd H; ``unpack_int4(pack_int4(q), H) == q``
  exactly (pinned by ``tests/test_quantize.py``).
* Biases are never quantized — they enter the gate sums in fp32 on every
  path already.

``precision`` values (the knob threaded ``ops`` → ``rnn.run_stack`` →
``classifier``/``autoencoder`` → ``StreamingEngine``):
``None`` (native dtypes, the pre-quantization behavior), ``"fp32"``,
``"bf16"`` (pure cast), ``"int8"``, ``"int4"`` (quantized weights over
bf16 activations).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: The serving-precision axis.  ``None`` (not listed) means "leave dtypes
#: alone" — the default for every existing caller.
PRECISIONS = ("fp32", "bf16", "int8", "int4")

#: Weight storage bits per precision (fp32/bf16 are plain casts).
WEIGHT_BITS = {"fp32": 32, "bf16": 16, "int8": 8, "int4": 4}

#: Symmetric integer range: qmax = 2^(bits-1) - 1 (the -2^(bits-1) code is
#: unused, keeping the grid symmetric around 0).
QMAX = {8: 127, 4: 7}

#: Precisions whose weights are integer-quantized (vs plain casts).
QUANTIZED = ("int8", "int4")


def check_precision(precision) -> None:
    if precision is not None and precision not in PRECISIONS:
        raise ValueError(f"precision must be one of {PRECISIONS} or None, "
                         f"got {precision!r}")


def activation_dtype(precision, default):
    """The activation/carry dtype a precision runs with.

    fp32 computes in fp32; bf16/int8/int4 all run bf16 activations (the
    quantized weights dequantize into bf16 registers); ``None`` keeps the
    caller's native dtype.
    """
    if precision is None:
        return default
    check_precision(precision)
    return jnp.float32 if precision == "fp32" else jnp.bfloat16


def quantize(w: jax.Array, bits: int, *, axis: int):
    """Symmetric per-output-channel quantization of ``w`` along ``axis``.

    ``axis`` is the contraction dim (reduced away by the matmul); every
    other coordinate is an output channel with its own scale.  Returns
    ``(q int8, scale fp32)`` with ``scale.shape = w.shape`` minus ``axis``.
    Zero/constant-zero channels get scale 1.0 (their q is 0 anyway), so no
    division ever sees 0.
    """
    qmax = QMAX[bits]
    w = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(w), axis=axis)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.round(w / jnp.expand_dims(scale, axis))
    q = jnp.clip(q, -qmax, qmax).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, *, axis: int) -> jax.Array:
    """The canonical dequant: ``q * scale`` broadcast over ``axis``, fp32.

    Every backend funnels through this one expression (the kernels call it
    on their VMEM-resident refs' values, wrappers and the reference on
    arrays) — the bit-identity contract across backends hinges on it.
    """
    return q.astype(jnp.float32) * jnp.expand_dims(scale, axis)


def fake_quant(w: jax.Array, precision: str, *, axis: int, act_dtype):
    """Quantize→dequantize in one step (reference / step-backend path).

    For the cast precisions this is just ``astype(act_dtype)``; for the
    quantized ones it produces exactly the values the sequence kernel
    dequantizes in-register — same (q, scale), same canonical dequant.
    """
    if precision in QUANTIZED:
        q, s = quantize(w, WEIGHT_BITS[precision], axis=axis)
        return dequantize(q, s, axis=axis).astype(act_dtype)
    return w.astype(act_dtype)


def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int4 codes two-per-byte along the last axis (pad odd lengths).

    ``q`` holds values in [-7, 7] (int8); the result is uint8 of length
    ``ceil(H/2)`` with the even column in the low nibble (two's-complement
    nibbles — ``-3`` stores as ``0xD``).
    """
    if q.shape[-1] % 2:
        q = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, 1)])
    u = q.astype(jnp.uint8)
    lo, hi = u[..., 0::2], u[..., 1::2]
    return (lo & 0xF) | ((hi & 0xF) << 4)


def unpack_int4(packed: jax.Array, n: int) -> jax.Array:
    """Invert :func:`pack_int4`: ``[..., ceil(n/2)] uint8 → [..., n] int8``.

    Pure jnp (works identically inside Pallas kernels and in host code);
    sign-extends each nibble, interleaves low/high and drops the pad
    column when ``n`` is odd.
    """
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    nib = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)
    nib = nib[..., :n]
    return jnp.where(nib >= 8, nib - 16, nib)


def packed_weight(q: jax.Array, bits: int) -> jax.Array:
    """Storage form of a quantized weight: int8 as-is, int4 nibble-packed."""
    return pack_int4(q) if bits == 4 else q


def kernel_weight(w_ref_val: jax.Array, scale: jax.Array, bits: int, *,
                  hidden: int, act_dtype) -> jax.Array:
    """In-register dequant of a VMEM-resident quantized weight operand.

    ``w_ref_val``: the kernel's weight block — ``[D, G, H]`` int8, or
    ``[D, G, ceil(H/2)]`` uint8 when int4-packed.  ``scale``: ``[G, H]``
    fp32.  Returns the ``[D, G, H]`` activation-dtype weights the gate
    matmuls consume — exactly :func:`fake_quant`'s values.
    """
    q = unpack_int4(w_ref_val, hidden) if bits == 4 else w_ref_val
    return dequantize(q, scale, axis=0).astype(act_dtype)


def weight_bytes(in_dim: int, hidden: int, gates: int, precision) -> int:
    """Resident weight bytes for one layer at a precision (VMEM budget math).

    ``wx [I, G, H]`` + ``wh [H, G, H]`` at the storage bit-width, plus the
    two fp32 ``[G, H]`` scale tensors for the quantized precisions, plus
    the fp32 bias.  ``None`` prices as fp32 (native dtypes).
    """
    bits = WEIGHT_BITS.get(precision, 32)
    total = (in_dim + hidden) * gates * hidden * bits // 8
    if precision in QUANTIZED:
        total += 2 * gates * hidden * 4          # per-channel fp32 scales
    total += gates * hidden * 4                  # fp32 bias
    return total

"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors its kernel's *mathematical contract* with no tiling —
tests sweep shapes/dtypes and assert allclose between kernel (interpret=True)
and these references.  Mask bits use the identical counter-PRNG formula, so
agreement is exact on the mask pattern and fp-tolerance on the matmuls.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cells, prng


def _mask(key, rows, n_feat: int, p_drop: float):
    cols = jnp.arange(n_feat, dtype=jnp.uint32)
    idx = rows.astype(jnp.uint32)[:, None] * jnp.uint32(n_feat) + cols
    bits = prng._mix32(jnp.asarray(key, jnp.uint32) ^ prng._mix32(idx))
    return bits >= prng.bernoulli_keep_threshold(p_drop)


def masked_activation(x, rows, key, p_drop: float):
    if p_drop == 0.0:
        return x
    keep = _mask(key, rows, x.shape[1], p_drop)
    scale = jnp.asarray(1.0 / (1.0 - p_drop), x.dtype)
    return jnp.where(keep, x * scale, jnp.zeros_like(x))


def mcd_matmul(x, w, rows, key, p_drop: float):
    xm = masked_activation(x, rows, key, p_drop)
    return jnp.dot(xm, w, preferred_element_type=jnp.float32).astype(x.dtype)


def dequant_weights(wx, wh, b, precision, *, act_dtype):
    """Oracle for the kernels' quantized-weight path (gate-stacked layout).

    Fake-quantizes ``wx [I, G, H]`` / ``wh [H, G, H]`` along the contraction
    axis with the canonical per-output-channel scheme — exactly the values
    ``mcd_lstm_seq``/``mcd_gru_seq`` dequantize in-register from their
    VMEM-resident int codes.  The bias is never quantized (it enters the
    gate sums in fp32 on every path).
    """
    from repro.kernels import quantize
    return (quantize.fake_quant(wx, precision, axis=0, act_dtype=act_dtype),
            quantize.fake_quant(wh, precision, axis=0, act_dtype=act_dtype),
            b)


def decode_attention(q, k_cache, v_cache, pos):
    """q: [B, H, hd]; caches: [B, S, KV, hd]; softmax over positions ≤ pos."""
    B, H, hd = q.shape
    KV = k_cache.shape[2]
    rep = H // KV
    qr = q.reshape(B, KV, rep, hd).astype(jnp.float32)
    s = jnp.einsum("bgrh,bsgh->bgrs", qr, k_cache.astype(jnp.float32)) \
        * hd ** -0.5
    valid = jnp.arange(k_cache.shape[1]) <= pos
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrs,bsgh->bgrh", w, v_cache.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def mcd_lstm_seq(x_seq, wx, wh, b, rows, keys, p_drop: float,
                 h0=None, c0=None, lengths=None):
    """Sequence oracle: scan :func:`mcd_lstm_step` over T from (h0, c0).

    x_seq: [B, T, I]; same weight/key layout as the kernels.  Returns
    (ys [B, T, H], h_T [B, H], c_T [B, H] fp32) — masks tied across T because
    ``keys`` never varies with t.  ``h0``/``c0`` default to zeros (a fresh
    sequence); ``lengths`` [B] freezes each row's state at its own chunk
    length, mirroring the kernel's ragged-batch contract.
    """
    B = x_seq.shape[0]
    H = wh.shape[0]
    h0 = (jnp.zeros((B, H), x_seq.dtype) if h0 is None
          else h0.astype(x_seq.dtype))
    c0 = (jnp.zeros((B, H), jnp.float32) if c0 is None
          else c0.astype(jnp.float32))

    def step(carry, xt):
        h, c = carry
        x_t, t = xt
        h_new, c_new = mcd_lstm_step(x_t, h, c, wx, wh, b, rows, keys, p_drop)
        if lengths is not None:
            h_new, c_new = cells.freeze_rows(t, lengths, h_new, c_new, h, c)
        return (h_new, c_new), h_new

    ts = jnp.arange(x_seq.shape[1], dtype=jnp.int32)
    (hT, cT), ys = jax.lax.scan(step, (h0, c0),
                                (jnp.swapaxes(x_seq, 0, 1), ts))
    return jnp.swapaxes(ys, 0, 1), hT, cT


def mcd_gru_seq(x_seq, wx, wh, b, rows, keys, p_drop: float,
                h0=None, lengths=None):
    """Sequence oracle: scan :func:`mcd_gru_step` over T from h0.

    x_seq: [B, T, I]; wx: [I, 3, H]; wh: [H, 3, H]; b: [3, H]; keys: [1, 6].
    Returns (ys [B, T, H], h_T [B, H]) — the GRU's whole carry is ``h``, in
    the activation dtype.  ``h0`` defaults to zeros; ``lengths`` [B] freezes
    each row's state at its own chunk length, mirroring the kernel.
    """
    B = x_seq.shape[0]
    H = wh.shape[0]
    h0 = (jnp.zeros((B, H), x_seq.dtype) if h0 is None
          else h0.astype(x_seq.dtype))

    def step(h, xt):
        x_t, t = xt
        h_new = mcd_gru_step(x_t, h, wx, wh, b, rows, keys, p_drop)
        if lengths is not None:
            h_new = cells.freeze_rows_h(t, lengths, h_new, h)
        return h_new, h_new

    ts = jnp.arange(x_seq.shape[1], dtype=jnp.int32)
    hT, ys = jax.lax.scan(step, h0, (jnp.swapaxes(x_seq, 0, 1), ts))
    return jnp.swapaxes(ys, 0, 1), hT


def mcd_gru_step(x, h, wx, wh, b, rows, keys, p_drop: float):
    """wx: [I, 3, H]; wh: [H, 3, H]; b: [3, H]; keys: [1, 6] (r, z, n)."""
    gx, gh = [], []
    det = (rows.astype(jnp.int32) < 0)[:, None]   # student (deterministic)
    for g in range(3):
        if p_drop > 0.0:
            sx = jnp.asarray(1.0 / (1.0 - p_drop), x.dtype)
            xg = jnp.where(_mask(keys[0, g], rows, x.shape[1], p_drop),
                           x * sx, 0.0)
            hg = jnp.where(_mask(keys[0, 3 + g], rows, h.shape[1], p_drop),
                           h * sx, 0.0)
            xg = jnp.where(det, x, xg)
            hg = jnp.where(det, h, hg)
        else:
            xg, hg = x, h
        gx.append(jnp.dot(xg, wx[:, g, :], preferred_element_type=jnp.float32))
        gh.append(jnp.dot(hg, wh[:, g, :], preferred_element_type=jnp.float32))
    r = jax.nn.sigmoid(gx[0] + gh[0] + b[0].astype(jnp.float32))
    z = jax.nn.sigmoid(gx[1] + gh[1] + b[1].astype(jnp.float32))
    n = jnp.tanh(gx[2] + r * gh[2] + b[2].astype(jnp.float32))
    h_new = (1.0 - z) * n + z * h.astype(jnp.float32)
    return h_new.astype(h.dtype)


def mcd_lstm_step(x, h, c, wx, wh, b, rows, keys, p_drop: float):
    """wx: [I, 4, H]; wh: [H, 4, H]; b: [4, H]; keys: [1, 8]."""
    gates = []
    det = (rows.astype(jnp.int32) < 0)[:, None]   # student (deterministic)
    for g in range(4):
        if p_drop > 0.0:
            sx = jnp.asarray(1.0 / (1.0 - p_drop), x.dtype)
            xg = jnp.where(_mask(keys[0, g], rows, x.shape[1], p_drop),
                           x * sx, 0.0)
            hg = jnp.where(_mask(keys[0, 4 + g], rows, h.shape[1], p_drop),
                           h * sx, 0.0)
            xg = jnp.where(det, x, xg)
            hg = jnp.where(det, h, hg)
        else:
            xg, hg = x, h
        acc = jnp.dot(xg, wx[:, g, :], preferred_element_type=jnp.float32) \
            + jnp.dot(hg, wh[:, g, :], preferred_element_type=jnp.float32) \
            + b[g].astype(jnp.float32)
        gates.append(acc)
    i = jax.nn.sigmoid(gates[0])
    f = jax.nn.sigmoid(gates[1])
    g_ = jnp.tanh(gates[2])
    o = jax.nn.sigmoid(gates[3])
    c_new = f * c.astype(jnp.float32) + i * g_
    h_new = (o * jnp.tanh(c_new)).astype(h.dtype)
    return h_new, c_new.astype(c.dtype)

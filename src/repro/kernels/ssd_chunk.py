"""Pallas TPU kernel: fused Mamba2/SSD chunk scan.

EXPERIMENTS.md §Perf Cell B found the jnp SSD memory-bound on its fp32
intermediates (dtx, decay, y_intra are materialized per chunk ×72 layers).
This kernel is the identified fix: the whole chunk pipeline — cumulative
log-decays, intra-chunk (quadratic) attention-like term, inter-chunk state
recurrence — runs in VMEM per (batch, head-block), streaming x/dt/B/C blocks
from HBM exactly once and carrying the [bh, P, N] state in scratch across the
sequential chunk dimension.  n_groups=1 (the assigned mamba2/jamba configs).

Grid: (B, H/bh, L/Q) with the chunk axis "arbitrary" (sequential).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, hout_ref,
            state_ref, *, q_chunk: int, grid_c: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # [Q, bh, P]
    dt = dt_ref[0].astype(jnp.float32)        # [Q, bh]
    a = a_ref[0].astype(jnp.float32)          # [bh]
    bm = b_ref[0].astype(jnp.float32)         # [Q, N]
    cm = c_ref[0].astype(jnp.float32)         # [Q, N]
    d_skip = d_ref[0].astype(jnp.float32)     # [bh]

    l = dt * a[None, :]                       # [Q, bh] log-decay per step
    cs = jnp.cumsum(l, axis=0)                # inclusive
    dtx = dt[..., None] * x                   # [Q, bh, P]

    # --- intra-chunk quadratic term ------------------------------------
    scores = jnp.einsum("qn,kn->qk", cm, bm)                  # [Q, Q]
    decay = jnp.exp(cs[:, None, :] - cs[None, :, :])          # [Q, Q, bh]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q_chunk, q_chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q_chunk, q_chunk), 1)
    gate = jnp.where(tri[..., None], decay, 0.0)              # [Q, Q, bh]
    y = jnp.einsum("qk,qkh,khp->qhp", scores, gate, dtx)

    # --- inter-chunk contribution from carried state --------------------
    state = state_ref[...]                                    # [bh, P, N]
    cin = jnp.exp(cs)                                         # [Q, bh]
    y += jnp.einsum("qn,qh,hpn->qhp", cm, cin, state)

    # --- state update ----------------------------------------------------
    dec_end = jnp.exp(cs[-1:, :] - cs)                        # [Q, bh]
    new_state = state * jnp.exp(cs[-1])[:, None, None] \
        + jnp.einsum("qn,qh,qhp->hpn", bm, dec_end, dtx)
    state_ref[...] = new_state

    y_ref[0] = (y + d_skip[None, :, None] * x).astype(y_ref.dtype)

    @pl.when(c_idx == grid_c - 1)
    def _store_state():
        hout_ref[0] = new_state.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q_chunk", "block_h",
                                             "interpret"))
def ssd_chunk_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
                   cm: jax.Array, d_skip: jax.Array, *, q_chunk: int = 256,
                   block_h: int = 8, interpret: bool = True):
    """Fused SSD scan (n_groups=1).

    x: [B, L, H, P]; dt: [B, L, H] (post-softplus); a: [H] (negative);
    bm, cm: [B, L, N]; d_skip: [H].
    Returns (y [B, L, H, P], final state [B, H, P, N] fp32).
    """
    B, L, H, P = x.shape
    N = bm.shape[-1]
    q = min(q_chunk, L)
    while L % q:
        q -= 1
    bh = min(block_h, H)
    while H % bh:
        bh -= 1
    grid = (B, H // bh, L // q)
    a2 = jnp.asarray(a).reshape(1, H)
    d2 = jnp.asarray(d_skip).reshape(1, H)
    y, h_final = pl.pallas_call(
        functools.partial(_kernel, q_chunk=q, grid_c=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, bh, P), lambda b, h, c: (b, c, h, 0)),  # x
            pl.BlockSpec((1, q, bh), lambda b, h, c: (b, c, h)),        # dt
            pl.BlockSpec((1, bh), lambda b, h, c: (0, h)),              # a
            pl.BlockSpec((1, q, N), lambda b, h, c: (b, c, 0)),         # B
            pl.BlockSpec((1, q, N), lambda b, h, c: (b, c, 0)),         # C
            pl.BlockSpec((1, bh), lambda b, h, c: (0, h)),              # D
        ],
        out_specs=[
            pl.BlockSpec((1, q, bh, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, bh, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bh, P, N), jnp.float32)],
        compiler_params=compat.compiler_params("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(x, dt, a2, bm, cm, d2)
    return y, h_final

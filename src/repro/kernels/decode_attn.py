"""Pallas TPU kernel: fused single-token (decode) attention over a KV cache.

The hot spot of Bayesian serving (EXPERIMENTS.md §Perf Cell C): one query
token attends over a seq_len-sized cache.  The kernel streams cache blocks
HBM→VMEM once, keeping the online-softmax running (max, denom, acc) in VMEM
scratch — no score tensor, no cache round-trips, and GQA handled by grouping
query heads with their KV head.

Grid: (batch, seq_blocks); the seq dimension is "arbitrary" (sequential) so
scratch carries across blocks; positions beyond `pos` are masked.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_s: int, grid_s: int, kv_heads: int, rep: int, hd: int):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0, 0]
    q = q_ref[0].reshape(kv_heads, rep, hd).astype(jnp.float32)   # [KV,rep,hd]
    k = k_ref[0].astype(jnp.float32)                              # [bs,KV,hd]
    v = v_ref[0].astype(jnp.float32)
    scale = hd ** -0.5
    s = jnp.einsum("grh,sgh->grs", q, k) * scale                  # [KV,rep,bs]
    j = jax.lax.broadcasted_iota(jnp.int32, (kv_heads, rep, block_s), 2) \
        + s_idx * block_s
    s = jnp.where(j <= pos, s, -jnp.inf)

    m_prev = m_ref[...]                                           # [KV,rep]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] \
        + jnp.einsum("grs,sgh->grh", p, v)

    @pl.when(s_idx == grid_s - 1)
    def _store():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = out.reshape(kv_heads * rep, hd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, block_s: int = 512,
                     interpret: bool = True) -> jax.Array:
    """q: [B, H, hd] (post-RoPE); caches: [B, S, KV, hd]; pos: scalar.

    Returns [B, H, hd] attention output (softmax over positions ≤ pos).
    """
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    rep = H // KV
    bs = min(block_s, S)
    while S % bs:
        bs -= 1
    grid = (B, S // bs)
    pos2 = jnp.asarray(pos, jnp.int32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_kernel, block_s=bs, grid_s=grid[1], kv_heads=KV,
                          rep=rep, hd=hd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, s: (0, 0)),           # pos
            pl.BlockSpec((1, H, hd), lambda b, s: (b, 0, 0)),    # q
            pl.BlockSpec((1, bs, KV, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, bs, KV, hd), lambda b, s: (b, s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((KV, rep), jnp.float32),
            pltpu.VMEM((KV, rep), jnp.float32),
            pltpu.VMEM((KV, rep, hd), jnp.float32),
        ],
        compiler_params=compat.compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(pos2, q, k_cache, v_cache)

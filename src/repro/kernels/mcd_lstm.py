"""Pallas TPU kernel: fused Bayesian LSTM cell step — the paper's Fig. 2.

One kernel = the whole per-timestep datapath of the paper's accelerator:
  Bernoulli samplers (counter PRNG in VMEM)  →  DX per-gate masking of x and
  h  →  4 gate MVMs on the MXU  →  σ/tanh + elementwise tail  →  (h_t, c_t).

Grid: (B/bb, H/bh).  Each program instance computes all four gates for its
hidden tile so the elementwise tail fuses locally (the paper's "LSTM tail"
unit).  Weights are laid out [I, 4, H] / [H, 4, H] so a tile loads the
contiguous gate stack for its hidden columns.  The cell state is carried in
fp32 (paper: c in 32-bit, everything else 16-bit).

Mask semantics are bit-identical to :func:`repro.core.mcd.lstm_gate_masks`
(kind ∈ {KIND_X, KIND_H}, gate ∈ {i,f,g,o}, index = row·feat_dim + col), so
this kernel, the jnp reference, and any sharded layout of either all compute
the same Bayesian draw.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import mcd, prng
from repro.kernels import compat


def _gate_mask(key, rows, cols0, shape, feat_dim: int, p_drop: float):
    cols = jax.lax.broadcasted_iota(jnp.uint32, shape, 1) + jnp.uint32(cols0)
    idx = rows[:, None].astype(jnp.uint32) * jnp.uint32(feat_dim) + cols
    bits = prng._mix32(jnp.asarray(key, jnp.uint32) ^ prng._mix32(idx))
    return bits >= prng.bernoulli_keep_threshold(p_drop)


def _kernel(rows_ref, keys_ref, x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref,
            ho_ref, co_ref, *, p_drop: float, in_dim: int, hidden: int):
    rows = rows_ref[...][:, 0]
    x = x_ref[...]                  # [bb, I]
    h = h_ref[...]                  # [bb, H]
    # Rows are int32 in-kernel, so the student flag (mcd.STUDENT_ROW_FLAG,
    # the uint32 high bit) is simply the sign bit: negative row = run this
    # row deterministic (dropout off), leaving every other row's draw alone.
    det = (rows < 0)[:, None]
    gates = []
    scale = jnp.asarray(1.0 / (1.0 - p_drop), x.dtype) if p_drop > 0 else None
    for g in range(4):
        xg, hg = x, h
        if p_drop > 0.0:
            kx = keys_ref[0, g]     # key for (layer, KIND_X, gate g)
            kh = keys_ref[0, 4 + g]
            mx = _gate_mask(kx, rows, 0, x.shape, in_dim, p_drop)
            mh = _gate_mask(kh, rows, 0, h.shape, hidden, p_drop)
            xg = jnp.where(mx, x * scale, jnp.zeros_like(x))
            hg = jnp.where(mh, h * scale, jnp.zeros_like(h))
            xg = jnp.where(det, x, xg)
            hg = jnp.where(det, h, hg)
        acc = jnp.dot(xg, wx_ref[:, g, :], preferred_element_type=jnp.float32)
        acc += jnp.dot(hg, wh_ref[:, g, :], preferred_element_type=jnp.float32)
        gates.append(acc + b_ref[g, :].astype(jnp.float32))
    i = jax.nn.sigmoid(gates[0])
    f = jax.nn.sigmoid(gates[1])
    g_ = jnp.tanh(gates[2])
    o = jax.nn.sigmoid(gates[3])
    c_new = f * c_ref[...].astype(jnp.float32) + i * g_
    co_ref[...] = c_new.astype(co_ref.dtype)
    ho_ref[...] = (o * jnp.tanh(c_new)).astype(ho_ref.dtype)


def gate_keys(seed, layer) -> jax.Array:
    """The 8 per-gate stream keys (x-side then h-side), shape [1, 8] uint32."""
    ks = [mcd.mask_key(seed, layer, mcd.KIND_X, g) for g in range(4)] + \
         [mcd.mask_key(seed, layer, mcd.KIND_H, g) for g in range(4)]
    return jnp.stack([jnp.asarray(k, jnp.uint32) for k in ks]).reshape(1, 8)


@functools.partial(jax.jit, static_argnames=("p_drop", "block_b", "block_h",
                                             "interpret"))
def mcd_lstm_step(x: jax.Array, h: jax.Array, c: jax.Array, wx: jax.Array,
                  wh: jax.Array, b: jax.Array, rows: jax.Array,
                  keys: jax.Array, p_drop: float, *, block_b: int = 128,
                  block_h: int = 256, interpret: bool = True):
    """Fused Bayesian LSTM step.

    x: [B, I]; h, c: [B, H]; wx: [I, 4, H]; wh: [H, 4, H]; b: [4, H];
    rows: [B] mask row ids; keys: [1, 8] from :func:`gate_keys`.
    Returns (h_new [B, H], c_new [B, H] fp32).
    """
    B, I = x.shape
    H = h.shape[1]
    bb, bh = min(block_b, B), min(block_h, H)
    assert H % bh == 0, (H, bh)
    rows2 = rows.astype(jnp.int32).reshape(B, 1)
    pad = -B % bb        # pad to the block multiple (odd serving batches),
    if pad:              # same fallback as the sequence kernel
        zb = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        x, h, c, rows2 = map(zb, (x, h, c, rows2))
    Bp = B + pad
    grid = (Bp // bb, H // bh)
    out = pl.pallas_call(
        functools.partial(_kernel, p_drop=p_drop, in_dim=I, hidden=H),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),      # rows
            pl.BlockSpec((1, 8), lambda i, j: (0, 0)),       # keys
            pl.BlockSpec((bb, I), lambda i, j: (i, 0)),      # x
            pl.BlockSpec((bb, H), lambda i, j: (i, 0)),      # h (full row)
            pl.BlockSpec((bb, bh), lambda i, j: (i, j)),     # c tile
            pl.BlockSpec((I, 4, bh), lambda i, j: (0, 0, j)),  # wx
            pl.BlockSpec((H, 4, bh), lambda i, j: (0, 0, j)),  # wh
            pl.BlockSpec((4, bh), lambda i, j: (0, j)),      # bias
        ],
        out_specs=[
            pl.BlockSpec((bb, bh), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bh), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, H), h.dtype),
            jax.ShapeDtypeStruct((Bp, H), c.dtype),
        ],
        compiler_params=compat.compiler_params("parallel", "parallel"),
        interpret=interpret,
    )(rows2, keys, x, h, c, wx, wh, b)
    if pad:
        out = [o[:B] for o in out]
    return out

"""Pallas TPU kernel: fused Bayesian GRU cell step (paper §III-A drop-in).

The paper's per-gate MCD design "can be used for other recurrent units such
as the gated recurrent unit" — this kernel is that drop-in: the same fused
datapath as :mod:`repro.kernels.mcd_lstm` with three gates instead of four
and no cell state (the GRU's whole recurrent carry is ``h``):

  Bernoulli samplers (counter PRNG, in-register)  →  DX per-gate masking of
  x and h  →  3 gate MVMs on the MXU (x- and h-side kept separate — the
  reset gate multiplies only the *recurrent* candidate matmul)  →  σ/tanh
  convex-update tail  →  h_t.

Grid: (B/bb, H/bh).  As in the LSTM step kernel each program computes all
gates for its hidden tile; ``h`` arrives twice — full-width for the
recurrent matmuls and tiled for the ``z·h`` convex update (the LSTM kernel's
``c`` tile, played by ``h`` itself here).  The update runs in fp32 and only
the stored ``h_t`` rounds to the activation dtype — the bf16-in /
fp32-accumulate policy of :func:`repro.core.cells.gru_step`.

Mask semantics are bit-identical to :func:`repro.core.mcd.gru_gate_masks`
(kind ∈ {KIND_X, KIND_H}, gate ∈ {r, z, n} = 0..2, index = row·feat_dim +
col), so this kernel, the jnp reference, and any tiling of either all
compute the same Bayesian draw.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import mcd
from repro.kernels import compat
from repro.kernels.mcd_lstm import _gate_mask


def _gru_update(x, h, h_prev, rows, keys_ref, wx_ref, wh_ref, b_ref, *,
                p_drop: float, in_dim: int, hidden: int):
    """The fused 3-gate GRU body, shared by the step and sequence kernels.

    ``h`` feeds the recurrent matmuls (must be the full hidden width);
    ``h_prev`` feeds the ``z·h`` convex update — the step kernel passes its
    *hidden tile* there, the sequence kernel passes ``h`` itself.  Returns
    h_new in fp32; numerics match :func:`repro.core.cells.gru_step` exactly
    (bit-identity across the kernels hinges on this single definition).
    """
    gx, gh = [], []
    # int32 rows: a negative id carries mcd.STUDENT_ROW_FLAG — run that row
    # deterministic (dropout off) without touching its neighbours' draw.
    det = (rows < 0)[:, None]
    scale = jnp.asarray(1.0 / (1.0 - p_drop), x.dtype) if p_drop > 0 else None
    for g in range(3):
        xg, hg = x, h
        if p_drop > 0.0:
            kx = keys_ref[0, g]     # key for (layer, KIND_X, gate g)
            kh = keys_ref[0, 3 + g]
            mx = _gate_mask(kx, rows, 0, x.shape, in_dim, p_drop)
            mh = _gate_mask(kh, rows, 0, h.shape, hidden, p_drop)
            xg = jnp.where(mx, x * scale, jnp.zeros_like(x))
            hg = jnp.where(mh, h * scale, jnp.zeros_like(h))
            xg = jnp.where(det, x, xg)
            hg = jnp.where(det, h, hg)
        # x- and h-side accumulators stay separate: the reset gate scales
        # gh[2] alone, before the candidate bias lands (cells.gru_step).
        gx.append(jnp.dot(xg, wx_ref[:, g, :],
                          preferred_element_type=jnp.float32))
        gh.append(jnp.dot(hg, wh_ref[:, g, :],
                          preferred_element_type=jnp.float32))
    r = jax.nn.sigmoid(gx[0] + gh[0] + b_ref[0, :].astype(jnp.float32))
    z = jax.nn.sigmoid(gx[1] + gh[1] + b_ref[1, :].astype(jnp.float32))
    n = jnp.tanh(gx[2] + r * gh[2] + b_ref[2, :].astype(jnp.float32))
    return (1.0 - z) * n + z * h_prev.astype(jnp.float32)


def _kernel(rows_ref, keys_ref, x_ref, h_ref, ht_ref, wx_ref, wh_ref, b_ref,
            ho_ref, *, p_drop: float, in_dim: int, hidden: int):
    rows = rows_ref[...][:, 0]
    x = x_ref[...]                  # [bb, I]
    h = h_ref[...]                  # [bb, H] — full row for the matmuls
    h_new = _gru_update(x, h, ht_ref[...], rows, keys_ref, wx_ref, wh_ref,
                        b_ref, p_drop=p_drop, in_dim=in_dim, hidden=hidden)
    ho_ref[...] = h_new.astype(ho_ref.dtype)


def gate_keys(seed, layer) -> jax.Array:
    """The 6 per-gate stream keys (x-side then h-side), shape [1, 6] uint32."""
    ks = [mcd.mask_key(seed, layer, mcd.KIND_X, g) for g in range(3)] + \
         [mcd.mask_key(seed, layer, mcd.KIND_H, g) for g in range(3)]
    return jnp.stack([jnp.asarray(k, jnp.uint32) for k in ks]).reshape(1, 6)


@functools.partial(jax.jit, static_argnames=("p_drop", "block_b", "block_h",
                                             "interpret"))
def mcd_gru_step(x: jax.Array, h: jax.Array, wx: jax.Array, wh: jax.Array,
                 b: jax.Array, rows: jax.Array, keys: jax.Array,
                 p_drop: float, *, block_b: int = 128, block_h: int = 256,
                 interpret: bool = True):
    """Fused Bayesian GRU step.

    x: [B, I]; h: [B, H]; wx: [I, 3, H]; wh: [H, 3, H]; b: [3, H];
    rows: [B] mask row ids; keys: [1, 6] from :func:`gate_keys`.
    Returns h_new [B, H].
    """
    B, I = x.shape
    H = h.shape[1]
    bb, bh = min(block_b, B), min(block_h, H)
    assert H % bh == 0, (H, bh)
    rows2 = rows.astype(jnp.int32).reshape(B, 1)
    pad = -B % bb        # pad to the block multiple (odd serving batches),
    if pad:              # same fallback as the LSTM kernels
        zb = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        x, h, rows2 = map(zb, (x, h, rows2))
    Bp = B + pad
    grid = (Bp // bb, H // bh)
    out = pl.pallas_call(
        functools.partial(_kernel, p_drop=p_drop, in_dim=I, hidden=H),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),      # rows
            pl.BlockSpec((1, 6), lambda i, j: (0, 0)),       # keys
            pl.BlockSpec((bb, I), lambda i, j: (i, 0)),      # x
            pl.BlockSpec((bb, H), lambda i, j: (i, 0)),      # h (full row)
            pl.BlockSpec((bb, bh), lambda i, j: (i, j)),     # h tile (z·h)
            pl.BlockSpec((I, 3, bh), lambda i, j: (0, 0, j)),  # wx
            pl.BlockSpec((H, 3, bh), lambda i, j: (0, 0, j)),  # wh
            pl.BlockSpec((3, bh), lambda i, j: (0, j)),      # bias
        ],
        out_specs=pl.BlockSpec((bb, bh), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, H), h.dtype),
        compiler_params=compat.compiler_params("parallel", "parallel"),
        interpret=interpret,
    )(rows2, keys, x, h, h, wx, wh, b)
    return out[:B] if pad else out

"""Pallas TPU kernel: sequence-fused Bayesian GRU layer.

The GRU counterpart of :mod:`repro.kernels.mcd_lstm_seq` — same residency
story (weights fetched into VMEM once, the sequence streams through the
resident datapath), same streaming contract, one structural difference: the
GRU's entire recurrent state is ``h``, so there is a single VMEM scratch
carry and a single carried-state operand.

* Grid ``(B/bb, T)`` with time as an ``"arbitrary"`` (sequential) dimension;
  the weight BlockSpecs map every grid step to the same block so
  ``wx [I,3,H]`` / ``wh [H,3,H]`` are fetched once; only the ``[bb, 1, I]``
  input slice streams per step.
* ``h`` lives in VMEM scratch across grid steps (seeded from ``h0`` at
  ``t == 0``), stored in the activation dtype each step — exactly the
  per-step rounding of :func:`repro.core.cells.gru_step`, which is what
  makes a chunk boundary (bf16 ``h`` out, bf16 ``h`` back in) lossless and
  chunked == unchunked bit-identical.  The gate math runs in fp32.
* The 3-gate Bernoulli keep-masks (r, z, n) are recomputed in-register each
  step from the 6 ``gate_keys`` streams; keys carry no time coordinate, so
  recomputation is the paper's tied-across-T semantics.
* ``lengths`` freezes a row's ``h`` once ``t >= lengths[row]`` (ragged
  chunks pad to a common T, each row comes back at its own last real step);
  ``block_b`` pads a non-dividing batch up to the block multiple.

No hidden-tile grid axis, for the same dependency reason as the LSTM
sequence kernel (docs/kernels.md): step t needs all H columns of
``h_{t-1}`` — and for the GRU twice over, since ``h`` feeds both the
recurrent matmuls and the ``z·h`` convex update.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat, quantize
from repro.kernels.mcd_gru import _gru_update


def _kernel(*refs, p_drop: float, in_dim: int, hidden: int, varlen: bool,
            weight_bits: int | None):
    # Quantized runs insert two [3, H] fp32 scale operands after the weights;
    # everything else (ref order, outputs, scratch) is unchanged.
    if weight_bits is None:
        (rows_ref, keys_ref, lens_ref, x_ref, h0_ref, wx_ref, wh_ref,
         b_ref, ys_ref, ht_ref, h_scr) = refs
    else:
        (rows_ref, keys_ref, lens_ref, x_ref, h0_ref, wx_ref, wh_ref,
         sx_ref, sh_ref, b_ref, ys_ref, ht_ref, h_scr) = refs
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _reset():
        # Carried-state entry point: a fresh sequence passes zeros here; a
        # resumed session passes the previous chunk's h_T.
        h_scr[...] = h0_ref[...]

    rows = rows_ref[...][:, 0]
    x = x_ref[:, 0, :]              # [bb, I] — this step's input slice
    h = h_scr[...]                  # [bb, H] — carried entirely in VMEM
    if weight_bits is None:
        wxv, whv = wx_ref[...], wh_ref[...]
    else:
        # In-register dequant of the int-resident weights: the canonical
        # q·scale expression (repro.kernels.quantize), cast to the activation
        # dtype — exactly the values fake_quant hands the other backends.
        wxv = quantize.kernel_weight(wx_ref[...], sx_ref[...], weight_bits,
                                     hidden=hidden, act_dtype=x.dtype)
        whv = quantize.kernel_weight(wh_ref[...], sh_ref[...], weight_bits,
                                     hidden=hidden, act_dtype=x.dtype)
    # Gate body shared with the step kernel; the keys are t-independent so
    # recomputing the masks here every step *is* tying them across time.
    h_new = _gru_update(x, h, h, rows, keys_ref, wxv, whv, b_ref,
                        p_drop=p_drop, in_dim=in_dim,
                        hidden=hidden).astype(h_scr.dtype)
    if varlen:
        # Rows whose chunk ended before this step keep their carried state —
        # the final h_T output is each row's state at its own length.
        live = t < lens_ref[...]                  # [bb, 1]
        h_new = jnp.where(live, h_new, h_scr[...])
    h_scr[...] = h_new
    ys_ref[:, 0, :] = h_new.astype(ys_ref.dtype)
    ht_ref[...] = h_new.astype(ht_ref.dtype)


@functools.partial(jax.jit, static_argnames=("p_drop", "block_b", "interpret",
                                             "weight_bits"))
def mcd_gru_seq(x_seq: jax.Array, wx: jax.Array, wh: jax.Array, b: jax.Array,
                rows: jax.Array, keys: jax.Array, p_drop: float, *,
                h0: jax.Array | None = None,
                lengths: jax.Array | None = None,
                weight_bits: int | None = None,
                wx_scale: jax.Array | None = None,
                wh_scale: jax.Array | None = None,
                block_b: int = 128, interpret: bool = True):
    """Sequence-fused Bayesian GRU layer, optionally resuming carried state.

    x_seq: [B, T, I]; wx: [I, 3, H]; wh: [H, 3, H]; b: [3, H];
    rows: [B] mask row ids; keys: [1, 6] from
    :func:`repro.kernels.mcd_gru.gate_keys`.
    h0 [B, H] seeds the carried state (zeros when omitted — a fresh
    sequence); it round-trips in the activation dtype, the GRU's only carry.
    lengths [B] (int) freezes a row's state at its own chunk length so ragged
    chunks can pad to a common T in one launch.
    weight_bits 8/4 switches to quantized weights: ``wx``/``wh`` carry int8
    codes (int4: nibble-packed uint8, last axis ``ceil(H/2)``) and
    ``wx_scale``/``wh_scale`` the [3, H] fp32 per-output-channel scales; the
    kernel dequantizes in-register, so the VMEM-resident weight bytes drop
    ~2×/4× vs bf16 while the gate math stays fp32-accumulated.
    Returns (ys [B, T, H], h_T [B, H]); with ``lengths``, h_T is each row's
    state at ``t = lengths[row]`` and ``ys[:, t >= lengths[row]]`` repeats
    the frozen h.
    """
    B, T, I = x_seq.shape
    H = wh.shape[0]
    if weight_bits is not None and (wx_scale is None or wh_scale is None):
        raise ValueError("weight_bits set but wx_scale/wh_scale missing")
    bb = min(block_b, B)
    varlen = lengths is not None
    h0 = jnp.zeros((B, H), x_seq.dtype) if h0 is None else h0.astype(x_seq.dtype)
    lens = (jnp.full((B,), T, jnp.int32) if lengths is None
            else lengths.astype(jnp.int32))
    rows2 = rows.astype(jnp.int32).reshape(B, 1)
    pad = -B % bb        # pad to the block multiple (prime/odd batch sizes)
    if pad:
        zb = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        x_seq, rows2, h0, lens = map(zb, (x_seq, rows2, h0, lens))
    Bp = B + pad
    lens2 = lens.reshape(Bp, 1)
    grid = (Bp // bb, T)
    Wl = wx.shape[-1]    # H, or ceil(H/2) when int4 nibble-packed
    w_specs = [
        pl.BlockSpec((I, 3, Wl), lambda i, t: (0, 0, 0)),      # wx — resident
        pl.BlockSpec((H, 3, Wl), lambda i, t: (0, 0, 0)),      # wh — resident
    ]
    w_ops = (wx, wh)
    if weight_bits is not None:
        w_specs += [pl.BlockSpec((3, H), lambda i, t: (0, 0)),  # wx scales
                    pl.BlockSpec((3, H), lambda i, t: (0, 0))]  # wh scales
        w_ops += (wx_scale, wh_scale)
    ys, hT = pl.pallas_call(
        functools.partial(_kernel, p_drop=p_drop, in_dim=I, hidden=H,
                          varlen=varlen, weight_bits=weight_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, 1), lambda i, t: (i, 0)),        # rows
            pl.BlockSpec((1, 6), lambda i, t: (0, 0)),         # keys
            pl.BlockSpec((bb, 1), lambda i, t: (i, 0)),        # lengths
            pl.BlockSpec((bb, 1, I), lambda i, t: (i, t, 0)),  # x_t slice
            pl.BlockSpec((bb, H), lambda i, t: (i, 0)),        # h0
            *w_specs,
            pl.BlockSpec((3, H), lambda i, t: (0, 0)),         # bias
        ],
        out_specs=[
            pl.BlockSpec((bb, 1, H), lambda i, t: (i, t, 0)),
            pl.BlockSpec((bb, H), lambda i, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, T, H), x_seq.dtype),
            jax.ShapeDtypeStruct((Bp, H), x_seq.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb, H), x_seq.dtype),    # h carry — the whole state
        ],
        compiler_params=compat.compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(rows2, keys, lens2, x_seq, h0, *w_ops, b)
    if pad:
        ys, hT = ys[:B], hT[:B]
    return ys, hT

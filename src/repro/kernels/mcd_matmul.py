"""Pallas TPU kernel: fused MCD-mask + matmul (DX unit feeding the MVM).

y = (x ⊙ z / (1-p)) @ W, with z generated in VMEM per x-tile from the counter
PRNG — the masked operand never exists in HBM.  K-tiled with an fp32 VMEM
accumulator; MXU dims default to 128/256 multiples.

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary"), accumulate in scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import prng
from repro.kernels import compat


def _kernel(rows_ref, key_ref, x_ref, w_ref, o_ref, acc_ref, *,
            p_drop: float, k_dim: int, block_k: int, grid_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    if p_drop > 0.0:
        rows = rows_ref[...][:, 0]
        key = key_ref[0, 0]
        cols = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 1) \
            + k.astype(jnp.uint32) * jnp.uint32(block_k)
        idx = rows[:, None].astype(jnp.uint32) * jnp.uint32(k_dim) + cols
        bits = prng._mix32(key ^ prng._mix32(idx))
        keep = bits >= prng.bernoulli_keep_threshold(p_drop)
        scale = jnp.asarray(1.0 / (1.0 - p_drop), x.dtype)
        x = jnp.where(keep, x * scale, jnp.zeros_like(x))
    acc_ref[...] += jnp.dot(x, w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == grid_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("p_drop", "block_m", "block_n",
                                             "block_k", "interpret"))
def mcd_matmul(x: jax.Array, w: jax.Array, rows: jax.Array, key: jax.Array,
               p_drop: float, *, block_m: int = 256, block_n: int = 256,
               block_k: int = 512, interpret: bool = True) -> jax.Array:
    """x: [M, K], w: [K, N], rows: [M] → [M, N] (fp32-accumulated)."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, bm, N, bn, K, bk)
    grid = (M // bm, N // bn, K // bk)
    rows2 = rows.astype(jnp.int32).reshape(M, 1)
    key2 = jnp.asarray(key, jnp.uint32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_kernel, p_drop=p_drop, k_dim=K, block_k=bk,
                          grid_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compat.compiler_params("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(rows2, key2, x, w)

"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel <name>.py carries explicit BlockSpec VMEM tiling; ops.py holds
the jit'd wrappers; ref.py the pure-jnp oracles the tests assert against
(interpret=True on CPU; native lowering on TPU).

  bernoulli_mask  counter-PRNG mask generate+apply (the paper's LFSR + DX)
  mcd_matmul      fused MCD mask + matmul (K-tiled, fp32 VMEM accumulator)
  mcd_lstm        fused Bayesian LSTM cell step (the paper's Fig. 2 datapath)
  mcd_lstm_seq    sequence-fused Bayesian LSTM layer — weights VMEM-resident
                  across all T timesteps (the paper's Fig. 5 wave pipelining)
  decode_attn     flash-decode attention over the KV cache (serving hot path)
  ssd_chunk       fused Mamba2/SSD chunk scan (VMEM-resident chunk state)
  quantize        per-channel int8/int4 weight quantization for the serving
                  path — packed codes + scales dequantized in-register by
                  the sequence kernels (the ``precision`` knob)

compat.py shims Pallas/sharding API names across jax releases; ops.py exposes
the ``LSTM_BACKENDS`` dispatch consumed by ``repro.core.rnn.run_stack``.
"""

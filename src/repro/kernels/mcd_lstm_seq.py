"""Pallas TPU kernel: sequence-fused Bayesian LSTM layer — the paper's Fig. 5.

:mod:`repro.kernels.mcd_lstm` fuses one *timestep* of the Bayesian LSTM
datapath; scanning it over T re-enters the kernel per step and re-fetches the
gate weights every iteration — exactly the weight-traffic the paper's FPGA
avoids by keeping the datapath resident while the sequence streams through
(wave pipelining).  This kernel is the TPU analogue of that residency:

* Grid ``(B/bb, T)`` with time as an ``"arbitrary"`` (sequential) dimension.
  The weight BlockSpecs map every grid step to the same block, so Pallas's
  revisiting semantics fetch ``wx [I,4,H]`` / ``wh [H,4,H]`` into VMEM
  **once**; only the ``[bb, 1, I]`` input slice streams per step.
* ``(h, c)`` live in VMEM scratch across grid steps (reset at ``t == 0``),
  with ``c`` in fp32 — the paper's 32-bit cell-state policy.
* The per-gate Bernoulli keep-masks are recomputed in-register each step from
  the counter PRNG.  Masks are tied across T (paper §II-B), so the 8 stream
  keys from :func:`repro.kernels.mcd_lstm.gate_keys` never change and every
  step reproduces bit-identical masks — same draws as the per-step kernel and
  the jnp reference.

Unlike the step kernel there is no hidden-tile grid axis: timestep t needs
*all* H columns of ``h_{t-1}`` for the recurrent matmul, so tiling H across
sequentially-revisited grid programs would either break the dependency
(time-innermost order) or re-fetch weights per step (tile-innermost order).
One program therefore owns the full hidden width of its batch tile — fine for
the paper's RNN regime (H up to a few hundred; weights ≈ 8·H·(I+H) bytes of
VMEM in bf16).

Streaming extensions (continuous-monitoring serving):

* ``h0`` / ``c0`` seed the scratch at ``t == 0`` instead of zeros, so a
  session resumes mid-sequence exactly where a previous chunk left off.
  ``c0`` is consumed in fp32 — the fp32 cell state round-trips losslessly
  across chunk boundaries, keeping chunked == unchunked bit-identical.
* ``lengths`` freezes a row's ``(h, c)`` once ``t >= lengths[row]``: ragged
  chunks from concurrent sessions pad to a common T and still come back with
  each row's state at *its own* last real step, in one launch.
* A ``block_b`` that does not divide B pads the batch up to the next block
  multiple (outputs sliced back) instead of degrading to ``bb = 1`` for prime
  batch sizes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat, quantize
from repro.kernels.mcd_lstm import _gate_mask


def _kernel(*refs,
            p_drop: float, in_dim: int, hidden: int, varlen: bool,
            weight_bits: int | None):
    # Quantized runs insert two [4, H] fp32 scale operands after the weights;
    # everything else (ref order, outputs, scratch) is unchanged.
    if weight_bits is None:
        (rows_ref, keys_ref, lens_ref, x_ref, h0_ref, c0_ref,
         wx_ref, wh_ref, b_ref,
         ys_ref, ht_ref, ct_ref, h_scr, c_scr) = refs
    else:
        (rows_ref, keys_ref, lens_ref, x_ref, h0_ref, c0_ref,
         wx_ref, wh_ref, sx_ref, sh_ref, b_ref,
         ys_ref, ht_ref, ct_ref, h_scr, c_scr) = refs
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _reset():
        # Carried-state entry point: a fresh sequence passes zeros here; a
        # resumed session passes the previous chunk's (h_T, c_T).
        h_scr[...] = h0_ref[...]
        c_scr[...] = c0_ref[...]

    rows = rows_ref[...][:, 0]
    x = x_ref[:, 0, :]              # [bb, I] — this step's input slice
    h = h_scr[...]                  # [bb, H] — carried entirely in VMEM
    if weight_bits is None:
        wxv, whv = wx_ref[...], wh_ref[...]
    else:
        # In-register dequant of the int-resident weights: the canonical
        # q·scale expression (repro.kernels.quantize), cast to the activation
        # dtype — exactly the values fake_quant hands the other backends.
        wxv = quantize.kernel_weight(wx_ref[...], sx_ref[...], weight_bits,
                                     hidden=hidden, act_dtype=x.dtype)
        whv = quantize.kernel_weight(wh_ref[...], sh_ref[...], weight_bits,
                                     hidden=hidden, act_dtype=x.dtype)
    gates = []
    # int32 rows: a negative id carries mcd.STUDENT_ROW_FLAG — that row runs
    # deterministic (dropout off), co-batched with the Bayesian rows.
    det = (rows < 0)[:, None]
    scale = jnp.asarray(1.0 / (1.0 - p_drop), x.dtype) if p_drop > 0 else None
    for g in range(4):
        xg, hg = x, h
        if p_drop > 0.0:
            # Same (key, row, col) → bit mapping as the step kernel; keys are
            # t-independent so recomputing here *is* tying across time.
            kx = keys_ref[0, g]
            kh = keys_ref[0, 4 + g]
            mx = _gate_mask(kx, rows, 0, x.shape, in_dim, p_drop)
            mh = _gate_mask(kh, rows, 0, h.shape, hidden, p_drop)
            xg = jnp.where(mx, x * scale, jnp.zeros_like(x))
            hg = jnp.where(mh, h * scale, jnp.zeros_like(h))
            xg = jnp.where(det, x, xg)
            hg = jnp.where(det, h, hg)
        acc = jnp.dot(xg, wxv[:, g, :], preferred_element_type=jnp.float32)
        acc += jnp.dot(hg, whv[:, g, :], preferred_element_type=jnp.float32)
        gates.append(acc + b_ref[g, :].astype(jnp.float32))
    i = jax.nn.sigmoid(gates[0])
    f = jax.nn.sigmoid(gates[1])
    g_ = jnp.tanh(gates[2])
    o = jax.nn.sigmoid(gates[3])
    c_new = f * c_scr[...] + i * g_
    h_new = (o * jnp.tanh(c_new)).astype(h_scr.dtype)
    if varlen:
        # Rows whose chunk ended before this step keep their carried state —
        # the final (h_T, c_T) outputs are each row's state at its own length.
        live = t < lens_ref[...]                  # [bb, 1]
        c_new = jnp.where(live, c_new, c_scr[...])
        h_new = jnp.where(live, h_new, h_scr[...])
    c_scr[...] = c_new
    h_scr[...] = h_new
    ys_ref[:, 0, :] = h_new.astype(ys_ref.dtype)
    ht_ref[...] = h_new.astype(ht_ref.dtype)
    ct_ref[...] = c_new.astype(ct_ref.dtype)


@functools.partial(jax.jit, static_argnames=("p_drop", "block_b", "interpret",
                                             "weight_bits"))
def mcd_lstm_seq(x_seq: jax.Array, wx: jax.Array, wh: jax.Array, b: jax.Array,
                 rows: jax.Array, keys: jax.Array, p_drop: float, *,
                 h0: jax.Array | None = None, c0: jax.Array | None = None,
                 lengths: jax.Array | None = None,
                 weight_bits: int | None = None,
                 wx_scale: jax.Array | None = None,
                 wh_scale: jax.Array | None = None,
                 block_b: int = 128, interpret: bool = True):
    """Sequence-fused Bayesian LSTM layer, optionally resuming carried state.

    x_seq: [B, T, I]; wx: [I, 4, H]; wh: [H, 4, H]; b: [4, H];
    rows: [B] mask row ids; keys: [1, 8] from
    :func:`repro.kernels.mcd_lstm.gate_keys`.
    h0 [B, H] / c0 [B, H] seed the carried state (zeros when omitted — a
    fresh sequence); c0 is accumulated in fp32 regardless of input dtype.
    lengths [B] (int) freezes a row's state at its own chunk length so ragged
    chunks can pad to a common T in one launch.
    weight_bits 8/4 switches to quantized weights: ``wx``/``wh`` carry int8
    codes (int4: nibble-packed uint8, last axis ``ceil(H/2)``) and
    ``wx_scale``/``wh_scale`` the [4, H] fp32 per-output-channel scales; the
    kernel dequantizes in-register, so the VMEM-resident weight bytes drop
    ~2×/4× vs bf16 while the gate math stays fp32-accumulated.
    Returns (ys [B, T, H], h_T [B, H], c_T [B, H] fp32); with ``lengths``,
    (h_T, c_T) is each row's state at ``t = lengths[row]`` and
    ``ys[:, t >= lengths[row]]`` repeats the frozen h.
    """
    B, T, I = x_seq.shape
    H = wh.shape[0]
    if weight_bits is not None and (wx_scale is None or wh_scale is None):
        raise ValueError("weight_bits set but wx_scale/wh_scale missing")
    bb = min(block_b, B)
    varlen = lengths is not None
    h0 = jnp.zeros((B, H), x_seq.dtype) if h0 is None else h0.astype(x_seq.dtype)
    c0 = (jnp.zeros((B, H), jnp.float32) if c0 is None
          else c0.astype(jnp.float32))
    lens = (jnp.full((B,), T, jnp.int32) if lengths is None
            else lengths.astype(jnp.int32))
    rows2 = rows.astype(jnp.int32).reshape(B, 1)
    pad = -B % bb        # pad to the block multiple (prime/odd batch sizes)
    if pad:
        zb = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        x_seq, rows2, h0, c0, lens = map(zb, (x_seq, rows2, h0, c0, lens))
    Bp = B + pad
    lens2 = lens.reshape(Bp, 1)
    grid = (Bp // bb, T)
    Wl = wx.shape[-1]    # H, or ceil(H/2) when int4 nibble-packed
    w_specs = [
        pl.BlockSpec((I, 4, Wl), lambda i, t: (0, 0, 0)),      # wx — resident
        pl.BlockSpec((H, 4, Wl), lambda i, t: (0, 0, 0)),      # wh — resident
    ]
    w_ops = (wx, wh)
    if weight_bits is not None:
        w_specs += [pl.BlockSpec((4, H), lambda i, t: (0, 0)),  # wx scales
                    pl.BlockSpec((4, H), lambda i, t: (0, 0))]  # wh scales
        w_ops += (wx_scale, wh_scale)
    ys, hT, cT = pl.pallas_call(
        functools.partial(_kernel, p_drop=p_drop, in_dim=I, hidden=H,
                          varlen=varlen, weight_bits=weight_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, 1), lambda i, t: (i, 0)),        # rows
            pl.BlockSpec((1, 8), lambda i, t: (0, 0)),         # keys
            pl.BlockSpec((bb, 1), lambda i, t: (i, 0)),        # lengths
            pl.BlockSpec((bb, 1, I), lambda i, t: (i, t, 0)),  # x_t slice
            pl.BlockSpec((bb, H), lambda i, t: (i, 0)),        # h0
            pl.BlockSpec((bb, H), lambda i, t: (i, 0)),        # c0 (fp32)
            *w_specs,
            pl.BlockSpec((4, H), lambda i, t: (0, 0)),         # bias
        ],
        out_specs=[
            pl.BlockSpec((bb, 1, H), lambda i, t: (i, t, 0)),
            pl.BlockSpec((bb, H), lambda i, t: (i, 0)),
            pl.BlockSpec((bb, H), lambda i, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, T, H), x_seq.dtype),
            jax.ShapeDtypeStruct((Bp, H), x_seq.dtype),
            jax.ShapeDtypeStruct((Bp, H), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb, H), x_seq.dtype),    # h carry
            pltpu.VMEM((bb, H), jnp.float32),    # c carry (32-bit policy)
        ],
        compiler_params=compat.compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(rows2, keys, lens2, x_seq, h0, c0, *w_ops, b)
    if pad:
        ys, hT, cT = ys[:B], hT[:B], cT[:B]
    return ys, hT, cT

"""Pallas TPU kernel: Bernoulli mask generation + apply (the LFSR + DX unit).

The paper's Fig. 3 sampler (LFSR → SIPO → FIFO) plus the DX masking unit of
Fig. 2, fused: random bits are produced *in VMEM* by the counter-PRNG
(~10 uint32 VPU ops/lane), thresholded to a Bernoulli(p) keep-mask, applied
to the activation tile, and never written to HBM.  Generation cost hides
under the surrounding compute exactly as the paper's Fig. 4 overlap.

Mask semantics match :func:`repro.core.mcd.feature_mask` bit-for-bit: element
(b, f) draws from stream index ``rows[b]·n_feat + f`` under the site key —
identical regardless of tiling, sharding, or restart.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import prng


def _kernel(rows_ref, key_ref, x_ref, o_ref, *, p_drop: float, n_feat: int,
            block_f: int):
    j = pl.program_id(1)
    rows = rows_ref[...][:, 0]                      # [bb]
    key = key_ref[0, 0]
    cols = jax.lax.broadcasted_iota(jnp.uint32, x_ref.shape, 1) \
        + jnp.uint32(j * block_f)
    idx = rows[:, None].astype(jnp.uint32) * jnp.uint32(n_feat) + cols
    bits = prng._mix32(key ^ prng._mix32(idx))
    keep = bits >= prng.bernoulli_keep_threshold(p_drop)
    scale = jnp.asarray(1.0 / (1.0 - p_drop), x_ref.dtype)
    o_ref[...] = jnp.where(keep, x_ref[...] * scale, jnp.zeros_like(x_ref[...]))


@functools.partial(jax.jit, static_argnames=("p_drop", "block_b", "block_f",
                                             "interpret"))
def masked_activation(x: jax.Array, rows: jax.Array, key: jax.Array,
                      p_drop: float, *, block_b: int = 256,
                      block_f: int = 512, interpret: bool = True) -> jax.Array:
    """x: [B, F] activations → x ⊙ z / (1-p) with z ~ Bern(1-p) per (row, f)."""
    B, F = x.shape
    bb, bf = min(block_b, B), min(block_f, F)
    assert B % bb == 0 and F % bf == 0, (B, bb, F, bf)
    rows2 = rows.astype(jnp.int32).reshape(B, 1)
    key2 = jnp.asarray(key, jnp.uint32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_kernel, p_drop=p_drop, n_feat=F, block_f=bf),
        grid=(B // bb, F // bf),
        in_specs=[
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((bb, bf), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bb, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, F), x.dtype),
        interpret=interpret,
    )(rows2, key2, x)

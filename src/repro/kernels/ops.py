"""jit'd high-level wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (CPU validation path); on a real TPU
backend the kernels compile natively.  The framework's model code uses the
pure-jnp mirrors by default (sharding-friendly under GSPMD); these wrappers
are the TPU hot-path entry points and the unit under test in
``tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import cells, mcd
from repro.core.rnn import CELLS  # noqa: F401 — single-source cell registry
from repro.kernels import (bernoulli_mask, mcd_gru, mcd_gru_seq, mcd_lstm,
                           mcd_lstm_seq, mcd_matmul, quantize)

#: Stack-layer execution paths (see ``repro.core.rnn.run_stack``):
#: "reference"    pure-jnp cells (sharding-friendly, the numerical oracle)
#: "pallas_step"  fused cell kernel re-entered per timestep via lax.scan
#: "pallas_seq"   sequence-fused kernel — weights resident across all T
LSTM_BACKENDS = ("reference", "pallas_step", "pallas_seq")


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()


def flash_decode_attention(q: jax.Array, k_cache: jax.Array,
                           v_cache: jax.Array, pos, **kw) -> jax.Array:
    """Fused decode attention (EXPERIMENTS.md §Perf Cell C hot path)."""
    from repro.kernels import decode_attn
    kw.setdefault("interpret", default_interpret())
    return decode_attn.decode_attention(q, k_cache, v_cache, pos, **kw)


def mcd_dense(x: jax.Array, w: jax.Array, rows: jax.Array, seed, layer: int,
              site: int, p_drop: float, **kw) -> jax.Array:
    """Fused masked dense: y = (x ⊙ z/(1-p)) @ W with the site-keyed stream."""
    key = mcd.mask_key(seed, layer, mcd.KIND_FEAT, site)
    kw.setdefault("interpret", default_interpret())
    return mcd_matmul.mcd_matmul(x, w, rows, key, p_drop, **kw)


def mcd_mask_apply(x: jax.Array, rows: jax.Array, seed, layer: int, site: int,
                   p_drop: float, **kw) -> jax.Array:
    key = mcd.mask_key(seed, layer, mcd.KIND_FEAT, site)
    kw.setdefault("interpret", default_interpret())
    return bernoulli_mask.masked_activation(x, rows, key, p_drop, **kw)


@functools.partial(jax.jit, static_argnames=("p_drop", "interpret"))
def fused_lstm_layer(wx4: jax.Array, wh4: jax.Array, b: jax.Array,
                     x_seq: jax.Array, rows: jax.Array, seed, layer: int,
                     p_drop: float, h0: jax.Array | None = None,
                     c0: jax.Array | None = None,
                     lengths: jax.Array | None = None,
                     interpret: bool | None = None):
    """Scan the fused cell kernel over time (paper Fig. 5 TS pipelining).

    wx4: [I, 4, H]; wh4: [H, 4, H]; b: [4, H]; x_seq: [B, T, I].
    ``h0``/``c0`` resume carried state (zeros when omitted); ``lengths``
    freezes each row's state at its own chunk length (ragged batching).
    Returns (outputs [B, T, H], (h_T, c_T fp32)).
    """
    if interpret is None:
        interpret = default_interpret()
    B, T, _ = x_seq.shape
    H = wh4.shape[0]
    keys = mcd_lstm.gate_keys(seed, layer)
    h0 = jnp.zeros((B, H), x_seq.dtype) if h0 is None else h0.astype(x_seq.dtype)
    c0 = (jnp.zeros((B, H), jnp.float32) if c0 is None
          else c0.astype(jnp.float32))

    def step(carry, xt):
        h, c = carry
        x_t, t = xt
        h_new, c_new = mcd_lstm.mcd_lstm_step(x_t, h, c, wx4, wh4, b, rows,
                                              keys, p_drop,
                                              interpret=interpret)
        if lengths is not None:
            h_new, c_new = cells.freeze_rows(t, lengths, h_new, c_new, h, c)
        return (h_new, c_new), h_new

    ts = jnp.arange(T, dtype=jnp.int32)
    (hT, cT), ys = jax.lax.scan(step, (h0, c0),
                                (jnp.swapaxes(x_seq, 0, 1), ts))
    return jnp.swapaxes(ys, 0, 1), (hT, cT)


@functools.partial(jax.jit, static_argnames=("p_drop", "interpret",
                                             "weight_bits"))
def fused_lstm_seq(wx4: jax.Array, wh4: jax.Array, b: jax.Array,
                   x_seq: jax.Array, rows: jax.Array, seed, layer: int,
                   p_drop: float, h0: jax.Array | None = None,
                   c0: jax.Array | None = None,
                   lengths: jax.Array | None = None,
                   weight_bits: int | None = None,
                   wx_scale: jax.Array | None = None,
                   wh_scale: jax.Array | None = None,
                   interpret: bool | None = None):
    """One kernel launch for the whole sequence (paper Fig. 5 wave pipelining).

    Same contract as :func:`fused_lstm_layer` — wx4: [I, 4, H]; wh4: [H, 4, H];
    b: [4, H]; x_seq: [B, T, I]; returns (outputs [B, T, H], (h_T, c_T)) —
    but the weights stay VMEM-resident across all T timesteps instead of being
    re-fetched per scan iteration.  ``h0``/``c0``/``lengths`` carry streaming
    session state into and out of the launch (see ``mcd_lstm_seq``).
    With ``weight_bits`` 8/4, ``wx4``/``wh4`` carry quantized codes and
    ``wx_scale``/``wh_scale`` the [4, H] fp32 scales (dequant in-register).
    """
    if interpret is None:
        interpret = default_interpret()
    keys = mcd_lstm.gate_keys(seed, layer)
    ys, hT, cT = mcd_lstm_seq.mcd_lstm_seq(x_seq, wx4, wh4, b, rows, keys,
                                           p_drop, h0=h0, c0=c0,
                                           lengths=lengths,
                                           weight_bits=weight_bits,
                                           wx_scale=wx_scale,
                                           wh_scale=wh_scale,
                                           interpret=interpret)
    return ys, (hT, cT)


def _precision_weights(wx, wh, x_seq, precision, *, seq: bool):
    """Apply a serving precision to gate-stacked weights + the input.

    Returns ``(wx, wh, x_seq, qkw)`` where ``qkw`` holds the extra kwargs the
    sequence-kernel wrappers take when the weights are quantized codes.  The
    step path gets the *dequantized* weights instead (same canonical q·scale
    values, applied outside the kernel), so every backend sees identical
    weight values at identical dtypes — the bit-identity contract.
    """
    if precision is None:
        return wx, wh, x_seq, {}
    act = quantize.activation_dtype(precision, x_seq.dtype)
    x_seq = x_seq.astype(act)
    if precision not in quantize.QUANTIZED:
        return wx.astype(act), wh.astype(act), x_seq, {}
    bits = quantize.WEIGHT_BITS[precision]
    qx, sx = quantize.quantize(wx, bits, axis=0)
    qh, sh = quantize.quantize(wh, bits, axis=0)
    if seq:
        return (quantize.packed_weight(qx, bits),
                quantize.packed_weight(qh, bits), x_seq,
                dict(weight_bits=bits, wx_scale=sx, wh_scale=sh))
    return (quantize.dequantize(qx, sx, axis=0).astype(act),
            quantize.dequantize(qh, sh, axis=0).astype(act), x_seq, {})


@functools.partial(jax.jit, static_argnames=("p_drop", "seq", "interpret",
                                             "precision"))
def lstm_stack_layer(wx: jax.Array, wh: jax.Array, b: jax.Array,
                     x_seq: jax.Array, rows: jax.Array, seed, layer,
                     p_drop: float, *, seq: bool,
                     initial_state=None, lengths: jax.Array | None = None,
                     precision: str | None = None,
                     interpret: bool | None = None):
    """Core-layout entry for ``run_stack``'s Pallas backends.

    Takes ``repro.core.cells.LSTMParams`` layout (wx: [4, I, H]; wh:
    [4, H, H]) and transposes to the kernels' gate-stacked layout *inside*
    jit, so repeated calls (the S MC-sample loop) don't pay an eager
    per-call transpose.  ``layer`` is traced (it only feeds the counter-PRNG
    key fold), so same-shaped layers share one compile.  ``seq`` picks
    sequence- vs step-fusion.  ``initial_state`` is an optional ``(h0, c0)``
    pair resuming a streaming session's carried state.  ``precision``
    (fp32/bf16/int8/int4) quantizes or casts the fp32 master weights
    in-graph — int8/int4 run the seq kernel with int-resident weights and
    in-register dequant, the step kernel with the same dequantized values.
    """
    wx4, wh4, b = cells.gate_stacked(cells.LSTMParams(wx, wh, b))
    wx4, wh4, x_seq, qkw = _precision_weights(wx4, wh4, x_seq, precision,
                                              seq=seq)
    h0, c0 = initial_state if initial_state is not None else (None, None)
    fn = fused_lstm_seq if seq else fused_lstm_layer
    return fn(wx4, wh4, b, x_seq, rows, seed, layer, p_drop, h0=h0, c0=c0,
              lengths=lengths, interpret=interpret, **qkw)


@functools.partial(jax.jit, static_argnames=("p_drop", "interpret"))
def fused_gru_layer(wx3: jax.Array, wh3: jax.Array, b: jax.Array,
                    x_seq: jax.Array, rows: jax.Array, seed, layer: int,
                    p_drop: float, h0: jax.Array | None = None,
                    lengths: jax.Array | None = None,
                    interpret: bool | None = None):
    """Scan the fused GRU cell kernel over time (per-step fusion baseline).

    wx3: [I, 3, H]; wh3: [H, 3, H]; b: [3, H]; x_seq: [B, T, I].
    ``h0`` resumes carried state (zeros when omitted); ``lengths`` freezes
    each row's state at its own chunk length (ragged batching).
    Returns (outputs [B, T, H], (h_T,)) — the carry is a 1-tuple because the
    GRU's entire recurrent state is ``h``.
    """
    if interpret is None:
        interpret = default_interpret()
    B, T, _ = x_seq.shape
    H = wh3.shape[0]
    keys = mcd_gru.gate_keys(seed, layer)
    h0 = jnp.zeros((B, H), x_seq.dtype) if h0 is None else h0.astype(x_seq.dtype)

    def step(h, xt):
        x_t, t = xt
        h_new = mcd_gru.mcd_gru_step(x_t, h, wx3, wh3, b, rows, keys, p_drop,
                                     interpret=interpret)
        if lengths is not None:
            h_new = cells.freeze_rows_h(t, lengths, h_new, h)
        return h_new, h_new

    ts = jnp.arange(T, dtype=jnp.int32)
    hT, ys = jax.lax.scan(step, h0, (jnp.swapaxes(x_seq, 0, 1), ts))
    return jnp.swapaxes(ys, 0, 1), (hT,)


@functools.partial(jax.jit, static_argnames=("p_drop", "interpret",
                                             "weight_bits"))
def fused_gru_seq(wx3: jax.Array, wh3: jax.Array, b: jax.Array,
                  x_seq: jax.Array, rows: jax.Array, seed, layer: int,
                  p_drop: float, h0: jax.Array | None = None,
                  lengths: jax.Array | None = None,
                  weight_bits: int | None = None,
                  wx_scale: jax.Array | None = None,
                  wh_scale: jax.Array | None = None,
                  interpret: bool | None = None):
    """One kernel launch for the whole GRU sequence (weights VMEM-resident).

    Same contract as :func:`fused_gru_layer`, but the 3-gate weights stay
    resident across all T timesteps instead of being re-fetched per scan
    iteration (the ``mcd_gru_seq`` kernel).  With ``weight_bits`` 8/4,
    ``wx3``/``wh3`` carry quantized codes and ``wx_scale``/``wh_scale`` the
    [3, H] fp32 scales (dequant in-register).
    """
    if interpret is None:
        interpret = default_interpret()
    keys = mcd_gru.gate_keys(seed, layer)
    ys, hT = mcd_gru_seq.mcd_gru_seq(x_seq, wx3, wh3, b, rows, keys, p_drop,
                                     h0=h0, lengths=lengths,
                                     weight_bits=weight_bits,
                                     wx_scale=wx_scale, wh_scale=wh_scale,
                                     interpret=interpret)
    return ys, (hT,)


@functools.partial(jax.jit, static_argnames=("p_drop", "seq", "interpret",
                                             "precision"))
def gru_stack_layer(wx: jax.Array, wh: jax.Array, b: jax.Array,
                    x_seq: jax.Array, rows: jax.Array, seed, layer,
                    p_drop: float, *, seq: bool,
                    initial_state=None, lengths: jax.Array | None = None,
                    precision: str | None = None,
                    interpret: bool | None = None):
    """Core-layout GRU entry for ``run_stack``'s Pallas backends.

    Mirrors :func:`lstm_stack_layer`: takes ``repro.core.cells.GRUParams``
    layout (wx: [3, I, H]; wh: [3, H, H]), transposes to the gate-stacked
    kernel layout inside jit, traces ``layer`` (shared compiles across
    same-shaped layers).  ``initial_state`` is the 1-tuple ``(h0,)`` carry
    a streaming session stores for a GRU layer.  ``precision`` quantizes or
    casts the fp32 master weights in-graph, as in the LSTM entry.
    """
    wx3, wh3, b = cells.gate_stacked(cells.GRUParams(wx, wh, b))
    wx3, wh3, x_seq, qkw = _precision_weights(wx3, wh3, x_seq, precision,
                                              seq=seq)
    (h0,) = initial_state if initial_state is not None else (None,)
    fn = fused_gru_seq if seq else fused_gru_layer
    return fn(wx3, wh3, b, x_seq, rows, seed, layer, p_drop, h0=h0,
              lengths=lengths, interpret=interpret, **qkw)

"""Uncertainty-aware batched serving — the paper's raison d'être, at LM scale.

A Bayesian request is served as S MC chains folded into the batch axis
(`repro.core.bayesian` semantics): one weight fetch feeds all S chains, and
every chain recomputes its own tied mask at each decode step from the counter
RNG — the serving state carries only (seed, row ids), not masks (the paper's
SIPO/FIFO buffer, for free).

At each step the S chains' logits are aggregated into the Bayesian
predictive distribution; the *mean* distribution picks the next token
(greedy/temperature), the same token is fed back to every chain, and the
per-token uncertainty decomposition (predictive entropy / expected entropy /
mutual information) is emitted alongside — the LM analogue of the paper's
Fig. 1 shaded band.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import mcd
from repro.core.uncertainty import classification_summary
from repro.models import backbone
from repro.models.config import ArchConfig
from repro.models.layers import Ctx


@dataclasses.dataclass
class GenerationResult:
    tokens: Any                 # [B, n_new]
    predictive_entropy: Any     # [B, n_new]  total uncertainty (nats)
    mutual_information: Any     # [B, n_new]  epistemic part
    mean_probs_last: Any        # [B, vocab]


class BayesianEngine:
    """Static-batch S-sample serving engine for any zoo architecture."""

    def __init__(self, params, cfg: ArchConfig, *, max_len: int = 512,
                 seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.seed = seed
        self._decode = jax.jit(
            lambda p, t, s, ctx: backbone.decode_step(p, cfg, t, s, ctx))
        self._prefill = jax.jit(
            lambda p, t, ctx, **kw: backbone.prefill(p, cfg, t, ctx,
                                                     max_len, **kw),
            static_argnames=())

    def _ctx(self, batch: int, s: int) -> Ctx:
        rows = mcd.sample_rows(batch, s)
        return Ctx(rows=rows, seed=self.seed, cfg=self.cfg.mcd,
                   deterministic=not self.cfg.mcd.any_bayesian)

    def generate(self, prompts: jax.Array, n_new: int, *,
                 frames=None, patches=None) -> GenerationResult:
        """prompts: [B, S] → greedy decode n_new tokens with uncertainty."""
        cfg = self.cfg
        B = prompts.shape[0]
        s = max(1, cfg.mcd.n_samples if cfg.mcd.any_bayesian else 1)
        ctx = self._ctx(B, s)
        tiled = jnp.broadcast_to(prompts[None], (s, *prompts.shape)).reshape(
            s * B, -1)
        kw = {}
        if frames is not None:
            kw["frames"] = jnp.broadcast_to(
                frames[None], (s, *frames.shape)).reshape(s * B, *frames.shape[1:])
        if patches is not None:
            kw["patches"] = jnp.broadcast_to(
                patches[None], (s, *patches.shape)).reshape(s * B, *patches.shape[1:])
        logits, state = self._prefill(self.params, tiled, ctx, **kw)

        toks, ents, mis = [], [], []
        probs = None
        for _ in range(n_new):
            summ = classification_summary(
                logits[:, 0].reshape(s, B, -1).astype(jnp.float32))
            probs = summ.probs
            next_tok = jnp.argmax(summ.probs, axis=-1).astype(prompts.dtype)
            toks.append(next_tok)
            ents.append(summ.predictive_entropy)
            mis.append(summ.mutual_information)
            fed = jnp.broadcast_to(next_tok[None], (s, B)).reshape(s * B, 1)
            logits, state = self._decode(self.params, fed, state, ctx)
        return GenerationResult(
            tokens=jnp.stack(toks, axis=1),
            predictive_entropy=jnp.stack(ents, axis=1),
            mutual_information=jnp.stack(mis, axis=1),
            mean_probs_last=probs)

"""Async admission with bounded backpressure for streaming sessions.

PR 2's ``SessionStore`` fails fast: at capacity, ``admit`` raises
:class:`~repro.serve.sessions.CapacityError` and the stream is simply not
served.  That is the wrong failure mode for the paper's deployment — a
patient monitor that silently drops a new stream at peak load is exactly
the unsafe behaviour the Bayesian uncertainty machinery exists to prevent.

This module turns admission into a *queue*: ``submit`` never races the
store, it records the request (sid, priority, optional evicted
:class:`~repro.serve.sessions.Session` to re-attach) and the engine drains
the queue into freed rows at tick boundaries.  Backpressure is explicit and
bounded — when ``max_pending`` requests are already waiting, ``submit``
raises the typed :class:`QueueFull` so upstream load-shedding can happen at
the edge, with a reason, instead of deep in the serving loop.

Ordering is priority-first (higher wins — an ICU stream preempts the
wait-list), FIFO within a priority class.  The queue holds no array state
for fresh admissions; a re-attach request carries its evicted ``Session``
(state + ``(seed, rows)`` coordinates), so draining it resumes the same
Bayesian draw.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Iterator

from repro.serve.sessions import CapacityError, Session, SessionStore


class QueueFull(RuntimeError):
    """Admission refused: ``max_pending`` requests are already waiting."""


class DrainRejected(RuntimeError):
    """One or more tickets could not be admitted during a drain.

    Raised *after* the drain completes: every admissible ticket behind a bad
    one still went live this drain, and the exception carries the full
    partial result so no admitted session is ever unreported —

    * ``admitted``: the sessions that went live (already in the store);
    * ``rejected``: ``[(Ticket, Exception), ...]`` for the tickets the store
      refused (dropped from the queue — they could never succeed later).
    """

    def __init__(self, admitted: list[Session], rejected: list):
        self.admitted = admitted
        self.rejected = rejected
        sids = ", ".join(repr(t.sid) for t, _ in rejected)
        super().__init__(
            f"drain rejected ticket(s) {sids} "
            f"({len(admitted)} session(s) still admitted this drain): "
            + "; ".join(str(err) for _, err in rejected))


@dataclasses.dataclass(frozen=True)
class Ticket:
    """One queued admission request (drain order: priority desc, then FIFO)."""

    sid: str
    priority: int
    seq: int                        # FIFO tiebreak within a priority class
    session: Session | None = None  # set for re-attach (evicted carry)
    submitted_at: float = 0.0       # monotonic clock at submit (queue-wait age)


class AdmissionQueue:
    """Bounded priority queue feeding a :class:`SessionStore`.

    ``submit`` enqueues; ``drain(store)`` admits (or re-attaches) as many
    waiting requests as the store has room for, in priority order.  The
    engine calls ``drain`` at every tick boundary and after every eviction,
    so a freed row is reused on the very next tick.
    """

    def __init__(self, max_pending: int = 256):
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.max_pending = int(max_pending)
        self._heap: list[tuple[int, int, Ticket]] = []
        self._pending: dict[str, Ticket] = {}
        self._seq = 0

    def submit(self, sid: str, *, priority: int = 0,
               session: Session | None = None) -> Ticket:
        """Queue an admission (or, with ``session``, a re-attach) request."""
        if session is not None and session.sid != sid:
            raise ValueError(f"ticket sid {sid!r} != session.sid "
                             f"{session.sid!r}")
        if sid in self._pending:
            raise ValueError(f"session {sid!r} already queued")
        if len(self._pending) >= self.max_pending:
            raise QueueFull(
                f"admission queue full ({self.max_pending} pending); "
                "shed load upstream or raise max_pending")
        ticket = Ticket(sid=sid, priority=int(priority), seq=self._seq,
                        session=session, submitted_at=time.monotonic())
        self._seq += 1
        self._pending[sid] = ticket
        heapq.heappush(self._heap, (-ticket.priority, ticket.seq, ticket))
        return ticket

    def cancel(self, sid: str) -> bool:
        """Withdraw a waiting request; False if it was not queued."""
        hit = self._pending.pop(sid, None) is not None
        # Deletion is lazy (drain skips stale heap entries), but a store
        # pinned at capacity never drains — compact so submit/cancel churn
        # can't grow the heap (and any carried Sessions) without bound.
        if hit and len(self._heap) > 2 * len(self._pending) + 8:
            self._heap = [(-t.priority, t.seq, t)
                          for t in self._pending.values()]
            heapq.heapify(self._heap)
        return hit

    def drain(self, store: SessionStore) -> list[Session]:
        """Admit waiting requests into free store rows, best-priority first.

        Returns the sessions that went live this drain.  A ticket the store
        rejects (re-attach seed/rows mismatch, sid collision) is dropped
        from the queue — it could never succeed later — but it must not
        poison the drain: the remaining tickets still get their shot at the
        free rows, and only then is :class:`DrainRejected` raised, carrying
        both the admitted sessions and the rejected tickets.  (The old
        raise-on-first-failure behaviour discarded the admitted list —
        sessions already live in the store went unreported — and starved
        every ticket queued behind the bad one for the tick.)
        """
        admitted: list[Session] = []
        rejected: list[tuple[Ticket, Exception]] = []
        while self._pending and len(store) < store.max_sessions:
            _, _, ticket = heapq.heappop(self._heap)
            if self._pending.get(ticket.sid) is not ticket:
                continue                      # cancelled (lazy deletion)
            del self._pending[ticket.sid]
            try:
                if ticket.session is not None:
                    admitted.append(store.attach(ticket.session))
                else:
                    admitted.append(store.admit(ticket.sid))
            except (ValueError, CapacityError) as err:
                rejected.append((ticket, err))
        if rejected:
            raise DrainRejected(admitted, rejected)
        return admitted

    def oldest_wait_s(self, now: float | None = None) -> float:
        """Age (s) of the oldest still-waiting ticket; 0.0 when empty.

        Measured at tick boundaries right after the drain, this is the
        head-of-line queueing delay — the observable that separates "the
        store is full and streams are waiting" (genuine overload) from a
        slow tick (compile stall, long chunk): ``TickMetrics.queue_wait_s``.
        """
        if not self._pending:
            return 0.0
        now = time.monotonic() if now is None else now
        return max(0.0, now - min(t.submitted_at
                                  for t in self._pending.values()))

    def waiting(self) -> list[Ticket]:
        """Live tickets in drain order (priority desc, FIFO within)."""
        live = [t for t in self._pending.values()]
        return sorted(live, key=lambda t: (-t.priority, t.seq))

    @property
    def depth(self) -> int:
        return len(self._pending)

    def __len__(self) -> int:
        return len(self._pending)

    def __contains__(self, sid: str) -> bool:
        return sid in self._pending

    def __iter__(self) -> Iterator[Ticket]:
        return iter(self.waiting())

"""Async admission with bounded backpressure for streaming sessions.

PR 2's ``SessionStore`` fails fast: at capacity, ``admit`` raises
:class:`~repro.serve.sessions.CapacityError` and the stream is simply not
served.  That is the wrong failure mode for the paper's deployment — a
patient monitor that silently drops a new stream at peak load is exactly
the unsafe behaviour the Bayesian uncertainty machinery exists to prevent.

This module turns admission into a *queue*: ``submit`` never races the
store, it records the request (sid, priority, optional evicted
:class:`~repro.serve.sessions.Session` to re-attach) and the engine drains
the queue into freed rows at tick boundaries.  Backpressure is explicit and
bounded — when ``max_pending`` requests are already waiting, ``submit``
raises the typed :class:`QueueFull` so upstream load-shedding can happen at
the edge, with a reason, instead of deep in the serving loop.

Ordering is priority-first (higher wins — an ICU stream preempts the
wait-list), FIFO within a priority class.  The queue holds no array state
for fresh admissions; a re-attach request carries its evicted ``Session``
(state + ``(seed, rows)`` coordinates), so draining it resumes the same
Bayesian draw.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from collections import deque
from typing import Callable, Iterator, Mapping

from repro.serve.sessions import CapacityError, Session, SessionStore


class QueueFull(RuntimeError):
    """Admission refused: ``max_pending`` requests are already waiting."""


class DrainRejected(RuntimeError):
    """One or more tickets could not be admitted during a drain.

    Raised *after* the drain completes: every admissible ticket behind a bad
    one still went live this drain, and the exception carries the full
    partial result so no admitted session is ever unreported —

    * ``admitted``: the sessions that went live (already in the store);
    * ``rejected``: ``[(Ticket, Exception), ...]`` for the tickets the store
      refused (dropped from the queue — they could never succeed later).
    """

    def __init__(self, admitted: list[Session], rejected: list):
        self.admitted = admitted
        self.rejected = rejected
        sids = ", ".join(repr(t.sid) for t, _ in rejected)
        super().__init__(
            f"drain rejected ticket(s) {sids} "
            f"({len(admitted)} session(s) still admitted this drain): "
            + "; ".join(str(err) for _, err in rejected))


@dataclasses.dataclass(frozen=True)
class Ticket:
    """One queued admission request (drain order: priority desc, then FIFO)."""

    sid: str
    priority: int
    seq: int                        # FIFO tiebreak within a priority class
    session: Session | None = None  # set for re-attach (evicted carry)
    submitted_at: float = 0.0       # monotonic clock at submit (queue-wait age)
    n_samples: int | None = None    # fresh admissions: chains to open with
                                    # (None: the store ceiling; ignored for
                                    # re-attach — the Session carries its own)
    mode: str | None = None         # fresh admissions: "mc" | "student"
                                    # (None: "mc"; ignored for re-attach —
                                    # the Session carries its own mode)


class AdmissionQueue:
    """Bounded priority queue feeding a :class:`SessionStore`.

    ``submit`` enqueues; ``drain(store)`` admits (or re-attaches) as many
    waiting requests as the store has room for, in priority order.  The
    engine calls ``drain`` at every tick boundary and after every eviction,
    so a freed row is reused on the very next tick.
    """

    def __init__(self, max_pending: int = 256):
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.max_pending = int(max_pending)
        self._heap: list[tuple[int, int, Ticket]] = []
        self._pending: dict[str, Ticket] = {}
        self._seq = 0

    def submit(self, sid: str, *, priority: int = 0,
               session: Session | None = None,
               n_samples: int | None = None,
               mode: str | None = None) -> Ticket:
        """Queue an admission (or, with ``session``, a re-attach) request.

        ``n_samples`` rides the ticket for a fresh admission: the session
        opens with that many MC chains when it goes live (None: the store
        ceiling).  ``mode`` likewise ("student" opens a single-row distilled
        session).  Both validated at drain time against the store.
        """
        if session is not None and session.sid != sid:
            raise ValueError(f"ticket sid {sid!r} != session.sid "
                             f"{session.sid!r}")
        if sid in self._pending:
            raise ValueError(f"session {sid!r} already queued")
        if len(self._pending) >= self.max_pending:
            raise QueueFull(
                f"admission queue full ({self.max_pending} pending); "
                "shed load upstream or raise max_pending")
        ticket = Ticket(sid=sid, priority=int(priority), seq=self._seq,
                        session=session, submitted_at=time.monotonic(),
                        n_samples=None if n_samples is None
                        else int(n_samples), mode=mode)
        self._seq += 1
        self._pending[sid] = ticket
        heapq.heappush(self._heap, (-ticket.priority, ticket.seq, ticket))
        return ticket

    def cancel(self, sid: str) -> bool:
        """Withdraw a waiting request; False if it was not queued."""
        hit = self._pending.pop(sid, None) is not None
        # Deletion is lazy (drain skips stale heap entries), but a store
        # pinned at capacity never drains — compact so submit/cancel churn
        # can't grow the heap (and any carried Sessions) without bound.
        if hit and len(self._heap) > 2 * len(self._pending) + 8:
            self._heap = [(-t.priority, t.seq, t)
                          for t in self._pending.values()]
            heapq.heapify(self._heap)
        return hit

    def drain(self, store: SessionStore) -> list[Session]:
        """Admit waiting requests into free store rows, best-priority first.

        Returns the sessions that went live this drain.  A ticket the store
        rejects (re-attach seed/rows mismatch, sid collision) is dropped
        from the queue — it could never succeed later — but it must not
        poison the drain: the remaining tickets still get their shot at the
        free rows, and only then is :class:`DrainRejected` raised, carrying
        both the admitted sessions and the rejected tickets.  (The old
        raise-on-first-failure behaviour discarded the admitted list —
        sessions already live in the store went unreported — and starved
        every ticket queued behind the bad one for the tick.)
        """
        admitted: list[Session] = []
        rejected: list[tuple[Ticket, Exception]] = []
        while self._pending and len(store) < store.max_sessions:
            _, _, ticket = heapq.heappop(self._heap)
            if self._pending.get(ticket.sid) is not ticket:
                continue                      # cancelled (lazy deletion)
            del self._pending[ticket.sid]
            try:
                if ticket.session is not None:
                    admitted.append(store.attach(ticket.session))
                else:
                    admitted.append(store.admit(
                        ticket.sid, n_samples=ticket.n_samples,
                        mode=ticket.mode or "mc"))
            except (ValueError, CapacityError) as err:
                rejected.append((ticket, err))
        if rejected:
            raise DrainRejected(admitted, rejected)
        return admitted

    def oldest_wait_s(self, now: float | None = None) -> float:
        """Age (s) of the oldest still-waiting ticket; 0.0 when empty.

        Measured at tick boundaries right after the drain, this is the
        head-of-line queueing delay — the observable that separates "the
        store is full and streams are waiting" (genuine overload) from a
        slow tick (compile stall, long chunk): ``TickMetrics.queue_wait_s``.
        """
        if not self._pending:
            return 0.0
        now = time.monotonic() if now is None else now
        return max(0.0, now - min(t.submitted_at
                                  for t in self._pending.values()))

    def waiting(self) -> list[Ticket]:
        """Live tickets in drain order (priority desc, FIFO within)."""
        live = [t for t in self._pending.values()]
        return sorted(live, key=lambda t: (-t.priority, t.seq))

    @property
    def depth(self) -> int:
        return len(self._pending)

    def __len__(self) -> int:
        return len(self._pending)

    def __contains__(self, sid: str) -> bool:
        return sid in self._pending

    def __iter__(self) -> Iterator[Ticket]:
        return iter(self.waiting())


# ---------------------------------------------------------------------------
# Weighted-fair admission across tenants (the fleet's shared queue)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetTicket(Ticket):
    """One queued admission request, tagged with its owning tenant."""

    tenant: str = ""
    enqueued_round: int = 0     # drain round at submit (aging-guard clock)


class WeightedFairQueue:
    """One bounded admission queue shared by every tenant of a fleet.

    ``submit`` tags each request with its tenant; ``drain`` hands free
    capacity out **weighted-fair**: among tenants that have pending tickets
    *and* room to admit, pick the one whose cumulative admitted count per
    unit weight is smallest (stride scheduling), so under sustained overload
    each tenant's share of admitted capacity converges to
    ``weight_t / sum(weights of backlogged tenants)``.  Within a tenant the
    order is strict FIFO — a tenant's own streams are peers; priority classes
    across streams of one tenant belong in a per-tenant queue, not here.

    Starvation guard: a head-of-line ticket that has waited
    ``aging_rounds`` drain rounds is admitted *before* the weighted pick,
    oldest first — a 1-weight tenant behind a 1000-weight tenant still
    admits eventually, it just pays proportionally more latency.

    The fairness state (cumulative per-tenant admitted counts + the round
    counter) is part of the fleet's durable state: ``state()`` /
    ``load_state`` round-trip it through fleet snapshots so a restored
    fleet keeps the same long-run shares instead of resetting the ledger.
    """

    def __init__(self, weights: Mapping[str, float], *,
                 max_pending: int = 256, aging_rounds: int = 16):
        if not weights:
            raise ValueError("need at least one tenant weight")
        for name, w in weights.items():
            if "/" in name:
                raise ValueError(f"tenant name {name!r} may not contain '/' "
                                 "(reserved for fleet sid namespacing)")
            if not w > 0:
                raise ValueError(f"tenant {name!r} weight must be > 0, "
                                 f"got {w}")
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        if aging_rounds < 1:
            raise ValueError(f"aging_rounds must be >= 1, "
                             f"got {aging_rounds}")
        self.weights = {name: float(w) for name, w in weights.items()}
        self.max_pending = int(max_pending)
        self.aging_rounds = int(aging_rounds)
        self._fifos: dict[str, deque[FleetTicket]] = {
            name: deque() for name in self.weights}
        self._admitted: dict[str, int] = {name: 0 for name in self.weights}
        self._round = 0
        self._seq = 0
        self._sids: set[str] = set()

    def submit(self, tenant: str, sid: str, *, priority: int = 0,
               session: Session | None = None,
               mode: str | None = None) -> FleetTicket:
        """Queue an admission (or re-attach) request for ``tenant``."""
        if tenant not in self._fifos:
            raise KeyError(f"unknown tenant {tenant!r} "
                           f"(fleet serves {sorted(self._fifos)})")
        if session is not None and session.sid != sid:
            raise ValueError(f"ticket sid {sid!r} != session.sid "
                             f"{session.sid!r}")
        if sid in self._sids:
            raise ValueError(f"session {sid!r} already queued")
        if len(self._sids) >= self.max_pending:
            raise QueueFull(
                f"fleet admission queue full ({self.max_pending} pending); "
                "shed load upstream or raise max_pending")
        ticket = FleetTicket(sid=sid, priority=int(priority), seq=self._seq,
                             session=session,
                             submitted_at=time.monotonic(), mode=mode,
                             tenant=tenant, enqueued_round=self._round)
        self._seq += 1
        self._sids.add(sid)
        self._fifos[tenant].append(ticket)
        return ticket

    def cancel(self, sid: str) -> bool:
        """Withdraw a waiting request; False if it was not queued."""
        if sid not in self._sids:
            return False
        self._sids.discard(sid)
        for fifo in self._fifos.values():
            for ticket in fifo:
                if ticket.sid == sid:
                    fifo.remove(ticket)
                    return True
        return True

    def drain(self, admit: Callable[[FleetTicket], Session],
              has_room: Callable[[str], bool],
              budget: int | None = None) -> list[FleetTicket]:
        """Admit pending tickets weighted-fair until no tenant can take more.

        Args:
          admit: callback taking a :class:`FleetTicket` and returning the
            live :class:`Session` (the fleet routes it into the ticket's
            tenant's launch group).  A ``ValueError``/``CapacityError`` it
            raises marks the ticket rejected (dropped — it could never
            succeed later) without poisoning the rest of the drain.
          has_room: per-tenant eligibility — False freezes that tenant's
            FIFO for this drain (its group store is full).
          budget: at most this many admissions this drain (None:
            unbounded).  The budget is the *shared* capacity the weights
            ration: with every tenant backlogged and roomy, a per-tick
            budget B splits as ``B · w_t / Σw`` — without one, each tenant
            simply fills its own free rows and the weights never bind.

        Returns the admitted tickets in admission order.  Raises
        :class:`DrainRejected` (admitted tickets + rejects attached) after
        the drain completes if any ticket was refused.
        """
        self._round += 1
        admitted: list[FleetTicket] = []
        rejected: list[tuple[FleetTicket, Exception]] = []
        left = float("inf") if budget is None else int(budget)

        def _take(ticket: FleetTicket) -> None:
            nonlocal left
            self._fifos[ticket.tenant].popleft()
            self._sids.discard(ticket.sid)
            try:
                admit(ticket)
            except (ValueError, CapacityError) as err:
                # Rejects don't consume budget — a poison ticket must not
                # cost a healthy one its slot.
                rejected.append((ticket, err))
                return
            self._admitted[ticket.tenant] += 1
            admitted.append(ticket)
            left -= 1

        # Aging guard first: head tickets older than the guard go straight
        # in (oldest enqueue round first), bypassing the weighted pick.
        while left > 0:
            stale = [f[0] for name, f in self._fifos.items()
                     if f and has_room(name)
                     and self._round - f[0].enqueued_round
                     >= self.aging_rounds]
            if not stale:
                break
            _take(min(stale, key=lambda t: (t.enqueued_round, t.seq)))

        # Weighted-fair: repeatedly admit from the eligible tenant with the
        # lowest admitted/weight pass (deterministic name tiebreak).
        while left > 0:
            eligible = [name for name, f in self._fifos.items()
                        if f and has_room(name)]
            if not eligible:
                break
            name = min(eligible,
                       key=lambda n: (self._admitted[n] / self.weights[n], n))
            _take(self._fifos[name][0])
        if rejected:
            raise DrainRejected(admitted, rejected)
        return admitted

    def oldest_wait_s(self, tenant: str | None = None,
                      now: float | None = None) -> float:
        """Head-of-line age (s) — fleet-wide, or one tenant's own FIFO."""
        fifos = ([self._fifos[tenant]] if tenant is not None
                 else self._fifos.values())
        heads = [f[0].submitted_at for f in fifos if f]
        if not heads:
            return 0.0
        now = time.monotonic() if now is None else now
        return max(0.0, now - min(heads))

    def waiting(self, tenant: str | None = None) -> list[FleetTicket]:
        """Pending tickets (one tenant's FIFO, or all tenants, FIFO order)."""
        if tenant is not None:
            return list(self._fifos[tenant])
        out = [t for f in self._fifos.values() for t in f]
        return sorted(out, key=lambda t: t.seq)

    def shares(self) -> dict[str, float]:
        """Cumulative admitted-capacity share per tenant (sums to 1.0)."""
        total = sum(self._admitted.values())
        if not total:
            return {name: 0.0 for name in self._admitted}
        return {name: n / total for name, n in self._admitted.items()}

    @property
    def depth(self) -> int:
        return len(self._sids)

    def depth_of(self, tenant: str) -> int:
        return len(self._fifos[tenant])

    def __len__(self) -> int:
        return len(self._sids)

    def __contains__(self, sid: str) -> bool:
        return sid in self._sids

    # -- persistence hooks (repro.serve.persistence fleet snapshots) ---------
    def state(self) -> dict:
        """Fairness ledger + round/seq cursors (tickets serialize apart)."""
        return {"admitted": dict(self._admitted), "round": self._round,
                "seq": self._seq}

    def load_state(self, state: dict) -> None:
        for name, n in (state.get("admitted") or {}).items():
            if name in self._admitted:
                self._admitted[name] = int(n)
        self._round = int(state.get("round", 0))
        self._seq = max(self._seq, int(state.get("seq", 0)))

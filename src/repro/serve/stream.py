"""Streaming engine: unbounded signals, chunk-by-chunk, one batched launch.

The continuous-monitoring counterpart of ``repro.serve.engine``: where the
LM engine serves static batches of prompts, this engine serves *sessions* —
open-ended signals (ECG leads, sensor feeds) that arrive as ragged chunks.
Per tick it

1. collects every submitted chunk, pads them to a common T,
2. folds each session's S MC chains into the batch axis (one weight fetch
   feeds every chain of every session — the paper's sample-wise pipelining,
   now also *session-wise*),
3. resumes each row's carried ``(h, c)`` through the sequence-fused kernel
   in **one ``pallas_seq`` launch per layer**, with per-row ``lengths``
   freezing ragged rows at their own chunk end,
4. emits per-chunk Bayesian uncertainty (``classification_summary`` /
   ``regression_summary``) and stores the new carry.

Bit-exactness contract: streaming passes always supply ``lengths`` (even
when every chunk has the same T).  The lengths-enabled graph family is
bit-identical across launch sizes, chunk splits, batch composition and
backends, so a session's results never depend on how its signal was chunked
or on which other sessions happened to share the batch — the invariant
``tests/test_streaming.py`` pins down.  Masks stay tied across the whole
session via the ``(seed, rows)`` coordinates in ``repro.serve.sessions``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autoencoder as _ae, classifier as _clf
from repro.core.uncertainty import (ClassificationSummary, RegressionSummary,
                                    classification_summary,
                                    regression_summary)
from repro.serve.sessions import SessionStore


@dataclasses.dataclass
class ChunkResult:
    """Per-chunk Bayesian output for one session."""

    sid: str
    length: int                # timesteps in this chunk
    steps_total: int           # timesteps consumed by the session so far
    summary: Any               # ClassificationSummary | RegressionSummary
                               # (leading batch axis squeezed away)


class StreamingEngine:
    """Stateful session serving for the ECG classifier / autoencoder models.

    Args:
      params: model parameters (``classifier.init`` / ``autoencoder.init``).
      cfg: the matching ``ClassifierConfig`` or ``AutoencoderConfig``; its
        ``mcd`` block fixes S (chains per session), p, placement and seed.
      backend: ``run_stack`` execution path; ``"pallas_seq"`` is the serving
        hot path (weights VMEM-resident across each chunk).
      max_sessions: admission bound on concurrently-open sessions.
      chunk_capacity: when set, every tick launches with a **fixed shape** —
        chunks pad to this many timesteps and the batch pads to
        ``max_sessions`` session slots (dummy rows, length 1, discarded).
        One jit trace / XLA compile serves every tick, whatever the ragged
        chunk lengths or tick composition; without it each new
        ``(max chunk len, session count)`` pair retraces.  Chunks longer
        than the capacity are rejected.
      interpret: forwarded to the Pallas backends (default: auto off-TPU).
    """

    def __init__(self, params, cfg, *, backend: str = "pallas_seq",
                 max_sessions: int = 64, chunk_capacity: int | None = None,
                 interpret: bool | None = None):
        if isinstance(cfg, _clf.ClassifierConfig):
            self.kind = "classifier"
        elif isinstance(cfg, _ae.AutoencoderConfig):
            self.kind = "autoencoder"
        else:
            raise TypeError(f"unsupported config type {type(cfg).__name__}")
        self.params = params
        self.cfg = cfg
        self.backend = backend
        self.interpret = interpret
        self.chunk_capacity = chunk_capacity
        self.max_sessions = max_sessions
        s = cfg.mcd.n_samples if cfg.mcd.any_bayesian else 1
        self.n_samples = max(1, s)
        self.store = SessionStore(self.n_samples, cfg.mcd.seed,
                                  max_sessions=max_sessions)

    # -- session lifecycle ---------------------------------------------------
    def open_session(self, sid: str):
        """Admit a stream; its S mask rows are fixed here, for life."""
        return self.store.admit(sid)

    def close_session(self, sid: str):
        """Evict a finished stream; returns the Session (final carry)."""
        return self.store.evict(sid)

    def attach_session(self, session):
        """Re-admit an evicted Session (same draw: state + (seed, rows))."""
        return self.store.attach(session)

    @property
    def active_sessions(self) -> list[str]:
        return self.store.active

    # -- serving -------------------------------------------------------------
    def step(self, chunks: Mapping[str, Any]) -> dict[str, ChunkResult]:
        """Serve one chunk per submitting session, in one batched pass.

        ``chunks`` maps session id → ``[t, input_dim]`` (or ``[t]`` when
        ``input_dim == 1``) signal slices; ``t`` may differ per session
        (ragged) and must be >= 1.  Every listed session must be open.
        Returns per-session :class:`ChunkResult`; carried state advances.
        """
        if not chunks:
            return {}
        s = self.n_samples
        sessions, xs, lens = [], [], []
        for sid, chunk in chunks.items():
            sess = self.store.get(sid)
            x = np.asarray(chunk)
            if x.ndim == 1:
                x = x[:, None]
            if x.ndim != 2 or x.shape[0] < 1:
                raise ValueError(f"chunk for {sid!r} must be [t>=1, "
                                 f"input_dim], got shape {tuple(x.shape)}")
            sessions.append(sess)
            xs.append(x)
            lens.append(x.shape[0])

        if self.chunk_capacity is not None and max(lens) > self.chunk_capacity:
            raise ValueError(f"chunk of {max(lens)} steps exceeds "
                             f"chunk_capacity={self.chunk_capacity}")
        t_max = self.chunk_capacity or max(lens)
        dtype = xs[0].dtype
        # Fixed-shape mode pads idle session slots so one compiled graph
        # serves every tick (dummy rows freeze after step 0, results dropped).
        n_pad = ((self.max_sessions - len(sessions)) * s
                 if self.chunk_capacity is not None else 0)
        # Batch assembly stages in host numpy — one device transfer per
        # operand per tick, not O(sessions) tiny dispatches.  Session-major,
        # chain-minor: row k*S+j is chain j of session k, matching the
        # concatenated per-session mask rows.
        nb = len(sessions) * s + n_pad
        x_host = np.zeros((nb, t_max, xs[0].shape[1]), dtype)
        rows_host = np.zeros((nb,), np.uint32)
        lens_host = np.ones((nb,), np.int32)
        for k, (x, L, sess) in enumerate(zip(xs, lens, sessions)):
            sl = slice(k * s, (k + 1) * s)
            x_host[sl, :L] = x[None]
            rows_host[sl] = np.asarray(sess.rows)
            lens_host[sl] = L
        x_batch = jnp.asarray(x_host)
        rows = jnp.asarray(rows_host)
        lengths = jnp.asarray(lens_host)
        initial_state = self._gather_states(sessions, dtype, n_pad)

        if self.kind == "classifier":
            logits, states = _clf.apply(
                self.params, x_batch, rows, self.cfg, backend=self.backend,
                initial_state=initial_state, lengths=lengths,
                return_state=True)
        else:
            mean, log_var, states = _ae.apply(
                self.params, x_batch, rows, self.cfg, backend=self.backend,
                initial_state=initial_state, lengths=lengths,
                return_state=True)

        # One batched summary over [S, n_sessions, ...] — per-session results
        # are indexed out, not recomputed per session.
        k_n = len(sessions)
        if self.kind == "classifier":
            per_chain = jnp.swapaxes(
                logits.reshape(-1, s, logits.shape[-1])[:k_n], 0, 1)
            batched = classification_summary(per_chain.astype(jnp.float32))
        else:
            shape = (-1, s) + mean.shape[1:]
            mu = jnp.swapaxes(mean.reshape(shape)[:k_n], 0, 1)
            lv = (None if log_var is None
                  else jnp.swapaxes(log_var.reshape(shape)[:k_n], 0, 1))
            batched = regression_summary(
                mu.astype(jnp.float32),
                None if lv is None else lv.astype(jnp.float32))

        results: dict[str, ChunkResult] = {}
        for k, (sess, L) in enumerate(zip(sessions, lens)):
            sl = slice(k * s, (k + 1) * s)
            if self.kind == "classifier":
                summary = ClassificationSummary(*(v[k] for v in batched))
            else:
                summary = RegressionSummary(*(v[k, :L] for v in batched))
            sess.state = [tuple(part[sl] for part in layer)
                          for layer in states]
            sess.steps += L
            sess.chunks += 1
            results[sess.sid] = ChunkResult(sid=sess.sid, length=L,
                                            steps_total=sess.steps,
                                            summary=summary)
        return results

    def _gather_states(self, sessions, dtype, n_pad: int = 0):
        """Concatenate per-session carries into batch-aligned layer states.

        Fresh sessions (and fixed-shape pad slots) contribute zeros in the
        backend's own carry dtypes (h in the activation dtype; c in fp32 on
        the Pallas backends, the activation dtype on reference), so a mixed
        fresh/resumed batch is bit-identical to serving each session alone.
        In fixed-shape mode zeros are always materialized: an all-fresh
        first tick must present the same jit pytree as every later tick,
        or the one-graph guarantee would break on tick two.
        """
        if all(sess.fresh for sess in sessions) and self.chunk_capacity is None:
            return None
        c_dtype = dtype if self.backend == "reference" else jnp.float32
        hiddens = (self._encoder_hiddens())
        layers = []
        for li, hid in enumerate(hiddens):
            hs, cs = [], []
            for sess in sessions:
                if sess.fresh:
                    hs.append(jnp.zeros((self.n_samples, hid), dtype))
                    cs.append(jnp.zeros((self.n_samples, hid), c_dtype))
                else:
                    h, c = sess.state[li]
                    hs.append(h)
                    cs.append(c)
            if n_pad:
                hs.append(jnp.zeros((n_pad, hid), dtype))
                cs.append(jnp.zeros((n_pad, hid), c_dtype))
            layers.append((jnp.concatenate(hs), jnp.concatenate(cs)))
        return layers

    def _encoder_hiddens(self):
        if self.kind == "classifier":
            return (self.cfg.hidden,) * self.cfg.num_layers
        return self.cfg.encoder_hiddens

"""Streaming engine: unbounded signals, chunk-by-chunk, one batched launch.

The continuous-monitoring counterpart of ``repro.serve.engine``: where the
LM engine serves static batches of prompts, this engine serves *sessions* —
open-ended signals (ECG leads, sensor feeds) that arrive as ragged chunks.
Per tick it

1. collects every submitted chunk, pads them to a common T,
2. folds each session's S MC chains into the batch axis (one weight fetch
   feeds every chain of every session — the paper's sample-wise pipelining,
   now also *session-wise*),
3. resumes each row's carried ``(h, c)`` through the sequence-fused kernel
   in **one ``pallas_seq`` launch per layer**, with per-row ``lengths``
   freezing ragged rows at their own chunk end,
4. emits per-chunk Bayesian uncertainty (``classification_summary`` /
   ``regression_summary``) and stores the new carry.

Bit-exactness contract: streaming passes always supply ``lengths`` (even
when every chunk has the same T).  The lengths-enabled graph family is
bit-identical across launch sizes, chunk splits, batch composition and
backends, so a session's results never depend on how its signal was chunked
or on which other sessions happened to share the batch — the invariant
``tests/test_streaming.py`` pins down.  Masks stay tied across the whole
session via the ``(seed, rows)`` coordinates in ``repro.serve.sessions``.

The control plane (PR 3) sits on top of this data plane: async admission
with priorities and bounded backpressure (``admit``/``repro.serve.
admission``), crash-safe durability (``snapshot``/``restore`` over
``repro.serve.persistence``), and an adaptive launch-shape scheduler with
per-tick metrics (``chunk_capacity="auto"``, ``repro.serve.scheduler``).

The multi-device data plane (PR 5) slots underneath: ``mesh=`` shards
every tick's batch rows over the mesh's data axes with bit-identical
results (``repro.launch.rnn_shardings``), session slots pad to whole
sessions per shard, and per-tick metrics flow through a pluggable
:class:`MetricsSink` (ring buffer by default, JSONL for a durable trail).
"""

from __future__ import annotations

import dataclasses
import json
import time
from collections import deque
from typing import Any, Mapping, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (autoencoder as _ae, classifier as _clf,
                        distill as _distill, mcd as _mcd)
from repro.kernels import quantize as _quant
from repro.core.uncertainty import (ClassificationSummary, RegressionSummary,
                                    RunningClassificationSummary,
                                    RunningRegressionSummary,
                                    classification_summary,
                                    regression_summary)
from repro.serve import persistence as _persist
from repro.serve.admission import AdmissionQueue, DrainRejected
from repro.serve.scheduler import AdaptiveTickScheduler, TickMetrics
from repro.serve.sessions import Session, SessionStore


def stack_compile_count() -> int:
    """Total jit cache entries across the recurrent-stack entry points.

    The delta across a tick is ``TickMetrics.compiles`` — how many *new*
    stack graphs that tick had to build.  A latency spike with
    ``compiles > 0`` is a compile stall (fix: ``scheduler.prewarm``); one
    with ``compiles == 0`` is genuine overload (fix: shed load or let the
    co-design controller downshift).  Counts the ``repro.kernels.ops``
    jitted wrappers every unsharded backend dispatches through (the sharded
    path caches whole-tick callables separately).
    """
    from repro.kernels import ops
    fns = (ops.lstm_stack_layer, ops.fused_lstm_seq, ops.fused_lstm_layer,
           ops.gru_stack_layer, ops.fused_gru_seq, ops.fused_gru_layer)
    return sum(fn._cache_size() for fn in fns)


@dataclasses.dataclass
class ChunkResult:
    """Per-chunk Bayesian output for one session."""

    sid: str
    length: int                # timesteps in this chunk
    steps_total: int           # timesteps consumed by the session so far
    summary: Any               # ClassificationSummary | RegressionSummary
                               # (leading batch axis squeezed away)


# ---------------------------------------------------------------------------
# Metrics sinks — where per-tick observables go
# ---------------------------------------------------------------------------

@runtime_checkable
class MetricsSink(Protocol):
    """Where the engine's per-tick :class:`TickMetrics` go.

    The engine serves *unbounded* streams, so the sink contract is
    explicitly bounded: ``emit`` consumes one record, ``window`` returns
    the recent records the sink still holds (for ``engine.metrics`` /
    ``summarize``) — how many is the sink's policy, not the engine's.
    """

    def emit(self, m: TickMetrics) -> None: ...

    def window(self) -> Sequence[TickMetrics]: ...

    def last(self) -> TickMetrics | None: ...

    def close(self) -> None: ...


class RingBufferSink:
    """Default sink: a bounded in-memory ring (the last ``window`` ticks)."""

    def __init__(self, window: int = 4096):
        self._ring: deque[TickMetrics] = deque(maxlen=int(window))

    def emit(self, m: TickMetrics) -> None:
        self._ring.append(m)

    def window(self) -> list[TickMetrics]:
        return list(self._ring)

    def last(self) -> TickMetrics | None:
        """Newest record, O(1) — serve loops poll this every tick."""
        return self._ring[-1] if self._ring else None

    def close(self) -> None:
        pass


class JsonlSink(RingBufferSink):
    """Append every tick as one JSON line; keeps the ring for ``window()``.

    Every record is flushed as it is written: the JSONL trail is what
    post-mortem SLO analysis reads after a crash, so a killed engine must
    not lose a buffered tail — at most the in-flight line is torn (and an
    operator can ``tail -f`` the file live).  Used by
    ``repro.launch.stream --metrics-out`` and, duck-typed, as the durable
    ``DecisionRecord`` trail of ``repro.serve.controller``.
    """

    def __init__(self, path, *, window: int = 4096):
        super().__init__(window)
        self.path = path
        self._fh = open(path, "a")

    def emit(self, m) -> None:
        super().emit(m)
        self._fh.write(json.dumps(dataclasses.asdict(m)) + "\n")
        self._fh.flush()

    def close(self) -> None:
        self._fh.close()


class StreamingEngine:
    """Stateful session serving for the ECG classifier / autoencoder models.

    Args:
      params: model parameters (``classifier.init`` / ``autoencoder.init``).
      cfg: the matching ``ClassifierConfig`` or ``AutoencoderConfig``; its
        ``mcd`` block fixes S (chains per session), p, placement and seed.
      backend: ``run_stack`` execution path; ``"pallas_seq"`` is the serving
        hot path (weights VMEM-resident across each chunk).
      max_sessions: admission bound on concurrently-open sessions.
      chunk_capacity: when an int, every tick launches with a **fixed
        shape** — chunks pad to this many timesteps and the batch pads to
        ``max_sessions`` session slots (dummy rows, length 1, discarded).
        One jit trace / XLA compile serves every tick, whatever the ragged
        chunk lengths or tick composition; without it each new
        ``(max chunk len, session count)`` pair retraces.  Chunks longer
        than the capacity are rejected.  ``"auto"`` delegates the choice to
        an :class:`AdaptiveTickScheduler` — per tick the launch T is picked
        from a small ladder of pre-warmable shapes tracking the observed
        chunk-length distribution (compiles bounded by the ladder length;
        batch still pads to ``max_sessions``).  All three policies are
        bit-identical: the lengths-pinned graph family doesn't care about
        launch shape.
      max_pending: admission-queue bound (``admit`` backpressure).
      ladder: capacity candidates for ``chunk_capacity="auto"`` (default:
        powers of two up to 512, see ``scheduler.pow2_ladder``).
      metrics_window: ring size of the default metrics sink (and the
        ``dropped_admissions`` bound) — bounded, the engine targets
        unbounded streams.
      metrics_sink: where per-tick :class:`TickMetrics` go (a
        :class:`MetricsSink`; default: ``RingBufferSink(metrics_window)``).
        ``engine.metrics`` reads the sink's window, so ``JsonlSink`` keeps
        the in-process observables *and* a durable JSONL trail.
      mesh, policy: shard every launch over the mesh's data axes
        (``repro.launch.rnn_shardings``).  The engine becomes placement-
        aware: session slots pad to a whole number per shard so each
        device serves complete sessions (all S chains of a session land
        on one shard), while mask rows stay *global* coordinates — which
        is exactly why snapshots remain host-portable: a snapshot taken
        on an 8-device mesh restores bit-identically onto 1 device (or
        any other mesh shape), because nothing device-shaped is ever part
        of the Bayesian draw or the carry.
      precision: serving precision (``repro.kernels.quantize.PRECISIONS``;
        None = native dtypes).  Quantized/cast in-graph from the fp32
        master params every launch — ``params`` and training checkpoints
        are untouched.  The carry dtypes follow the precision (h in the
        activation dtype, LSTM c in fp32), so snapshots record it and
        :meth:`restore` refuses a mismatch — resuming bf16 carries into
        an fp32 engine would silently change the stream's numerics.
      early_exit_threshold: enable staged early-exit MC sampling.  After
        each served chunk the engine compares a session's uncertainty
        summary over *all* its chains against the summary over the first
        half (the incremental ``Running*Summary`` accumulators in
        ``repro.core.uncertainty``): a prefix-converged session —
        classification: ``|MI_full - MI_half|``; autoencoder: mean
        ``|epistemic_full - epistemic_half|`` — has its surplus chains
        retired to ``max(min_samples, ceil(s/2))``, one stage per tick.
        Retirement keeps a chain *prefix*, so surviving chains' masks and
        carries are untouched and co-batched neighbours are unaffected
        (masks stay pure functions of ``(seed, rows)``).  ``None``
        (default) disables the estimator entirely — the engine is then
        bit-identical to the pre-dynamic-S static engine on every
        backend, cell, chunking and snapshot path.  Incompatible with
        ``mesh`` (ragged chain counts would unbalance the shards).
      min_samples: the early-exit floor — no session is ever retired
        below this many chains (the ``SLOPolicy.min_samples`` uncertainty
        floor, enforced in the data plane).
      student: distilled student heads (``repro.core.distill.init_student``)
        enabling ``mode="student"`` sessions — one deterministic row
        (``STUDENT_ROW_FLAG``: the kernels skip its masks in-register)
        co-batched in the same per-layer launches as the MC chains, decoded
        through the student heads instead of the chain-axis estimator.
        None (default): student admissions are refused and the engine is
        bit-identical to the pre-distill engine.  Incompatible with
        ``mesh`` (single-row sessions would break the whole-sessions-per-
        shard placement).
      student_escalate_threshold: MC fallback trigger.  After each served
        chunk, a student session whose *predicted* epistemic uncertainty
        (classifier: MI nats; autoencoder: mean epistemic variance) exceeds
        this value escalates: ``SessionStore.grow`` retires the student row
        and regrows ``n_samples`` fresh MC chains from the student's carry,
        so from the next chunk the session runs the full Bayesian
        estimator.  Fresh rows mean no mask reuse — the escalated session
        is bit-identical to an always-MC session attached at that carry.
        None: students never escalate (still serviceable via an explicit
        ``store.grow``).
      interpret: forwarded to the Pallas backends (default: auto off-TPU).
    """

    def __init__(self, params, cfg, *, backend: str = "pallas_seq",
                 max_sessions: int = 64,
                 chunk_capacity: int | str | None = None,
                 max_pending: int = 256, ladder=None,
                 scheduler: AdaptiveTickScheduler | None = None,
                 metrics_window: int = 4096,
                 metrics_sink: MetricsSink | None = None,
                 mesh=None, policy=None, precision: str | None = None,
                 early_exit_threshold: float | None = None,
                 min_samples: int = 1,
                 student=None,
                 student_escalate_threshold: float | None = None,
                 interpret: bool | None = None):
        if isinstance(cfg, _clf.ClassifierConfig):
            self.kind = "classifier"
        elif isinstance(cfg, _ae.AutoencoderConfig):
            self.kind = "autoencoder"
        else:
            raise TypeError(f"unsupported config type {type(cfg).__name__}")
        self.params = params
        self.cfg = cfg
        self.backend = backend
        if precision is not None:
            _quant.check_precision(precision)
        self.precision = precision
        self.interpret = interpret
        self.chunk_capacity = chunk_capacity
        self.max_sessions = max_sessions
        self.mesh = mesh
        self.policy = policy
        if mesh is not None:
            # deferred: serve must import without the launch layer
            from repro.launch import rnn_shardings as _rs
            self._shards = _rs.data_size(mesh, policy or _rs.DEFAULT_POLICY)
        else:
            self._shards = 1
        self._scheduler = None
        if chunk_capacity == "auto":
            # A caller-tuned scheduler (percentile, window) wins over the
            # default ladder-only construction.
            self._scheduler = scheduler or AdaptiveTickScheduler(ladder)
        elif isinstance(chunk_capacity, str):
            raise ValueError(f"chunk_capacity must be an int, None or "
                             f"'auto', got {chunk_capacity!r}")
        # Fixed-shape launches (idle session slots padded) for both the
        # hand-set capacity and the adaptive ladder — one graph per shape.
        self._fixed = chunk_capacity is not None
        # Recurrent cell type drives the carry pytree arity: LSTM sessions
        # store per-layer (h, c), GRU sessions (h,) — see _gather_states.
        self.cell = getattr(cfg, "cell", "lstm")
        s = cfg.mcd.n_samples if cfg.mcd.any_bayesian else 1
        # The engine-wide chain *ceiling*.  S itself is per-session state
        # (SessionStore): admissions may open below the ceiling and early
        # exit retires chains mid-stream, so launch shapes are sized by the
        # ceiling while live chain counts drift underneath it.
        self.n_samples = max(1, s)
        if early_exit_threshold is not None:
            if mesh is not None:
                raise ValueError(
                    "early_exit_threshold is incompatible with mesh= — "
                    "ragged per-session chain counts would unbalance the "
                    "whole-sessions-per-shard placement; run early exit "
                    "unsharded or disable it on the mesh engine")
            if not float(early_exit_threshold) >= 0.0:
                raise ValueError(f"early_exit_threshold must be >= 0, "
                                 f"got {early_exit_threshold}")
        self.early_exit_threshold = (None if early_exit_threshold is None
                                     else float(early_exit_threshold))
        if not 1 <= int(min_samples) <= self.n_samples:
            raise ValueError(
                f"min_samples must be in [1, {self.n_samples}], "
                f"got {min_samples}")
        self.min_samples = int(min_samples)
        if student is not None and mesh is not None:
            raise ValueError(
                "student= is incompatible with mesh= — single-row student "
                "sessions would break the whole-sessions-per-shard "
                "placement; serve the distilled fast path unsharded")
        self.student = student
        if student_escalate_threshold is not None:
            if student is None:
                raise ValueError("student_escalate_threshold needs student= "
                                 "heads — there is nothing to escalate from")
            if not float(student_escalate_threshold) >= 0.0:
                raise ValueError(
                    f"student_escalate_threshold must be >= 0, "
                    f"got {student_escalate_threshold}")
        self.student_escalate_threshold = (
            None if student_escalate_threshold is None
            else float(student_escalate_threshold))
        self.store = SessionStore(self.n_samples, cfg.mcd.seed,
                                  max_sessions=max_sessions)
        # Per-tick attribution for the fleet sink: sid -> chains served /
        # rows retired / student rows / escalations on the most recent
        # step() (read by FleetEngine to split the tick-level counts
        # across tenant records).
        self._last_served_chains: dict[str, int] = {}
        self._last_reclaimed: dict[str, int] = {}
        self._last_student_rows: dict[str, int] = {}
        self._last_escalated: dict[str, int] = {}
        self.queue = AdmissionQueue(max_pending)
        self.tick = 0
        # Pluggable, bounded: the engine is built for unbounded streams —
        # an ever-growing per-tick list would leak on exactly that
        # workload.  summarize() rolls up whatever the sink's window holds.
        self.metrics_sink: MetricsSink = (metrics_sink
                                          or RingBufferSink(metrics_window))
        # Tickets the store refused mid-drain ((Ticket, error) pairs, newest
        # last).  A drain rejection concerns the ticket's *owner*, not
        # whichever caller happened to trigger the drain — see _drain.
        self.dropped_admissions: deque = deque(maxlen=metrics_window)
        # Drops not yet surfaced in a TickMetrics record.  The deque above
        # is in-memory only; the metrics trail is the durable record, so
        # every drop — whether it happened inside step()'s drain or between
        # ticks in admit()/close_session() — lands in the next tick's
        # ``dropped`` count.
        self._dropped_unreported = 0

    # -- session lifecycle ---------------------------------------------------
    def open_session(self, sid: str, *, n_samples: int | None = None,
                     mode: str = "mc"):
        """Admit a stream *now* or fail fast with ``CapacityError``.

        The synchronous path — callers that would rather wait for a freed
        row than handle the error use :meth:`admit`.  Its mask rows are
        fixed here, for life; ``n_samples`` opens below the engine ceiling
        (None: the ceiling).  ``mode="student"`` opens on the distilled
        fast path (one deterministic row; needs ``student=`` heads).
        """
        if mode == "student":
            self._check_student(sid)
        else:
            self._check_chain_count(sid, n_samples)
        return self.store.admit(sid, n_samples=n_samples, mode=mode)

    def _check_student(self, sid: str) -> None:
        if self.student is None:
            raise ValueError(
                f"session {sid!r}: mode='student' needs an engine built "
                "with student= head params (repro.core.distill)")

    def _check_chain_count(self, sid: str, n_samples: int | None) -> None:
        # Sharded engines place whole sessions per shard assuming one S —
        # refuse a sub-ceiling admission up front rather than poisoning the
        # tick every co-batched session shares (see step()'s guard).
        if (n_samples is not None and self._shards > 1
                and int(n_samples) != self.n_samples):
            raise ValueError(
                f"session {sid!r}: sharded engines serve a uniform "
                f"{self.n_samples} chains/session; per-session S needs an "
                "unsharded engine")

    def admit(self, sid: str, *, priority: int = 0,
              session: Session | None = None,
              n_samples: int | None = None,
              mode: str | None = None):
        """Queue a stream for admission; drain it into any free row now.

        The asynchronous path: never raises ``CapacityError`` — at capacity
        the request waits (bounded by ``max_pending``; ``QueueFull`` beyond
        that) and goes live when an eviction or tick boundary frees a row,
        highest ``priority`` first, FIFO within a class.  ``session`` makes
        it a re-attach request (an evicted carry resumes the same draw).
        ``mode="student"`` queues a distilled fast-path admission.
        Returns the live :class:`Session` if admitted immediately, else
        None (it is queued; watch ``queued_sessions``).
        """
        if mode == "student":
            self._check_student(sid)
        if sid in self.store:
            raise ValueError(f"session {sid!r} already admitted")
        if session is not None:
            # Fail the statically-checkable mismatches *here*, not later
            # inside whichever step()/close_session() happens to drain the
            # ticket (where the error would hit an unrelated caller and,
            # in close_session, cost them the evicted carry).
            if session.seed != self.store.seed:
                raise ValueError(
                    f"session {sid!r} was drawn under seed "
                    f"{session.seed!r}, engine uses {self.store.seed!r}")
            if int(session.rows.shape[0]) > self.n_samples:
                raise ValueError(
                    f"session {sid!r} carries {int(session.rows.shape[0])} "
                    f"MC chains, engine ceiling is {self.n_samples}")
            if (self._shards > 1
                    and int(session.rows.shape[0]) != self.n_samples):
                self._check_chain_count(sid, int(session.rows.shape[0]))
            if session.mode == "student":
                self._check_student(sid)
        elif mode != "student":
            self._check_chain_count(sid, n_samples)
        self.queue.submit(sid, priority=priority, session=session,
                          n_samples=n_samples, mode=mode)
        try:
            self.queue.drain(self.store)
        except DrainRejected as err:
            # The caller is synchronously present for *its own* ticket: if
            # the drain rejected it (e.g. a row collision only the store
            # can detect), re-raise rather than return the None that means
            # "queued" — the ticket is gone and would never go live.
            # Other sessions' poison is contained as in _drain.
            mine = next((e for t, e in err.rejected if t.sid == sid), None)
            others = [(t, e) for t, e in err.rejected if t.sid != sid]
            self.dropped_admissions.extend(others)
            self._dropped_unreported += len(others)
            if mine is not None:
                raise mine from err
        live = self.store
        return live.get(sid) if sid in live else None

    def close_session(self, sid: str):
        """Evict a finished stream; returns the Session (final carry).

        The freed row is immediately offered to the admission queue.
        """
        sess = self.store.evict(sid)
        self._drain()
        return sess

    def attach_session(self, session):
        """Re-admit an evicted Session (same draw: state + (seed, rows))."""
        if session.mode == "student":
            self._check_student(session.sid)
        else:
            self._check_chain_count(session.sid, int(session.rows.shape[0]))
        return self.store.attach(session)

    def _drain(self):
        # DrainRejected stops at this layer: the poison is some *other*
        # session's ticket, and raising here would fail an unrelated caller
        # — close_session would lose the evicted carry it must return, a
        # successful admit() would look failed, step() would drop its tick.
        # The drain already completed (healthy tickets went live); record
        # the rejects for the operator and keep serving.
        try:
            return self.queue.drain(self.store)
        except DrainRejected as err:
            self.dropped_admissions.extend(err.rejected)
            self._dropped_unreported += len(err.rejected)
            return err.admitted

    @property
    def active_sessions(self) -> list[str]:
        return self.store.active

    @property
    def queued_sessions(self) -> list[str]:
        """Sids still waiting for a row, in drain order."""
        return [t.sid for t in self.queue.waiting()]

    @property
    def metrics(self) -> Sequence[TickMetrics]:
        """The metrics sink's retained window (recent ticks, oldest first)."""
        return self.metrics_sink.window()

    @property
    def last_metrics(self) -> TickMetrics | None:
        return self.metrics_sink.last()

    # -- durability ----------------------------------------------------------
    def snapshot(self, directory: str, *, step: int | None = None,
                 extra: dict | None = None) -> str:
        """Atomic, crash-safe snapshot of every live + queued stream.

        Durable state is exactly: per-session per-chain ``(h, c)`` carries,
        ``(seed, rows)`` mask coordinates, step/chunk cursors, the row
        allocator, the admission wait-list, the scheduler's observation
        window and the tick counter.  Masks themselves are *not* stored —
        the counter PRNG recomputes them from ``(seed, rows)``, which is
        why restore is bit-exact.  Model params ride the training
        checkpoint, not the session snapshot.
        """
        return _persist.snapshot_store(directory, self.store, step=step,
                                       queue=self.queue,
                                       extra=self._engine_meta(extra))

    def _engine_meta(self, extra: dict | None = None) -> dict:
        """The per-engine snapshot meta — validated by :meth:`restore`.

        Factored out so a :class:`~repro.serve.fleet.FleetEngine` snapshot
        can embed one of these per launch group under a single atomic
        manifest and reuse the exact same restore-time validation.
        """
        engine_meta = {"tick": self.tick, "kind": self.kind,
                       "backend": self.backend, "cell": self.cell,
                       # Validated on restore: the carry dtypes (h in the
                       # activation dtype, LSTM c fp32) follow the serving
                       # precision, so the stream is only resumable under
                       # the precision that produced it.
                       "precision": self.precision,
                       # Observability only — deliberately NOT validated on
                       # restore: a snapshot is host-portable and restores
                       # onto any mesh shape (mask rows are global, carries
                       # are device-free host arrays).
                       "data_shards": self._shards,
                       "mcd": {"p": float(self.cfg.mcd.p),
                               "placement":
                                   _mcd.placement_str(self.cfg.mcd.placement)}}
        if self._scheduler is not None:
            engine_meta["sched"] = self._scheduler.state()
        if extra is not None:
            engine_meta["extra"] = extra
        return engine_meta

    def restore(self, directory: str, *, step: int | None = None,
                sids: list[str] | None = None) -> dict:
        """Resume every snapshotted stream into this (fresh) engine.

        Replaces the store, wait-list and tick counter with the snapshot's;
        serving then continues bit-identically to the uninterrupted run
        (any backend, any ``chunk_capacity`` — including one different
        from the snapshotting process's).  Returns the engine ``extra``
        meta stashed by :meth:`snapshot`.  The engine must be freshly
        constructed (no live sessions) with a matching model config.
        """
        if self.store.sessions() or len(self.queue):
            raise RuntimeError("restore() needs a fresh engine: live or "
                               "queued sessions would collide")
        # Size the replacement queue to hold the snapshot's whole wait-list
        # — a valid snapshot must restore even if this process was launched
        # with a smaller max_pending than the one that wrote it.
        peek = _persist.load_snapshot_meta(directory, step)
        queue = AdmissionQueue(max(self.queue.max_pending,
                                   len(peek["queue"]) or 1))
        store, meta = _persist.restore_store(
            directory, step=peek["step"], sids=sids, queue=queue,
            max_sessions=self.max_sessions)
        engine_meta = self._check_restore_meta(meta)
        self._adopt(store, queue, engine_meta)
        return engine_meta.get("extra", {})

    def _check_restore_meta(self, meta: dict) -> dict:
        """Validate snapshot meta against this engine; return its engine meta.

        Shared by :meth:`restore` and the fleet restore path — every typed
        mismatch error below fires identically whether the snapshot is a
        standalone engine's or one launch group inside a fleet manifest.
        """
        # The snapshot records the writing store's chain *ceiling*; sessions
        # carry their own S in their rows arrays (pre-dynamic snapshots
        # simply have every session at the old uniform S).  The ceilings
        # must match exactly: it pins the row-allocator layout, and a
        # mismatch is a config mixup, not a resumable state.
        if meta["n_samples"] != self.n_samples:
            raise ValueError(
                f"snapshot's chain ceiling is {meta['n_samples']} MC "
                f"chains/session, engine ceiling is {self.n_samples}")
        if meta["seed"] != self.cfg.mcd.seed:
            raise ValueError(
                f"snapshot drawn under seed {meta['seed']!r}, engine uses "
                f"{self.cfg.mcd.seed!r} — resuming would change the masks")
        engine_meta = meta.get("extra") or {}
        if engine_meta.get("kind") not in (None, self.kind):
            raise ValueError(f"snapshot is a {engine_meta['kind']} stream, "
                             f"engine is a {self.kind}")
        # The carry pytree arity follows the cell — resuming LSTM (h, c)
        # carries into a GRU engine (or vice versa) could only mis-structure
        # the states (and the mask gate count differs anyway).
        snap_cell = engine_meta.get("cell", "lstm")
        if snap_cell != self.cell:
            raise ValueError(f"snapshot streamed through a {snap_cell} "
                             f"stack, engine runs {self.cell} — the carries "
                             "are not interchangeable")
        # The carry dtypes follow the serving precision (h in the
        # activation dtype, LSTM c fp32) — resuming across a precision
        # change would mix dtypes mid-stream and silently change the
        # numerics.  Pre-quantization snapshots carry no key: they were
        # written by native-dtype engines, so they restore only into one
        # (precision=None), which is exactly what get() defaults to.
        snap_prec = engine_meta.get("precision")
        if snap_prec != self.precision:
            raise ValueError(
                f"snapshot streamed at precision {snap_prec!r}, engine "
                f"serves {self.precision!r} — the carries are not "
                "interchangeable")
        # p/placement change the mask *values* even under the same (seed,
        # rows) — resuming across them would silently alter the draw.
        snap_mcd = engine_meta.get("mcd")
        here_mcd = {"p": float(self.cfg.mcd.p),
                    "placement": _mcd.placement_str(self.cfg.mcd.placement)}
        if snap_mcd is not None and snap_mcd != here_mcd:
            raise ValueError(
                f"snapshot streamed under mcd {snap_mcd}, engine uses "
                f"{here_mcd} — resuming would silently change the masks")
        return engine_meta

    def _adopt(self, store: SessionStore, queue: AdmissionQueue,
               engine_meta: dict) -> None:
        """Take over a restored store/queue + validated engine meta."""
        # A student session decodes through the student heads — adopting
        # one into an engine that has none would silently misserve it
        # (pre-distill snapshots carry no modes and restore everywhere).
        if self.student is None:
            stu = ([s.sid for s in store.sessions() if s.mode == "student"]
                   + [t.sid for t in queue.waiting()
                      if getattr(t, "mode", None) == "student"])
            if stu:
                raise ValueError(
                    f"snapshot carries student-mode sessions {sorted(stu)}; "
                    "this engine was built without student= heads")
        # The engine's own ceiling governs from here on (meta check pinned
        # them equal) — restored sessions keep whatever per-session S their
        # rows arrays carry.
        store.n_samples = self.n_samples
        self.store = store
        self.queue = queue
        self.tick = int(engine_meta.get("tick", 0))
        if self._scheduler is not None and "sched" in engine_meta:
            self._scheduler.load_state(engine_meta["sched"])

    # -- serving -------------------------------------------------------------
    def step(self, chunks: Mapping[str, Any]) -> dict[str, ChunkResult]:
        """Serve one chunk per submitting session, in one batched pass.

        ``chunks`` maps session id → ``[t, input_dim]`` (or ``[t]`` when
        ``input_dim == 1``) signal slices; ``t`` may differ per session
        (ragged) and must be >= 1.  Every listed session must be open.
        Returns per-session :class:`ChunkResult`; carried state advances.
        """
        self._drain()          # tick boundary: freed rows feed the wait-list
        if not chunks:
            return {}
        # Head-of-line admission delay *after* the drain: how long the
        # oldest stream that still couldn't get a row has been waiting.
        queue_wait_s = self.queue.oldest_wait_s()
        compiles_before = stack_compile_count()
        t_start = time.perf_counter()
        sessions, xs, lens = [], [], []
        for sid, chunk in chunks.items():
            sess = self.store.get(sid)
            x = np.asarray(chunk)
            if x.ndim == 1:
                x = x[:, None]
            if x.ndim != 2 or x.shape[0] < 1:
                raise ValueError(f"chunk for {sid!r} must be [t>=1, "
                                 f"input_dim], got shape {tuple(x.shape)}")
            sessions.append(sess)
            xs.append(x)
            lens.append(x.shape[0])
        # Per-session chain counts — S is session state, not an engine
        # constant.  With every session at the ceiling (the threshold-off
        # default) the layout below is byte-identical to the static-S
        # engine's; sharded launches require exactly that (whole sessions
        # per shard is only well-defined with one S).
        s_list = [int(sess.rows.shape[0]) for sess in sessions]
        if self._shards > 1 and any(si != self.n_samples for si in s_list):
            raise ValueError(
                "sharded launches need every session at the engine ceiling "
                f"({self.n_samples} chains); got {s_list} — per-session S "
                "would straddle shard boundaries")

        if self._scheduler is not None:
            t_max = self._scheduler.plan(lens)
        elif self.chunk_capacity is not None:
            if max(lens) > self.chunk_capacity:
                raise ValueError(f"chunk of {max(lens)} steps exceeds "
                                 f"chunk_capacity={self.chunk_capacity}")
            t_max = self.chunk_capacity
        else:
            t_max = max(lens)
        dtype = xs[0].dtype
        slots = self._slot_count(len(sessions))
        # Launch size: fixed-shape modes always budget ceiling chains per
        # slot — retired chains become tail padding and the one-graph
        # guarantee survives early exit.  Dynamic mode launches exactly the
        # live chains, so retirement shrinks the actual compute.
        live_chains = sum(s_list)
        nb = slots * self.n_samples if (self._fixed or self._shards > 1) \
            else live_chains
        n_pad = nb - live_chains
        # Batch assembly stages in host numpy — one device transfer per
        # operand per tick, not O(sessions) tiny dispatches.  Session-major,
        # chain-minor: session k's chains pack at offsets[k], matching the
        # concatenated per-session mask rows (offset k*S when uniform).
        x_host = np.zeros((nb, t_max, xs[0].shape[1]), dtype)
        rows_host = np.zeros((nb,), np.uint32)
        lens_host = np.ones((nb,), np.int32)
        offsets, off = [], 0
        for x, L, sess, si in zip(xs, lens, sessions, s_list):
            sl = slice(off, off + si)
            offsets.append(off)
            x_host[sl, :L] = x[None]
            rows_host[sl] = np.asarray(sess.rows)
            lens_host[sl] = L
            off += si
        x_batch = jnp.asarray(x_host)
        rows = jnp.asarray(rows_host)
        lengths = jnp.asarray(lens_host)
        initial_state = self._gather_states(sessions, dtype, n_pad)

        outs, states = self._apply(x_batch, rows, lengths, initial_state)
        if self.kind == "classifier":
            (logits,) = outs
        else:
            mean, log_var, dec_out = outs

        # Batched summaries over [s, group, ...] — per-session results are
        # indexed out, not recomputed per session.  A uniform tick (the
        # common case, and always when the threshold is off) is one reshape
        # of the contiguous live prefix — the static engine's exact op
        # sequence.  Ragged ticks group sessions by chain count (staged
        # halving keeps distinct counts at most log2(S)+1) and gather each
        # group's rows; values are launch-layout-invariant either way.
        # Student sessions sit outside the chain-axis estimator entirely:
        # their single deterministic row is decoded through the student
        # heads below, and only the MC sessions group.
        k_n = len(sessions)
        summaries: list = [None] * k_n
        stu_ks = [k for k in range(k_n) if sessions[k].mode == "student"]
        mc_ks = [k for k in range(k_n) if sessions[k].mode != "student"]
        mc_s = [s_list[k] for k in mc_ks]
        groups = ([(s_list[0], list(range(k_n)))]
                  if not stu_ks and len(set(s_list)) == 1
                  else sorted({si: [k for k in mc_ks if s_list[k] == si]
                               for si in set(mc_s)}.items()))
        for si, ks in groups:
            if len(ks) == k_n:
                sel = lambda a: a.reshape((-1, si) + a.shape[1:])[:k_n]  # noqa: E731
            else:
                idx = jnp.asarray(np.concatenate(
                    [np.arange(offsets[k], offsets[k] + si) for k in ks]))
                sel = lambda a: a[idx].reshape((len(ks), si) + a.shape[1:])  # noqa: E731
            if self.kind == "classifier":
                per_chain = jnp.swapaxes(sel(logits), 0, 1)
                batched = classification_summary(
                    per_chain.astype(jnp.float32))
                for j, k in enumerate(ks):
                    summaries[k] = ClassificationSummary(
                        *(v[j] for v in batched))
            else:
                mu = jnp.swapaxes(sel(mean), 0, 1)
                lv = (None if log_var is None
                      else jnp.swapaxes(sel(log_var), 0, 1))
                batched = regression_summary(
                    mu.astype(jnp.float32),
                    None if lv is None else lv.astype(jnp.float32))
                for j, k in enumerate(ks):
                    summaries[k] = RegressionSummary(
                        *(v[j] for v in batched))

        # Distilled fast path: a student session's summary comes from the
        # student heads on its one deterministic row's features — h_T for
        # the classifier, the decoder hidden sequence for the autoencoder.
        # One batched head call over every student row, indexed out like
        # the MC groups — per-session calls would put O(sessions) tiny
        # dispatches back on the tick.
        if stu_ks:
            idx = jnp.asarray([offsets[k] for k in stu_ks])
            if self.kind == "classifier":
                batched = _distill.classifier_student_summary(
                    self.student, states[-1][0][idx])
            else:
                batched = _distill.autoencoder_student_summary(
                    self.student, dec_out[idx],
                    getattr(self.cfg, "heteroscedastic", True))
            for j, k in enumerate(stu_ks):
                summaries[k] = type(batched)(*(v[j] for v in batched))

        # Windowed-decoder AEs reconstruct only min(L, W) positions per chunk
        # — the valid slice is capped by the decode window, not the chunk.
        win = getattr(self.cfg, "decode_window", None)
        results: dict[str, ChunkResult] = {}
        for k, (sess, L) in enumerate(zip(sessions, lens)):
            sl = slice(offsets[k], offsets[k] + s_list[k])
            if self.kind == "classifier":
                summary = summaries[k]
            else:
                valid = L if win is None else min(L, win)
                summary = RegressionSummary(
                    *(v[:valid] for v in summaries[k]))
            sess.state = [tuple(part[sl] for part in layer)
                          for layer in states]
            sess.steps += L
            sess.chunks += 1
            results[sess.sid] = ChunkResult(sid=sess.sid, length=L,
                                            steps_total=sess.steps,
                                            summary=summary)

        self._last_served_chains = {sess.sid: si for sess, si
                                    in zip(sessions, s_list)}
        self._last_student_rows = {sessions[k].sid: 1 for k in stu_ks}
        reclaimed = self._early_exit(sessions, lens, s_list, offsets, outs,
                                     win)
        # Escalation runs *after* state writeback: grow() tiles the carry
        # the tick just stored, so the regrown chains resume exactly the
        # student's post-chunk state.
        escalations = self._escalate(sessions, results)

        # Control-plane observables (host wall-clock; on CPU interpret the
        # dispatch is effectively synchronous, on TPU it's a dispatch proxy).
        dur = time.perf_counter() - t_start
        live_steps = int(sum(lens))
        live_chain_steps = int(sum(L * si for L, si in zip(lens, s_list)))
        m = TickMetrics(
            tick=self.tick, capacity=int(t_max), n_chunks=len(sessions),
            live_rows=live_chains, batch_rows=nb,
            queue_depth=len(self.queue), live_steps=live_steps,
            live_chain_steps=live_chain_steps,
            padded_steps=nb * int(t_max),
            pad_waste=1.0 - live_chain_steps / (nb * int(t_max)),
            duration_s=dur,
            tokens_per_sec=live_chain_steps / dur if dur > 0 else 0.0,
            shards=self._shards, queue_wait_s=queue_wait_s,
            compiles=stack_compile_count() - compiles_before,
            dropped=self._take_dropped(),
            active_chains=self.store.active_chains,
            reclaimed_rows=reclaimed,
            student_rows=len(stu_ks), escalations=escalations)
        self.metrics_sink.emit(m)
        self.tick += 1
        return results

    def _early_exit(self, sessions, lens, s_list, offsets, outs, win) -> int:
        """Retire surplus chains of prefix-converged sessions (one stage).

        For each served session still above the floor, compare the
        uncertainty summary over the prefix it would keep
        (``max(min_samples, ceil(s/2))`` chains) against the summary over
        all its chains, via the incremental accumulators — classification:
        ``|MI_full - MI_prefix|``; autoencoder: mean
        ``|epistemic_full - epistemic_prefix|`` over the valid positions.
        A delta at or under the threshold halves the session (down to the
        floor) through ``SessionStore.retire`` — prefix-trim only, so the
        survivors' masks/carries and every co-batched neighbour are
        untouched.  Returns total rows retired this tick.
        """
        self._last_reclaimed = {}
        if self.early_exit_threshold is None:
            return 0
        reclaimed = 0
        for k, (sess, L) in enumerate(zip(sessions, lens)):
            si = s_list[k]
            keep = max(self.min_samples, (si + 1) // 2)
            if keep >= si:
                continue
            off = offsets[k]
            if self.kind == "classifier":
                (logits,) = outs
                lg = np.asarray(logits[off:off + si])[:, None, :]  # [s,1,C]
                prefix = RunningClassificationSummary().update(lg[:keep])
                full = prefix.copy().update(lg[keep:])
                delta = float(np.abs(
                    np.asarray(full.finalize().mutual_information)
                    - np.asarray(prefix.finalize().mutual_information))[0])
            else:
                mean, log_var = outs[0], outs[1]
                valid = L if win is None else min(L, win)
                mu = np.asarray(mean[off:off + si, :valid])
                lv = (None if log_var is None
                      else np.asarray(log_var[off:off + si, :valid]))
                prefix = RunningRegressionSummary().update(
                    mu[:keep], None if lv is None else lv[:keep])
                full = prefix.copy().update(
                    mu[keep:], None if lv is None else lv[keep:])
                delta = float(np.mean(np.abs(
                    np.asarray(full.finalize().epistemic)
                    - np.asarray(prefix.finalize().epistemic))))
            if delta <= self.early_exit_threshold:
                n_ret = self.store.retire(sess.sid, keep)
                if n_ret:
                    reclaimed += n_ret
                    self._last_reclaimed[sess.sid] = n_ret
        return reclaimed

    def _escalate(self, sessions, results) -> int:
        """Regrow student sessions whose predicted uncertainty crossed the
        threshold (the MC fallback).

        Reads each student session's *served* summary — the student heads'
        predicted MI (classifier) / mean epistemic variance (autoencoder) —
        and a strict ``>`` compare against ``student_escalate_threshold``
        triggers ``SessionStore.grow(sid, n_samples)``: the det row retires
        and the engine-ceiling count of fresh MC chains resumes the tiled
        carry.  From the next chunk the session is indistinguishable from
        an always-MC session attached at that carry (fresh rows ⇒ fresh
        masks; pinned bit-identical in tests).  Returns escalation count.
        """
        self._last_escalated = {}
        if self.student_escalate_threshold is None:
            return 0
        n = 0
        for sess in sessions:
            if sess.mode != "student":
                continue
            summ = results[sess.sid].summary
            if self.kind == "classifier":
                u = float(np.asarray(summ.mutual_information))
            else:
                u = float(np.mean(np.asarray(summ.epistemic)))
            if u > self.student_escalate_threshold:
                self.store.grow(sess.sid, self.n_samples)
                self._last_escalated[sess.sid] = 1
                n += 1
        return n

    def _take_dropped(self) -> int:
        """Drops accumulated since the last metrics record (and reset)."""
        n, self._dropped_unreported = self._dropped_unreported, 0
        return n

    def _slot_count(self, n_sessions: int) -> int:
        """Session slots a tick launches with — the batch-layout contract.

        Fixed-shape modes pad idle slots to ``max_sessions`` so one
        compiled graph per shape serves every tick (dummy rows freeze
        after step 0, dropped); shard-aware placement then rounds up to a
        whole number of sessions per shard, so a session's S chains never
        straddle a device boundary and every shard launches the same
        shape.  Mask rows stay global — placement is a batch-layout
        concern only.  Single source for both :meth:`step` and
        :func:`repro.serve.scheduler.prewarm`: the prewarm guarantee is
        exactly "compiles the graph this formula will launch".
        """
        slots = self.max_sessions if self._fixed else n_sessions
        return -(-slots // self._shards) * self._shards

    def _apply(self, x_batch, rows, lengths, initial_state):
        """One batched model launch — the tick hot path.

        Factored out of :meth:`step` so :func:`repro.serve.scheduler.prewarm`
        can drive the *exact* serving graph (same shapes, dtypes and state
        pytree) at boot, compiling every ladder rung before traffic arrives.
        Returns ``(model outputs tuple, per-layer states)`` — for the
        autoencoder the outputs are ``(mean, log_var, dec_out)``: the
        decoder hidden sequence is requested unconditionally (``_ae.apply``
        is not itself jitted, so the extra return changes no numerics and
        keeps the graph independent of whether any student row is present;
        the student summary path reads it).
        """
        if self.kind == "classifier":
            logits, states = _clf.apply(
                self.params, x_batch, rows, self.cfg, backend=self.backend,
                initial_state=initial_state, lengths=lengths,
                return_state=True, mesh=self.mesh, policy=self.policy,
                precision=self.precision)
            return (logits,), states
        mean, log_var, dec_out, states = _ae.apply(
            self.params, x_batch, rows, self.cfg, backend=self.backend,
            initial_state=initial_state, lengths=lengths,
            return_state=True, return_decoded=True, mesh=self.mesh,
            policy=self.policy, precision=self.precision)
        return (mean, log_var, dec_out), states

    def _gather_states(self, sessions, dtype, n_pad: int = 0):
        """Concatenate per-session carries into batch-aligned layer states.

        Fresh sessions (and fixed-shape pad slots) contribute zeros in the
        backend's own carry dtypes (h in the activation dtype; LSTM c in
        fp32 on the Pallas backends, the activation dtype on reference), so
        a mixed fresh/resumed batch is bit-identical to serving each session
        alone.  The per-layer pytree follows the cell: ``(h, c)`` for LSTM,
        ``(h,)`` for GRU — whatever ``run_stack`` returned is what a session
        stored, part by part.  In fixed-shape mode zeros are always
        materialized: an all-fresh first tick must present the same jit
        pytree as every later tick, or the one-graph guarantee would break
        on tick two.
        """
        if all(sess.fresh for sess in sessions) and not self._fixed:
            return None
        if self.precision is not None:
            # Serving precision fixes the carry dtypes on every backend:
            # h in the activation dtype, LSTM c in fp32 (run_stack's 32-bit
            # cell-state policy).  prewarm passes the host chunk dtype, so
            # the mapping lives here, not in step().
            dtype = _quant.activation_dtype(self.precision, dtype)
            c_dtype = jnp.float32
        else:
            c_dtype = dtype if self.backend == "reference" else jnp.float32
        part_dtypes = (dtype,) if self.cell == "gru" else (dtype, c_dtype)
        hiddens = (self._encoder_hiddens())
        layers = []
        for li, hid in enumerate(hiddens):
            parts = [[] for _ in part_dtypes]
            for sess in sessions:
                if sess.fresh:
                    # Zeros sized by the session's *own* chain count — the
                    # batch layout packs per-session S, not the ceiling.
                    for acc, dt in zip(parts, part_dtypes):
                        acc.append(jnp.zeros(
                            (int(sess.rows.shape[0]), hid), dt))
                else:
                    for acc, part in zip(parts, sess.state[li]):
                        acc.append(part)
            if n_pad:
                for acc, dt in zip(parts, part_dtypes):
                    acc.append(jnp.zeros((n_pad, hid), dt))
            layers.append(tuple(jnp.concatenate(acc) for acc in parts))
        return layers

    def _encoder_hiddens(self):
        if self.kind == "classifier":
            return (self.cfg.hidden,) * self.cfg.num_layers
        return self.cfg.encoder_hiddens

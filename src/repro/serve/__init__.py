"""serve substrate: static-batch LM engine + streaming session serving.

Data plane: ``sessions`` (carried state + mask coordinates) and ``stream``
(the batched tick loop).  Control plane: ``admission`` (async queue with
bounded backpressure), ``persistence`` (crash-safe snapshots over
``repro.ckpt``) and ``scheduler`` (adaptive launch shapes + tick metrics).
"""

from repro.serve.admission import (AdmissionQueue, DrainRejected, QueueFull,
                                   Ticket)
from repro.serve.persistence import (load_snapshot_meta, restore_store,
                                     snapshot_store)
from repro.serve.scheduler import (AdaptiveTickScheduler, TickMetrics,
                                   pow2_ladder, prewarm, summarize)
from repro.serve.sessions import CapacityError, Session, SessionStore
from repro.serve.stream import (ChunkResult, JsonlSink, MetricsSink,
                                RingBufferSink, StreamingEngine)

__all__ = ["AdmissionQueue", "AdaptiveTickScheduler", "CapacityError",
           "ChunkResult", "DrainRejected", "JsonlSink", "MetricsSink",
           "QueueFull", "RingBufferSink", "Session", "SessionStore",
           "StreamingEngine", "Ticket", "TickMetrics",
           "load_snapshot_meta", "pow2_ladder", "prewarm", "restore_store",
           "snapshot_store", "summarize"]

"""serve substrate."""

"""serve substrate: static-batch LM engine + streaming session serving."""

from repro.serve.sessions import CapacityError, Session, SessionStore
from repro.serve.stream import ChunkResult, StreamingEngine

__all__ = ["CapacityError", "ChunkResult", "Session", "SessionStore",
           "StreamingEngine"]

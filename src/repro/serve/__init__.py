"""serve substrate: static-batch LM engine + streaming session serving.

Data plane: ``sessions`` (carried state + mask coordinates) and ``stream``
(the batched tick loop).  Control plane: ``admission`` (async queue with
bounded backpressure), ``persistence`` (crash-safe snapshots over
``repro.ckpt``), ``scheduler`` (adaptive launch shapes + tick metrics) and
``controller`` (online co-design: calibrated DSE over the live knobs,
applied via prewarmed config swaps under an SLO).  Service plane:
``fleet`` — heterogeneous tenants batched into per-config launch groups
per tick, with weighted-fair shared admission, per-tenant metrics and one
atomic fleet snapshot.
"""

from repro.serve.admission import (AdmissionQueue, DrainRejected,
                                   FleetTicket, QueueFull, Ticket,
                                   WeightedFairQueue)
from repro.serve.controller import (CoDesignController, DecisionRecord,
                                    FleetController, KnobSpace,
                                    ServingConfig, SimulatedLoadSink,
                                    SLOPolicy)
from repro.serve.fleet import FleetEngine, TenantSpec
from repro.serve.persistence import (load_fleet_meta, load_snapshot_meta,
                                     restore_fleet, restore_store,
                                     snapshot_fleet, snapshot_store)
from repro.serve.scheduler import (AdaptiveTickScheduler, TickMetrics,
                                   pow2_ladder, prewarm, summarize)
from repro.serve.sessions import CapacityError, Session, SessionStore
from repro.serve.stream import (ChunkResult, JsonlSink, MetricsSink,
                                RingBufferSink, StreamingEngine)

__all__ = ["AdmissionQueue", "AdaptiveTickScheduler", "CapacityError",
           "ChunkResult", "CoDesignController", "DecisionRecord",
           "DrainRejected", "FleetController", "FleetEngine", "FleetTicket",
           "JsonlSink", "KnobSpace", "MetricsSink", "QueueFull",
           "RingBufferSink", "SLOPolicy", "Session", "SessionStore",
           "ServingConfig", "SimulatedLoadSink", "StreamingEngine",
           "TenantSpec", "Ticket", "TickMetrics", "WeightedFairQueue",
           "load_fleet_meta", "load_snapshot_meta", "pow2_ladder", "prewarm",
           "restore_fleet", "restore_store", "snapshot_fleet",
           "snapshot_store", "summarize"]

"""Adaptive tick scheduling: pick the launch shape from the observed load.

The streaming engine has two shape policies from PR 2: dynamic (pad each
tick to its own max chunk length — minimal FLOPs, but every new
``(T, batch)`` pair retraces and recompiles) and fixed (hand-set
``chunk_capacity`` — one compiled graph forever, but the operator has to
guess the right capacity up front and eats the pad waste of a bad guess).

This scheduler closes the loop: it watches the ragged chunk-length
distribution and, per tick, picks a capacity from a small **ladder** of
pre-warmable fixed shapes.  Compilation stays bounded by the ladder length
(each rung is one graph, exactly like PR 2's fixed-shape mode), while the
rung tracks the observed load — a quiet night of short chunks slides down
to a small rung, a burst of long chunks climbs, and the mask/carry numerics
never notice because the lengths-pinned graph family is bit-identical
across launch shapes (docs/kernels.md).

Per tick it also emits :class:`TickMetrics` — rows occupied, queue depth,
pad waste, tokens/sec — the control-plane observables the ROADMAP's
"serve heavy traffic" north star needs before any autoscaling can exist.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Iterable, Sequence


def pow2_ladder(max_capacity: int, *, first: int = 8) -> tuple[int, ...]:
    """Power-of-two rungs up to a top rung of exactly ``max_capacity``.

    Every rung honors ``max_capacity`` — the ladder is the operator's stated
    launch-shape budget, and the scheduler rejects chunks above its top rung,
    so a rung above the cap would silently accept chunks longer than the
    operator allowed (that was a real bug: ``pow2_ladder(4)`` used to return
    ``(8,)``, and ``pow2_ladder(100)`` topped out at 128).
    """
    if max_capacity < 1:
        raise ValueError(f"max_capacity must be >= 1, got {max_capacity}")
    rungs, c = [], min(max(1, first), max_capacity)
    while c < max_capacity:
        rungs.append(c)
        c *= 2
    rungs.append(max_capacity)
    return tuple(rungs)


@dataclasses.dataclass
class TickMetrics:
    """Per-tick control-plane observables (host-side, no device sync)."""

    tick: int
    capacity: int          # launch T this tick (ladder rung / fixed / max len)
    n_chunks: int          # sessions served this tick
    live_rows: int         # session-chain rows carrying real data
    batch_rows: int        # launch rows incl. idle-slot padding
    queue_depth: int       # admissions still waiting after the drain
    live_steps: int        # sum of chunk lengths (signal timesteps served)
    live_chain_steps: int  # live_steps x S MC chains (chain-timesteps)
    padded_steps: int      # batch_rows * capacity (chain-timesteps launched)
    pad_waste: float       # 1 - live_chain_steps/padded_steps
    duration_s: float      # wall-clock of the engine tick (dispatch incl.)
    tokens_per_sec: float  # live chain-timesteps / duration (proxy off-TPU)
    shards: int = 1        # data-parallel width the tick launched across
    queue_wait_s: float = 0.0  # oldest-pending admission age at the drain
    compiles: int = 0      # new stack-graph jit entries this tick (a slow
                           # tick with compiles > 0 is a compile stall, not
                           # overload — the co-design controller and any
                           # operator reading the JSONL trail need the split)
    dropped: int = 0       # admissions the store refused this tick (tickets
                           # drained out of the queue that could never go
                           # live — previously visible only in the engine's
                           # in-memory dropped_admissions deque)
    active_chains: int = 0     # live MC chains across the whole store at
                               # tick end (post-retire) — with early-exit
                               # sampling this drifts below sessions x S,
                               # and it is what expected-chain cost pricing
                               # (dse.calibrate) reads
    reclaimed_rows: int = 0    # chain rows retired by early exit this tick
                               # (freed batch capacity; row ids stay burned)
    student_rows: int = 0      # rows served on the distilled fast path this
                               # tick (one per student session — the rest of
                               # the batch is MC chains)
    escalations: int = 0       # student sessions that crossed the
                               # uncertainty threshold this tick and regrew
                               # to S fresh MC chains (store.grow)
    tenant: str | None = None  # owning tenant when the record came from a
                               # FleetEngine tick (None: single-tenant
                               # engine); summarize() groups on it


class AdaptiveTickScheduler:
    """Pick ``chunk_capacity`` online from the ragged-chunk distribution.

    Args:
      ladder: ascending candidate capacities; each rung is one compiled
        graph, so ``len(ladder)`` bounds total recompiles for life.
      window: how many recent chunk lengths inform the choice.
      percentile: the rung must cover this percentile of the window (100 =
        the windowed max).  Lower values shrink pad waste for long-tailed
        loads at the cost of climbing a rung when an outlier does arrive.
        The current tick's own max is always covered regardless.
    """

    def __init__(self, ladder: Sequence[int] | None = None, *,
                 max_capacity: int = 512, window: int = 64,
                 percentile: float = 100.0):
        self.ladder = tuple(sorted(ladder)) if ladder \
            else pow2_ladder(max_capacity)
        if not self.ladder or any(c < 1 for c in self.ladder):
            raise ValueError(f"bad capacity ladder {self.ladder}")
        if not 0.0 < percentile <= 100.0:
            raise ValueError(f"percentile must be in (0, 100], "
                             f"got {percentile}")
        self.percentile = float(percentile)
        self._window: deque[int] = deque(maxlen=int(window))

    @property
    def max_capacity(self) -> int:
        return self.ladder[-1]

    def plan(self, lens: Iterable[int]) -> int:
        """Record this tick's chunk lengths; return the capacity to launch.

        Chunks longer than the top rung are rejected exactly like PR 2's
        fixed-shape mode rejects over-capacity chunks — the ladder is the
        pre-warmed shape budget, not a suggestion.
        """
        lens = [int(n) for n in lens]
        if not lens:
            return self.ladder[0]
        need = max(lens)
        if need > self.ladder[-1]:
            raise ValueError(
                f"chunk of {need} steps exceeds the capacity ladder "
                f"(top rung {self.ladder[-1]}); split the chunk or extend "
                "the ladder")
        self._window.extend(lens)
        target = max(need, self._percentile_target())
        for rung in self.ladder:
            if rung >= target:
                return rung
        return self.ladder[-1]

    def _percentile_target(self) -> int:
        win = sorted(self._window)
        if not win:
            return self.ladder[0]
        k = max(0, min(len(win) - 1,
                       int(round(self.percentile / 100.0 * len(win))) - 1))
        return win[k]

    # -- persistence hooks (repro.serve.persistence) -------------------------
    def state(self) -> dict:
        """JSON-able state: the observation window."""
        return {"window": list(self._window)}

    def load_state(self, state: dict) -> None:
        self._window.extend(int(n) for n in state.get("window", ()))


def prewarm(engine, *, dtype=None) -> list[int]:
    """Compile every capacity rung at boot instead of on first use.

    PR 3's adaptive ladder bounds total recompiles by the ladder length,
    but each rung still compiled lazily on the first tick that needed it —
    a latency spike landing on whichever patient stream happened to trigger
    the climb.  This walks the engine's ladder (or its single fixed
    capacity) and drives the *exact* serving graph for each rung — same
    batch layout (``max_sessions`` slots padded to the shard multiple, S
    chains each), same dtypes, same materialized state pytree — so the
    first real tick of any shape hits a warm jit cache.  Dynamic-shape
    engines (``chunk_capacity=None``) have no finite shape family to warm
    and are rejected.

    Args:
      engine: a ``StreamingEngine`` with ``chunk_capacity`` an int or
        ``"auto"``.
      dtype: chunk dtype traffic will arrive in (default float32 — what
        the launchers feed; a mismatched dtype would compile a second
        graph family on the first real tick).

    Returns the list of capacities compiled, ascending.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    if engine._scheduler is not None:
        caps = list(engine._scheduler.ladder)
    elif isinstance(engine.chunk_capacity, int):
        caps = [engine.chunk_capacity]
    else:
        raise ValueError(
            "prewarm needs a bounded shape family: chunk_capacity must be "
            "an int or 'auto' (dynamic mode compiles per observed shape)")
    dtype = np.dtype(np.float32 if dtype is None else dtype)
    s = engine.n_samples
    nb = engine._slot_count(0) * s      # the fixed-mode tick batch layout
    in_dim = engine.cfg.input_dim
    for cap in caps:
        x = jnp.zeros((nb, cap, in_dim), dtype)
        rows = jnp.zeros((nb,), jnp.uint32)
        lengths = jnp.ones((nb,), jnp.int32)
        state = engine._gather_states([], dtype, n_pad=nb)
        outs, states = engine._apply(x, rows, lengths, state)
        jax.block_until_ready((outs, states))
    return caps


def percentile(values: Sequence[float], p: float) -> float:
    """Nearest-rank percentile (p in (0, 100]); 0.0 on an empty sequence.

    The SLO arithmetic used by ``summarize`` and the co-design controller —
    one definition so "p95 tick latency" means the same thing in the
    decision trail, the benchmark and the tests.
    """
    vals = sorted(values)
    if not vals:
        return 0.0
    k = max(0, min(len(vals) - 1, math.ceil(p / 100.0 * len(vals)) - 1))
    return vals[k]


def summarize(metrics: Sequence[TickMetrics]) -> dict:
    """Aggregate control-plane observables over recorded ticks.

    The engine's ``metrics`` list is the single source of truth (the
    scheduler holds no copy); feed it here for the roll-up an operator or
    autoscaler wants: pad waste, distinct launch shapes (compiled-graph
    count), queue depth, chain-timesteps/sec.  Latency and throughput come
    as p50/p95 too, not just means — an SLO is a tail guarantee, and the
    mean hides exactly the slow ticks the controller must react to.

    Fleet trails carry tenant-tagged records (``TickMetrics.tenant``).
    When any are present the roll-up gains a ``"tenants"`` key: per-tenant
    sub-summaries over that tenant's own records, so each tenant's SLO
    (queue_wait_s_p95, duration_s_p95, dropped) is read off its own slice
    rather than the fleet mix.
    """
    if not metrics:
        return {"ticks": 0}
    live = sum(m.live_chain_steps for m in metrics)
    padded = sum(m.padded_steps for m in metrics)
    dur = sum(m.duration_s for m in metrics)
    durs = [m.duration_s for m in metrics]
    tps = [m.tokens_per_sec for m in metrics]
    out = {
        "ticks": len(metrics),
        "capacities_used": sorted({m.capacity for m in metrics}),
        "live_chain_steps": live,
        "padded_steps": padded,
        "pad_waste": 1.0 - live / padded if padded else 0.0,
        "mean_queue_depth": (sum(m.queue_depth for m in metrics)
                             / len(metrics)),
        "tokens_per_sec": live / dur if dur > 0 else 0.0,
        "duration_s_p50": percentile(durs, 50),
        "duration_s_p95": percentile(durs, 95),
        "tokens_per_sec_p50": percentile(tps, 50),
        "tokens_per_sec_p95": percentile(tps, 95),
        "queue_wait_s_p95": percentile([m.queue_wait_s for m in metrics], 95),
        "compiles": sum(m.compiles for m in metrics),
        "dropped": sum(m.dropped for m in metrics),
        # Early-exit observables: how many chains the store still runs
        # (mean over the window — a gauge, not a counter) and how many
        # rows convergence retired in total.  active_chains_mean equal to
        # live sessions x S means early exit never fired (or is off).
        "active_chains_mean": (sum(m.active_chains for m in metrics)
                               / len(metrics)),
        "reclaimed_rows": sum(m.reclaimed_rows for m in metrics),
        # Distill observables: rows on the single-chain fast path (gauge —
        # mean over the window) and total MC escalations (counter).
        "student_rows_mean": (sum(m.student_rows for m in metrics)
                              / len(metrics)),
        "escalations": sum(m.escalations for m in metrics),
    }
    tenants = sorted({m.tenant for m in metrics if m.tenant is not None})
    if tenants:
        # Sub-summaries see tenant-stripped copies — a tagged record must
        # not spawn a second "tenants" level inside its own slice.
        out["tenants"] = {
            name: summarize([dataclasses.replace(m, tenant=None)
                             for m in metrics if m.tenant == name])
            for name in tenants}
    return out

"""Multi-tenant fleet engine: heterogeneous Bayesian RNN workloads, one tick.

A real monitoring fleet is not one model: an ICU ward mixes LSTM ECG
classifiers, GRU anomaly autoencoders, cheap low-priority int8 tenants —
different cells, widths, MC sample counts and precisions, each under its own
SLO.  The :class:`~repro.serve.stream.StreamingEngine` serves exactly one
``(cell, task, H, S, precision)`` config per instance; this module is the
layer above, where the serving stack becomes a *service*:

* **Tenants** (:class:`TenantSpec`) declare a model config + params, a
  priority weight and capacity.  Tenants whose sessions would compile the
  same graph family — same params object and same ``(config, backend,
  precision, chunk policy)`` — fold into one **launch group**: a single
  shared ``StreamingEngine`` whose tick batches every submitting session of
  every member tenant into one ``pallas_seq`` launch per layer (the paper's
  sample-wise pipelining, generalized session-wise in PR 2, now
  tenant-wise).  Heterogeneous tenants get their own groups; a fleet tick
  is one engine tick per active group.
* **Weighted-fair admission**: all tenants share one bounded
  :class:`~repro.serve.admission.WeightedFairQueue`.  Under overload the
  admitted-capacity shares converge to the tenant weights, order within a
  tenant is FIFO, and an aging guard keeps any starved low-weight tenant
  admitting eventually.
* **Per-tenant observability**: every fleet tick emits one tenant-tagged
  :class:`~repro.serve.scheduler.TickMetrics` per involved tenant
  (``tenant=`` field) into the fleet's sink; ``scheduler.summarize`` groups
  them, so each tenant's p95/queue-wait/drop counts read off its own slice.
* **One atomic snapshot**: :meth:`FleetEngine.snapshot` commits every
  group's sessions, the shared queue and the fairness ledger under a single
  sha256 manifest (``repro.serve.persistence.snapshot_fleet``); kill →
  :meth:`restore` resumes every tenant bit-identically.

Bit-exactness carries over wholesale: the per-group engines are unmodified
``StreamingEngine`` instances, and batch composition / launch shape / chunk
split invariance (PR 2/PR 6) is exactly why a tenant served inside a shared
fleet tick is bit-identical to the same tenant alone in its own
single-tenant engine from the same carried state — the heterogeneity pin in
``tests/test_fleet.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import numpy as np

from repro.core import autoencoder as _ae, classifier as _clf
from repro.serve import persistence as _persist
from repro.serve.admission import (DrainRejected, FleetTicket,
                                   WeightedFairQueue)
from repro.serve.scheduler import TickMetrics
from repro.serve.sessions import CapacityError, Session
from repro.serve.stream import (ChunkResult, MetricsSink, RingBufferSink,
                                StreamingEngine)


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant of the fleet: a model, its capacity and its priority.

    ``cfg`` fixes the architecture, cell and MC-dropout block (S rides in
    ``cfg.mcd.n_samples``; ``n_samples`` here overrides it without the
    caller rebuilding the config).  ``weight`` is the tenant's share of
    admitted capacity under overload — twice the weight, twice the admitted
    sessions per unit time once every tenant is backlogged.  ``slo`` is
    opaque to the engine (the fleet controller reads it); ``max_sessions``
    is the tenant's own live-session cap, enforced even inside a shared
    launch group.
    """

    name: str
    cfg: Any                       # ClassifierConfig | AutoencoderConfig
    params: Any
    weight: float = 1.0
    n_samples: int | None = None   # override cfg.mcd.n_samples — the
                                   # tenant's chain *ceiling*: sessions
                                   # admit at it and early exit retires
                                   # below it, never above
    precision: str | None = None
    backend: str = "pallas_seq"
    max_sessions: int = 64
    chunk_capacity: int | str | None = None
    slo: Any = None                # SLOPolicy, read by FleetController
    early_exit_threshold: float | None = None  # staged early-exit sampling
                                   # (StreamingEngine docstring); part of
                                   # the launch-group signature — tenants
                                   # sharing an engine share the policy
    min_samples: int = 1           # early-exit floor for this tenant
    student: Any = None            # distilled student heads enabling
                                   # mode="student" admissions for this
                                   # tenant (repro.core.distill); identity
                                   # is part of the launch-group signature
                                   # like params
    student_escalate_threshold: float | None = None  # MC fallback trigger
                                   # (StreamingEngine docstring); group
                                   # signature too — co-batched tenants
                                   # share the escalation policy

    def __post_init__(self):
        if "/" in self.name:
            raise ValueError(f"tenant name {self.name!r} may not contain "
                             "'/' (reserved for fleet sid namespacing)")
        if not self.weight > 0:
            raise ValueError(f"tenant {self.name!r} weight must be > 0, "
                             f"got {self.weight}")
        if not isinstance(self.cfg, (_clf.ClassifierConfig,
                                     _ae.AutoencoderConfig)):
            raise TypeError(f"tenant {self.name!r}: unsupported config "
                            f"type {type(self.cfg).__name__}")

    def resolved_cfg(self):
        """The model config with the S override folded in."""
        if (self.n_samples is None
                or self.n_samples == self.cfg.mcd.n_samples):
            return self.cfg
        return dataclasses.replace(
            self.cfg, mcd=self.cfg.mcd.replace(n_samples=self.n_samples))


@dataclasses.dataclass
class _Group:
    """One launch group: a shared engine + the tenants folded into it."""

    name: str
    engine: StreamingEngine
    tenants: list[str]


class FleetEngine:
    """Serve a set of heterogeneous tenants, one weighted-fair tick at a time.

    Args:
      tenants: the fleet's :class:`TenantSpec` table (names unique).
      max_pending: bound of the shared admission queue (fleet-wide).
      aging_rounds: drain rounds after which a starved head-of-line ticket
        bypasses the weighted-fair pick (see ``WeightedFairQueue``).
      metrics_sink: where tenant-tagged per-tick :class:`TickMetrics` go
        (fleet-level; each group engine keeps a small private ring for its
        own launch-shape bookkeeping).
      mesh, policy, interpret: forwarded to every group engine.

    Session ids are namespaced ``"tenant/sid"`` inside the launch groups so
    tenants sharing a group can never collide; the public API (``admit``,
    ``step``, ``close``) speaks (tenant, bare-sid) pairs throughout.
    """

    def __init__(self, tenants: Sequence[TenantSpec], *,
                 max_pending: int = 256, aging_rounds: int = 16,
                 admit_per_tick: int | None = None,
                 metrics_window: int = 4096,
                 metrics_sink: MetricsSink | None = None,
                 mesh=None, policy=None, interpret: bool | None = None):
        if not tenants:
            raise ValueError("a fleet needs at least one tenant")
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names in {names}")
        self.specs: dict[str, TenantSpec] = {t.name: t for t in tenants}
        self._mesh, self._policy, self._interpret = mesh, policy, interpret
        # Launch-group folding: tenants sharing the same weights *object*
        # and the same compiled signature (config incl. cell/H/NL/mcd,
        # backend, precision, chunk policy, early-exit policy) share one
        # engine — their sessions batch into the same per-layer launches.
        # S is *not* part of the signature (unsharded): per-session chain
        # counts made it session state, so a 4-chain tenant co-batches
        # with an 8-chain tenant under the group ceiling (max member S).
        # A meshed fleet keeps S in the signature — sharded launches place
        # whole sessions per shard assuming one S.  Different params can
        # never share a launch, so they never share a group.
        self.groups: dict[str, _Group] = {}
        self._tenant_group: dict[str, str] = {}
        self._group_seq = 0      # names must never recycle: a reconfigured
        #                          tenant's fresh group could otherwise be
        #                          named after — and then deleted with — the
        #                          emptied group it replaces
        by_sig: dict[tuple, list[TenantSpec]] = {}
        for spec in tenants:
            cfg = spec.resolved_cfg()
            cfg_key = cfg if mesh is not None else dataclasses.replace(
                cfg, mcd=cfg.mcd.replace(n_samples=1))
            sig = (id(spec.params), cfg_key, spec.backend,
                   spec.precision, spec.chunk_capacity,
                   spec.early_exit_threshold, spec.min_samples,
                   id(spec.student) if spec.student is not None else None,
                   spec.student_escalate_threshold)
            by_sig.setdefault(sig, []).append(spec)
        for members in by_sig.values():
            self._make_group([m.name for m in members])
        self.queue = WeightedFairQueue(
            {t.name: t.weight for t in tenants},
            max_pending=max_pending, aging_rounds=aging_rounds)
        # The shared admission budget the weights ration.  When set, the
        # fleet is rate-limited: admit() only queues, and each step() drains
        # at most this many admissions split weighted-fair across backlogged
        # tenants.  None: admissions drain eagerly on submit/close — each
        # tenant then fills its own free rows and fair shares only bind
        # inside a shared launch group's store.
        self.admit_per_tick = admit_per_tick
        self.metrics_sink: MetricsSink = (metrics_sink
                                          or RingBufferSink(metrics_window))
        self.tick = 0
        self.dropped_admissions: list = []
        self._dropped_unreported: dict[str, int] = {n: 0 for n in names}

    def _resolved_s(self, tenant: str) -> int:
        """The tenant's chain ceiling (spec S override folded in)."""
        cfg = self.specs[tenant].resolved_cfg()
        return max(1, cfg.mcd.n_samples if cfg.mcd.any_bayesian else 1)

    def _make_group(self, members: list[str],
                    engine: StreamingEngine | None = None) -> _Group:
        """Register a launch group for ``members`` (build its engine).

        The group engine's chain ceiling is the max member S — members
        with a smaller S admit their sessions below it (per-session chain
        counts), and the engine's launch shapes are sized by the ceiling.
        """
        gname = f"g{self._group_seq}"
        self._group_seq += 1
        if engine is None:
            lead = self.specs[members[0]]
            ceiling = max(self._resolved_s(m) for m in members)
            cfg = lead.resolved_cfg()
            if cfg.mcd.any_bayesian and cfg.mcd.n_samples != ceiling:
                cfg = dataclasses.replace(
                    cfg, mcd=cfg.mcd.replace(n_samples=ceiling))
            engine = StreamingEngine(
                lead.params, cfg, backend=lead.backend,
                max_sessions=sum(self.specs[m].max_sessions
                                 for m in members),
                chunk_capacity=lead.chunk_capacity,
                metrics_sink=RingBufferSink(64),
                mesh=self._mesh, policy=self._policy,
                precision=lead.precision,
                early_exit_threshold=lead.early_exit_threshold,
                min_samples=min(lead.min_samples, ceiling),
                student=lead.student,
                student_escalate_threshold=lead.student_escalate_threshold,
                interpret=self._interpret)
        group = _Group(name=gname, engine=engine, tenants=list(members))
        self.groups[gname] = group
        for m in members:
            self._tenant_group[m] = gname
        return group

    # -- addressing ----------------------------------------------------------
    def group_of(self, tenant: str) -> _Group:
        try:
            return self.groups[self._tenant_group[tenant]]
        except KeyError:
            raise KeyError(f"unknown tenant {tenant!r} (fleet serves "
                           f"{sorted(self.specs)})") from None

    @staticmethod
    def _gsid(tenant: str, sid: str) -> str:
        return f"{tenant}/{sid}"

    def _live_count(self, tenant: str) -> int:
        store = self.group_of(tenant).engine.store
        prefix = tenant + "/"
        return sum(1 for sid in store.active if sid.startswith(prefix))

    def _has_room(self, tenant: str) -> bool:
        """Per-tenant admission eligibility (the drain's ``has_room``)."""
        return (self._live_count(tenant)
                < self.specs[tenant].max_sessions)

    # -- session lifecycle ---------------------------------------------------
    def admit(self, tenant: str, sid: str, *, priority: int = 0,
              session: Session | None = None,
              mode: str | None = None) -> Session | None:
        """Queue a stream for a tenant (and, unless rate-limited, drain).

        Mirrors ``StreamingEngine.admit``: returns the live
        :class:`Session` if the stream went live in this drain, None if it
        is queued (``QueueFull`` beyond ``max_pending``).  With
        ``admit_per_tick`` set the fleet is rate-limited: submissions only
        queue here and the budgeted weighted-fair drain runs at the next
        tick boundary.  ``session`` makes it a re-attach (an evicted carry
        resumes the same draw; its sid is re-namespaced into the tenant's
        group).  ``mode="student"`` queues a distilled fast-path admission
        (the tenant's spec must carry ``student`` heads).
        """
        engine = self.group_of(tenant).engine
        gsid = self._gsid(tenant, sid)
        if gsid in engine.store:
            raise ValueError(f"session {sid!r} already admitted "
                             f"for tenant {tenant!r}")
        if mode == "student" or (session is not None
                                 and session.mode == "student"):
            engine._check_student(gsid)
        if session is not None:
            # Same eager checks as StreamingEngine.admit — fail the caller
            # now, not whichever tick happens to drain the ticket.
            if session.seed != engine.store.seed:
                raise ValueError(
                    f"session {sid!r} was drawn under seed "
                    f"{session.seed!r}, tenant {tenant!r} uses "
                    f"{engine.store.seed!r}")
            if int(session.rows.shape[0]) > self._resolved_s(tenant):
                raise ValueError(
                    f"session {sid!r} carries "
                    f"{int(session.rows.shape[0])} MC chains, tenant "
                    f"{tenant!r}'s ceiling is {self._resolved_s(tenant)}")
            if session.sid != gsid:
                session = dataclasses.replace(session, sid=gsid)
        self.queue.submit(tenant, gsid, priority=priority, session=session,
                          mode=mode)
        if self.admit_per_tick is not None:
            # Rate-limited mode: admissions happen only at tick boundaries,
            # where the budget is split weighted-fair — an immediate drain
            # here would let submit order bypass the rationing.
            return None
        try:
            self.queue.drain(self._admit_ticket, self._has_room)
        except DrainRejected as err:
            # The caller is synchronously present for *its own* ticket: a
            # reject of this submit must raise, not read as "queued".
            # Other tickets' poison is contained (recorded per tenant).
            mine = next((e for t, e in err.rejected if t.sid == gsid), None)
            others = [(t, e) for t, e in err.rejected if t.sid != gsid]
            self._record_drops(others)
            if mine is not None:
                raise mine from err
        store = engine.store
        return store.get(gsid) if gsid in store else None

    def close(self, tenant: str, sid: str) -> Session:
        """Evict a tenant's stream; the freed row feeds the shared queue.

        Returns the final :class:`Session` with its bare (un-namespaced)
        sid, ready to re-``admit`` later.
        """
        sess = self.group_of(tenant).engine.store.evict(
            self._gsid(tenant, sid))
        if self.admit_per_tick is None:
            self._drain()
        return dataclasses.replace(sess, sid=sid)

    def _admit_ticket(self, ticket: FleetTicket) -> Session:
        """Route one drained ticket into its tenant's launch group.

        Fresh sessions open at the *tenant's* ceiling, which may sit below
        the group engine's (the group ceiling is the max member S); student
        tickets open one deterministic row instead.
        """
        store = self.group_of(ticket.tenant).engine.store
        if ticket.session is not None:
            return store.attach(ticket.session)
        if ticket.mode == "student":
            return store.admit(ticket.sid, mode="student")
        return store.admit(ticket.sid,
                           n_samples=self._resolved_s(ticket.tenant))

    def _record_drops(self, rejected: list) -> None:
        self.dropped_admissions.extend(rejected)
        del self.dropped_admissions[:-1024]
        for ticket, _ in rejected:
            self._dropped_unreported[ticket.tenant] += 1

    def _drain(self) -> list[FleetTicket]:
        """One weighted-fair drain over every tenant's FIFO.

        Rejections are contained exactly like ``StreamingEngine._drain``:
        the poison ticket's drop is recorded (per-tenant, for the metrics
        trail) and serving continues.
        """
        try:
            return self.queue.drain(self._admit_ticket, self._has_room,
                                    self.admit_per_tick)
        except DrainRejected as err:
            self._record_drops(err.rejected)
            return err.admitted

    def sessions_of(self, tenant: str) -> list[Session]:
        """A tenant's live sessions (namespaced sids), admission order."""
        prefix = tenant + "/"
        return [s for s in self.group_of(tenant).engine.store.sessions()
                if s.sid.startswith(prefix)]

    @property
    def active_sessions(self) -> dict[str, list[str]]:
        """tenant → live bare sids."""
        out: dict[str, list[str]] = {}
        for name in self.specs:
            prefix = name + "/"
            out[name] = [s.sid[len(prefix):] for s in self.sessions_of(name)]
        return out

    @property
    def metrics(self) -> Sequence[TickMetrics]:
        return self.metrics_sink.window()

    def summarize(self) -> dict:
        from repro.serve.scheduler import summarize
        return summarize(list(self.metrics))

    # -- serving -------------------------------------------------------------
    def step(self, chunks: Mapping[str, Mapping[str, Any]]
             ) -> dict[str, dict[str, ChunkResult]]:
        """One fleet tick: drain the shared queue, launch every active group.

        ``chunks`` maps tenant → {bare sid → [t, input_dim] chunk}.  Every
        listed session must be live.  Each launch group with submissions
        runs one batched engine tick (sessions of all member tenants fold
        into the same per-layer launches); per-tenant tagged
        :class:`TickMetrics` land in the fleet sink — including a quiet
        record for tenants with queued-but-unserved work, so a starving
        tenant is visible in the trail it isn't serving in.  Returns
        tenant → {bare sid → :class:`ChunkResult`}.
        """
        self._drain()
        # Per-tenant queue wait measured after the drain — the head-of-line
        # age of the streams that still couldn't get a row.
        waits = {name: self.queue.oldest_wait_s(name) for name in self.specs}
        by_group: dict[str, dict[str, Any]] = {}
        tenant_lens: dict[str, dict[str, int]] = {}
        for tenant, tchunks in chunks.items():
            group = self.group_of(tenant)          # raises on unknown tenant
            if not tchunks:
                continue
            gmap = by_group.setdefault(group.name, {})
            lens = tenant_lens.setdefault(tenant, {})
            for sid, chunk in tchunks.items():
                x = np.asarray(chunk)
                gsid = self._gsid(tenant, sid)
                lens[gsid] = x.shape[0] if x.ndim else 1
                gmap[gsid] = chunk

        results: dict[str, dict[str, ChunkResult]] = {
            t: {} for t in chunks if chunks[t]}
        group_metrics: dict[str, TickMetrics] = {}
        for gname, gmap in by_group.items():
            engine = self.groups[gname].engine
            res = engine.step(gmap)
            gm = engine.last_metrics
            if gm is not None:
                group_metrics[gname] = gm
            for gsid, cr in res.items():
                tenant, sid = gsid.split("/", 1)
                results[tenant][sid] = dataclasses.replace(cr, sid=sid)

        # One tagged record per tenant that served, plus a quiet record for
        # tenants with pending or dropped work that got nothing this tick.
        # Chain accounting is per-session (the engine's _last_served_chains /
        # _last_reclaimed tick attribution): with early exit live, a
        # tenant's rows/chain-steps reflect its sessions' *own* chain
        # counts, not the group ceiling.
        for tenant, lens in tenant_lens.items():
            engine = self.group_of(tenant).engine
            gm = group_metrics.get(self._tenant_group[tenant])
            if gm is None:
                continue
            served = engine._last_served_chains
            chains = sum(served.get(gsid, 0) for gsid in lens)
            chain_steps = sum(L * served.get(gsid, 0)
                              for gsid, L in lens.items())
            reclaimed = sum(n for gsid, n in engine._last_reclaimed.items()
                            if gsid in lens)
            stu_rows = sum(n for gsid, n in
                           engine._last_student_rows.items() if gsid in lens)
            escal = sum(n for gsid, n in engine._last_escalated.items()
                        if gsid in lens)
            live = int(sum(lens.values()))
            self.metrics_sink.emit(dataclasses.replace(
                gm, tick=self.tick, tenant=tenant,
                n_chunks=len(lens), live_rows=chains,
                live_steps=live, live_chain_steps=chain_steps,
                tokens_per_sec=(chain_steps / gm.duration_s
                                if gm.duration_s > 0 else 0.0),
                queue_depth=self.queue.depth_of(tenant),
                queue_wait_s=waits[tenant],
                dropped=self._take_dropped(tenant),
                active_chains=self._active_chains(tenant),
                reclaimed_rows=reclaimed,
                student_rows=stu_rows, escalations=escal))
        for tenant in self.specs:
            if tenant in tenant_lens:
                continue
            dropped = self._take_dropped(tenant)
            if not (dropped or self.queue.depth_of(tenant)):
                continue
            self.metrics_sink.emit(TickMetrics(
                tick=self.tick, capacity=0, n_chunks=0, live_rows=0,
                batch_rows=0, queue_depth=self.queue.depth_of(tenant),
                live_steps=0, live_chain_steps=0, padded_steps=0,
                pad_waste=0.0, duration_s=0.0, tokens_per_sec=0.0,
                queue_wait_s=waits[tenant], dropped=dropped,
                active_chains=self._active_chains(tenant),
                tenant=tenant))
        self.tick += 1
        return results

    def _active_chains(self, tenant: str) -> int:
        """Live MC chains across one tenant's sessions (post-retire gauge)."""
        return sum(int(s.rows.shape[0]) for s in self.sessions_of(tenant))

    def _take_dropped(self, tenant: str) -> int:
        n, self._dropped_unreported[tenant] = \
            self._dropped_unreported[tenant], 0
        return n

    # -- reconfiguration (the fleet controller's apply path) -----------------
    def reconfigure_tenant(self, tenant: str, new) -> StreamingEngine:
        """Swap one tenant to a new serving config, sessions intact.

        ``new`` is a ``repro.serve.controller.ServingConfig`` (duck-typed:
        ``n_samples``/``precision``/``chunk_capacity`` attributes).  The
        tenant's sessions are converted (``convert_session`` — a downshift
        keeps the first S′ chains bit-exactly, an upshift appends fresh
        rows) and moved into a dedicated new launch group; other tenants
        sharing the old group are untouched.  Both stores' row allocators
        advance past every row the transfer drew, so no later admission in
        either group can repeat a Bayesian draw.
        """
        # Deferred: the controller layer imports repro.dse; the data plane
        # must not pay that import unless a reconfig actually happens.
        from repro.serve.controller import carry_dtypes, convert_session

        spec = self.specs[tenant]
        old_ceiling = self._resolved_s(tenant)
        old_group = self.group_of(tenant)
        old_engine = old_group.engine
        new_cap = getattr(new, "chunk_capacity", 0) or spec.chunk_capacity
        new_spec = dataclasses.replace(
            spec, n_samples=int(new.n_samples),
            precision=getattr(new, "precision", spec.precision),
            chunk_capacity=new_cap)
        self.specs[tenant] = new_spec

        moved = self.sessions_of(tenant)
        for sess in moved:
            old_engine.store.evict(sess.sid)
        old_group.tenants.remove(tenant)

        # Always a dedicated fresh group: an existing group's store
        # allocated rows independently, so folding a reconfigured tenant
        # into it could only collide.  The new store's cursor starts past
        # everything the old group ever drew (same seed space).
        new_ceiling = max(1, int(new.n_samples))
        engine = StreamingEngine(
            new_spec.params, new_spec.resolved_cfg(),
            backend=new_spec.backend, max_sessions=new_spec.max_sessions,
            chunk_capacity=new_spec.chunk_capacity,
            metrics_sink=RingBufferSink(64),
            mesh=self._mesh, policy=self._policy,
            precision=new_spec.precision,
            early_exit_threshold=new_spec.early_exit_threshold,
            min_samples=min(new_spec.min_samples, new_ceiling),
            interpret=self._interpret)
        cursor = old_engine.store.next_row
        part_dtypes = carry_dtypes(engine.cell, new_spec.precision,
                                   engine.backend)
        for sess in moved:
            extra = None
            s_i = int(np.asarray(sess.rows).shape[0])
            # A session at the old tenant ceiling follows the new ceiling;
            # one early exit already shrank keeps its earned smaller S
            # (capped) — the swap must not resurrect retired chains.
            target = (engine.n_samples if s_i == old_ceiling
                      else min(s_i, engine.n_samples))
            missing = target - s_i
            if missing > 0:
                extra = np.arange(cursor, cursor + missing, dtype=np.uint32)
                cursor += missing
            engine.store.attach(convert_session(
                sess, n_samples=target, part_dtypes=part_dtypes,
                extra_rows=extra))
        engine.store._next_row = max(engine.store.next_row, cursor)
        old_engine.store._next_row = max(old_engine.store.next_row, cursor)
        engine.tick = old_engine.tick
        group = self._make_group([tenant], engine=engine)
        if not old_group.tenants:
            del self.groups[old_group.name]
        return group.engine

    # -- durability ----------------------------------------------------------
    def snapshot(self, directory: str, *, step: int | None = None) -> str:
        """One atomic manifest covering every tenant: kill → restore bit-id.

        Per group: every live session's carry + the engine meta (tick,
        cell, precision, mcd — the same dict a standalone engine snapshot
        validates).  Fleet-wide: the tenant table (name → group, weight,
        S, precision), the shared queue's tickets (attached carries
        included) and the fairness ledger.  All of it commits in one
        ``os.replace``.
        """
        groups = {g.name: (g.engine.store, g.engine._engine_meta())
                  for g in self.groups.values()}
        tenants = {
            name: {"group": self._tenant_group[name],
                   "weight": self.specs[name].weight,
                   # The tenant's own ceiling (may sit below its group
                   # engine's — the group ceiling is the max member S).
                   "n_samples": self._resolved_s(name),
                   "precision": self.specs[name].precision,
                   "backend": self.specs[name].backend}
            for name in self.specs}
        return _persist.snapshot_fleet(
            directory, groups=groups, tenants=tenants,
            queue=self.queue.waiting(), fair=self.queue.state(),
            tick=self.tick, step=step)

    def restore(self, directory: str, *, step: int | None = None) -> dict:
        """Resume a whole fleet from one manifest (fresh fleet only).

        Accepts two layouts: a fleet snapshot (every tenant, the shared
        queue and the fairness ledger restore together), or — for a
        single-tenant fleet — a plain pre-fleet ``StreamingEngine``
        snapshot, whose sessions are adopted under the tenant's namespace
        (the typed mismatch errors of ``StreamingEngine.restore`` apply
        unchanged).  Returns the fleet meta dict.
        """
        for g in self.groups.values():
            if g.engine.store.sessions() or len(self.queue):
                raise RuntimeError("restore() needs a fresh fleet: live or "
                                   "queued sessions would collide")
        peek = _persist.load_any_snapshot_meta(directory, step)
        if "sessions" in peek:          # legacy single-engine layout
            return self._restore_single(directory, step=peek["step"])
        meta, stores = _persist.restore_fleet(directory, step=peek["step"])
        snap_tenants = meta["tenants"]
        if set(snap_tenants) != set(self.specs):
            raise ValueError(
                f"fleet snapshot serves tenants "
                f"{sorted(snap_tenants)}, this fleet serves "
                f"{sorted(self.specs)}")
        # Tenant → group assignment must agree structurally: the snapshot's
        # grouping was derived from the same folding rule, so mismatched
        # membership means mismatched specs.
        for name, t_meta in snap_tenants.items():
            mine = sorted(self.group_of(name).tenants)
            theirs = sorted(n for n, m in snap_tenants.items()
                            if m["group"] == t_meta["group"])
            if mine != theirs:
                raise ValueError(
                    f"tenant {name!r} shares a launch group with {theirs} "
                    f"in the snapshot but {mine} in this fleet — the specs "
                    "diverge")
        # Validate + adopt per snapshot group, through the standalone
        # engine's own typed checks (n_samples, seed, cell, precision, mcd).
        for gname_s, (store, g_meta) in stores.items():
            members = [n for n, m in snap_tenants.items()
                       if m["group"] == gname_s]
            group = self.group_of(members[0])
            engine_meta = group.engine._check_restore_meta(g_meta)
            store.max_sessions = group.engine.max_sessions
            group.engine._adopt(store, group.engine.queue, engine_meta)
        self.queue.load_state(meta.get("fair") or {})
        for entry in meta["queue"]:
            self.queue.submit(entry["tenant"], entry["sid"],
                              priority=entry["priority"],
                              session=entry.get("session_obj"),
                              mode=entry.get("mode"))
        self.tick = int(meta.get("tick", 0))
        return meta

    def _restore_single(self, directory: str, *, step: int) -> dict:
        """Adopt a pre-fleet single-engine snapshot as a one-tenant fleet."""
        if len(self.specs) != 1:
            raise ValueError(
                f"snapshot is a single-engine layout; this fleet serves "
                f"{len(self.specs)} tenants ({sorted(self.specs)}) — only "
                "a one-tenant fleet can adopt it")
        (tenant,) = self.specs
        engine = self.group_of(tenant).engine
        extra = engine.restore(directory, step=step)
        # Namespace the adopted sessions and wait-list under the tenant.
        prefix = tenant + "/"
        for sess in list(engine.store.sessions()):
            if sess.sid.startswith(prefix):
                continue
            engine.store.evict(sess.sid)
            engine.store.attach(dataclasses.replace(
                sess, sid=self._gsid(tenant, sess.sid)))
        for ticket in engine.queue.waiting():
            engine.queue.cancel(ticket.sid)
            sess = ticket.session
            if sess is not None and not sess.sid.startswith(prefix):
                sess = dataclasses.replace(
                    sess, sid=self._gsid(tenant, sess.sid))
            self.queue.submit(tenant, self._gsid(tenant, ticket.sid),
                              priority=ticket.priority, session=sess,
                              mode=ticket.mode)
        self.tick = engine.tick
        return {"tenants": {tenant: {"group": self._tenant_group[tenant]}},
                "tick": self.tick, "extra": extra}

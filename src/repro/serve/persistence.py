"""Durable session state: crash-safe snapshots of the streaming store.

The data plane (PR 2) keeps every live stream's state in process memory —
per-chain ``(h, c)`` carries plus ``(seed, rows)`` mask coordinates.  Kill
the process and every patient stream is gone, which the ROADMAP's streaming-
hardening item calls out as incompatible with continuous monitoring.

This module makes that state durable on top of :mod:`repro.ckpt.checkpoint`
— the same atomic, sha256-manifested format the trainer uses, so a crash
mid-snapshot can never leave a readable-but-corrupt latest:

* arrays (each session's ``rows`` and per-layer ``(h, c)`` carry) go into
  the checkpoint tree, keyed by sid;
* everything structural — the allocator cursor, per-session step/chunk
  cursors, queue order/priorities, scheduler window — rides as JSON ``meta``
  inside the same manifest (``ckpt.save(meta=...)``), so arrays and
  bookkeeping commit in one ``os.replace``.

Restore is *exact*, not approximate: the counter-PRNG tied-mask design means
masks are pure functions of ``(seed, rows)`` and are simply recomputed;
``c`` carries round-trip in fp32 (the Pallas accumulator dtype); nothing
stochastic lives outside the snapshot.  A killed process therefore resumes
every live stream **bit-identically** — the invariant
``tests/test_controlplane.py`` pins across all three backends, including
across a ``chunk_capacity`` change at resume (the lengths-pinned graph
family is shape-independent).

A queued re-attach (an evicted session waiting in the admission queue with
its carry) is state too — snapshots include it, so a crash can't silently
drop a waiting patient either.
"""

from __future__ import annotations

import re

import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.serve.admission import AdmissionQueue
from repro.serve.sessions import Session, SessionStore

FORMAT_VERSION = 1

_KEY_RE = re.compile(r"[^\w.-]+")


def _tree_key(sid: str, used: set[str]) -> str:
    """A collision-free checkpoint key for a sid.

    Sids are free-form ('ward 3' and 'ward_3' may coexist) but checkpoint
    leaf names are sanitized, so two sids could alias the same leaf and a
    *partial* restore could silently read the wrong patient's carry.  The
    key actually used is made unique here and recorded in the meta, so
    restores always address arrays by the recorded key, never by a
    re-derived (and possibly ambiguous) name.
    """
    base = _KEY_RE.sub("_", sid).strip("_") or "sid"
    key, n = base, 1
    while key in used:
        key = f"{base}__{n}"
        n += 1
    used.add(key)
    return key


def _session_tree(sess: Session) -> dict:
    entry = {"rows": np.asarray(sess.rows)}
    if sess.state is not None:
        # Cell-agnostic: each layer's carry is a tuple of parts — (h, c) for
        # LSTM sessions, (h,) for GRU — serialized part by part.
        entry["state"] = [[np.asarray(part) for part in layer]
                          for layer in sess.state]
    return entry


def _session_meta(sess: Session) -> dict:
    meta = {"steps": int(sess.steps), "chunks": int(sess.chunks),
            "layers": None if sess.state is None else len(sess.state)}
    if sess.state is not None:
        # Carry arity per layer ((h, c) → 2, (h,) → 1); absent in pre-GRU
        # snapshots, which were all 2-part LSTM carries.
        meta["parts"] = len(sess.state[0])
    if sess.mode != "mc":
        # Written only off the default, so pre-distill snapshots are
        # byte-identical to this format and restore as all-MC.
        meta["mode"] = sess.mode
    return meta


def _session_like(meta: dict) -> dict:
    like = {"rows": 0}
    if meta["layers"] is not None:
        parts = int(meta.get("parts", 2))
        like["state"] = [[0] * parts for _ in range(meta["layers"])]
    return like


def _rebuild_session(sid: str, meta: dict, arrays: dict, seed) -> Session:
    state = None
    if meta["layers"] is not None:
        state = [tuple(jnp.asarray(part) for part in layer)
                 for layer in arrays["state"]]
    return Session(sid=sid, rows=jnp.asarray(arrays["rows"]), seed=seed,
                   state=state, steps=int(meta["steps"]),
                   chunks=int(meta["chunks"]),
                   mode=meta.get("mode", "mc"))


def _store_tree_meta(store: SessionStore, used: set[str],
                     extra: dict | None = None) -> tuple[dict, dict]:
    """One store's checkpoint tree + structural meta (no queue, no save).

    The shared core of :func:`snapshot_store` and :func:`snapshot_fleet` —
    the fleet commits one of these per launch group under a single
    manifest, with ``extra`` carrying that group's engine meta.
    """
    tree: dict = {}
    meta: dict = {
        "format": FORMAT_VERSION,
        "n_samples": store.n_samples,
        "seed": store.seed,
        "max_sessions": store.max_sessions,
        "next_row": store.next_row,
        "sessions": {},
        "queue": [],
    }
    for sess in store.sessions():
        key = _tree_key(sess.sid, used)
        tree[key] = _session_tree(sess)
        meta["sessions"][sess.sid] = dict(_session_meta(sess), key=key)
    if extra is not None:
        meta["extra"] = extra
    return tree, meta


def snapshot_store(directory: str, store: SessionStore, *,
                   step: int | None = None, queue: AdmissionQueue | None = None,
                   extra: dict | None = None) -> str:
    """Atomically snapshot a store (and optionally its admission queue).

    ``step`` defaults to one past the latest snapshot in ``directory`` (a
    monotone history; prune with ``ckpt.keep_last``).  ``extra`` is caller
    JSON riding in the manifest (engines stash tick counters etc. there).
    Returns the snapshot path.
    """
    if step is None:
        latest = ckpt.latest_step(directory)
        step = 0 if latest is None else latest + 1
    used: set[str] = set()
    tree, meta = _store_tree_meta(store, used, extra)
    if queue is not None:
        for ticket in queue.waiting():
            entry = {"sid": ticket.sid, "priority": ticket.priority,
                     "attached": ticket.session is not None}
            if ticket.n_samples is not None:
                # A fresh ticket's requested chain count is admission state
                # too — dropping it on restore would silently admit the
                # stream at the ceiling.  (Absent in pre-dynamic-S
                # snapshots; restore_store's .get() defaults to None.)
                entry["n_samples"] = int(ticket.n_samples)
            if ticket.mode is not None:
                # Same contract as n_samples: a fresh student ticket must
                # still open as a student after the crash.
                entry["mode"] = ticket.mode
            if ticket.session is not None:
                # A queued re-attach carries live state — it must survive
                # the crash with the same fidelity as an admitted session.
                key = _tree_key(ticket.sid, used)
                tree[key] = _session_tree(ticket.session)
                entry["session"] = dict(_session_meta(ticket.session),
                                        key=key)
            meta["queue"].append(entry)
    return ckpt.save(directory, step, tree, meta=meta)


def load_snapshot_meta(directory: str, step: int | None = None) -> dict:
    """The snapshot's meta dict (resolving ``step=None`` to the latest)."""
    if step is None:
        step = ckpt.latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no snapshot under {directory!r}")
    meta = ckpt.load_meta(directory, step)
    if meta is None or "sessions" not in meta:
        raise IOError(f"{directory!r} step {step} is not a session snapshot")
    if meta.get("format") != FORMAT_VERSION:
        raise IOError(f"snapshot format {meta.get('format')!r}, "
                      f"expected {FORMAT_VERSION}")
    meta["step"] = step
    return meta


def restore_store(directory: str, *, step: int | None = None,
                  sids: list[str] | None = None,
                  queue: AdmissionQueue | None = None,
                  max_sessions: int | None = None,
                  ) -> tuple[SessionStore, dict]:
    """Rebuild a :class:`SessionStore` from a snapshot, bit-identically.

    ``sids`` restores only a subset of the saved sessions — live, queued
    re-attach and fresh wait-list entries alike (partial-tree read through
    ``ckpt.restore``; e.g. shedding low-priority streams on a smaller
    replacement host); the allocator cursor is restored either way, so
    unrestored sessions' rows are never re-drawn by later admissions.
    ``queue``: an :class:`AdmissionQueue` to refill with the snapshotted
    wait-list (priorities and FIFO order preserved; re-attach tickets get
    their sessions rebuilt).  Returns ``(store, meta)``.
    """
    meta = load_snapshot_meta(directory, step)
    step = meta["step"]
    queued_attached = {e["sid"]: e for e in meta["queue"] if e["attached"]}
    queued_fresh = {e["sid"] for e in meta["queue"] if not e["attached"]}
    known = set(meta["sessions"]) | set(queued_attached) | queued_fresh
    want = known if sids is None else set(sids)
    if want - known:
        raise KeyError(f"snapshot has no session(s) {sorted(want - known)}")
    if queue is None and (lost := want - set(meta["sessions"])):
        raise ValueError(
            f"session(s) {sorted(lost)} are wait-list entries; pass queue= "
            "(or a sids= selection excluding them) — a restore must never "
            "silently drop a waiting stream")
    # Arrays are addressed by the snapshot's recorded keys, never by a
    # re-derived sid sanitization — two sids that alias the same leaf name
    # can therefore never cross-contaminate a partial restore.  Fresh
    # wait-list entries carry no arrays; selecting them just re-queues.
    keys, like = {}, {}
    for sid in want - queued_fresh:
        smeta = (meta["sessions"].get(sid)
                 or queued_attached[sid]["session"])
        keys[sid] = smeta["key"]
        like[smeta["key"]] = _session_like(smeta)
    loaded = ckpt.restore(directory, step, like, partial=True) if like else {}
    arrays = {sid: loaded[key] for sid, key in keys.items()}

    # The cursor outlives the sessions (first_row): rows of unrestored (or
    # long-evicted) streams stay burned, so no post-restore admission can
    # ever repeat a pre-crash Bayesian draw.
    store = SessionStore(meta["n_samples"], meta["seed"],
                         max_sessions=max_sessions or meta["max_sessions"],
                         first_row=int(meta["next_row"]))
    for sid, smeta in meta["sessions"].items():
        if sid not in want:
            continue
        store.attach(_rebuild_session(sid, smeta, arrays[sid], meta["seed"]))
    if queue is not None:
        for entry in meta["queue"]:
            if entry["sid"] not in want:     # the sids filter selects the
                continue                     # wait-list too, both kinds
            sess = None
            if entry["attached"]:
                sess = _rebuild_session(entry["sid"], entry["session"],
                                        arrays[entry["sid"]], meta["seed"])
            queue.submit(entry["sid"], priority=entry["priority"],
                         session=sess, n_samples=entry.get("n_samples"),
                         mode=entry.get("mode"))
    return store, meta


# ---------------------------------------------------------------------------
# Fleet snapshots — every launch group under one atomic manifest
# ---------------------------------------------------------------------------

FLEET_FORMAT_VERSION = 1


def snapshot_fleet(directory: str, *, groups, tenants: dict, queue,
                   fair: dict, tick: int, step: int | None = None) -> str:
    """Atomically snapshot a whole fleet: N stores, one ``os.replace``.

    Args:
      groups: ``{group name: (SessionStore, engine meta dict)}`` — one per
        launch group; the engine meta is what
        ``StreamingEngine._engine_meta`` builds (validated per group on
        restore by ``_check_restore_meta``).
      tenants: JSON tenant table ``{name: {"group": ..., "weight": ...}}``.
      queue: the fleet's pending :class:`~repro.serve.admission.FleetTicket`
        list (``WeightedFairQueue.waiting()``); attached re-attach carries
        are serialized with session fidelity under their tenant's group.
      fair: the fairness ledger (``WeightedFairQueue.state()``) — restored
        so long-run admitted shares survive the crash instead of resetting.
      tick: the fleet tick counter.

    A crash mid-save can never leave a readable-but-partial fleet: arrays
    for every group and all bookkeeping commit in the one manifest.
    """
    if step is None:
        latest = ckpt.latest_step(directory)
        step = 0 if latest is None else latest + 1
    tree: dict = {}
    used_by_group: dict[str, set[str]] = {}
    meta: dict = {
        "fleet_format": FLEET_FORMAT_VERSION,
        "tick": int(tick),
        "tenants": dict(tenants),
        "fair": dict(fair),
        "groups": {},
        "queue": [],
    }
    for gname, (store, engine_meta) in groups.items():
        used = used_by_group.setdefault(gname, set())
        g_tree, g_meta = _store_tree_meta(store, used, engine_meta)
        tree[gname] = g_tree
        meta["groups"][gname] = g_meta
    for ticket in queue:
        tenant = ticket.tenant
        gname = tenants[tenant]["group"]
        entry = {"tenant": tenant, "sid": ticket.sid,
                 "priority": ticket.priority,
                 "attached": ticket.session is not None}
        if ticket.mode is not None:
            entry["mode"] = ticket.mode
        if ticket.session is not None:
            key = _tree_key(ticket.sid, used_by_group.setdefault(gname,
                                                                 set()))
            tree.setdefault(gname, {})[key] = _session_tree(ticket.session)
            entry["session"] = dict(_session_meta(ticket.session),
                                    key=key, group=gname)
        meta["queue"].append(entry)
    return ckpt.save(directory, step, tree, meta=meta)


def load_any_snapshot_meta(directory: str, step: int | None = None) -> dict:
    """Peek a snapshot's meta, fleet or single-engine layout alike.

    Returns the meta with ``"step"`` resolved; the caller branches on
    layout (``"sessions"`` key: single engine; ``"groups"``: fleet).
    """
    if step is None:
        step = ckpt.latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no snapshot under {directory!r}")
    meta = ckpt.load_meta(directory, step)
    if meta is None or not ("sessions" in meta or "groups" in meta):
        raise IOError(f"{directory!r} step {step} is not a session or "
                      "fleet snapshot")
    meta["step"] = step
    return meta


def load_fleet_meta(directory: str, step: int | None = None) -> dict:
    """The fleet snapshot's meta dict (typed errors on the wrong layout)."""
    meta = load_any_snapshot_meta(directory, step)
    if "groups" not in meta:
        raise IOError(
            f"{directory!r} step {meta['step']} is a single-engine session "
            "snapshot, not a fleet snapshot — restore it through a "
            "one-tenant FleetEngine (or a StreamingEngine)")
    if meta.get("fleet_format") != FLEET_FORMAT_VERSION:
        raise IOError(f"fleet snapshot format {meta.get('fleet_format')!r}, "
                      f"expected {FLEET_FORMAT_VERSION}")
    for gname, g_meta in meta["groups"].items():
        if g_meta.get("format") != FORMAT_VERSION:
            raise IOError(f"group {gname!r} snapshot format "
                          f"{g_meta.get('format')!r}, "
                          f"expected {FORMAT_VERSION}")
    return meta


def restore_fleet(directory: str, step: int | None = None,
                  ) -> tuple[dict, dict]:
    """Rebuild every launch group's store from one fleet manifest.

    Returns ``(meta, {group name: (SessionStore, group meta)})``; queued
    re-attach carries are rebuilt and attached to their ``meta["queue"]``
    entries as ``entry["session_obj"]`` (None for fresh wait-list entries),
    so the caller refills its fleet queue without touching arrays itself.
    Restores everything — partial (per-sid) restores stay a single-engine
    feature; shedding a tenant is a fleet-level reconfiguration, not a
    restore-time filter.
    """
    meta = load_fleet_meta(directory, step)
    step = meta["step"]
    like: dict = {}
    for gname, g_meta in meta["groups"].items():
        g_like = {smeta["key"]: _session_like(smeta)
                  for smeta in g_meta["sessions"].values()}
        if g_like:
            like[gname] = g_like
    for entry in meta["queue"]:
        if entry["attached"]:
            smeta = entry["session"]
            like.setdefault(smeta["group"], {})[smeta["key"]] = \
                _session_like(smeta)
    loaded = ckpt.restore(directory, step, like, partial=True) if like else {}
    stores: dict = {}
    for gname, g_meta in meta["groups"].items():
        store = SessionStore(g_meta["n_samples"], g_meta["seed"],
                             max_sessions=g_meta["max_sessions"],
                             first_row=int(g_meta["next_row"]))
        for sid, smeta in g_meta["sessions"].items():
            store.attach(_rebuild_session(
                sid, smeta, loaded[gname][smeta["key"]], g_meta["seed"]))
        stores[gname] = (store, g_meta)
    for entry in meta["queue"]:
        entry["session_obj"] = None
        if entry["attached"]:
            smeta = entry["session"]
            g_meta = meta["groups"][smeta["group"]]
            entry["session_obj"] = _rebuild_session(
                entry["sid"], smeta, loaded[smeta["group"]][smeta["key"]],
                g_meta["seed"])
    return meta, stores

"""Online co-design: close the paper's DSE→serving loop under an SLO.

The paper's central contribution (§IV, Fig. 7) is a framework that searches
algorithmic–hardware configurations for the best accuracy/latency/
uncertainty trade-off — offline, against a benchmarked lookup table.  The
serving stack meanwhile emits live :class:`~repro.serve.scheduler.
TickMetrics` through a :class:`~repro.serve.stream.MetricsSink` that, until
this module, nothing consumed.  :class:`CoDesignController` runs the same
framework *online*:

1. **observe** — roll up the sink's recent window (p95 tick latency,
   tokens/s, queue depth, queue wait, compile count);
2. **calibrate** — fit the :mod:`repro.dse.tpu_model` roofline to the
   observed durations (:mod:`repro.dse.calibrate`), so predicted candidate
   latency is in the same wall-clock world the SLO is written in;
3. **search** — build a candidate table over the live knobs (S MC chains,
   serving precision, chunk-capacity ladder, shard width) and drive
   :func:`repro.dse.search.optimize` with the calibrated
   ``latency_model=`` and the SLO as ``requirements=`` — exactly the
   paper's requirement-filtered DSE, pointed at live traffic;
4. **apply** — swap the winning config in at a tick boundary: a fresh
   engine is built, every live session's carry is converted and
   re-attached (same ``(seed, rows)`` mask coordinates, so the Bayesian
   draw continues), queued tickets follow, and the new engine is prewarmed
   (``scheduler.prewarm``) before it takes traffic — post-swap ticks
   compile nothing.

Every evaluation that proposes (or refuses) a change is recorded as a
typed :class:`DecisionRecord` — candidate table, winner, predicted vs
observed latency, calibration fit, reason — to its own sink (the
``MetricsSink`` protocol is duck-typed: ``RingBufferSink`` in memory,
``JsonlSink`` for a durable trail).  Hysteresis and a post-swap cooldown
keep an overload burst from thrashing reconfigurations: downshifts need a
breached window, upshifts need a comfortably-under-SLO window *and* a
calibrated prediction that the richer config stays under the SLO with
margin.

The safety contract, pinned by ``tests/test_controller.py``: a session's
streamed outputs across a reconfiguration boundary are bit-identical to an
uninterrupted run at the new config from the same carried state — the
PR 3/PR 6 snapshot contract extended across config swaps.
"""

from __future__ import annotations

import copy
import dataclasses
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import mcd as _mcd
from repro.dse import calibrate as _calib
from repro.dse import search as _search
from repro.dse.fpga_model import RNNArch
from repro.kernels import quantize as _quant
from repro.serve import scheduler as _sched
from repro.serve.scheduler import TickMetrics, percentile, pow2_ladder
from repro.serve.sessions import Session
from repro.serve.stream import RingBufferSink, StreamingEngine

#: Serving-quality rank of each precision (higher = richer numerics).  The
#: paper's Opt-* modes trade metric quality against latency; online we rank
#: a config's quality as S first (the uncertainty estimate the whole
#: Bayesian machinery exists for degrades directly with fewer MC chains),
#: precision second.  ``None`` (native dtypes) and ``"fp32"`` tie.
PRECISION_RANK = {None: 3, "fp32": 3, "bf16": 2, "int8": 1, "int4": 0}

#: Roofline weight width per serving precision (``None`` = native fp32).
_WEIGHT_BITS = {**_quant.WEIGHT_BITS, None: 32}


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """The service-level objective the controller defends.

    ``p95_tick_s`` is the headline bound: the 95th-percentile engine tick
    wall-clock over the observation window.  ``min_tokens_per_sec`` bounds
    delivered throughput (p50), ``max_queue_depth`` the admissions left
    waiting after a drain, and ``min_samples`` is the **uncertainty
    floor** — the controller never trades S below it, however hard the
    latency requirement binds (an uncertainty-free Bayesian monitor is a
    contradiction, not a config).
    """

    p95_tick_s: float
    min_tokens_per_sec: float = 0.0
    max_queue_depth: int | None = None
    min_samples: int = 1

    def __post_init__(self):
        if self.p95_tick_s <= 0:
            raise ValueError(f"p95_tick_s must be > 0, got {self.p95_tick_s}")
        if self.min_samples < 1:
            raise ValueError(
                f"min_samples must be >= 1, got {self.min_samples}")


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """The live-reconfigurable knobs — the online slice of the DSE space.

    ``chunk_capacity`` is the launch-shape budget (the top ladder rung; 0 =
    dynamic shapes, no budget).  H/NL/placement/cell stay offline: they
    change the parameter set itself, which is a deploy, not a reconfig.
    """

    n_samples: int
    precision: str | None = None
    chunk_capacity: int = 0
    shards: int = 1

    @property
    def quality(self) -> int:
        """Scalar serving quality: S dominates, precision breaks ties."""
        return self.n_samples * 8 + PRECISION_RANK[self.precision]


@dataclasses.dataclass(frozen=True)
class KnobSpace:
    """Candidate values per knob — the controller's search grid."""

    samples: tuple[int, ...]
    precisions: tuple[str | None, ...] = (None,)
    capacities: tuple[int, ...] = (0,)
    shards: tuple[int, ...] = (1,)

    @classmethod
    def around(cls, config: ServingConfig, *,
               precisions: Sequence[str | None] | None = None) -> KnobSpace:
        """The default grid: pow2 S downshifts from the current config.

        S candidates are ``S, S/2, …, 1``; precision/capacity/shards stay
        at the current value unless ``precisions`` widens that axis.  A
        deliberately conservative default — an operator opts into the
        sharper knives (precision downshift, capacity changes) explicitly.
        """
        s, ladder = config.n_samples, []
        while s >= 1:
            ladder.append(s)
            s //= 2
        return cls(samples=tuple(ladder),
                   precisions=(tuple(precisions) if precisions
                               else (config.precision,)),
                   capacities=(config.chunk_capacity,),
                   shards=(config.shards,))

    def configs(self) -> list[ServingConfig]:
        """Every grid point, best quality first (ties: larger capacity).

        The order is the tiebreak: ``search.optimize``'s sort is stable, so
        equal-score survivors keep table order.
        """
        out = []
        for s in sorted(set(self.samples), reverse=True):
            for prec in sorted(set(self.precisions),
                               key=lambda p: -PRECISION_RANK[p]):
                for cap in sorted(set(self.capacities), reverse=True):
                    for sh in self.shards:
                        out.append(ServingConfig(
                            n_samples=int(s), precision=prec,
                            chunk_capacity=int(cap), shards=int(sh)))
        return out


@dataclasses.dataclass(frozen=True)
class DecisionRecord:
    """One controller evaluation — the observable decision trail.

    JSON-able end to end (``dataclasses.asdict`` → one JSONL line via a
    ``JsonlSink``): what was observed, what the calibration believed, every
    candidate's predicted latency, the winner, and why.  ``applied`` is
    False for records that explain a *refusal* (compile stall, no feasible
    candidate, already optimal) — those are exactly the ones an operator
    paging through an incident needs.
    """

    tick: int
    reason: str            # slo-breach | headroom-upshift | compile-stall |
                           # no-feasible-fallback | already-optimal
    applied: bool
    current: dict          # ServingConfig, asdict
    winner: dict | None    # ServingConfig, asdict
    predicted_s: float | None   # winner's calibrated per-tick latency
    observed: dict         # the window roll-up the decision was made on
    slo: dict
    fit: dict | None       # RooflineFit, asdict
    candidates: list = dataclasses.field(default_factory=list)
    tenant: str | None = None   # owning tenant when a FleetController made
                                # the call (None: single-engine controller)


class SimulatedLoadSink(RingBufferSink):
    """A metrics sink that *rewrites* tick durations from a cost model.

    Real tick wall-clock is noisy and platform-bound — useless for
    deterministic tests, demos and CI of control logic.  This sink keeps
    every structural observable the engine measured (rows, capacity, queue
    depth, compiles) and replaces ``duration_s``/``tokens_per_sec`` with

        load(tick) · (overhead_s + per_chain_step_s · batch_rows · capacity)

    so latency responds to the knobs exactly as a busy accelerator would
    (more chains, longer launches, heavier load ⇒ slower ticks), and an
    injected ``load`` burst is reproducible to the tick.  The controller
    cannot tell the difference — it reads the sink window like any other.
    """

    def __init__(self, *, per_chain_step_s: float = 1e-5,
                 overhead_s: float = 5e-4,
                 load: Callable[[int], float] | None = None,
                 window: int = 4096):
        super().__init__(window)
        self.per_chain_step_s = float(per_chain_step_s)
        self.overhead_s = float(overhead_s)
        self.load = load or (lambda tick: 1.0)

    def emit(self, m) -> None:
        if isinstance(m, TickMetrics):
            dur = self.load(m.tick) * (
                self.overhead_s
                + self.per_chain_step_s * m.batch_rows * m.capacity)
            m = dataclasses.replace(
                m, duration_s=dur,
                tokens_per_sec=m.live_chain_steps / dur if dur > 0 else 0.0)
        super().emit(m)


def carry_dtypes(cell: str, precision: str | None, backend: str,
                 chunk_dtype=jnp.float32) -> tuple:
    """Per-part carry dtypes a target engine stores sessions in.

    Mirrors ``StreamingEngine._gather_states``: h in the activation dtype
    of the serving precision, LSTM c in fp32 (reference backend keeps c in
    the activation dtype).  Converting a transferred carry **to** these
    dtypes is what keeps the post-swap jit signature identical to the
    prewarmed graphs — and the conversion itself is the documented numeric
    boundary of a precision swap (an fp32→bf16 downshift rounds the carry
    once, exactly as if the stream had always been served at bf16 from
    that state onward).
    """
    h_dt = _quant.activation_dtype(precision, chunk_dtype)
    if precision is not None:
        c_dt = jnp.float32
    else:
        c_dt = chunk_dtype if backend == "reference" else jnp.float32
    return (h_dt,) if cell == "gru" else (h_dt, c_dt)


def convert_session(sess: Session, *, n_samples: int, part_dtypes: tuple,
                    extra_rows: np.ndarray | None = None) -> Session:
    """Re-shape one session's carry for a new (S, precision) config.

    Chains are independent trajectories (each batch row sees only its own
    mask row and the shared signal), so a downshift keeps the *first*
    ``n_samples`` chains bit-exactly — their continuation is identical to a
    session that had streamed at the smaller S with those rows all along.
    An upshift appends fresh chains (zero state, newly-allocated rows via
    ``extra_rows``): they join the draw mid-signal, warming up from the
    swap point.  Dtype casts follow ``part_dtypes`` (see
    :func:`carry_dtypes`).  Cursors and sid are preserved — the stream
    does not notice the swap.
    """
    rows = np.asarray(sess.rows)
    s_old = int(rows.shape[0])
    if n_samples <= s_old:
        new_rows = rows[:n_samples]
    else:
        if extra_rows is None or len(extra_rows) != n_samples - s_old:
            raise ValueError(
                f"upshift {s_old}→{n_samples} needs {n_samples - s_old} "
                "freshly-allocated extra_rows")
        new_rows = np.concatenate([rows, np.asarray(extra_rows, np.uint32)])
    state = None
    if sess.state is not None:
        state = []
        for layer in sess.state:
            parts = []
            for part, dt in zip(layer, part_dtypes):
                p = jnp.asarray(part)[:min(s_old, n_samples)].astype(dt)
                if n_samples > s_old:
                    pad = jnp.zeros((n_samples - s_old, p.shape[-1]), dt)
                    p = jnp.concatenate([p, pad])
                parts.append(p)
            state.append(tuple(parts))
    return Session(sid=sess.sid, rows=jnp.asarray(new_rows, jnp.uint32),
                   seed=sess.seed, state=state, steps=sess.steps,
                   chunks=sess.chunks)


class CoDesignController:
    """Drive the paper's co-design search online, against live metrics.

    Two modes share the decision logic:

    * **attached** (``engine=`` given): the controller owns the serving
      engine — call :meth:`maybe_reconfigure` after each tick; on a
      decision it swaps ``controller.engine`` for a prewarmed replacement
      with every session transferred.  Always read the engine through the
      controller after that.
    * **detached** (``engine=None``, ``config=``/``arch=`` given): pure
      decision logic over a caller-supplied metrics window —
      :meth:`plan` returns the :class:`DecisionRecord` it *would* apply.
      This is the unit-test and what-if surface; :meth:`mark_applied`
      simulates the apply (config + cooldown bookkeeping).

    Args:
      engine: the :class:`StreamingEngine` to control, or None (detached).
      slo: the :class:`SLOPolicy` to defend.
      knobs: the candidate grid; default ``KnobSpace.around(current)``
        (S downshifts only — see its docstring).
      decision_sink: where :class:`DecisionRecord`\\ s go (``MetricsSink``
        duck-typed; default in-memory ring).
      window: ticks of history a decision looks at (and how many
        comfortable ticks an upshift requires).
      min_ticks: observations below which the controller stays silent —
        both for SLO stats and the calibration fit.
      cooldown_ticks: after any emitted decision, no further evaluation
        for this many ticks (thrash guard; also the recovery budget the
        acceptance test holds the controller to).
      upshift_margin: hysteresis — upshift only when observed p95 is under
        ``margin × p95_tick_s`` *and* the candidate's predicted latency
        stays under the same margin.
      headroom: downshift target — a breach picks candidates predicted
        under ``headroom × p95_tick_s``, not exactly at the line.
      prewarm: compile every ladder rung of a replacement engine before it
        takes traffic (needs a bounded shape family; skipped for
        dynamic-shape engines).
      config, arch, slots: detached-mode substitutes for what an engine
        would provide (current config, its :class:`RNNArch`, and the
        session slots a fixed-shape tick pads to).
    """

    def __init__(self, engine: StreamingEngine | None, slo: SLOPolicy, *,
                 knobs: KnobSpace | None = None, decision_sink=None,
                 window: int = 16, min_ticks: int = 4,
                 cooldown_ticks: int = 8, upshift_margin: float = 0.5,
                 headroom: float = 0.9, prewarm: bool = True,
                 config: ServingConfig | None = None,
                 arch: RNNArch | None = None, slots: int | None = None):
        self.engine = engine
        self.slo = slo
        self.window = int(window)
        self.min_ticks = int(min_ticks)
        self.cooldown_ticks = int(cooldown_ticks)
        self.upshift_margin = float(upshift_margin)
        self.headroom = float(headroom)
        self.prewarm = bool(prewarm)
        self.decision_sink = decision_sink or RingBufferSink()
        if engine is not None:
            self.config = self._derive_config(engine)
            self.arch = self._derive_arch(engine, self.config)
            self._slots = engine.max_sessions if engine._fixed else None
        else:
            if config is None or arch is None:
                raise ValueError("detached mode (engine=None) needs "
                                 "config= and arch=")
            self.config = config
            self.arch = dataclasses.replace(
                arch, weight_bits=_WEIGHT_BITS[config.precision])
            self._slots = slots
        self.knobs = knobs or KnobSpace.around(self.config)
        if min(self.knobs.samples) < 1:
            raise ValueError(f"knob S candidates must be >= 1, "
                             f"got {self.knobs.samples}")
        self._window_start_tick = 0
        self._cooldown_until = 0
        self.last_swap: dict | None = None

    # -- observation ---------------------------------------------------------
    @property
    def decisions(self) -> list:
        """The decision sink's retained window (oldest first)."""
        return list(self.decision_sink.window())

    def window_metrics(self, metrics: Sequence[TickMetrics] | None = None
                       ) -> list[TickMetrics]:
        """The ticks a decision may look at: post-last-swap, bounded.

        The window resets at every applied swap — a calibration fit (and an
        SLO judgment) must not straddle a config change, since the old
        config's ticks were produced by a different arch.
        """
        if metrics is None:
            if self.engine is None:
                raise ValueError("detached controller: pass metrics=")
            metrics = self.engine.metrics
        return [m for m in metrics
                if m.tick >= self._window_start_tick][-self.window:]

    # -- decision ------------------------------------------------------------
    def plan(self, metrics: Sequence[TickMetrics] | None = None
             ) -> DecisionRecord | None:
        """Evaluate the window; return the decision, or None for a no-op.

        Pure with respect to the engine: nothing is applied and nothing is
        emitted — :meth:`maybe_reconfigure` owns the side effects.  Returns
        None when the SLO is met with no upshift headroom, inside a
        cooldown, or with too little history to judge.
        """
        win = self.window_metrics(metrics)
        if len(win) < self.min_ticks:
            return None
        tick = win[-1].tick
        if tick < self._cooldown_until:
            return None
        stats = _sched.summarize(win)
        observed = {
            "duration_s_p95": stats["duration_s_p95"],
            "duration_s_p50": stats["duration_s_p50"],
            "tokens_per_sec_p50": stats["tokens_per_sec_p50"],
            "mean_queue_depth": stats["mean_queue_depth"],
            "queue_wait_s_p95": stats["queue_wait_s_p95"],
            "compiles": stats["compiles"],
            "ticks": stats["ticks"],
        }
        lat_breach = stats["duration_s_p95"] > self.slo.p95_tick_s
        tps_breach = (self.slo.min_tokens_per_sec > 0 and
                      stats["tokens_per_sec_p50"]
                      < self.slo.min_tokens_per_sec)
        q_breach = (self.slo.max_queue_depth is not None and
                    stats["mean_queue_depth"] > self.slo.max_queue_depth)
        if lat_breach and not (tps_breach or q_breach):
            # A slow window whose slowness vanishes once compile ticks are
            # excluded is a compile stall, not overload: reconfiguring
            # would *cause* more compiles.  Record the distinction (the
            # queue_wait/compiles satellite exists for this) and hold —
            # also when compiles are present but too few clean ticks remain
            # to judge: a downshift on contaminated evidence is exactly the
            # boot-time thrash this guard exists to prevent.
            clean = [m.duration_s for m in win if m.compiles == 0]
            if any(m.compiles for m in win) and (
                    len(clean) < self.min_ticks
                    or percentile(clean, 95) <= self.slo.p95_tick_s):
                return self._record(tick, "compile-stall", observed,
                                    fit=None, winner=None, candidates=[])
        breach = lat_breach or tps_breach or q_breach
        if not breach:
            best = max(c.quality for c in self.knobs.configs())
            if (self.config.quality >= best
                    or len(win) < self.window
                    or stats["duration_s_p95"]
                    > self.upshift_margin * self.slo.p95_tick_s):
                return None
            target_lat = self.upshift_margin * self.slo.p95_tick_s
            reason = "headroom-upshift"
        else:
            target_lat = self.headroom * self.slo.p95_tick_s
            reason = "slo-breach"
        fit = _calib.fit_roofline(win, self.arch, min_ticks=self.min_ticks)
        if fit is None:
            return None
        winner_cfg, predicted, cands = self._search(win, fit, target_lat)
        if winner_cfg is None and breach:
            winner_cfg, predicted, cands = self._search(
                win, fit, target_lat, fallback=True)
            reason = "no-feasible-fallback"
        if winner_cfg is None or winner_cfg == self.config:
            if reason == "headroom-upshift":
                return None          # nothing better that is safely faster
            return self._record(tick, "already-optimal", observed, fit=fit,
                                winner=None, candidates=cands)
        rec = self._record(tick, reason, observed, fit=fit,
                           winner=winner_cfg, candidates=cands,
                           predicted_s=predicted, applied=True)
        return rec

    def maybe_reconfigure(self) -> DecisionRecord | None:
        """Plan against the engine's window; apply and record the outcome.

        The attached-mode entry point — call once per tick, *after*
        ``engine.step``.  Emits every non-None decision to the decision
        sink and starts the cooldown; on an applied decision the engine is
        swapped (sessions transferred, replacement prewarmed) before the
        record is emitted, so a crash between swap and emit can lose the
        record but never a session.
        """
        if self.engine is None:
            raise ValueError("detached controller: use plan()/mark_applied()")
        rec = self.plan()
        if rec is None:
            return None
        if rec.applied:
            self.apply_config(ServingConfig(**rec.winner))
        self._cooldown_until = rec.tick + self.cooldown_ticks
        self.decision_sink.emit(rec)
        return rec

    def mark_applied(self, rec: DecisionRecord) -> None:
        """Detached-mode apply: adopt the winner + cooldown bookkeeping."""
        if rec.winner is not None:
            self.config = ServingConfig(**rec.winner)
            self.arch = dataclasses.replace(
                self.arch, weight_bits=_WEIGHT_BITS[self.config.precision])
        self._window_start_tick = rec.tick + 1
        self._cooldown_until = rec.tick + self.cooldown_ticks

    # -- the DSE call --------------------------------------------------------
    def _search(self, win, fit, target_lat, *, fallback=False):
        """One ``dse.search.optimize`` run over the knob grid.

        Normal mode maximizes config quality under the SLO requirements
        (latency ≤ target, S ≥ floor, tokens/s ≥ floor) — the paper's
        requirement-filtered DSE.  ``fallback`` (no candidate met the
        requirements) keeps only the uncertainty floor and minimizes
        latency: under a breach the least-bad config is still better than
        thrashing at the current one.
        """
        demand = max(1, int(percentile([m.n_chunks for m in win], 95)))
        obs_cap = max((m.capacity for m in win), default=1)
        # Expected-chains discount: with early exit live, a served session
        # averages live_rows/n_chunks chains — a fraction of the ceiling.
        # Candidates are priced on *expected* active chains (cfg S scaled
        # by the observed ratio), not max S: a half-retired fleet has twice
        # the latency headroom the ceiling would suggest.  Uniform traffic
        # (threshold off) gives ratio 1.0 and the pre-dynamic-S pricing.
        ratios = [m.live_rows / (m.n_chunks * self.config.n_samples)
                  for m in win if m.n_chunks > 0]
        eff = min(1.0, sum(ratios) / len(ratios)) if ratios else 1.0
        lat_model = _calib.latency_model(fit, slots=self._slots,
                                         shards=self.config.shards)
        table, cfgs = [], []
        for i, cfg in enumerate(self.knobs.configs()):
            cap = cfg.chunk_capacity or obs_cap
            arch = dataclasses.replace(
                self.arch, weight_bits=_WEIGHT_BITS[cfg.precision],
                timesteps=cap)
            pred = lat_model(arch, None, batch=demand,
                             n_samples=cfg.n_samples * eff)
            slots = max(demand, self._slots or 0)
            tps = (slots * cfg.n_samples * eff * cap / pred) \
                if pred > 0 else 0.0
            table.append(_search.Candidate(
                arch=arch, n_samples=cfg.n_samples,
                metrics={"quality": float(cfg.quality),
                         "samples": float(cfg.n_samples),
                         "tokens_per_sec": tps,
                         "cand_index": float(i)}))
            cfgs.append((cfg, pred, tps))
        if fallback:
            mode, requirements = "latency", {
                "samples": float(self.slo.min_samples)}
        else:
            mode, requirements = "quality", {
                "latency": target_lat,
                "samples": float(self.slo.min_samples),
                "tokens_per_sec": self.slo.min_tokens_per_sec,
            }
        winner = _search.optimize(table, mode, requirements=requirements,
                                  latency_model=lat_model, hw_model=None,
                                  batch=demand)
        cands = [dict(dataclasses.asdict(cfg), predicted_s=pred,
                      tokens_per_sec=tps,
                      feasible=(pred <= target_lat
                                and cfg.n_samples >= self.slo.min_samples
                                and tps >= self.slo.min_tokens_per_sec))
                 for cfg, pred, tps in cfgs]
        if winner is None:
            return None, None, cands
        w_cfg, w_pred, _ = cfgs[int(winner.metrics["cand_index"])]
        return w_cfg, w_pred, cands

    def _record(self, tick, reason, observed, *, fit, winner, candidates,
                predicted_s=None, applied=False) -> DecisionRecord:
        return DecisionRecord(
            tick=int(tick), reason=reason, applied=applied,
            current=dataclasses.asdict(self.config),
            winner=None if winner is None else dataclasses.asdict(winner),
            predicted_s=predicted_s, observed=observed,
            slo=dataclasses.asdict(self.slo),
            fit=None if fit is None else dataclasses.asdict(fit),
            candidates=candidates)

    # -- apply: the prewarmed graph swap -------------------------------------
    def apply_config(self, new: ServingConfig) -> StreamingEngine:
        """Swap the engine to ``new`` at a tick boundary, sessions intact.

        The dims ``restore`` refuses to mismatch (S, precision) are exactly
        why this is a rebuild, not a restore: a fresh engine is constructed
        at the new config, every live session's carry is converted
        (:func:`convert_session`) and re-attached with its original mask
        coordinates, queued tickets are re-queued in order, the tick
        counter and metrics sink carry over (one continuous trail), and the
        replacement is prewarmed before it takes traffic.  The row
        allocator cursor transfers too, so post-swap admissions can never
        collide with any row ever drawn in the old engine.
        """
        old = self.engine
        _quant.check_precision(new.precision)
        model_cfg = dataclasses.replace(
            old.cfg, mcd=old.cfg.mcd.replace(n_samples=new.n_samples))
        if old._scheduler is not None:
            cap_arg = "auto"
            ladder = (pow2_ladder(new.chunk_capacity) if new.chunk_capacity
                      else old._scheduler.ladder)
        elif isinstance(old.chunk_capacity, int):
            cap_arg, ladder = (new.chunk_capacity or old.chunk_capacity), None
        else:
            cap_arg, ladder = None, None
        mesh, policy = old.mesh, old.policy
        if new.shards != old._shards:
            if new.shards <= 1:
                mesh = policy = None
            else:
                from repro.launch.mesh import make_data_mesh
                mesh, policy = make_data_mesh(new.shards), old.policy
        # Early-exit config survives the swap; an attached controller also
        # enforces its SLO's uncertainty floor in the data plane (the
        # engine floor is the *early-exit* floor — capped by the new
        # ceiling, since a 2-chain config can't floor at 4).
        floor = min(new.n_samples, max(old.min_samples,
                                       self.slo.min_samples))
        eng = StreamingEngine(
            old.params, model_cfg, backend=old.backend,
            max_sessions=old.max_sessions, chunk_capacity=cap_arg,
            ladder=ladder, max_pending=old.queue.max_pending,
            metrics_sink=old.metrics_sink, mesh=mesh, policy=policy,
            precision=new.precision,
            early_exit_threshold=(None if mesh is not None
                                  else old.early_exit_threshold),
            min_samples=floor, interpret=old.interpret)
        if (old._scheduler is not None and eng._scheduler is not None
                and eng._scheduler.ladder == old._scheduler.ladder):
            # Same ladder → carry the chunk-length observation window, so
            # the replacement starts on the rung the traffic had settled on
            # instead of re-learning it from the bottom.
            eng._scheduler.load_state(old._scheduler.state())
        part_dtypes = carry_dtypes(eng.cell, new.precision, eng.backend)
        # Per-session conversion targets: a session still at the old
        # *ceiling* follows the new ceiling (the engine-wide S swap); one
        # that early exit already shrank keeps its earned smaller S (capped
        # by the new ceiling) — an upshift must not resurrect chains
        # convergence retired.
        def _target(s_i: int) -> int:
            return (new.n_samples if s_i == old.n_samples
                    else min(s_i, new.n_samples))

        # Fresh chains on an upshift draw rows the old engine never used.
        cursor = old.store.next_row
        moved: list[Session] = []
        for sess in old.store.sessions():
            extra = None
            target = _target(int(np.asarray(sess.rows).shape[0]))
            missing = target - int(np.asarray(sess.rows).shape[0])
            if missing > 0:
                extra = np.arange(cursor, cursor + missing, dtype=np.uint32)
                cursor += missing
            moved.append(convert_session(sess, n_samples=target,
                                         part_dtypes=part_dtypes,
                                         extra_rows=extra))
        for sess in moved:
            eng.attach_session(sess)
        for t in old.queue.waiting():
            queued = None
            if t.session is not None:
                target = _target(int(np.asarray(t.session.rows).shape[0]))
                missing = target - int(np.asarray(t.session.rows).shape[0])
                extra = None
                if missing > 0:
                    extra = np.arange(cursor, cursor + missing,
                                      dtype=np.uint32)
                    cursor += missing
                queued = convert_session(t.session,
                                         n_samples=target,
                                         part_dtypes=part_dtypes,
                                         extra_rows=extra)
            eng.queue.submit(t.sid, priority=t.priority, session=queued,
                             n_samples=(None if t.n_samples is None
                                        else min(t.n_samples,
                                                 new.n_samples)))
        # Never re-draw a row either engine ever allocated.
        eng.store._next_row = max(eng.store.next_row, cursor)
        eng.tick = old.tick
        if self.prewarm and (eng._scheduler is not None
                             or isinstance(eng.chunk_capacity, int)):
            _sched.prewarm(eng)
        self.last_swap = {
            "tick": old.tick,
            "old_config": self.config,
            "new_config": new,
            # Shallow session copies: carries are immutable jax arrays, so
            # a copy of the dataclass pins the pre-swap state for
            # verification (the bit-identity acceptance check replays from
            # these).
            "old_sessions": [copy.copy(s) for s in old.store.sessions()],
        }
        self.engine = eng
        self.config = new
        self.arch = dataclasses.replace(
            self.arch, weight_bits=_WEIGHT_BITS[new.precision])
        self._slots = eng.max_sessions if eng._fixed else None
        self._window_start_tick = eng.tick
        return eng

    # -- derivation helpers --------------------------------------------------
    @staticmethod
    def _derive_config(engine: "StreamingEngine") -> ServingConfig:
        if engine._scheduler is not None:
            cap = engine._scheduler.max_capacity
        elif isinstance(engine.chunk_capacity, int):
            cap = engine.chunk_capacity
        else:
            cap = 0
        return ServingConfig(n_samples=engine.n_samples,
                             precision=engine.precision,
                             chunk_capacity=cap, shards=engine._shards)

    @staticmethod
    def _derive_arch(engine: StreamingEngine,
                     config: ServingConfig) -> RNNArch:
        cfg = engine.cfg
        if engine.kind == "classifier":
            out_dim = cfg.num_classes
        else:
            out_dim = cfg.input_dim
        return RNNArch(hidden=cfg.hidden, num_layers=cfg.num_layers,
                       placement=_mcd.placement_str(cfg.mcd.placement),
                       kind=engine.kind, cell=engine.cell,
                       weight_bits=_WEIGHT_BITS[config.precision],
                       input_dim=cfg.input_dim, output_dim=out_dim,
                       timesteps=config.chunk_capacity or 1)


class FleetController:
    """Per-tenant co-design over a fleet: one SLO loop per tenant.

    Wraps one *detached* :class:`CoDesignController` per tenant with an
    SLO (``TenantSpec.slo``, or the ``slos`` override).  Each tenant's
    controller sees only that tenant's tagged slice of the fleet metrics
    trail, derives its config/arch from the tenant's own launch group, and
    scopes its knob grid to that tenant's live knobs — a breach on the
    GRU-autoencoder tenant downshifts *its* S, never the classifier's.

    Applied decisions go through :meth:`FleetEngine.reconfigure_tenant`
    (the tenant's sessions move to a dedicated group, carries converted
    bit-safely); every decision — applied or refused — is emitted to the
    shared decision sink tagged with ``DecisionRecord.tenant``.
    """

    def __init__(self, fleet, *, slos=None, knobs=None, decision_sink=None,
                 **ctrl_kwargs):
        """``fleet``: a :class:`~repro.serve.fleet.FleetEngine`.

        ``slos``: {tenant: SLOPolicy} overriding/extending the specs' own;
        tenants without an SLO from either source are left unmanaged.
        ``knobs``: {tenant: KnobSpace} per-tenant grid override.
        ``ctrl_kwargs`` forward to every per-tenant controller (window,
        min_ticks, cooldown_ticks, ...).
        """
        self.fleet = fleet
        self.decision_sink = decision_sink or RingBufferSink()
        slos = dict(slos or {})
        for name, spec in fleet.specs.items():
            if name not in slos and spec.slo is not None:
                slos[name] = spec.slo
        self.controllers: dict[str, CoDesignController] = {}
        for name, slo in slos.items():
            engine = fleet.group_of(name).engine
            config = CoDesignController._derive_config(engine)
            self.controllers[name] = CoDesignController(
                None, slo, config=config,
                arch=CoDesignController._derive_arch(engine, config),
                slots=engine.max_sessions if engine._fixed else None,
                knobs=(knobs or {}).get(name),
                decision_sink=RingBufferSink(4), **ctrl_kwargs)

    @property
    def decisions(self) -> list:
        return list(self.decision_sink.window())

    def maybe_reconfigure(self) -> list[DecisionRecord]:
        """Run every tenant's loop once; apply winners; return the records.

        Call once per fleet tick, after ``fleet.step``.  Per tenant: plan
        on the tenant's metric slice; an applied plan reconfigures just
        that tenant (and resets its observation window); refusals record
        with the same cooldown the single-engine controller keeps.
        """
        out: list[DecisionRecord] = []
        trail = list(self.fleet.metrics)
        for name, ctrl in self.controllers.items():
            win = [m for m in trail if m.tenant == name]
            rec = ctrl.plan(metrics=win)
            if rec is None:
                continue
            if rec.applied:
                self.fleet.reconfigure_tenant(name,
                                              ServingConfig(**rec.winner))
                ctrl.mark_applied(rec)
            else:
                ctrl._cooldown_until = rec.tick + ctrl.cooldown_ticks
            rec = dataclasses.replace(rec, tenant=name)
            self.decision_sink.emit(rec)
            out.append(rec)
        return out

"""Per-session carried state for streaming Bayesian RNN serving.

The paper's target workload is *continuous* monitoring: a Bayesian LSTM
watches an unbounded signal (ECG leads, MRI series) and emits per-window
uncertainty.  Serving that stream chunk-by-chunk needs exactly two pieces of
state per session, and this module owns both:

* the per-layer, per-MC-chain ``(h, c)`` carry — what the sequence-fused
  kernel's ``(h0, c0)`` operands resume from at each chunk boundary; ``c``
  stays in fp32 on the Pallas backends (the paper's 32-bit cell-state
  policy) so the carry round-trips losslessly and chunked == unchunked is
  bit-identical;
* the ``(seed, rows)`` mask-stream coordinates.  A session's row ids are
  allocated **once at admission** and never change, so every chunk of the
  session redraws the *same* per-gate Bernoulli masks from the counter PRNG
  — the paper's §II-B tying across T, extended across resume boundaries.
  Masks are tied across the whole session, not per chunk: dropping a chunk
  boundary anywhere in the signal changes nothing about the Bayesian draw.

The store itself is a plain capacity-bounded registry — admission fails fast
when full (the engine's batch is the admission-controlled unit of work) and
eviction returns the final session so callers can checkpoint the carry.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


class CapacityError(RuntimeError):
    """Admission refused: the store already holds ``max_sessions`` sessions."""


@dataclasses.dataclass
class Session:
    """One monitored stream: mask coordinates + carried recurrent state."""

    sid: str
    rows: jax.Array            # [S] uint32 — fixed mask-stream row ids
    seed: Any                  # counter-PRNG base seed (shared, engine-wide)
    state: list | None = None  # per-layer [(h [S,H], c [S,H]), ...] or fresh
    steps: int = 0             # timesteps consumed so far
    chunks: int = 0            # chunks served so far

    @property
    def fresh(self) -> bool:
        return self.state is None


class SessionStore:
    """Capacity-bounded registry of live streaming sessions.

    ``n_samples`` is S, the number of MC chains per session: each admitted
    session reserves S consecutive mask-stream rows from a monotone
    allocator, so concurrent (and successive) sessions draw independent
    masks while each session's own masks stay tied across every chunk it
    ever streams.  Row ids are never reused after eviction — a restarted
    session is a *new* Bayesian draw unless the caller re-attaches the
    evicted :class:`Session` object itself.
    """

    def __init__(self, n_samples: int, seed=0, *, max_sessions: int = 64,
                 first_row: int = 0):
        if n_samples < 1:
            raise ValueError(f"n_samples must be >= 1, got {n_samples}")
        self.n_samples = int(n_samples)
        self.seed = seed
        self.max_sessions = int(max_sessions)
        self._next_row = int(first_row)
        self._sessions: dict[str, Session] = {}

    def admit(self, sid: str) -> Session:
        """Register a new stream; allocates its S mask rows for life."""
        if sid in self._sessions:
            raise ValueError(f"session {sid!r} already admitted")
        if len(self._sessions) >= self.max_sessions:
            raise CapacityError(
                f"store full ({self.max_sessions} sessions); evict first")
        rows = jnp.arange(self._next_row, self._next_row + self.n_samples,
                          dtype=jnp.uint32)
        self._next_row += self.n_samples
        sess = Session(sid=sid, rows=rows, seed=self.seed)
        self._sessions[sid] = sess
        return sess

    def attach(self, session: Session) -> Session:
        """Re-admit a previously evicted :class:`Session` object.

        Restores its carried state *and* its original ``(seed, rows)`` mask
        coordinates, so the resumed stream continues the same Bayesian draw
        (masks stay tied across the eviction gap — this is the checkpoint/
        restore path for long-lived monitoring streams).
        """
        if session.sid in self._sessions:
            raise ValueError(f"session {session.sid!r} already admitted")
        if len(self._sessions) >= self.max_sessions:
            raise CapacityError(
                f"store full ({self.max_sessions} sessions); evict first")
        if session.seed != self.seed:
            raise ValueError(
                f"session {session.sid!r} was drawn under seed "
                f"{session.seed!r}, store uses {self.seed!r} — reattaching "
                "would silently change its masks")
        if int(session.rows.shape[0]) != self.n_samples:
            raise ValueError(
                f"session {session.sid!r} carries "
                f"{int(session.rows.shape[0])} MC chains, store serves "
                f"{self.n_samples}")
        attached = {int(r) for r in np.asarray(session.rows)}
        for live in self._sessions.values():
            if attached & {int(r) for r in np.asarray(live.rows)}:
                raise ValueError(
                    f"session {session.sid!r} rows collide with live "
                    f"session {live.sid!r} — same (seed, rows) would "
                    "correlate their Bayesian draws")
        # Future admissions must not re-allocate the attached rows either.
        self._next_row = max(self._next_row, max(attached) + 1)
        self._sessions[session.sid] = session
        return session

    def get(self, sid: str) -> Session:
        try:
            return self._sessions[sid]
        except KeyError:
            raise KeyError(f"unknown session {sid!r} (admitted: "
                           f"{sorted(self._sessions)})") from None

    def evict(self, sid: str) -> Session:
        """Remove a finished stream; returns it (final carry + coordinates)."""
        self.get(sid)                       # raises the uniform KeyError
        return self._sessions.pop(sid)

    @property
    def active(self) -> list[str]:
        return list(self._sessions)

    def sessions(self) -> list[Session]:
        """Live sessions in admission order (snapshot iteration order)."""
        return list(self._sessions.values())

    @property
    def next_row(self) -> int:
        """The allocator cursor — part of the durable-snapshot format:
        restoring it is what keeps post-restart admissions from re-drawing
        the rows (and hence the Bayesian draws) of pre-crash sessions."""
        return self._next_row

    def __len__(self) -> int:
        return len(self._sessions)

    def __contains__(self, sid: str) -> bool:
        return sid in self._sessions

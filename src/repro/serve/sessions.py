"""Per-session carried state for streaming Bayesian RNN serving.

The paper's target workload is *continuous* monitoring: a Bayesian LSTM
watches an unbounded signal (ECG leads, MRI series) and emits per-window
uncertainty.  Serving that stream chunk-by-chunk needs exactly two pieces of
state per session, and this module owns both:

* the per-layer, per-MC-chain ``(h, c)`` carry — what the sequence-fused
  kernel's ``(h0, c0)`` operands resume from at each chunk boundary; ``c``
  stays in fp32 on the Pallas backends (the paper's 32-bit cell-state
  policy) so the carry round-trips losslessly and chunked == unchunked is
  bit-identical;
* the ``(seed, rows)`` mask-stream coordinates.  A session's row ids are
  allocated **once at admission** and never change, so every chunk of the
  session redraws the *same* per-gate Bernoulli masks from the counter PRNG
  — the paper's §II-B tying across T, extended across resume boundaries.
  Masks are tied across the whole session, not per chunk: dropping a chunk
  boundary anywhere in the signal changes nothing about the Bayesian draw.

The store itself is a plain capacity-bounded registry — admission fails fast
when full (the engine's batch is the admission-controlled unit of work) and
eviction returns the final session so callers can checkpoint the carry.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mcd as _mcd

#: Session serving modes: ``"mc"`` runs S Bayesian chains; ``"student"``
#: runs one deterministic row (the distilled fast path — its row id carries
#: :data:`repro.core.mcd.STUDENT_ROW_FLAG`, so the kernels skip its masks).
MODES = ("mc", "student")


class CapacityError(RuntimeError):
    """Admission refused: the store already holds ``max_sessions`` sessions."""


@dataclasses.dataclass
class Session:
    """One monitored stream: mask coordinates + carried recurrent state."""

    sid: str
    rows: jax.Array            # [s] uint32 mask-stream row ids; s is *this
                               # session's* chain count — allocated once at
                               # admission, only ever trimmed to a prefix
                               # (retire) or regrown fresh (grow); ids never
                               # reassigned
    seed: Any                  # counter-PRNG base seed (shared, engine-wide)
    state: list | None = None  # per-layer [(h [S,H], c [S,H]), ...] or fresh
    steps: int = 0             # timesteps consumed so far
    chunks: int = 0            # chunks served so far
    mode: str = "mc"           # "mc" | "student" (MODES); student sessions
                               # carry exactly one flagged deterministic row

    @property
    def fresh(self) -> bool:
        return self.state is None


class SessionStore:
    """Capacity-bounded registry of live streaming sessions.

    ``n_samples`` is the store's **chain ceiling**: the default (and
    maximum) number of MC chains per session.  S itself is *per-session
    state* — ``admit`` takes an optional smaller chain count, and
    :meth:`retire` shrinks a live session's chains mid-stream (the
    early-exit path).  Each admitted session reserves its chains'
    mask-stream rows from a monotone allocator, so concurrent (and
    successive) sessions draw independent masks while each session's own
    masks stay tied across every chunk it ever streams.  Row ids are never
    reused — neither after eviction nor after a retire — so a restarted
    session is a *new* Bayesian draw unless the caller re-attaches the
    evicted :class:`Session` object itself, and a shrunk session's
    surviving chains keep exactly the masks they always had.
    """

    def __init__(self, n_samples: int, seed=0, *, max_sessions: int = 64,
                 first_row: int = 0):
        if n_samples < 1:
            raise ValueError(f"n_samples must be >= 1, got {n_samples}")
        self.n_samples = int(n_samples)
        self.seed = seed
        self.max_sessions = int(max_sessions)
        self._next_row = int(first_row)
        self._sessions: dict[str, Session] = {}

    def admit(self, sid: str, *, n_samples: int | None = None,
              mode: str = "mc") -> Session:
        """Register a new stream; allocates its mask rows for life.

        ``n_samples`` opens the session with fewer chains than the store
        ceiling (None: the ceiling) — a cheap tenant or an operator who
        already knows the traffic is easy; it can never exceed the ceiling,
        which is what co-batched launch shapes are sized against.

        ``mode="student"`` opens the distilled fast path instead: one
        deterministic row whose id carries the
        :data:`repro.core.mcd.STUDENT_ROW_FLAG` high bit (the kernels run it
        dropout-off in the same launch as its MC neighbours).  The allocator
        burns one base id for it, so :meth:`grow` can later escalate the
        session to fresh MC rows without any id collision.
        """
        if sid in self._sessions:
            raise ValueError(f"session {sid!r} already admitted")
        if len(self._sessions) >= self.max_sessions:
            raise CapacityError(
                f"store full ({self.max_sessions} sessions); evict first")
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        if mode == "student":
            if n_samples not in (None, 1):
                raise ValueError(
                    f"session {sid!r}: student sessions run exactly one "
                    f"deterministic row, got n_samples={n_samples}")
            self._check_allocator(1)
            rows = jnp.asarray([_mcd.student_row(self._next_row)],
                               dtype=jnp.uint32)
            self._next_row += 1
            sess = Session(sid=sid, rows=rows, seed=self.seed,
                           mode="student")
            self._sessions[sid] = sess
            return sess
        s = self.n_samples if n_samples is None else int(n_samples)
        if not 1 <= s <= self.n_samples:
            raise ValueError(
                f"session {sid!r} wants {s} MC chains, store ceiling is "
                f"{self.n_samples} (floor 1)")
        self._check_allocator(s)
        rows = jnp.arange(self._next_row, self._next_row + s,
                          dtype=jnp.uint32)
        self._next_row += s
        sess = Session(sid=sid, rows=rows, seed=self.seed)
        self._sessions[sid] = sess
        return sess

    def _check_allocator(self, count: int) -> None:
        # Base row ids must stay below the student-flag bit, or a flagged id
        # would be ambiguous with a plain one (and the masks would collide).
        if self._next_row + count > _mcd.STUDENT_ROW_FLAG:
            raise RuntimeError(
                f"row allocator exhausted ({self._next_row} ids burned; "
                f"ceiling {_mcd.STUDENT_ROW_FLAG})")

    def retire(self, sid: str, keep: int) -> int:
        """Shrink a live session to its first ``keep`` MC chains.

        The early-exit primitive: chains are independent trajectories
        (each batch row sees only its own mask row and the shared signal),
        so keeping a *prefix* leaves the survivors' masks and carries
        untouched — the shrunk session streams on bit-identically to a
        session that had those ``keep`` rows all along, and co-batched
        neighbours never notice (masks are pure functions of ``(seed,
        rows)``; batch composition is launch-invariant).  The freed rows
        are released as batch capacity only — their ids stay burned in the
        allocator, a retired chain's draw is never repeated.  Returns the
        number of rows retired.
        """
        sess = self.get(sid)
        s_old = int(sess.rows.shape[0])
        keep = int(keep)
        if not 1 <= keep <= s_old:
            raise ValueError(
                f"session {sid!r}: keep={keep} must be in [1, {s_old}]")
        if keep == s_old:
            return 0
        sess.rows = sess.rows[:keep]
        if sess.state is not None:
            sess.state = [tuple(part[:keep] for part in layer)
                          for layer in sess.state]
        return s_old - keep

    def grow(self, sid: str, n: int) -> int:
        """Grow a live session to ``n`` total MC chains with fresh rows.

        The reverse of :meth:`retire`, and the student-escalation
        primitive.  ``n`` is the *target* chain count (mirror of retire's
        ``keep``).  Fresh rows come from the monotone allocator — never a
        reused id, so the new chains are genuinely new Bayesian draws and
        no mask is ever repeated.

        * An MC session gains ``n - s`` chains; the newcomers start from
          zero carries (a fresh chain has seen none of the signal — same
          semantics as a config-swap upshift in
          ``repro.serve.controller.convert_session``).
        * A student session is *replaced*: its single deterministic row
          retires (a det row's masks are the identity — it cannot become an
          MC chain) and ``n`` fresh MC rows take over, every one resuming a
          tiled copy of the student's carry.  The escalated session is
          bit-identical to an always-MC session :meth:`attach`-ed with
          those row ids and that tiled state — the distill fallback pin in
          ``tests/test_streaming.py``.  Mode flips to ``"mc"``.

        Returns the number of fresh rows allocated (0 if already at ``n``).
        """
        sess = self.get(sid)
        s_old = int(sess.rows.shape[0])
        n = int(n)
        student = sess.mode == "student"
        if not (1 if student else s_old) <= n <= self.n_samples:
            raise ValueError(
                f"session {sid!r}: grow target {n} must be in "
                f"[{s_old}, {self.n_samples}]")
        count = n if student else n - s_old
        if count == 0:
            return 0
        self._check_allocator(count)
        fresh = jnp.arange(self._next_row, self._next_row + count,
                           dtype=jnp.uint32)
        self._next_row += count
        if student:
            sess.rows = fresh
            if sess.state is not None:
                sess.state = [tuple(jnp.repeat(part, n, axis=0)
                                    for part in layer)
                              for layer in sess.state]
            sess.mode = "mc"
        else:
            sess.rows = jnp.concatenate([sess.rows, fresh])
            if sess.state is not None:
                sess.state = [tuple(jnp.concatenate(
                    [part, jnp.zeros((count,) + part.shape[1:], part.dtype)])
                    for part in layer) for layer in sess.state]
        return count

    def attach(self, session: Session) -> Session:
        """Re-admit a previously evicted :class:`Session` object.

        Restores its carried state *and* its original ``(seed, rows)`` mask
        coordinates, so the resumed stream continues the same Bayesian draw
        (masks stay tied across the eviction gap — this is the checkpoint/
        restore path for long-lived monitoring streams).
        """
        if session.sid in self._sessions:
            raise ValueError(f"session {session.sid!r} already admitted")
        if len(self._sessions) >= self.max_sessions:
            raise CapacityError(
                f"store full ({self.max_sessions} sessions); evict first")
        if session.seed != self.seed:
            raise ValueError(
                f"session {session.sid!r} was drawn under seed "
                f"{session.seed!r}, store uses {self.seed!r} — reattaching "
                "would silently change its masks")
        if int(session.rows.shape[0]) > self.n_samples:
            raise ValueError(
                f"session {session.sid!r} carries "
                f"{int(session.rows.shape[0])} MC chains, store ceiling is "
                f"{self.n_samples}")
        attached = {int(r) for r in np.asarray(session.rows)}
        for live in self._sessions.values():
            if attached & {int(r) for r in np.asarray(live.rows)}:
                raise ValueError(
                    f"session {session.sid!r} rows collide with live "
                    f"session {live.sid!r} — same (seed, rows) would "
                    "correlate their Bayesian draws")
        # Future admissions must not re-allocate the attached rows either.
        # Student rows carry the high flag bit — strip it, or one attached
        # student session would blow the base-id cursor past the ceiling.
        self._next_row = max(self._next_row,
                             max(_mcd.base_row(r) for r in attached) + 1)
        self._sessions[session.sid] = session
        return session

    def get(self, sid: str) -> Session:
        try:
            return self._sessions[sid]
        except KeyError:
            raise KeyError(f"unknown session {sid!r} (admitted: "
                           f"{sorted(self._sessions)})") from None

    def evict(self, sid: str) -> Session:
        """Remove a finished stream; returns it (final carry + coordinates)."""
        self.get(sid)                       # raises the uniform KeyError
        return self._sessions.pop(sid)

    @property
    def active(self) -> list[str]:
        return list(self._sessions)

    def sessions(self) -> list[Session]:
        """Live sessions in admission order (snapshot iteration order)."""
        return list(self._sessions.values())

    @property
    def active_chains(self) -> int:
        """Total live MC chains across every session (post-retire gauge)."""
        return sum(int(s.rows.shape[0]) for s in self._sessions.values())

    @property
    def next_row(self) -> int:
        """The allocator cursor — part of the durable-snapshot format:
        restoring it is what keeps post-restart admissions from re-drawing
        the rows (and hence the Bayesian draws) of pre-crash sessions."""
        return self._next_row

    def __len__(self) -> int:
        return len(self._sessions)

    def __contains__(self, sid: str) -> bool:
        return sid in self._sessions

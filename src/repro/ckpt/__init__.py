"""ckpt substrate."""

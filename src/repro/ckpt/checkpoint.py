"""Fault-tolerant checkpointing: atomic, integrity-checked, reshardable.

Requirements at 1000+ nodes (DESIGN.md §4):
  * **Atomicity** — a step directory is staged as ``.tmp-<step>`` and
    ``os.replace``d into place only after every array + the manifest are
    fsynced; a crash mid-save can never leave a readable-but-corrupt latest.
  * **Integrity** — every leaf carries a sha256 in ``manifest.json``;
    restore verifies before returning (a bad DIMM on one host shows up as a
    checksum mismatch, not silent divergence).
  * **Elastic restart** — arrays are stored unsharded (np), restore takes an
    optional target-sharding pytree; loading onto a *different* mesh shape is
    just a different placement, which is the whole elastic-rescale story:
    drop a pod → rebuild mesh → restore onto it.
  * **Determinism** — the counter-RNG means a restored run recomputes
    byte-identical MCD masks; nothing stochastic lives outside the ckpt.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil

import jax
import numpy as np

_LEAF_RE = re.compile(r"[^\w.-]+")


def _leaf_names(tree):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in paths:
        name = _LEAF_RE.sub("_", jax.tree_util.keystr(path)).strip("_")
        names.append(name or "leaf")
    # disambiguate duplicates deterministically
    seen: dict[str, int] = {}
    out = []
    for n in names:
        k = seen.get(n, 0)
        seen[n] = k + 1
        out.append(f"{n}__{k}" if k else n)
    return out


def save(directory: str, step: int, tree, *, meta=None) -> str:
    """Atomically save a pytree as step-<step>/ under directory.

    ``meta``: optional JSON-serializable dict stored inside ``manifest.json``
    — it rides the same atomic rename as the arrays, so callers that need
    structural metadata alongside the leaves (e.g. the serving control
    plane's session registry) never see arrays without their meta or vice
    versa.  Read it back with :func:`load_meta`.
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step-{step:010d}")
    tmp = os.path.join(directory, f".tmp-{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = jax.tree_util.tree_leaves(tree)
    names = _leaf_names(tree)
    manifest = {"step": step, "leaves": []}
    if meta is not None:
        manifest["meta"] = json.loads(json.dumps(meta))  # fail fast if not JSON
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        path = os.path.join(tmp, name + ".npy")
        np.save(path, arr)
        with open(path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["leaves"].append({
            "name": name, "dtype": str(arr.dtype), "shape": list(arr.shape),
            "sha256": digest})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("-")[1]) for d in os.listdir(directory)
             if d.startswith("step-")]
    return max(steps) if steps else None


def _reinterpret(arr, want: str, name: str, path: str):
    """Give extended dtypes their identity back on load.

    numpy serializes ml_dtypes arrays (bfloat16, fp8, …) as opaque void
    records; the manifest remembers the true dtype string, so a mismatched
    load is re-viewed through ml_dtypes.  Bit-exact either way — the bytes
    on disk are the bytes that were checksummed.
    """
    if str(arr.dtype) == want:
        return arr
    try:
        import ml_dtypes
        return arr.view(np.dtype(getattr(ml_dtypes, want)))
    except (ImportError, AttributeError, TypeError) as e:
        raise IOError(f"cannot reinterpret {name} in {path} as "
                      f"{want!r}: {e}") from None


def load_meta(directory: str, step: int):
    """The ``meta`` dict a checkpoint was saved with, or None."""
    path = os.path.join(directory, f"step-{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f).get("meta")


def restore(directory: str, step: int, like, shardings=None, *,
            partial: bool = False):
    """Restore into the structure of ``like``; verify checksums.

    ``shardings``: optional pytree of jax.sharding.Sharding matching ``like``
    — pass target-mesh shardings to reshard elastically on restore.

    ``partial``: when True, ``like`` may name only a *subset* of the saved
    leaves (matched by flattened path name) — the hook the serving control
    plane uses to restore a few sessions out of a store-wide snapshot.  A
    leaf of ``like`` that the manifest doesn't know is still an error:
    partial restore narrows the read, it never invents data.  When False
    (the default), ``like`` must cover *every* saved leaf — a truncated
    like-tree is a caller bug, not a silent partial restore.
    """
    path = os.path.join(directory, f"step-{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    names = _leaf_names(like)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    if not partial and (missing := set(by_name) - set(names)):
        raise ValueError(
            f"like-tree misses {len(missing)} saved leaves (e.g. "
            f"{sorted(missing)[:3]}); pass partial=True for a subset "
            "restore")
    if partial:
        # The __k duplicate-name disambiguation is positional over the FULL
        # tree; a subset like-tree re-derives different positions, so a
        # name that was deduplicated at save time cannot be addressed
        # safely — refuse rather than silently return a sibling's data.
        for name in names:
            if f"{name}__1" in by_name or re.search(r"__\d+$", name):
                raise ValueError(
                    f"leaf name {name!r} was disambiguated positionally at "
                    "save time; a partial restore cannot address it safely "
                    "— restore the full tree or save under unique keys")
    leaves = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(names))
    for name, shard in zip(names, shard_leaves):
        try:
            entry = by_name[name]
        except KeyError:
            raise KeyError(
                f"leaf {name!r} not in checkpoint {path}"
                + (" (partial restore reads a subset, it cannot add leaves)"
                   if partial else "")) from None
        fpath = os.path.join(path, name + ".npy")
        with open(fpath, "rb") as f:
            data = f.read()
        if hashlib.sha256(data).hexdigest() != entry["sha256"]:
            raise IOError(f"checksum mismatch for {name} in {path}")
        arr = _reinterpret(np.load(fpath), entry["dtype"], name, path)
        leaves.append(jax.device_put(arr, shard) if shard is not None else arr)
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def resume_or_none(directory: str, like, shardings=None):
    """(step, tree) from the latest valid checkpoint, else None."""
    step = latest_step(directory)
    while step is not None:
        try:
            return step, restore(directory, step, like, shardings)
        except (IOError, FileNotFoundError, KeyError, ValueError):
            # corrupt/partial: fall back to the previous step
            older = [s for s in
                     (int(d.split("-")[1]) for d in os.listdir(directory)
                      if d.startswith("step-")) if s < step]
            step = max(older) if older else None
    return None


def keep_last(directory: str, n: int = 3) -> None:
    """Garbage-collect old checkpoints, keeping the newest n."""
    if not os.path.isdir(directory):
        return
    steps = sorted(int(d.split("-")[1]) for d in os.listdir(directory)
                   if d.startswith("step-"))
    for s in steps[:-n]:
        shutil.rmtree(os.path.join(directory, f"step-{s:010d}"),
                      ignore_errors=True)

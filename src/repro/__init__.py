"""repro — Bayesian RNN/NN inference & training at TPU pod scale.

Reproduction + scale-out of Ferianc et al. (2021), "Optimizing Bayesian
Recurrent Neural Networks on an FPGA-based Accelerator".  See DESIGN.md.
"""

__version__ = "1.0.0"

"""Training loop: microbatched grad accumulation, compressed all-reduce,
checkpoint/auto-resume, straggler watchdog.

Distributed-optimization features (DESIGN.md §4):
  * **Microbatch accumulation** — `lax.scan` over microbatches; under XLA's
    async collectives the reduce of microbatch i overlaps the compute of
    i+1 (the paper's sample-wise pipelining, at gradient granularity).
  * **Gradient compression** — optional error-feedback int8/bf16 cast applied
    to the per-microbatch gradient contribution before accumulation; the
    fp32 residual stays in the accumulator state (classic EF-SGD), so the
    compression bias is corrected over steps.
  * **Fault tolerance** — atomic checkpoints every `ckpt_every`, auto-resume
    from the latest valid step, per-step wall-clock watchdog that flags
    stragglers (> straggler_factor × running median).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint
from repro.train import optimizer


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: optimizer.AdamWConfig = dataclasses.field(default_factory=optimizer.AdamWConfig)
    microbatches: int = 1
    grad_compression: str = "none"       # none | bf16 | int8
    ckpt_every: int = 100
    ckpt_dir: str | None = None
    keep_ckpts: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


def _compress(g: jax.Array, err: jax.Array, mode: str):
    """Error-feedback compression of one gradient leaf (fp32 residual)."""
    if mode == "none":
        return g, err
    g32 = g.astype(jnp.float32) + err
    if mode == "bf16":
        deq = g32.astype(jnp.bfloat16).astype(jnp.float32)
    elif mode == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
    else:
        raise ValueError(mode)
    return deq.astype(g.dtype), g32 - deq


def make_train_step(loss_fn: Callable, cfg: TrainConfig):
    """Build the jittable step.

    loss_fn(params, batch, step) → (loss, metrics-dict).
    State = (params, AdamWState, err_tree).  Batch leading axis is split into
    `cfg.microbatches` chunks and scanned.
    """

    def step_fn(params, opt_state, err, batch, step):
        nm = cfg.microbatches

        def micro(carry, mb):
            gacc, lacc = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb, step)
            gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                gacc, grads)
            return (gacc, lacc + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if nm > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape(nm, x.shape[0] // nm, *x.shape[1:]), batch)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0.0)), mbs)
        else:
            (gsum, lsum), _ = micro((zeros, jnp.float32(0.0)), batch)
        grads = jax.tree.map(lambda g: g / nm, gsum)
        loss = lsum / nm

        if cfg.grad_compression != "none":
            flat_g, tdef = jax.tree_util.tree_flatten(grads)
            flat_e = jax.tree_util.tree_leaves(err)
            pairs = [_compress(g, e, cfg.grad_compression)
                     for g, e in zip(flat_g, flat_e)]
            grads = jax.tree_util.tree_unflatten(tdef, [p[0] for p in pairs])
            err = jax.tree_util.tree_unflatten(tdef, [p[1] for p in pairs])

        params, opt_state, metrics = optimizer.apply(cfg.adamw, params, grads,
                                                     opt_state)
        metrics["loss"] = loss
        return params, opt_state, err, metrics

    return step_fn


class Trainer:
    """Orchestrates steps, checkpointing, resume, and the straggler watchdog."""

    def __init__(self, loss_fn, params, cfg: TrainConfig, *, jit_kwargs=None):
        self.cfg = cfg
        self.params = params
        self.opt_state = optimizer.init(params)
        self.err = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
                    if cfg.grad_compression != "none" else
                    jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params))
        self.step = 0
        self.step_fn = jax.jit(make_train_step(loss_fn, cfg),
                               **(jit_kwargs or {}))
        self.step_times: list[float] = []
        self.straggler_events: list[int] = []
        if cfg.ckpt_dir:
            resumed = checkpoint.resume_or_none(
                cfg.ckpt_dir, (self.params, self.opt_state))
            if resumed is not None:
                self.step, (self.params, self.opt_state) = resumed

    def run(self, batches, num_steps: int, log=print):
        it = iter(batches)
        history = []
        while self.step < num_steps:
            try:
                batch = next(it)
            except StopIteration:
                break
            t0 = time.monotonic()
            self.params, self.opt_state, self.err, metrics = self.step_fn(
                self.params, self.opt_state, self.err, batch,
                jnp.int32(self.step))
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.monotonic() - t0
            self._watchdog(dt)
            self.step += 1
            history.append(metrics)
            if self.cfg.log_every and self.step % self.cfg.log_every == 0:
                log(f"step {self.step}: loss={metrics['loss']:.4f} "
                    f"gnorm={metrics['grad_norm']:.3f} ({dt*1e3:.0f} ms)")
            if (self.cfg.ckpt_dir and self.cfg.ckpt_every
                    and self.step % self.cfg.ckpt_every == 0):
                checkpoint.save(self.cfg.ckpt_dir, self.step,
                                (self.params, self.opt_state))
                checkpoint.keep_last(self.cfg.ckpt_dir, self.cfg.keep_ckpts)
        if self.cfg.ckpt_dir:
            checkpoint.save(self.cfg.ckpt_dir, self.step,
                            (self.params, self.opt_state))
            checkpoint.keep_last(self.cfg.ckpt_dir, self.cfg.keep_ckpts)
        return history

    def _watchdog(self, dt: float):
        """Flag steps slower than straggler_factor × running median.

        On a real cluster this hook triggers the elastic path: evict the slow
        host, rebuild the mesh without it, and restore the latest checkpoint
        onto the new mesh (see repro.ckpt.checkpoint.restore(shardings=...)).
        """
        self.step_times.append(dt)
        window = self.step_times[-50:]
        if len(window) >= 10:
            med = sorted(window)[len(window) // 2]
            if dt > self.cfg.straggler_factor * med:
                self.straggler_events.append(self.step)

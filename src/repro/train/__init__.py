"""train substrate."""

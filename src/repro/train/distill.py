"""Distillation trainer: roll the MC teacher over a stream, fit the student.

The trunk is frozen — only the student's two dense heads
(:func:`repro.core.distill.init_student`) train.  That makes each batch two
phases:

1. **Teacher pass** (no grad): one S·B-row launch produces the chain-axis
   summary via the ``Running*`` accumulators — the mean prediction and the
   epistemic target (MI / Var_s[mu]).  In the same sweep the trunk runs once
   more with *flagged* (deterministic) rows to cache the student's feature
   (``h_T`` / ``dec_out``) — the same values the serving fast path computes.
2. **Student step** (jitted): heads-only loss on the cached features —
   KL(teacher probs ‖ student softmax) + MSE on the uncertainty head for the
   classifier; mean/log-var matching + epistemic MSE for the autoencoder.

Because the features are precomputed, the jitted train step never touches the
recurrent stack: distillation costs one teacher sweep over the stream plus a
dense-head regression, not S epochs of BPTT.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Iterable

import jax
import jax.numpy as jnp

from repro.core import autoencoder, classifier, distill
from repro.train import optimizer, trainer


@dataclasses.dataclass(frozen=True)
class DistillConfig:
    n_samples: int | None = None   # teacher chain count (None: cfg.mcd.n_samples)
    unc_weight: float = 1.0        # weight of the uncertainty-regression term
    lr: float = 1e-2               # heads-only — far stiffer than trunk training
    backend: str = "reference"     # teacher/trunk execution path
    log_every: int = 0
    #: Materialize the teacher feed once and cycle it: ``xs`` must then be
    #: finite, and training literally costs one teacher sweep however many
    #: head steps follow (the targets are deterministic in ``(params, x)``,
    #: so re-sweeping identical batches buys nothing).
    cache_targets: bool = False

    def train_config(self) -> trainer.TrainConfig:
        return trainer.TrainConfig(
            adamw=optimizer.AdamWConfig(lr=self.lr, weight_decay=0.0),
            log_every=self.log_every)


def classifier_batches(params: dict[str, Any], cfg, xs: Iterable[jax.Array],
                       dcfg: DistillConfig):
    """Yield ``{"feat", "probs", "mi"}`` per input batch (teacher pass)."""
    for x in xs:
        t = distill.classifier_teacher_targets(
            params, x, cfg, n_samples=dcfg.n_samples, backend=dcfg.backend)
        _, states = classifier.apply(params, x, distill.det_rows(x.shape[0]),
                                     cfg, backend=dcfg.backend,
                                     return_state=True)
        yield {"feat": states[-1][0], "probs": t.probs,
               "mi": t.mutual_information}


def autoencoder_batches(params: dict[str, Any], cfg, xs: Iterable[jax.Array],
                        dcfg: DistillConfig):
    """Yield ``{"feat", "mean", "eps"}`` per input batch (teacher pass)."""
    for x in xs:
        t = distill.autoencoder_teacher_targets(
            params, x, cfg, n_samples=dcfg.n_samples, backend=dcfg.backend)
        out = autoencoder.apply(params, x, distill.det_rows(x.shape[0]), cfg,
                                backend=dcfg.backend, return_decoded=True)
        yield {"feat": out[-1], "mean": t.mean, "eps": t.epistemic}


def _kl(p: jax.Array, q: jax.Array) -> jax.Array:
    """Mean KL(p ‖ q) over the batch, probabilities in, nats out."""
    p = jnp.clip(p, 1e-12, 1.0)
    q = jnp.clip(q, 1e-12, 1.0)
    return jnp.mean(jnp.sum(p * (jnp.log(p) - jnp.log(q)), axis=-1))


def distill_classifier(params: dict[str, Any], cfg, xs: Iterable[jax.Array],
                       num_steps: int, *, key: jax.Array | None = None,
                       dcfg: DistillConfig = DistillConfig(),
                       student: dict[str, Any] | None = None):
    """Fit a classifier student on ``xs`` batches.  Returns (student, history)."""
    if student is None:
        student = distill.init_student(
            key if key is not None else jax.random.PRNGKey(0), cfg, params)

    def loss_fn(stu, batch, step):
        summ = distill.classifier_student_summary(stu, batch["feat"])
        kl = _kl(batch["probs"], summ.probs)
        unc = jnp.mean((summ.mutual_information - batch["mi"]) ** 2)
        return kl + dcfg.unc_weight * unc, {"kl": kl, "unc_mse": unc}

    tr = trainer.Trainer(loss_fn, student, dcfg.train_config())
    feed = classifier_batches(params, cfg, xs, dcfg)
    if dcfg.cache_targets:
        feed = itertools.cycle(list(feed))
    hist = tr.run(feed, num_steps)
    return tr.params, hist


def distill_autoencoder(params: dict[str, Any], cfg, xs: Iterable[jax.Array],
                        num_steps: int, *, key: jax.Array | None = None,
                        dcfg: DistillConfig = DistillConfig(),
                        student: dict[str, Any] | None = None):
    """Fit an autoencoder student on ``xs`` batches.  Returns (student, history)."""
    if student is None:
        student = distill.init_student(
            key if key is not None else jax.random.PRNGKey(0), cfg, params)

    def loss_fn(stu, batch, step):
        summ = distill.autoencoder_student_summary(stu, batch["feat"],
                                                   cfg.heteroscedastic)
        mse = jnp.mean((summ.mean - batch["mean"]) ** 2)
        unc = jnp.mean((summ.epistemic - batch["eps"]) ** 2)
        return mse + dcfg.unc_weight * unc, {"mse": mse, "unc_mse": unc}

    tr = trainer.Trainer(loss_fn, student, dcfg.train_config())
    feed = autoencoder_batches(params, cfg, xs, dcfg)
    if dcfg.cache_targets:
        feed = itertools.cycle(list(feed))
    hist = tr.run(feed, num_steps)
    return tr.params, hist

"""AdamW + global-norm clipping (paper §V: clip 3.0, weight decay 1e-4).

Self-contained pytree optimizer (no optax dependency).  Optimizer moments are
kept fp32 regardless of param dtype; at pod scale the trainer shards them
ZeRO-style over the data axes via the sharding rules in
``repro.launch.shardings`` (moments inherit the param specs with the data
axis folded in).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 1e-4     # paper §V
    clip_norm: float = 3.0         # paper §V
    warmup_steps: int = 0


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        tree), norm


def apply(cfg: AdamWConfig, params, grads, state: AdamWState):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cfg.lr
    if cfg.warmup_steps:
        lr = lr * jnp.minimum(1.0, step.astype(jnp.float32) / cfg.warmup_steps)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mh = m / b1c
        vh = v / b2c
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [t[0] for t in new])
    new_m = jax.tree_util.tree_unflatten(treedef, [t[1] for t in new])
    new_v = jax.tree_util.tree_unflatten(treedef, [t[2] for t in new])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}

"""The optimization framework (paper §IV, Fig. 7): lookup table + modes.

Flow (exactly the paper's):
  1. user gives hardware constraints + metric requirements + focus mode
  2. algorithmic DSE over A = {H, NL, B} against a benchmarked lookup table
  3. quantization (fp32 → bf16/int8 here; 16-bit fixed point on the FPGA)
  4. hardware-parameter optimization against the resource model
     (reuse factors / DSP budget on FPGA; mesh split / HBM budget on TPU)
  5. latency estimate from the latency model; filter by minimum requirements

Modes: Opt-Latency, Opt-Accuracy, Opt-Precision, Opt-Recall, Opt-AUC,
Opt-Entropy (paper Tables V/VI).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.dse import fpga_model

MAXIMIZE = {"accuracy", "auc", "ap", "ar", "entropy", "precision", "recall"}
MINIMIZE = {"latency", "nll", "rmse"}

MODES = {
    "Opt-Latency": "latency",
    "Opt-Accuracy": "accuracy",
    "Opt-Precision": "ap",
    "Opt-Recall": "ar",
    "Opt-AUC": "auc",
    "Opt-Entropy": "entropy",
}


@dataclasses.dataclass
class Candidate:
    """One row of the lookup table: a benchmarked (A, metrics) pair."""
    arch: fpga_model.RNNArch
    metrics: dict[str, float]          # algorithmic metrics (benchmarked)
    n_samples: int = 30
    hw: Any = None                     # filled by the hardware stage
    latency_s: float | None = None

    def score(self, metric: str) -> float:
        if metric == "latency":
            return self.latency_s if self.latency_s is not None else float("inf")
        return self.metrics.get(metric, float("-inf"))


def optimize(table: list[Candidate], mode: str, *,
             dsp_total: int = fpga_model.DSP_TOTAL_ZC706,
             batch: int = 1,
             requirements: dict[str, float] | None = None,
             latency_model: Callable | None = None) -> Candidate | None:
    """Greedy DSE per the paper: algorithmic pick → hw fit → filter → best.

    ``latency_model(arch, hw, batch, n_samples)`` defaults to the paper's
    §IV-C model; pass a TPU-roofline-backed callable for the TPU flow.
    """
    metric = MODES.get(mode, mode)
    lat_fn = latency_model or fpga_model.latency_s
    survivors = []
    for cand in table:
        # Opt-Latency trades Bayesian sampling away (paper: S=1, B=N…N)
        n_samples = 1 if metric == "latency" and not any(
            c == "Y" for c in cand.arch.placement) else cand.n_samples
        hw = fpga_model.best_reuse_factors(cand.arch, dsp_total)
        if hw is None:
            continue                     # does not fit the chip at any reuse
        lat = lat_fn(cand.arch, hw, batch=batch, n_samples=n_samples)
        cand = dataclasses.replace(cand, hw=hw, latency_s=lat,
                                   n_samples=n_samples)
        ok = True
        for req_metric, req_value in (requirements or {}).items():
            v = cand.score(req_metric)
            ok &= (v <= req_value) if req_metric in MINIMIZE else (v >= req_value)
        if ok:
            survivors.append(cand)
    if not survivors:
        return None
    reverse = metric not in MINIMIZE
    survivors.sort(key=lambda c: c.score(metric), reverse=reverse)
    return survivors[0]


def pareto_front(table: list[Candidate], x_metric: str,
                 y_metric: str) -> list[Candidate]:
    """Pareto-optimal candidates (paper Fig. 8/9: most are partially Bayesian)."""
    pts = [(c.score(x_metric), c.score(y_metric), c) for c in table]
    front = []
    for x, y, c in pts:
        dominated = any(
            (x2 <= x and y2 >= y and (x2 < x or y2 > y))
            if x_metric in MINIMIZE else
            (x2 >= x and y2 >= y and (x2 > x or y2 > y))
            for x2, y2, _ in pts)
        if not dominated:
            front.append(c)
    return front

"""The optimization framework (paper §IV, Fig. 7): lookup table + modes.

Flow (exactly the paper's):
  1. user gives hardware constraints + metric requirements + focus mode
  2. algorithmic DSE over A = {H, NL, B} against a benchmarked lookup table
  3. quantization (fp32 → bf16/int8 here; 16-bit fixed point on the FPGA)
  4. hardware-parameter optimization against the resource model
     (reuse factors / DSP budget on FPGA; mesh split / HBM budget on TPU)
  5. latency estimate from the latency model; filter by minimum requirements

Modes: Opt-Latency, Opt-Accuracy, Opt-Precision, Opt-Recall, Opt-AUC,
Opt-Entropy (paper Tables V/VI).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.dse import fpga_model

MAXIMIZE = {"accuracy", "auc", "ap", "ar", "entropy", "precision", "recall"}
MINIMIZE = {"latency", "nll", "rmse"}

MODES = {
    "Opt-Latency": "latency",
    "Opt-Accuracy": "accuracy",
    "Opt-Precision": "ap",
    "Opt-Recall": "ar",
    "Opt-AUC": "auc",
    "Opt-Entropy": "entropy",
}


@dataclasses.dataclass
class Candidate:
    """One row of the lookup table: a benchmarked (A, metrics) pair.

    ``cell`` surfaces the recurrent-unit axis of the algorithmic space
    (paper §III-A: GRU drops into the same per-gate MCD design at 3/4 the
    datapath cost).  It defaults to the arch's own cell; passing it
    explicitly rewrites the arch, so a table can be built from shared
    ``RNNArch`` shapes with per-row cells and the resource/latency stage
    prices each row with its own gate count.
    """
    arch: fpga_model.RNNArch
    metrics: dict[str, float]          # algorithmic metrics (benchmarked)
    n_samples: int = 30
    cell: str | None = None            # recurrent unit; None = arch.cell
    hw: Any = None                     # filled by the hardware stage
    latency_s: float | None = None

    def __post_init__(self):
        if self.cell is None:
            self.cell = self.arch.cell
        elif self.cell != self.arch.cell:
            self.arch = dataclasses.replace(self.arch, cell=self.cell)

    def score(self, metric: str) -> float:
        if metric == "latency":
            return self.latency_s if self.latency_s is not None else float("inf")
        return self.metrics.get(metric, float("-inf"))


_FPGA_FIT = object()     # sentinel: default hw stage (so None can mean "no gate")


def optimize(table: list[Candidate], mode: str, *,
             dsp_total: int = fpga_model.DSP_TOTAL_ZC706,
             batch: int = 1,
             requirements: dict[str, float] | None = None,
             latency_model: Callable | None = None,
             hw_model: Callable | None = _FPGA_FIT) -> Candidate | None:
    """Greedy DSE per the paper: algorithmic pick → hw fit → filter → best.

    ``latency_model(arch, hw, batch, n_samples)`` defaults to the paper's
    §IV-C model; pass a TPU-roofline-backed callable for the TPU flow.
    ``hw_model(arch, dsp_total)`` is the hardware-feasibility stage —
    default: the paper's reuse-factor search under the ZC706 DSP budget,
    which rejects any arch that cannot fit the FPGA at *any* reuse.  The
    TPU flow passes ``hw_model=None`` (no DSP gate — TPU feasibility is
    HBM-bounded and priced inside the latency model; ``cand.hw`` stays
    None) or its own search callable.
    """
    metric = MODES.get(mode, mode)
    if hw_model is None and latency_model is None:
        raise ValueError(
            "hw_model=None (no FPGA fit stage) needs an explicit "
            "latency_model: the default §IV-C model prices reuse factors "
            "the disabled stage would have chosen (e.g. pass "
            "latency_model=tpu_model.rnn_latency_s for the TPU flow)")
    lat_fn = latency_model or fpga_model.latency_s
    hw_fn = fpga_model.best_reuse_factors if hw_model is _FPGA_FIT else hw_model
    survivors = []
    for cand in table:
        # Opt-Latency trades Bayesian sampling away (paper: S=1, B=N…N)
        n_samples = 1 if metric == "latency" and not any(
            c == "Y" for c in cand.arch.placement) else cand.n_samples
        hw = hw_fn(cand.arch, dsp_total) if hw_fn is not None else None
        if hw_fn is not None and hw is None:
            continue                     # does not fit the chip at any reuse
        lat = lat_fn(cand.arch, hw, batch=batch, n_samples=n_samples)
        cand = dataclasses.replace(cand, hw=hw, latency_s=lat,
                                   n_samples=n_samples)
        ok = True
        for req_metric, req_value in (requirements or {}).items():
            v = cand.score(req_metric)
            ok &= (v <= req_value) if req_metric in MINIMIZE else (v >= req_value)
        if ok:
            survivors.append(cand)
    if not survivors:
        return None
    reverse = metric not in MINIMIZE
    survivors.sort(key=lambda c: c.score(metric), reverse=reverse)
    return survivors[0]


def pareto_front(table: list[Candidate], x_metric: str,
                 y_metric: str) -> list[Candidate]:
    """Pareto-optimal candidates (paper Fig. 8/9: most are partially Bayesian)."""
    pts = [(c.score(x_metric), c.score(y_metric), c) for c in table]
    front = []
    for x, y, c in pts:
        dominated = any(
            (x2 <= x and y2 >= y and (x2 < x or y2 > y))
            if x_metric in MINIMIZE else
            (x2 >= x and y2 >= y and (x2 > x or y2 > y))
            for x2, y2, _ in pts)
        if not dominated:
            front.append(c)
    return front

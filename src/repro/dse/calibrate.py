"""Calibrate the TPU roofline against *observed* serving ticks.

The paper's DSE prices candidates with an analytic model (FPGA §IV-B/C;
:mod:`repro.dse.tpu_model` on TPU).  Offline that is enough — every
candidate is compared under the same model, so only the *ranking* matters.
An **online** controller closing the DSE→serving loop needs more: its SLO
is an absolute wall-clock bound, so the model's predictions must track the
latencies the engine actually measures (interpret-mode CPU, a real TPU, a
noisy shared host — each a different constant factor plus per-tick
dispatch overhead the roofline knows nothing about).

This module is that bridge.  Each served tick is one observation
``(raw, duration)`` where ``raw`` is the uncalibrated roofline time for the
tick's launch shape (``TickMetrics.batch_rows`` × ``capacity``, the shape
the engine reports) and ``duration`` is what the engine measured.  A
two-parameter affine fit

    observed ≈ scale · raw + overhead

absorbs the platform's effective-throughput factor (``scale``) and the
fixed per-tick cost (``overhead``: dispatch, host staging, summary
gather).  The calibrated model then prices *candidate* configurations —
other S, precision, chunk capacity, shard width — in observed-world
seconds, which is what ``repro.serve.controller`` feeds to
``search.optimize(latency_model=…)`` and checks against the SLO.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

from repro.dse import tpu_model
from repro.dse.fpga_model import RNNArch

#: Relative x-variance below which the affine fit is unidentifiable (every
#: observed tick launched the same shape) and the ratio fallback is used.
_DEGENERATE_REL_VAR = 1e-12


@dataclasses.dataclass(frozen=True)
class RooflineFit:
    """An affine map from roofline seconds to observed seconds.

    ``scale`` is the platform factor (observed seconds per modeled second —
    huge in interpret mode, ~1 on hardware the roofline constants match);
    ``overhead_s`` the fixed per-tick cost.  ``resid_s`` (rms residual over
    the fitted window) is the honesty metric: predictions are only as
    SLO-trustworthy as the fit, and a decision trail that records it lets
    an operator see *why* the controller believed a candidate was safe.
    """

    scale: float
    overhead_s: float
    n_ticks: int
    resid_s: float

    def predict(self, raw_s: float) -> float:
        """Observed-world seconds for a modeled (uncalibrated) time."""
        return self.scale * raw_s + self.overhead_s


def tick_raw_seconds(arch: RNNArch, *, rows: float, capacity: int,
                     shards: int = 1) -> float:
    """Uncalibrated roofline time for one engine tick.

    A tick launches ``rows`` batch rows (sessions × S chains, padding
    included — padded rows run the same graph) for ``capacity`` timesteps,
    ``shards``-way data-parallel.  ``rows`` may be fractional: with early
    exit live the controller prices candidates on *expected* active chains
    (ceiling × observed survival ratio), and the roofline is smooth in the
    batch dimension anyway.  ``arch.timesteps`` is overridden by the launch
    capacity: the arch describes the *model*, the tick decides how much
    signal one launch consumes.
    """
    arch_t = dataclasses.replace(arch, timesteps=int(capacity))
    m = tpu_model.rnn_step_model(arch_t, batch=float(rows), n_samples=1,
                                 data=int(shards))
    return m["t_step"]


def fit_roofline(metrics: Sequence, arch: RNNArch, *,
                 min_ticks: int = 4) -> RooflineFit | None:
    """Least-squares fit of observed tick durations to the roofline.

    ``metrics`` is a window of ``TickMetrics``; ``arch`` the architecture
    that served them (the *current* config — calibration windows must not
    straddle a reconfiguration, the controller resets its window at every
    swap).  Returns None below ``min_ticks`` observations — an SLO decision
    off a two-tick fit would be noise dressed as policy.

    Fallbacks keep the fit usable on degenerate windows: when every tick
    launched the same shape the slope is unidentifiable and the fit
    collapses to the ratio ``mean(observed)/mean(raw)`` (zero overhead) —
    still monotone in every knob, which is what candidate ranking needs.
    A non-positive slope or negative overhead (noise) falls back the same
    way.
    """
    obs = [(tick_raw_seconds(arch, rows=m.batch_rows, capacity=m.capacity,
                             shards=m.shards), float(m.duration_s))
           for m in metrics if m.duration_s > 0 and m.batch_rows > 0]
    if len(obs) < min_ticks:
        return None
    n = float(len(obs))
    mx = sum(x for x, _ in obs) / n
    my = sum(y for _, y in obs) / n
    vx = sum((x - mx) ** 2 for x, _ in obs) / n
    if mx <= 0.0:
        return None
    if vx / (mx * mx) < _DEGENERATE_REL_VAR:
        scale, overhead = my / mx, 0.0
    else:
        cov = sum((x - mx) * (y - my) for x, y in obs) / n
        scale = cov / vx
        overhead = my - scale * mx
        if scale <= 0.0:
            scale, overhead = my / mx, 0.0
        elif overhead < 0.0:
            # Clamp to the physical floor, re-aim the slope through the
            # centroid so the fit still passes through the observed mean.
            scale, overhead = my / mx, 0.0
    resid = math.sqrt(sum((y - (scale * x + overhead)) ** 2
                          for x, y in obs) / n)
    return RooflineFit(scale=scale, overhead_s=overhead,
                       n_ticks=int(n), resid_s=resid)


def latency_model(fit: RooflineFit, *, slots: int | None = None,
                  shards: int = 1) -> Callable:
    """A calibrated ``latency_model=`` for :func:`repro.dse.search.optimize`.

    The returned callable prices a candidate's *per-tick* latency in
    observed-world seconds.  ``arch.timesteps`` carries the candidate's
    chunk capacity (the controller builds each candidate's arch that way);
    ``batch`` is the live session count and ``n_samples`` the candidate's S.
    ``slots`` mirrors the engine's fixed-shape padding: a fixed/auto engine
    always launches ``max_sessions`` session slots whatever the live count,
    so the candidate must be priced at the shape it would actually launch.
    Pass ``hw_model=None`` to ``optimize`` alongside this — the FPGA DSP
    gate has no business filtering TPU/serving candidates.
    """

    def model(arch: RNNArch, hw=None, batch: int = 1,
              n_samples: float = 1) -> float:
        del hw
        sessions = max(int(batch), 1)
        if slots is not None:
            sessions = max(sessions, int(slots))
        # n_samples may be fractional — expected active chains under early
        # exit (ceiling × survival ratio), not a chain count.
        rows = sessions * max(float(n_samples), 1.0)
        raw = tick_raw_seconds(arch, rows=rows, capacity=arch.timesteps,
                               shards=shards)
        return fit.predict(raw)

    return model

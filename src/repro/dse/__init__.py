"""Design-space exploration: the paper co-design framework (FPGA + TPU)."""

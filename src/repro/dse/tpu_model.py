"""TPU-side analytic performance model — the roofline replaces DSPs/II.

Napkin-math formulas per block kind (flops, HBM bytes, collective bytes per
device) as a function of the architecture config and the hardware
configuration (mesh split, microbatches, fsdp, remat).  The same three-term
roofline as :mod:`repro.launch.analysis` — validated against the probe-based
measurements in EXPERIMENTS.md §Roofline (this model is the cheap inner loop
of the DSE; the probes are the ground truth).

Hardware knobs here = the paper's reuse factors: they trade parallelism
(lower latency) against per-chip residency (HBM instead of DSPs).
"""

from __future__ import annotations

import dataclasses

from repro.dse.fpga_model import RNNArch
from repro.launch.analysis import HBM_BW, ICI_BW, PEAK_FLOPS, active_params
from repro.models.config import ArchConfig, ShapeCell


@dataclasses.dataclass(frozen=True)
class TpuHwConfig:
    """Hardware half of the DSE space (TPU analogue of R_x/R_h/R_d)."""
    data: int = 16
    model: int = 16
    pod: int = 1
    microbatches: int = 1
    fsdp: bool = False
    remat: bool = True

    @property
    def chips(self) -> int:
        return self.data * self.model * self.pod

    @property
    def dp(self) -> int:
        return self.data * self.pod


def rnn_step_model(arch: RNNArch, *, batch: float = 1, n_samples: float = 1,
                   data: int = 1, dtype_bytes: int = 2) -> dict:
    """Roofline terms for the paper's recurrent stack itself (both cells).

    The TPU analogue of §IV-B/§IV-C for the Bayesian RNN workload: per-gate
    flop and byte counts (``arch.gates`` — 4 for LSTM, 3 for GRU, so the
    GRU row prices at 3/4 of the LSTM datapath exactly as in
    ``fpga_model.dsp_usage``), with ``batch × n_samples`` MC-chain rows
    sharded ``data``-ways (`repro.launch.rnn_shardings`' data strategy —
    the mesh split is the reuse-factor analogue here).  ``batch`` and
    ``n_samples`` may be fractional: under early-exit serving the
    controller prices *expected* active chains (ceiling × survival
    ratio), and a roofline is smooth in the row dimension.

    Weight bytes are charged **once per launch**, not per timestep — the
    sequence-fused kernel's VMEM residency (docs/kernels.md) is precisely
    this term's reduction; activations stream per step.

    ``arch.weight_bits`` prices the quantized serving path: ``wx``/``wh``
    store at ``weight_bits/8`` bytes per element plus the fp32 per-channel
    scale rows (2 × G × H × 4, charged only below 16 bits — bf16 carries no
    scales), while the bias and activations stay at ``dtype_bytes``.  At
    the default 16 bits this reduces exactly to the pre-quantization
    formula, so calibrated DSE baselines are unchanged.
    """
    g = float(arch.gates)
    rows = max(batch * n_samples / max(data, 1), 1.0)
    _ = arch.dsp_per_mac                  # validates weight_bits
    w_byte = arch.weight_bits / 8.0
    flops_step = 0.0          # per row per timestep
    weight_bytes = 0.0        # resident per launch, per device
    act_bytes_step = 0.0      # streamed per row per timestep
    for (i_dim, h_dim) in arch.layer_dims():
        flops_step += 2.0 * g * (i_dim * h_dim + h_dim * h_dim)
        flops_step += 12.0 * h_dim                     # elementwise tail
        weight_bytes += g * (i_dim + h_dim) * h_dim * w_byte
        weight_bytes += g * h_dim * dtype_bytes        # bias row
        if arch.weight_bits < 16:
            weight_bytes += 2 * g * h_dim * 4          # fp32 scales (wx, wh)
        act_bytes_step += (i_dim + h_dim) * dtype_bytes
    h_last = arch.layer_dims()[-1][1]
    head_mult = arch.timesteps if arch.kind == "autoencoder" else 1
    flops_head = 2.0 * h_last * arch.output_dim * head_mult
    # NOTE: layer_dims() already spans encoder *and* decoder for the AE, so
    # T is not doubled here — the paper's ×2 is a latency-serialization
    # fact (decoder waits for the encoder), not extra work, and a roofline
    # prices work.  (Doubling it penalized AE candidates ~2× in the DSE.)
    t_steps = arch.timesteps
    flops = rows * (t_steps * flops_step + flops_head)
    bytes_hbm = weight_bytes + rows * t_steps * act_bytes_step
    return {"flops": flops, "bytes": bytes_hbm, "coll": 0.0,
            "t_compute": flops / PEAK_FLOPS, "t_memory": bytes_hbm / HBM_BW,
            "t_collective": 0.0,
            "t_step": max(flops / PEAK_FLOPS, bytes_hbm / HBM_BW)}


def rnn_latency_s(arch: RNNArch, hw=None, batch: int = 1,
                  n_samples: int = 1, *, data: int = 1) -> float:
    """TPU latency estimate with the FPGA model's call signature.

    Drop-in ``latency_model=`` for :func:`repro.dse.search.optimize` —
    pass ``hw_model=None`` alongside it, or TPU-sized archs (H far past
    the ZC706's 900 DSPs) are silently rejected by the default FPGA
    reuse-factor gate before this model ever prices them.  ``hw`` (the
    FPGA reuse factors, or None when the gate is off) is irrelevant on
    TPU and ignored; GRU rows price at their 3-gate cost.
    """
    del hw
    return rnn_step_model(arch, batch=batch, n_samples=n_samples,
                          data=data)["t_step"]


def step_model(cfg: ArchConfig, cell: ShapeCell, hw: TpuHwConfig) -> dict:
    """Analytic per-device (flops, bytes, collective bytes) for one step."""
    n_active = active_params(cfg)
    n_total = _total_params(cfg)
    D = cfg.d_model
    if cell.kind == "train":
        tokens_local = cell.global_batch * cell.seq_len / hw.dp
        flops = 6.0 * n_active * tokens_local
        flops += _attention_flops(cfg, cell.seq_len, cell.global_batch,
                                  causal_factor=2.0, bwd=True) / hw.chips
        if hw.remat:
            flops *= 4.0 / 3.0          # one extra forward
        # bytes: weights (re-read per microbatch) + activation stream + moments
        act = tokens_local * D * 2 * 8 * cfg.num_layers
        weights = n_total * 2 / hw.model / (hw.dp if hw.fsdp else 1)
        bytes_hbm = (weights * 3 * hw.microbatches     # w read fwd+bwd(+remat)
                     + act                             # activations
                     + n_total / hw.model * 16)        # moments r/w fp32
        # collectives: grad reduce (2× params) + TP activation all-reduces
        coll = 2 * n_total * 4 / hw.model / (hw.dp if hw.fsdp else 1)
        coll += 2 * 2 * tokens_local * D * 2 * cfg.num_layers  # 2 AR/layer ×2 ring
        if hw.fsdp:
            coll += n_total * 2 / hw.model * 2          # weight all-gathers
    elif cell.kind == "prefill":
        tokens_local = cell.global_batch * cell.seq_len / hw.dp
        flops = 2.0 * n_active * tokens_local
        flops += _attention_flops(cfg, cell.seq_len, cell.global_batch,
                                  causal_factor=2.0, bwd=False) / hw.chips
        weights = n_total * 2 / hw.model / (hw.dp if hw.fsdp else 1)
        act = tokens_local * D * 2 * 8 * cfg.num_layers
        bytes_hbm = weights + act
        coll = 2 * 2 * tokens_local * D * 2 * cfg.num_layers
    else:  # decode
        bsz = max(cell.global_batch / hw.dp, 1)
        flops = 2.0 * n_active * cell.global_batch / hw.chips
        flops += _decode_attention_flops(cfg, cell.seq_len,
                                         cell.global_batch) / hw.chips
        weights = n_total * 2 / hw.model / (hw.dp if hw.fsdp else 1)
        cache = _cache_bytes(cfg, cell.seq_len) * cell.global_batch / hw.chips
        bytes_hbm = weights + cache
        coll = 2 * bsz * D * 2 * 2 * cfg.num_layers
    return {"flops": flops, "bytes": bytes_hbm, "coll": coll,
            "t_compute": flops / PEAK_FLOPS, "t_memory": bytes_hbm / HBM_BW,
            "t_collective": coll / ICI_BW,
            "t_step": max(flops / PEAK_FLOPS, bytes_hbm / HBM_BW,
                          coll / ICI_BW)}


def memory_model(cfg: ArchConfig, cell: ShapeCell, hw: TpuHwConfig) -> float:
    """Per-device HBM residency (bytes) — the TPU resource model (vs 16 GB)."""
    n_total = _total_params(cfg)
    shard = hw.model * (hw.dp if hw.fsdp else 1)
    mem = n_total * 2 / shard                        # bf16 params
    if cell.kind == "train":
        mem += n_total * 2 / shard                   # grads
        mem += n_total * 8 / (hw.model * hw.dp)      # ZeRO moments fp32
        tokens_local = cell.global_batch * cell.seq_len / hw.dp / hw.microbatches
        per_layer = tokens_local * cfg.d_model * 2
        mem += per_layer * (cfg.num_layers if hw.remat else 8 * cfg.num_layers)
    else:
        mem += _cache_bytes(cfg, cell.seq_len) * cell.global_batch / hw.chips
    return mem


def _total_params(cfg: ArchConfig) -> float:
    """All parameters (MoE: every expert), for memory/weight traffic."""
    n = active_params(cfg)
    if cfg.moe is not None:
        moe_layers = sum(st.repeat for st in cfg.stages
                         for k in st.pattern if k.endswith("moe"))
        act_e = cfg.moe.top_k + cfg.moe.num_shared
        n += moe_layers * 3 * cfg.d_model * cfg.moe.d_ff_expert \
            * (cfg.moe.num_experts - act_e + cfg.moe.num_shared * 0)
    return n


def _attention_layers(cfg: ArchConfig) -> int:
    return sum(st.repeat for st in cfg.stages
               for k in st.pattern if k.split(".")[0] in ("attn", "dec_attn", "mla"))


def _attention_flops(cfg: ArchConfig, seq: int, batch: int, *,
                     causal_factor: float, bwd: bool) -> float:
    """Global score+value flops (full S² blocks; ÷2 if block-skipping)."""
    n_attn = _attention_layers(cfg)
    hd = cfg.head_dim if cfg.mla is None else (cfg.mla.nope_head_dim
                                               + cfg.mla.rope_head_dim)
    per_layer = 2.0 * 2.0 * batch * seq * seq * cfg.num_heads * hd
    if bwd:
        per_layer *= 2.5
    # SSD chunk-quadratic term for mamba mixers
    ssm_layers = sum(st.repeat for st in cfg.stages
                     for k in st.pattern if k.split(".")[0] == "mamba")
    ssd = 0.0
    if ssm_layers and cfg.ssm is not None:
        q = cfg.ssm.chunk
        d_inner = cfg.ssm.expand * cfg.d_model
        ssd = 2.0 * 2.0 * batch * seq * q * (d_inner + cfg.ssm.d_state)
        if bwd:
            ssd *= 2.5
    return per_layer * n_attn + ssd * ssm_layers


def _decode_attention_flops(cfg: ArchConfig, seq: int, batch: int) -> float:
    n_attn = _attention_layers(cfg)
    if cfg.mla is not None:
        per = 2.0 * batch * seq * cfg.num_heads * (cfg.mla.kv_lora_rank * 2)
    else:
        per = 2.0 * 2.0 * batch * seq * cfg.num_heads * cfg.head_dim
    return per * n_attn


def _cache_bytes(cfg: ArchConfig, seq: int) -> float:
    """KV/state bytes per sequence."""
    total = 0.0
    for st in cfg.stages:
        for k in st.pattern:
            mixer = k.split(".")[0]
            if mixer in ("attn", "dec_attn"):
                total += st.repeat * 2 * seq * cfg.num_kv_heads * cfg.head_dim * 2
            elif mixer == "mla":
                total += st.repeat * seq * (cfg.mla.kv_lora_rank
                                            + cfg.mla.rope_head_dim) * 2
            elif mixer == "mamba":
                d_inner = cfg.ssm.expand * cfg.d_model
                n_heads = d_inner // cfg.ssm.head_dim
                total += st.repeat * (n_heads * cfg.ssm.head_dim
                                      * cfg.ssm.d_state * 4)
    return total


def search_hw(cfg: ArchConfig, cell: ShapeCell, *, chips: int = 256,
              hbm_limit: float = 16e9, pod: int = 1) -> list[dict]:
    """Enumerate mesh splits × microbatches; keep feasible, sort by t_step.

    The TPU DSE inner loop: the analogue of scanning reuse factors under the
    DSP budget (§IV-B) — scan mesh factorizations under the HBM budget.
    """
    out = []
    d = 1
    while d <= chips:
        if chips % d == 0:
            m = chips // d
            for mb in (1, 2, 4, 8):
                for fsdp in (False, True):
                    hw = TpuHwConfig(data=d, model=m, pod=pod,
                                     microbatches=mb, fsdp=fsdp)
                    if cell.global_batch % max(hw.dp, 1) and cell.global_batch > 1:
                        continue
                    mem = memory_model(cfg, cell, hw)
                    perf = step_model(cfg, cell, hw)
                    out.append({"hw": hw, "mem": mem,
                                "feasible": mem <= hbm_limit, **perf})
        d *= 2
    out.sort(key=lambda r: (not r["feasible"], r["t_step"]))
    return out

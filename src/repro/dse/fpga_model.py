"""Paper-faithful resource & latency models (§IV-B, §IV-C) — the FPGA half.

Reproduced exactly as published:

  DSP_i      = 4·I_i·H_i / R_x  +  4·H_i² / R_h  +  4·H_i
  DSP_design = Σ_i DSP_i + DSP_d  ≤  DSP_total            (ZC706: 900 DSPs)
  DSP_d      = H_L·O·T / R_d   (autoencoder)  |  H_L·O / R_d   (classifier)

  II          = max_i II_i          (cascade balanced to the largest layer)
  Lat_i       = II·T + (IL_i − II)
  Lat_design  = II·T + (IL − II)·NL          (×2 for the autoencoder:
                the decoder starts only after the encoder finishes)

The II of a layer is driven by its reuse factors (a multiplier reused R times
needs R cycles per MVM): II_i = max(R_x, R_h) + II_TAIL.  IL (iteration
latency) = II + pipeline fill depth.  The paper's §V-C check: with the
published configuration (H=16, NL=2, R_x=16, R_h=5 / H=8, NL=3, R_x=12,
R_h=1) this model predicts 42.25 ms and 25.77 ms for batch 50 — reproduced in
``benchmarks/bench_resource_model.py``.

These models power the same DSE loop on the TPU side via
:mod:`repro.dse.tpu_model` (roofline terms replace DSPs/II).
"""

from __future__ import annotations

import dataclasses

DSP_TOTAL_ZC706 = 900
CLOCK_HZ = 100e6          # paper: 100 MHz design frequency
HLS_MARGIN = 0.05         # paper: +5% DSP_total slack for HLS optimizations

# Calibrated against the paper's own §V-C predictions (42.25 ms / 25.77 ms
# at batch 50 × S=30 = 1500 streamed passes): II = max(R_x, R_h) plus a small
# autoencoder handoff constant (bottleneck replay), IL − II = pipeline fill.
II_TAIL_AE = 4
II_TAIL_CLF = 0
PIPELINE_FILL = 34


#: Gate count per recurrent cell — the §III-A algorithmic knob: a GRU layer
#: instantiates 3 gate MVMs where the LSTM needs 4, scaling every DSP /
#: flop / weight-byte term by 3/4 at the same (H, NL).
CELL_GATES = {"lstm": 4, "gru": 3}

#: DSPs per MAC at each weight width.  The paper's published formula is the
#: 16-bit fixed-point instance (one DSP48 per multiply — multiplier 1, which
#: keeps the §V-C calibration intact at the default).  32-bit multipliers
#: compose 4 DSP48s; 8-bit packs two MACs per DSP (the stock INT8 DSP-packing
#: trick), 4-bit packs four.  Serving-side these widths are the
#: ``repro.kernels.quantize`` precisions: 16 ↔ bf16, 8 ↔ int8, 4 ↔ int4.
DSP_PER_MAC = {32: 4.0, 16: 1.0, 8: 0.5, 4: 0.25}


@dataclasses.dataclass(frozen=True)
class RNNArch:
    """Paper's algorithmic parameters A = {H, NL, B} (+ task shape).

    ``cell`` joins the algorithmic DSE space (paper §III-A: the per-gate
    MCD design drops into the GRU unchanged): the 3-gate cell cuts the
    datapath's multiplier count by a quarter, which the hardware stage
    converts into smaller feasible reuse factors — i.e. lower II — under
    the same DSP budget.  The co-design loop can therefore trade the
    cheaper cell against whatever accuracy it costs on the task.
    """
    hidden: int
    num_layers: int                 # NL (encoder; AE has 2·NL total)
    placement: str                  # B-string
    kind: str = "classifier"        # classifier | autoencoder
    cell: str = "lstm"              # recurrent unit (CELL_GATES)
    weight_bits: int = 16           # recurrent-MVM operand width (DSP_PER_MAC)
    input_dim: int = 1
    output_dim: int = 4             # classes, or input_dim for AE
    timesteps: int = 140            # T (ECG5000)

    @property
    def gates(self) -> int:
        if self.cell not in CELL_GATES:
            raise ValueError(f"cell must be one of {sorted(CELL_GATES)}, "
                             f"got {self.cell!r}")
        return CELL_GATES[self.cell]

    @property
    def dsp_per_mac(self) -> float:
        if self.weight_bits not in DSP_PER_MAC:
            raise ValueError(
                f"weight_bits must be one of {sorted(DSP_PER_MAC)}, "
                f"got {self.weight_bits!r}")
        return DSP_PER_MAC[self.weight_bits]

    def layer_dims(self):
        """[(I_i, H_i)] for every LSTM layer in hardware order."""
        dims = []
        d = self.input_dim
        if self.kind == "autoencoder":
            hs = [self.hidden] * (self.num_layers - 1) + [self.hidden // 2]
            for h in hs:
                dims.append((d, h))
                d = h
            d = self.hidden // 2
            for _ in range(self.num_layers):
                dims.append((d, self.hidden))
                d = self.hidden
        else:
            for _ in range(self.num_layers):
                dims.append((d, self.hidden))
                d = self.hidden
        return dims


@dataclasses.dataclass(frozen=True)
class HwConfig:
    """Paper's hardware parameters R = reuse factors."""
    r_x: int = 1
    r_h: int = 1
    r_d: int = 1


def dsp_usage(arch: RNNArch, hw: HwConfig) -> float:
    """DSP_design per §IV-B (paper reports ≥98% accuracy of this model).

    The published formula is the LSTM instance (G = 4); the gate count
    generalizes it — every term is per-gate hardware (an input-side MVM, a
    recurrent MVM, and the elementwise tail), so a GRU layer costs 3/4 of
    the LSTM layer at the same (I, H).  ``arch.weight_bits`` scales only
    the two MVM terms (DSP_PER_MAC: the weight operand width sets how many
    MACs pack into a DSP); the elementwise tail and the dense head keep the
    baseline width — exactly the serving path's contract, where only the
    recurrent ``wx``/``wh`` quantize and the head stays fp32.
    """
    g = float(arch.gates)
    mac = arch.dsp_per_mac
    total = 0.0
    for (i_dim, h_dim) in arch.layer_dims():
        total += (mac * g * i_dim * h_dim / hw.r_x
                  + mac * g * h_dim * h_dim / hw.r_h
                  + g * h_dim)
    h_last = arch.layer_dims()[-1][1]
    if arch.kind == "autoencoder":
        total += h_last * arch.output_dim * arch.timesteps / hw.r_d
    else:
        total += h_last * arch.output_dim / hw.r_d
    return total


def fits(arch: RNNArch, hw: HwConfig,
         dsp_total: int = DSP_TOTAL_ZC706) -> bool:
    return dsp_usage(arch, hw) <= dsp_total * (1.0 + HLS_MARGIN)


def latency_s(arch: RNNArch, hw: HwConfig, batch: int = 1,
              n_samples: int = 1) -> float:
    """End-to-end latency per §IV-C (seconds).

    First pass pays the full pipeline latency (×2 for the autoencoder — the
    decoder starts only after the encoder drains).  Batch elements and MC
    samples then stream back-to-back (paper Fig. 4/5 sample-wise + time-step
    pipelining): each extra pass costs II·T only — the encoder works on
    sample k+1 while the decoder finishes k, so AE steady-state throughput is
    the same II·T.  Matches the paper's §V-C estimates to <2%.
    """
    ii = max(hw.r_x, hw.r_h) + (
        II_TAIL_AE if arch.kind == "autoencoder" else II_TAIL_CLF)
    il = ii + PIPELINE_FILL
    fill = ii * arch.timesteps + (il - ii) * arch.num_layers
    if arch.kind == "autoencoder":
        fill *= 2                   # decoder waits for the encoder (1st pass)
    passes = batch * n_samples
    total = fill + (passes - 1) * ii * arch.timesteps
    return total / CLOCK_HZ


def best_reuse_factors(arch: RNNArch,
                       dsp_total: int = DSP_TOTAL_ZC706) -> HwConfig | None:
    """§IV-B: smallest reuse factors (lowest II) that fit the chip."""
    best = None
    for r_x in range(1, 65):
        for r_h in range(1, 65):
            for r_d in (1, 2, 4, 8, 16, 32):
                hw = HwConfig(r_x, r_h, r_d)
                if not fits(arch, hw, dsp_total):
                    continue
                lat = latency_s(arch, hw)
                if best is None or lat < best[0]:
                    best = (lat, hw)
    return best[1] if best else None

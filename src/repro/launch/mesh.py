"""Production mesh construction (pure function — importing this module never
touches jax device state).

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — "pod" is the
slow-ICI/DCN dimension; only data parallelism (gradient reduce) crosses it.
"""

from __future__ import annotations

from repro.kernels import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh with the same axis names (CPU tests / smoke runs)."""
    return compat.make_mesh((1, 1), ("data", "model"))


def make_data_mesh(n_data: int, *, model: int = 1):
    """A ``(data, model)`` mesh over the first ``n_data × model`` devices.

    The device-count-sweep entry point (``bench_sharding``, the multi-device
    tests): on a host forced to N CPU devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``) this builds
    submeshes of any size that fits, so one process can sweep 1/2/4/8-way
    sharding without restarting.
    """
    import jax
    import numpy as np

    need = n_data * model
    devs = jax.devices()
    if need > len(devs):
        raise ValueError(f"mesh ({n_data}, {model}) needs {need} devices, "
                         f"host has {len(devs)}")
    grid = np.asarray(devs[:need]).reshape(n_data, model)
    return jax.sharding.Mesh(grid, ("data", "model"))


def axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes that carry data parallelism (pod joins data when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)

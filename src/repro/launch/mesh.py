"""Production mesh construction (pure function — importing this module never
touches jax device state).

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — "pod" is the
slow-ICI/DCN dimension; only data parallelism (gradient reduce) crosses it.
"""

from __future__ import annotations

from repro.kernels import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh with the same axis names (CPU tests / smoke runs)."""
    return compat.make_mesh((1, 1), ("data", "model"))


def axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes that carry data parallelism (pod joins data when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)

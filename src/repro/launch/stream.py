"""Streaming session-serving launcher: continuous ECG monitoring.

Opens concurrent sessions, each an unbounded synthetic-ECG signal
(concatenated ECG5000-compatible beats), and decodes them chunk-by-chunk
through the sequence-fused Pallas kernel with carried per-session state —
per-chunk Bayesian uncertainty over the signal-so-far.

The PR 3 control plane is wired in: ``--overload`` admits more streams
than the store holds (they wait in the priority admission queue and go
live as rows free up), ``--capacity auto`` lets the adaptive scheduler
pick the launch shape per tick, and ``--snapshot-dir``/``--resume`` make
the whole thing crash-safe — kill the process at any tick and relaunch
with ``--resume`` to continue every stream bit-identically.

The PR 5 multi-device data plane rides the same loop: ``--shards N``
partitions every launch's batch rows (sessions × MC chains) over the first
N devices (``repro.launch.rnn_shardings``) with bit-identical results,
``--prewarm`` compiles every capacity rung before the first tick, and
``--metrics-out`` streams per-tick ``TickMetrics`` to a JSONL file.

``--controller`` closes the DSE→serving loop online: a
``CoDesignController`` watches the tick metrics, calibrates the roofline
against observed latency, and under an SLO breach (``--slo-p95-ms``,
``--min-tokens-per-sec``) re-runs the paper's optimization over the live
knobs — swapping the winning config in at a tick boundary with every
session's stream continuing bit-identically.  ``--decisions-out`` appends
each ``DecisionRecord`` as a JSON line.

``--early-exit-threshold`` makes S per-session state: every stream still
*opens* with ``--samples`` chains (the engine ceiling), but once a
session's uncertainty summary has converged — dropping half its chains
would move the summary by at most the threshold — the engine retires the
surplus rows mid-stream (never below ``--min-samples``).  Confident
streams get cheaper; uncertain ones keep the full posterior sample.
``0.0`` is the strictest setting (retire only exactly-converged
summaries); the flag is incompatible with ``--shards``.

``--tenants fleet.json`` switches to multi-tenant fleet serving (ISSUE 8):
the JSON declares heterogeneous tenants — classifier or autoencoder, LSTM
or GRU, each with its own S, precision and priority weight — and one
``FleetEngine`` serves all of them per tick (same-config tenants fold into
shared launch groups; admission is weighted-fair under overload).  The
other serving flags (``--chunk-len``, ``--metrics-out``,
``--snapshot-dir``, ``--resume``) apply fleet-wide.

Usage:
  PYTHONPATH=src python -m repro.launch.stream --sessions 4 --chunk-len 20 \
      --samples 8 --beats 2 --backend pallas_seq
  PYTHONPATH=src python -m repro.launch.stream --tenants fleet.json \
      --chunk-len 20 --metrics-out /tmp/fleet.jsonl
  PYTHONPATH=src python -m repro.launch.stream --sessions 4 --cell gru
  PYTHONPATH=src python -m repro.launch.stream --sessions 2 --overload 6 \
      --capacity auto --snapshot-dir /tmp/snap --snapshot-every 3
  PYTHONPATH=src python -m repro.launch.stream --sessions 2 --overload 6 \
      --capacity auto --snapshot-dir /tmp/snap --resume
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.stream --sessions 8 --shards 8 \
      --capacity auto --prewarm --metrics-out /tmp/ticks.jsonl
  PYTHONPATH=src python -m repro.launch.stream --sessions 4 --samples 8 \
      --capacity auto --controller --slo-p95-ms 30 \
      --decisions-out /tmp/decisions.jsonl
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint
from repro.core import autoencoder as ae, classifier as clf, mcd
from repro.data import ecg
from repro.serve import (FleetEngine, JsonlSink, StreamingEngine, TenantSpec,
                         pow2_ladder, prewarm, summarize)


def build_streams(n_sessions: int, beats: int, seed: int):
    """Per-session continuous signals: `beats` ECG beats back to back."""
    _, _, ex, ey = ecg.make_ecg5000(seed)
    rng = np.random.default_rng(seed)
    streams, labels = [], []
    for _ in range(n_sessions):
        idx = rng.integers(0, len(ex), size=beats)
        streams.append(np.concatenate([ex[i] for i in idx], axis=0))
        labels.append([int(ey[i]) for i in idx])
    return streams, labels


def load_fleet(path: str, default_seed: int):
    """Parse a fleet JSON tenant table into ``TenantSpec``s + stream plans.

    Schema (every per-tenant key optional except ``name``)::

        {"admit_per_tick": 4, "aging_rounds": 16, "max_pending": 256,
         "tenants": [
           {"name": "ward", "task": "classifier", "cell": "lstm",
            "hidden": 8, "layers": 2, "classes": 5, "samples": 4,
            "p": 0.125, "placement": "YN", "weight": 3.0,
            "precision": null, "backend": "pallas_seq",
            "max_sessions": 4, "streams": 6, "beats": 2,
            "decode_window": null, "seed": 0,
            "early_exit_threshold": null, "min_samples": 1},
           ...]}

    ``streams`` is how many signals the tenant submits (> ``max_sessions``
    overloads its row quota and exercises the weighted-fair queue);
    ``decode_window`` truncates autoencoder replay to the last W steps.
    Tenants declaring identical model spec *and* seed share one params
    object, so the fleet folds them into a shared launch group.
    """
    with open(path) as fh:
        doc = json.load(fh)
    specs, plans, params_cache = [], {}, {}
    for e in doc["tenants"]:
        name = e["name"]
        task = e.get("task", "classifier")
        layers = int(e.get("layers", 2))
        m = mcd.MCDConfig(
            p=float(e.get("p", 0.125)),
            placement=e.get("placement") or "Y" + "N" * (layers - 1),
            n_samples=int(e.get("samples", 4)),
            seed=int(e.get("seed", default_seed)))
        if task == "classifier":
            cfg = clf.ClassifierConfig(
                hidden=int(e.get("hidden", 8)), num_layers=layers,
                num_classes=int(e.get("classes", 5)),
                cell=e.get("cell", "lstm"), mcd=m)
            init = clf.init
        elif task == "autoencoder":
            cfg = ae.AutoencoderConfig(
                hidden=int(e.get("hidden", 8)), num_layers=layers,
                cell=e.get("cell", "lstm"), mcd=m,
                decode_window=e.get("decode_window"))
            init = ae.init
        else:
            raise ValueError(f"tenant {name!r}: unknown task {task!r} "
                             "(classifier | autoencoder)")
        key = (task, cfg, m.seed)
        if key not in params_cache:
            params_cache[key] = init(jax.random.key(m.seed), cfg)
        max_sessions = int(e.get("max_sessions", 4))
        eet = e.get("early_exit_threshold")
        specs.append(TenantSpec(
            name=name, cfg=cfg, params=params_cache[key],
            weight=float(e.get("weight", 1.0)),
            precision=e.get("precision"),
            backend=e.get("backend", "pallas_seq"),
            max_sessions=max_sessions,
            early_exit_threshold=None if eet is None else float(eet),
            min_samples=int(e.get("min_samples", 1))))
        plans[name] = {"streams": int(e.get("streams", max_sessions)),
                       "beats": int(e.get("beats", 2)),
                       "seed": int(e.get("seed", default_seed))}
    fleet_kw = {k: doc[k] for k in ("admit_per_tick", "aging_rounds",
                                    "max_pending") if k in doc}
    return specs, plans, fleet_kw


def run_fleet(args):
    """Serve a multi-tenant fleet declared by ``--tenants fleet.json``."""
    specs, plans, fleet_kw = load_fleet(args.tenants, args.seed)
    sink = JsonlSink(args.metrics_out) if args.metrics_out else None
    fleet = FleetEngine(specs, metrics_sink=sink, **fleet_kw)
    for g in fleet.groups.values():
        print(f"launch group {g.name}: tenants={g.tenants}")
    print(f"fleet of {len(specs)} tenant(s), "
          f"admit_per_tick={fleet.admit_per_tick or 'eager'} | "
          + " ".join(f"{s.name}[w={s.weight:g} rows={s.max_sessions} "
                     f"streams={plans[s.name]['streams']}]" for s in specs))

    # Streams regenerate deterministically from the tenant table, so a
    # resume only needs the snapshot + the same fleet.json.
    streams = {t: build_streams(p["streams"], p["beats"], p["seed"])[0]
               for t, p in plans.items()}
    planned = {t: [f"s{k}" for k in range(p["streams"])]
               for t, p in plans.items()}
    done: dict[str, set[str]] = {t: set() for t in plans}
    if args.resume:
        fleet.restore(args.snapshot_dir)
        live = fleet.active_sessions
        queued = {(t.tenant, t.sid.split("/", 1)[1])
                  for t in fleet.queue.waiting()}
        # Everything was admitted before the first snapshot, so a planned
        # sid that is neither live nor queued has already finished.
        for t in plans:
            done[t] = {s for s in planned[t]
                       if s not in live.get(t, []) and (t, s) not in queued}
        print(f"resumed fleet tick {fleet.tick}: live={live} "
              f"queued={sorted(queued)} "
              f"done={ {t: sorted(v) for t, v in done.items() if v} }")
    else:
        for t in sorted(plans):
            for k, s in enumerate(planned[t]):
                went_live = fleet.admit(t, s, priority=len(planned[t]) - k)
                print(f"admit {t}/{s}: "
                      f"{'live' if went_live is not None else 'queued'}")

    rng = np.random.default_rng(args.seed + 1)
    total = sum(len(v) for v in planned.values())
    while sum(len(v) for v in done.values()) < total:
        chunks: dict[str, dict[str, jnp.ndarray]] = {}
        for t, sids in fleet.active_sessions.items():
            store = fleet.group_of(t).engine.store
            for s in sids:
                sig = streams[t][int(s[1:])]
                pos = store.get(f"{t}/{s}").steps
                if pos >= len(sig):
                    continue
                n = args.chunk_len
                if args.ragged:
                    n = int(rng.integers(1, args.chunk_len + 1))
                chunks.setdefault(t, {})[s] = jnp.asarray(
                    sig[pos:pos + n], jnp.float32)
        results = fleet.step(chunks)
        print(f"tick {fleet.tick:3d} | " + " ".join(
            f"{t}:{len(results.get(t, {}))}r q={fleet.queue.depth_of(t)} "
            f"done={len(done[t])}/{len(planned[t])}"
            for t in sorted(plans)))
        for t, sids in list(fleet.active_sessions.items()):
            store = fleet.group_of(t).engine.store
            for s in list(sids):
                if store.get(f"{t}/{s}").steps >= len(streams[t][int(s[1:])]):
                    sess = fleet.close(t, s)
                    done[t].add(s)
                    print(f"  {t}/{s}: served {sess.steps} steps in "
                          f"{sess.chunks} chunks")
        if args.snapshot_dir and fleet.tick % args.snapshot_every == 0:
            path = fleet.snapshot(args.snapshot_dir)
            checkpoint.keep_last(args.snapshot_dir, args.snapshot_keep)
            print(f"  snapshot -> {path}")

    agg = fleet.summarize()
    for t, sub in sorted(agg.get("tenants", {}).items()):
        print(f"{t}: {sub['ticks']} served tick(s) | "
              f"p95 wait {sub['queue_wait_s_p95'] * 1e3:.2f}ms | "
              f"dropped {sub['dropped']}")
    if args.metrics_out:
        fleet.metrics_sink.close()
        print(f"tick metrics -> {args.metrics_out}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", default=None, metavar="FLEET_JSON",
                    help="multi-tenant fleet mode: serve the tenant table "
                    "in this JSON file through one FleetEngine (see "
                    "load_fleet for the schema); per-model flags below "
                    "are ignored, serving flags (--chunk-len, --ragged, "
                    "--metrics-out, --snapshot-*, --resume) apply")
    ap.add_argument("--sessions", type=int, default=4,
                    help="store capacity: concurrently-live streams")
    ap.add_argument("--overload", type=int, default=None,
                    help="total streams to serve (> --sessions exercises "
                    "the admission queue; default: --sessions)")
    ap.add_argument("--chunk-len", type=int, default=20)
    ap.add_argument("--beats", type=int, default=2,
                    help="ECG beats (T=140 each) per session stream")
    ap.add_argument("--samples", type=int, default=8, help="S MC chains")
    ap.add_argument("--backend", default="pallas_seq",
                    choices=("reference", "pallas_step", "pallas_seq"))
    ap.add_argument("--precision", default=None,
                    choices=("fp32", "bf16", "int8", "int4"),
                    help="serving precision: per-channel weight "
                    "quantization + bf16 activations (default: native "
                    "dtypes).  Snapshots record it; --resume must match.")
    ap.add_argument("--cell", default="lstm", choices=("lstm", "gru"),
                    help="recurrent unit (paper §III-A: GRU drops into the "
                    "same per-gate MCD design; h-only carried state)")
    ap.add_argument("--hidden", type=int, default=8)
    ap.add_argument("--layers", type=int, default=3)
    ap.add_argument("--placement", default="YNY")
    ap.add_argument("--p", type=float, default=0.125)
    ap.add_argument("--ragged", action="store_true",
                    help="jitter chunk lengths per session per tick")
    ap.add_argument("--capacity", default="fixed",
                    choices=("fixed", "auto", "dynamic"),
                    help="launch-shape policy: fixed=--chunk-len, "
                    "auto=adaptive ladder, dynamic=per-tick max")
    ap.add_argument("--max-pending", type=int, default=256,
                    help="admission-queue backpressure bound")
    ap.add_argument("--shards", type=int, default=0,
                    help="shard every launch over the first N devices "
                    "(batch/data parallel; 0 = no mesh.  Off-TPU, force "
                    "devices with XLA_FLAGS="
                    "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--prewarm", action="store_true",
                    help="compile every capacity rung at boot "
                    "(scheduler.prewarm) so no tick pays a first-use "
                    "compile; needs --capacity fixed or auto")
    ap.add_argument("--metrics-out", default=None,
                    help="append per-tick TickMetrics as JSON lines to "
                    "this file (JsonlSink; default: in-memory ring only)")
    ap.add_argument("--controller", action="store_true",
                    help="run the online co-design controller: calibrate "
                    "the roofline against observed ticks and reconfigure "
                    "(S chains, precision) at tick boundaries to hold the "
                    "SLO (repro.serve.controller)")
    ap.add_argument("--slo-p95-ms", type=float, default=50.0,
                    help="SLO: p95 tick latency bound in milliseconds")
    ap.add_argument("--min-tokens-per-sec", type=float, default=0.0,
                    help="SLO: minimum delivered chain-timesteps/sec (p50)")
    ap.add_argument("--min-samples", type=int, default=1,
                    help="uncertainty floor: neither the controller nor "
                    "early exit ever takes a session below this many "
                    "chains")
    ap.add_argument("--early-exit-threshold", type=float, default=None,
                    metavar="DELTA",
                    help="adaptive sampling: retire a session's surplus MC "
                    "chains once halving them would move its uncertainty "
                    "summary by at most DELTA (0.0 = only exactly "
                    "converged; default: off, every session keeps "
                    "--samples chains).  Incompatible with --shards.")
    ap.add_argument("--decisions-out", default=None,
                    help="append controller DecisionRecords as JSON lines "
                    "(default: in-memory ring only)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="durable session snapshots (crash-safe resume)")
    ap.add_argument("--snapshot-every", type=int, default=5,
                    help="snapshot cadence in ticks")
    ap.add_argument("--snapshot-keep", type=int, default=3,
                    help="snapshots retained (older ones pruned; an "
                    "unbounded history would fill the disk on exactly "
                    "the long-running streams snapshots exist for)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest snapshot in --snapshot-dir "
                    "and continue every stream where it left off")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    total = args.overload or args.sessions
    if args.resume and not args.snapshot_dir:
        ap.error("--resume requires --snapshot-dir")
    if args.early_exit_threshold is not None and args.shards:
        ap.error("--early-exit-threshold is incompatible with --shards "
                 "(sharded launches need uniform chains per session)")
    if args.tenants:
        return run_fleet(args)

    cfg = clf.ClassifierConfig(
        hidden=args.hidden, num_layers=args.layers, cell=args.cell,
        mcd=mcd.MCDConfig(p=args.p, placement=args.placement,
                          n_samples=args.samples, seed=args.seed))
    params = clf.init(jax.random.key(args.seed), cfg)
    capacity = {"fixed": args.chunk_len, "auto": "auto",
                "dynamic": None}[args.capacity]
    mesh = None
    if args.shards:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(args.shards)
        print(f"sharding launches over {args.shards} devices (data axis)")
    sink = JsonlSink(args.metrics_out) if args.metrics_out else None
    # The ladder is the operator's launch-shape budget: this launcher never
    # submits chunks longer than --chunk-len, so cap the rungs there (the
    # engine default tops at 512 — pointless compiles for this workload).
    ladder = pow2_ladder(args.chunk_len) if capacity == "auto" else None
    eng = StreamingEngine(params, cfg, backend=args.backend,
                          precision=args.precision,
                          max_sessions=args.sessions,
                          chunk_capacity=capacity, ladder=ladder,
                          max_pending=args.max_pending,
                          mesh=mesh, metrics_sink=sink,
                          early_exit_threshold=args.early_exit_threshold,
                          min_samples=min(args.min_samples, args.samples))
    if args.prewarm:
        t0 = time.perf_counter()
        caps = prewarm(eng)
        print(f"prewarmed capacities {caps} in "
              f"{time.perf_counter() - t0:.2f}s")
    ctrl = None
    if args.controller:
        from repro.serve import CoDesignController, SLOPolicy
        slo = SLOPolicy(p95_tick_s=args.slo_p95_ms / 1e3,
                        min_tokens_per_sec=args.min_tokens_per_sec,
                        min_samples=args.min_samples)
        trail = (JsonlSink(args.decisions_out) if args.decisions_out
                 else None)
        ctrl = CoDesignController(eng, slo, decision_sink=trail)
        print(f"controller on: SLO p95<={args.slo_p95_ms}ms "
              f"tokens/s>={args.min_tokens_per_sec} "
              f"S>={args.min_samples} | knobs S{list(ctrl.knobs.samples)}")

    # Streams are regenerated deterministically from their generation
    # params; the per-stream cursor lives *in* the session (steps served
    # so far), so a resumed process only needs the snapshot + those params
    # to pick up.  The params ride the snapshot — a resume with different
    # flags would otherwise silently serve different signal content.
    done: set[str] = set()
    if args.resume:
        extra = eng.restore(args.snapshot_dir)
        done = set(extra.get("done", []))
        gen = extra.get("gen")
        if gen and (gen["total"], gen["beats"]) != (total, args.beats):
            print(f"resume: adopting snapshot stream params "
                  f"total={gen['total']} beats={gen['beats']} "
                  f"(CLI values differ)")
        if gen:
            total, args.beats = int(gen["total"]), int(gen["beats"])
        print(f"resumed tick {eng.tick}: live={eng.active_sessions} "
              f"queued={eng.queued_sessions} done={sorted(done)}")
        # (--seed / --samples mismatches are already rejected by
        # eng.restore: they would change the Bayesian draw itself.)
    streams, labels = build_streams(total, args.beats, args.seed)
    if not args.resume:
        # Admit everything up front: the first --sessions go live, the
        # rest wait in the queue (earlier streams get higher priority —
        # think triage order) and go live as streams finish.
        for k in range(total):
            live = eng.admit(f"ecg-{k}", priority=total - k)
            tag = "live" if live is not None else "queued"
            print(f"admit ecg-{k}: {tag}")

    print(f"streaming {total} sessions ({args.sessions} live rows) × "
          f"{args.beats} beats (T={ecg.T_STEPS} each) | S={args.samples} "
          f"chains/session p={cfg.mcd.p} "
          f"B={mcd.placement_str(cfg.mcd.placement)} "
          f"cell={args.cell} backend={args.backend} "
          f"precision={args.precision or 'native'} "
          f"capacity={args.capacity}")

    rng = np.random.default_rng(args.seed + 1)
    while len(done) < total:
        chunks = {}
        for sid in eng.active_sessions:
            k = int(sid.split("-")[1])
            pos = eng.store.get(sid).steps
            if pos >= len(streams[k]):
                continue
            n = args.chunk_len
            if args.ragged:
                n = int(rng.integers(1, args.chunk_len + 1))
            chunks[sid] = jnp.asarray(streams[k][pos:pos + n], jnp.float32)
        results = eng.step(chunks)
        line = []
        for sid, res in sorted(results.items()):
            su = res.summary
            cls = int(np.argmax(np.asarray(su.probs)))
            line.append(f"{sid}@{res.steps_total:4d} cls={cls} "
                        f"H={float(su.predictive_entropy):5.3f} "
                        f"MI={float(su.mutual_information):6.4f}")
        m = eng.last_metrics
        stat = (f"cap={m.capacity} q={m.queue_depth} "
                f"waste={m.pad_waste:4.2f}" if m else "idle")
        if m and args.early_exit_threshold is not None:
            stat += f" chains={m.active_chains}"
            if m.reclaimed_rows:
                stat += f" -{m.reclaimed_rows}"
        print(f"tick {eng.tick:3d} [{stat}] | " + " | ".join(line))
        if ctrl is not None:
            rec = ctrl.maybe_reconfigure()
            if rec is not None:
                print(f"  controller[{rec.reason}] applied={rec.applied} "
                      f"winner={rec.winner} "
                      f"p95={rec.observed['duration_s_p95'] * 1e3:.2f}ms")
            eng = ctrl.engine       # maybe a prewarmed replacement

        for sid in list(eng.active_sessions):
            k = int(sid.split("-")[1])
            if eng.store.get(sid).steps >= len(streams[k]):
                sess = eng.close_session(sid)      # frees a row; queue drains
                done.add(sid)
                print(f"{sid}: served {sess.steps} steps in {sess.chunks} "
                      f"chunks (beat labels {labels[k]})")
        if args.snapshot_dir and eng.tick % args.snapshot_every == 0:
            path = eng.snapshot(args.snapshot_dir, extra={
                "done": sorted(done),
                "gen": {"total": total, "beats": args.beats,
                        "seed": args.seed}})
            checkpoint.keep_last(args.snapshot_dir, args.snapshot_keep)
            print(f"  snapshot -> {path}")

    if eng.metrics:
        agg = summarize(eng.metrics)
        print(f"served {sum(m.live_steps for m in eng.metrics)} signal "
              f"steps over {agg['ticks']} ticks | "
              f"capacities used {agg['capacities_used']} | "
              f"pad waste {agg['pad_waste']:4.2f}")
        if args.early_exit_threshold is not None:
            print(f"early exit: {agg['reclaimed_rows']} chain(s) retired | "
                  f"mean active chains {agg['active_chains_mean']:.1f}")
    if ctrl is not None:
        n_applied = sum(1 for r in ctrl.decisions if r.applied)
        print(f"controller: {len(ctrl.decisions)} decision(s), "
              f"{n_applied} applied | final config {ctrl.config}")
        if args.decisions_out:
            ctrl.decision_sink.close()
            print(f"decision trail -> {args.decisions_out}")
    if args.metrics_out:
        eng.metrics_sink.close()
        print(f"tick metrics -> {args.metrics_out}")


if __name__ == "__main__":
    main()

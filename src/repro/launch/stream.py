"""Streaming session-serving launcher: continuous ECG monitoring.

Opens N concurrent sessions, each an unbounded synthetic-ECG signal
(concatenated ECG5000-compatible beats), and decodes them chunk-by-chunk
through the sequence-fused Pallas kernel with carried per-session state —
per-chunk Bayesian uncertainty over the signal-so-far.

Usage:
  PYTHONPATH=src python -m repro.launch.stream --sessions 4 --chunk-len 20 \
      --samples 8 --beats 2 --backend pallas_seq
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import classifier as clf, mcd
from repro.data import ecg
from repro.serve import StreamingEngine


def build_streams(n_sessions: int, beats: int, seed: int):
    """Per-session continuous signals: `beats` ECG beats back to back."""
    _, _, ex, ey = ecg.make_ecg5000(seed)
    rng = np.random.default_rng(seed)
    streams, labels = [], []
    for _ in range(n_sessions):
        idx = rng.integers(0, len(ex), size=beats)
        streams.append(np.concatenate([ex[i] for i in idx], axis=0))
        labels.append([int(ey[i]) for i in idx])
    return streams, labels


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=4)
    ap.add_argument("--chunk-len", type=int, default=20)
    ap.add_argument("--beats", type=int, default=2,
                    help="ECG beats (T=140 each) per session stream")
    ap.add_argument("--samples", type=int, default=8, help="S MC chains")
    ap.add_argument("--backend", default="pallas_seq",
                    choices=("reference", "pallas_step", "pallas_seq"))
    ap.add_argument("--hidden", type=int, default=8)
    ap.add_argument("--layers", type=int, default=3)
    ap.add_argument("--placement", default="YNY")
    ap.add_argument("--p", type=float, default=0.125)
    ap.add_argument("--ragged", action="store_true",
                    help="jitter chunk lengths per session per tick")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = clf.ClassifierConfig(
        hidden=args.hidden, num_layers=args.layers,
        mcd=mcd.MCDConfig(p=args.p, placement=args.placement,
                          n_samples=args.samples, seed=args.seed))
    params = clf.init(jax.random.key(args.seed), cfg)
    # Fixed-shape mode: ragged ticks and draining sessions all reuse one
    # compiled graph (chunks never exceed --chunk-len by construction).
    eng = StreamingEngine(params, cfg, backend=args.backend,
                          max_sessions=args.sessions,
                          chunk_capacity=args.chunk_len)

    streams, labels = build_streams(args.sessions, args.beats, args.seed)
    for k in range(args.sessions):
        eng.open_session(f"ecg-{k}")
    print(f"streaming {args.sessions} sessions × {args.beats} beats "
          f"(T={ecg.T_STEPS} each) | S={args.samples} chains/session "
          f"p={cfg.mcd.p} B={mcd.placement_str(cfg.mcd.placement)} "
          f"backend={args.backend}")

    rng = np.random.default_rng(args.seed + 1)
    pos = [0] * args.sessions
    tick = 0
    while any(pos[k] < len(streams[k]) for k in range(args.sessions)):
        chunks = {}
        for k in range(args.sessions):
            if pos[k] >= len(streams[k]):
                continue
            n = args.chunk_len
            if args.ragged:
                n = int(rng.integers(1, args.chunk_len + 1))
            chunks[f"ecg-{k}"] = jnp.asarray(
                streams[k][pos[k]:pos[k] + n], jnp.float32)
            pos[k] += n
        results = eng.step(chunks)
        line = []
        for sid, res in results.items():
            su = res.summary
            cls = int(np.argmax(np.asarray(su.probs)))
            line.append(f"{sid}@{res.steps_total:4d} cls={cls} "
                        f"H={float(su.predictive_entropy):5.3f} "
                        f"MI={float(su.mutual_information):6.4f}")
        print(f"tick {tick:3d} | " + " | ".join(line))
        tick += 1

    for k in range(args.sessions):
        sess = eng.close_session(f"ecg-{k}")
        print(f"ecg-{k}: served {sess.steps} steps in {sess.chunks} chunks "
              f"(beat labels {labels[k]})")


if __name__ == "__main__":
    main()

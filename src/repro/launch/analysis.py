"""Roofline analysis from compiled artifacts (no hardware required).

Terms (per device, seconds) — v5e constants:
  compute    = HLO_FLOPs / 197e12          (bf16 MXU peak)
  memory     = HLO_bytes / 819e9           (HBM bandwidth)
  collective = collective_bytes / 50e9     (ICI per-link)

``cost_analysis()`` on the SPMD-partitioned module reports *per-device*
flops/bytes.  collective_bytes is parsed from the partitioned HLO text:
per-op output bytes × an op factor (all-reduce counts 2× for the
reduce+broadcast ring phases; others 1×).
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_COLL_RE = re.compile(
    r"=\s*([a-z0-9_]+)\[([0-9,]*)\]"                  # dtype[shape]
    r"(?:\{[^}]*\})?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_TUPLE_COLL_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_FACTORS = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
            "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(dtype: str, dims: str) -> float:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return float(n * nb)


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-op-kind collective traffic (bytes, per device) from HLO text."""
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        out[kind] = out.get(kind, 0.0) + _shape_bytes(dtype, dims)
    for m in _TUPLE_COLL_RE.finditer(hlo_text):
        tup, kind = m.group(1), m.group(2)
        total = 0.0
        for part in re.finditer(r"([a-z0-9_]+)\[([0-9,]*)\]", tup):
            total += _shape_bytes(part.group(1), part.group(2))
        out[kind] = out.get(kind, 0.0) + total / 2.0  # tuple lists in+out
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device
    bytes_hbm: float             # per device
    bytes_collective: float      # per device (factor-weighted)
    coll_by_kind: dict
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    memory_per_device: dict      # from memory_analysis()

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def roofline_fraction(self) -> float:
        """compute term / binding term — 1.0 means compute-bound at peak."""
        return self.t_compute / max(self.t_bound, 1e-30)


def analyse(compiled, hlo_text: str | None = None) -> Roofline:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    bytes_hbm = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    weighted = sum(_FACTORS[k] * v for k, v in coll.items())
    t_c = flops / PEAK_FLOPS
    t_m = bytes_hbm / HBM_BW
    t_x = weighted / ICI_BW
    bott = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
    return Roofline(flops=flops, bytes_hbm=bytes_hbm,
                    bytes_collective=weighted, coll_by_kind=coll,
                    t_compute=t_c, t_memory=t_m, t_collective=t_x,
                    bottleneck=bott, memory_per_device=mem)


def model_flops(cfg, cell, chips: int) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE), per device.

    N counts *active* parameters (MoE: top-k experts + shared); D = tokens
    processed by the step (train: batch·seq fwd+bwd = 6ND; prefill: 2ND;
    decode: 2N per token · batch).
    """
    n_active = active_params(cfg)
    if cell.kind == "train":
        d = cell.global_batch * cell.seq_len
        total = 6.0 * n_active * d
    elif cell.kind == "prefill":
        d = cell.global_batch * cell.seq_len
        total = 2.0 * n_active * d
    else:  # decode: one token per sequence
        total = 2.0 * n_active * cell.global_batch
    return total / chips


def active_params(cfg) -> float:
    """Active parameter count from the architecture config (no allocation)."""
    from repro.models import backbone as bb
    from repro.models import mamba2 as m2
    D = cfg.d_model
    hd = cfg.head_dim
    n = cfg.vocab_size * D * (1 if cfg.tie_embeddings else 2)
    def block_params(kind: str) -> float:
        mixer, cross, ffn = bb._parse(kind)
        p = 0.0
        if mixer in ("attn", "enc_attn", "dec_attn"):
            p += D * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
        elif mixer == "mla":
            c = cfg.mla
            p += D * cfg.num_heads * (c.nope_head_dim + c.rope_head_dim)
            p += D * (c.kv_lora_rank + c.rope_head_dim)
            p += c.kv_lora_rank * cfg.num_heads * (c.nope_head_dim + c.v_head_dim)
            p += cfg.num_heads * c.v_head_dim * D
        elif mixer == "mamba":
            d_inner, n_heads, conv_dim = m2.dims(D, cfg.ssm)
            d_in_proj = 2 * d_inner + 2 * cfg.ssm.n_groups * cfg.ssm.d_state + n_heads
            p += D * d_in_proj + d_inner * D + conv_dim * cfg.ssm.d_conv
        if cross:
            p += D * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
        if ffn == "mlp":
            p += 3 * D * cfg.d_ff
        elif ffn == "moe":
            mo = cfg.moe
            p += 3 * D * mo.d_ff_expert * (mo.top_k + mo.num_shared)
            p += D * mo.num_experts        # router
        return p
    for stage in tuple(cfg.stages) + tuple(cfg.encoder_stages):
        for kind in stage.pattern:
            n += stage.repeat * block_params(kind)
    return n

"""Training launcher.

Two modes:
  * --task ecg-ae / ecg-clf — the paper's models on the ECG5000-compatible
    dataset (paper §V hyperparameters; runs on CPU).
  * --task lm --arch <id>   — a zoo architecture on synthetic token streams
    (reduced configs on CPU; full configs are for the production mesh).

Fault tolerance: --ckpt-dir enables atomic checkpoints + auto-resume; kill
the process at any step and rerun the same command to continue.

Usage:
  PYTHONPATH=src python -m repro.launch.train --task ecg-clf --steps 200
  PYTHONPATH=src python -m repro.launch.train --task lm --arch llama3-8b \
      --reduced --steps 20 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALIASES, get_config
from repro.core import autoencoder as ae
from repro.core import classifier as clf
from repro.core import mcd
from repro.core import prng
from repro.data import ecg
from repro.models import backbone
from repro.models.layers import Ctx
from repro.train import optimizer, trainer


def ecg_batches(task: str, batch_size: int, seed: int, epochs: int = 10_000):
    tx, ty, _, _ = ecg.make_ecg5000(seed)
    if task == "ecg-ae":        # anomaly detection: train on normal only
        tx, ty = tx[ty == 0], ty[ty == 0]
    pipe = ecg.Pipeline(tx, ty, batch_size=batch_size, seed=seed)
    for e in range(epochs):
        yield from pipe.epoch(e)


def make_ecg_loss(task: str, cfg):
    if task == "ecg-ae":
        def loss(params, batch, step):
            x, _ = batch
            rows = jnp.arange(x.shape[0], dtype=jnp.uint32)
            c = cfg.mcd.replace(seed=int(cfg.mcd.seed))
            mean, log_var = ae.apply(params, x, rows,
                                     cfg.replace(mcd=c) if False else cfg)
            return jnp.mean(ae.gaussian_nll(mean, log_var, x)), {}
        return loss

    def loss(params, batch, step):
        x, y = batch
        rows = jnp.arange(x.shape[0], dtype=jnp.uint32)
        logits = clf.apply(params, x, rows, cfg)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
        return jnp.mean(nll), {}
    return loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", choices=("ecg-ae", "ecg-clf", "lm"),
                    default="ecg-clf")
    ap.add_argument("--arch", choices=sorted(ALIASES))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=64)      # paper §V
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--placement", default=None, help="MCD B-string")
    ap.add_argument("--p", type=float, default=0.125)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", choices=("none", "bf16", "int8"),
                    default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    tcfg = trainer.TrainConfig(
        adamw=optimizer.AdamWConfig(lr=args.lr),   # clip 3.0 / wd 1e-4 per paper
        microbatches=args.microbatches,
        grad_compression=args.grad_compression,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50)

    if args.task in ("ecg-ae", "ecg-clf"):
        mcfg = mcd.MCDConfig(
            p=args.p,
            placement=args.placement or ("YNYN" if args.task == "ecg-ae" else "YNY"),
            n_samples=30, seed=args.seed)
        if args.task == "ecg-ae":
            cfg = ae.AutoencoderConfig(hidden=args.hidden,
                                       num_layers=args.layers, mcd=mcfg)
            params = ae.init(jax.random.key(args.seed), cfg)
        else:
            cfg = clf.ClassifierConfig(hidden=8, num_layers=3, mcd=mcfg)
            params = clf.init(jax.random.key(args.seed), cfg)
        loss = make_ecg_loss(args.task, cfg)
        batches = (jax.tree.map(jnp.asarray, b)
                   for b in ecg_batches(args.task, args.batch, args.seed))
    else:
        cfg = get_config(args.arch or "llama3-8b", reduced=args.reduced)
        params = backbone.init_params(jax.random.key(args.seed), cfg,
                                      dtype=jnp.float32)

        def loss(params, batch, step):
            toks, targets = batch
            ctx = Ctx(rows=jnp.arange(toks.shape[0], dtype=jnp.uint32),
                      seed=prng.fold_ids(cfg.mcd.seed, step), cfg=cfg.mcd)
            return backbone.loss_fn(params, cfg, toks, targets, ctx)

        def lm_batches():
            rng = np.random.default_rng(args.seed)
            while True:
                t = rng.integers(0, cfg.vocab_size,
                                 (args.batch, args.seq + 1), dtype=np.int32)
                # learnable structure: next token = (token + 1) % vocab on half
                t[:, 1::2] = (t[:, 0::2] + 1) % cfg.vocab_size
                yield jnp.asarray(t[:, :-1]), jnp.asarray(t[:, 1:])
        batches = lm_batches()

    tr = trainer.Trainer(loss, params, tcfg)
    hist = tr.run(batches, args.steps)
    if hist:
        print(f"final loss {hist[-1]['loss']:.4f} after {tr.step} steps; "
              f"stragglers flagged: {len(tr.straggler_events)}")


if __name__ == "__main__":
    main()

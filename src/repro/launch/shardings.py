"""Sharding rules: PartitionSpec pytrees mirroring the model structures.

The specs are built *structurally* (mirror functions for each param group)
rather than by path-regex — every leaf's spec is written next to the shape it
shards, with divisibility guards, so adding an arch can't silently fall back
to replication.

Policy knobs (the hardware half of the paper's DSE space — the TPU analogue
of reuse factors R_x/R_h/R_d):
  * tp           — tensor-parallel axis name ("model")
  * fsdp         — shard params+grads over the data axes too (weight
                   all-gather per layer; required for ≥100B-param train)
  * zero         — shard optimizer moments over the data axes (ZeRO-1)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import backbone, layers, mamba2, mla, moe
from repro.models.config import ArchConfig, Stage


@dataclasses.dataclass(frozen=True)
class Policy:
    axes: dict                      # mesh axis name → size
    dp: tuple[str, ...]             # data-parallel axes (("pod","data") or ("data",))
    tp: str = "model"
    fsdp: bool = False
    zero: bool = True

    def dp_size(self) -> int:
        out = 1
        for a in self.dp:
            out *= self.axes[a]
        return out

    def tp_size(self) -> int:
        return self.axes.get(self.tp, 1)

    def tp_if(self, dim: int):
        """tp axis if the dim is divisible, else replicate."""
        return self.tp if dim % max(self.tp_size(), 1) == 0 else None

    def dp_if(self, dim: int):
        return self.dp if dim % max(self.dp_size(), 1) == 0 else None

    def fsdp_if(self, dim: int):
        return self.dp if (self.fsdp and dim % max(self.dp_size(), 1) == 0) else None


# ---------------------------------------------------------------------------
# Parameter specs (mirror init_* structures)
# ---------------------------------------------------------------------------

def spec_attn(cfg: ArchConfig, po: Policy) -> layers.AttnParams:
    H, KV, hd, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    return layers.AttnParams(
        wq=P(po.fsdp_if(D), po.tp_if(H), None),
        wk=P(po.fsdp_if(D), po.tp_if(KV), None),
        wv=P(po.fsdp_if(D), po.tp_if(KV), None),
        wo=P(po.tp_if(H), None, po.fsdp_if(D)),
        q_scale=P() if cfg.qk_norm else None,
        k_scale=P() if cfg.qk_norm else None,
        norm=P())


def spec_mlp(cfg: ArchConfig, po: Policy, d_ff: int) -> layers.MLPParams:
    D = cfg.d_model
    return layers.MLPParams(
        wi=P(po.fsdp_if(D), None, po.tp_if(d_ff)),
        wo=P(po.tp_if(d_ff), po.fsdp_if(D)),
        norm=P())


def spec_moe(cfg: ArchConfig, po: Policy) -> moe.MoEParams:
    D, E = cfg.d_model, cfg.moe.num_experts
    dffe = cfg.moe.d_ff_expert
    shared = None
    if cfg.moe.num_shared:
        shared = spec_mlp(cfg, po, cfg.moe.num_shared * dffe)
    return moe.MoEParams(
        router=P(None, None),
        wi=P(po.tp_if(E), po.fsdp_if(D), None, None),
        wo=P(po.tp_if(E), None, po.fsdp_if(D)),
        shared=shared,
        norm=P())


def spec_mla(cfg: ArchConfig, po: Policy) -> mla.MLAParams:
    H, D = cfg.num_heads, cfg.d_model
    return mla.MLAParams(
        norm=P(),
        wq=P(po.fsdp_if(D), po.tp_if(H), None),
        w_dkv=P(po.fsdp_if(D), None),
        kv_norm=P(),
        w_krope=P(None, None),
        w_uk=P(None, po.tp_if(H), None),
        w_uv=P(None, po.tp_if(H), None),
        wo=P(po.tp_if(H), None, po.fsdp_if(D)))


def spec_mamba(cfg: ArchConfig, po: Policy) -> mamba2.MambaParams:
    D = cfg.d_model
    d_inner, n_heads, conv_dim = mamba2.dims(D, cfg.ssm)
    d_in_proj = 2 * d_inner + 2 * cfg.ssm.n_groups * cfg.ssm.d_state + n_heads
    return mamba2.MambaParams(
        norm=P(),
        in_proj=P(po.fsdp_if(D), None),
        conv_w=P(po.tp_if(conv_dim), None),
        conv_b=P(po.tp_if(conv_dim)),
        a_log=P(), d_skip=P(), dt_bias=P(),
        out_norm=P(po.tp_if(d_inner)),
        out_proj=P(po.tp_if(d_inner), po.fsdp_if(D)))


def spec_block(kind: str, cfg: ArchConfig, po: Policy) -> dict:
    mixer, has_cross, ffn = backbone._parse(kind)
    out = {}
    if mixer in ("attn", "enc_attn", "dec_attn"):
        out["mixer"] = spec_attn(cfg, po)
    elif mixer == "mla":
        out["mixer"] = spec_mla(cfg, po)
    elif mixer == "mamba":
        out["mixer"] = spec_mamba(cfg, po)
    if has_cross:
        out["cross"] = spec_attn(cfg, po)
    if ffn == "mlp":
        out["ffn"] = spec_mlp(cfg, po, cfg.d_ff)
    elif ffn == "moe":
        out["ffn"] = spec_moe(cfg, po)
    return out


def _prepend(spec):
    """Stacked stage params carry a leading repeat dim → prepend None."""
    return jax.tree.map(lambda s: P(None, *s), spec,
                        is_leaf=lambda x: isinstance(x, P))


def spec_stage(stage: Stage, cfg: ArchConfig, po: Policy):
    return tuple(_prepend(spec_block(kind, cfg, po)) for kind in stage.pattern)


def param_specs(cfg: ArchConfig, po: Policy):
    V, D = cfg.vocab_size, cfg.d_model
    specs = {
        "embed": layers.EmbedParams(
            table=P(po.tp_if(V), po.fsdp_if(D)),
            head=None if cfg.tie_embeddings else P(po.fsdp_if(D), po.tp_if(V)),
            final_norm=P()),
        "stages": [spec_stage(s, cfg, po) for s in cfg.stages],
    }
    if cfg.encoder_stages:
        specs["encoder_stages"] = [spec_stage(s, cfg, po)
                                   for s in cfg.encoder_stages]
        specs["encoder_norm"] = P()
    return specs


def optstate_specs(pspecs, po: Policy, param_shapes):
    """ZeRO-1: moments inherit the param spec with the data axes folded into
    the first still-replicated, divisible dim."""
    def fold(spec, shape):
        if not po.zero or po.fsdp:          # fsdp already uses the dp axes
            return spec
        parts = list(spec)
        while len(parts) < len(shape.shape):
            parts.append(None)
        for i, (axis, dim) in enumerate(zip(parts, shape.shape)):
            if axis is None and dim % max(po.dp_size(), 1) == 0 and dim > 1:
                parts[i] = po.dp
                return P(*parts)
        return spec

    from repro.train.optimizer import AdamWState
    m = jax.tree.map(fold, pspecs, param_shapes,
                     is_leaf=lambda x: isinstance(x, P))
    return AdamWState(step=P(), m=m, v=m)


# ---------------------------------------------------------------------------
# Input / decode-state specs
# ---------------------------------------------------------------------------

def batch_spec(batch: int, po: Policy):
    """Shard the batch dim over dp axes when divisible (long_500k: batch 1)."""
    return po.dp if batch % max(po.dp_size(), 1) == 0 else None


def cache_specs(cfg: ArchConfig, po: Policy, batch: int,
                kv_quant: bool = False):
    """PartitionSpecs mirroring backbone.init_decode_state structure."""
    b = batch_spec(batch, po)

    def attn_cache():
        # [repeat, B, Smax, KV, hd]: prefer head sharding; else shard the
        # sequence (flash-decoding style — partial softmax + all-reduce).
        if cfg.num_kv_heads % max(po.tp_size(), 1) == 0:
            kv = P(None, b, None, po.tp, None)
            sc = P(None, b, None, po.tp)
        elif b is None:
            kv = P(None, None, po.dp + (po.tp,), None, None)
            sc = P(None, None, po.dp + (po.tp,), None)
        else:
            kv = P(None, b, po.tp, None, None)
            sc = P(None, b, po.tp, None)
        if kv_quant:
            return (kv, sc, kv, sc)
        return (kv, kv)

    def mla_cache():
        return mla.MLACache(c_kv=P(None, b, None, None),
                            k_rope=P(None, b, None, None))

    def mamba_cache():
        d_inner, n_heads, conv_dim = mamba2.dims(cfg.d_model, cfg.ssm)
        return mamba2.MambaState(
            ssm=P(None, b, po.tp_if(n_heads), None, None),
            conv=P(None, b, None, po.tp_if(conv_dim)))

    caches, crosses = [], []
    any_cross = False
    for st in cfg.stages:
        per_c, per_x = [], []
        for kind in st.pattern:
            mixer, has_cross, _ = backbone._parse(kind)
            if mixer in ("attn", "dec_attn"):
                per_c.append(attn_cache())
            elif mixer == "mla":
                per_c.append(mla_cache())
            elif mixer == "mamba":
                per_c.append(mamba_cache())
            else:
                per_c.append(None)
            if has_cross:
                any_cross = True
                kv = P(None, b, None, po.tp_if(cfg.num_kv_heads), None)
                per_x.append((kv, kv))
            else:
                per_x.append(None)
        caches.append(tuple(per_c))
        crosses.append(tuple(per_x))
    return backbone.DecodeState(pos=P(), caches=caches,
                                cross=crosses if any_cross else None)

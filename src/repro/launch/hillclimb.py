import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: compile ONE probe (or the full composition) under
named optimization variants and print the roofline deltas — the fast
hypothesis → change → measure loop.

  PYTHONPATH=src python -m repro.launch.hillclimb --arch olmoe-1b-7b \
      --shape train_4k --variants base,moe_shard
"""

import argparse
import contextlib
import json

import jax

from repro.configs import ALIASES, get_config
from repro.kernels import compat
from repro.launch import analysis, mesh as mesh_lib, specs
from repro.models import backbone, layers, moe
from repro.models.config import SHAPES


@contextlib.contextmanager
def variant_ctx(names: set[str], mesh):
    """Compose optimization contexts by name."""
    dp = mesh_lib.dp_axes(mesh)
    with contextlib.ExitStack() as stack:
        if "moe_shard" in names:
            stack.enter_context(moe.moe_sharding(expert_axis="model",
                                                 token_axes=dp))
        if "moe_group" in names:
            dp_size = 1
            for a in dp:
                dp_size *= mesh_lib.axis_sizes(mesh)[a]
            stack.enter_context(moe.moe_sharding(
                expert_axis="model", token_axes=dp, groups=dp_size))
        if "seqpar" in names:
            stack.enter_context(backbone.activation_sharding(
                spec=(dp, "model", None)))
        if "flash_block" in names:
            stack.enter_context(layers.attention_override(
                q_block=256, kv_block=512))
        yield


def measure(arch: str, shape: str, variants: set[str], *,
            probe_filter: str | None = None, multi_pod: bool = False):
    import dataclasses
    cfg = get_config(arch)
    for v in variants:
        if v.startswith("chunk") and cfg.ssm is not None:
            cfg = cfg.replace(ssm=dataclasses.replace(cfg.ssm,
                                                      chunk=int(v[5:])))
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    cell = SHAPES[shape]
    tot = {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
    details = []
    attn_probe_cfg = specs._attn_blocks_for(cell.seq_len)
    if "flash_block" in variants:
        attn_probe_cfg = dict(q_block=max(256, cell.seq_len // 16),
                              kv_block=max(512, cell.seq_len // 16),
                              unroll=True)
    with layers.attention_override(**attn_probe_cfg):
        with variant_ctx(variants - {"flash_block"}, mesh):
            for pr in specs.probe_jobs(cfg, shape, mesh,
                                       kv_quant="kv8" in variants):
                if probe_filter and probe_filter not in pr.name:
                    continue
                with compat.set_mesh(mesh):
                    compiled = jax.jit(
                        pr.fn, in_shardings=pr.in_shardings).lower(
                            *pr.args).compile()
                    roof = analysis.analyse(compiled)
                tot["flops"] += roof.flops * pr.multiplier
                tot["bytes"] += roof.bytes_hbm * pr.multiplier
                tot["coll"] += roof.bytes_collective * pr.multiplier
                details.append((pr.name, pr.multiplier, roof))
    t_c = tot["flops"] / analysis.PEAK_FLOPS
    t_m = tot["bytes"] / analysis.HBM_BW
    t_x = tot["coll"] / analysis.ICI_BW
    return {"t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
            "t_bound": max(t_c, t_m, t_x),
            "bottleneck": max((t_c, "compute"), (t_m, "memory"),
                              (t_x, "collective"))[1],
            "details": details, **tot}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ALIASES))
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--variants", default="base",
                    help="comma list of variant sets separated by ';' "
                         "e.g. 'base;moe_shard;moe_shard+seqpar'")
    ap.add_argument("--probe", default=None, help="probe-name filter")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    records = []
    for vs in args.variants.split(";"):
        names = set() if vs == "base" else set(vs.split("+"))
        r = measure(args.arch, args.shape, names, probe_filter=args.probe)
        print(f"[{vs:24s}] t_c={r['t_compute']:.3f}s t_m={r['t_memory']:.3f}s "
              f"t_x={r['t_collective']:.3f}s bound={r['bottleneck']} "
              f"t_bound={r['t_bound']:.3f}s", flush=True)
        for name, mult, roof in r["details"]:
            print(f"    {name:26s} x{mult:3d} fl={roof.flops:.2e} "
                  f"by={roof.bytes_hbm:.2e} cl={roof.bytes_collective:.2e}")
        records.append({"arch": args.arch, "shape": args.shape, "variant": vs,
                        **{k: r[k] for k in ("t_compute", "t_memory",
                                             "t_collective", "t_bound",
                                             "bottleneck", "flops", "bytes",
                                             "coll")}})
    if args.out:
        with open(args.out, "a") as f:
            for rec in records:
                f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()

"""Sharded execution of the recurrent stack: the multi-device data plane.

The paper's FPGA scales by spending parallelism knobs (reuse factors
R_x/R_h); the TPU analogue is *device* parallelism, and this module is
where the recurrent stack learns it.  ``rnn.run_stack(..., mesh=...)``
lands here and picks one of two strategies over a ``(data, model)`` mesh
(`repro.launch.mesh` builds the production shapes):

* ``"data"`` — the serving hot path.  Batch rows (sessions × MC chains)
  partition over the ``data`` axes via ``shard_map``; every device runs
  the *unmodified* sequence-fused Pallas kernel on its batch shard with
  the weights replicated.  This is Fan et al.'s trick of replicating
  Monte-Carlo samples across compute units, applied at mesh scale: MC
  chains are batch rows here, so sharding the batch *is* sharding the
  chains.
* ``"gspmd"`` — the wide-H fallback.  docs/kernels.md explains why a
  hidden-tile grid axis cannot live inside the sequence kernel (step t
  needs all H columns of h_{t-1}); when H outgrows one core's VMEM the
  stack instead runs the ``"reference"`` jnp scan under GSPMD with the
  weights' H *output* dim sharded over the ``model`` axis (contractions
  stay unsplit — XLA all-gathers the small per-step ``h``, never splits a
  reduction) and the batch over ``data``.

Determinism contract (what makes sharded == unsharded **bit-identical**
at any device count, pinned by ``tests/test_rnn_sharding.py``):

1. Masks are pure functions of global ``(seed, rows)`` coordinates
   (docs/architecture.md).  ``rows`` ride the batch axis into each shard,
   so a shard draws exactly the bits the unsharded run draws for those
   rows — there is no per-device RNG anywhere.
2. The sharded path always runs the **lengths-pinned graph family**:
   when the caller passes no ``lengths`` it synthesizes full-T lengths.
   That family is bit-identical across launch sizes, splits and backends
   (the freeze-select pins XLA fusion — docs/kernels.md), so slicing the
   batch across devices cannot change any row's numerics.
3. Batch padding (to a device-count multiple) only ever appends rows,
   whose outputs are sliced off; per-row math never sees its neighbours.

Policy knobs live in :class:`StackShardingPolicy`; ``"auto"`` picks
``"data"`` for the Pallas backends until H exceeds the per-core VMEM
budget, then falls back to ``"gspmd"`` (and always uses ``"gspmd"`` for
the reference backend, which is GSPMD-native).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import mcd, rnn
from repro.kernels.compat import shard_map
from repro.launch import mesh as mesh_lib

#: H above which ``"auto"`` stops replicating the sequence kernel's weights.
#: docs/kernels.md: resident weights ≈ 2·G·H·(I+H) bytes in bf16 against a
#: ~16 MB VMEM core — a few hundred to ~1k columns; beyond that the kernel's
#: whole-H-per-program design is the wrong tool and GSPMD H-tiling takes over.
WIDE_H_DEFAULT = 1024

STRATEGIES = ("auto", "data", "gspmd")


@dataclasses.dataclass(frozen=True)
class StackShardingPolicy:
    """How the recurrent stack maps onto a mesh (the sharding half of DSE).

    Attributes:
      data: mesh axes carrying batch rows (``("pod", "data")`` on multi-pod
        meshes — only axes actually present on the mesh are used).
      model: mesh axis carrying the hidden width in the GSPMD fallback.
      strategy: ``"data"`` (shard_map batch partition over the Pallas
        kernels), ``"gspmd"`` (reference scan, H over ``model``), or
        ``"auto"`` (data until ``wide_h``, gspmd beyond — and always gspmd
        for the reference backend).
      wide_h: the VMEM-residency threshold ``"auto"`` switches at.
    """

    data: tuple[str, ...] = ("pod", "data")
    model: str = "model"
    strategy: str = "auto"
    wide_h: int = WIDE_H_DEFAULT

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"strategy must be one of {STRATEGIES}, "
                             f"got {self.strategy!r}")


DEFAULT_POLICY = StackShardingPolicy()


def data_axes(mesh, policy: StackShardingPolicy = DEFAULT_POLICY):
    """The policy's data axes actually present on this mesh, mesh-ordered.

    Returns ``None`` (the replicated PartitionSpec entry) when the mesh has
    no data axis at all, so the specs below degrade gracefully.
    """
    axes = tuple(a for a in mesh.axis_names if a in policy.data)
    return axes or None


def data_size(mesh, policy: StackShardingPolicy = DEFAULT_POLICY) -> int:
    sizes = mesh_lib.axis_sizes(mesh)
    out = 1
    for a in (data_axes(mesh, policy) or ()):
        out *= sizes[a]
    return out


def model_size(mesh, policy: StackShardingPolicy = DEFAULT_POLICY) -> int:
    return mesh_lib.axis_sizes(mesh).get(policy.model, 1)


def resolve_strategy(mesh, policy: StackShardingPolicy, backend: str,
                     hiddens) -> str:
    """Pick the execution strategy for this (mesh, backend, stack) triple."""
    if policy.strategy != "auto":
        return policy.strategy
    if backend == "reference":
        return "gspmd"              # the jnp scan is GSPMD-native
    if max(hiddens) > policy.wide_h and model_size(mesh, policy) > 1:
        return "gspmd"              # H-tiling cannot live inside the kernel
    return "data"


# ---------------------------------------------------------------------------
# PartitionSpecs mirroring the stack structures (the rnn analogue of
# launch/shardings.py's structural spec builders)
# ---------------------------------------------------------------------------

def _param_specs(cell: str, hiddens, mesh,
                 policy: StackShardingPolicy, strategy: str):
    """The one place the H-sharding rule lives (both entry points below
    and the jitted gspmd factory call this)."""
    from repro.core import cells
    cls = cells.GRUParams if cell == "gru" else cells.LSTMParams
    tp = policy.model if policy.model in mesh.axis_names else None
    ms = model_size(mesh, policy)

    def out_dim(h):
        if strategy != "gspmd" or tp is None or h % max(ms, 1) or ms <= 1:
            return None
        return tp

    return [cls(wx=P(None, None, out_dim(h)),
                wh=P(None, None, out_dim(h)),
                b=P(None, out_dim(h))) for h in hiddens]


def stack_param_specs(params, mesh, policy: StackShardingPolicy = DEFAULT_POLICY,
                      *, strategy: str = "data"):
    """Per-layer PartitionSpecs for core-layout stack weights.

    Core layout (``cells.LSTMParams``/``GRUParams``): ``wx [G, I, H]``,
    ``wh [G, H, H]``, ``b [G, H]``.  The ``"data"`` strategy replicates
    weights (each shard runs the full kernel); ``"gspmd"`` shards the H
    *output* dim over ``model`` where divisible — never a contraction dim,
    so no reduction is ever split (the bit-identity argument above).
    """
    from repro.core import cells
    cell = "gru" if isinstance(params[0], cells.GRUParams) else "lstm"
    return _param_specs(cell, tuple(lp.wh.shape[-1] for lp in params),
                        mesh, policy, strategy)


def carry_specs(n_layers: int, mesh,
                policy: StackShardingPolicy = DEFAULT_POLICY,
                *, cell: str = "lstm"):
    """Per-layer state specs: ``[B, H]`` parts shard batch over data axes.

    The pytree arity follows the cell — ``(h, c)`` for LSTM, ``(h,)`` for
    GRU — exactly what ``run_stack(return_all_states=True)`` hands back
    (and what the execution factories below use for carries in and out).
    """
    dp = data_axes(mesh, policy)
    parts = 1 if cell == "gru" else 2
    return [tuple(P(dp, None) for _ in range(parts))
            for _ in range(n_layers)]


def batch_specs(mesh, policy: StackShardingPolicy = DEFAULT_POLICY) -> dict:
    """Specs for the batch-aligned operands: x_seq, mask rows, lengths.

    ``rows`` shard with the batch: each device receives the *global* mask
    coordinates of its rows, which is the whole determinism story — masks
    are functions of coordinates, not of device ids.
    """
    dp = data_axes(mesh, policy)
    return {"x_seq": P(dp, None, None), "rows": P(dp), "lengths": P(dp)}


# ---------------------------------------------------------------------------
# Entry point (run_stack's mesh= dispatch lands here)
# ---------------------------------------------------------------------------

def run_stack_sharded(params, x_seq, masks, p, *, mesh,
                      policy: StackShardingPolicy | None = None,
                      backend: str = "pallas_seq", return_sequence: bool = True,
                      rows=None, seed=0, layer_offset: int = 0,
                      interpret: bool | None = None, initial_state=None,
                      lengths=None, return_all_states: bool = False,
                      cell: str = "lstm", precision: str | None = None):
    """Run the stack sharded over ``mesh`` — same contract as ``run_stack``.

    Callers use ``rnn.run_stack(..., mesh=..., policy=...)``; this is the
    implementation.  The sharded path always runs the lengths-pinned graph
    family (synthesizing full-T lengths when the caller passes none), so
    its output is bit-identical to the unsharded lengths-enabled run at
    any device count — including 1, which makes ``mesh=`` safe to leave on
    everywhere.  ``precision`` follows ``run_stack``'s serving-precision
    contract: the input is cast to the activation dtype *before* staging,
    so the gspmd strategy's in-graph mask draws sample in the same dtype
    the kernels materialize the 1/(1-p) scale in, and sharded stays
    bit-identical to unsharded per precision.
    """
    policy = policy or DEFAULT_POLICY
    if rows is None:
        raise ValueError("mesh= needs the mask-stream `rows` (the global "
                         "coordinates are what keep sharded masks "
                         "deterministic per logical row)")
    if precision is not None:
        from repro.kernels import quantize
        quantize.check_precision(precision)
        x_seq = x_seq.astype(quantize.activation_dtype(precision,
                                                       x_seq.dtype))
    hiddens = [lp.wh.shape[-1] for lp in params]
    strategy = resolve_strategy(mesh, policy, backend, hiddens)
    if lengths is None:
        # Pin the graph family: the freeze-select is what makes the batch
        # split across devices numerically invisible (docs/kernels.md).
        lengths = jnp.full((x_seq.shape[0],), x_seq.shape[1], jnp.int32)
    kw = dict(p=p, return_sequence=return_sequence, rows=rows, seed=seed,
              layer_offset=layer_offset, interpret=interpret,
              initial_state=initial_state, lengths=lengths,
              return_all_states=return_all_states, cell=cell,
              precision=precision)
    if strategy == "gspmd":
        return _run_gspmd(params, x_seq, masks, mesh=mesh, policy=policy,
                          **kw)
    return _run_data_sharded(params, x_seq, masks, mesh=mesh, policy=policy,
                             backend=backend, **kw)


def _pad_batch(arr, pad, value=0):
    if pad == 0:
        return arr
    widths = [(0, pad)] + [(0, 0)] * (arr.ndim - 1)
    return jnp.pad(arr, widths, constant_values=value)


def _shard_pad(batch: int, ndev: int) -> int:
    """Rows to append so the batch shards evenly with ≥ 2 rows per device.

    The two-row floor is numeric, not cosmetic: a single-row shard would
    launch the kernel's ``[1, I] @ [I, G·H]`` matvec codepath, whose
    reduction rounds differently from the batched matmul every other
    launch shape takes — the one shape the "bit-identical across launch
    sizes" pin does not cover.  ``ndev == 1`` never pads: the single shard
    then runs the *exact* unsharded launch.
    """
    if ndev <= 1:
        return 0
    per_shard = max(2, -(-batch // ndev))
    return per_shard * ndev - batch


def _split_masks(masks):
    """Separate shardable mask arrays from the static plan (sentinels/None).

    Returns (static_plan, value_tree): the plan keeps ``IN_KERNEL_MASKS`` /
    ``None`` markers (hashable — they key the compiled-callable cache), the
    value tree carries only real arrays (shard_map / jit operands).  Host
    numpy masks count as arrays too — an ndarray in the *plan* would be an
    unhashable cache key (and wrongly baked into the compiled graph).
    """
    is_arr = lambda v: isinstance(v, (jax.Array, np.ndarray))
    plan, values = [], []
    for zx, zh in masks:
        plan.append((None if is_arr(zx) else zx,
                     None if is_arr(zh) else zh))
        values.append((jnp.asarray(zx) if is_arr(zx) else None,
                       jnp.asarray(zh) if is_arr(zh) else None))
    return tuple(plan), values


def _merge_masks(plan, values):
    return [(vx if vx is not None else px, vh if vh is not None else ph)
            for (px, ph), (vx, vh) in zip(plan, values)]


def _stage_batch(x_seq, rows, lengths, initial_state, mask_vals, ndev):
    """Pad every batch-aligned operand for an even ≥2-rows/shard split.

    Shared by both strategies — the padding contract (appended rows get
    mask-row 0 and length 1, outputs sliced off by :func:`_unpad`) must
    never diverge between them.  Returns
    ``(B, pad, x, rows, lengths, state, mask_vals, presence)``.
    """
    B = x_seq.shape[0]
    pad = _shard_pad(B, ndev)
    x_p = _pad_batch(x_seq, pad)
    rows_p = _pad_batch(jnp.asarray(rows, jnp.uint32), pad)
    lens_p = _pad_batch(jnp.asarray(lengths, jnp.int32), pad, value=1)
    state_p = None
    if initial_state is not None:
        state_p = [tuple(_pad_batch(part, pad) for part in layer)
                   for layer in initial_state]
    mask_p = [tuple(None if v is None else _pad_batch(v, pad)
                    for v in pair) for pair in mask_vals]
    presence = tuple((vx is not None, vh is not None)
                     for vx, vh in mask_vals)
    return B, pad, x_p, rows_p, lens_p, state_p, mask_p, presence


def _unpad(out, states, B, pad):
    if not pad:
        return out, states
    return (None if out is None else out[:B],
            [tuple(part[:B] for part in layer) for layer in states])


def _finalize(out, states, x_dtype, *, backend, cell, return_all_states,
              precision=None):
    """Match run_stack's non-all-states return contract after an
    always-all-states inner run."""
    if return_all_states:
        return out, states
    last = states[-1]
    if cell == "gru" or backend == "reference" or precision is not None:
        # Under a serving precision every backend keeps c fp32 (run_stack's
        # 32-bit cell-state policy) — no cast to the activation dtype.
        return out, last
    h_t, c_t = last
    return out, (h_t, c_t.astype(x_dtype))


@functools.lru_cache(maxsize=512)
def _data_sharded_fn(mesh, dp, backend, cell, p, layer_offset, interpret,
                     return_sequence, plan, presence, has_state, n_layers,
                     precision=None):
    """Build (once per static signature) the jitted shard_map callable.

    The cache is what makes the sharded path servable: a fresh closure per
    tick would re-trace and re-lower every call.  Everything in the key is
    hashable and everything per-tick (arrays, seed) is an operand, so a
    streaming engine's ticks hit one compiled executable per launch shape
    — the same economics as the unsharded jit path.
    """
    def local(params_, x_, mvals_, rows_, seed_, lens_, state_):
        out, states = rnn.run_stack(
            params_, x_, _merge_masks(plan, mvals_), p,
            return_sequence=return_sequence, backend=backend, rows=rows_,
            seed=seed_, layer_offset=layer_offset, interpret=interpret,
            initial_state=state_, lengths=lens_, return_all_states=True,
            cell=cell, precision=precision)
        return out, states

    po = StackShardingPolicy(data=dp or ())
    bs = batch_specs(mesh, po)
    mspec = tuple((bs["x_seq"] if px else None, bs["x_seq"] if ph else None)
                  for px, ph in presence)        # masks are [B, G, dim] too
    cspec = carry_specs(n_layers, mesh, po, cell=cell)
    out_spec = (bs["x_seq"] if return_sequence else None, cspec)
    sharded = shard_map(
        local, mesh=mesh,
        in_specs=(P(), bs["x_seq"], mspec, bs["rows"], P(), bs["lengths"],
                  cspec if has_state else None),
        out_specs=out_spec, check_rep=False)
    return jax.jit(sharded)


def _run_data_sharded(params, x_seq, masks, *, mesh, policy, backend, p,
                      return_sequence, rows, seed, layer_offset, interpret,
                      initial_state, lengths, return_all_states, cell,
                      precision=None):
    """Batch rows over the data axes via shard_map; weights replicated.

    Every device runs the unmodified Pallas (or reference) stack on its
    batch shard.  The batch pads up to a device-count multiple (appended
    rows are discarded), so any session count shards.
    """
    ndev = data_size(mesh, policy)
    dp = data_axes(mesh, policy)
    plan, mask_vals = _split_masks(masks)
    B, pad, x_p, rows_p, lens_p, state_p, mask_p, presence = _stage_batch(
        x_seq, rows, lengths, initial_state, mask_vals, ndev)

    fn = _data_sharded_fn(mesh, dp, backend, cell, float(p),
                          int(layer_offset), interpret, bool(return_sequence),
                          plan, presence, state_p is not None, len(params),
                          precision)
    out, states = fn(params, x_p, tuple(mask_p), rows_p,
                     jnp.asarray(seed, jnp.uint32), lens_p, state_p)
    out, states = _unpad(out, states, B, pad)
    return _finalize(out, states, x_seq.dtype, backend=backend, cell=cell,
                     return_all_states=return_all_states, precision=precision)


@functools.lru_cache(maxsize=512)
def _gspmd_fn(mesh, policy, cell, p, layer_offset, return_sequence, plan,
              presence, has_state, in_dims, hiddens, precision=None):
    """Build (once per static signature) the GSPMD-jitted reference scan.

    Same caching rationale as :func:`_data_sharded_fn`; param specs come
    from the same :func:`_param_specs` rule the public spec builder uses.
    A ``plan`` entry that is still the ``IN_KERNEL_MASKS`` sentinel (a
    Pallas-backed caller's ``stack_mask_plan``) has its mask values drawn
    *inside* the jitted fn from the same ``(seed, layer, rows)``
    coordinates the kernels use — same bits (the mask-stream contract),
    but fused into the compiled graph instead of re-dispatched eagerly
    every call.
    """
    ns = functools.partial(NamedSharding, mesh)
    gate_masks = mcd.gru_gate_masks if cell == "gru" else mcd.lstm_gate_masks
    pspec = _param_specs(cell, hiddens, mesh, policy, "gspmd")
    bs = batch_specs(mesh, policy)
    mspec = [(bs["x_seq"] if px else None, bs["x_seq"] if ph else None)
             for px, ph in presence]             # masks are [B, G, dim] too
    cspec = carry_specs(len(hiddens), mesh, policy, cell=cell)
    out_spec = (bs["x_seq"] if return_sequence else None, cspec)

    def fn(params_, x_, mvals_, rows_, seed_, lens_, state_):
        masks_ = []
        for i, (zx, zh) in enumerate(_merge_masks(plan, mvals_)):
            if zx is rnn.IN_KERNEL_MASKS:
                masks_.append(gate_masks(seed_, layer_offset + i, rows_,
                                         in_dims[i], hiddens[i], p,
                                         dtype=x_.dtype))
            else:
                masks_.append((zx, zh))
        return rnn.run_stack(params_, x_, masks_, p,
                             return_sequence=return_sequence,
                             backend="reference", rows=rows_,
                             initial_state=state_, lengths=lens_,
                             return_all_states=True, cell=cell,
                             precision=precision)

    to_ns = lambda tree: jax.tree.map(ns, tree,
                                      is_leaf=lambda s: isinstance(s, P))
    return jax.jit(fn,
                   in_shardings=to_ns((pspec, bs["x_seq"], mspec,
                                       bs["rows"], P(), bs["lengths"],
                                       cspec if has_state else None)),
                   out_shardings=to_ns(out_spec))


def _run_gspmd(params, x_seq, masks, *, mesh, policy, p, return_sequence,
               rows, seed, layer_offset, interpret, initial_state, lengths,
               return_all_states, cell, precision=None):
    """Wide-H strategy: reference scan under GSPMD, H over ``model``.

    Weights shard on their H *output* dim only (never a contraction dim —
    per-element results stay bit-identical; XLA all-gathers the small
    per-step ``h`` instead of splitting a reduction), batch rows and mask
    coordinates over the data axes.  This is the H-tiling docs/kernels.md
    says cannot live inside the sequence kernel.
    """
    del interpret  # reference scan — nothing to interpret
    plan, mask_vals = _split_masks(masks)
    # GSPMD's explicit in_shardings need the batch divisible just like
    # shard_map does — same staging, same padding contract.
    B, pad, x_p, rows_p, lens_p, state_p, mask_p, presence = _stage_batch(
        x_seq, rows, lengths, initial_state, mask_vals,
        data_size(mesh, policy))

    jf = _gspmd_fn(mesh, policy, cell, float(p), int(layer_offset),
                   bool(return_sequence), plan, presence,
                   state_p is not None,
                   tuple(lp.wx.shape[1] for lp in params),
                   tuple(lp.wh.shape[-1] for lp in params), precision)
    out, states = jf(params, x_p, mask_p, rows_p,
                     jnp.asarray(seed, jnp.uint32), lens_p, state_p)
    out, states = _unpad(out, states, B, pad)
    return _finalize(out, states, x_seq.dtype, backend="reference", cell=cell,
                     return_all_states=return_all_states, precision=precision)

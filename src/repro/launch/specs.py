"""Lowering jobs: (arch × shape × mesh) → function + ShapeDtypeStruct args +
shardings.  Everything is built with jax.eval_shape — no real allocation;
the FULL configs only ever exist as abstract arrays on this container.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import prng
from repro.launch import mesh as mesh_lib
from repro.launch import shardings
from repro.models import backbone
from repro.models.config import ArchConfig, SHAPES, shape_applicable
from repro.models.layers import Ctx
from repro.train import optimizer, trainer


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _named(mesh, spec_tree):
    leaf = lambda x: isinstance(x, P)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=leaf)


@dataclasses.dataclass
class LoweringJob:
    name: str
    fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any
    kind: str                      # train | prefill | decode
    notes: str = ""


def make_policy(mesh, cfg: ArchConfig) -> shardings.Policy:
    axes = mesh_lib.axis_sizes(mesh)
    dp = mesh_lib.dp_axes(mesh)
    # FSDP for archs whose TP-sharded params would not fit a 16 GB chip:
    # params_bytes / tp_size > ~4 GB → shard over data too.
    big = cfg.name.startswith("jamba")
    return shardings.Policy(axes=axes, dp=dp, tp="model", fsdp=big, zero=True)


def model_input_specs(cfg: ArchConfig, batch: int, seq: int, *,
                      with_targets: bool, po: shardings.Policy):
    """(args-dict of ShapeDtypeStruct, specs-dict of PartitionSpec)."""
    b = shardings.batch_spec(batch, po)
    toks = seq
    extras, espec = {}, {}
    if cfg.family == "audio":
        extras["frames"] = _sds((batch, cfg.encoder_seq, cfg.d_model),
                                jnp.bfloat16)
        espec["frames"] = P(b, None, None)
    if cfg.family == "vlm":
        toks = seq - cfg.num_patches
        extras["patches"] = _sds((batch, cfg.num_patches, cfg.d_model),
                                 jnp.bfloat16)
        espec["patches"] = P(b, None, None)
    args = {"tokens": _sds((batch, toks), jnp.int32), **extras}
    spec = {"tokens": P(b, None), **espec}
    if with_targets:
        args["targets"] = _sds((batch, toks), jnp.int32)
        spec["targets"] = P(b, None)
    return args, spec


def _ctx_for(cfg: ArchConfig, batch: int, po: shardings.Policy):
    b = shardings.batch_spec(batch, po)
    ctx_arg = Ctx(rows=_sds((batch,), jnp.uint32),
                  seed=_sds((), jnp.uint32), cfg=cfg.mcd)
    ctx_spec = Ctx(rows=P(b), seed=P(), cfg=cfg.mcd)
    return ctx_arg, ctx_spec


def train_job(cfg: ArchConfig, shape_name: str, mesh,
              microbatches: int = 1) -> LoweringJob:
    cell = SHAPES[shape_name]
    po = make_policy(mesh, cfg)
    batch, seq = cell.global_batch, cell.seq_len

    params_sh = jax.eval_shape(
        functools.partial(backbone.init_params, cfg=cfg, dtype=jnp.bfloat16),
        jax.random.key(0))
    opt_sh = jax.eval_shape(optimizer.init, params_sh)
    pspecs = shardings.param_specs(cfg, po)
    ospecs = shardings.optstate_specs(pspecs, po, params_sh)
    batch_args, batch_specs = model_input_specs(cfg, batch, seq,
                                                with_targets=True, po=po)

    tcfg = trainer.TrainConfig(microbatches=microbatches, log_every=0)

    def loss(params, b, step):
        ctx = Ctx(rows=jnp.arange(b["tokens"].shape[0], dtype=jnp.uint32),
                  seed=prng.fold_ids(cfg.mcd.seed, step), cfg=cfg.mcd)
        return backbone.loss_fn(params, cfg, b["tokens"], b["targets"], ctx,
                                frames=b.get("frames"),
                                patches=b.get("patches"))

    raw_step = trainer.make_train_step(loss, tcfg)

    def train_step(params, opt_state, batch_in, step):
        err = jax.tree.map(lambda _: jnp.zeros((), jnp.float32), params)
        params, opt_state, _, metrics = raw_step(params, opt_state, err,
                                                 batch_in, step)
        return params, opt_state, metrics

    in_spec = (pspecs, ospecs, batch_specs, P())
    out_spec = (pspecs, ospecs, {"loss": P(), "grad_norm": P(), "lr": P()})
    return LoweringJob(
        name=f"{cfg.name}:{shape_name}",
        fn=train_step,
        args=(params_sh, opt_sh, batch_args, _sds((), jnp.int32)),
        in_shardings=_named(mesh, in_spec),
        out_shardings=_named(mesh, out_spec),
        kind="train")


def prefill_job(cfg: ArchConfig, shape_name: str, mesh) -> LoweringJob:
    cell = SHAPES[shape_name]
    po = make_policy(mesh, cfg)
    batch, seq = cell.global_batch, cell.seq_len
    params_sh = jax.eval_shape(
        functools.partial(backbone.init_params, cfg=cfg, dtype=jnp.bfloat16),
        jax.random.key(0))
    pspecs = shardings.param_specs(cfg, po)
    batch_args, batch_specs = model_input_specs(cfg, batch, seq,
                                                with_targets=False, po=po)
    ctx_arg, ctx_spec = _ctx_for(cfg, batch, po)
    state_specs = shardings.cache_specs(cfg, po, batch)
    b = shardings.batch_spec(batch, po)

    def prefill_step(params, b_in, ctx):
        return backbone.prefill(params, cfg, b_in["tokens"], ctx, seq,
                                frames=b_in.get("frames"),
                                patches=b_in.get("patches"))

    in_spec = (pspecs, batch_specs, ctx_spec)
    out_spec = (P(b, None, None), state_specs)
    return LoweringJob(
        name=f"{cfg.name}:{shape_name}",
        fn=prefill_step,
        args=(params_sh, batch_args, ctx_arg),
        in_shardings=_named(mesh, in_spec),
        out_shardings=_named(mesh, out_spec),
        kind="prefill")


def decode_job(cfg: ArchConfig, shape_name: str, mesh,
               kv_quant: bool = False) -> LoweringJob:
    cell = SHAPES[shape_name]
    po = make_policy(mesh, cfg)
    batch, seq = cell.global_batch, cell.seq_len
    params_sh = jax.eval_shape(
        functools.partial(backbone.init_params, cfg=cfg, dtype=jnp.bfloat16),
        jax.random.key(0))
    pspecs = shardings.param_specs(cfg, po)
    state_sh = jax.eval_shape(
        functools.partial(backbone.init_decode_state, cfg, batch, seq,
                          jnp.bfloat16, kv_quant=kv_quant))
    state_specs = shardings.cache_specs(cfg, po, batch, kv_quant=kv_quant)
    ctx_arg, ctx_spec = _ctx_for(cfg, batch, po)
    b = shardings.batch_spec(batch, po)

    def serve_step(params, token, state, ctx):
        return backbone.decode_step(params, cfg, token, state, ctx)

    in_spec = (pspecs, P(b, None), state_specs, ctx_spec)
    out_spec = (P(b, None, None), state_specs)
    return LoweringJob(
        name=f"{cfg.name}:{shape_name}",
        fn=serve_step,
        args=(params_sh, _sds((batch, 1), jnp.int32), state_sh, ctx_arg),
        in_shardings=_named(mesh, in_spec),
        out_shardings=_named(mesh, out_spec),
        kind="decode",
        notes=f"KV/state length {seq}")


# ---------------------------------------------------------------------------
# Roofline probes — XLA's cost analysis counts while-loop bodies once, so the
# full-cell numbers undercount scanned layers.  Probes compile each unique
# (stage, position) block (+ head + optimizer) standalone with attention
# scans unrolled, and the roofline composes  Σ body × repeat + head + opt.
# Everything stays derived from compiled artifacts.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Probe:
    name: str
    fn: Callable
    args: tuple
    in_shardings: Any
    multiplier: int                # how many times this body runs per step


def _attn_blocks_for(seq: int):
    """Probe tiling: ≤64 unrolled attention bodies regardless of seq."""
    qb = max(512, seq // 8)
    kb = max(1024, seq // 8)
    return dict(q_block=qb, kv_block=kb, unroll=True)


def probe_jobs(cfg: ArchConfig, shape_name: str, mesh,
               kv_quant: bool = False) -> list[Probe]:
    from repro.models import layers as L

    cell = SHAPES[shape_name]
    po = make_policy(mesh, cfg)
    batch, seq = cell.global_batch, cell.seq_len
    kind_step = cell.kind
    b = shardings.batch_spec(batch, po)
    dtype = jnp.bfloat16
    probes: list[Probe] = []
    ctx_arg, ctx_spec = _ctx_for(cfg, batch, po)
    x_seq = seq if kind_step != "decode" else 1
    x_arg = _sds((batch, x_seq, cfg.d_model), dtype)
    x_spec = P(b, None, None)

    def add_block_probes(stages, tag: str, block_seq: int):
        xa = _sds((batch, block_seq, cfg.d_model), dtype)
        for si, st in enumerate(stages):
            for j, kind in enumerate(st.pattern):
                bl_specs = shardings.spec_block(kind, cfg, po)
                bl_shapes = jax.eval_shape(
                    functools.partial(backbone.init_block, kind=kind, cfg=cfg,
                                      dtype=dtype), jax.random.key(0))
                positions = jnp.arange(block_seq)
                bayes = cfg.mcd.bayesian(j)
                has_cross = "cross" in kind.split(".")
                ekv_arg = ekv_spec = None
                if has_cross:
                    kv = _sds((batch, cfg.encoder_seq, cfg.num_kv_heads,
                               cfg.head_dim), dtype)
                    ekv_arg = (kv, kv)
                    sp = P(b, None, po.tp_if(cfg.num_kv_heads), None)
                    ekv_spec = (sp, sp)

                if kind_step == "train":
                    def fn(p, x, ekv, ctx, _kind=kind, _pos=positions,
                           _by=bayes):
                        # checkpointed to match the remat policy of the real
                        # train step (backward recomputes block internals)
                        @jax.checkpoint
                        def f(p_, x_):
                            out, aux, _ = backbone._block_forward(
                                p_, _kind, cfg, x_, _pos, ctx, 0, _by,
                                enc_kv=ekv)
                            return jnp.sum(out.astype(jnp.float32)) + aux
                        return jax.grad(f, argnums=(0, 1))(p, x)
                else:
                    def fn(p, x, ekv, ctx, _kind=kind, _pos=positions,
                           _by=bayes):
                        out, aux, _ = backbone._block_forward(
                            p, _kind, cfg, x, _pos, ctx, 0, _by, enc_kv=ekv)
                        return out

                probes.append(Probe(
                    name=f"{tag}{si}.{j}:{kind}",
                    fn=fn, args=(bl_shapes, xa, ekv_arg, ctx_arg),
                    in_shardings=_named(mesh, (bl_specs, x_spec, ekv_spec,
                                               ctx_spec)),
                    multiplier=st.repeat))

    def add_decode_block_probes():
        state_sh = jax.eval_shape(
            functools.partial(backbone.init_decode_state, cfg, batch, seq,
                              dtype, kv_quant=kv_quant))
        state_specs = shardings.cache_specs(cfg, po, batch,
                                            kv_quant=kv_quant)
        for si, st in enumerate(cfg.stages):
            for j, kind in enumerate(st.pattern):
                bl_specs = shardings.spec_block(kind, cfg, po)
                bl_shapes = jax.eval_shape(
                    functools.partial(backbone.init_block, kind=kind, cfg=cfg,
                                      dtype=dtype), jax.random.key(0))
                # unstacked cache slice for this block
                cache_sh = jax.tree.map(lambda a: _sds(a.shape[1:], a.dtype),
                                        state_sh.caches[si][j])
                cache_sp = jax.tree.map(
                    lambda s: P(*s[1:]), state_specs.caches[si][j],
                    is_leaf=lambda x: isinstance(x, P))
                cross_sh = cross_sp = None
                if state_sh.cross is not None and state_sh.cross[si][j] is not None:
                    cross_sh = jax.tree.map(
                        lambda a: _sds(a.shape[1:], a.dtype),
                        state_sh.cross[si][j])
                    cross_sp = jax.tree.map(
                        lambda s: P(*s[1:]), state_specs.cross[si][j],
                        is_leaf=lambda x: isinstance(x, P))
                bayes = cfg.mcd.bayesian(j)

                def fn(p, x, cache, cross, pos, ctx, _kind=kind, _by=bayes):
                    return backbone._block_decode(p, _kind, cfg, x, cache,
                                                  pos, ctx, 0, _by,
                                                  cross_kv=cross)

                probes.append(Probe(
                    name=f"dec{si}.{j}:{kind}",
                    fn=fn,
                    args=(bl_shapes, x_arg, cache_sh, cross_sh,
                          _sds((), jnp.int32), ctx_arg),
                    in_shardings=_named(mesh, (bl_specs, x_spec, cache_sp,
                                               cross_sp, P(), ctx_spec)),
                    multiplier=st.repeat))

    # --- blocks ---
    if kind_step == "decode":
        add_decode_block_probes()
    else:
        add_block_probes(cfg.stages, "blk", seq)
        if cfg.encoder_stages:
            add_block_probes(cfg.encoder_stages, "enc", cfg.encoder_seq)

    # --- embedding + head ---
    embed_sh = jax.eval_shape(
        functools.partial(layers_init_embed_shapes, cfg, dtype),
        jax.random.key(0))
    embed_sp = shardings.param_specs(cfg, po)["embed"]
    toks = _sds((batch, x_seq), jnp.int32)
    if kind_step == "train":
        def head_fn(ep, tokens, targets):
            # embed fwd+bwd + logits/xent fwd+bwd in one probe
            def f(ep_):
                x = L.embed(ep_, tokens)
                return backbone._chunked_xent(ep_, x, targets)
            return jax.grad(f)(ep)
        probes.append(Probe(
            name="head:embed+xent",
            fn=head_fn,
            args=(embed_sh, toks, _sds((batch, x_seq), jnp.int32)),
            in_shardings=_named(mesh, (embed_sp, P(b, None), P(b, None))),
            multiplier=1))
    else:
        out_positions = x_seq if kind_step == "prefill" else 1

        def head_fn(ep, tokens):
            x = L.embed(ep, tokens)
            return L.logits(ep, x)
        probes.append(Probe(
            name="head:embed+logits",
            fn=head_fn,
            args=(embed_sh, _sds((batch, out_positions), jnp.int32)),
            in_shardings=_named(mesh, (embed_sp, P(b, None))),
            multiplier=1))

    # --- optimizer update (train only) ---
    if kind_step == "train":
        params_sh = jax.eval_shape(
            functools.partial(backbone.init_params, cfg=cfg, dtype=dtype),
            jax.random.key(0))
        opt_sh = jax.eval_shape(optimizer.init, params_sh)
        pspecs = shardings.param_specs(cfg, po)
        ospecs = shardings.optstate_specs(pspecs, po, params_sh)
        grads_sh = jax.tree.map(lambda a: _sds(a.shape, jnp.float32), params_sh)
        tcfg = trainer.TrainConfig()

        def opt_fn(params, grads, state):
            return optimizer.apply(tcfg.adamw, params, grads, state)
        probes.append(Probe(
            name="opt:adamw",
            fn=opt_fn, args=(params_sh, grads_sh, opt_sh),
            in_shardings=_named(mesh, (pspecs, pspecs, ospecs)),
            multiplier=1))
    return probes


def layers_init_embed_shapes(cfg: ArchConfig, dtype, key):
    from repro.models import layers as L
    return L.init_embed(key, cfg.vocab_size, cfg.d_model, cfg.tie_embeddings,
                        dtype)


def make_job(cfg: ArchConfig, shape_name: str, mesh) -> LoweringJob | None:
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        return None
    kind = SHAPES[shape_name].kind
    if kind == "train":
        return train_job(cfg, shape_name, mesh)
    if kind == "prefill":
        return prefill_job(cfg, shape_name, mesh)
    return decode_job(cfg, shape_name, mesh)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
mesh; record roofline terms.  The two lines above MUST stay first — jax locks
the device count on first init (do not set this flag globally).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod --out results/
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multipod
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ALIASES, get_config
from repro.kernels import compat
from repro.launch import analysis, mesh as mesh_lib, specs
from repro.models.config import SHAPES, shape_applicable


def run_cell(arch: str, shape: str, multi_pod: bool, *,
             microbatches: int = 1, verbose: bool = True,
             probes: bool = True, opts: tuple = ()) -> dict:
    import contextlib

    from repro.launch import mesh as _m
    from repro.models import backbone as _bb
    from repro.models import moe as _moe

    cfg = get_config(arch)
    record = {"arch": arch, "shape": shape,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "kind": SHAPES[shape].kind, "opts": list(opts)}
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        record["status"] = "skipped"
        record["reason"] = why
        return record
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    dp = _m.dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= _m.axis_sizes(mesh)[a]
    opt_stack = contextlib.ExitStack()
    if "moe_group" in opts:
        opt_stack.enter_context(_moe.moe_sharding(
            expert_axis="model", token_axes=dp, groups=dp_size))
    if "seqpar" in opts:
        opt_stack.enter_context(_bb.activation_sharding(
            spec=(dp, "model", None)))
    t0 = time.time()
    try:
        job = specs.make_job(cfg, shape, mesh)
        if SHAPES[shape].kind == "train" and microbatches > 1:
            job = specs.train_job(cfg, shape, mesh, microbatches=microbatches)
        if SHAPES[shape].kind == "decode" and "kv8" in opts:
            job = specs.decode_job(cfg, shape, mesh, kv_quant=True)
        with opt_stack, compat.set_mesh(mesh):
            lowered = jax.jit(job.fn, in_shardings=job.in_shardings,
                              out_shardings=job.out_shardings).lower(*job.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            print(compiled.memory_analysis())
            hlo = compiled.as_text()
            roof = analysis.analyse(compiled, hlo)
            ca = compiled.cost_analysis() or {}
            print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
        mf = analysis.model_flops(cfg, SHAPES[shape], chips)
        record.update({
            "status": "ok",
            "chips": chips,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "raw_flops_per_device": roof.flops,
            "raw_bytes_per_device": roof.bytes_hbm,
            "raw_collective_bytes_per_device": roof.bytes_collective,
            "model_flops_per_device": mf,
            "memory": roof.memory_per_device,
        })
        if probes:
            record.update(run_probes(cfg, shape, mesh, opts=opts))
            record["useful_flops_ratio"] = (
                mf / record["flops_per_device"]
                if record.get("flops_per_device") else 0.0)
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
    if verbose:
        s = record["status"]
        extra = (f" bottleneck={record.get('bottleneck')}"
                 f" t=({record.get('t_compute', 0):.3e},"
                 f"{record.get('t_memory', 0):.3e},"
                 f"{record.get('t_collective', 0):.3e})s"
                 if s == "ok" else record.get("reason", record.get("error", "")))
        print(f"[dryrun] {arch} × {shape} × {record['mesh']}: {s}{extra}",
              flush=True)
    return record


def run_probes(cfg, shape: str, mesh, opts: tuple = ()) -> dict:
    """Compile per-block probes and compose the corrected roofline
    (Σ body × repeat + head + opt — see specs.probe_jobs docstring)."""
    import contextlib

    from repro.launch import mesh as _m
    from repro.models import backbone as _bb
    from repro.models import layers as L
    from repro.models import moe as _moe

    cell = SHAPES[shape]
    tot = {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
    details = []
    dp = _m.dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= _m.axis_sizes(mesh)[a]
    stack = contextlib.ExitStack()
    if "moe_group" in opts:
        stack.enter_context(_moe.moe_sharding(
            expert_axis="model", token_axes=dp, groups=dp_size))
    if "seqpar" in opts:
        stack.enter_context(_bb.activation_sharding(spec=(dp, "model", None)))
    with stack, L.attention_override(**specs._attn_blocks_for(cell.seq_len)):
        for pr in specs.probe_jobs(cfg, shape, mesh,
                                   kv_quant="kv8" in opts):
            with compat.set_mesh(mesh):
                compiled = jax.jit(
                    pr.fn, in_shardings=pr.in_shardings).lower(
                        *pr.args).compile()
                roof = analysis.analyse(compiled)
            tot["flops"] += roof.flops * pr.multiplier
            tot["bytes"] += roof.bytes_hbm * pr.multiplier
            tot["coll"] += roof.bytes_collective * pr.multiplier
            details.append({
                "probe": pr.name, "multiplier": pr.multiplier,
                "flops": roof.flops, "bytes": roof.bytes_hbm,
                "collective_bytes": roof.bytes_collective,
                "collectives": roof.coll_by_kind})
    t_c = tot["flops"] / analysis.PEAK_FLOPS
    t_m = tot["bytes"] / analysis.HBM_BW
    t_x = tot["coll"] / analysis.ICI_BW
    bott = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    return {
        "flops_per_device": tot["flops"],
        "bytes_per_device": tot["bytes"],
        "collective_bytes_per_device": tot["coll"],
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
        "bottleneck": bott,
        "roofline_fraction": t_c / max(t_c, t_m, t_x, 1e-30),
        "probes": details,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ALIASES))
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-probes", action="store_true",
                    help="compile-only (skip roofline probe composition)")
    ap.add_argument("--opt", action="append", default=[],
                    choices=("moe_group", "seqpar", "kv8"),
                    help="optimization variants (§Perf)")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    cells = []
    archs = sorted(ALIASES) if args.all or not args.arch else [args.arch]
    shapes = sorted(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    records = []
    for arch, shape, mp in cells:
        rec = run_cell(arch, shape, mp, microbatches=args.microbatches,
                       probes=not args.no_probes, opts=tuple(args.opt))
        records.append(rec)
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = len(records) - n_ok - n_skip
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Serving launcher: batched Bayesian generation with per-token uncertainty.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch olmoe-1b-7b --reduced \
      --batch 4 --prompt-len 16 --new-tokens 16 --samples 8
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALIASES, get_config
from repro.models import backbone
from repro.serve.engine import BayesianEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ALIASES), default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--samples", type=int, default=8)
    ap.add_argument("--p", type=float, default=None, help="override MCD p")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    mcd_cfg = cfg.mcd.replace(n_samples=args.samples,
                              **({"p": args.p} if args.p is not None else {}))
    cfg = cfg.replace(mcd=mcd_cfg)
    params = backbone.init_params(jax.random.key(args.seed), cfg,
                                  dtype=jnp.float32)
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32))
    kw = {}
    if cfg.family == "audio":
        kw["frames"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        kw["patches"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.num_patches, cfg.d_model)).astype(np.float32))

    eng = BayesianEngine(params, cfg,
                         max_len=args.prompt_len + args.new_tokens
                         + (cfg.num_patches if cfg.family == "vlm" else 0),
                         seed=args.seed)
    res = eng.generate(prompts, args.new_tokens, **kw)
    print(f"arch={cfg.name} S={args.samples} p={cfg.mcd.p} "
          f"B={cfg.mcd.placement and ''.join('Y' if b else 'N' for b in cfg.mcd.placement)}")
    for b in range(args.batch):
        toks = np.asarray(res.tokens[b])
        ent = np.asarray(res.predictive_entropy[b])
        mi = np.asarray(res.mutual_information[b])
        print(f"req {b}: tokens={toks.tolist()}")
        print(f"       H(total)={np.round(ent, 3).tolist()}")
        print(f"       MI(epistemic)={np.round(mi, 4).tolist()}")


if __name__ == "__main__":
    main()

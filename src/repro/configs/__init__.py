"""Assigned-architecture registry: ``--arch <id>`` resolves here.

Each module exports CONFIG (the exact published configuration) and REDUCED
(a same-family miniature for CPU smoke tests).  FULL configs are exercised
only via the dry-run (ShapeDtypeStruct — no allocation).
"""

from __future__ import annotations

import importlib

ARCH_IDS = (
    "llama3_8b",
    "qwen3_1p7b",
    "jamba_1p5_large_398b",
    "mamba2_370m",
    "deepseek_v2_lite_16b",
    "olmoe_1b_7b",
)

# accept the assignment-sheet spellings too
ALIASES = {
    "llama3-8b": "llama3_8b",
    "qwen3-1.7b": "qwen3_1p7b",
    "jamba-1.5-large-398b": "jamba_1p5_large_398b",
    "mamba2-370m": "mamba2_370m",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "olmoe-1b-7b": "olmoe_1b_7b",
}


def get_config(name: str, reduced: bool = False):
    key = ALIASES.get(name, name.replace("-", "_").replace(".", "p"))
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.REDUCED if reduced else mod.CONFIG

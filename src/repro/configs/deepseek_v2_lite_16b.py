"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 64 routed top-6 + 2 shared.

Layer 0 uses a dense MLP (published config d_ff=10944); layers 1..26 use MoE
with per-expert d_ff=1408.  [arXiv:2405.04434]
"""


from repro.core.mcd import MCDConfig
from repro.models.config import ArchConfig, MLAConfig, MoEConfig, Stage

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    stages=(Stage(pattern=("mla.mlp",), repeat=1),
            Stage(pattern=("mla.moe",), repeat=26)),
    d_model=2048, num_heads=16, num_kv_heads=16, d_ff=10944,
    vocab_size=102400, rope_theta=10000.0,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2),
    mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128,
                  v_head_dim=128),
    mcd=MCDConfig(p=0.1, placement="Y", n_samples=8),
)

REDUCED = CONFIG.replace(
    name="deepseek-v2-lite-reduced",
    stages=(Stage(pattern=("mla.mlp",), repeat=1),
            Stage(pattern=("mla.moe",), repeat=2)),
    d_model=64, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=256,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32, num_shared=1,
                  capacity_factor=8.0),
    mla=MLAConfig(kv_lora_rank=32, rope_head_dim=8, nope_head_dim=16,
                  v_head_dim=16),
)

"""olmoe-1b-7b [moe] — 64 experts top-8, d_ff_expert=1024. [arXiv:2409.02060]"""

from repro.core.mcd import MCDConfig
from repro.models.config import ArchConfig, MoEConfig, uniform_stages

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    stages=uniform_stages("attn.moe", 16),
    d_model=2048, num_heads=16, num_kv_heads=16, d_ff=1024,
    vocab_size=50304, qk_norm=True, rope_theta=10000.0,
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024),
    mcd=MCDConfig(p=0.1, placement="Y", n_samples=8),
)

REDUCED = CONFIG.replace(
    name="olmoe-reduced",
    stages=uniform_stages("attn.moe", 2),
    d_model=64, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=64,
    vocab_size=256,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32, capacity_factor=8.0),
)

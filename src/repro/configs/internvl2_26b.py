"""internvl2-26b [vlm] — InternLM2-20B backbone; InternViT frontend is a STUB.

input_specs() provides precomputed patch embeddings [B, 1024, 6144] prepended
to the text stream; assigned seq_len counts total backbone positions.
[arXiv:2404.16821]
"""

from repro.core.mcd import MCDConfig
from repro.models.config import ArchConfig, uniform_stages

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    stages=uniform_stages("attn.mlp", 48),
    d_model=6144, num_heads=48, num_kv_heads=8, d_ff=16384,
    vocab_size=92553, rope_theta=1000000.0,
    num_patches=1024,
    mcd=MCDConfig(p=0.1, placement="Y", n_samples=8),
)

REDUCED = CONFIG.replace(
    name="internvl2-reduced",
    stages=uniform_stages("attn.mlp", 2),
    d_model=64, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=256, num_patches=4,
)

"""qwen3-1.7b [dense] — GQA kv=8, qk_norm. [hf:Qwen/Qwen3-8B]"""

from repro.core.mcd import MCDConfig
from repro.models.config import ArchConfig, uniform_stages

CONFIG = ArchConfig(
    name="qwen3-1.7b", family="dense",
    stages=uniform_stages("attn.mlp", 28),
    d_model=2048, num_heads=16, num_kv_heads=8, head_dim=128, d_ff=6144,
    vocab_size=151936, qk_norm=True, rope_theta=1000000.0,
    mcd=MCDConfig(p=0.1, placement="Y", n_samples=8),
)

REDUCED = CONFIG.replace(
    name="qwen3-1.7b-reduced",
    stages=uniform_stages("attn.mlp", 2),
    d_model=64, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=256,
)

"""deepseek-7b [dense] — llama-arch, MHA (GQA kv=32). [arXiv:2401.02954]"""

from repro.core.mcd import MCDConfig
from repro.models.config import ArchConfig, uniform_stages

CONFIG = ArchConfig(
    name="deepseek-7b", family="dense",
    stages=uniform_stages("attn.mlp", 30),
    d_model=4096, num_heads=32, num_kv_heads=32, d_ff=11008,
    vocab_size=102400, rope_theta=10000.0,
    mcd=MCDConfig(p=0.1, placement="Y", n_samples=8),
)

REDUCED = CONFIG.replace(
    name="deepseek-7b-reduced",
    stages=uniform_stages("attn.mlp", 2),
    d_model=64, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=256,
)

"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2.

Period of 8 layers: 1 attention + 7 mamba; MoE FFN on every other layer
(4 per period → 36 of 72).  [arXiv:2403.19887]
"""

from repro.core.mcd import MCDConfig
from repro.models.config import ArchConfig, MoEConfig, SSMConfig, Stage

_PERIOD = ("attn.moe", "mamba.mlp", "mamba.moe", "mamba.mlp",
           "mamba.moe", "mamba.mlp", "mamba.moe", "mamba.mlp")

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    stages=(Stage(pattern=_PERIOD, repeat=9),),          # 72 layers
    d_model=8192, num_heads=64, num_kv_heads=8, d_ff=24576,
    vocab_size=65536, rope_theta=10000.0,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    sub_quadratic=True,
    mcd=MCDConfig(p=0.1, placement="Y", n_samples=8),
)

REDUCED = CONFIG.replace(
    name="jamba-reduced",
    stages=(Stage(pattern=_PERIOD, repeat=1),),
    d_model=64, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=256,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64, capacity_factor=8.0),
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=16),
)

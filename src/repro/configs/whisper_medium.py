"""whisper-medium [audio] — enc-dec backbone; conv frontend is a STUB.

24 encoder + 24 decoder layers; input_specs() provides precomputed frame
embeddings [B, 1500, 1024] (the post-conv mel frame count of the published
frontend).  Assigned shapes apply to the decoder token stream.
[arXiv:2212.04356]
"""

from repro.core.mcd import MCDConfig
from repro.models.config import ArchConfig, uniform_stages

CONFIG = ArchConfig(
    name="whisper-medium", family="audio",
    stages=uniform_stages("dec_attn.cross.mlp", 24),
    encoder_stages=uniform_stages("enc_attn.mlp", 24),
    encoder_seq=1500,
    d_model=1024, num_heads=16, num_kv_heads=16, d_ff=4096,
    vocab_size=51865, rope_theta=10000.0,
    mcd=MCDConfig(p=0.1, placement="Y", n_samples=8),
)

REDUCED = CONFIG.replace(
    name="whisper-reduced",
    stages=uniform_stages("dec_attn.cross.mlp", 2),
    encoder_stages=uniform_stages("enc_attn.mlp", 2),
    encoder_seq=16,
    d_model=64, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=256,
)

"""qwen3-32b [dense] — GQA kv=8, qk_norm, head_dim=128. [hf:Qwen/Qwen3-8B]"""

from repro.core.mcd import MCDConfig
from repro.models.config import ArchConfig, uniform_stages

CONFIG = ArchConfig(
    name="qwen3-32b", family="dense",
    stages=uniform_stages("attn.mlp", 64),
    d_model=5120, num_heads=64, num_kv_heads=8, head_dim=128, d_ff=25600,
    vocab_size=151936, qk_norm=True, rope_theta=1000000.0,
    mcd=MCDConfig(p=0.1, placement="Y", n_samples=8),
)

REDUCED = CONFIG.replace(
    name="qwen3-32b-reduced",
    stages=uniform_stages("attn.mlp", 2),
    d_model=64, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=256,
)

"""mamba2-370m [ssm] — attention-free SSD. [arXiv:2405.21060]

48 mamba blocks, d_model=1024, ssm_state=128, expand=2 → d_inner=2048,
head_dim=64 → 32 SSD heads.  num_heads/num_kv_heads/d_ff are unused
(attn-free; the paper's MCD technique applies to the in-projections —
DESIGN.md §5).
"""

from repro.core.mcd import MCDConfig
from repro.models.config import ArchConfig, SSMConfig, uniform_stages

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    stages=uniform_stages("mamba", 48),
    d_model=1024, num_heads=16, num_kv_heads=16, d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    sub_quadratic=True,
    mcd=MCDConfig(p=0.1, placement="Y", n_samples=8),
)

REDUCED = CONFIG.replace(
    name="mamba2-reduced",
    stages=uniform_stages("mamba", 3),
    d_model=64, vocab_size=256,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=16),
)

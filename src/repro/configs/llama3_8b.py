"""llama3-8b [dense] — GQA kv=8, 128k vocab. [arXiv:2407.21783]"""

from repro.core.mcd import MCDConfig
from repro.models.config import ArchConfig, uniform_stages

CONFIG = ArchConfig(
    name="llama3-8b", family="dense",
    stages=uniform_stages("attn.mlp", 32),
    d_model=4096, num_heads=32, num_kv_heads=8, d_ff=14336,
    vocab_size=128256, rope_theta=500000.0,
    mcd=MCDConfig(p=0.1, placement="Y", n_samples=8),
)

REDUCED = CONFIG.replace(
    name="llama3-8b-reduced",
    stages=uniform_stages("attn.mlp", 2),
    d_model=64, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=256,
)

"""Synthetic ECG5000-compatible dataset + sharded input pipeline.

ECG5000 (PhysioNet [37]) is not downloadable in this container, so we generate
a statistically compatible replacement matching the paper's description:
T=140 samples per beat, 4 classes (1 normal + 3 anomaly morphologies),
500-train / 4500-test split with heavy class imbalance, each trace normalized
to zero mean / unit variance.  Waveforms are PQRST Gaussian-pulse
compositions with physiological jitter; anomalies are (1) inverted T wave +
ST elevation, (2) premature/displaced R peak (PVC-like), (3) low-amplitude
fibrillation-like noise.

The pipeline is deterministic in (seed, epoch) — restart-reproducible — and
shards the batch axis over the mesh's data axes via ``shard_batch``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

T_STEPS = 140
NUM_CLASSES = 4
CLASS_FRACTIONS = (0.58, 0.25, 0.12, 0.05)     # imbalance like ECG5000


def _pqrst(rng: np.random.Generator, n: int) -> np.ndarray:
    """Normal beats: P, Q, R, S, T Gaussian bumps with jitter. [n, T]"""
    t = np.linspace(0.0, 1.0, T_STEPS)[None, :]
    def bump(center, width, amp):
        c = center + rng.normal(0, 0.008, (n, 1))
        w = width * (1 + rng.normal(0, 0.08, (n, 1)))
        a = amp * (1 + rng.normal(0, 0.10, (n, 1)))
        return a * np.exp(-0.5 * ((t - c) / w) ** 2)
    x = (bump(0.18, 0.025, 0.18)       # P
         + bump(0.385, 0.012, -0.25)   # Q
         + bump(0.42, 0.016, 1.60)     # R
         + bump(0.455, 0.012, -0.35)   # S
         + bump(0.68, 0.045, 0.40))    # T
    x += rng.normal(0, 0.015, x.shape)             # sensor noise
    return x


def _make_class(rng: np.random.Generator, n: int, label: int) -> np.ndarray:
    x = _pqrst(rng, n)
    t = np.linspace(0.0, 1.0, T_STEPS)[None, :]
    if label == 1:     # inverted T + ST elevation
        x -= 2 * 0.40 * np.exp(-0.5 * ((t - 0.68) / 0.045) ** 2)
        x += 0.22 * ((t > 0.47) & (t < 0.62))
    elif label == 2:   # premature / displaced R (PVC-like)
        x += 1.2 * np.exp(-0.5 * ((t - 0.80) / 0.03) ** 2)
        x -= 0.8 * np.exp(-0.5 * ((t - 0.42) / 0.016) ** 2)
    elif label == 3:   # fibrillation-like: low-amp irregular oscillation
        phase = rng.uniform(0, 2 * np.pi, (n, 1))
        freq = rng.uniform(9, 14, (n, 1))
        x = 0.35 * np.sin(2 * np.pi * freq * t + phase) \
            + rng.normal(0, 0.12, x.shape)
    return x


def make_ecg5000(seed: int = 0):
    """Returns (train_x [500,140,1], train_y, test_x [4500,140,1], test_y)."""
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    total = 5000
    for label, frac in enumerate(CLASS_FRACTIONS):
        n = int(round(total * frac))
        xs.append(_make_class(rng, n, label))
        ys.append(np.full((n,), label, np.int32))
    x = np.concatenate(xs)[:total]
    y = np.concatenate(ys)[:total]
    # per-sample zero mean / unit variance (paper preprocessing)
    x = (x - x.mean(axis=1, keepdims=True)) / (x.std(axis=1, keepdims=True) + 1e-8)
    order = rng.permutation(total)
    x, y = x[order][..., None].astype(np.float32), y[order]
    return x[:500], y[:500], x[500:], y[500:]


@dataclasses.dataclass
class Pipeline:
    """Deterministic shuffled-batch iterator; epoch keyed into the seed."""
    x: np.ndarray
    y: np.ndarray
    batch_size: int = 64
    seed: int = 0
    drop_remainder: bool = True

    def epoch(self, epoch: int):
        rng = np.random.default_rng((self.seed, epoch))
        order = rng.permutation(len(self.x))
        n_full = len(self.x) // self.batch_size
        end = n_full * self.batch_size if self.drop_remainder else len(self.x)
        for i in range(0, end, self.batch_size):
            idx = order[i:i + self.batch_size]
            yield self.x[idx], self.y[idx]


def shard_batch(batch, mesh, data_axes=("data",)):
    """Place a host batch onto the mesh, sharded over the data axes."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    def put(a):
        spec = P(data_axes, *([None] * (a.ndim - 1)))
        return jax.device_put(a, NamedSharding(mesh, spec))
    return jax.tree.map(put, batch)

"""data substrate."""

"""Uncertainty decomposition & metrics (paper Fig. 1, Tables I/II/V/VI).

Regression (autoencoder):   total = aleatoric + epistemic where
  aleatoric  = E_s[σ²_s(x)]        (mean predicted variance)
  epistemic  = Var_s[μ_s(x)]       (variance of predicted means over S)
Classification:  predictive entropy H[E_s p_s]  (paper's nats metric),
  expected entropy E_s H[p_s] (aleatoric), mutual information (epistemic).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class RegressionSummary(NamedTuple):
    mean: jax.Array        # [B, T, I] predictive mean
    aleatoric: jax.Array   # [B, T, I] E_s[σ²]
    epistemic: jax.Array   # [B, T, I] Var_s[μ]
    total: jax.Array       # [B, T, I]


def regression_summary(means: jax.Array,
                       log_vars: jax.Array | None) -> RegressionSummary:
    """means/log_vars: [S, B, T, I] stacked MC passes."""
    mu = jnp.mean(means, axis=0)
    epistemic = jnp.var(means, axis=0)
    aleatoric = (jnp.mean(jnp.exp(log_vars), axis=0) if log_vars is not None
                 else jnp.zeros_like(mu))
    return RegressionSummary(mu, aleatoric, epistemic, aleatoric + epistemic)


def regression_nll(summary: RegressionSummary, target: jax.Array) -> jax.Array:
    """Gaussian NLL of the moment-matched predictive distribution, per example."""
    var = jnp.maximum(summary.total, 1e-8)
    return 0.5 * jnp.mean((summary.mean - target) ** 2 / var + jnp.log(var)
                          + jnp.log(2.0 * jnp.pi), axis=(-2, -1))


def rmse(summary: RegressionSummary, target: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.mean((summary.mean - target) ** 2, axis=(-2, -1)))


def l1(summary: RegressionSummary, target: jax.Array) -> jax.Array:
    return jnp.mean(jnp.abs(summary.mean - target), axis=(-2, -1))


class ClassificationSummary(NamedTuple):
    probs: jax.Array               # [B, C] mean predictive probabilities
    predictive_entropy: jax.Array  # [B] H[E_s p_s]  (total, nats)
    expected_entropy: jax.Array    # [B] E_s H[p_s]  (aleatoric)
    mutual_information: jax.Array  # [B] epistemic (BALD)


def _entropy(p: jax.Array, axis: int = -1) -> jax.Array:
    return -jnp.sum(p * jnp.log(jnp.clip(p, 1e-12, 1.0)), axis=axis)


def classification_summary(logits: jax.Array) -> ClassificationSummary:
    """logits: [S, B, C] stacked MC passes."""
    probs_s = jax.nn.softmax(logits, axis=-1)
    probs = jnp.mean(probs_s, axis=0)
    pred_h = _entropy(probs)
    exp_h = jnp.mean(_entropy(probs_s), axis=0)
    return ClassificationSummary(probs, pred_h, exp_h, pred_h - exp_h)


def accuracy(probs: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(probs, -1) == labels).astype(jnp.float32))


def expected_calibration_error(probs: jax.Array, labels: jax.Array,
                               n_bins: int = 10) -> jax.Array:
    """ECE — calibration quality of the Bayesian predictive distribution."""
    conf = jnp.max(probs, -1)
    correct = (jnp.argmax(probs, -1) == labels).astype(jnp.float32)
    bins = jnp.clip((conf * n_bins).astype(jnp.int32), 0, n_bins - 1)
    ece = jnp.float32(0.0)
    n = probs.shape[0]
    for b in range(n_bins):
        in_bin = (bins == b).astype(jnp.float32)
        cnt = jnp.sum(in_bin)
        acc_b = jnp.where(cnt > 0, jnp.sum(correct * in_bin) / jnp.maximum(cnt, 1), 0.0)
        conf_b = jnp.where(cnt > 0, jnp.sum(conf * in_bin) / jnp.maximum(cnt, 1), 0.0)
        ece += (cnt / n) * jnp.abs(acc_b - conf_b)
    return ece

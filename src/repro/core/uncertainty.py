"""Uncertainty decomposition & metrics (paper Fig. 1, Tables I/II/V/VI).

Regression (autoencoder):   total = aleatoric + epistemic where
  aleatoric  = E_s[σ²_s(x)]        (mean predicted variance)
  epistemic  = Var_s[μ_s(x)]       (variance of predicted means over S)
Classification:  predictive entropy H[E_s p_s]  (paper's nats metric),
  expected entropy E_s H[p_s] (aleatoric), mutual information (epistemic).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class RegressionSummary(NamedTuple):
    mean: jax.Array        # [B, T, I] predictive mean
    aleatoric: jax.Array   # [B, T, I] E_s[σ²]
    epistemic: jax.Array   # [B, T, I] Var_s[μ]
    total: jax.Array       # [B, T, I]


def regression_summary(means: jax.Array,
                       log_vars: jax.Array | None) -> RegressionSummary:
    """means/log_vars: [S, B, T, I] stacked MC passes."""
    mu = jnp.mean(means, axis=0)
    epistemic = jnp.var(means, axis=0)
    aleatoric = (jnp.mean(jnp.exp(log_vars), axis=0) if log_vars is not None
                 else jnp.zeros_like(mu))
    return RegressionSummary(mu, aleatoric, epistemic, aleatoric + epistemic)


def regression_nll(summary: RegressionSummary, target: jax.Array) -> jax.Array:
    """Gaussian NLL of the moment-matched predictive distribution, per example."""
    var = jnp.maximum(summary.total, 1e-8)
    return 0.5 * jnp.mean((summary.mean - target) ** 2 / var + jnp.log(var)
                          + jnp.log(2.0 * jnp.pi), axis=(-2, -1))


def rmse(summary: RegressionSummary, target: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.mean((summary.mean - target) ** 2, axis=(-2, -1)))


def l1(summary: RegressionSummary, target: jax.Array) -> jax.Array:
    return jnp.mean(jnp.abs(summary.mean - target), axis=(-2, -1))


class ClassificationSummary(NamedTuple):
    probs: jax.Array               # [B, C] mean predictive probabilities
    predictive_entropy: jax.Array  # [B] H[E_s p_s]  (total, nats)
    expected_entropy: jax.Array    # [B] E_s H[p_s]  (aleatoric)
    mutual_information: jax.Array  # [B] epistemic (BALD)


def _entropy(p: jax.Array, axis: int = -1) -> jax.Array:
    return -jnp.sum(p * jnp.log(jnp.clip(p, 1e-12, 1.0)), axis=axis)


def classification_summary(logits: jax.Array) -> ClassificationSummary:
    """logits: [S, B, C] stacked MC passes."""
    probs_s = jax.nn.softmax(logits, axis=-1)
    probs = jnp.mean(probs_s, axis=0)
    pred_h = _entropy(probs)
    exp_h = jnp.mean(_entropy(probs_s), axis=0)
    return ClassificationSummary(probs, pred_h, exp_h, pred_h - exp_h)


# ---------------------------------------------------------------------------
# Incremental (mergeable) chain-axis summaries — the early-exit estimators
# ---------------------------------------------------------------------------
#
# The streaming engine's early-exit path needs the uncertainty summary of a
# *prefix* of a session's MC chains and of the full set, without recomputing
# either from scratch: accumulate the first k chains, snapshot the summary,
# fold in the rest, compare.  Both accumulators below are exact one-pass
# algorithms over the chain axis — plain sums for the classification moments
# (probs and entropies are chain-wise means) and Welford/Chan for the
# regression variance (Var_s[mu] must not be computed as E[x^2]-E[x]^2 in
# fp32).  Accumulation is float64 host numpy: a convergence *decision* must
# not flip on fp32 summation order, and the chain counts are tiny (S <= 128)
# so the cost is noise.  ``merge`` implements the parallel (partitioned)
# update, so summaries over chain subsets compose associatively — the
# property tests in tests/test_uncertainty_running.py pin both agreement
# with the batch formulas at fp32 and partition invariance.

class RunningClassificationSummary:
    """One-pass accumulator over MC chains for ``classification_summary``.

    ``update`` folds in a ``[s, B, C]`` block of stacked chain logits;
    ``finalize`` returns the same :class:`ClassificationSummary` the batch
    formula produces over every chain seen so far (fp32).  ``merge`` folds
    another accumulator in (disjoint chain sets), ``copy`` snapshots the
    state — together they give prefix-vs-full comparisons for free.
    """

    def __init__(self):
        self.count = 0
        self._prob_sum: np.ndarray | None = None   # [B, C] float64
        self._ent_sum: np.ndarray | None = None    # [B]    float64

    def update(self, logits) -> "RunningClassificationSummary":
        block = np.asarray(logits, np.float64)
        if block.ndim != 3:
            raise ValueError(f"logits block must be [s, B, C], "
                             f"got shape {block.shape}")
        # Stable softmax + entropy per chain, accumulated as plain sums —
        # the batch formula's means are sums/count, recovered in finalize.
        z = block - block.max(axis=-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=-1, keepdims=True)
        ent = -np.sum(p * np.log(np.clip(p, 1e-12, 1.0)), axis=-1)
        if self._prob_sum is None:
            self._prob_sum = p.sum(axis=0)
            self._ent_sum = ent.sum(axis=0)
        else:
            self._prob_sum += p.sum(axis=0)
            self._ent_sum += ent.sum(axis=0)
        self.count += block.shape[0]
        return self

    def merge(self, other: "RunningClassificationSummary"
              ) -> "RunningClassificationSummary":
        """Fold ``other``'s chains in (disjoint chain sets, any order)."""
        if other.count == 0:
            return self
        if self.count == 0:
            self._prob_sum = other._prob_sum.copy()
            self._ent_sum = other._ent_sum.copy()
        else:
            self._prob_sum = self._prob_sum + other._prob_sum
            self._ent_sum = self._ent_sum + other._ent_sum
        self.count += other.count
        return self

    def copy(self) -> "RunningClassificationSummary":
        out = RunningClassificationSummary()
        out.count = self.count
        if self._prob_sum is not None:
            out._prob_sum = self._prob_sum.copy()
            out._ent_sum = self._ent_sum.copy()
        return out

    def finalize(self) -> ClassificationSummary:
        if self.count == 0:
            raise ValueError("no chains accumulated")
        probs = self._prob_sum / self.count
        pred_h = -np.sum(probs * np.log(np.clip(probs, 1e-12, 1.0)), axis=-1)
        exp_h = self._ent_sum / self.count
        f32 = lambda a: jnp.asarray(a, jnp.float32)  # noqa: E731
        return ClassificationSummary(f32(probs), f32(pred_h), f32(exp_h),
                                     f32(pred_h - exp_h))


class RunningRegressionSummary:
    """Welford/Chan accumulator over MC chains for ``regression_summary``.

    ``update`` folds in ``[s, B, T, I]`` blocks of chain means (and
    matching log-variances); ``finalize`` matches the batch formula over
    every chain seen (population variance, as ``jnp.var``).  The mean/M2
    pair merges by Chan's parallel rule, so partitioned accumulation is
    order-invariant up to float64 rounding.
    """

    def __init__(self):
        self.count = 0
        self._mean: np.ndarray | None = None      # [B, T, I] float64
        self._m2: np.ndarray | None = None        # [B, T, I] float64
        self._var_sum: np.ndarray | None = None   # [B, T, I] E_s[sigma^2] sum

    def update(self, means, log_vars=None) -> "RunningRegressionSummary":
        block = np.asarray(means, np.float64)
        if block.ndim < 2:
            raise ValueError(f"means block must be [s, ...], "
                             f"got shape {block.shape}")
        other = RunningRegressionSummary()
        other.count = block.shape[0]
        other._mean = block.mean(axis=0)
        other._m2 = ((block - other._mean) ** 2).sum(axis=0)
        if log_vars is not None:
            other._var_sum = np.exp(
                np.asarray(log_vars, np.float64)).sum(axis=0)
        else:
            other._var_sum = np.zeros_like(other._mean)
        return self.merge(other)

    def merge(self, other: "RunningRegressionSummary"
              ) -> "RunningRegressionSummary":
        """Chan's parallel variance update over disjoint chain sets."""
        if other.count == 0:
            return self
        if self.count == 0:
            self._mean = other._mean.copy()
            self._m2 = other._m2.copy()
            self._var_sum = other._var_sum.copy()
            self.count = other.count
            return self
        n_a, n_b = self.count, other.count
        n = n_a + n_b
        delta = other._mean - self._mean
        self._m2 = self._m2 + other._m2 + delta ** 2 * (n_a * n_b / n)
        self._mean = self._mean + delta * (n_b / n)
        self._var_sum = self._var_sum + other._var_sum
        self.count = n
        return self

    def copy(self) -> "RunningRegressionSummary":
        out = RunningRegressionSummary()
        out.count = self.count
        if self._mean is not None:
            out._mean = self._mean.copy()
            out._m2 = self._m2.copy()
            out._var_sum = self._var_sum.copy()
        return out

    def finalize(self) -> RegressionSummary:
        if self.count == 0:
            raise ValueError("no chains accumulated")
        epistemic = self._m2 / self.count
        aleatoric = self._var_sum / self.count
        f32 = lambda a: jnp.asarray(a, jnp.float32)  # noqa: E731
        return RegressionSummary(f32(self._mean), f32(aleatoric),
                                 f32(epistemic), f32(aleatoric + epistemic))


def accuracy(probs: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(probs, -1) == labels).astype(jnp.float32))


def expected_calibration_error(probs: jax.Array, labels: jax.Array,
                               n_bins: int = 10) -> jax.Array:
    """ECE — calibration quality of the Bayesian predictive distribution."""
    conf = jnp.max(probs, -1)
    correct = (jnp.argmax(probs, -1) == labels).astype(jnp.float32)
    bins = jnp.clip((conf * n_bins).astype(jnp.int32), 0, n_bins - 1)
    ece = jnp.float32(0.0)
    n = probs.shape[0]
    for b in range(n_bins):
        in_bin = (bins == b).astype(jnp.float32)
        cnt = jnp.sum(in_bin)
        acc_b = jnp.where(cnt > 0, jnp.sum(correct * in_bin) / jnp.maximum(cnt, 1), 0.0)
        conf_b = jnp.where(cnt > 0, jnp.sum(conf * in_bin) / jnp.maximum(cnt, 1), 0.0)
        ece += (cnt / n) * jnp.abs(acc_b - conf_b)
    return ece

"""Dense layer (the paper's single-MVM temporal dense unit) with MCD hook."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import mcd


class DenseParams(NamedTuple):
    w: jax.Array  # [in, out]
    b: jax.Array  # [out]


def init_dense(key: jax.Array, in_dim: int, out_dim: int,
               dtype=jnp.float32) -> DenseParams:
    s = (6.0 / (in_dim + out_dim)) ** 0.5
    return DenseParams(jax.random.uniform(key, (in_dim, out_dim), dtype, -s, s),
                       jnp.zeros((out_dim,), dtype))


def dense(params: DenseParams, x: jax.Array, mask: jax.Array | None = None,
          p: float = 0.0) -> jax.Array:
    """y = (x ⊙ z / (1-p)) @ W + b; mask broadcasts over leading/time axes."""
    if mask is not None and mask.ndim == x.ndim - 1:
        mask = mask[..., None, :]  # tie across the time axis
    x = mcd.apply_mask(x, mask, p)
    return jnp.einsum("...i,io->...o", x, params.w.astype(x.dtype),
                      preferred_element_type=jnp.float32).astype(x.dtype) \
        + params.b.astype(x.dtype)

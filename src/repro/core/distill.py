"""Distilled single-chain student: deterministic trunk + uncertainty head.

The MC-dropout teacher prices every prediction at S stochastic passes.  The
student collapses that to one: the *same* RNN trunk run deterministic (every
mask replaced by the identity — rows carrying
:data:`repro.core.mcd.STUDENT_ROW_FLAG` take the raw view in every kernel and
oracle), the teacher's own dense head for the prediction, and a small
*uncertainty head* regressed against the teacher's chain-axis uncertainty:

* classifier — the head predicts the BALD mutual information (epistemic
  nats) from the trunk's final hidden state ``h_T``;
* autoencoder — the head predicts the per-position epistemic variance
  ``Var_s[mu]`` from the decoder's hidden sequence ``dec_out``.

Nothing here owns a forward pass: the trunk is the existing
:mod:`repro.core.classifier` / :mod:`repro.core.autoencoder` apply with
flagged rows, so a student row co-batches with MC rows in the same per-layer
kernel launches (the serving fast path — ``repro.serve.stream``).  Teacher
targets reuse the ``Running*Summary`` accumulators from
:mod:`repro.core.uncertainty`, i.e. the exact estimator serving reports.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import autoencoder, classifier, linear, mcd, uncertainty


def det_rows(n: int, base: int = 0) -> jax.Array:
    """``n`` distinct student (deterministic) row ids: flagged ``base+i``."""
    return (jnp.arange(base, base + n, dtype=jnp.uint32)
            | jnp.uint32(mcd.STUDENT_ROW_FLAG))


def _is_classifier(cfg) -> bool:
    if isinstance(cfg, classifier.ClassifierConfig):
        return True
    if isinstance(cfg, autoencoder.AutoencoderConfig):
        return False
    raise TypeError(f"expected ClassifierConfig or AutoencoderConfig, "
                    f"got {type(cfg).__name__}")


def init_student(key: jax.Array, cfg, params: dict[str, Any] | None = None,
                 dtype=jnp.float32) -> dict[str, Any]:
    """Student head params: ``{"head": DenseParams, "unc": DenseParams}``.

    ``head`` maps the trunk feature to the prediction — initialized from the
    teacher's own head when ``params`` is given (the natural starting point:
    at init the student's mean prediction is the teacher's deterministic
    pass), fresh Glorot otherwise.  ``unc`` maps the same feature to the
    epistemic estimate — always fresh (the teacher has no such head):
    ``H → 1`` (MI) for the classifier, ``H → I`` (per-feature Var_s[mu]) for
    the autoencoder.  A softplus keeps both outputs non-negative
    (:func:`classifier_student_summary` / :func:`autoencoder_student_summary`).
    """
    k_head, k_unc = jax.random.split(key)
    if _is_classifier(cfg):
        head = (params["head"] if params is not None else
                linear.init_dense(k_head, cfg.hidden, cfg.num_classes, dtype))
        unc = linear.init_dense(k_unc, cfg.hidden, 1, dtype)
    else:
        out_dim = 2 * cfg.input_dim if cfg.heteroscedastic else cfg.input_dim
        head = (params["head"] if params is not None else
                linear.init_dense(k_head, cfg.hidden, out_dim, dtype))
        unc = linear.init_dense(k_unc, cfg.hidden, cfg.input_dim, dtype)
    return {"head": head, "unc": unc}


def classifier_student_summary(student: dict[str, Any], h_T: jax.Array
                               ) -> uncertainty.ClassificationSummary:
    """One-pass summary from the deterministic trunk's ``h_T`` [B, H].

    The student's probs play the ensemble mean; its predicted MI is the
    epistemic estimate, and expected entropy is derived as
    ``predictive - MI`` so the summary obeys the same decomposition identity
    the S-chain estimator does.
    """
    logits = linear.dense(student["head"], h_T)
    probs = jax.nn.softmax(logits, axis=-1)
    pred_h = uncertainty._entropy(probs)
    mi_hat = jax.nn.softplus(linear.dense(student["unc"], h_T))[..., 0]
    return uncertainty.ClassificationSummary(probs, pred_h, pred_h - mi_hat,
                                             mi_hat)


def autoencoder_student_summary(student: dict[str, Any], dec_out: jax.Array,
                                heteroscedastic: bool = True
                                ) -> uncertainty.RegressionSummary:
    """One-pass summary from the decoder hidden sequence ``dec_out`` [B, W, H].

    Mean/aleatoric come from the (teacher-shaped) head; the predicted
    epistemic variance comes from the uncertainty head, so
    ``total = aleatoric + epistemic`` holds exactly as in the MC estimator.
    """
    y = linear.dense(student["head"], dec_out)
    if heteroscedastic:
        mean, log_var = jnp.split(y, 2, axis=-1)
        aleatoric = jnp.exp(jnp.clip(log_var, -10.0, 10.0))
    else:
        mean, aleatoric = y, jnp.zeros_like(y)
    eps_hat = jax.nn.softplus(linear.dense(student["unc"], dec_out))
    return uncertainty.RegressionSummary(mean, aleatoric, eps_hat,
                                         aleatoric + eps_hat)


def classifier_teacher_targets(params: dict[str, Any], x_seq: jax.Array,
                               cfg, *, n_samples: int | None = None,
                               backend: str = "reference", base_row: int = 0,
                               **apply_kw) -> uncertainty.ClassificationSummary:
    """S-chain teacher summary for a training batch — the distill target.

    Broadcasts ``x_seq`` [B, T, I] to S·B rows (chain-major, matching the
    serving engine's row layout) and runs **one** launch; the chain axis is
    folded through :class:`~repro.core.uncertainty.RunningClassificationSummary`
    so the targets are the exact estimator serving reports.
    """
    S = int(n_samples if n_samples is not None else cfg.mcd.n_samples)
    B = x_seq.shape[0]
    rows = jnp.arange(base_row, base_row + S * B, dtype=jnp.uint32)
    xb = jnp.tile(x_seq, (S, 1, 1))
    logits = classifier.apply(params, xb, rows, cfg, backend=backend,
                              **apply_kw)
    acc = uncertainty.RunningClassificationSummary()
    acc.update(jnp.reshape(logits, (S, B, -1)))
    return acc.finalize()


def autoencoder_teacher_targets(params: dict[str, Any], x_seq: jax.Array,
                                cfg, *, n_samples: int | None = None,
                                backend: str = "reference", base_row: int = 0,
                                **apply_kw) -> uncertainty.RegressionSummary:
    """S-chain teacher summary for an autoencoder batch (see classifier twin)."""
    S = int(n_samples if n_samples is not None else cfg.mcd.n_samples)
    B = x_seq.shape[0]
    rows = jnp.arange(base_row, base_row + S * B, dtype=jnp.uint32)
    xb = jnp.tile(x_seq, (S, 1, 1))
    mean, log_var = autoencoder.apply(params, xb, rows, cfg, backend=backend,
                                      **apply_kw)
    acc = uncertainty.RunningRegressionSummary()
    lv = (jnp.reshape(log_var, (S, B) + log_var.shape[1:])
          if log_var is not None else None)
    acc.update(jnp.reshape(mean, (S, B) + mean.shape[1:]), lv)
    return acc.finalize()

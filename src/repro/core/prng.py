"""Counter-based stateless PRNG — the TPU-native analogue of the paper's LFSR.

The paper's Bernoulli sampler is a 4-tap LFSR: a few XOR gates producing one
random bit per cycle, cheap enough that its cost hides entirely under the LSTM
matrix-vector compute (paper Fig. 3/4).  The TPU analogue is a *counter-based
hash*: a handful of uint32 VPU ops (xor/shift/multiply) per lane, evaluated
directly in VMEM inside the consuming kernel so random bits never touch HBM.

Design requirements (all load-bearing for the rest of the framework):

* **Stateless / order-free** — the value at logical coordinates
  ``(seed, stream, row, col)`` is a pure function of those coordinates.  This
  makes masks identical regardless of sharding layout (TP/DP/EP shards each
  compute their own slice), identical across checkpoint restarts (fault
  tolerance), and identical between the Pallas kernel path and the pure-jnp
  reference path (kernel validation).
* **Kernel-safe** — pure ``jnp`` uint32 arithmetic: works inside a Pallas
  kernel body, in interpret mode on CPU, and compiled on TPU.
* **Cheap** — 2 finalizer rounds per output word (~10 VPU ops); like the LFSR,
  generation is fully hidden under the MXU matmuls it feeds.

The hash is the murmur3/splitmix 32-bit finalizer, combined over stream ids
with the boost ``hash_combine`` fold.  It passes the statistical smoke tests in
``tests/test_prng.py`` (mean/variance/decorrelation); it is *not* a
cryptographic RNG, matching the paper's LFSR quality point.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

_GOLDEN = jnp.uint32(0x9E3779B9)


def _mix32(x: jax.Array) -> jax.Array:
    """murmur3-style 32-bit finalizer (full avalanche)."""
    x = jnp.asarray(x, jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def _combine(h: jax.Array, k: jax.Array) -> jax.Array:
    """boost::hash_combine fold of one stream id into the running hash."""
    h = jnp.asarray(h, jnp.uint32)
    k = jnp.asarray(k, jnp.uint32)
    return h ^ (_mix32(k) + _GOLDEN + (h << 6) + (h >> 2))


def fold_ids(seed, *ids) -> jax.Array:
    """Fold integer stream identifiers into a single uint32 key.

    ``ids`` may be python ints or scalar/broadcastable integer arrays; the
    result broadcasts accordingly.  Typical use:
    ``fold_ids(seed, layer_id, sample_id)``.
    """
    h = _mix32(jnp.asarray(seed, jnp.uint32))
    for k in ids:
        h = _combine(h, jnp.asarray(k, jnp.uint32))
    return h


def random_bits(key: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    """uint32 random bits of ``shape`` for a (broadcastable) uint32 ``key``.

    Each element's bits are ``mix32(key ^ mix32(flat_index))`` — a pure
    function of (key, coordinates), independent of how the array is tiled or
    sharded.  Inside a Pallas kernel, pass the *global* coordinates via
    ``offset`` so every tile draws from the same global stream.
    """
    # 2-D+ iota keeps this legal on TPU (1-D iota is not).
    if len(shape) == 0:
        idx = jnp.uint32(0)
    else:
        idx = lax.broadcasted_iota(jnp.uint32, shape, len(shape) - 1)
        stride = 1
        for d in reversed(range(len(shape) - 1)):
            stride *= shape[d + 1]
            idx = idx + lax.broadcasted_iota(jnp.uint32, shape, d) * jnp.uint32(stride)
    key = jnp.asarray(key, jnp.uint32)
    return _mix32(key ^ _mix32(idx))


def random_bits_at(key: jax.Array, row0: jax.Array, col0: jax.Array,
                   shape: tuple[int, int], row_stride: int) -> jax.Array:
    """Tile-local random bits consistent with the global stream.

    For a 2-D global array with ``row_stride`` columns, returns the bits of the
    tile whose top-left corner is (row0, col0).  Used by Pallas kernels so that
    block-tiled generation equals the un-tiled reference exactly.
    """
    rows = lax.broadcasted_iota(jnp.uint32, shape, 0) + jnp.asarray(row0, jnp.uint32)
    cols = lax.broadcasted_iota(jnp.uint32, shape, 1) + jnp.asarray(col0, jnp.uint32)
    idx = rows * jnp.uint32(row_stride) + cols
    key = jnp.asarray(key, jnp.uint32)
    return _mix32(key ^ _mix32(idx))


def uniform(key: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    """float32 uniforms in [0, 1) from the counter stream."""
    bits = random_bits(key, shape)
    # Use the top 24 bits for an exactly-representable float32 uniform.
    return (bits >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def bernoulli_keep_threshold(p_drop: float) -> jnp.uint32:
    """uint32 threshold t such that P(bits >= t) = 1 - p_drop (keep prob)."""
    t = min(max(int(round(p_drop * 4294967296.0)), 0), 0xFFFFFFFF)
    return jnp.uint32(t)


def bernoulli(key: jax.Array, p_drop: float, shape: tuple[int, ...],
              dtype=jnp.float32) -> jax.Array:
    """Keep-mask z ∈ {0,1}: z=0 with probability ``p_drop`` (paper's Bern(1-p)).

    Arbitrary ``p_drop`` — the paper's hardware fixed p=0.125 (3 LFSRs + NAND)
    and lists general p as future work; thresholding a 32-bit counter stream
    supports any p at identical cost.
    """
    bits = random_bits(key, shape)
    return (bits >= bernoulli_keep_threshold(p_drop)).astype(dtype)

"""S-sample Bayesian predictive engine (the paper's MC sampling loop).

On the FPGA, the S MC samples stream through the pipeline back-to-back
(sample-wise pipelining, Fig. 4/5) so weights are fetched once.  The TPU
equivalent: **fold the S samples into the batch axis** — one forward pass over
[S·B, ...] reuses each HBM weight fetch S times, multiplying arithmetic
intensity by S.  This is the single most important performance property of the
whole design: Bayesian inference at *higher* MFU than pointwise inference of
the same batch, because the weight traffic amortizes.

Two execution strategies:
  * ``fold``  — tile to [S·B] and run once (throughput-optimal; default).
  * ``scan``  — lax.map over samples (memory-constrained fallback; activations
    for one sample at a time — the FPGA's sequential-sample behaviour).

Both produce bit-identical masks (counter RNG keyed by global row id), so the
choice is purely a memory/throughput trade-off the DSE framework can flip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mcd


def predict(apply_fn, params, x: jax.Array, cfg: mcd.MCDConfig,
            *, strategy: str = "fold"):
    """Run S stochastic forward passes; returns pytree with leading [S, B].

    ``apply_fn(params, x, rows)`` must accept a row-id vector aligned with
    the batch axis of ``x`` (see :func:`repro.core.mcd.sample_rows`).
    """
    batch = x.shape[0]
    s = max(1, cfg.n_samples if cfg.any_bayesian else 1)
    if strategy == "fold":
        x_tiled = jnp.broadcast_to(x[None], (s, *x.shape)).reshape(
            s * batch, *x.shape[1:])
        rows = mcd.sample_rows(batch, s)
        out = apply_fn(params, x_tiled, rows)
        return jax.tree.map(
            lambda y: y.reshape(s, batch, *y.shape[1:]), out)
    elif strategy == "scan":
        def one(sample_id):
            rows = sample_id * batch + jnp.arange(batch, dtype=jnp.uint32)
            return apply_fn(params, x, rows)
        return jax.lax.map(one, jnp.arange(s, dtype=jnp.uint32))
    raise ValueError(f"unknown strategy {strategy!r}")

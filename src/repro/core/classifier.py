"""Recurrent classifier (paper §III-C, Fig. 6b): encoder + dense + softmax."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import linear, mcd, rnn


@dataclasses.dataclass(frozen=True)
class ClassifierConfig:
    input_dim: int = 1
    hidden: int = 8           # H
    num_layers: int = 3       # NL (encoder only — fully pipelined in hardware)
    num_classes: int = 4
    cell: str = "lstm"        # recurrent unit (rnn.CELLS); §III-A GRU drop-in
    mcd: mcd.MCDConfig = dataclasses.field(
        default_factory=lambda: mcd.MCDConfig(placement="YNY"))


def init(key: jax.Array, cfg: ClassifierConfig, dtype=jnp.float32) -> dict[str, Any]:
    k_enc, k_head = jax.random.split(key)
    hiddens = (cfg.hidden,) * cfg.num_layers
    return {
        "encoder": rnn.init_stack(k_enc, cfg.input_dim, hiddens, dtype,
                                  cell=cfg.cell),
        "head": linear.init_dense(k_head, cfg.hidden, cfg.num_classes, dtype),
    }


def apply(params: dict[str, Any], x_seq: jax.Array, rows: jax.Array,
          cfg: ClassifierConfig, *, backend: str = "reference",
          initial_state=None, lengths: jax.Array | None = None,
          return_state: bool = False, mesh=None, policy=None,
          precision: str | None = None):
    """Logits [B, num_classes] for one set of MCD masks.

    ``backend`` selects the encoder execution path (see
    :func:`repro.core.rnn.run_stack`); all backends draw the same masks.
    ``mesh``/``policy`` shard the encoder over devices (batch rows over the
    data axes; see ``repro.launch.rnn_shardings``) — sharded logits are
    bit-identical to the unsharded lengths-enabled pass, so the flag is
    purely a throughput knob.

    ``precision`` (``repro.kernels.quantize.PRECISIONS``; None = native
    dtypes) selects the serving precision of the encoder: the input is cast
    to the activation dtype up front — so the reference masks sample in the
    same dtype the kernels materialize the 1/(1-p) scale in — and the fp32
    master weights are quantized/cast in-graph per ``run_stack``.  The dense
    head always runs its fp32 weights (logits stay fp32).

    Streaming resumption: ``initial_state`` (per-layer ``(h, c)`` list from a
    previous chunk), ``lengths`` (per-row valid chunk lengths when ragged
    chunks are padded to a common T) and ``return_state=True`` (also return
    the per-layer encoder states to carry into the next chunk) let a session
    classify an unbounded signal chunk-by-chunk; the logits then summarize
    the signal *up to each row's last real sample*.
    """
    if precision is not None:
        from repro.kernels import quantize
        x_seq = x_seq.astype(quantize.activation_dtype(precision,
                                                       x_seq.dtype))
    hiddens = (cfg.hidden,) * cfg.num_layers
    # Pallas backends regenerate masks in-kernel — don't materialize them.
    masks = (rnn.sample_stack_masks(cfg.mcd, rows, cfg.input_dim, hiddens,
                                    dtype=x_seq.dtype, cell=cfg.cell)
             if backend == "reference"
             else rnn.stack_mask_plan(cfg.mcd, cfg.num_layers))
    _, states = rnn.run_stack(params["encoder"], x_seq, masks, cfg.mcd.p,
                              return_sequence=False, backend=backend,
                              rows=rows, seed=cfg.mcd.seed,
                              initial_state=initial_state, lengths=lengths,
                              return_all_states=True, cell=cfg.cell,
                              mesh=mesh, policy=policy, precision=precision)
    logits = linear.dense(params["head"], states[-1][0])
    return (logits, states) if return_state else logits

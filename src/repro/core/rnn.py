"""Cascaded LSTM stacks with time-step scanning and MCD mask pre-sampling.

Structure mirrors the paper's pipelined cascade (Fig. 5): layer i's output at
time t feeds layer i+1 at time t — on the FPGA that is wave pipelining; under
XLA it is a fused scan body where all layers advance one step per iteration
(the scan carries every layer's (h, c)).  This "wavefront" scan is
mathematically identical to running layers sequentially but exposes the same
cross-layer parallelism the paper's II-balancing exploits, and it keeps the
HLO small (one scan) for pod-scale compilation.

Mask pre-sampling (paper Fig. 4 "overlap"): all masks for a forward pass are
produced *before* the scan from the counter RNG — since they are tied across
T they carry no time dimension, and since the RNG is stateless the
"pre-sampling" costs a few VPU ops, not on-chip FIFO memory.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import cells, mcd


def init_stack(key: jax.Array, in_dim: int, hiddens: Sequence[int],
               dtype=jnp.float32) -> list[cells.LSTMParams]:
    params = []
    dims = [in_dim, *hiddens]
    for i, (d_in, d_h) in enumerate(zip(dims[:-1], dims[1:])):
        key, sub = jax.random.split(key)
        params.append(cells.init_lstm(sub, d_in, d_h, dtype))
    return params


def sample_stack_masks(cfg: mcd.MCDConfig, rows: jax.Array, in_dim: int,
                       hiddens: Sequence[int], *, layer_offset: int = 0,
                       dtype=jnp.float32):
    """Pre-sample (z_x, z_h) per layer; None where the layer is pointwise."""
    masks = []
    dims = [in_dim, *hiddens]
    for i, (d_in, d_h) in enumerate(zip(dims[:-1], dims[1:])):
        layer = layer_offset + i
        if cfg.any_bayesian and cfg.bayesian(layer) and cfg.p > 0.0:
            masks.append(mcd.lstm_gate_masks(cfg.seed, layer, rows, d_in, d_h,
                                             cfg.p, dtype=dtype))
        else:
            masks.append((None, None))
    return masks


def run_stack(params: Sequence[cells.LSTMParams], x_seq: jax.Array,
              masks, p: float, *, return_sequence: bool = True):
    """Run a cascaded LSTM stack over a [B, T, I] sequence.

    Returns (outputs [B, T, H_last] if return_sequence else None,
             (h_T, c_T) of the last layer).
    """
    batch = x_seq.shape[0]
    dtype = x_seq.dtype
    carries = [(jnp.zeros((batch, pl.wh.shape[1]), dtype),
                jnp.zeros((batch, pl.wh.shape[1]), dtype)) for pl in params]
    xs = jnp.swapaxes(x_seq, 0, 1)  # [T, B, I] time-major for scan

    def step(carry, x_t):
        new_carry = []
        inp = x_t
        for (h, c), layer_params, (zx, zh) in zip(carry, params, masks):
            h, c = cells.lstm_step(layer_params, h, c, inp, zx, zh, p)
            new_carry.append((h, c))
            inp = h
        return new_carry, (inp if return_sequence else jnp.zeros((0,), dtype))

    final_carry, ys = jax.lax.scan(step, carries, xs)
    out = jnp.swapaxes(ys, 0, 1) if return_sequence else None
    return out, final_carry[-1]

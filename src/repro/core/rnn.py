"""Cascaded recurrent (LSTM/GRU) stacks with MCD mask pre-sampling.

Structure mirrors the paper's pipelined cascade (Fig. 5): layer i's output at
time t feeds layer i+1 at time t — on the FPGA that is wave pipelining; under
XLA it is a fused scan body where all layers advance one step per iteration
(the scan carries every layer's (h, c)).  This "wavefront" scan is
mathematically identical to running layers sequentially but exposes the same
cross-layer parallelism the paper's II-balancing exploits, and it keeps the
HLO small (one scan) for pod-scale compilation.

Mask pre-sampling (paper Fig. 4 "overlap"): all masks for a forward pass are
produced *before* the scan from the counter RNG — since they are tied across
T they carry no time dimension, and since the RNG is stateless the
"pre-sampling" costs a few VPU ops, not on-chip FIFO memory.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import cells, mcd


#: Recurrent cell types ``run_stack`` (and everything above it) dispatches
#: on.  Paper §III-A: the per-gate MCD design "drops in directly" for GRU —
#: same mask-stream contract, 3 gates instead of 4, h-only carry.
CELLS = ("lstm", "gru")


def _check_cell(cell: str) -> None:
    if cell not in CELLS:
        raise ValueError(f"cell must be one of {CELLS}, got {cell!r}")


def init_stack(key: jax.Array, in_dim: int, hiddens: Sequence[int],
               dtype=jnp.float32, *, cell: str = "lstm") -> list:
    _check_cell(cell)
    init = cells.init_gru if cell == "gru" else cells.init_lstm
    params = []
    dims = [in_dim, *hiddens]
    for i, (d_in, d_h) in enumerate(zip(dims[:-1], dims[1:])):
        key, sub = jax.random.split(key)
        params.append(init(sub, d_in, d_h, dtype))
    return params


def sample_stack_masks(cfg: mcd.MCDConfig, rows: jax.Array, in_dim: int,
                       hiddens: Sequence[int], *, layer_offset: int = 0,
                       dtype=jnp.float32, cell: str = "lstm"):
    """Pre-sample (z_x, z_h) per layer; None where the layer is pointwise."""
    _check_cell(cell)
    gate_masks = mcd.gru_gate_masks if cell == "gru" else mcd.lstm_gate_masks
    masks = []
    dims = [in_dim, *hiddens]
    for i, (d_in, d_h) in enumerate(zip(dims[:-1], dims[1:])):
        layer = layer_offset + i
        if cfg.any_bayesian and cfg.bayesian(layer) and cfg.p > 0.0:
            masks.append(gate_masks(cfg.seed, layer, rows, d_in, d_h,
                                    cfg.p, dtype=dtype))
        else:
            masks.append((None, None))
    return masks


#: Sentinel masks entry: the layer is Bayesian but its masks are recomputed
#: inside the Pallas kernel — no tensors to materialize (see stack_mask_plan).
IN_KERNEL_MASKS = object()


def stack_mask_plan(cfg: mcd.MCDConfig, n_layers: int, *,
                    layer_offset: int = 0):
    """Per-layer Bayesian on/off in the shape ``run_stack`` expects of
    ``masks``, without materializing any mask tensors.

    Use with the Pallas backends, which recompute masks in-kernel from the
    counter PRNG and only need to know *whether* each layer masks — passing
    :func:`sample_stack_masks` output also works but pays the paper's
    mask-buffer cost the fused kernels exist to avoid.
    """
    return [(IN_KERNEL_MASKS, None)
            if cfg.any_bayesian and cfg.bayesian(layer_offset + i)
            and cfg.p > 0.0 else (None, None)
            for i in range(n_layers)]


def run_stack(params: Sequence, x_seq: jax.Array,
              masks, p: float, *, return_sequence: bool = True,
              backend: str = "reference", rows: jax.Array | None = None,
              seed=0, layer_offset: int = 0, interpret: bool | None = None,
              initial_state=None, lengths: jax.Array | None = None,
              return_all_states: bool = False, cell: str = "lstm",
              mesh=None, policy=None, precision: str | None = None):
    """Run a cascaded recurrent stack over a [B, T, I] sequence.

    ``cell`` selects the recurrent unit (:data:`CELLS`): ``"lstm"`` (the
    paper's main datapath) or ``"gru"`` (§III-A drop-in — 3 gates, no cell
    state).  Every backend serves both cells, and the per-layer state pytree
    follows the cell: ``(h, c)`` pairs for LSTM, ``(h,)`` 1-tuples for GRU.

    Backends (``repro.kernels.ops.LSTM_BACKENDS``):
      * ``"reference"``: the jnp wavefront scan below, consuming the
        pre-sampled ``masks`` — sharding-friendly, the numerical oracle.
      * ``"pallas_step"``: per-timestep fused kernel scanned over T.
      * ``"pallas_seq"``: sequence-fused kernel, weights resident across T.
    The Pallas backends recompute masks in-kernel from the counter PRNG, so
    they ignore the pre-sampled mask *values* and instead need the stream
    coordinates: ``rows`` (as passed to :func:`sample_stack_masks`), ``seed``
    (``cfg.seed``) and ``layer_offset``.  A layer whose ``masks`` entry is
    ``(None, None)`` runs with p=0 on every backend.

    Streaming session state (all three backends, both cells):
      * ``initial_state``: per-layer list of state tuples resuming a
        previous chunk's carry (``None`` entries or ``None`` itself = zeros).
        Feed back exactly what ``return_all_states=True`` returned — the
        carry dtypes round-trip losslessly, keeping chunked == unchunked
        bit-identical per backend (Pallas backends hand back LSTM ``c`` in
        fp32, the 32-bit cell-state policy; the GRU carry is ``h`` in the
        activation dtype on every backend).
      * ``lengths``: int [B] freezing each row's state once ``t >= length``
        so ragged chunks can pad to a common T in one batched launch.
      * ``return_all_states=True``: the second return value becomes the full
        per-layer ``[(h_T, c_T), ...]`` (LSTM) / ``[(h_T,), ...]`` (GRU)
        list (what a session must store).

    Multi-device execution (``repro.launch.rnn_shardings``):
      * ``mesh``: a jax Mesh — batch rows (sessions × MC chains) partition
        over its data axes via ``shard_map`` around the Pallas kernels;
        wide-H stacks (and the reference backend) run GSPMD-partitioned
        instead.  Sharded output is **bit-identical** to the unsharded
        lengths-enabled run at any device count: masks key off global
        ``(seed, rows)`` coordinates, and the sharded path always runs the
        lengths-pinned graph family (full-T lengths are synthesized when
        ``lengths`` is None — pass ``lengths`` explicitly to compare
        against an unsharded run bit-for-bit).
      * ``policy``: a ``StackShardingPolicy`` (axis names, data/gspmd
        strategy, the wide-H threshold); None = the default policy.

    Serving precision (``repro.kernels.quantize.PRECISIONS``):
      * ``precision``: None (native dtypes — the default), ``"fp32"``,
        ``"bf16"`` (cast), ``"int8"`` / ``"int4"`` (per-output-channel
        quantized weights over bf16 activations, fp32 accumulate).  ``x_seq``
        is cast to the precision's activation dtype up front; the fp32
        master ``params`` are quantized/cast in-graph, never mutated.  The
        sequence kernels keep the int codes VMEM-resident and dequantize
        in-register; the step and reference backends apply the identical
        canonical dequant outside, so all three backends stay bit-identical
        at every precision.  The reference backend needs ``masks`` sampled
        in the activation dtype (``sample_stack_masks(..., dtype=act)``) —
        mask values carry the 1/(1-p) scale, which the kernels materialize
        in the activation dtype.

    Returns (outputs [B, T, H_last] if return_sequence else None,
             the last layer's state — ``(h_T, c_T)`` / ``(h_T,)`` — or the
             per-layer list).
    """
    _check_cell(cell)
    if precision is not None:
        # deferred: core must import without the kernels package eagerly
        from repro.kernels import quantize
        quantize.check_precision(precision)
        x_seq = x_seq.astype(quantize.activation_dtype(precision,
                                                       x_seq.dtype))
    if mesh is not None:
        # deferred: core must import without the launch layer (and jax
        # device state must stay untouched until a mesh actually exists)
        from repro.launch import rnn_shardings
        return rnn_shardings.run_stack_sharded(
            params, x_seq, masks, p, mesh=mesh, policy=policy,
            backend=backend, return_sequence=return_sequence, rows=rows,
            seed=seed, layer_offset=layer_offset, interpret=interpret,
            initial_state=initial_state, lengths=lengths,
            return_all_states=return_all_states, cell=cell,
            precision=precision)
    if backend != "reference":
        return _run_stack_pallas(params, x_seq, masks, p, backend=backend,
                                 return_sequence=return_sequence, rows=rows,
                                 seed=seed, layer_offset=layer_offset,
                                 interpret=interpret,
                                 initial_state=initial_state, lengths=lengths,
                                 return_all_states=return_all_states,
                                 cell=cell, precision=precision)
    if any(zx is IN_KERNEL_MASKS for zx, _ in masks):
        raise ValueError("stack_mask_plan() entries carry no mask values; "
                         "the reference backend needs sample_stack_masks()")
    if precision is not None:
        # Fake-quantize in core [G, I/H, H] layout (contraction axis 1) —
        # bit-identical (q, scale) to the kernels' [I/H, G, H] axis-0
        # quantization: the reductions cover the same element sets and every
        # other op is elementwise.
        params = [lp._replace(
            wx=quantize.fake_quant(lp.wx, precision, axis=1,
                                   act_dtype=x_seq.dtype),
            wh=quantize.fake_quant(lp.wh, precision, axis=1,
                                   act_dtype=x_seq.dtype))
            for lp in params]
    batch = x_seq.shape[0]
    dtype = x_seq.dtype
    # Under a serving precision the reference matches the kernels' 32-bit
    # cell-state policy: c seeds/carries/returns fp32 even for bf16 h.
    c_dtype = jnp.float32 if precision is not None else dtype
    carries = _seed_carries(params, initial_state, batch, dtype, cell,
                            c_dtype=c_dtype)
    xs = jnp.swapaxes(x_seq, 0, 1)  # [T, B, I] time-major for scan
    varlen = lengths is not None
    lens = lengths.astype(jnp.int32) if varlen else None
    gru = cell == "gru"
    # Student rows (mcd.STUDENT_ROW_FLAG) run deterministic on every backend;
    # the kernels read the flag off the int32 sign bit, the reference threads
    # an explicit per-row boolean into the cell steps.
    det = mcd.det_row_mask(rows) if rows is not None else None

    def step(carry, xt):
        x_t, t = xt
        new_carry = []
        inp = x_t
        for state, layer_params, (zx, zh) in zip(carry, params, masks):
            if gru:
                (h,) = state
                h_new = cells.gru_step(layer_params, h, inp, zx, zh, p,
                                       det=det)
                if varlen:
                    h_new = cells.freeze_rows_h(t, lens, h_new, h)
                new_state = (h_new,)
            else:
                h, c = state
                h_new, c_new = cells.lstm_step(layer_params, h, c, inp,
                                               zx, zh, p, det=det)
                if varlen:
                    h_new, c_new = cells.freeze_rows(t, lens, h_new, c_new,
                                                     h, c)
                new_state = (h_new, c_new)
            new_carry.append(new_state)
            inp = h_new
        return new_carry, (inp if return_sequence else jnp.zeros((0,), dtype))

    ts = jnp.arange(x_seq.shape[1], dtype=jnp.int32)
    final_carry, ys = jax.lax.scan(step, carries, (xs, ts))
    out = jnp.swapaxes(ys, 0, 1) if return_sequence else None
    return out, (final_carry if return_all_states else final_carry[-1])


def _seed_carries(params, initial_state, batch, dtype, cell="lstm",
                  c_dtype=None):
    """Per-layer state carries: zeros, or the resumed session state as-is.

    Cell-aware pytrees: LSTM layers carry ``(h, c)``, GRU layers ``(h,)``.
    ``c_dtype`` (default: ``dtype``) seeds the LSTM cell state — fp32 under
    a serving precision, matching the kernels' 32-bit cell-state policy.
    """
    parts = 1 if cell == "gru" else 2
    dtypes = (dtype, c_dtype or dtype)[:parts]
    carries = []
    for i, layer_params in enumerate(params):
        hidden = layer_params.wh.shape[-1]
        state = initial_state[i] if initial_state is not None else None
        if state is None:
            state = tuple(jnp.zeros((batch, hidden), dt) for dt in dtypes)
        carries.append(tuple(state))
    return carries


def _run_stack_pallas(params, x_seq, masks, p, *, backend, return_sequence,
                      rows, seed, layer_offset, interpret, initial_state,
                      lengths, return_all_states, cell, precision=None):
    """Kernel-backed stack: layers run whole-sequence, one after another.

    The wavefront trick above exists to fuse the scan body across layers; the
    kernels already fuse a full layer (step- or sequence-level), so here the
    cascade is the plain layer-by-layer composition — identical math.
    """
    from repro.kernels import ops  # deferred: core must import without pallas

    if backend not in ops.LSTM_BACKENDS:
        raise ValueError(f"backend must be one of {ops.LSTM_BACKENDS}, "
                         f"got {backend!r}")
    if rows is None:
        raise ValueError(f"backend={backend!r} needs the mask-stream `rows` "
                         "(the same ids passed to sample_stack_masks)")
    seq = backend == "pallas_seq"
    gru = cell == "gru"
    stack_layer = ops.gru_stack_layer if gru else ops.lstm_stack_layer
    inp = x_seq
    states = []
    for i, (layer_params, (zx, _)) in enumerate(zip(params, masks)):
        p_eff = p if zx is not None else 0.0
        state0 = initial_state[i] if initial_state is not None else None
        inp, carry = stack_layer(*layer_params, inp, rows, seed,
                                 layer_offset + i, p_eff, seq=seq,
                                 initial_state=state0,
                                 lengths=lengths, precision=precision,
                                 interpret=interpret)
        states.append(carry)
    out = inp if return_sequence else None
    if return_all_states:
        # Session-resume form: LSTM c stays fp32 (the kernels' carry dtype),
        # so a chunk boundary round-trips the cell state losslessly; the GRU
        # carry is h in the activation dtype already.
        return out, states
    if gru:
        return out, states[-1]                  # (h_T,) — no dtype to match
    # Match the reference carry contract: c in the input dtype (the kernels
    # hand back their fp32 accumulator).  Under a serving precision the
    # reference itself carries c in fp32, so no cast.
    hT, cT = states[-1]
    return out, (hT, cT if precision is not None else cT.astype(x_seq.dtype))

"""Cascaded LSTM stacks with time-step scanning and MCD mask pre-sampling.

Structure mirrors the paper's pipelined cascade (Fig. 5): layer i's output at
time t feeds layer i+1 at time t — on the FPGA that is wave pipelining; under
XLA it is a fused scan body where all layers advance one step per iteration
(the scan carries every layer's (h, c)).  This "wavefront" scan is
mathematically identical to running layers sequentially but exposes the same
cross-layer parallelism the paper's II-balancing exploits, and it keeps the
HLO small (one scan) for pod-scale compilation.

Mask pre-sampling (paper Fig. 4 "overlap"): all masks for a forward pass are
produced *before* the scan from the counter RNG — since they are tied across
T they carry no time dimension, and since the RNG is stateless the
"pre-sampling" costs a few VPU ops, not on-chip FIFO memory.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import cells, mcd


def init_stack(key: jax.Array, in_dim: int, hiddens: Sequence[int],
               dtype=jnp.float32) -> list[cells.LSTMParams]:
    params = []
    dims = [in_dim, *hiddens]
    for i, (d_in, d_h) in enumerate(zip(dims[:-1], dims[1:])):
        key, sub = jax.random.split(key)
        params.append(cells.init_lstm(sub, d_in, d_h, dtype))
    return params


def sample_stack_masks(cfg: mcd.MCDConfig, rows: jax.Array, in_dim: int,
                       hiddens: Sequence[int], *, layer_offset: int = 0,
                       dtype=jnp.float32):
    """Pre-sample (z_x, z_h) per layer; None where the layer is pointwise."""
    masks = []
    dims = [in_dim, *hiddens]
    for i, (d_in, d_h) in enumerate(zip(dims[:-1], dims[1:])):
        layer = layer_offset + i
        if cfg.any_bayesian and cfg.bayesian(layer) and cfg.p > 0.0:
            masks.append(mcd.lstm_gate_masks(cfg.seed, layer, rows, d_in, d_h,
                                             cfg.p, dtype=dtype))
        else:
            masks.append((None, None))
    return masks


#: Sentinel masks entry: the layer is Bayesian but its masks are recomputed
#: inside the Pallas kernel — no tensors to materialize (see stack_mask_plan).
IN_KERNEL_MASKS = object()


def stack_mask_plan(cfg: mcd.MCDConfig, n_layers: int, *,
                    layer_offset: int = 0):
    """Per-layer Bayesian on/off in the shape ``run_stack`` expects of
    ``masks``, without materializing any mask tensors.

    Use with the Pallas backends, which recompute masks in-kernel from the
    counter PRNG and only need to know *whether* each layer masks — passing
    :func:`sample_stack_masks` output also works but pays the paper's
    mask-buffer cost the fused kernels exist to avoid.
    """
    return [(IN_KERNEL_MASKS, None)
            if cfg.any_bayesian and cfg.bayesian(layer_offset + i)
            and cfg.p > 0.0 else (None, None)
            for i in range(n_layers)]


def run_stack(params: Sequence[cells.LSTMParams], x_seq: jax.Array,
              masks, p: float, *, return_sequence: bool = True,
              backend: str = "reference", rows: jax.Array | None = None,
              seed=0, layer_offset: int = 0, interpret: bool | None = None,
              initial_state=None, lengths: jax.Array | None = None,
              return_all_states: bool = False):
    """Run a cascaded LSTM stack over a [B, T, I] sequence.

    Backends (``repro.kernels.ops.LSTM_BACKENDS``):
      * ``"reference"``: the jnp wavefront scan below, consuming the
        pre-sampled ``masks`` — sharding-friendly, the numerical oracle.
      * ``"pallas_step"``: per-timestep fused kernel scanned over T.
      * ``"pallas_seq"``: sequence-fused kernel, weights resident across T.
    The Pallas backends recompute masks in-kernel from the counter PRNG, so
    they ignore the pre-sampled mask *values* and instead need the stream
    coordinates: ``rows`` (as passed to :func:`sample_stack_masks`), ``seed``
    (``cfg.seed``) and ``layer_offset``.  A layer whose ``masks`` entry is
    ``(None, None)`` runs with p=0 on every backend.

    Streaming session state (all three backends):
      * ``initial_state``: per-layer list of ``(h, c)`` pairs resuming a
        previous chunk's carry (``None`` entries or ``None`` itself = zeros).
        Feed back exactly what ``return_all_states=True`` returned — the
        carry dtypes round-trip losslessly, keeping chunked == unchunked
        bit-identical per backend (Pallas backends hand back ``c`` in fp32,
        the 32-bit cell-state policy; reference in its carry dtype).
      * ``lengths``: int [B] freezing each row's state once ``t >= length``
        so ragged chunks can pad to a common T in one batched launch.
      * ``return_all_states=True``: the second return value becomes the full
        per-layer ``[(h_T, c_T), ...]`` list (what a session must store).

    Returns (outputs [B, T, H_last] if return_sequence else None,
             (h_T, c_T) of the last layer — or the per-layer list).
    """
    if backend != "reference":
        return _run_stack_pallas(params, x_seq, masks, p, backend=backend,
                                 return_sequence=return_sequence, rows=rows,
                                 seed=seed, layer_offset=layer_offset,
                                 interpret=interpret,
                                 initial_state=initial_state, lengths=lengths,
                                 return_all_states=return_all_states)
    if any(zx is IN_KERNEL_MASKS for zx, _ in masks):
        raise ValueError("stack_mask_plan() entries carry no mask values; "
                         "the reference backend needs sample_stack_masks()")
    batch = x_seq.shape[0]
    dtype = x_seq.dtype
    carries = _seed_carries(params, initial_state, batch, dtype)
    xs = jnp.swapaxes(x_seq, 0, 1)  # [T, B, I] time-major for scan
    varlen = lengths is not None
    lens = lengths.astype(jnp.int32) if varlen else None

    def step(carry, xt):
        x_t, t = xt
        new_carry = []
        inp = x_t
        for (h, c), layer_params, (zx, zh) in zip(carry, params, masks):
            h_new, c_new = cells.lstm_step(layer_params, h, c, inp, zx, zh, p)
            if varlen:
                h_new, c_new = cells.freeze_rows(t, lens, h_new, c_new, h, c)
            new_carry.append((h_new, c_new))
            inp = h_new
        return new_carry, (inp if return_sequence else jnp.zeros((0,), dtype))

    ts = jnp.arange(x_seq.shape[1], dtype=jnp.int32)
    final_carry, ys = jax.lax.scan(step, carries, (xs, ts))
    out = jnp.swapaxes(ys, 0, 1) if return_sequence else None
    return out, (final_carry if return_all_states else final_carry[-1])


def _seed_carries(params, initial_state, batch, dtype):
    """Per-layer (h, c) carries: zeros, or the resumed session state as-is."""
    carries = []
    for i, layer_params in enumerate(params):
        hidden = layer_params.wh.shape[-1]
        state = initial_state[i] if initial_state is not None else None
        if state is None:
            state = (jnp.zeros((batch, hidden), dtype),
                     jnp.zeros((batch, hidden), dtype))
        carries.append(tuple(state))
    return carries


def _run_stack_pallas(params, x_seq, masks, p, *, backend, return_sequence,
                      rows, seed, layer_offset, interpret, initial_state,
                      lengths, return_all_states):
    """Kernel-backed stack: layers run whole-sequence, one after another.

    The wavefront trick above exists to fuse the scan body across layers; the
    kernels already fuse a full layer (step- or sequence-level), so here the
    cascade is the plain layer-by-layer composition — identical math.
    """
    from repro.kernels import ops  # deferred: core must import without pallas

    if backend not in ops.LSTM_BACKENDS:
        raise ValueError(f"backend must be one of {ops.LSTM_BACKENDS}, "
                         f"got {backend!r}")
    if rows is None:
        raise ValueError(f"backend={backend!r} needs the mask-stream `rows` "
                         "(the same ids passed to sample_stack_masks)")
    seq = backend == "pallas_seq"
    inp = x_seq
    states = []
    for i, (layer_params, (zx, _)) in enumerate(zip(params, masks)):
        p_eff = p if zx is not None else 0.0
        state0 = initial_state[i] if initial_state is not None else None
        inp, carry = ops.lstm_stack_layer(*layer_params, inp, rows, seed,
                                          layer_offset + i, p_eff, seq=seq,
                                          initial_state=state0,
                                          lengths=lengths,
                                          interpret=interpret)
        states.append(carry)
    out = inp if return_sequence else None
    if return_all_states:
        # Session-resume form: c stays fp32 (the kernels' carry dtype), so a
        # chunk boundary round-trips the cell state losslessly.
        return out, states
    # Match the reference carry contract: c in the input dtype (the kernels
    # hand back their fp32 accumulator).
    hT, cT = states[-1]
    return out, (hT, cT.astype(x_seq.dtype))

"""Monte-Carlo-Dropout engine (Gal & Ghahramani, as deployed by the paper).

Semantics reproduced exactly from paper §II-B:

* A Bernoulli *keep*-mask ``z ~ Bern(1 - p)`` is sampled **once per MC sample
  per layer** and **tied across all T time steps** of that sample.
* For LSTM layers the input ``x_t`` and hidden state ``h_{t-1}`` each get a
  **separate mask per gate** (z_x^{i,f,g,o} ∈ R^I, z_h^{i,f,g,o} ∈ R^H).
* Dropout may be enabled per layer (placement string ``B``, e.g. ``"YNYN"``),
  giving partially-Bayesian architectures.
* The prediction is the average of S stochastic forward passes.

Systems note (the paper's memory-challenge, solved the TPU way): masks are
never *stored* anywhere.  Because :mod:`repro.core.prng` is a stateless
counter RNG, a mask is a pure function of ``(seed, sample, layer, site, gate,
batch-row, feature)`` and is **recomputed in-register wherever it is needed**
— inside the fused Pallas kernel, inside each TP/EP shard, and at every decode
step of a serving request (tying across decode steps = tying across T).  The
paper needed a SIPO+FIFO to buffer pre-sampled bits; on TPU the recompute is
~10 VPU ops and replaces that on-chip memory entirely.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import prng

# Stream-id namespaces (stable constants — part of the checkpoint contract:
# changing them changes every mask in a restarted run).
KIND_X = 0        # LSTM input-side gate masks
KIND_H = 1        # LSTM hidden-side gate masks
KIND_FEAT = 2     # generic per-site feature mask (transformer/ssm blocks)

GATES = ("i", "f", "g", "o")
GRU_GATES = ("r", "z", "n")   # GRU gate ids 0..2 in the same (kind, gate)
                              # coordinate space — a model is one cell type,
                              # so LSTM gate i and GRU gate r never coexist
                              # under the same (seed, layer).


def parse_placement(b: str | Sequence[bool]) -> tuple[bool, ...]:
    """Parse the paper's B-string (``"YNYN"``) into per-layer booleans."""
    if isinstance(b, str):
        bad = set(b.upper()) - {"Y", "N"}
        if bad:
            raise ValueError(f"placement must be Y/N string, got {b!r}")
        return tuple(c == "Y" for c in b.upper())
    return tuple(bool(x) for x in b)


def placement_str(b: Sequence[bool]) -> str:
    return "".join("Y" if x else "N" for x in b)


@dataclasses.dataclass(frozen=True)
class MCDConfig:
    """Algorithmic parameters of the Bayesian architecture (paper's A/B/S).

    Attributes:
      p: dropout probability (paper hardware fixed 0.125; we allow any p).
      placement: per-layer Bayesian on/off (paper's B, e.g. "YNYN").
      n_samples: S, number of MC forward passes at inference.
      seed: base seed for the counter RNG.  Together with (sample, layer,
        site) it fully determines every mask — restart-reproducible.
    """
    p: float = 0.125
    placement: tuple[bool, ...] = ()
    n_samples: int = 30
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.p < 1.0:
            raise ValueError(f"p must be in [0,1), got {self.p}")
        object.__setattr__(self, "placement", parse_placement(self.placement))

    def bayesian(self, layer: int) -> bool:
        """Is layer Bayesian?  The B-string cycles (e.g. "YN" = alternating)."""
        if not self.placement:
            return False
        return self.placement[layer % len(self.placement)]

    @property
    def any_bayesian(self) -> bool:
        return any(self.placement)

    def replace(self, **kw) -> "MCDConfig":
        return dataclasses.replace(self, **kw)


def mask_key(seed, layer: int, kind: int, gate: int = 0) -> jax.Array:
    """uint32 stream key for one mask site."""
    return prng.fold_ids(seed, layer, kind, gate)


def feature_mask(seed, layer: int, rows: jax.Array, n_feat: int,
                 p: float, *, kind: int = KIND_FEAT, gate: int = 0,
                 dtype=jnp.float32) -> jax.Array:
    """Keep-mask of shape ``rows.shape + (n_feat,)`` tied across time.

    ``rows`` carries the global (sample·batch) row index of each element so
    that every MC sample / batch row draws an independent mask while remaining
    a pure function of its coordinates (sharding- and restart-stable).
    """
    key = mask_key(seed, layer, kind, gate)
    rows = jnp.asarray(rows, jnp.uint32)[..., None]
    cols = jnp.arange(n_feat, dtype=jnp.uint32)
    idx = rows * jnp.uint32(n_feat) + cols
    bits = prng._mix32(jnp.asarray(key, jnp.uint32) ^ prng._mix32(idx))
    return (bits >= prng.bernoulli_keep_threshold(p)).astype(dtype)


def lstm_gate_masks(seed, layer: int, rows: jax.Array, in_dim: int,
                    hidden_dim: int, p: float, dtype=jnp.float32):
    """The paper's eight per-gate masks for one LSTM layer.

    Returns ``(z_x, z_h)`` with shapes ``rows.shape + (4, in_dim)`` and
    ``rows.shape + (4, hidden_dim)`` — one mask per gate (i, f, g, o), tied
    across all T time steps (no time dimension).
    """
    zx = jnp.stack([feature_mask(seed, layer, rows, in_dim, p, kind=KIND_X,
                                 gate=g, dtype=dtype) for g in range(4)], axis=-2)
    zh = jnp.stack([feature_mask(seed, layer, rows, hidden_dim, p, kind=KIND_H,
                                 gate=g, dtype=dtype) for g in range(4)], axis=-2)
    return zx, zh


def gru_gate_masks(seed, layer: int, rows: jax.Array, in_dim: int,
                   hidden_dim: int, p: float, dtype=jnp.float32):
    """The six per-gate masks for one GRU layer (paper §III-A drop-in).

    Returns ``(z_x, z_h)`` with shapes ``rows.shape + (3, in_dim)`` and
    ``rows.shape + (3, hidden_dim)`` — one mask per gate (r, z, n), tied
    across all T time steps, drawn from the same ``(kind, gate)`` stream
    namespace as the LSTM masks.
    """
    zx = jnp.stack([feature_mask(seed, layer, rows, in_dim, p, kind=KIND_X,
                                 gate=g, dtype=dtype) for g in range(3)], axis=-2)
    zh = jnp.stack([feature_mask(seed, layer, rows, hidden_dim, p, kind=KIND_H,
                                 gate=g, dtype=dtype) for g in range(3)], axis=-2)
    return zx, zh


def apply_mask(x: jax.Array, mask: jax.Array | None, p: float) -> jax.Array:
    """Inverted-dropout application ``x · z / (1-p)`` (broadcasts over time)."""
    if mask is None or p == 0.0:
        return x
    scale = jnp.asarray(1.0 / (1.0 - p), x.dtype)
    return x * mask.astype(x.dtype) * scale


def sample_rows(batch: int, n_samples: int) -> jax.Array:
    """Global row ids for S MC samples folded into the batch axis.

    Row id = ``s * batch + b`` — each (sample, batch-element) pair gets an
    independent mask stream; reshaping [S·B, ...] → [S, B, ...] after the
    forward pass recovers the per-sample axis.
    """
    return jnp.arange(n_samples * batch, dtype=jnp.uint32)


#: High bit of a row id marks a *deterministic* (distilled-student) row: the
#: RNN stack runs it with dropout off (identity instead of mask·scale) while
#: normal rows in the same launch keep their Bayesian draw untouched.  Row
#: allocators therefore stay below 2^31, and stripping the flag recovers the
#: allocation-order id.  Part of the snapshot contract, like the KIND_* ids.
STUDENT_ROW_FLAG = 0x8000_0000


def student_row(row: int) -> int:
    """Tag an allocator row id as deterministic (student fast path)."""
    return int(row) | STUDENT_ROW_FLAG


def base_row(row: int) -> int:
    """Strip a possible student flag, recovering the allocator id."""
    return int(row) & (STUDENT_ROW_FLAG - 1)


def is_student_row(row: int) -> bool:
    return bool(int(row) & STUDENT_ROW_FLAG)


def det_row_mask(rows: jax.Array) -> jax.Array:
    """Boolean [rows...] — True where the row id carries the student flag.

    Kernels view rows as int32, where the flag is simply the sign bit; this
    helper is the host/reference-side equivalent.
    """
    return jnp.asarray(rows, jnp.uint32) >= jnp.uint32(STUDENT_ROW_FLAG)

"""Recurrent autoencoder for anomaly detection (paper §III-C, Fig. 6a).

Encoder: NL cascaded LSTMs, last layer hidden size H/2 (the bottleneck —
"reduced dimensionality R^{H/2} in order to learn to convey only the most
relevant information").  The bottleneck h_T is repeated T times ("effectively
achieved by caching it for exactly T time steps") and decoded by NL LSTMs of
hidden size H, followed by a temporal dense layer applied at every step.

The head is heteroscedastic (mean + log-variance per feature) so the model
expresses *aleatoric* uncertainty; *epistemic* uncertainty comes from the S
MCD passes — together they are the paper's Fig. 1 "total uncertainty".
MCD placement B indexes the 2·NL LSTM layers encoder-first (paper's "YNYN").
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import linear, mcd, rnn


@dataclasses.dataclass(frozen=True)
class AutoencoderConfig:
    input_dim: int = 1
    hidden: int = 16          # H
    num_layers: int = 2       # NL (per encoder / per decoder)
    cell: str = "lstm"        # recurrent unit (rnn.CELLS); §III-A GRU drop-in
    mcd: mcd.MCDConfig = dataclasses.field(
        default_factory=lambda: mcd.MCDConfig(placement="YNYN"))
    heteroscedastic: bool = True
    # Windowed decoder (the cheap-AE serving path): replay the bottleneck
    # over only min(T, decode_window) positions instead of the full
    # repeat-T cache.  The encoder (and therefore the rolling bottleneck a
    # streaming session carries) is untouched, and the decoder replay at
    # position t depends only on the bottleneck and the time-invariant
    # per-row masks — so the windowed reconstruction is bit-identical to
    # the first min(T, W) positions of the full replay, on every backend.
    # None: full replay (the paper's repeat-T decoder).
    decode_window: int | None = None

    def __post_init__(self):
        if self.decode_window is not None and self.decode_window < 1:
            raise ValueError(f"decode_window must be >= 1 or None, "
                             f"got {self.decode_window}")

    @property
    def encoder_hiddens(self) -> tuple[int, ...]:
        return tuple([self.hidden] * (self.num_layers - 1) + [self.hidden // 2])

    @property
    def decoder_hiddens(self) -> tuple[int, ...]:
        return tuple([self.hidden] * self.num_layers)


def init(key: jax.Array, cfg: AutoencoderConfig, dtype=jnp.float32) -> dict[str, Any]:
    k_enc, k_dec, k_head = jax.random.split(key, 3)
    out_dim = 2 * cfg.input_dim if cfg.heteroscedastic else cfg.input_dim
    return {
        "encoder": rnn.init_stack(k_enc, cfg.input_dim, cfg.encoder_hiddens,
                                  dtype, cell=cfg.cell),
        "decoder": rnn.init_stack(k_dec, cfg.hidden // 2, cfg.decoder_hiddens,
                                  dtype, cell=cfg.cell),
        "head": linear.init_dense(k_head, cfg.hidden, out_dim, dtype),
    }


def apply(params: dict[str, Any], x_seq: jax.Array, rows: jax.Array,
          cfg: AutoencoderConfig, *, backend: str = "reference",
          initial_state=None, lengths: jax.Array | None = None,
          return_state: bool = False, mesh=None, policy=None,
          precision: str | None = None, return_decoded: bool = False):
    """Forward pass for one set of MCD masks.

    Args:
      x_seq: [B, T, I] input sequences.
      rows: [B] global (sample·batch) row ids keying the mask streams.
      backend: stack execution path (see :func:`repro.core.rnn.run_stack`);
        all backends draw the same masks.
      initial_state: per-layer encoder ``(h, c)`` list from a previous chunk
        (streaming resumption — the running bottleneck keeps integrating).
      lengths: per-row valid lengths when ragged chunks pad to a common T.
      return_state: also return the per-layer encoder states to carry.
      mesh, policy: shard both stacks over devices (batch rows over the
        mesh's data axes — ``repro.launch.rnn_shardings``); bit-identical
        to the unsharded lengths-enabled pass.
      precision: serving precision of both stacks (``quantize.PRECISIONS``;
        None = native dtypes) — input cast to the activation dtype up front
        (reference masks then sample in it), fp32 master weights
        quantized/cast in-graph; the dense head stays fp32.
      return_decoded: also return the decoder's hidden sequence ``dec_out``
        [B, W, H] (before the dense head) — the feature the distilled
        student's per-position uncertainty head reads
        (:mod:`repro.core.distill`).  Appended after ``log_var``.
    Returns:
      (mean [B, W, I], log_var [B, W, I] or None)[, encoder states], where
      ``W = min(T, cfg.decode_window or T)`` — the full T unless the config
      asks for a windowed decode.
      When streaming, each chunk is reconstructed from the *running*
      bottleneck h_T (encoder state carries across chunks; the decoder
      replays the current bottleneck over the chunk's T — per-chunk
      reconstruction of an unbounded signal).
    """
    T = x_seq.shape[1]
    if precision is not None:
        from repro.kernels import quantize
        x_seq = x_seq.astype(quantize.activation_dtype(precision,
                                                       x_seq.dtype))
    if backend == "reference":
        enc_masks = rnn.sample_stack_masks(cfg.mcd, rows, cfg.input_dim,
                                           cfg.encoder_hiddens, layer_offset=0,
                                           dtype=x_seq.dtype, cell=cfg.cell)
        dec_masks = rnn.sample_stack_masks(cfg.mcd, rows, cfg.hidden // 2,
                                           cfg.decoder_hiddens,
                                           layer_offset=cfg.num_layers,
                                           dtype=x_seq.dtype, cell=cfg.cell)
    else:  # Pallas backends regenerate masks in-kernel — nothing to sample.
        enc_masks = rnn.stack_mask_plan(cfg.mcd, cfg.num_layers)
        dec_masks = rnn.stack_mask_plan(cfg.mcd, cfg.num_layers,
                                        layer_offset=cfg.num_layers)
    # Encode → bottleneck h_T ∈ R^{H/2}; the decoder starts only after the
    # encoder finishes (paper: latency = 2 × Lat_design for the AE).
    _, enc_states = rnn.run_stack(params["encoder"], x_seq, enc_masks,
                                  cfg.mcd.p, return_sequence=False,
                                  backend=backend, rows=rows,
                                  seed=cfg.mcd.seed,
                                  initial_state=initial_state,
                                  lengths=lengths, return_all_states=True,
                                  cell=cfg.cell, mesh=mesh, policy=policy,
                                  precision=precision)
    h_T = enc_states[-1][0]
    # Repeat the encoding T times (cached-replay in hardware).  The decoder
    # is replayed fresh per chunk — only encoder state streams forward — but
    # it inherits `lengths` so streaming stays on the pinned graph family
    # end-to-end (rows past their own length are sliced off by the caller).
    # Windowed decoder: replay only the newest min(T, W) positions.  The
    # replay at position t sees the same bottleneck and the same
    # time-invariant masks whatever the launch T, so truncating the replay
    # is bit-exact on the positions it does produce (config docstring).
    W = T if cfg.decode_window is None else min(T, cfg.decode_window)
    dec_in = jnp.broadcast_to(h_T[:, None, :], (h_T.shape[0], W, h_T.shape[1]))
    dec_lengths = (lengths if lengths is None or W == T
                   else jnp.minimum(lengths, W))
    dec_out, _ = rnn.run_stack(params["decoder"], dec_in, dec_masks, cfg.mcd.p,
                               backend=backend, rows=rows, seed=cfg.mcd.seed,
                               layer_offset=cfg.num_layers, lengths=dec_lengths,
                               cell=cfg.cell, mesh=mesh, policy=policy,
                               precision=precision)
    y = linear.dense(params["head"], dec_out)
    if cfg.heteroscedastic:
        mean, log_var = jnp.split(y, 2, axis=-1)
        out = mean, jnp.clip(log_var, -10.0, 10.0)
    else:
        out = y, None
    if return_decoded:
        out = (*out, dec_out)
    return (*out, enc_states) if return_state else out


def gaussian_nll(mean: jax.Array, log_var: jax.Array | None,
                 target: jax.Array) -> jax.Array:
    """Per-example Gaussian NLL (the paper's Fig. 1 fit metric)."""
    if log_var is None:
        return 0.5 * jnp.mean((mean - target) ** 2, axis=(-2, -1))
    inv_var = jnp.exp(-log_var)
    return 0.5 * jnp.mean((mean - target) ** 2 * inv_var + log_var
                          + jnp.log(2.0 * jnp.pi), axis=(-2, -1))

"""LSTM / GRU cells with the paper's per-gate MCD mask views.

Paper §II-A decouples the input and hidden state per gate
(x^i, x^f, x^g, x^o = x;  h^i, ... = h) precisely so that MCD can mask each
view independently.  We keep that decoupling: weights are stored as
``[4, in, hidden]`` stacks (gate axis first) and the masked views are applied
per-gate before the gate matmuls.

On the FPGA each gate had its own MVM unit (Fig. 2).  On TPU the four gate
matmuls are a single ``[B,4,I] × [4,I,H]`` batched contraction — one MXU pass,
the fusion analogue of the paper's 1:1 DSP unrolling.  A Pallas-fused version
of the full step (masks + matmuls + nonlinearities + cell update) lives in
``repro.kernels.mcd_lstm``; this module is the composable/jnp path and the
numerical ground truth for it.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import mcd


class LSTMParams(NamedTuple):
    wx: jax.Array  # [4, in_dim, hidden]
    wh: jax.Array  # [4, hidden, hidden]
    b: jax.Array   # [4, hidden]


def init_lstm(key: jax.Array, in_dim: int, hidden: int,
              dtype=jnp.float32) -> LSTMParams:
    kx, kh = jax.random.split(key)
    sx = (6.0 / (in_dim + hidden)) ** 0.5
    sh = (6.0 / (2 * hidden)) ** 0.5
    wx = jax.random.uniform(kx, (4, in_dim, hidden), dtype, -sx, sx)
    wh = jax.random.uniform(kh, (4, hidden, hidden), dtype, -sh, sh)
    b = jnp.zeros((4, hidden), dtype)
    # forget-gate bias 1.0 (standard recurrent practice)
    b = b.at[1].set(jnp.ones((hidden,), dtype))
    return LSTMParams(wx, wh, b)


def freeze_rows(t, lengths, h_new, c_new, h_old, c_old):
    """Per-row streaming freeze: keep the old carry once ``t >= lengths``.

    Single source for the ragged-chunk select used by the reference scan,
    the step-kernel scan and the sequence-kernel oracle.  The exact
    formulation (one ``<`` compare, two selects on the *new* values) is part
    of the bit-identity contract across backends — see docs/kernels.md
    "numerics pin"; don't restate it inline elsewhere.
    """
    live = (t < lengths.astype(jnp.int32))[:, None]
    return jnp.where(live, h_new, h_old), jnp.where(live, c_new, c_old)


def freeze_rows_h(t, lengths, h_new, h_old):
    """:func:`freeze_rows` for cells whose carry is ``h`` alone (GRU)."""
    live = (t < lengths.astype(jnp.int32))[:, None]
    return jnp.where(live, h_new, h_old)


def gate_stacked(params):
    """Pallas-kernel weight layout: ``[G, in, H] → ([in, G, H], [H, G, H], b)``.

    The kernels tile the hidden axis, so each tile wants the contiguous
    G-gate stack for its hidden columns (gate axis second, not first).
    Works for both cells: G=4 (:class:`LSTMParams`) and G=3
    (:class:`GRUParams`).
    """
    return (jnp.moveaxis(params.wx, 0, 1), jnp.moveaxis(params.wh, 0, 1),
            params.b)


def _pin_operands(*ops):
    """Materialize sub-fp32 matmul operands at their stated dtype.

    Inside jit, XLA fuses elementwise producers (mask·1/(1-p) scaling, fp32→
    bf16 weight/input casts) into the dot and evaluates the chain at the
    dot's higher internal precision — silently skipping the bf16 rounding
    the Pallas kernels apply when they materialize the same intermediates in
    registers.  An optimization barrier pins each operand to its rounded
    value, keeping the reference backend bit-identical to the kernels for
    bf16 activations (the int8/int4/bf16 serving precisions).  fp32 operands
    pass through untouched — rounding is unaffected, so no barrier tax.
    """
    if any(o.dtype != jnp.float32 for o in ops):
        return jax.lax.optimization_barrier(ops)
    return ops


def lstm_step(params: LSTMParams, h: jax.Array, c: jax.Array, x: jax.Array,
              zx: jax.Array | None, zh: jax.Array | None, p: float,
              compute_dtype=None, det: jax.Array | None = None):
    """One LSTM time step with per-gate MCD masks (paper's Eq. block + DX units).

    Args:
      h, c: [B, H] carry.  x: [B, I] input at time t.
      zx: [B, 4, I] or None; zh: [B, 4, H] or None — keep-masks tied across T.
      p: dropout probability (for inverted scaling).
      det: [B] bool or None — True rows run deterministic (student fast path):
        the mask·scale is replaced by the raw view for that row only, exactly
        as the kernels do for rows carrying :data:`repro.core.mcd.STUDENT_ROW_FLAG`.
    Returns:
      (h_new, c_new), each [B, H].  c is accumulated in fp32 (the paper keeps
      c in 32-bit while everything else is 16-bit — same policy here).
    """
    cd = compute_dtype or x.dtype
    wx, wh, b = params
    # Per-gate masked views: [B, 4, I] and [B, 4, H].
    xr = jnp.broadcast_to(x[:, None, :], (x.shape[0], 4, x.shape[1])).astype(cd)
    hr = jnp.broadcast_to(h[:, None, :], (h.shape[0], 4, h.shape[1])).astype(cd)
    xg = mcd.apply_mask(xr, zx, p)
    hg = mcd.apply_mask(hr, zh, p)
    if det is not None:
        xg = jnp.where(det[:, None, None], xr, xg)
        hg = jnp.where(det[:, None, None], hr, hg)
    xg, hg, wxc, whc = _pin_operands(xg, hg, wx.astype(cd), wh.astype(cd))
    gates = (jnp.einsum("bgi,gih->bgh", xg, wxc,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bgh,ghk->bgk", hg, whc,
                          preferred_element_type=jnp.float32)
             + b.astype(jnp.float32))
    i = jax.nn.sigmoid(gates[:, 0])
    f = jax.nn.sigmoid(gates[:, 1])
    g = jnp.tanh(gates[:, 2])
    o = jax.nn.sigmoid(gates[:, 3])
    c_new = f * c.astype(jnp.float32) + i * g           # fp32 cell state
    h_new = (o * jnp.tanh(c_new)).astype(h.dtype)
    return h_new, c_new.astype(c.dtype)


class GRUParams(NamedTuple):
    wx: jax.Array  # [3, in_dim, hidden]
    wh: jax.Array  # [3, hidden, hidden]
    b: jax.Array   # [3, hidden]


def init_gru(key: jax.Array, in_dim: int, hidden: int,
             dtype=jnp.float32) -> GRUParams:
    kx, kh = jax.random.split(key)
    sx = (6.0 / (in_dim + hidden)) ** 0.5
    sh = (6.0 / (2 * hidden)) ** 0.5
    return GRUParams(
        jax.random.uniform(kx, (3, in_dim, hidden), dtype, -sx, sx),
        jax.random.uniform(kh, (3, hidden, hidden), dtype, -sh, sh),
        jnp.zeros((3, hidden), dtype))


def gru_step(params: GRUParams, h: jax.Array, x: jax.Array,
             zx: jax.Array | None, zh: jax.Array | None, p: float,
             compute_dtype=None, det: jax.Array | None = None):
    """GRU step with per-gate masks (paper §III-A notes GRU drops in directly).

    Args:
      h: [B, H] carry (the GRU's entire recurrent state — no cell state).
      x: [B, I] input at time t.
      zx: [B, 3, I] or None; zh: [B, 3, H] or None — keep-masks tied across T,
        gate order (r, z, n).
      p: dropout probability (for inverted scaling).
      det: [B] bool or None — True rows run deterministic (student fast path),
        mirroring the kernels' :data:`repro.core.mcd.STUDENT_ROW_FLAG` rows.
    Returns:
      h_new [B, H].  Same dtype policy as :func:`lstm_step`: inputs and
      weights compute in ``compute_dtype`` (default: x's dtype, so bf16 in →
      bf16 matmuls) while the gate accumulations, bias adds and the convex
      ``(1-z)·n + z·h`` update run in fp32.
    """
    cd = compute_dtype or x.dtype
    wx, wh, b = params
    xr = jnp.broadcast_to(x[:, None, :], (x.shape[0], 3, x.shape[1])).astype(cd)
    hr = jnp.broadcast_to(h[:, None, :], (h.shape[0], 3, h.shape[1])).astype(cd)
    xg = mcd.apply_mask(xr, zx, p)
    hg = mcd.apply_mask(hr, zh, p)
    if det is not None:
        xg = jnp.where(det[:, None, None], xr, xg)
        hg = jnp.where(det[:, None, None], hr, hg)
    xg, hg, wxc, whc = _pin_operands(xg, hg, wx.astype(cd), wh.astype(cd))
    gx = jnp.einsum("bgi,gih->bgh", xg, wxc,
                    preferred_element_type=jnp.float32)
    gh = jnp.einsum("bgh,ghk->bgk", hg, whc,
                    preferred_element_type=jnp.float32)
    bf = b.astype(jnp.float32)
    r = jax.nn.sigmoid(gx[:, 0] + gh[:, 0] + bf[0])
    zt = jax.nn.sigmoid(gx[:, 1] + gh[:, 1] + bf[1])
    # The candidate's bias stays outside the reset product (r gates only the
    # recurrent matmul) — the kernels replicate this placement exactly.
    n = jnp.tanh(gx[:, 2] + r * gh[:, 2] + bf[2])
    h_new = (1.0 - zt) * n + zt * h.astype(jnp.float32)
    return h_new.astype(h.dtype)

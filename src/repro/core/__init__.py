"""Core library: the paper's contribution (MCD Bayesian recurrent inference).

Public surface:
  MCDConfig, parse_placement       — algorithmic Bayesian parameters (p, B, S)
  predict                          — S-sample predictive engine
  AutoencoderConfig / ClassifierConfig + init/apply — the paper's two models
  regression_summary / classification_summary — uncertainty decomposition
"""

from repro.core.mcd import MCDConfig, parse_placement, placement_str  # noqa: F401
from repro.core.bayesian import predict  # noqa: F401
from repro.core.autoencoder import AutoencoderConfig  # noqa: F401
from repro.core.classifier import ClassifierConfig  # noqa: F401
from repro.core.uncertainty import (  # noqa: F401
    regression_summary, classification_summary,
)

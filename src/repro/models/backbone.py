"""Generic stage-scanned backbone: one engine for all 10 assigned archs.

A model = embedding + a sequence of *stages*; each stage scans a repeated
period of blocks (see ``config.Stage``).  Scanning stacked parameters keeps
the HLO one-period-sized regardless of depth — a 72-layer Jamba compiles the
same program as a 8-layer one — which is what makes 512-device dry-runs
tractable and is also how the II-balanced cascade of the paper shows up here:
every scan step advances the whole period wavefront.

Three entry points per model:
  forward      — full-sequence (train / prefill shapes)
  prefill      — forward + cache/state construction for serving
  decode_step  — single-token with KV caches / SSM states

MCD: Bayesian placement (B) is static per pattern position (cycling the
B-string); mask *values* vary per layer via the traced layer index folded
into the counter-RNG key.  Masks for decode are recomputed per step from the
same key — tied across decode steps by construction (paper's tied-across-T).
"""

from __future__ import annotations

import contextlib
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers, mamba2, mla, moe
from repro.models.config import ArchConfig, Stage

# Trace-time activation-sharding override (§Perf: Megatron-style sequence
# parallelism).  When set, block outputs are constrained to shard the
# sequence dim over the TP axis — GSPMD then inserts reduce-scatter +
# all-gather pairs instead of full all-reduces (≈2× less TP traffic).
_ACT_OVERRIDE: dict = {}


@contextlib.contextmanager
def activation_sharding(spec=None):
    old = dict(_ACT_OVERRIDE)
    _ACT_OVERRIDE.update(spec=spec)
    try:
        yield
    finally:
        _ACT_OVERRIDE.clear()
        _ACT_OVERRIDE.update(old)


def _constrain_act(x):
    spec = _ACT_OVERRIDE.get("spec")
    if spec is None or x.shape[1] == 1:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


# --------------------------------------------------------------------------
# Block kinds
# --------------------------------------------------------------------------

def _parse(kind: str) -> tuple[str, bool, str | None]:
    """kind string → (mixer, has_cross, ffn|None)."""
    parts = kind.split(".")
    mixer = parts[0]
    has_cross = "cross" in parts[1:]
    ffn = parts[-1] if parts[-1] in ("mlp", "moe") else None
    return mixer, has_cross, ffn


def init_block(key, kind: str, cfg: ArchConfig, dtype) -> dict[str, Any]:
    mixer, has_cross, ffn = _parse(kind)
    keys = jax.random.split(key, 3)
    p: dict[str, Any] = {}
    if mixer in ("attn", "enc_attn", "dec_attn"):
        p["mixer"] = layers.init_attn(keys[0], cfg.d_model, cfg.num_heads,
                                      cfg.num_kv_heads, cfg.head_dim,
                                      cfg.qk_norm, dtype)
    elif mixer == "mla":
        p["mixer"] = mla.init_mla(keys[0], cfg.d_model, cfg.num_heads,
                                  cfg.mla, dtype)
    elif mixer == "mamba":
        p["mixer"] = mamba2.init_mamba(keys[0], cfg.d_model, cfg.ssm, dtype)
    else:
        raise ValueError(f"unknown mixer {mixer!r}")
    if has_cross:
        p["cross"] = layers.init_attn(keys[1], cfg.d_model, cfg.num_heads,
                                      cfg.num_kv_heads, cfg.head_dim,
                                      cfg.qk_norm, dtype)
    if ffn == "mlp":
        p["ffn"] = layers.init_mlp(keys[2], cfg.d_model, cfg.d_ff, dtype)
    elif ffn == "moe":
        p["ffn"] = moe.init_moe(keys[2], cfg.d_model, cfg.moe, dtype)
    return p


def _block_forward(p, kind: str, cfg: ArchConfig, x, positions, ctx: layers.Ctx,
                   layer_id, bayes: bool, enc_kv=None, return_cache=False):
    """One block, full-sequence.  Returns (x, aux, cache|None)."""
    mixer, has_cross, ffn = _parse(kind)
    aux = jnp.float32(0.0)
    cache = None
    causal = mixer != "enc_attn"
    if mixer in ("attn", "enc_attn", "dec_attn"):
        m = layers.site_mask(ctx, bayes, layer_id, layers.SITE_ATTN,
                             cfg.d_model, x.dtype)
        res = layers.attention_forward(p["mixer"], x, positions,
                                       cfg.rope_theta, causal=causal,
                                       mask_in=m, p_drop=ctx.cfg.p,
                                       return_kv=return_cache)
        if return_cache:
            res, cache = res
        x = x + res
    elif mixer == "mla":
        m = layers.site_mask(ctx, bayes, layer_id, layers.SITE_ATTN,
                             cfg.d_model, x.dtype)
        res = mla.mla_forward(p["mixer"], x, positions, cfg.rope_theta,
                              cfg.mla, m, ctx.cfg.p, return_cache=return_cache)
        if return_cache:
            res, cache = res
        x = x + res
    elif mixer == "mamba":
        m = layers.site_mask(ctx, bayes, layer_id, layers.SITE_MIXER,
                             cfg.d_model, x.dtype)
        res = mamba2.mamba_forward(p["mixer"], x, cfg.ssm, m, ctx.cfg.p,
                                   cfg.d_model, return_state=return_cache)
        if return_cache:
            res, cache = res
        x = x + res
    if has_cross:
        m = layers.site_mask(ctx, bayes, layer_id, layers.SITE_CROSS,
                             cfg.d_model, x.dtype)
        ek, ev = enc_kv
        x = x + layers.cross_attention(p["cross"], x, ek, ev, m, ctx.cfg.p)
    if ffn == "mlp":
        m = layers.site_mask(ctx, bayes, layer_id, layers.SITE_MLP,
                             cfg.d_model, x.dtype)
        x = x + layers.mlp_forward(p["ffn"], x, m, ctx.cfg.p)
    elif ffn == "moe":
        m = layers.site_mask(ctx, bayes, layer_id, layers.SITE_MLP,
                             cfg.d_model, x.dtype)
        y, a = moe.moe_forward(p["ffn"], x, cfg.moe, m, ctx.cfg.p)
        x = x + y
        aux = aux + a
    x = _constrain_act(x)
    return x, aux, cache


def _block_decode(p, kind: str, cfg: ArchConfig, x, cache, pos,
                  ctx: layers.Ctx, layer_id, bayes: bool, cross_kv=None):
    """One block, single-token.  Returns (x, new_cache)."""
    mixer, has_cross, ffn = _parse(kind)
    if mixer in ("attn", "dec_attn"):
        m = layers.site_mask(ctx, bayes, layer_id, layers.SITE_ATTN,
                             cfg.d_model, x.dtype)
        res, cache = layers.attention_decode(p["mixer"], x, cache, pos,
                                             cfg.rope_theta, m, ctx.cfg.p)
        x = x + res
    elif mixer == "mla":
        m = layers.site_mask(ctx, bayes, layer_id, layers.SITE_ATTN,
                             cfg.d_model, x.dtype)
        res, cache = mla.mla_decode(p["mixer"], x, cache, pos, cfg.rope_theta,
                                    cfg.mla, m, ctx.cfg.p)
        x = x + res
    elif mixer == "mamba":
        m = layers.site_mask(ctx, bayes, layer_id, layers.SITE_MIXER,
                             cfg.d_model, x.dtype)
        res, cache = mamba2.mamba_decode(p["mixer"], x, cache, cfg.ssm, m,
                                         ctx.cfg.p, cfg.d_model)
        x = x + res
    if has_cross:
        m = layers.site_mask(ctx, bayes, layer_id, layers.SITE_CROSS,
                             cfg.d_model, x.dtype)
        ek, ev = cross_kv
        x = x + layers.cross_attention(p["cross"], x, ek, ev, m, ctx.cfg.p)
    if ffn == "mlp":
        m = layers.site_mask(ctx, bayes, layer_id, layers.SITE_MLP,
                             cfg.d_model, x.dtype)
        x = x + layers.mlp_forward(p["ffn"], x, m, ctx.cfg.p)
    elif ffn == "moe":
        m = layers.site_mask(ctx, bayes, layer_id, layers.SITE_MLP,
                             cfg.d_model, x.dtype)
        y, _ = moe.moe_forward(p["ffn"], x, cfg.moe, m, ctx.cfg.p)
        x = x + y
    return x, cache


def _block_cache_spec(kind: str, cfg: ArchConfig, batch: int, max_len: int,
                      enc_len: int, dtype, kv_quant: bool = False):
    """Zero-initialized cache for one block (None for cache-free blocks)."""
    mixer, has_cross, _ = _parse(kind)
    cache = None
    if mixer in ("attn", "dec_attn"):
        if kv_quant:
            kv = jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                           jnp.int8)
            sc = jnp.zeros((batch, max_len, cfg.num_kv_heads), jnp.bfloat16)
            cache = (kv, sc, kv, sc)
        else:
            kv = jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                           dtype)
            cache = (kv, kv)
    elif mixer == "mla":
        cache = mla.init_cache(batch, max_len, cfg.mla, dtype)
    elif mixer == "mamba":
        cache = mamba2.init_state(batch, cfg.d_model, cfg.ssm, dtype)
    cross = None
    if has_cross:
        kv = jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.head_dim), dtype)
        cross = (kv, kv)
    return cache, cross


# --------------------------------------------------------------------------
# Stages
# --------------------------------------------------------------------------

def init_stage(key, stage: Stage, cfg: ArchConfig, dtype):
    """Per-position stacked params: tuple over pattern, leaves [repeat, ...]."""
    out = []
    for j, kind in enumerate(stage.pattern):
        keys = jax.random.split(jax.random.fold_in(key, j), stage.repeat)
        out.append(jax.vmap(lambda k: init_block(k, kind, cfg, dtype))(keys))
    return tuple(out)


def _stage_bayes(cfg: ArchConfig, layer_offset: int, stage: Stage) -> tuple[bool, ...]:
    return tuple(cfg.mcd.bayesian(layer_offset + j)
                 for j in range(len(stage.pattern)))


def run_stage_forward(stage_params, stage: Stage, cfg: ArchConfig, x,
                      positions, ctx: layers.Ctx, layer_offset: int,
                      enc_kv_stacked=None, collect_caches: bool = False,
                      remat: bool = False):
    """Scan a stage over its repeats.  Returns (x, aux, caches|None).

    ``remat=True`` checkpoints each scan body (one period of layers): the
    backward pass recomputes block internals instead of saving them —
    activation memory drops from O(layers × intermediates) to
    O(layers × d_model) + one period of recompute workspace.
    """
    period = len(stage.pattern)
    bayes = _stage_bayes(cfg, layer_offset, stage)

    def body(carry, xs):
        x, aux = carry
        params_slice, ekv, ridx = xs
        caches = []
        for j, kind in enumerate(stage.pattern):
            layer_id = layer_offset + ridx * period + j
            x, a, c = _block_forward(params_slice[j], kind, cfg, x, positions,
                                     ctx, layer_id, bayes[j],
                                     enc_kv=ekv[j] if ekv is not None else None,
                                     return_cache=collect_caches)
            aux = aux + a
            caches.append(c)
        return (x, aux), (tuple(caches) if collect_caches else 0)

    if remat:
        body = jax.checkpoint(body)
    ridx = jnp.arange(stage.repeat, dtype=jnp.uint32)
    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.float32(0.0)), (stage_params, enc_kv_stacked, ridx))
    return x, aux, (caches if collect_caches else None)


def run_stage_decode(stage_params, stage: Stage, cfg: ArchConfig, x, caches,
                     cross_kvs, pos, ctx: layers.Ctx, layer_offset: int):
    period = len(stage.pattern)
    bayes = _stage_bayes(cfg, layer_offset, stage)

    def body(carry, xs):
        x = carry
        params_slice, cache_slice, cross_slice, ridx = xs
        new_caches = []
        for j, kind in enumerate(stage.pattern):
            layer_id = layer_offset + ridx * period + j
            x, c = _block_decode(params_slice[j], kind, cfg, x, cache_slice[j],
                                 pos, ctx, layer_id, bayes[j],
                                 cross_kv=cross_slice[j] if cross_slice is not None else None)
            new_caches.append(c)
        return x, tuple(new_caches)

    ridx = jnp.arange(stage.repeat, dtype=jnp.uint32)
    x, new_caches = jax.lax.scan(
        body, x, (stage_params, caches, cross_kvs, ridx))
    return x, new_caches


# --------------------------------------------------------------------------
# Model-level API
# --------------------------------------------------------------------------

class DecodeState(NamedTuple):
    pos: jax.Array                  # scalar int32: next position to write
    caches: Any                     # per-stage stacked caches
    cross: Any                      # per-stage stacked cross K/V (or None)


def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict[str, Any]:
    keys = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": layers.init_embed(keys[0], cfg.vocab_size, cfg.d_model,
                                   cfg.tie_embeddings, dtype),
        "stages": [init_stage(jax.random.fold_in(keys[1], i), s, cfg, dtype)
                   for i, s in enumerate(cfg.stages)],
    }
    if cfg.encoder_stages:
        params["encoder_stages"] = [
            init_stage(jax.random.fold_in(keys[2], i), s, cfg, dtype)
            for i, s in enumerate(cfg.encoder_stages)]
        params["encoder_norm"] = layers.init_rmsnorm(cfg.d_model, dtype)
    return params


def _encoder_forward(params, cfg: ArchConfig, frames, ctx: layers.Ctx):
    """Whisper encoder over stub frame embeddings [B, enc_seq, D]."""
    x = frames
    positions = jnp.arange(frames.shape[1])
    offset = 10_000  # encoder layers use a distinct mask-stream namespace
    for sp, st in zip(params["encoder_stages"], cfg.encoder_stages):
        x, _, _ = run_stage_forward(sp, st, cfg, x, positions, ctx, offset)
        offset += st.num_layers
    return layers.rmsnorm(params["encoder_norm"], x)


def _stacked_cross_kv(params, cfg: ArchConfig, enc_out):
    """Precompute per-(stage, position, repeat) cross K/V from encoder output."""
    out = []
    for sp, st in zip(params["stages"], cfg.stages):
        per_pos = []
        for j, kind in enumerate(st.pattern):
            if "cross" in kind.split("."):
                kv = jax.vmap(lambda p: layers.cross_kv(p, enc_out))(sp[j]["cross"])
            else:
                kv = None
            per_pos.append(kv)
        out.append(tuple(per_pos))
    return out


def forward(params, cfg: ArchConfig, tokens, ctx: layers.Ctx, *,
            frames=None, patches=None, collect_caches: bool = False,
            remat: bool = False, return_hidden: bool = False):
    """Full-sequence forward.  Returns (logits, aux, caches)."""
    x = layers.embed(params["embed"], tokens)
    if patches is not None:                       # VLM: prepend patch embeds
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    enc_stacked_all = None
    if cfg.encoder_stages:
        enc_out = _encoder_forward(params, cfg, frames, ctx)
        enc_stacked_all = _stacked_cross_kv(params, cfg, enc_out)
    positions = jnp.arange(x.shape[1])
    aux = jnp.float32(0.0)
    offset = 0
    all_caches = []
    for i, (sp, st) in enumerate(zip(params["stages"], cfg.stages)):
        ekv = enc_stacked_all[i] if enc_stacked_all is not None else None
        x, a, caches = run_stage_forward(sp, st, cfg, x, positions, ctx, offset,
                                         enc_kv_stacked=ekv,
                                         collect_caches=collect_caches,
                                         remat=remat)
        aux = aux + a
        offset += st.num_layers
        all_caches.append(caches)
    out = x if return_hidden else layers.logits(params["embed"], x)
    if collect_caches:
        return out, aux, (all_caches, enc_stacked_all)
    return out, aux, None


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16, kv_quant: bool = False) -> DecodeState:
    """Zero decode state (stacked caches per stage/pattern position)."""
    caches, crosses = [], []
    any_cross = False
    for st in cfg.stages:
        per_pos_c, per_pos_x = [], []
        for kind in st.pattern:
            c, cr = _block_cache_spec(kind, cfg, batch, max_len,
                                      cfg.encoder_seq, dtype,
                                      kv_quant=kv_quant)
            # stack over repeats
            per_pos_c.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (st.repeat, *a.shape)), c)
                if c is not None else None)
            if cr is not None:
                any_cross = True
                per_pos_x.append(jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (st.repeat, *a.shape)), cr))
            else:
                per_pos_x.append(None)
        caches.append(tuple(per_pos_c))
        crosses.append(tuple(per_pos_x))
    return DecodeState(pos=jnp.int32(0), caches=caches,
                       cross=crosses if any_cross else None)


def _pad_cache_to(cache, kind: str, max_len: int):
    """Pad sequence-indexed caches ([repeat, B, S, ...]) up to max_len."""
    mixer, _, _ = _parse(kind)
    if cache is None:
        return None
    if mixer in ("attn", "dec_attn"):
        def pad(a):
            return jnp.pad(a, ((0, 0), (0, 0), (0, max_len - a.shape[2]),
                               (0, 0), (0, 0)))
        return (pad(cache[0]), pad(cache[1]))
    if mixer == "mla":
        def pad(a):
            return jnp.pad(a, ((0, 0), (0, 0), (0, max_len - a.shape[2]),
                               (0, 0)))
        return mla.MLACache(pad(cache.c_kv), pad(cache.k_rope))
    return cache  # mamba state: no sequence axis


def prefill(params, cfg: ArchConfig, tokens, ctx: layers.Ctx, max_len: int, *,
            frames=None, patches=None):
    """Process the prompt, return (last-position logits, DecodeState).

    The MCD masks drawn here (keyed by ctx.rows/seed) are the *same* masks
    every subsequent decode_step recomputes — tied across the whole request,
    the serving analogue of the paper's tied-across-T requirement.
    """
    hidden, _, (caches, crosses) = forward(params, cfg, tokens, ctx,
                                           frames=frames, patches=patches,
                                           collect_caches=True,
                                           return_hidden=True)
    lg = layers.logits(params["embed"], hidden[:, -1:])
    padded = []
    for st, stage_caches in zip(cfg.stages, caches):
        per_pos = tuple(_pad_cache_to(stage_caches[j], kind, max_len)
                        for j, kind in enumerate(st.pattern))
        padded.append(per_pos)
    any_cross = crosses is not None
    seq = tokens.shape[1] + (patches.shape[1] if patches is not None else 0)
    return lg[:, -1:], DecodeState(pos=jnp.int32(seq), caches=padded,
                                   cross=crosses if any_cross else None)


def decode_step(params, cfg: ArchConfig, token, state: DecodeState,
                ctx: layers.Ctx):
    """One decode step.  token: [B, 1] → (logits [B, 1, V], new state)."""
    x = layers.embed(params["embed"], token)
    offset = 0
    new_caches = []
    for i, (sp, st) in enumerate(zip(params["stages"], cfg.stages)):
        cross = state.cross[i] if state.cross is not None else None
        x, nc = run_stage_decode(sp, st, cfg, x, state.caches[i], cross,
                                 state.pos, ctx, offset)
        offset += st.num_layers
        new_caches.append(nc)
    lg = layers.logits(params["embed"], x)
    return lg, DecodeState(pos=state.pos + 1, caches=new_caches,
                           cross=state.cross)


def _chunked_xent(embed_params, hidden, targets, chunk: int = 512):
    """Cross-entropy without materializing full fp32 log-probs for backward.

    Scans sequence chunks with remat: each chunk's [B, c, V] logits exist
    only inside its (recomputed) segment — peak memory O(B·c·V), not
    O(B·S·V).
    """
    B, S, D = hidden.shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    hs = hidden.reshape(B, S // c, c, D).swapaxes(0, 1)
    ts = targets.reshape(B, S // c, c).swapaxes(0, 1)

    @jax.checkpoint
    def one(carry, xs):
        h, t = xs
        lg = layers.logits(embed_params, h).astype(jnp.float32)
        logp = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(logp, t[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(nll), None

    total, _ = jax.lax.scan(one, jnp.float32(0.0), (hs, ts))
    return total / (B * S)


def loss_fn(params, cfg: ArchConfig, tokens, targets, ctx: layers.Ctx, *,
            frames=None, patches=None, remat: bool = True,
            xent_chunk: int = 512):
    """Next-token cross-entropy + MoE aux.  targets = tokens shifted."""
    hidden, aux, _ = forward(params, cfg, tokens, ctx, frames=frames,
                             patches=patches, remat=remat, return_hidden=True)
    if patches is not None:
        hidden = hidden[:, patches.shape[1]:]    # loss over text positions only
    nll = _chunked_xent(params["embed"], hidden, targets, xent_chunk)
    return nll + aux, {"nll": nll, "aux": aux}

"""Mamba2 (SSD — state-space duality) mixer, TPU-adapted.

Training/prefill uses the *chunked* SSD algorithm: quadratic attention-like
matmuls **within** a chunk (MXU-dense, [Q×Q] with Q=256) and a linear
recurrence **across** chunks (lax.scan) — exactly the Mamba2 paper's
block-decomposition, which is the right shape for a systolic array (big dense
tiles, tiny sequential state hop).  Decode is the O(1)-per-token recurrent
update on state [B, H, P, N].

MCD hook: one feature mask on the block input (site=SITE_MIXER), tied across
all sequence positions / decode steps — the SSM state recurrence is precisely
the paper's h_{t-1} mask-tying case (DESIGN.md §5).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import SSMConfig


class MambaParams(NamedTuple):
    norm: jax.Array          # [D] pre-norm
    in_proj: jax.Array       # [D, 2*d_inner + 2*G*N + H]
    conv_w: jax.Array        # [conv_dim, d_conv] depthwise
    conv_b: jax.Array        # [conv_dim]
    a_log: jax.Array         # [H]
    d_skip: jax.Array        # [H]
    dt_bias: jax.Array       # [H]
    out_norm: jax.Array      # [d_inner] gated-output RMSNorm
    out_proj: jax.Array      # [d_inner, D]


def dims(d_model: int, cfg: SSMConfig):
    d_inner = cfg.expand * d_model
    n_heads = d_inner // cfg.head_dim
    conv_dim = d_inner + 2 * cfg.n_groups * cfg.d_state
    return d_inner, n_heads, conv_dim


def init_mamba(key, d_model: int, cfg: SSMConfig, dtype) -> MambaParams:
    d_inner, n_heads, conv_dim = dims(d_model, cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    d_in_proj = 2 * d_inner + 2 * cfg.n_groups * cfg.d_state + n_heads
    return MambaParams(
        norm=layers.init_rmsnorm(d_model, dtype),
        in_proj=jax.random.normal(k1, (d_model, d_in_proj), dtype) * d_model ** -0.5,
        conv_w=jax.random.normal(k2, (conv_dim, cfg.d_conv), dtype) * 0.1,
        conv_b=jnp.zeros((conv_dim,), dtype),
        a_log=jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        d_skip=jnp.ones((n_heads,), jnp.float32),
        dt_bias=jnp.log(jnp.expm1(jnp.linspace(1e-3, 0.1, n_heads))).astype(jnp.float32),
        out_norm=layers.init_rmsnorm(d_inner, dtype),
        out_proj=jax.random.normal(k3, (d_inner, d_model), dtype) * d_inner ** -0.5)


def _split_in_proj(proj: jax.Array, d_model: int, cfg: SSMConfig):
    d_inner, n_heads, _ = dims(d_model, cfg)
    gn = cfg.n_groups * cfg.d_state
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:2 * d_inner + 2 * gn]
    dt = proj[..., 2 * d_inner + 2 * gn:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Causal depthwise conv via shift-and-add (d_conv taps). xbc: [B, L, C]."""
    d_conv = w.shape[1]
    out = xbc * w[:, -1]
    for i in range(1, d_conv):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, :-i or None, :][:, :xbc.shape[1], :]
        out = out + shifted * w[:, -1 - i]
    return jax.nn.silu(out + b)


def _ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
                 cm: jax.Array, d_skip: jax.Array, chunk: int,
                 h0: jax.Array | None = None):
    """Chunked SSD scan.

    x: [B, L, H, P]; dt: [B, L, H] (post-softplus); a: [H] (negative);
    bm, cm: [B, L, G, N].  Returns (y [B, L, H, P], h_final [B, H, P, N]).
    """
    B, L, H, P = x.shape
    G, N = bm.shape[2], bm.shape[3]
    rep = H // G
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // Q
    xc = x.reshape(B, nc, Q, H, P)
    dtc = dt.reshape(B, nc, Q, H).astype(jnp.float32)
    bc = bm.reshape(B, nc, Q, G, N)
    cc = cm.reshape(B, nc, Q, G, N)

    l = dtc * a[None, None, None, :]                 # log-decay per step
    cs = jnp.cumsum(l, axis=2)                       # inclusive cumsum over Q
    dtx = (dtc[..., None] * xc.astype(jnp.float32))  # [B,nc,Q,H,P]

    # --- intra-chunk (quadratic within Q; MXU-dense) ----------------------
    scores = jnp.einsum("bcqgn,bckgn->bcgqk", cc.astype(jnp.float32),
                        bc.astype(jnp.float32))      # [B,nc,G,Q,Q]
    cs_h = cs.transpose(0, 1, 3, 2)                  # [B,nc,H,Q]
    decay = jnp.exp(cs_h[..., :, None] - cs_h[..., None, :])  # [B,nc,H,Q,Q]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(tri[None, None, None], decay, 0.0)
    dh = decay.reshape(B, nc, G, rep, Q, Q)
    dtx_h = dtx.reshape(B, nc, Q, G, rep, P)
    y_intra = jnp.einsum("bcgqk,bcgrqk,bckgrp->bcqgrp", scores, dh, dtx_h)

    # --- chunk states ------------------------------------------------------
    dec_end = jnp.exp(cs[..., -1:, :] - cs)          # [B,nc,Q,H]
    dec_end_h = dec_end.reshape(B, nc, Q, G, rep)
    s_chunk = jnp.einsum("bckgn,bckgr,bckgrp->bcgrpn", bc.astype(jnp.float32),
                         dec_end_h, dtx_h)           # [B,nc,G,rep,P,N]
    s_chunk = s_chunk.reshape(B, nc, H, P, N)
    chunk_decay = jnp.exp(cs[:, :, -1, :])           # [B,nc,H]

    # --- inter-chunk recurrence (lax.scan over chunks) ---------------------
    def step(h, inp):
        s_c, dec_c = inp                              # [B,H,P,N], [B,H]
        h_new = h * dec_c[..., None, None] + s_c
        return h_new, h                               # emit state *before* chunk

    h_init = (jnp.zeros((B, H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_final, h_prevs = jax.lax.scan(
        step, h_init, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)             # [B,nc,H,P,N]

    cin = jnp.exp(cs)                                 # decay-in within chunk
    cin_h = cin.reshape(B, nc, Q, G, rep)
    cc_h = cc.astype(jnp.float32)
    y_inter = jnp.einsum("bcqgn,bcqgr,bcgrpn->bcqgrp", cc_h, cin_h,
                         h_prevs.reshape(B, nc, G, rep, P, N))

    y = (y_intra + y_inter).reshape(B, Lp, H, P) \
        + d_skip[None, None, :, None] * x.astype(jnp.float32)
    return y[:, :L].astype(x.dtype), h_final


def mamba_forward(p: MambaParams, x: jax.Array, cfg: SSMConfig,
                  mask_in: jax.Array | None, p_drop: float,
                  d_model: int, return_state: bool = False):
    """Full-sequence mamba block. x: [B, L, D] → [B, L, D]."""
    d_inner, n_heads, _ = dims(d_model, cfg)
    h = layers.rmsnorm(p.norm, x)
    h = layers.apply_site_mask(h, mask_in, p_drop)
    proj = jnp.einsum("bld,de->ble", h, p.in_proj.astype(h.dtype))
    z, xbc_raw, dt = _split_in_proj(proj, d_model, cfg)
    xbc = _causal_conv(xbc_raw, p.conv_w.astype(xbc_raw.dtype),
                       p.conv_b.astype(xbc_raw.dtype))
    gn = cfg.n_groups * cfg.d_state
    xs = xbc[..., :d_inner].reshape(*xbc.shape[:2], n_heads, cfg.head_dim)
    bm = xbc[..., d_inner:d_inner + gn].reshape(*xbc.shape[:2], cfg.n_groups, cfg.d_state)
    cm = xbc[..., d_inner + gn:].reshape(*xbc.shape[:2], cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias)
    a = -jnp.exp(p.a_log)
    y, h_final = _ssd_chunked(xs, dt, a, bm, cm, p.d_skip, cfg.chunk)
    y = y.reshape(*y.shape[:2], d_inner)
    y = layers.rmsnorm(p.out_norm, y * jax.nn.silu(z))
    out = jnp.einsum("ble,ed->bld", y, p.out_proj.astype(y.dtype))
    if return_state:
        conv_state = xbc_raw[:, -(cfg.d_conv - 1):, :]
        return out, MambaState(ssm=h_final, conv=conv_state)
    return out


class MambaState(NamedTuple):
    ssm: jax.Array    # [B, H, P, N] fp32
    conv: jax.Array   # [B, d_conv-1, conv_dim]


def init_state(batch: int, d_model: int, cfg: SSMConfig, dtype) -> MambaState:
    d_inner, n_heads, conv_dim = dims(d_model, cfg)
    return MambaState(
        ssm=jnp.zeros((batch, n_heads, cfg.head_dim, cfg.d_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype))


def mamba_decode(p: MambaParams, x: jax.Array, state: MambaState,
                 cfg: SSMConfig, mask_in: jax.Array | None, p_drop: float,
                 d_model: int):
    """One-token recurrent update. x: [B, 1, D] → (y [B, 1, D], state)."""
    d_inner, n_heads, conv_dim = dims(d_model, cfg)
    h = layers.rmsnorm(p.norm, x)
    h = layers.apply_site_mask(h, mask_in, p_drop)
    proj = jnp.einsum("bld,de->ble", h, p.in_proj.astype(h.dtype))
    z, xbc, dt = _split_in_proj(proj, d_model, cfg)
    xbc = xbc[:, 0]                                    # [B, conv_dim]
    # conv state update
    w = p.conv_w.astype(xbc.dtype)
    hist = jnp.concatenate([state.conv, xbc[:, None, :]], axis=1)  # [B,d_conv,C]
    conv_out = jnp.einsum("bwc,cw->bc", hist, w) + p.conv_b.astype(xbc.dtype)
    conv_out = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:]
    gn = cfg.n_groups * cfg.d_state
    xs = conv_out[..., :d_inner].reshape(-1, n_heads, cfg.head_dim)
    bm = conv_out[..., d_inner:d_inner + gn].reshape(-1, cfg.n_groups, cfg.d_state)
    cm = conv_out[..., d_inner + gn:].reshape(-1, cfg.n_groups, cfg.d_state)
    dt_v = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p.dt_bias)  # [B,H]
    a = -jnp.exp(p.a_log)
    decay = jnp.exp(dt_v * a)                          # [B,H]
    rep = n_heads // cfg.n_groups
    bm_h = jnp.repeat(bm, rep, axis=1)                 # [B,H,N]
    cm_h = jnp.repeat(cm, rep, axis=1)
    upd = (dt_v[..., None] * xs.astype(jnp.float32))[..., None] \
        * bm_h[:, :, None, :].astype(jnp.float32)      # [B,H,P,N]
    ssm = state.ssm * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", ssm, cm_h.astype(jnp.float32)) \
        + p.d_skip[None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(-1, 1, d_inner).astype(x.dtype)
    y = layers.rmsnorm(p.out_norm, y * jax.nn.silu(z))
    out = jnp.einsum("ble,ed->bld", y, p.out_proj.astype(y.dtype))
    return out, MambaState(ssm=ssm, conv=new_conv)

"""Transformer building blocks with MCD hooks (GQA attention, SwiGLU, RoPE).

MCD placement note: inside scanned stages the Bayesian on/off decision (B) is
static per *pattern position* (mask presence must be layout-static under
``lax.scan``), while mask *values* still differ per layer — the traced layer
index is folded into the counter-RNG key.  The paper's small ECG models keep
exact per-layer placement via ``repro.core.rnn``.

Attention is blockwise (online-softmax over KV chunks) so activation memory
stays linear in sequence length — the pure-JAX mirror of the Pallas flash
tiling, and the form whose HLO the dry-run rooflines.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import mcd
from repro.core.mcd import MCDConfig

# MCD site ids (folded into the RNG key as the `gate` field).
SITE_ATTN = 0
SITE_MLP = 1
SITE_MIXER = 2
SITE_CROSS = 3


@jax.tree_util.register_pytree_node_class
class Ctx:
    """Per-forward MCD context: who am I (rows), which draw (seed).

    ``rows``/``seed`` are traced arrays; ``cfg``/``deterministic`` are static
    pytree aux data so a Ctx passes straight through jit boundaries.
    """

    def __init__(self, rows, seed, cfg: MCDConfig, deterministic: bool = False):
        self.rows = rows
        self.seed = seed
        self.cfg = cfg
        self.deterministic = deterministic

    def tree_flatten(self):
        return (self.rows, self.seed), (self.cfg, self.deterministic)

    @classmethod
    def tree_unflatten(cls, aux, children):
        rows, seed = children
        return cls(rows, seed, aux[0], aux[1])

    @staticmethod
    def disabled(batch: int) -> "Ctx":
        return Ctx(jnp.zeros((batch,), jnp.uint32), 0, MCDConfig(p=0.0),
                   deterministic=True)


def site_mask(ctx: Ctx, bayesian: bool, layer_id, site: int, n_feat: int,
              dtype) -> jax.Array | None:
    """[B, n_feat] keep-mask tied across sequence positions, or None."""
    if ctx.deterministic or not bayesian or ctx.cfg.p == 0.0:
        return None
    return mcd.feature_mask(ctx.seed, layer_id, ctx.rows, n_feat, ctx.cfg.p,
                            kind=mcd.KIND_FEAT, gate=site, dtype=dtype)


def apply_site_mask(x: jax.Array, mask: jax.Array | None, p: float) -> jax.Array:
    """x: [B, S, D]; mask [B, D] broadcasts over S (tied across positions)."""
    if mask is None:
        return x
    return mcd.apply_mask(x, mask[:, None, :], p)


# --------------------------------------------------------------------------
# Normalization / RoPE / embeddings
# --------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype)


def rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) \
        * scale.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding; x: [..., S, H, hd], positions: [S] or [B, S]."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    if x.ndim == cos.ndim + 1:      # positions lacked a batch dim
        cos, sin = cos[None], sin[None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------

class AttnParams(NamedTuple):
    wq: jax.Array   # [D, H, hd]
    wk: jax.Array   # [D, KV, hd]
    wv: jax.Array   # [D, KV, hd]
    wo: jax.Array   # [H, hd, D]
    q_scale: jax.Array | None   # qk_norm scales, [hd]
    k_scale: jax.Array | None
    norm: jax.Array             # pre-norm scale [D]


def init_attn(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
              qk_norm: bool, dtype) -> AttnParams:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d_model ** -0.5
    return AttnParams(
        wq=jax.random.normal(kq, (d_model, n_heads, head_dim), dtype) * s,
        wk=jax.random.normal(kk, (d_model, n_kv, head_dim), dtype) * s,
        wv=jax.random.normal(kv, (d_model, n_kv, head_dim), dtype) * s,
        wo=jax.random.normal(ko, (n_heads, head_dim, d_model), dtype) * s,
        q_scale=jnp.ones((head_dim,), dtype) if qk_norm else None,
        k_scale=jnp.ones((head_dim,), dtype) if qk_norm else None,
        norm=init_rmsnorm(d_model, dtype))


def _qk_normalize(q, k, p: AttnParams):
    if p.q_scale is not None:
        q = rmsnorm(p.q_scale, q)
        k = rmsnorm(p.k_scale, k)
    return q, k


import contextlib

_ATTN_OVERRIDE: dict = {}


@contextlib.contextmanager
def attention_override(**kw):
    """Trace-time override of attention tiling (used by roofline probes:
    bigger blocks + unroll=True make XLA's cost analysis count every
    iteration, since HLO while-bodies are otherwise counted once)."""
    old = dict(_ATTN_OVERRIDE)
    _ATTN_OVERRIDE.update(kw)
    try:
        yield
    finally:
        _ATTN_OVERRIDE.clear()
        _ATTN_OVERRIDE.update(old)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, q_block: int = 512,
                        kv_block: int = 1024) -> jax.Array:
    """Online-softmax attention, linear activation memory.

    q: [B, Sq, H, hd]; k, v: [B, Skv, KV, hd] (GQA: H = KV · rep).
    Scans query blocks; each query block scans KV blocks carrying the running
    (max, denom, acc) — the jnp mirror of flash tiling.
    """
    q_block = _ATTN_OVERRIDE.get("q_block", q_block)
    kv_block = _ATTN_OVERRIDE.get("kv_block", kv_block)
    unroll = _ATTN_OVERRIDE.get("unroll", 1)
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    hdv = v.shape[-1]              # may differ from hd (MLA)
    rep = H // KV

    def fit(size, want):           # largest divisor of size ≤ want
        b = min(want, size)
        while size % b:
            b -= 1
        return b

    qb = fit(Sq, q_block)
    kb = fit(Skv, kv_block)
    scale = hd ** -0.5
    qr = q.reshape(B, Sq // qb, qb, KV, rep, hd)
    kr = k.reshape(B, Skv // kb, kb, KV, hd)
    vr = v.reshape(B, Skv // kb, kb, KV, hdv)

    def q_step(_, qi_idx):
        qi, iq = qi_idx            # qi: [B, qb, KV, rep, hd]
        minit = jnp.full((B, KV, rep, qb), -jnp.inf, jnp.float32)
        linit = jnp.zeros((B, KV, rep, qb), jnp.float32)
        ainit = jnp.zeros((B, KV, rep, qb, hdv), jnp.float32)

        def kv_step(carry, kv_idx):
            m, l, acc = carry
            kj, vj, jk = kv_idx    # kj/vj: [B, kb, KV, hd]
            s = jnp.einsum("bqgrh,bkgh->bgrqk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = iq * qb + jax.lax.broadcasted_iota(
                    jnp.int32, (qb, kb), 0)
                kpos = jk * kb + jax.lax.broadcasted_iota(
                    jnp.int32, (qb, kb), 1)
                s = jnp.where(qpos >= kpos, s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows (m_new = -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bkgh->bgrqh", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (minit, linit, ainit),
            (jnp.swapaxes(kr, 0, 1), jnp.swapaxes(vr, 0, 1),
             jnp.arange(Skv // kb)), unroll=unroll)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out           # [B, KV, rep, qb, hd]

    _, outs = jax.lax.scan(
        q_step, None,
        (jnp.swapaxes(qr, 0, 1), jnp.arange(Sq // qb)), unroll=unroll)
    # outs: [nq, B, KV, rep, qb, hdv] → [B, Sq, H, hdv]
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 2, 3, 1, 4, 5)
    return out.reshape(B, KV * rep, Sq, hdv).swapaxes(1, 2).astype(q.dtype)


def attention_forward(p: AttnParams, x: jax.Array, positions: jax.Array,
                      theta: float, *, causal: bool,
                      mask_in: jax.Array | None, p_drop: float,
                      return_kv: bool = False):
    """Full-sequence attention (train / prefill). x: [B, S, D]."""
    h = rmsnorm(p.norm, x)
    h = apply_site_mask(h, mask_in, p_drop)
    q = jnp.einsum("bsd,dnh->bsnh", h, p.wq.astype(h.dtype))
    k = jnp.einsum("bsd,dnh->bsnh", h, p.wk.astype(h.dtype))
    v = jnp.einsum("bsd,dnh->bsnh", h, p.wv.astype(h.dtype))
    q, k = _qk_normalize(q, k, p)
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    o = blockwise_attention(q, k, v, causal=causal)
    out = jnp.einsum("bsnh,nhd->bsd", o, p.wo.astype(o.dtype))
    if return_kv:
        return out, (k, v)
    return out


def _quantize_kv(kv: jax.Array):
    """Per-(batch, token, head) symmetric int8: [B, 1, KV, hd] → (i8, scale)."""
    scale = jnp.max(jnp.abs(kv.astype(jnp.float32)), axis=-1,
                    keepdims=False) / 127.0                # [B, 1, KV]
    q = jnp.clip(jnp.round(kv.astype(jnp.float32)
                           / jnp.maximum(scale, 1e-8)[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def attention_decode(p: AttnParams, x: jax.Array, cache, pos: jax.Array,
                     theta: float, mask_in: jax.Array | None, p_drop: float):
    """Single-token decode with KV cache.

    x: [B, 1, D]; cache: (k, v) with [B, Smax, KV, hd] — or the int8 form
    (k_i8, k_scale, v_i8, v_scale) (§Perf: halves cache HBM traffic, the
    dominant decode roofline term).  Returns (out [B, 1, D], new cache).
    """
    B, _, D = x.shape
    quant = len(cache) == 4
    h = rmsnorm(p.norm, x)
    h = apply_site_mask(h, mask_in, p_drop)
    q = jnp.einsum("bsd,dnh->bsnh", h, p.wq.astype(h.dtype))
    k = jnp.einsum("bsd,dnh->bsnh", h, p.wk.astype(h.dtype))
    v = jnp.einsum("bsd,dnh->bsnh", h, p.wv.astype(h.dtype))
    q, k = _qk_normalize(q, k, p)
    posv = jnp.full((1,), pos, jnp.int32)
    q = rope(q, posv, theta)
    k = rope(k, posv, theta)

    def upd(buf, val, axis=1):
        return jax.lax.dynamic_update_slice_in_dim(
            buf, val.astype(buf.dtype), pos, axis=axis)

    if quant:
        k8, ks, v8, vs = cache
        kq, kqs = _quantize_kv(k)
        vq, vqs = _quantize_kv(v)
        cache = (upd(k8, kq), upd(ks, kqs), upd(v8, vq), upd(vs, vqs))
        k_eff = cache[0].astype(jnp.bfloat16) \
            * cache[1][..., None].astype(jnp.bfloat16)
        v_eff = cache[2].astype(jnp.bfloat16) \
            * cache[3][..., None].astype(jnp.bfloat16)
    else:
        cache = (upd(cache[0], k), upd(cache[1], v))
        k_eff, v_eff = cache
    KV = k_eff.shape[2]
    rep = q.shape[2] // KV
    qr = q.reshape(B, KV, rep, q.shape[-1])
    s = jnp.einsum("bgrh,bkgh->bgrk", qr.astype(k_eff.dtype), k_eff,
                   preferred_element_type=jnp.float32) * (q.shape[-1] ** -0.5)
    valid = jnp.arange(k_eff.shape[1]) <= pos
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrk,bkgh->bgrh", w.astype(v_eff.dtype), v_eff,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, -1, q.shape[-1]).astype(x.dtype)
    return jnp.einsum("bsnh,nhd->bsd", o, p.wo.astype(o.dtype)), cache


def cross_attention(p: AttnParams, x: jax.Array, enc_k: jax.Array,
                    enc_v: jax.Array, mask_in: jax.Array | None,
                    p_drop: float) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V (whisper)."""
    h = rmsnorm(p.norm, x)
    h = apply_site_mask(h, mask_in, p_drop)
    q = jnp.einsum("bsd,dnh->bsnh", h, p.wq.astype(h.dtype))
    if p.q_scale is not None:
        q = rmsnorm(p.q_scale, q)
    o = blockwise_attention(q, enc_k, enc_v, causal=False)
    return jnp.einsum("bsnh,nhd->bsd", o, p.wo.astype(o.dtype))


def cross_kv(p: AttnParams, enc_out: jax.Array):
    k = jnp.einsum("bsd,dnh->bsnh", enc_out, p.wk.astype(enc_out.dtype))
    v = jnp.einsum("bsd,dnh->bsnh", enc_out, p.wv.astype(enc_out.dtype))
    if p.k_scale is not None:
        k = rmsnorm(p.k_scale, k)
    return k, v


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------

class MLPParams(NamedTuple):
    wi: jax.Array   # [D, 2, dff] (gate ‖ up)
    wo: jax.Array   # [dff, D]
    norm: jax.Array


def init_mlp(key, d_model: int, d_ff: int, dtype) -> MLPParams:
    ki, ko = jax.random.split(key)
    return MLPParams(
        wi=jax.random.normal(ki, (d_model, 2, d_ff), dtype) * d_model ** -0.5,
        wo=jax.random.normal(ko, (d_ff, d_model), dtype) * d_ff ** -0.5,
        norm=init_rmsnorm(d_model, dtype))


def mlp_forward(p: MLPParams, x: jax.Array, mask_in: jax.Array | None,
                p_drop: float) -> jax.Array:
    h = rmsnorm(p.norm, x)
    h = apply_site_mask(h, mask_in, p_drop)
    gu = jnp.einsum("bsd,dcf->bscf", h, p.wi.astype(h.dtype),
                    preferred_element_type=jnp.float32)
    act = jax.nn.silu(gu[..., 0, :]) * gu[..., 1, :]
    return jnp.einsum("bsf,fd->bsd", act.astype(h.dtype), p.wo.astype(h.dtype))


# --------------------------------------------------------------------------
# Embeddings / head
# --------------------------------------------------------------------------

class EmbedParams(NamedTuple):
    table: jax.Array        # [V, D]
    head: jax.Array | None  # [D, V] (None → tied)
    final_norm: jax.Array


def init_embed(key, vocab: int, d_model: int, tie: bool, dtype) -> EmbedParams:
    ke, kh = jax.random.split(key)
    return EmbedParams(
        table=jax.random.normal(ke, (vocab, d_model), dtype) * 0.02,
        head=None if tie else jax.random.normal(kh, (d_model, vocab), dtype) * d_model ** -0.5,
        final_norm=init_rmsnorm(d_model, dtype))


def embed(p: EmbedParams, tokens: jax.Array) -> jax.Array:
    return jnp.take(p.table, tokens, axis=0)


def logits(p: EmbedParams, x: jax.Array) -> jax.Array:
    h = rmsnorm(p.final_norm, x)
    w = p.table.T if p.head is None else p.head
    return jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype),
                      preferred_element_type=jnp.float32)

"""Assigned-architecture model zoo (see repro.models.backbone for the engine)."""

from repro.models.config import ArchConfig, ShapeCell, SHAPES, shape_applicable  # noqa: F401
from repro.models import backbone  # noqa: F401

"""Unified architecture configuration for the assigned model zoo.

A model is described as a sequence of *stages*; each stage is a repeated
period of heterogeneous blocks (``pattern``).  Homogeneous repetition lets the
backbone scan over stacked parameters — one period of HLO regardless of depth,
which is what keeps 64–72-layer models compilable on a 512-device mesh.

Block kinds (mixer/ffn pairs):
  "attn.mlp"   GQA attention + dense SwiGLU MLP
  "attn.moe"   GQA attention + MoE FFN
  "mla.mlp"    multi-head latent attention + dense MLP
  "mla.moe"    MLA + MoE
  "mamba"      Mamba2/SSD mixer (no FFN — mamba2 arch style)
  "mamba.mlp"  Mamba2 mixer + dense MLP (jamba style)
  "mamba.moe"  Mamba2 mixer + MoE
  "enc_attn.mlp"          bidirectional self-attention (encoder)
  "dec_attn.cross.mlp"    causal self-attention + cross-attention (decoder)
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.mcd import MCDConfig


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    d_conv: int = 4
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class Stage:
    pattern: tuple[str, ...]
    repeat: int

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeat


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    stages: tuple[Stage, ...]
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None     # default d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 500000.0
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    # Encoder–decoder (whisper): encoder stages listed separately.
    encoder_stages: tuple[Stage, ...] = ()
    encoder_seq: int = 0            # fixed stub frontend length (audio frames)
    # VLM: number of patch-embedding positions prepended by the stub frontend.
    num_patches: int = 0
    tie_embeddings: bool = False
    mcd: MCDConfig = dataclasses.field(
        default_factory=lambda: MCDConfig(p=0.1, placement="Y", n_samples=8))
    # True sub-quadratic support (SSM/hybrid) → eligible for long_500k.
    sub_quadratic: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def num_layers(self) -> int:
        return sum(s.num_layers for s in self.stages)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


def uniform_stages(kind: str, n_layers: int) -> tuple[Stage, ...]:
    return (Stage(pattern=(kind,), repeat=n_layers),)


# ---------------------------------------------------------------------------
# Input-shape cells (assigned): every LM arch is paired with all four shapes;
# ``decode_*``/``long_*`` lower serve_step, ``long_500k`` only for
# sub-quadratic archs (skip recorded in the roofline table + DESIGN.md).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """(applicable?, reason-if-not) for one (arch × shape) cell."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k dense-attention decode "
                       "is out of regime (see DESIGN.md §5); run for SSM/hybrid only")
    return True, ""

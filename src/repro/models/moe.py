"""Mixture-of-Experts FFN: sort-based dispatch with static capacity.

Design constraints (pod-scale):
  * **Linear FLOPs** — no GShard one-hot dispatch einsum (quadratic in local
    tokens).  Tokens are argsorted by expert id; per-expert slots are computed
    from exclusive-cumsum offsets; expert compute is a dense batched einsum
    over [E, C, D] with static capacity C — MXU-friendly, static-shaped,
    GSPMD/EP-shardable (expert axis sharded over "model"/"expert" mesh axes).
  * **Capacity dropping** — tokens beyond C per expert are dropped (standard);
    combine weights renormalized over surviving routes.
  * **Deterministic router under MCD** — the router sees the *unmasked*
    activations; only the expert inputs are masked.  Routing noise would
    conflate with epistemic uncertainty (DESIGN.md §5).
"""

from __future__ import annotations

import contextlib
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.config import MoEConfig

# Trace-time sharding override (§Perf hillclimb): without explicit
# constraints GSPMD replicates the dispatch buffers and the expert einsum
# runs with *global* capacity per device (~dp× flop bloat).  Constraining
# x_exp/y_exp to (expert→tp, capacity→dp) shards both axes.
_MOE_OVERRIDE: dict = {}


@contextlib.contextmanager
def moe_sharding(expert_axis=None, token_axes=None, groups: int = 1):
    """groups > 1 → group-local dispatch: tokens are routed within each of
    ``groups`` shards (aligned with the DP axes), so dispatch never moves
    tokens across data shards — only the expert-axis all-to-all remains
    (per-group capacity, standard in EP systems)."""
    old = dict(_MOE_OVERRIDE)
    _MOE_OVERRIDE.update(expert_axis=expert_axis, token_axes=token_axes,
                         groups=groups)
    try:
        yield
    finally:
        _MOE_OVERRIDE.clear()
        _MOE_OVERRIDE.update(old)


def _constrain(x, spec):
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):   # no mesh in context (unit tests)
        return x


class MoEParams(NamedTuple):
    router: jax.Array        # [D, E]
    wi: jax.Array            # [E, D, 2, dffe]
    wo: jax.Array            # [E, dffe, D]
    shared: layers.MLPParams | None
    norm: jax.Array


def init_moe(key, d_model: int, cfg: MoEConfig, dtype) -> MoEParams:
    kr, ki, ko, ks = jax.random.split(key, 4)
    e, dffe = cfg.num_experts, cfg.d_ff_expert
    shared = None
    if cfg.num_shared:
        shared = layers.init_mlp(ks, d_model, cfg.num_shared * dffe, dtype)
    return MoEParams(
        router=jax.random.normal(kr, (d_model, e), jnp.float32) * d_model ** -0.5,
        wi=jax.random.normal(ki, (e, d_model, 2, dffe), dtype) * d_model ** -0.5,
        wo=jax.random.normal(ko, (e, dffe, d_model), dtype) * dffe ** -0.5,
        shared=shared,
        norm=layers.init_rmsnorm(d_model, dtype))


def capacity(num_tokens: int, cfg: MoEConfig) -> int:
    c = math.ceil(num_tokens * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)   # round up to a multiple of 8 (lane-friendly)


def _dispatch(flat, flat_router, router_w, cfg: MoEConfig, C: int):
    """Route one token group: returns (x_exp [E,C,D], slot_token,
    slot_weight, counts, probs).  Pure function — vmapped over groups."""
    T, D = flat.shape
    E, K = cfg.num_experts, cfg.top_k
    logits = jnp.einsum("td,de->te", flat_router.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)          # [T, K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)       # renormalize top-k

    # ---- sort-based dispatch --------------------------------------------
    eids = gate_idx.reshape(-1)                            # [T·K]
    tids = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)   # [T·K]
    wvals = gate_vals.reshape(-1)
    order = jnp.argsort(eids)                              # stable in jnp
    eids_s, tids_s, w_s = eids[order], tids[order], wvals[order]
    counts = jnp.bincount(eids, length=E)                  # [E]
    starts = jnp.cumsum(counts) - counts                   # exclusive cumsum
    pos_in_e = jnp.arange(T * K, dtype=jnp.int32) - starts[eids_s]
    keep = pos_in_e < C
    slot = jnp.where(keep, eids_s * C + pos_in_e, E * C)   # overflow → waste slot

    # slot → token map (+1 sentinel row of zeros for dropped slots)
    slot_token = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        jnp.where(keep, tids_s, T))[:E * C]
    slot_weight = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, w_s, 0.0))[:E * C]
    x_pad = jnp.concatenate([flat, jnp.zeros((1, D), flat.dtype)], 0)
    x_exp = x_pad[slot_token].reshape(E, C, D)
    return x_exp, slot_token, slot_weight, counts, probs


def moe_forward(p: MoEParams, x: jax.Array, cfg: MoEConfig,
                mask_in: jax.Array | None, p_drop: float):
    """x: [B, S, D] → (y [B, S, D], aux_loss scalar)."""
    B, S, D = x.shape
    h = layers.rmsnorm(p.norm, x)
    hm = layers.apply_site_mask(h, mask_in, p_drop)
    T = B * S
    E, K = cfg.num_experts, cfg.top_k
    ea = _MOE_OVERRIDE.get("expert_axis")
    ta = _MOE_OVERRIDE.get("token_axes")
    G = _MOE_OVERRIDE.get("groups", 1) or 1
    if T % G:
        G = 1
    Tg = T // G
    C = capacity(Tg, cfg)

    flat = hm.reshape(G, Tg, D)
    flat_router = h.reshape(G, Tg, D)           # router: unmasked, fp32
    if G > 1:
        flat = _constrain(flat, P(ta, None, None))
        flat_router = _constrain(flat_router, P(ta, None, None))
    x_exp, slot_token, slot_weight, counts, probs = jax.vmap(
        lambda f, fr: _dispatch(f, fr, p.router, cfg, C))(flat, flat_router)

    # ---- expert compute (dense, static, EP-shardable over E) ------------
    if ea or ta:
        x_exp = _constrain(x_exp, P(ta if G > 1 else None, ea,
                                    None if G > 1 else ta, None))
    gu = jnp.einsum("gecd,edhf->gechf", x_exp, p.wi.astype(x_exp.dtype),
                    preferred_element_type=jnp.float32)
    act = jax.nn.silu(gu[..., 0, :]) * gu[..., 1, :]
    y_exp = jnp.einsum("gecf,efd->gecd", act.astype(x_exp.dtype),
                       p.wo.astype(x_exp.dtype))
    if ea or ta:
        y_exp = _constrain(y_exp, P(ta if G > 1 else None, ea,
                                    None if G > 1 else ta, None))

    # ---- combine (scatter-add per group) ---------------------------------
    def combine(y_e, st, sw):
        return jnp.zeros((Tg + 1, D), jnp.float32).at[st].add(
            y_e.reshape(E * C, D).astype(jnp.float32) * sw[:, None])[:Tg]

    y_flat = jax.vmap(combine)(y_exp, slot_token, slot_weight)
    if G > 1:
        y_flat = _constrain(y_flat, P(ta, None, None))
    y = y_flat.reshape(B, S, D).astype(x.dtype)

    if p.shared is not None:
        y = y + layers.mlp_forward(p.shared, x, mask_in, p_drop)

    # Switch-style load-balance aux loss (global over groups).
    f = jnp.sum(counts, 0).astype(jnp.float32) / jnp.maximum(T * K, 1)
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = cfg.router_aux_weight * E * jnp.sum(f * pmean)
    return y, aux

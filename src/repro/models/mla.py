"""Multi-head Latent Attention (DeepSeek-V2) with absorbed decode.

Prefill/train: expand the compressed latent c_kv into per-head K/V and run
standard attention.  Decode: the **absorbed** form — queries are projected
into the 512-d latent space and attention runs directly against the cached
latents, so the KV cache per token is (kv_lora_rank + rope_dim) = 576 values
instead of 2·H·128 = 4096 (the MLA memory win, which is what makes
decode_32k × batch 128 fit).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import MLAConfig


class MLAParams(NamedTuple):
    norm: jax.Array       # [D]
    wq: jax.Array         # [D, H, nope+rope]
    w_dkv: jax.Array      # [D, kv_lora]
    kv_norm: jax.Array    # [kv_lora]
    w_krope: jax.Array    # [D, rope_dim]
    w_uk: jax.Array       # [kv_lora, H, nope]
    w_uv: jax.Array       # [kv_lora, H, v_dim]
    wo: jax.Array         # [H, v_dim, D]


def init_mla(key, d_model: int, n_heads: int, cfg: MLAConfig, dtype) -> MLAParams:
    ks = jax.random.split(key, 6)
    s = d_model ** -0.5
    qdim = cfg.nope_head_dim + cfg.rope_head_dim
    return MLAParams(
        norm=layers.init_rmsnorm(d_model, dtype),
        wq=jax.random.normal(ks[0], (d_model, n_heads, qdim), dtype) * s,
        w_dkv=jax.random.normal(ks[1], (d_model, cfg.kv_lora_rank), dtype) * s,
        kv_norm=layers.init_rmsnorm(cfg.kv_lora_rank, dtype),
        w_krope=jax.random.normal(ks[2], (d_model, cfg.rope_head_dim), dtype) * s,
        w_uk=jax.random.normal(ks[3], (cfg.kv_lora_rank, n_heads,
                                       cfg.nope_head_dim), dtype) * cfg.kv_lora_rank ** -0.5,
        w_uv=jax.random.normal(ks[4], (cfg.kv_lora_rank, n_heads,
                                       cfg.v_head_dim), dtype) * cfg.kv_lora_rank ** -0.5,
        wo=jax.random.normal(ks[5], (n_heads, cfg.v_head_dim, d_model), dtype) * s)


def mla_forward(p: MLAParams, x: jax.Array, positions: jax.Array,
                theta: float, cfg: MLAConfig, mask_in: jax.Array | None,
                p_drop: float, return_cache: bool = False):
    """Full-sequence MLA (train / prefill). x: [B, S, D]."""
    h = layers.rmsnorm(p.norm, x)
    h = layers.apply_site_mask(h, mask_in, p_drop)
    q = jnp.einsum("bsd,dnh->bsnh", h, p.wq.astype(h.dtype))
    q_nope = q[..., :cfg.nope_head_dim]
    q_rope = layers.rope(q[..., cfg.nope_head_dim:], positions, theta)
    c_kv = layers.rmsnorm(p.kv_norm,
                          jnp.einsum("bsd,dl->bsl", h, p.w_dkv.astype(h.dtype)))
    k_rope = layers.rope(
        jnp.einsum("bsd,dr->bsr", h, p.w_krope.astype(h.dtype))[:, :, None, :],
        positions, theta)[:, :, 0, :]
    k_nope = jnp.einsum("bsl,lnh->bsnh", c_kv, p.w_uk.astype(h.dtype))
    v = jnp.einsum("bsl,lnv->bsnv", c_kv, p.w_uv.astype(h.dtype))
    H = q.shape[2]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_rope.shape[:2], H, cfg.rope_head_dim))], -1)
    qf = jnp.concatenate([q_nope, q_rope], -1)
    o = layers.blockwise_attention(qf, k, v, causal=True)
    out = jnp.einsum("bsnv,nvd->bsd", o, p.wo.astype(o.dtype))
    if return_cache:
        return out, MLACache(c_kv=c_kv, k_rope=k_rope)
    return out


class MLACache(NamedTuple):
    c_kv: jax.Array     # [B, Smax, kv_lora]
    k_rope: jax.Array   # [B, Smax, rope_dim]


def init_cache(batch: int, max_len: int, cfg: MLAConfig, dtype) -> MLACache:
    return MLACache(jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
                    jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype))


def mla_decode(p: MLAParams, x: jax.Array, cache: MLACache, pos: jax.Array,
               theta: float, cfg: MLAConfig, mask_in: jax.Array | None,
               p_drop: float):
    """Absorbed single-token decode. x: [B, 1, D]."""
    B = x.shape[0]
    h = layers.rmsnorm(p.norm, x)
    h = layers.apply_site_mask(h, mask_in, p_drop)
    q = jnp.einsum("bsd,dnh->bsnh", h, p.wq.astype(h.dtype))[:, 0]   # [B,H,qdim]
    q_nope, q_rope = q[..., :cfg.nope_head_dim], q[..., cfg.nope_head_dim:]
    posv = jnp.full((1,), pos, jnp.int32)
    q_rope = layers.rope(q_rope[:, None], posv, theta)[:, 0]
    c_kv_new = layers.rmsnorm(
        p.kv_norm, jnp.einsum("bsd,dl->bsl", h, p.w_dkv.astype(h.dtype)))
    k_rope_new = layers.rope(
        jnp.einsum("bsd,dr->bsr", h, p.w_krope.astype(h.dtype))[:, :, None, :],
        posv, theta)[:, :, 0, :]
    cache = MLACache(
        jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_kv_new.astype(cache.c_kv.dtype), pos, 1),
        jax.lax.dynamic_update_slice_in_dim(cache.k_rope, k_rope_new.astype(cache.k_rope.dtype), pos, 1))
    # Absorb W_uk into the query: attention runs in latent space.
    q_lat = jnp.einsum("bnh,lnh->bnl", q_nope, p.w_uk.astype(q.dtype))  # [B,H,L]
    scale = (cfg.nope_head_dim + cfg.rope_head_dim) ** -0.5
    s = (jnp.einsum("bnl,btl->bnt", q_lat, cache.c_kv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bnr,btr->bnt", q_rope, cache.k_rope,
                      preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(cache.c_kv.shape[1]) <= pos
    s = jnp.where(valid[None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bnt,btl->bnl", w.astype(cache.c_kv.dtype), cache.c_kv,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    o = jnp.einsum("bnl,lnv->bnv", ctx_lat, p.w_uv.astype(x.dtype))
    out = jnp.einsum("bnv,nvd->bd", o, p.wo.astype(x.dtype))[:, None, :]
    return out, cache

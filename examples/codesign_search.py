"""The co-design framework end-to-end (paper §IV / Fig. 7), both targets.

FPGA target: scan reuse factors under the ZC706 DSP budget for the paper's
best models.  TPU target: scan mesh factorizations under the 16 GB HBM
budget for an assigned zoo architecture.

    PYTHONPATH=src python examples/codesign_search.py
"""

from repro.configs import get_config
from repro.dse import fpga_model as fm
from repro.dse import search, tpu_model
from repro.models.config import SHAPES

# ---------------------------------------------------------------- FPGA side
print("=== FPGA DSE (paper §IV): reuse factors under the DSP budget ===")
table = [
    search.Candidate(arch=fm.RNNArch(8, 1, "N"), n_samples=1,
                     metrics={"accuracy": 0.90, "ap": 0.62, "entropy": 0.15}),
    search.Candidate(arch=fm.RNNArch(8, 3, "YNY"),
                     metrics={"accuracy": 0.92, "ap": 0.69, "entropy": 0.30}),
    search.Candidate(arch=fm.RNNArch(8, 3, "YNN"),
                     metrics={"accuracy": 0.89, "ap": 0.59, "entropy": 0.60}),
    # §III-A cell axis: the 3-gate GRU datapath at 3/4 the DSP cost —
    # the co-design loop may trade it against the accuracy it gives up.
    search.Candidate(arch=fm.RNNArch(8, 3, "YNY"), cell="gru",
                     metrics={"accuracy": 0.91, "ap": 0.66, "entropy": 0.28}),
]
for mode in ("Opt-Latency", "Opt-Accuracy", "Opt-Entropy"):
    got = search.optimize(table, mode, batch=50)
    print(f"{mode:14s} → H={got.arch.hidden} NL={got.arch.num_layers} "
          f"B={got.arch.placement} S={got.n_samples} cell={got.cell} "
          f"R=({got.hw.r_x},{got.hw.r_h},{got.hw.r_d}) "
          f"lat={got.latency_s*1e3:.2f} ms "
          f"DSPs={fm.dsp_usage(got.arch, got.hw):.0f}/900")

# ----------------------------------------------------------------- TPU side
print("\n=== TPU DSE: mesh factorizations under the 16 GB HBM budget ===")
for arch in ("llama3-8b", "olmoe-1b-7b", "jamba-1.5-large-398b"):
    cfg = get_config(arch)
    rows = tpu_model.search_hw(cfg, SHAPES["train_4k"], chips=256)
    best = next((r for r in rows if r["feasible"]), None)
    if best is None:
        rows2 = tpu_model.search_hw(cfg, SHAPES["train_4k"], chips=256, pod=2)
        best = next((r for r in rows2 if r["feasible"]), None)
        pods = 2
    else:
        pods = 1
    if best is None:
        print(f"{arch:24s} infeasible even at 2 pods")
        continue
    hw = best["hw"]
    print(f"{arch:24s} → pods={pods} mesh=({hw.data}×{hw.model}) "
          f"mb={hw.microbatches} fsdp={hw.fsdp} "
          f"mem={best['mem']/1e9:.1f} GB t_step={best['t_step']:.2f}s "
          f"bound={'C' if best['t_compute']==best['t_step'] else 'M/X'}")
